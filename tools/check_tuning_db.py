"""CI gate: validate a ``tuning-db/v1`` database file.

Usage: python tools/check_tuning_db.py tuning-db/v1.json

Checks, in order (DESIGN.md §12):

1. **schema** — the file parses and declares ``schema: "tuning-db/v1"``
   (a stale or future schema is rejected loudly; the resolve path would
   silently fall back to heuristics, CI must not);
2. **env-fingerprint sanity** — the build environment block carries a
   non-empty backend string and a positive integer device count (the
   comparability half of every lookup key);
3. **key integrity** — every entry key has the full
   (shape_class, weights, mode, backend, device_count, mesh) tuple,
   the shape class parses as an ``n<i>d<j>`` bucket, the weights class
   is one of int/float/na, and backend/device_count agree with the
   database's own env fingerprint (entries measured elsewhere can never
   match a lookup made here);
4. **knob referential integrity against the current SolveSpec** — the
   stored knob names are a subset of the tunable set and the values
   actually construct a valid ``SolveSpec`` for the entry's mode (the
   strongest possible check: ``__post_init__`` re-runs every
   consolidated validation rule, so a field renamed or an enum retired
   since the DB was built fails here instead of at resolve time).

Exit codes: 0 valid, 1 invalid (one reason per line on stderr),
2 usage error.
"""
from __future__ import annotations

import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

WEIGHT_CLASSES = ("int", "float", "na")


def check(path: str) -> list[str]:
    """All validation failures of the database at ``path`` ([] = valid)."""
    import dataclasses

    from repro.coarsen.config import CoarsenConfig
    from repro.solve.spec import SolveSpec
    from repro.solve.tune import (
        SCHEMA,
        TUNABLE_KNOBS,
        _COARSEN_KNOBS,
        parse_shape_class,
    )

    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: cannot parse: {e}"]
    problems: list[str] = []
    schema = doc.get("schema") if isinstance(doc, dict) else None
    if schema != SCHEMA:
        return [f"{path}: unsupported schema {schema!r} (expected {SCHEMA!r})"]

    env = doc.get("env")
    if not isinstance(env, dict):
        problems.append(f"{path}: missing env fingerprint")
        env = {}
    backend = env.get("backend")
    if not isinstance(backend, str) or not backend:
        problems.append(f"{path}: env.backend is not a non-empty string")
    devices = env.get("device_count")
    if not isinstance(devices, int) or devices < 1:
        problems.append(f"{path}: env.device_count is not a positive int")

    entries = doc.get("entries")
    if not isinstance(entries, list):
        return problems + [f"{path}: entries is not a list"]
    allowed = set(TUNABLE_KNOBS) | {"coarsen"}
    for i, item in enumerate(entries):
        where = f"{path}: entry #{i}"
        key = item.get("key") if isinstance(item, dict) else None
        knobs = item.get("knobs") if isinstance(item, dict) else None
        if not isinstance(key, dict) or not isinstance(knobs, dict):
            problems.append(f"{where}: missing key/knobs objects")
            continue
        missing = [f for f in ("shape_class", "weights", "mode", "backend",
                               "device_count", "mesh") if f not in key]
        if missing:
            problems.append(f"{where}: key missing fields {missing}")
            continue
        if parse_shape_class(str(key["shape_class"])) is None:
            problems.append(
                f"{where}: unparseable shape_class {key['shape_class']!r}")
        if key["weights"] not in WEIGHT_CLASSES:
            problems.append(
                f"{where}: unknown weights class {key['weights']!r}")
        if isinstance(backend, str) and key["backend"] != backend:
            problems.append(
                f"{where}: key backend {key['backend']!r} != env backend "
                f"{backend!r} (mixed-environment database)")
        if isinstance(devices, int) and key["device_count"] != devices:
            problems.append(
                f"{where}: key device_count {key['device_count']!r} != "
                f"env device_count {devices}")
        unknown = set(knobs) - allowed
        if unknown:
            problems.append(
                f"{where}: unknown knob(s) {sorted(unknown)} "
                f"(tunable: {sorted(allowed)})")
            continue
        co = knobs.get("coarsen")
        if co is not None and (not isinstance(co, dict)
                               or set(co) - set(_COARSEN_KNOBS)):
            problems.append(f"{where}: bad coarsen block {co!r}")
            continue
        # Referential integrity: the knobs must construct a valid spec
        # for this mode under the *current* SolveSpec validation rules.
        try:
            kw = {k: v for k, v in knobs.items()
                  if k != "coarsen" and v is not None}
            if kw.get("dedupe") is None:
                kw.pop("dedupe", None)
            if co:
                kw["coarsen"] = CoarsenConfig(**co)
            spec = SolveSpec(mode=str(key["mode"]), **kw)
            dataclasses.replace(spec)  # re-runs __post_init__
        except (TypeError, ValueError) as e:
            problems.append(
                f"{where}: knobs do not validate against the current "
                f"SolveSpec ({e})")
    return problems


def main(argv: list[str]) -> int:
    if len(argv) != 1:
        print("usage: check_tuning_db.py tuning-db/v1.json", file=sys.stderr)
        return 2
    problems = check(argv[0])
    if problems:
        for p in problems:
            print(p, file=sys.stderr)
        return 1
    with open(argv[0]) as f:
        n = len(json.load(f)["entries"])
    print(f"{argv[0]}: OK ({n} entries)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
