"""CI gate: compare a bench run against committed baselines.

Usage::

    python tools/check_bench_regression.py \
        --baseline benchmarks/baselines --current <run-dir-or-file> \
        [--tolerance 0.5] [--update] [--history DIR]

``--current`` is a ``bench-rows/v2`` document (``BENCH_*.json``) or a
directory of them; each maps to ``<baseline-dir>/<stem>.json`` where the
stem drops the ``BENCH_`` prefix (``BENCH_solve_smoke.json`` →
``solve_smoke.json``).

Decision rule (DESIGN.md §11) — a time row (``unit == "us"``) regresses
iff **both** hold:

1. ``cur.median > base.median * (1 + tolerance)`` — the relative gate,
   sized for shared-runner noise (default 0.5 = 50%);
2. ``cur.median > base.median + base.iqr`` — the new median falls
   outside the baseline's own inter-quartile spread, so the move is
   larger than the baseline's recorded run-to-run noise.

Explicit non-failure semantics, reported per file:

- **first-run**: no committed baseline → pass (create it with
  ``--update``);
- **env-skip**: baseline backend or device_count differs from the
  current run → comparison is meaningless, skip;
- **new-row / gone-row**: rows added or removed are reported, never
  failed — renames land as an explicit baseline update in the same PR;
- non-time rows (speedups, byte volumes, counts) are provenance, not
  gates.

``--update`` rewrites the baselines from the current run (the committed
refresh path). ``--history DIR`` additionally appends every current
document to the append-only history store (``benchmarks/history.py``).
Exit codes: 0 ok/skip/first-run, 1 regression, 2 usage error.
"""
from __future__ import annotations

import json
import os
import sys

# repo root (parent of tools/) — so `python tools/check_bench_regression.py`
# finds the benchmarks/ namespace package without PYTHONPATH gymnastics
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

_PREFIX = "BENCH_"
DEFAULT_TOLERANCE = 0.5


def baseline_stem(current_path: str) -> str:
    name = os.path.basename(current_path)
    if name.startswith(_PREFIX):
        name = name[len(_PREFIX):]
    return name


def _load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc.get("rows"), list):
        raise ValueError(f"{path}: not a bench-rows document (no rows list)")
    return doc


def _env(doc: dict) -> tuple[str, int]:
    env = doc.get("env", {})
    return (
        str(env.get("backend", doc.get("backend", "unknown"))),
        int(env.get("device_count", doc.get("device_count", 0))),
    )


def _time_rows(doc: dict) -> dict[str, dict]:
    return {
        r["name"]: r
        for r in doc["rows"]
        if r.get("unit", "us") == "us" and "median" in r
    }


def check_doc(
    base: dict, cur: dict, *, tolerance: float = DEFAULT_TOLERANCE
) -> tuple[str, list[str]]:
    """Compare two bench documents.

    Returns ``(status, messages)`` with status one of ``"ok"``,
    ``"env-skip"``, ``"regression"``.
    """
    if _env(base) != _env(cur):
        return "env-skip", [
            f"env mismatch: baseline {_env(base)} vs current {_env(cur)}"
        ]
    b_rows, c_rows = _time_rows(base), _time_rows(cur)
    msgs: list[str] = []
    regressed = False
    for name, c in sorted(c_rows.items()):
        b = b_rows.get(name)
        if b is None:
            msgs.append(f"  new-row  {name}: {c['median']:.1f}us (no baseline)")
            continue
        b_med, c_med = float(b["median"]), float(c["median"])
        b_iqr = float(b.get("iqr", 0.0))
        rel_gate = c_med > b_med * (1.0 + tolerance)
        iqr_gate = c_med > b_med + b_iqr
        ratio = c_med / b_med if b_med > 0 else float("inf")
        if rel_gate and iqr_gate:
            regressed = True
            msgs.append(
                f"  REGRESSION {name}: {c_med:.1f}us vs baseline "
                f"{b_med:.1f}us (+iqr {b_iqr:.1f}us) = {ratio:.2f}x "
                f"(tolerance {1.0 + tolerance:.2f}x)"
            )
        else:
            msgs.append(f"  ok       {name}: {c_med:.1f}us "
                        f"({ratio:.2f}x of {b_med:.1f}us)")
    for name in sorted(set(b_rows) - set(c_rows)):
        msgs.append(f"  gone-row {name}: in baseline, absent from run")
    return ("regression" if regressed else "ok"), msgs


def _current_files(current: str) -> list[str]:
    if os.path.isdir(current):
        return sorted(
            os.path.join(current, f)
            for f in os.listdir(current)
            if f.startswith(_PREFIX) and f.endswith(".json")
        )
    return [current]


def main(argv: list[str]) -> int:
    from benchmarks.common import flag_value

    baseline_dir = flag_value(argv, "--baseline")
    current = flag_value(argv, "--current")
    if baseline_dir is None or current is None:
        print(__doc__, file=sys.stderr)
        return 2
    tol_s = flag_value(argv, "--tolerance")
    tolerance = float(tol_s) if tol_s is not None else DEFAULT_TOLERANCE
    if tolerance < 0:
        print("--tolerance must be >= 0", file=sys.stderr)
        return 2
    update = "--update" in argv
    history_dir = flag_value(argv, "--history")

    files = _current_files(current)
    if not files:
        print(f"{current}: no {_PREFIX}*.json documents found",
              file=sys.stderr)
        return 2

    failed = False
    for path in files:
        try:
            cur = _load(path)
        except (OSError, ValueError) as e:
            print(f"{path}: cannot load: {e}", file=sys.stderr)
            return 2
        stem = baseline_stem(path)
        bpath = os.path.join(baseline_dir, stem)
        if history_dir:
            from benchmarks.history import append

            append(history_dir, stem.removesuffix(".json"), cur)
        if not os.path.exists(bpath):
            if update:
                os.makedirs(baseline_dir, exist_ok=True)
                with open(bpath, "w") as f:
                    json.dump(cur, f, indent=1, sort_keys=True)
                print(f"{path}: first-run, baseline created at {bpath}")
            else:
                print(f"{path}: first-run, no baseline at {bpath} "
                      f"(pass; commit one with --update)")
            continue
        try:
            base = _load(bpath)
        except (OSError, ValueError) as e:
            print(f"{bpath}: cannot load baseline: {e}", file=sys.stderr)
            return 2
        status, msgs = check_doc(base, cur, tolerance=tolerance)
        print(f"{path} vs {bpath}: {status}")
        for m in msgs:
            print(m)
        if status == "regression":
            failed = True
        elif update:
            with open(bpath, "w") as f:
                json.dump(cur, f, indent=1, sort_keys=True)
            print(f"  baseline refreshed at {bpath}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
