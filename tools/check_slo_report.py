"""CI gate: validate an ``slo-report/v1`` JSON (and optionally a
metrics snapshot) emitted by ``repro.launch.loadgen``.

Usage::

    python tools/check_slo_report.py SLO_REPORT.json [--tcp]
        [--metrics METRICS.json]

Asserts the report parses, carries the ``slo-report/v1`` schema with
every block the loadgen promises (env, config, queries, latency_ms,
writer, slo), that the counts are internally consistent (answered +
dropped [+ rejected/errors] never exceeds offered; latency count equals
answered), and that the SLO verdict matches its failure list. With
``--tcp`` the report must be a ``--target`` run: a ``server`` block
with end-of-run status and ``serve.*`` metrics, a positive served-query
count, and a writer that actually applied wire writes. ``--metrics``
additionally validates a ``repro.obs`` metrics snapshot JSON (the
``--metrics-out`` artifact of ``serve_graph --serve``) — counters /
gauges / histograms with the summary fields the registry promises.

Exit code 0 on success; a one-line reason on stderr otherwise. This is
what keeps the uploaded SLO_*.json artifacts honest — a refactor that
silently empties the report fails CI here, not in a dashboard weeks
later.
"""
from __future__ import annotations

import argparse
import json
import sys

SCHEMA = "slo-report/v1"
_HIST_FIELDS = ("count", "sum", "min", "max", "p50", "p95", "p99")


def _load(path: str):
    try:
        with open(path) as f:
            return json.load(f), None
    except (OSError, ValueError) as e:
        return None, f"{path}: cannot parse: {e}"


def check_report(path: str, *, tcp: bool = False) -> str | None:
    """Return None when the report is valid, else the failure reason."""
    doc, err = _load(path)
    if err:
        return err
    if doc.get("schema") != SCHEMA:
        return f"{path}: schema {doc.get('schema')!r} != {SCHEMA!r}"
    for block in ("env", "config", "queries", "latency_ms", "writer", "slo"):
        if not isinstance(doc.get(block), dict):
            return f"{path}: missing block {block!r}"
    q = doc["queries"]
    for field in ("offered", "answered", "dropped", "timeouts"):
        if not isinstance(q.get(field), int) or q[field] < 0:
            return f"{path}: queries.{field} must be a non-negative int"
    accounted = (q["answered"] + q["dropped"]
                 + q.get("rejected", 0) + q.get("errors", 0))
    if accounted > q["offered"]:
        return (f"{path}: answered+dropped+rejected+errors {accounted} "
                f"> offered {q['offered']}")
    lat = doc["latency_ms"]
    for field in ("p50", "p95", "p99", "mean", "count"):
        if not isinstance(lat.get(field), (int, float)) or lat[field] < 0:
            return f"{path}: latency_ms.{field} must be a non-negative number"
    if lat["count"] != q["answered"]:
        return (f"{path}: latency_ms.count {lat['count']} != "
                f"queries.answered {q['answered']}")
    if lat["p50"] > lat["p99"] + 1e-9:
        return f"{path}: p50 {lat['p50']} > p99 {lat['p99']}"
    slo = doc["slo"]
    if not isinstance(slo.get("failures"), list):
        return f"{path}: slo.failures must be a list"
    if bool(slo.get("passed")) != (not slo["failures"]):
        return f"{path}: slo.passed inconsistent with slo.failures"
    if tcp:
        srv = doc.get("server")
        if not isinstance(srv, dict):
            return f"{path}: --tcp report has no server block"
        if not srv.get("target", "").startswith("tcp://"):
            return f"{path}: server.target {srv.get('target')!r} not tcp://"
        counters = srv.get("metrics", {}).get("counters", {})
        if counters.get("serve.queries", 0) <= 0:
            return f"{path}: server served no queries (serve.queries)"
        if counters.get("serve.writes", 0) <= 0:
            return f"{path}: server applied no writes (serve.writes)"
        if doc["writer"].get("updates", 0) <= 0:
            return f"{path}: wire writer applied no update batches"
        status = srv.get("status", {})
        if status.get("status") not in ("serving", "draining"):
            return f"{path}: server.status.status {status.get('status')!r}"
    elif "batcher" not in doc:
        return f"{path}: in-process report has no batcher block"
    return None


def check_metrics(path: str) -> str | None:
    """Validate a ``repro.obs`` metrics snapshot JSON."""
    doc, err = _load(path)
    if err:
        return err
    for block in ("counters", "gauges", "histograms"):
        if not isinstance(doc.get(block), dict):
            return f"{path}: missing block {block!r}"
    for name, val in doc["counters"].items():
        if not isinstance(val, int) or val < 0:
            return f"{path}: counter {name!r} must be a non-negative int"
    for name, s in doc["histograms"].items():
        if not isinstance(s, dict):
            return f"{path}: histogram {name!r} is not a summary dict"
        missing = [f for f in _HIST_FIELDS if f not in s]
        if missing:
            return f"{path}: histogram {name!r} missing {missing}"
    if not any(k.startswith("serve.") for k in doc["counters"]):
        return f"{path}: no serve.* counters — not a serving-tier snapshot"
    return None


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="check_slo_report")
    ap.add_argument("report")
    ap.add_argument("--tcp", action="store_true",
                    help="require the --target run shape (server block)")
    ap.add_argument("--metrics", default=None,
                    help="also validate this obs metrics snapshot JSON")
    args = ap.parse_args(argv)
    reason = check_report(args.report, tcp=args.tcp)
    if reason is None and args.metrics:
        reason = check_metrics(args.metrics)
    if reason is not None:
        print(reason, file=sys.stderr)
        return 1
    print(f"{args.report}: OK"
          + (f" (+ {args.metrics})" if args.metrics else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
