#!/usr/bin/env python
"""Lint: no ``src/`` module outside the shims may call a deprecated
entry point (``msf``, ``msf_weight``, ``msf_distributed``,
``StreamingMSF``, ``coarsen_msf``).

The deprecated names stay importable for external callers, but internal
code must go through ``repro.solve`` (or the internal builders the solve
engines use) — otherwise every internal call would emit the shim's
``DeprecationWarning`` and the "shims are thin" invariant would quietly
rot. A plain ``grep`` false-positives on the many docstrings that show
the historical call patterns, so this walks the AST and flags only real
``Call`` nodes (by bare name or attribute, e.g. ``module.msf(...)``).

Exits 1 with a file:line listing when a violation exists. Wired into CI
and ``tests/test_no_deprecated_calls.py`` (tier-1).
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

DEPRECATED = {"msf", "msf_weight", "msf_distributed", "StreamingMSF", "coarsen_msf"}

#: The shim modules themselves (definitions + their mutual delegation,
#: e.g. ``msf_weight`` → ``msf``) — everything else in src/ is checked.
ALLOWED = {
    Path("src/repro/core/msf.py"),
    Path("src/repro/core/msf_dist.py"),
    Path("src/repro/stream/engine.py"),
    Path("src/repro/coarsen/engine.py"),  # defines the coarsen_msf shim
}


def _call_name(node: ast.Call) -> str | None:
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def check(root: Path) -> list[str]:
    violations = []
    for path in sorted((root / "src").rglob("*.py")):
        rel = path.relative_to(root)
        if rel in ALLOWED:
            continue
        tree = ast.parse(path.read_text(), filename=str(rel))
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                name = _call_name(node)
                if name in DEPRECATED:
                    violations.append(
                        f"{rel}:{node.lineno}: call to deprecated entry "
                        f"point {name}(...) — route through repro.solve"
                    )
    return violations


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(".")
    violations = check(root)
    if violations:
        print("\n".join(violations))
        print(
            f"\n{len(violations)} deprecated entry-point call(s) in src/ "
            f"outside the shims ({', '.join(str(p) for p in sorted(ALLOWED))})"
        )
        return 1
    print("OK: src/ is free of deprecated entry-point calls outside the shims")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
