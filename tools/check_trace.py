"""CI gate: validate an exported Chrome-trace/Perfetto JSON.

Usage: python tools/check_trace.py TRACE.json [expected-span ...]

Asserts the file parses, follows the Trace Event Format the exporter
promises (``traceEvents`` list; complete events carry ``ph: "X"`` with
numeric ``ts``/``dur`` and a ``pid``/``tid``), and — when expected span
names are given — that each appears at least once. Exit code 0 on
success; a one-line reason on stderr otherwise. Keeps CI honest that the
``--trace`` artifact uploaded next to BENCH_*.json actually opens in
ui.perfetto.dev / chrome://tracing.
"""
from __future__ import annotations

import json
import sys


def check(path: str, expected: list[str]) -> str | None:
    """Return None when the trace is valid, else the failure reason."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return f"{path}: cannot parse: {e}"
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return f"{path}: no traceEvents"
    complete = [e for e in events if e.get("ph") == "X"]
    if not complete:
        return f"{path}: no complete ('X') events"
    for e in complete:
        for field in ("name", "ts", "dur", "pid", "tid"):
            if field not in e:
                return f"{path}: event missing {field!r}: {e}"
        if not isinstance(e["ts"], (int, float)) or e["ts"] < 0:
            return f"{path}: bad ts in {e}"
        if not isinstance(e["dur"], (int, float)) or e["dur"] < 0:
            return f"{path}: bad dur in {e}"
    names = {e["name"] for e in complete}
    missing = [want for want in expected if want not in names]
    if missing:
        return (
            f"{path}: expected spans absent: {missing} "
            f"(have: {sorted(names)})"
        )
    return None


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_trace.py TRACE.json [expected-span ...]",
              file=sys.stderr)
        return 2
    reason = check(argv[0], argv[1:])
    if reason is not None:
        print(reason, file=sys.stderr)
        return 1
    with open(argv[0]) as f:
        n = len(json.load(f)["traceEvents"])
    print(f"{argv[0]}: OK ({n} events)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
