"""Autotune CLI: refresh / verify the ``tuning-db/v1`` database.

Build (the CI ``autotune`` job's refresh step, DESIGN.md §12)::

    python tools/tune.py --smoke --out tuning-db/v1.json \
        [--classes rmat,grid,components] [--modes flat,coarsen] \
        [--iters 3] [--warmup 1] [--seed 0] [--merge tuning-db/v1.json]

Runs the candidate sweep (enumerate → cost-prune → measure) over the CI
graph classes for each requested mode and writes the winners as one
``tuning-db/v1`` document. ``--smoke`` shrinks the graphs and the
candidate space to the CI-sized sweep; ``--merge PATH`` seeds the
database from an existing file first (the rolling-cache refresh: keys
re-tuned this run are overwritten, others survive).

Verify (the parity gate)::

    python tools/tune.py --verify tuning-db/v1.json [--smoke]

Loads the database, then solves every graph class with
``tuning="db"`` and ``tuning="off"`` asserting identical forest weight
and MSF edge set — the proof that consulting the database never changes
an answer, only its latency.

Exit codes: 0 ok, 1 parity/tuning failure, 2 usage error.
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

SMOKE_SCALE = 8
FULL_SCALE = 12
DEFAULT_CLASSES = "rmat,grid,components"
DEFAULT_MODES = "flat,coarsen"


def _flag(argv, flag, default=None):
    from benchmarks.common import flag_value

    v = flag_value(argv, flag)
    return v if v is not None else default


def graph_classes(names: list[str], smoke: bool):
    """The CI graph classes (the bench smoke inputs) by name."""
    from repro.graphs.generators import (
        components_graph,
        grid_road_graph,
        rmat_graph,
    )

    scale = SMOKE_SCALE if smoke else FULL_SCALE
    side = 32 if smoke else 128
    out = []
    for name in names:
        if name == "rmat":
            out.append((f"rmat_s{scale}",
                        rmat_graph(scale, 4 if smoke else 8, seed=9)))
        elif name == "grid":
            out.append((f"grid_{side}x{side}",
                        grid_road_graph(side, side, seed=2)))
        elif name == "components":
            k, sz = (8, 32) if smoke else (32, 128)
            out.append((f"components_{k}x{sz}",
                        components_graph(k, sz, seed=5)))
        else:
            raise SystemExit(f"unknown graph class {name!r} "
                             f"(expected from: {DEFAULT_CLASSES})")
    return out


def build(argv: list[str]) -> int:
    from repro.solve.tune import TuningDB, tune

    out = _flag(argv, "--out")
    if out is None:
        print(__doc__, file=sys.stderr)
        return 2
    smoke = "--smoke" in argv
    iters = int(_flag(argv, "--iters", "3"))
    warmup = int(_flag(argv, "--warmup", "1"))
    seed = int(_flag(argv, "--seed", "0"))
    modes = [m for m in _flag(argv, "--modes", DEFAULT_MODES).split(",") if m]
    classes = [c for c in
               _flag(argv, "--classes", DEFAULT_CLASSES).split(",") if c]
    merge = _flag(argv, "--merge")

    db = TuningDB.load(merge) if merge and os.path.exists(merge) else TuningDB()
    space = "smoke" if smoke else "full"
    for gname, g in graph_classes(classes, smoke):
        for mode in modes:
            res = tune(g, mode, db=db, space=space,
                       iters=iters, warmup=warmup, seed=seed)
            best = res.ranking[0]
            print(
                f"{gname:>22} {mode:>8}: key={res.key.shape_class}/"
                f"{res.key.weights} winner median={best.median_us:.1f}us "
                f"iqr={best.iqr_us:.1f}us "
                f"(measured {len(res.ranking)}, pruned {res.pruned})"
            )
    path = db.save(out)
    print(f"# tuning DB: {len(db)} entries -> {path}")
    return 0


def verify(argv: list[str]) -> int:
    import numpy as np

    from repro.solve import SolveSpec, plan, set_tuning_db
    from repro.solve.tune import TuningDB

    path = _flag(argv, "--verify")
    db = TuningDB.load(path)  # loud on schema/shape problems
    set_tuning_db(db)
    smoke = "--smoke" in argv
    modes = [m for m in _flag(argv, "--modes", DEFAULT_MODES).split(",") if m]
    classes = [c for c in
               _flag(argv, "--classes", DEFAULT_CLASSES).split(",") if c]
    failures = 0
    for gname, g in graph_classes(classes, smoke):
        for mode in modes:
            r_off = plan(g, SolveSpec(mode=mode, tuning="off")).solve()
            r_db = plan(g, SolveSpec(mode=mode, tuning="db")).solve()
            w_ok = abs(float(r_off.weight) - float(r_db.weight)) <= max(
                1.0, 1e-6 * abs(float(r_off.weight)))
            eids = lambda r: set(
                np.asarray(r.msf_eids)[: int(r.n_msf_edges)].tolist())
            e_ok = eids(r_off) == eids(r_db)
            status = "ok" if (w_ok and e_ok) else "PARITY FAILURE"
            print(f"{gname:>22} {mode:>8}: tuning=db vs off {status} "
                  f"(weight {r_db.weight:.1f} vs {r_off.weight:.1f})")
            if not (w_ok and e_ok):
                failures += 1
    if failures:
        print(f"# {failures} parity failure(s)", file=sys.stderr)
        return 1
    print(f"# tuning=db parity OK against {path} ({len(db)} entries)")
    return 0


def main(argv: list[str]) -> int:
    if "--verify" in argv:
        return verify(argv)
    if "--out" in argv:
        return build(argv)
    print(__doc__, file=sys.stderr)
    return 2


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
