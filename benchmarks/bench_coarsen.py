"""Coarsening (contract+filter levels) vs the flat AS solve.

Rows per graph family (rmat at increasing scale, grid road, components):
- ``coarsen_*`` — ``CoarsenMSF`` end-to-end latency (levels + residual),
  with ``speedup_vs_flat`` and the level schedule in the derived field;
- ``flat_*``    — ``core.msf`` over the same graph (what the seed did).

``--smoke`` runs one tiny rmat and *asserts* flat/coarsen parity (weight
and edge set) — the CI kernel-regression tripwire: a broken contraction
or dedupe kernel fails the step, not just a slower benchmark.
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import row, timeit
from repro.coarsen import CoarsenConfig, CoarsenMSF
from repro.core.msf import msf
from repro.graphs import grid_road_graph, rmat_graph
from repro.graphs.generators import components_graph

RMAT_SCALES = [12, 13, 14]  # edge factor 8; largest scale is the headline
EDGE_FACTOR = 8
SMOKE_SCALE = 8


def _eid_set(r):
    return set(np.asarray(r.msf_eids)[: int(r.n_msf_edges)].tolist())


def _bench_graph(name: str, g, cfg: CoarsenConfig, check: bool = False):
    eng = CoarsenMSF(cfg)
    if check:
        flat_r, co_r = msf(g), eng(g)
        assert abs(float(flat_r.weight) - float(co_r.weight)) <= max(
            1.0, 1e-6 * float(flat_r.weight)
        ), (float(flat_r.weight), float(co_r.weight))
        assert _eid_set(flat_r) == _eid_set(co_r), "coarsen MSF edge set drifted"
    t_flat = timeit(lambda: msf(g), iters=3)
    t_co = timeit(lambda: eng(g), iters=3)
    st = eng.last_stats
    sched = "|".join(f"{l.n}/{l.m}>{l.n_next}/{l.m_next}" for l in st.levels)
    return [
        row(
            f"coarsen_{name}",
            t_co * 1e6,
            f"speedup_vs_flat={t_flat / t_co:.2f}x;levels={len(st.levels)};"
            f"schedule={sched};residual_n={st.residual_n};"
            f"residual_m={st.residual_m}",
        ),
        row(f"flat_{name}", t_flat * 1e6, f"edges={g.num_directed_edges}"),
    ]


def run_rows(smoke: bool = False):
    if smoke:
        g = rmat_graph(SMOKE_SCALE, 4, seed=9)
        cfg = CoarsenConfig(rounds_per_level=2, cutoff=32)
        return _bench_graph(f"rmat_s{SMOKE_SCALE}_e4_smoke", g, cfg, check=True)
    out = []
    for scale in RMAT_SCALES:
        g = rmat_graph(scale, EDGE_FACTOR, seed=9)
        cfg = CoarsenConfig(rounds_per_level=2, cutoff=max(128, g.n >> 4))
        out += _bench_graph(f"rmat_s{scale}_e{EDGE_FACTOR}", g, cfg)
    g = grid_road_graph(128, 128, seed=2)
    out += _bench_graph(
        "grid_128x128", g, CoarsenConfig(rounds_per_level=2, cutoff=1024)
    )
    g = components_graph(64, 256, seed=5)
    out += _bench_graph(
        "components_64x256", g, CoarsenConfig(rounds_per_level=2, cutoff=1024)
    )
    return out


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv[1:]
    print("\n".join(run_rows(smoke=smoke)))
    if smoke:
        print("# coarsen smoke: flat/coarsen parity OK", file=sys.stderr)
