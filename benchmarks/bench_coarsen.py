"""Coarsening (contract+filter levels) vs the flat AS solve.

Every measured path runs through the unified ``repro.solve`` API
(``plan(graph_or_part, SolveSpec(...)).solve()``); only the historical
PR-2 baseline reconstruction reaches into engine internals.

Rows per graph family (rmat at increasing scale, grid road, components):
- ``coarsen_*`` — coarsen-mode plan end-to-end latency (levels +
  residual), with ``speedup_vs_flat`` and the level schedule in the
  derived field;
- ``flat_*``    — a flat plan over the same graph (what the seed did).

``--fused`` adds ``fused_*`` rows: the one-jit device-resident level
pipeline (``CoarsenConfig(fused=True)``) against the PR-2 host-round-trip
level path over the same graphs, with ``speedup_vs_host_levels`` as the
headline derived metric.

``--dist`` adds ``dist_fused_*`` rows: the in-mesh fused level pipeline
(``SolveSpec(mode="dist", coarsen=...)``, dedupe pinned to
"device" so the measured path is the zero-round-trip one on every
backend) against the PR-2 host-prelude pipeline
(``precontract_partition`` + Fig-2 solve + ``merge_distributed``) on the
largest 2D mesh the available devices support. The derived fields carry
``host_repartitions`` — 0 for the in-mesh path vs L (one per level) for
the prelude baseline, the acceptance metric of the distributed fused
levels.

``--smoke`` runs one tiny rmat and *asserts* flat/coarsen parity (weight
and edge set) — the CI kernel-regression tripwire: a broken contraction
or dedupe kernel fails the step, not just a slower benchmark. With
``--fused`` the fused pipeline parity is asserted too; with ``--dist``
both distributed pipelines' parity and the zero-round-trip stat.

``--json PATH`` writes the rows as a BENCH trajectory point (CI artifact).
"""
from __future__ import annotations

import dataclasses
import sys

import numpy as np

from benchmarks.common import assert_msf_parity as _assert_parity
from benchmarks.common import cost_fragment, emit, measure
from repro.coarsen import CoarsenConfig
from repro.graphs import grid_road_graph, rmat_graph
from repro.graphs.generators import components_graph
from repro.solve import SolveSpec, plan

RMAT_SCALES = [12, 13, 14]  # edge factor 8; largest scale is the headline
EDGE_FACTOR = 8
SMOKE_SCALE = 8


def _bench_graph(name: str, g, cfg: CoarsenConfig, check: bool = False):
    p_flat = plan(g, SolveSpec())
    p_co = plan(g, SolveSpec(mode="coarsen", coarsen=cfg))
    rep = p_co.solve()  # warms the jit caches AND supplies the level stats
    if check:
        _assert_parity(p_flat.solve(), rep, f"coarsen_{name}")
    m_flat = measure(f"flat_{name}", lambda: p_flat.solve(), iters=3)
    m_co = measure(f"coarsen_{name}", lambda: p_co.solve(), warmup=0, iters=3)
    t_flat, t_co = m_flat.median / 1e6, m_co.median / 1e6
    sched = "|".join(f"{l.n}/{l.m}>{l.n_next}/{l.m_next}" for l in rep.levels)
    last = rep.levels[-1] if rep.levels else None
    m_und = int(np.asarray(g.valid).sum()) // 2
    return [
        m_co.with_derived(
            f"speedup_vs_flat={t_flat / t_co:.2f}x;levels={len(rep.levels)};"
            f"schedule={sched};"
            f"residual_n={last.n_next if last else g.n};"
            f"residual_m={last.m_next if last else m_und}"
            + cost_fragment(rep, t_co)
        ),
        m_flat.with_derived(
            f"edges={g.num_directed_edges}"
            + cost_fragment(p_flat.solve(), t_flat)
        ),
    ]


def _pr2_run_levels(g, cfg: CoarsenConfig):
    """The PR-2 level loop, reconstructed faithfully from its pieces: the
    directed 2E concatenation into ``contract_level`` and the numpy
    lexsort filter, with every level round-tripping arrays through the
    host. This is the *historical* baseline the fused path replaces —
    the current unfused engine already shares this PR's symmetric
    contraction, so it is benched separately (``host_levels_*``)."""
    from repro.coarsen.contract import contract_level
    from repro.coarsen.engine import _IMAX, _canonical_host, _next_pow2
    from repro.coarsen.filter import filter_level_host
    from repro.stream.service import next_pow2

    lo, hi, w, eid, valid, m_cur = _canonical_host(g)
    n_cur, levels = g.n, 0
    while levels < cfg.max_levels and n_cur > cfg.cutoff and m_cur > 0:
        n_pad = next_pow2(n_cur, floor=8)
        res = contract_level(
            np.concatenate([lo, hi]), np.concatenate([hi, lo]),
            np.concatenate([w, w]), np.concatenate([eid, eid]),
            np.concatenate([valid, valid]),
            n=n_pad, rounds=cfg.rounds_per_level, pack=True,
        )
        n_next = int(res.n_next) - (n_pad - n_cur)
        if n_next == n_cur:
            break
        l2, h2, w2, e2 = filter_level_host(
            lo, hi, w, eid, valid, np.asarray(res.new_ids), n_cur
        )
        m_next = len(l2)
        pad = _next_pow2(m_next)
        lo = np.zeros(pad, np.int32)
        hi = np.zeros(pad, np.int32)
        w = np.full(pad, np.inf, np.float32)
        eid = np.full(pad, _IMAX, np.int32)
        lo[:m_next], hi[:m_next] = l2, h2
        w[:m_next], eid[:m_next] = w2, e2
        valid = np.arange(pad) < m_next
        n_cur, m_cur = n_next, m_next
        levels += 1
    return n_cur, m_cur


def _bench_fused(name: str, g, cfg: CoarsenConfig, check: bool = False):
    """Fused one-jit levels vs the PR-2 host-round-trip level path and the
    current unfused host path (levels only — the residual solve is
    identical across all three)."""
    from repro.coarsen.engine import run_levels

    cfg_fused = dataclasses.replace(cfg, fused=True, dedupe="auto")
    cfg_host = dataclasses.replace(cfg, fused=False, dedupe="host")
    if check:
        _assert_parity(
            plan(g, SolveSpec()).solve(),
            plan(g, SolveSpec(mode="coarsen", coarsen=cfg_fused)).solve(),
            f"fused_{name}",
        )
    m_pr2 = measure(f"pr2_levels_{name}", lambda: _pr2_run_levels(g, cfg),
                    iters=3, derived=f"edges={g.num_directed_edges}")
    m_host = measure(f"host_levels_{name}", lambda: run_levels(g, cfg_host),
                     iters=3, derived=f"edges={g.num_directed_edges}")
    m_fused = measure(f"fused_levels_{name}", lambda: run_levels(g, cfg_fused),
                      iters=3)
    t_pr2, t_host, t_fused = (
        m_pr2.median / 1e6, m_host.median / 1e6, m_fused.median / 1e6,
    )
    pre = run_levels(g, cfg_fused)
    st = pre.stats
    return [
        m_fused.with_derived(
            f"speedup_vs_pr2={t_pr2 / t_fused:.2f}x;"
            f"speedup_vs_host={t_host / t_fused:.2f}x;"
            f"levels={len(st.levels)};residual_n={st.residual_n};"
            f"residual_m={st.residual_m}"
        ),
        m_pr2,
        m_host,
    ]


def _dist_mesh():
    """Largest 2D mesh the available devices support (conftest's policy)."""
    import jax

    from repro.compat import make_mesh

    n = jax.device_count()
    shape = (2, 4) if n >= 8 else (2, 2) if n >= 4 else (1, 2) if n >= 2 else (1, 1)
    return make_mesh(shape, ("data", "model")), shape


def _bench_dist(name: str, g, cfg: CoarsenConfig, check: bool = False):
    """In-mesh fused levels (zero per-level host re-partitions) vs the PR-2
    host-prelude pipeline (L round-trips + one residual re-partition)."""
    from repro.coarsen import merge_distributed, precontract_partition
    from repro.graphs.partition import partition_edges_2d

    mesh, (rows, cols) = _dist_mesh()
    part0 = partition_edges_2d(g, rows, cols)
    cfg_mesh = dataclasses.replace(cfg, fused=True, dedupe="device")
    p_mesh = plan(part0, SolveSpec(mode="dist", coarsen=cfg_mesh), mesh=mesh)

    def run_inmesh():
        return p_mesh.solve()

    cfg_host = dataclasses.replace(cfg, fused=False, dedupe="host")
    # Build the residual driver once: the prelude is deterministic, so the
    # per-iteration re-partition hits the same shapes/executable.
    part_r, prelude = precontract_partition(g, rows, cols, config=cfg_host)
    p_res = plan(
        part_r, SolveSpec(mode="dist", shortcut="csp", capacity=4096),
        mesh=mesh,
    )

    def run_prelude():
        p, pre = precontract_partition(g, rows, cols, config=cfg_host)
        r = p_res.solve(p.src_row, p.dst_col, p.w, p.eid, p.valid)
        return merge_distributed(pre, r.raw)

    if check:
        flat_r = plan(g, SolveSpec()).solve()
        rep = run_inmesh()
        _assert_parity(flat_r, rep, f"dist_fused_{name}")
        assert rep.host_roundtrips == 0, "in-mesh path round-tripped"
        assert len(rep.levels) >= 1, "in-mesh contraction never ran"
        _assert_parity(flat_r, run_prelude(), f"dist_prelude_{name}")
    m_mesh = measure(f"dist_fused_{name}", run_inmesh, iters=3)
    m_pre = measure(
        f"dist_prelude_{name}", run_prelude, iters=3,
        derived=f"host_repartitions={len(prelude.stats.levels)};"
        f"mesh={rows}x{cols}",
    )
    t_mesh, t_pre = m_mesh.median / 1e6, m_pre.median / 1e6
    st = p_mesh.driver.last_stats
    return [
        m_mesh.with_derived(
            f"speedup_vs_prelude={t_pre / t_mesh:.2f}x;"
            f"host_repartitions=0;levels={len(st.levels)};"
            f"residual_n={st.residual_n};residual_iters={st.residual_iters};"
            f"mesh={rows}x{cols}"
        ),
        m_pre,
    ]


def run_rows(smoke: bool = False, fused: bool = False, dist: bool = False):
    if smoke:
        g = rmat_graph(SMOKE_SCALE, 4, seed=9)
        cfg = CoarsenConfig(rounds_per_level=2, cutoff=32)
        out = _bench_graph(f"rmat_s{SMOKE_SCALE}_e4_smoke", g, cfg, check=True)
        if fused:
            out += _bench_fused(
                f"rmat_s{SMOKE_SCALE}_e4_smoke", g, cfg, check=True
            )
        if dist:
            out += _bench_dist(
                f"rmat_s{SMOKE_SCALE}_e4_smoke", g, cfg, check=True
            )
        return out
    out = []
    for scale in RMAT_SCALES:
        g = rmat_graph(scale, EDGE_FACTOR, seed=9)
        cfg = CoarsenConfig(rounds_per_level=2, cutoff=max(128, g.n >> 4))
        out += _bench_graph(f"rmat_s{scale}_e{EDGE_FACTOR}", g, cfg)
        if fused:
            out += _bench_fused(f"rmat_s{scale}_e{EDGE_FACTOR}", g, cfg)
        if dist:
            out += _bench_dist(f"rmat_s{scale}_e{EDGE_FACTOR}", g, cfg)
    g = grid_road_graph(128, 128, seed=2)
    cfg = CoarsenConfig(rounds_per_level=2, cutoff=1024)
    out += _bench_graph("grid_128x128", g, cfg)
    if fused:
        out += _bench_fused("grid_128x128", g, cfg)
    if dist:
        out += _bench_dist("grid_128x128", g, cfg)
    g = components_graph(64, 256, seed=5)
    out += _bench_graph(
        "components_64x256", g, CoarsenConfig(rounds_per_level=2, cutoff=1024)
    )
    return out


if __name__ == "__main__":
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    fused = "--fused" in argv
    dist = "--dist" in argv
    emit(run_rows(smoke=smoke, fused=fused, dist=dist), argv)
    if smoke:
        tag = "".join(
            t for t, on in ((" (+fused)", fused), (" (+dist)", dist)) if on
        )
        print(f"# coarsen smoke: flat/coarsen parity OK{tag}", file=sys.stderr)
