"""Paper Fig 5/6: strong scaling.

Wall-clock scaling needs real chips; what the dry-run *can* measure is the
thing the paper's scaling is made of: per-device communication volume and
per-device work as p grows. We lower the distributed MSF engine for
p ∈ {1, 4, 16, 64} (2D grids) on a fixed graph shape and report per-device
collective bytes per AS iteration (from the compiled HLO) plus per-device
edge work — the strong-scaling curve of the paper's Fig 2 schedule.
Single-device wall time on the real graphs (Fig 5/6 inputs, scaled down)
anchors the absolute numbers.
"""
from __future__ import annotations

import subprocess
import sys
import os
import json

from benchmarks.common import emit, measure, point
from repro.core.msf import msf
from repro.graphs import grid_road_graph, rmat_graph

_CHILD = r"""
import sys, json
import jax
from repro.launch.mesh import make_mesh
from repro.launch.cells import build_msf_cell
from repro.configs.base import ShapeCell
from repro.analysis.hlo_analyzer import analyze
r, c, n, m = map(int, sys.argv[1:5])
mesh = make_mesh((r, c), ("data", "model"))
cell = build_msf_cell(ShapeCell(name="bench", kind="msf", n_nodes=n, n_edges=m), mesh)
co = cell.fn.lower(*cell.abstract_args).compile()
res = analyze(co.as_text())
print(json.dumps(dict(p=r*c, coll=res["collective_bytes"], bytes=res["bytes"])))
"""


def run_rows():
    out = []
    # absolute anchor: single-device iteration time, road-like + rmat
    for nm, g in [("road_300x300", grid_road_graph(300, 300, seed=0)),
                  ("rmat_s14_e8", rmat_graph(14, 8, seed=1))]:
        r = msf(g)
        m = measure(f"fig5_single_device_{nm}", lambda: msf(g))
        out.append(m.with_derived(
            f"iters={int(r.iterations)};"
            f"per_iter_us={m.median / max(int(r.iterations), 1):.0f}"
        ))
    # communication-volume strong scaling (per AS iteration, per device)
    n, m = 1 << 20, (1 << 20) * 8
    for (rr, cc) in [(1, 1), (2, 2), (4, 4), (8, 8)]:
        env = dict(os.environ, PYTHONPATH="src",
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={rr*cc}")
        res = subprocess.run([sys.executable, "-c", _CHILD,
                              str(rr), str(cc), str(n), str(m)],
                             capture_output=True, text=True, env=env, timeout=560)
        d = json.loads(res.stdout.strip().splitlines()[-1])
        out.append(point(
            f"fig5_commvolume_p{d['p']}", d["coll"], "bytes",
            f"collective_bytes_per_device_per_iter;n={n};m={m}",
        ))
    return out


if __name__ == "__main__":
    emit(run_rows(), sys.argv[1:])
