"""Paper Table I / §VII-A analogue: MSF over the graph-family suite with
correctness, iteration counts, and throughput (directed edges/s)."""
from __future__ import annotations

from benchmarks.common import emit, measure
from repro.core.connectivity import connected_components
from repro.core.msf import msf
from repro.graphs import grid_road_graph, random_graph, rmat_graph
from repro.graphs.generators import components_graph
from repro.graphs.structures import nx_free_msf_weight, nx_free_n_components


def run_rows():
    suite = {
        "social_rmat_s15_e16": rmat_graph(15, 16, seed=2),
        "road_grid_250": grid_road_graph(250, 250, seed=3),
        "uniform_1e5": random_graph(1 << 16, 1 << 19, seed=4),
        "components_16x4k": components_graph(16, 4096, seed=5),
    }
    out = []
    for nm, g in suite.items():
        oracle = nx_free_msf_weight(g)
        r = msf(g)
        assert abs(float(r.weight) - oracle) < max(1.0, 1e-6 * oracle), nm
        m = measure(f"table1_msf_{nm}", lambda: msf(g), iters=2)
        meps = g.num_directed_edges / (m.median / 1e6) / 1e6
        out.append(m.with_derived(
            f"iters={int(r.iterations)};Medges_per_s={meps:.1f}"
        ))
        cc = connected_components(g)
        assert int(cc.n_components) == nx_free_n_components(g), nm
        out.append(measure(
            f"table1_cc_{nm}", lambda: connected_components(g), iters=2,
            derived=f"ncc={int(cc.n_components)};iters={int(cc.iterations)}",
        ))
    return out


if __name__ == "__main__":
    import sys

    emit(run_rows(), sys.argv[1:])
