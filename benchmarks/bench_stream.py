"""Streaming MSF engine vs full recompute, plus batched query throughput.

Driven through the unified ``repro.solve`` API (stream plans vs flat
plans); the deprecated ``StreamingMSF`` construction this file used to
demonstrate lives on only in the shim-parity suites.

Rows:
- ``stream_insert_*``    — median latency of one ``plan.update`` batch
  (the sparsification path: MSF over ≤ (n−1) + B padded union edges);
- ``stream_recompute_*`` — full flat solve over the accumulated edge set
  at the same point in the stream (what the seed had to do per update);
- ``stream_queries_*``   — fused snapshot-gather query throughput.

``--smoke`` streams a tiny graph and *asserts* the engine's forest weight
matches a full recompute (for both the flat and the coarsen-recompute
union paths) — the CI tripwire for the sparsification/union machinery —
then runs a **delete-heavy phase**: a third of the inserted pairs are
deleted through the replacement-edge reservoir and the post-replacement
snapshot must be non-stale (``n_unhealed == 0``) and weight-identical to
a flat recompute over the surviving multiset (DESIGN.md §6.4).
``--json PATH`` writes the rows as a BENCH trajectory point.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.common import emit, from_samples, measure
from repro.graphs.generators import rmat_graph
from repro.graphs.structures import from_edges
from repro.launch.serve_graph import undirected_edges
from repro.solve import SolveSpec, plan

SCALE = 14
EDGE_FACTOR = 8
BATCH = 2048
QUERY_BATCH = 1 << 14


SMOKE_SCALE = 10
SMOKE_BATCH = 256


def run_smoke_rows():
    """Tiny stream with parity asserts; one row per engine flavour."""
    from repro.coarsen import CoarsenConfig

    n = 1 << SMOKE_SCALE
    g_full = rmat_graph(SMOKE_SCALE, 4, seed=9)
    lo, hi, w = undirected_edges(g_full)
    plans = {
        "flat": plan(n, SolveSpec(mode="stream", batch_capacity=SMOKE_BATCH)),
        # cutoff far below n so the rebuild runs real contraction levels
        "coarsen": plan(
            n,
            SolveSpec(
                mode="stream", batch_capacity=SMOKE_BATCH,
                coarsen=CoarsenConfig(cutoff=128), coarsen_threshold=512,
            ),
        ),
    }
    out = []
    n_batches = len(lo) // SMOKE_BATCH
    for name, p in plans.items():
        t0 = time.perf_counter()
        rep = None
        for k in range(n_batches):
            sl = slice(k * SMOKE_BATCH, (k + 1) * SMOKE_BATCH)
            rep = p.update(lo[sl], hi[sl], w[sl])
        dt = time.perf_counter() - t0
        m_seen = n_batches * SMOKE_BATCH
        g_acc = from_edges(
            lo[:m_seen], hi[:m_seen], w[:m_seen].astype(np.float64), n
        )
        want = plan(g_acc, SolveSpec()).solve().weight
        assert abs(rep.weight - want) <= max(1.0, 1e-6 * want), (
            name, rep.weight, want,
        )
        if name == "coarsen":
            assert len(rep.levels) >= 1, (
                "coarsen smoke degenerated to the flat recompute"
            )
        out.append(
            from_samples(
                f"stream_smoke_{name}_s{SMOKE_SCALE}_b{SMOKE_BATCH}",
                [dt], per=n_batches,
                derived=f"batches={n_batches};weight={rep.weight:.0f}",
            )
        )
    out.append(_smoke_delete_row(n, lo, hi, w, n_batches))
    return out


def _smoke_delete_row(n, lo, hi, w, n_batches):
    """Delete-heavy phase: exact replacement-edge deletions vs recompute.

    Streams the same batches into a fresh plan with a lossless reservoir,
    deletes a third of the inserted pairs, and asserts the published
    snapshot is NOT stale and matches a flat recompute over the surviving
    multiset — the CI tripwire for the §6.4 deletion protocol.
    """
    p = plan(
        n,
        SolveSpec(
            mode="stream", batch_capacity=SMOKE_BATCH,
            reservoir_capacity=1 << 16, reservoir_per_component=1 << 16,
        ),
    )
    m_seen = n_batches * SMOKE_BATCH
    for k in range(n_batches):
        sl = slice(k * SMOKE_BATCH, (k + 1) * SMOKE_BATCH)
        p.update(lo[sl], hi[sl], w[sl])
    # canonical unique pairs of everything inserted; delete every 3rd
    plo = np.minimum(lo[:m_seen], hi[:m_seen]).astype(np.int64)
    phi = np.maximum(lo[:m_seen], hi[:m_seen]).astype(np.int64)
    keys = np.unique(plo * n + phi)
    dkeys = keys[::3]
    dlo, dhi = dkeys // n, dkeys % n
    t0 = time.perf_counter()
    rep = None
    n_del = 0
    for k in range(0, len(dlo), SMOKE_BATCH):
        sl = slice(k, k + SMOKE_BATCH)
        rep = p.delete(dlo[sl], dhi[sl])
        assert rep.n_unhealed == 0 and not rep.stale, (
            "smoke delete phase lost replacements: reservoir exhausted"
        )
        n_del += rep.raw.n_deleted
    dt = time.perf_counter() - t0
    # parity: flat recompute over the surviving edge multiset
    survive = ~np.isin(plo * n + phi, dkeys)
    g_sur = from_edges(
        lo[:m_seen][survive], hi[:m_seen][survive],
        w[:m_seen][survive].astype(np.float64), n,
    )
    want = plan(g_sur, SolveSpec()).solve().weight
    assert abs(rep.weight - want) <= max(1.0, 1e-6 * want), (
        "delete", rep.weight, want,
    )
    n_rounds = (len(dlo) + SMOKE_BATCH - 1) // SMOKE_BATCH
    return from_samples(
        f"stream_smoke_delete_s{SMOKE_SCALE}_b{SMOKE_BATCH}",
        [dt], per=n_rounds,
        derived=f"deleted_pairs={len(dlo)};forest_deletes={n_del};"
        f"weight={rep.weight:.0f}",
    )


def run_rows():
    n = 1 << SCALE
    g_full = rmat_graph(SCALE, EDGE_FACTOR, seed=9)
    lo, hi, w = undirected_edges(g_full)
    rng = np.random.default_rng(9)
    perm = rng.permutation(len(lo))
    lo, hi, w = lo[perm], hi[perm], w[perm]

    stream = plan(n, SolveSpec(mode="stream", batch_capacity=BATCH))

    # Stream everything in; time the steady-state tail batches.
    n_batches = len(lo) // BATCH
    lats = []
    for k in range(n_batches):
        sl = slice(k * BATCH, (k + 1) * BATCH)
        t0 = time.perf_counter()
        stream.update(lo[sl], hi[sl], w[sl])
        lats.append(time.perf_counter() - t0)
    tail = lats[max(1, n_batches // 2):]
    t_insert = float(np.median(tail))

    # Full recompute over the same accumulated edge set (seed behaviour).
    m_seen = n_batches * BATCH
    g_acc = from_edges(lo[:m_seen], hi[:m_seen], w[:m_seen].astype(np.float64), n)
    full = plan(g_acc, SolveSpec())
    m_full = measure(f"stream_recompute_rmat_s{SCALE}_e{EDGE_FACTOR}_b{BATCH}",
                     lambda: full.solve(), iters=2)
    t_full = m_full.median / 1e6

    union_directed = stream._engine.engine.last_union_shape[0]
    name = f"rmat_s{SCALE}_e{EDGE_FACTOR}_b{BATCH}"
    out = [
        from_samples(
            f"stream_insert_{name}", tail,
            derived=f"union_edges={union_directed};"
            f"updates_per_s={1.0 / t_insert:.1f};"
            f"edges_per_s={BATCH / t_insert:.0f}",
        ),
        m_full.with_derived(
            f"edges={g_acc.num_directed_edges};"
            f"speedup_vs_stream={t_full / t_insert:.1f}x"
        ),
    ]

    qu = rng.integers(0, n, QUERY_BATCH)
    qv = rng.integers(0, n, QUERY_BATCH)
    m_q = measure(f"stream_queries_{name}", lambda: stream.query(qu, qv),
                  iters=3)
    t_q = m_q.median / 1e6
    out.append(
        m_q.with_derived(
            f"batch={QUERY_BATCH};queries_per_s={QUERY_BATCH / t_q:.0f}"
        )
    )
    return out


if __name__ == "__main__":
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    emit(run_smoke_rows() if smoke else run_rows(), argv)
    if smoke:
        print("# stream smoke: engine/recompute weight parity OK", file=sys.stderr)
