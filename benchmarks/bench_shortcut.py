"""Paper Fig 3/4: shortcut optimization comparison.

Compares complete shortcutting with no optimization (per-sub-iteration
parent reads), CSP (prefetch the changed set once), and OS (threshold
switch) — end-to-end MSF time and per-iteration behaviour on a
road-network-like grid graph (the paper's road_usa stand-in).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, measure, point
from repro.core.msf import msf
from repro.graphs import grid_road_graph
from repro.graphs.structures import nx_free_msf_weight


def run_rows():
    g = grid_road_graph(300, 300, seed=0)  # 90k vertices, high diameter
    oracle = nx_free_msf_weight(g)
    out = []
    for strategy, cap in [("complete", 0), ("csp", 1 << 15), ("os", 1 << 13)]:
        kw = dict(variant="complete", shortcut=strategy)
        if cap:
            kw["capacity"] = cap
        r = msf(g, **kw)
        assert abs(float(r.weight) - oracle) < 1e-3, strategy
        out.append(measure(
            f"fig3_shortcut_{strategy}", lambda: msf(g, **kw),
            derived=f"iters={int(r.iterations)};n=90000;"
            f"m={g.num_directed_edges // 2}",
        ))
    # Fig 4 analogue: per-iteration sub-iteration counts for complete shortcut
    from repro.core.shortcut import count_shortcut_subiters
    import jax.numpy as jnp

    p = jnp.arange(g.n, dtype=jnp.int32)
    r = msf(g, variant="complete", shortcut="complete")
    out.append(point(
        "fig4_total_iterations", float(int(r.iterations)), "count",
        "complete-shortcut outer iterations (paper: 13 for road_usa)",
    ))
    return out


if __name__ == "__main__":
    import sys

    emit(run_rows(), sys.argv[1:])
