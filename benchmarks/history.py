"""Append-only bench history, one JSONL stream per (suite, backend,
device_count).

Each call to :func:`append` adds one line — a full ``bench-rows/v2``
document plus a timestamp — to
``<dir>/<suite>__<backend>__<device_count>.jsonl``. Appending never
rewrites earlier lines, so the file is a time series the weekly CI job
can keep extending through the artifact cache and the sentinel (or a
human with jq) can aggregate without stitching per-run artifacts
together. Environment changes land in *different* files by
construction: runs that are not comparable (different backend or
device topology) never share a stream. DESIGN.md §11.
"""
from __future__ import annotations

import json
import os
import re
import time


def history_key(suite: str, backend: str, device_count: int) -> str:
    """Filename stem of one comparable measurement stream."""
    slug = re.sub(r"[^A-Za-z0-9_.-]+", "_", suite)
    return f"{slug}__{backend}__{int(device_count)}"


def history_path(history_dir: str, suite: str, backend: str,
                 device_count: int) -> str:
    return os.path.join(
        history_dir, history_key(suite, backend, device_count) + ".jsonl"
    )


def append(history_dir: str, suite: str, doc: dict, *,
           timestamp: float | None = None) -> str:
    """Append one bench document to the suite's stream; returns the path.

    ``doc`` is a ``bench-rows/v2`` document (``benchmarks.common
    .write_json`` shape); backend/device_count are read from it so the
    stream key always matches the run's own fingerprint.
    """
    env = doc.get("env", {})
    backend = env.get("backend", doc.get("backend", "unknown"))
    devices = env.get("device_count", doc.get("device_count", 0))
    path = history_path(history_dir, suite, backend, devices)
    os.makedirs(history_dir, exist_ok=True)
    line = dict(doc)
    line["suite"] = suite
    line["ts"] = time.time() if timestamp is None else float(timestamp)
    with open(path, "a") as f:
        f.write(json.dumps(line, sort_keys=True) + "\n")
    return path


def load(history_dir: str, suite: str, backend: str,
         device_count: int) -> list[dict]:
    """All appended documents of one stream, oldest first; [] when the
    stream does not exist yet (empty history is not an error)."""
    path = history_path(history_dir, suite, backend, device_count)
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for ln in f:
            ln = ln.strip()
            if ln:
                out.append(json.loads(ln))
    return out
