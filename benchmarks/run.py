"""Benchmark driver — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (benchmarks/bench_*.py each map to a
paper figure; the roofline/§Perf numbers come from launch/dryrun.py).

``--metrics-summary`` turns ``repro.obs`` metrics mode on for the whole
run and prints the registry snapshot (counters + span-latency summaries)
to stderr after each registered bench, resetting between benches so each
snapshot is per-bench."""
from __future__ import annotations

import json
import sys
import time


def main() -> None:
    from benchmarks import (
        bench_coarsen,
        bench_graph_suite,
        bench_multilinear,
        bench_shortcut,
        bench_solve,
        bench_stream,
        bench_strong_scaling,
        bench_weak_scaling,
    )

    metrics = "--metrics-summary" in sys.argv[1:]
    if metrics:
        from repro import obs

        obs.enable("metrics")

    mods = [
        ("fig3/4-shortcut", bench_shortcut),
        ("fig5/6-strong-scaling", bench_strong_scaling),
        ("fig7-weak-scaling", bench_weak_scaling),
        ("fig8-multilinear-vs-pairwise", bench_multilinear),
        ("table1-graph-suite", bench_graph_suite),
        ("stream-msf-serving", bench_stream),
        ("coarsen-levels-vs-flat", bench_coarsen),
        ("solve-api-parity", bench_solve),
    ]
    print("name,us_per_call,derived")
    for label, mod in mods:
        t0 = time.time()
        for r in mod.run_rows():
            print(r, flush=True)
        print(f"# {label} done in {time.time()-t0:.0f}s", file=sys.stderr)
        if metrics:
            from repro import obs

            print(
                f"# metrics[{label}]: "
                + json.dumps(obs.metrics_snapshot(), sort_keys=True),
                file=sys.stderr,
            )
            obs.metrics_reset()


if __name__ == "__main__":
    main()
