"""Benchmark driver — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (benchmarks/bench_*.py each map to a
paper figure; the roofline/§Perf numbers come from launch/dryrun.py);
every row is a structured ``benchmarks.common.Measurement`` underneath.

``--json-dir DIR`` writes one ``bench-rows/v2`` document per module
(``DIR/BENCH_<slug>.json``) — the shapes the regression sentinel
compares. ``--history DIR`` appends each module's document to the
append-only per-(suite, backend, device_count) history store
(``benchmarks/history.py``) — the weekly CI job's trajectory artifact.

``--metrics-summary`` turns ``repro.obs`` metrics mode on for the whole
run and prints the registry snapshot (counters + span-latency summaries)
to stderr after each registered bench, resetting between benches so each
snapshot is per-bench (rows measured under it also carry the snapshot in
their ``metrics`` field)."""
from __future__ import annotations

import json
import os
import re
import sys
import time


def _slug(label: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]+", "_", label)


def main() -> None:
    from benchmarks import (
        bench_coarsen,
        bench_graph_suite,
        bench_multilinear,
        bench_shortcut,
        bench_solve,
        bench_stream,
        bench_strong_scaling,
        bench_weak_scaling,
    )
    from benchmarks.common import document, flag_value

    argv = sys.argv[1:]
    metrics = "--metrics-summary" in argv
    json_dir = flag_value(argv, "--json-dir")
    history_dir = flag_value(argv, "--history")
    if json_dir:
        os.makedirs(json_dir, exist_ok=True)
    if metrics:
        from repro import obs

        obs.enable("metrics")

    mods = [
        ("fig3/4-shortcut", bench_shortcut),
        ("fig5/6-strong-scaling", bench_strong_scaling),
        ("fig7-weak-scaling", bench_weak_scaling),
        ("fig8-multilinear-vs-pairwise", bench_multilinear),
        ("table1-graph-suite", bench_graph_suite),
        ("stream-msf-serving", bench_stream),
        ("coarsen-levels-vs-flat", bench_coarsen),
        ("solve-api-parity", bench_solve),
    ]
    print("name,us_per_call,derived")
    for label, mod in mods:
        t0 = time.time()
        rows = list(mod.run_rows())
        for r in rows:
            print(r, flush=True)
        print(f"# {label} done in {time.time()-t0:.0f}s", file=sys.stderr)
        if json_dir or history_dir:
            doc = document(rows)
            if json_dir:
                path = os.path.join(json_dir, f"BENCH_{_slug(label)}.json")
                with open(path, "w") as f:
                    json.dump(doc, f, indent=1, sort_keys=True)
            if history_dir:
                from benchmarks.history import append

                append(history_dir, _slug(label), doc)
        if metrics:
            from repro import obs

            print(
                f"# metrics[{label}]: "
                + json.dumps(obs.metrics_snapshot(), sort_keys=True),
                file=sys.stderr,
            )
            obs.metrics_reset()


if __name__ == "__main__":
    main()
