"""Unified-API parity gate: SolveSpec plans vs the deprecated kwarg paths.

For each graph family (rmat, grid road) this bench runs the same solve
through the new front door (``repro.solve.plan``) and through the
deprecated entry points (``msf``, ``msf_distributed``, ``StreamingMSF``
— warnings suppressed here; the shim-parity *test* suite asserts the
warning contract), asserting identical forest weight and MSF edge set,
and reporting the spec-path latency with the shim-path latency as the
derived comparison — the CI tripwire that the spec → resolve → plan
pipeline stays bit-identical to the four historical paths while both
exist.

Rows:
- ``solve_flat_*``    — flat plan vs ``msf(g)``;
- ``solve_coarsen_*`` — coarsen plan (fused levels) vs
  ``msf(g, coarsen=cfg, fused=True)``;
- ``solve_dist_*``    — dist plan on the largest available mesh vs the
  ``msf_distributed`` driver;
- ``solve_stream_*``  — stream plan replay vs a ``StreamingMSF`` replay.

``--smoke`` shrinks the graphs for the CI gate (parity is asserted in
both sizes). ``--json PATH`` writes the rows as a BENCH trajectory
point.
"""
from __future__ import annotations

import sys
import warnings

from benchmarks.common import assert_msf_parity as _assert_parity
from benchmarks.common import cost_fragment, emit, from_samples, measure, timeit, with_trace
from repro.coarsen import CoarsenConfig
from repro.graphs import grid_road_graph, rmat_graph
from repro.solve import SolveSpec, plan

SMOKE_SCALE = 8
FULL_SCALE = 12
STREAM_BATCH = 256


def _deprecated(fn, *args, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*args, **kw)


def _bench_flat(name, g):
    from repro.core.msf import msf

    p = plan(g, SolveSpec())
    rep = p.solve()
    shim_r = _deprecated(msf, g)
    _assert_parity(rep, shim_r, f"solve_flat_{name}")
    m = measure(f"solve_flat_{name}", lambda: p.solve(), iters=3)
    t_shim = timeit(lambda: _deprecated(msf, g), iters=3)
    return [m.with_derived(
        f"shim_us={t_shim * 1e6:.1f};edges={g.num_directed_edges}"
        + cost_fragment(rep, m.median / 1e6)
    )]


def _bench_coarsen(name, g, cfg):
    from repro.core.msf import msf

    p = plan(g, SolveSpec(mode="coarsen", coarsen=cfg, fused=True))
    rep = p.solve()
    shim_r = _deprecated(msf, g, coarsen=cfg, fused=True)
    _assert_parity(rep, shim_r, f"solve_coarsen_{name}")
    m = measure(f"solve_coarsen_{name}", lambda: p.solve(), iters=3)
    t_shim = timeit(lambda: _deprecated(msf, g, coarsen=cfg, fused=True), iters=3)
    return [m.with_derived(
        f"shim_us={t_shim * 1e6:.1f};levels={len(rep.levels)}"
        + cost_fragment(rep, m.median / 1e6)
    )]


def _bench_dist(name, g):
    import jax

    from repro.compat import make_mesh
    from repro.core.msf_dist import msf_distributed
    from repro.graphs.partition import partition_edges_2d

    n = jax.device_count()
    shape = (2, 4) if n >= 8 else (2, 2) if n >= 4 else (1, 2) if n >= 2 else (1, 1)
    mesh = make_mesh(shape, ("data", "model"))
    part = partition_edges_2d(g, *shape)
    p = plan(part, SolveSpec(mode="dist"), mesh=mesh)
    drv = _deprecated(msf_distributed, part, mesh)
    args = (part.src_row, part.dst_col, part.w, part.eid, part.valid)
    _assert_parity(p.solve(), drv(*args), f"solve_dist_{name}")
    m = measure(f"solve_dist_{name}", lambda: p.solve(), iters=3)
    t_shim = timeit(lambda: drv(*args), iters=3)
    return [m.with_derived(
        f"shim_us={t_shim * 1e6:.1f};mesh={shape[0]}x{shape[1]}"
    )]


def _bench_stream(name, g):
    from repro.launch.serve_graph import undirected_edges
    from repro.stream import StreamingMSF

    lo, hi, w = undirected_edges(g)
    n_batches = max(1, len(lo) // STREAM_BATCH)

    def replay_spec():
        p = plan(g.n, SolveSpec(mode="stream", batch_capacity=STREAM_BATCH))
        rep = None
        for k in range(n_batches):
            sl = slice(k * STREAM_BATCH, (k + 1) * STREAM_BATCH)
            rep = p.update(lo[sl], hi[sl], w[sl])
        return rep

    def replay_shim():
        eng = _deprecated(StreamingMSF, g.n, batch_capacity=STREAM_BATCH)
        for k in range(n_batches):
            sl = slice(k * STREAM_BATCH, (k + 1) * STREAM_BATCH)
            eng.insert_batch(lo[sl], hi[sl], w[sl])
        return eng

    rep, eng = replay_spec(), replay_shim()
    assert abs(rep.weight - eng.weight) <= max(1.0, 1e-6 * abs(rep.weight)), (
        f"solve_stream_{name}", rep.weight, eng.weight,
    )
    import time as _time

    ts = []
    for _ in range(2):
        t0 = _time.perf_counter()
        replay_spec()
        ts.append(_time.perf_counter() - t0)
    m = from_samples(f"solve_stream_{name}", ts, per=n_batches)
    t_shim = timeit(replay_shim, warmup=0, iters=2)
    return [m.with_derived(
        f"shim_us={t_shim / n_batches * 1e6:.1f};batches={n_batches}"
    )]


def run_rows(smoke: bool = False):
    scale = SMOKE_SCALE if smoke else FULL_SCALE
    g_rmat = rmat_graph(scale, 4 if smoke else 8, seed=9)
    side = 32 if smoke else 128
    g_grid = grid_road_graph(side, side, seed=2)
    cfg = CoarsenConfig(rounds_per_level=2, cutoff=32 if smoke else 1024)
    out = []
    for name, g in ((f"rmat_s{scale}", g_rmat), (f"grid_{side}x{side}", g_grid)):
        out += _bench_flat(name, g)
        out += _bench_coarsen(name, g, cfg)
        out += _bench_dist(name, g)
    out += _bench_stream(f"rmat_s{scale}", g_rmat)
    return out


if __name__ == "__main__":
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    emit(with_trace(argv, lambda: run_rows(smoke=smoke)), argv)
    if smoke:
        print("# solve smoke: spec/deprecated path parity OK", file=sys.stderr)
