"""Paper Fig 7: edge weak scaling on uniform random graphs (n²/p constant).

Single-device proxy: time-per-iteration as the local problem grows with the
paper's n ∝ √p law, plus sparsity sensitivity (f = 100·m/n² as in Fig 7).
"""
from __future__ import annotations

from benchmarks.common import emit, measure
from repro.core.msf import msf
from repro.graphs import random_graph


def run_rows():
    out = []
    n0 = 1 << 14
    for pp in [1, 4, 16]:  # n grows like n0·√p (n²/p const)
        n = int(n0 * pp ** 0.5)
        for sp in [0.01, 0.05]:  # edge percentage f
            m = int(sp / 100 * n * n)
            g = random_graph(n, max(m, n), seed=pp)
            r = msf(g)
            out.append(measure(
                f"fig7_weak_p{pp}_sp{sp}", lambda: msf(g), iters=2,
                derived=f"n={n};m={g.num_directed_edges // 2};"
                f"iters={int(r.iterations)}",
            ))
    return out


if __name__ == "__main__":
    import sys

    emit(run_rows(), sys.argv[1:])
