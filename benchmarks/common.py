"""Shared benchmark harness: structured :class:`Measurement` rows.

Every bench module emits ``Measurement`` records through this harness
(timing on the CPU container; the TPU story is the dry-run roofline,
EXPERIMENTS.md §Roofline). A measurement carries the full sample
statistics (median/IQR/min/max over k post-warmup iterations), the
per-bench ``repro.obs`` metrics snapshot when metrics mode is on, and a
``unit`` so non-time rows (speedups, communication volume, iteration
counts) stay structured instead of being smuggled through the time
column. ``write_json`` persists them as a ``bench-rows/v2`` document
with an environment fingerprint — the trajectory points the regression
sentinel (``tools/check_bench_regression.py``) and the append-only
history store (``benchmarks/history.py``) consume. DESIGN.md §11.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import time
from typing import Optional

import jax
import numpy as np

SCHEMA = "bench-rows/v2"


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One bench row. ``median``/``iqr``/``min``/``max`` are in ``unit``
    (microseconds for time rows); ``iters`` is the post-warmup sample
    count (1 for single-shot and non-time point values)."""

    name: str
    median: float
    iqr: float = 0.0  # q75 - q25 of the samples; 0 when iters < 2
    min: float = 0.0
    max: float = 0.0
    iters: int = 1
    warmup: int = 0
    unit: str = "us"  # "us" | "x" | "bytes" | "count"
    derived: str = ""  # free-form key=value;... context (v1 compat)
    metrics: Optional[dict] = None  # obs snapshot; None when obs off

    def __str__(self) -> str:
        # the printed CSV row (run.py header: name,us_per_call,derived)
        return f"{self.name},{self.median:.1f},{self.derived}"

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if d["metrics"] is None:
            del d["metrics"]
        return d

    def with_derived(self, derived: str) -> "Measurement":
        """Same measurement, new derived string (stats are immutable)."""
        return dataclasses.replace(self, derived=derived)


def _obs_snapshot() -> Optional[dict]:
    from repro import obs

    return obs.metrics_snapshot() if obs.metrics_active() else None


def from_samples(
    name: str,
    samples_s,
    *,
    warmup: int = 0,
    derived: str = "",
    per: float = 1.0,
) -> Measurement:
    """Build a time Measurement from raw wall-clock samples (seconds).

    ``per`` divides every sample (e.g. batches per sample) so the row
    reports per-call microseconds.
    """
    us = np.asarray(samples_s, dtype=np.float64) / max(per, 1e-30) * 1e6
    if us.size == 0:
        raise ValueError(f"{name}: no samples")
    q25, q75 = np.percentile(us, [25, 75]) if us.size > 1 else (us[0], us[0])
    return Measurement(
        name=name,
        median=float(np.median(us)),
        iqr=float(q75 - q25),
        min=float(us.min()),
        max=float(us.max()),
        iters=int(us.size),
        warmup=int(warmup),
        unit="us",
        derived=derived,
        metrics=_obs_snapshot(),
    )


def measure_samples(fn, *args, warmup: int = 1, iters: int = 3) -> list:
    """Raw post-warmup wall-clock samples (seconds) of ``fn(*args)``,
    blocking on device results — the shared timing core of
    :func:`measure` / :func:`timeit`, and the measurement harness the
    SolveSpec autotuner (``repro.solve.tune``, DESIGN.md §12) runs its
    candidates under."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return ts


def measure(
    name: str,
    fn,
    *args,
    warmup: int = 1,
    iters: int = 3,
    derived: str = "",
    per: float = 1.0,
) -> Measurement:
    """Time ``fn(*args)`` (blocking on device results) into a Measurement."""
    ts = measure_samples(fn, *args, warmup=warmup, iters=iters)
    return from_samples(name, ts, warmup=warmup, derived=derived, per=per)


def point(name: str, value: float, unit: str, derived: str = "") -> Measurement:
    """A non-time scalar row (speedup, byte volume, iteration count)."""
    v = float(value)
    return Measurement(
        name=name, median=v, iqr=0.0, min=v, max=v, iters=1, warmup=0,
        unit=unit, derived=derived, metrics=_obs_snapshot(),
    )


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time (seconds) of jitted fn(*args), post-warmup —
    the scalar core of :func:`measure`, kept for ratio rows that need
    raw seconds (speedup numerators/denominators)."""
    return float(np.median(measure_samples(fn, *args, warmup=warmup,
                                           iters=iters)))


def eid_set(r) -> set:
    """MSF edge-id set of a SolveReport (trimmed) or an engine result
    (IMAX-padded ``msf_eids`` + ``n_msf_edges``)."""
    eids = np.asarray(r.msf_eids)
    return set(eids[: int(r.n_msf_edges)].tolist())


def assert_msf_parity(ref, other, what: str) -> None:
    """The shared weight + eid-set parity gate of the smoke benches —
    one definition so every CI gate enforces the same contract."""
    assert abs(float(ref.weight) - float(other.weight)) <= max(
        1.0, 1e-6 * abs(float(ref.weight))
    ), (what, float(ref.weight), float(other.weight))
    assert eid_set(ref) == eid_set(other), f"{what}: MSF edge set drifted"


def cost_fragment(rep, t_s: float) -> str:
    """Measured-vs-roofline derived fields from ``SolveReport.cost``.

    ``flops``/``hbm_bytes`` are the analytic counts of the plan's
    executable (× iterations when the convergence loop is dynamic);
    ``roofline_frac`` is the analytic bound time over the measured time
    on the reference accelerator (TPU v5e constants — on the CPU
    container it reads as "how far this run is from the modeled chip",
    the dry-run story of EXPERIMENTS.md §Roofline)."""
    c = getattr(rep, "cost", None)
    if c is None or t_s <= 0:
        return ""
    mult = max(int(rep.iterations), 1) if c.dynamic_loops else 1
    flops, byts = c.flops * mult, c.bytes * mult
    from repro.analysis.roofline import TPU_V5E

    bound_s = max(flops / TPU_V5E["peak_flops_bf16"],
                  byts / TPU_V5E["hbm_bw"])
    return (
        f";flops={flops:.4g};hbm_bytes={byts:.4g}"
        f";gflops_per_s={flops / t_s / 1e9:.3f}"
        f";roofline_frac={bound_s / t_s:.2e}"
    )


def env_fingerprint() -> dict:
    """The comparability key of a bench document: two runs are
    comparable iff backend and device_count agree (the sentinel's
    skip rule); the rest is provenance."""
    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count(),
    }


def document(rows: list) -> dict:
    """The ``bench-rows/v2`` document of a run: environment fingerprint
    + structured rows — no string re-parsing, so bench names are free to
    contain anything (the v1 schema split on commas and corrupted any
    name containing one)."""
    return {
        "schema": SCHEMA,
        "env": env_fingerprint(),
        # duplicated at top level for cheap jq access / v1 familiarity
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "rows": [r.as_dict() for r in rows],
    }


def write_json(path: str, rows: list) -> None:
    """Persist Measurement rows as a BENCH_*.json trajectory point."""
    with open(path, "w") as f:
        json.dump(document(rows), f, indent=1, sort_keys=True)


def emit(rows: list, argv: list[str]) -> None:
    """Print rows; honor ``--json PATH`` when present."""
    print("\n".join(str(r) for r in rows))
    path = flag_value(argv, "--json")
    if path is not None:
        write_json(path, rows)


def flag_value(argv: list[str], flag: str) -> str | None:
    """PATH/value operand of ``flag`` in argv, or None when absent."""
    if flag not in argv:
        return None
    at = argv.index(flag)
    if at + 1 >= len(argv) or argv[at + 1].startswith("--"):
        raise SystemExit(f"{flag} requires an argument")
    return argv[at + 1]


def with_trace(argv: list[str], fn):
    """Run ``fn()`` under ``repro.obs`` trace mode when ``--trace PATH``
    is present, exporting the Chrome-trace/Perfetto JSON to PATH after —
    the shared bench-side surface of DESIGN.md §10.5. Without the flag,
    ``fn()`` runs untouched (obs stays off)."""
    path = flag_value(argv, "--trace")
    if path is None:
        return fn()
    from repro import obs

    obs.enable("trace")
    try:
        return fn()
    finally:
        obs.export_trace(path)
        obs.disable()
        obs.reset()
