"""Shared benchmark helpers (timing on the CPU container; the TPU story is
the dry-run roofline, EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
import time

import jax
import numpy as np


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time (seconds) of jitted fn(*args), post-warmup."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


def eid_set(r) -> set:
    """MSF edge-id set of a SolveReport (trimmed) or an engine result
    (IMAX-padded ``msf_eids`` + ``n_msf_edges``)."""
    eids = np.asarray(r.msf_eids)
    return set(eids[: int(r.n_msf_edges)].tolist())


def assert_msf_parity(ref, other, what: str) -> None:
    """The shared weight + eid-set parity gate of the smoke benches —
    one definition so every CI gate enforces the same contract."""
    assert abs(float(ref.weight) - float(other.weight)) <= max(
        1.0, 1e-6 * abs(float(ref.weight))
    ), (what, float(ref.weight), float(other.weight))
    assert eid_set(ref) == eid_set(other), f"{what}: MSF edge set drifted"


def write_json(path: str, rows: list[str]) -> None:
    """Persist CSV rows as a BENCH_*.json trajectory point (CI artifact).

    One file per bench run: environment fingerprint + the parsed rows, so
    successive CI artifacts line up into a per-benchmark time series
    without re-parsing stdout logs.
    """
    parsed = []
    for r in rows:
        name, us, derived = r.split(",", 2)
        parsed.append(
            {"name": name, "us_per_call": float(us), "derived": derived}
        )
    doc = {
        "schema": "bench-rows/v1",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "rows": parsed,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)


def emit(rows: list[str], argv: list[str]) -> None:
    """Print rows; honor a ``--json PATH`` CLI flag when present."""
    print("\n".join(rows))
    if "--json" in argv:
        at = argv.index("--json")
        if at + 1 >= len(argv) or argv[at + 1].startswith("--"):
            raise SystemExit("--json requires a PATH argument")
        write_json(argv[at + 1], rows)


def flag_value(argv: list[str], flag: str) -> str | None:
    """PATH/value operand of ``flag`` in argv, or None when absent."""
    if flag not in argv:
        return None
    at = argv.index(flag)
    if at + 1 >= len(argv) or argv[at + 1].startswith("--"):
        raise SystemExit(f"{flag} requires an argument")
    return argv[at + 1]


def with_trace(argv: list[str], fn):
    """Run ``fn()`` under ``repro.obs`` trace mode when ``--trace PATH``
    is present, exporting the Chrome-trace/Perfetto JSON to PATH after —
    the shared bench-side surface of DESIGN.md §10.5. Without the flag,
    ``fn()`` runs untouched (obs stays off)."""
    path = flag_value(argv, "--trace")
    if path is None:
        return fn()
    from repro import obs

    obs.enable("trace")
    try:
        return fn()
    finally:
        obs.export_trace(path)
        obs.disable()
        obs.reset()
