"""Paper Fig 8: multilinear (all-at-once) kernel vs the pairwise SpMV
formulation — the paper's headline kernel result (R-MAT input).

The pairwise path materializes (a_ij, p_j) into nnz-sized buffers before
reducing with p_i (the extra writes the paper analyzes in §IV-A); the
multilinear kernel fuses f(p_i, a_ij, p_j) into the reduction.
"""
from __future__ import annotations

from benchmarks.common import emit, measure, point
from repro.core.msf import msf
from repro.graphs import rmat_graph
from repro.graphs.structures import nx_free_msf_weight


def run_rows():
    out = []
    for scale, ef in [(14, 8), (12, 64)]:
        g = rmat_graph(scale, ef, seed=1)
        oracle = nx_free_msf_weight(g)
        times = {}
        for variant in ["complete", "pairwise"]:
            r = msf(g, variant=variant)
            assert abs(float(r.weight) - oracle) < 1e-3
            nm = "multilinear" if variant == "complete" else "pairwise"
            m = measure(
                f"fig8_S{scale}_E{ef}_{nm}", lambda: msf(g, variant=variant),
                derived=f"iters={int(r.iterations)};"
                f"m={g.num_directed_edges // 2}",
            )
            times[nm] = m.median / 1e6
            out.append(m)
        out.append(point(
            f"fig8_S{scale}_E{ef}_speedup",
            times["pairwise"] / times["multilinear"], "x",
            "multilinear over pairwise; paper's orders-of-magnitude Fig-8 "
            "gap is CTF's distributed tensor-update remote writes — XLA "
            "fuses most of the local materialization away (see EXPERIMENTS)",
        ))
    return out


if __name__ == "__main__":
    import sys

    emit(run_rows(), sys.argv[1:])
