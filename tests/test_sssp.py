"""Algebraic Bellman-Ford (paper §II-B) vs scipy shortest path."""
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st  # skips cleanly if absent

from repro.core.sssp import sssp
from repro.graphs import random_graph, grid_road_graph
from repro.graphs.structures import from_edges


def _oracle(g, source):
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csg

    src, dst, w = np.asarray(g.src), np.asarray(g.dst), np.asarray(g.w)
    v = np.asarray(g.valid)
    a = sp.coo_matrix((w[v], (src[v], dst[v])), shape=(g.n, g.n)).tocsr()
    return csg.shortest_path(a, directed=False, indices=source)


@pytest.mark.parametrize("g", [random_graph(150, 500, seed=1), grid_road_graph(10, 12, seed=2)],
                         ids=["random", "grid"])
def test_sssp_matches_scipy(g):
    d, it = sssp(g, 0)
    np.testing.assert_allclose(np.asarray(d), _oracle(g, 0), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 40), m=st.integers(0, 100), seed=st.integers(0, 2**31 - 1))
def test_sssp_property(n, m, seed):
    rng = np.random.default_rng(seed)
    g = from_edges(rng.integers(0, n, m), rng.integers(0, n, m),
                   rng.integers(1, 256, m).astype(np.float64), n)
    d, _ = sssp(g, 0)
    np.testing.assert_allclose(np.asarray(d), _oracle(g, 0), rtol=1e-6)
