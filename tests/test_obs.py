"""Observability (`repro.obs`) — tracer, registry, export, and the
no-observer-effect contract (DESIGN.md §10).

The load-bearing suite here is the parity block: enabling ``obs="trace"``
switches the flat and coarsen engines to phase-split execution
(host-driven round/phase loops instead of the one-jit production paths),
and these tests pin that the switch changes **no solver output bit** —
weight, msf_eids, and parent must be identical across obs modes for
every engine.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro import obs
from repro.graphs.generators import random_graph
from repro.graphs.structures import nx_free_n_components


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with obs off and empty buffers."""
    obs.disable()
    obs.reset()
    obs.metrics_reset()
    yield
    obs.disable()
    obs.reset()
    obs.metrics_reset()


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_disabled_span_is_shared_noop_singleton():
    # The disabled path is one branch + zero allocation: span() must
    # return the same object every time, and it must be inert.
    s1 = obs.span("a")
    s2 = obs.span("b", level=3)
    assert s1 is s2 is obs.NOOP_SPAN
    with s1 as sp:
        assert sp.attach("payload") == "payload"
        sp.set(anything="goes")
    assert obs.trace_events() == []
    assert obs.metrics_snapshot()["histograms"] == {}


def test_span_nesting_records_all_levels():
    obs.enable("trace")
    with obs.span("outer", level=0):
        with obs.span("inner", level=1):
            pass
        with obs.span("inner", level=2):
            pass
    names = [e[0] for e in obs.trace_events()]
    # Children exit (and record) before the parent.
    assert names == ["inner", "inner", "outer"]
    outer = next(e for e in obs.trace_events() if e[0] == "outer")
    inner = [e for e in obs.trace_events() if e[0] == "inner"]
    # Interval containment on the same thread — what Perfetto nests by.
    for name, t0, dur, tid, _attrs in inner:
        assert tid == outer[3]
        assert outer[1] <= t0
        assert t0 + dur <= outer[1] + outer[2]


def test_enabled_is_upgrade_only():
    obs.enable("trace")
    with obs.enabled("metrics"):  # must NOT downgrade the global mode
        assert obs.mode() == "trace"
    with obs.enabled("off"):
        assert obs.mode() == "trace"
    obs.disable()
    with obs.enabled("metrics"):
        assert obs.mode() == "metrics"
        with obs.enabled("trace"):
            assert obs.mode() == "trace"
        assert obs.mode() == "metrics"
    assert obs.mode() == "off"


def test_collect_timings_aggregates_by_name():
    obs.enable("metrics")
    with obs.collect_timings() as t:
        with obs.span("phase.a"):
            pass
        with obs.span("phase.a"):
            pass
        with obs.span("phase.b"):
            pass
    assert set(t) == {"phase.a", "phase.b"}
    assert all(v >= 0.0 for v in t.values())
    h = obs.metrics_snapshot()["histograms"]
    assert h["span.phase.a"]["count"] == 2
    assert h["span.phase.b"]["count"] == 1


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_and_gauge():
    obs.counter("c").inc()
    obs.counter("c").inc(41)
    obs.gauge("g").set(2.5)
    snap = obs.metrics_snapshot()
    assert snap["counters"]["c"] == 42
    assert snap["gauges"]["g"] == 2.5
    with pytest.raises(ValueError):
        obs.counter("c").inc(-1)


def test_histogram_percentiles_uniform():
    # 1..1000 ms uniformly: percentiles should match the analytic value
    # to within one log-bucket's width (the documented approximation).
    h = obs.histogram("lat")
    for ms in range(1, 1001):
        h.observe(ms / 1e3)
    for q in (50, 95, 99):
        got = h.percentile(q)
        want = q / 100.0  # q-th percentile of U(0, 1] seconds
        assert want / 2.2 <= got <= want * 2.2, (q, got, want)
    s = h.summary()
    assert s["count"] == 1000
    assert s["min"] == pytest.approx(1e-3)
    assert s["max"] == pytest.approx(1.0)
    assert s["p50"] <= s["p95"] <= s["p99"] <= s["max"]


def test_histogram_single_value_and_clamping():
    h = obs.histogram("one")
    for _ in range(10):
        h.observe(0.25)
    s = h.summary()
    # Interpolation is clamped to the observed [min, max]: a
    # single-valued stream reports that value at every quantile.
    assert s["p50"] == s["p95"] == s["p99"] == pytest.approx(0.25)


def test_histogram_rejects_bad_bounds():
    from repro.obs.metrics import Histogram

    with pytest.raises(ValueError):
        Histogram(bounds=())
    with pytest.raises(ValueError):
        Histogram(bounds=(1.0, 1.0))


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------


def test_export_trace_schema_roundtrip(tmp_path):
    obs.enable("trace")
    with obs.span("outer", n=64):
        with obs.span("inner"):
            pass
    path = str(tmp_path / "trace.json")
    doc = obs.export_trace(path)
    on_disk = json.loads(open(path).read())
    assert on_disk == doc
    complete = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert {e["name"] for e in complete} == {"outer", "inner"}
    for e in complete:
        assert isinstance(e["ts"], float) and e["ts"] >= 0.0
        assert isinstance(e["dur"], float) and e["dur"] >= 0.0
        assert e["pid"] == 0 and isinstance(e["tid"], int)
    outer = next(e for e in complete if e["name"] == "outer")
    assert outer["args"] == {"n": 64}
    # Metadata events name the process/threads for the Perfetto UI.
    assert any(e.get("ph") == "M" for e in doc["traceEvents"])
    assert doc["otherData"]["dropped_events"] == 0
    # The repo's own CI validator must accept its own exporter's output.
    import sys

    sys.path.insert(0, "tools")
    try:
        from check_trace import check

        assert check(path, ["outer", "inner"]) is None
        assert check(path, ["absent-span"]) is not None
    finally:
        sys.path.remove("tools")


# ---------------------------------------------------------------------------
# no-observer-effect parity: obs must never change solver output
# ---------------------------------------------------------------------------


def _assert_reports_identical(a, b, what):
    assert float(a.weight) == float(b.weight), what
    assert np.array_equal(np.asarray(a.msf_eids), np.asarray(b.msf_eids)), what
    assert np.array_equal(np.asarray(a.parent), np.asarray(b.parent)), what


@pytest.mark.parametrize("fused", [False, True])
def test_trace_parity_coarsen(fused):
    from repro.coarsen import CoarsenConfig
    from repro.solve import SolveSpec, plan

    g = random_graph(512, 2048, seed=11)
    cfg = CoarsenConfig(cutoff=32, rounds_per_level=2)
    base = plan(g, SolveSpec(mode="coarsen", coarsen=cfg, fused=fused)).solve()
    for mode in ("metrics", "trace"):
        obs.reset()
        rep = plan(
            g, SolveSpec(mode="coarsen", coarsen=cfg, fused=fused, obs=mode)
        ).solve()
        _assert_reports_identical(base, rep, f"coarsen fused={fused} {mode}")
        assert rep.timings and "solve.coarsen" in rep.timings
    assert base.timings == {}
    # The acceptance contract: the fused trace shows the per-level phases.
    if fused:
        names = {e[0] for e in obs.trace_events()}
        assert {"coarsen.level", "coarsen.contract", "coarsen.relabel",
                "coarsen.filter", "coarsen.residual"} <= names


def test_trace_parity_flat():
    from repro.solve import SolveSpec, plan

    g = random_graph(256, 1024, seed=7)
    base = plan(g, SolveSpec()).solve()
    rep = plan(g, SolveSpec(obs="trace")).solve()
    _assert_reports_identical(base, rep, "flat trace")
    assert rep.timings["msf.round"] >= 0.0
    names = [e[0] for e in obs.trace_events()]
    # One span per hook+shortcut round, nested under msf.flat.
    assert names.count("msf.round") == int(rep.iterations)
    assert "msf.flat" in names


def test_trace_parity_stream():
    from repro.solve import SolveSpec, plan

    rng = np.random.default_rng(3)
    reports = []
    for mode in ("off", "trace"):
        p = plan(256, SolveSpec(mode="stream", obs=mode))
        r = np.random.default_rng(5)
        rep = None
        for _ in range(3):
            u = r.integers(0, 256, 64).astype(np.int32)
            v = r.integers(0, 256, 64).astype(np.int32)
            w = r.random(64).astype(np.float32)
            rep = p.update(u, v, w)
        reports.append(p.solve())
        if mode == "trace":
            conn = p.query(np.arange(8), np.arange(8, 16))
            assert conn.shape == (8,)
            h = obs.metrics_snapshot()["histograms"]
            assert h["span.stream.update"]["count"] == 3
            assert {"p50", "p95", "p99"} <= set(h["span.stream.query"])
    _assert_reports_identical(reports[0], reports[1], "stream trace")
    obs.disable()
    del rng


def test_trace_parity_dist(dist_mesh, dist_mesh_shape):
    from repro.coarsen import CoarsenConfig
    from repro.graphs.partition import partition_edges_2d
    from repro.solve import SolveSpec, plan

    g = random_graph(512, 2048, seed=13)
    part = partition_edges_2d(g, *dist_mesh_shape)
    cfg = CoarsenConfig(cutoff=64)
    base = plan(part, SolveSpec(mode="dist", coarsen=cfg), mesh=dist_mesh).solve()
    rep = plan(
        part, SolveSpec(mode="dist", coarsen=cfg, obs="metrics"),
        mesh=dist_mesh,
    ).solve()
    _assert_reports_identical(base, rep, "dist metrics")
    snap = obs.metrics_snapshot()
    # Analytic all-reduce accounting: every level + residual round adds
    # its combine passes over the dense [n_pad] accumulator.
    assert snap["counters"]["dist.allreduce.passes"] > 0
    assert snap["counters"]["dist.allreduce.elements"] > 0
    assert "span.dist.residual" in snap["histograms"]


def test_plan_cache_counters():
    from repro.solve import SolveSpec, plan
    from repro.solve.planner import clear_plan_cache

    g = random_graph(128, 512, seed=2)
    clear_plan_cache()
    plan(g, SolveSpec(obs="metrics"))
    plan(g, SolveSpec(obs="metrics"))
    snap = obs.metrics_snapshot()["counters"]
    assert snap["plan.cache.miss"] == 1
    assert snap["plan.cache.hit"] == 1


def test_spec_rejects_unknown_obs_mode():
    from repro.solve import SolveSpec

    with pytest.raises(ValueError, match="obs"):
        SolveSpec(obs="verbose")


# ---------------------------------------------------------------------------
# SolveReport.n_components (satellite fix): canonical-root counting
# ---------------------------------------------------------------------------


def test_n_components_counts_canonical_roots():
    from repro.solve.report import SolveReport

    # Non-canonical parent: 3 -> 2 -> 1 -> 1 chain plus root 0. A naive
    # parent[i] == i count is right here, but np.unique on the raw
    # (uncanonicalized) vector would see {1, 2} labels as distinct
    # components — the regression the canonicalizing property fixes.
    parent = np.array([0, 1, 1, 2], np.int32)
    rep = SolveReport(
        mode="flat", weight=0.0, msf_eids=np.zeros(0, np.int32),
        parent=parent, n_msf_edges=0, iterations=0, levels=(),
        host_roundtrips=0, recompiles=0, raw=None,
    )
    assert rep.n_components == 2
    # Oracle: unique labels after full pointer-jumping canonicalization.
    p = parent.copy()
    while not np.array_equal(p[p], p):
        p = p[p]
    assert rep.n_components == len(np.unique(p))


def test_n_components_matches_graph_truth():
    from repro.solve import SolveSpec, plan

    g = random_graph(200, 300, seed=21)
    rep = plan(g, SolveSpec()).solve()
    assert rep.n_components == nx_free_n_components(g)
    p = np.asarray(rep.parent)
    while not np.array_equal(p[p], p):
        p = p[p]
    assert rep.n_components == len(np.unique(p))
