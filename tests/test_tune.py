"""Tier-1 tests of the SolveSpec autotuner + tuning database
(``repro.solve.tune``, DESIGN.md §12): key bucketing, DB round-trip and
nearest-bucket lookup, loud stale-schema rejection with quiet
resolve-time fallback, tuner determinism under an injected timer,
cost-pruning safety against real measurements, and plan-cache key
separation of ``tuning="db"`` vs ``"off"``."""
import json
import os
import sys
import warnings

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.graphs.generators import (  # noqa: E402
    components_graph,
    grid_road_graph,
    rmat_graph,
)
from repro.solve import (  # noqa: E402
    SolveSpec,
    clear_plan_cache,
    plan,
    plan_cache_info,
    set_tuning_db,
)
from repro.solve.tune import (  # noqa: E402
    MAX_BUCKET_DISTANCE,
    SCHEMA,
    TuneKey,
    TuningDB,
    TuningDBError,
    enumerate_candidates,
    key_for,
    parse_shape_class,
    prune_by_cost,
    shape_class,
    spec_knobs,
    tune,
)


@pytest.fixture(autouse=True)
def _no_active_db():
    """Every test starts and ends with no active tuning DB (the module
    state is process-global)."""
    set_tuning_db(None)
    yield
    set_tuning_db(None)


def _eids(rep):
    return set(np.asarray(rep.msf_eids)[: int(rep.n_msf_edges)].tolist())


# ---------------------------------------------------------------------------
# keys
# ---------------------------------------------------------------------------

def test_shape_class_buckets_and_roundtrip():
    assert shape_class(256, 1024) == "n8d2"
    assert shape_class(1, 0) == "n0d0"
    # ~sqrt(2)x wiggle shares a bucket; 2x moves one bucket
    assert shape_class(256, 1024) == shape_class(300, 1200)
    assert parse_shape_class(shape_class(2**12, 2**15)) == (12, 3)
    assert parse_shape_class("bogus") is None


def test_key_for_graph():
    g = rmat_graph(7, 4, seed=9)
    key = key_for("flat", g)
    assert key.shape_class == shape_class(g.n, len(np.asarray(g.src)))
    assert key.mode == "flat"
    assert key.weights in ("int", "float")
    assert key.mesh == ""
    with pytest.raises(ValueError):
        key_for("flat", object())


# ---------------------------------------------------------------------------
# database: round-trip, nearest bucket, loud schema rejection
# ---------------------------------------------------------------------------

def _key(shape="n8d2", mode="flat", **over):
    base = dict(shape_class=shape, weights="int", mode=mode,
                backend="cpu", device_count=1, mesh="")
    base.update(over)
    return TuneKey(**base)


def test_db_roundtrip(tmp_path):
    db = TuningDB()
    db.put(_key(), {"pack": True, "shortcut": "csp"}, {"median_us": 10.0})
    path = db.save(str(tmp_path / "v1.json"))
    doc = json.load(open(path))
    assert doc["schema"] == SCHEMA
    assert "backend" in doc["env"]
    back = TuningDB.load(path)
    assert len(back) == 1
    entry, exact = back.lookup(_key())
    assert exact and entry.knobs == {"pack": True, "shortcut": "csp"}
    assert entry.stats["median_us"] == 10.0


def test_db_nearest_bucket_lookup():
    db = TuningDB()
    db.put(_key("n7d2"), {"shortcut": "csp"})
    db.put(_key("n6d2"), {"shortcut": "complete"})
    # exact wins
    assert db.lookup(_key("n7d2"))[1] is True
    # n8d3 is distance 2 from n7d2, distance 3 from n6d2 → nearest wins
    entry, exact = db.lookup(_key("n8d3"))
    assert not exact and entry.knobs == {"shortcut": "csp"}
    # beyond MAX_BUCKET_DISTANCE → no match
    far = _key(f"n{8 + MAX_BUCKET_DISTANCE + 7}d2")
    assert db.lookup(far) is None
    # any non-shape field mismatch disqualifies even an adjacent bucket
    assert db.lookup(_key("n7d2", weights="float")) is None
    assert db.lookup(_key("n7d2", mode="coarsen")) is None
    assert db.lookup(_key("n7d2", device_count=8)) is None


def test_db_stale_schema_rejected_loudly(tmp_path):
    path = tmp_path / "stale.json"
    path.write_text(json.dumps({"schema": "tuning-db/v0", "entries": []}))
    with pytest.raises(TuningDBError, match="tuning-db/v0"):
        TuningDB.load(str(path))
    with pytest.raises(TuningDBError):
        set_tuning_db(str(path))
    with pytest.raises(TuningDBError, match="malformed"):
        TuningDB.from_doc({"schema": SCHEMA, "entries": [{"key": {}}]})


def test_resolve_falls_back_on_invalid_env_db(tmp_path, monkeypatch):
    """An unreadable REPRO_TUNING_DB warns once and resolves like
    tuning="off" — a bad cache must never fail a solve."""
    path = tmp_path / "stale.json"
    path.write_text(json.dumps({"schema": "tuning-db/v0", "entries": []}))
    monkeypatch.setenv("REPRO_TUNING_DB", str(path))
    set_tuning_db(None)  # drop the memoized env load
    g = rmat_graph(6, 4, seed=3)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        rs_db = SolveSpec(mode="flat", tuning="db").resolve(g)
        SolveSpec(mode="flat", tuning="db").resolve(g)
    assert [w for w in caught if issubclass(w.category, RuntimeWarning)], \
        "invalid env DB should warn"
    rs_off = SolveSpec(mode="flat", tuning="off").resolve(g)
    # identical knob resolution — only the tuning field differs
    assert rs_db.pack == rs_off.pack
    assert rs_db.spec.shortcut == rs_off.spec.shortcut


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------

def _fake_timer():
    """Deterministic injected clock: each candidate's 'latency' is a
    stable hash of its knobs, so two tune() runs see identical
    measurements without touching the real clock."""
    def timer(spec, solve_fn):
        h = abs(hash(json.dumps(spec_knobs(spec), sort_keys=True,
                                default=str))) % 1000
        base = 1e-4 + h * 1e-7
        return [base, base * 1.01, base * 0.99]
    return timer


def test_tune_determinism_fixed_seed():
    g = rmat_graph(6, 4, seed=1)
    kw = dict(space="smoke", seed=7, timer=_fake_timer())
    r1 = tune(g, "flat", **kw)
    r2 = tune(g, "flat", **kw)
    assert [spec_knobs(r.spec) for r in r1.ranking] == \
        [spec_knobs(r.spec) for r in r2.ranking]
    assert [r.median_us for r in r1.ranking] == \
        [r.median_us for r in r2.ranking]
    assert spec_knobs(r1.winner) == spec_knobs(r2.winner)


def test_tune_persists_winner_and_db_resolution_uses_it():
    g = rmat_graph(6, 4, seed=2)
    db = TuningDB()
    res = tune(g, "flat", db=db, space="smoke", timer=_fake_timer())
    assert res.entry is not None and len(db) == 1
    assert res.entry.key == key_for("flat", g)
    set_tuning_db(db)
    rs = SolveSpec(mode="flat", tuning="db").resolve(g)
    knobs = spec_knobs(res.winner)
    assert rs.spec.shortcut == knobs["shortcut"]
    assert rs.pack == knobs["pack"]
    # an explicitly pinned knob beats the stored winner
    other = "complete" if knobs["shortcut"] != "complete" else "csp"
    rs_pin = SolveSpec(mode="flat", shortcut=other, tuning="db").resolve(g)
    assert rs_pin.spec.shortcut == other


def test_tune_db_parity_flat_and_coarsen():
    """tuning="db" must return the identical forest, whatever the DB
    elected (the CI gate's contract, in-process)."""
    g = grid_road_graph(12, 12, seed=2)
    db = TuningDB()
    for mode in ("flat", "coarsen"):
        tune(g, mode, db=db, space="smoke", iters=1, warmup=1)
    set_tuning_db(db)
    for mode in ("flat", "coarsen"):
        r_off = plan(g, SolveSpec(mode=mode, tuning="off")).solve()
        r_db = plan(g, SolveSpec(mode=mode, tuning="db")).solve()
        assert abs(float(r_off.weight) - float(r_db.weight)) <= max(
            1.0, 1e-6 * abs(float(r_off.weight)))
        assert _eids(r_off) == _eids(r_db), mode


def test_pruning_never_discards_measured_winner():
    """The cost model may only drop order-of-magnitude losers: on the
    property-suite graph classes, measuring ALL candidates must elect a
    winner the pruned sweep kept (or one within noise of a kept one)."""
    for g in (rmat_graph(6, 4, seed=9), grid_road_graph(10, 10, seed=2),
              components_graph(4, 16, seed=5)):
        cands = enumerate_candidates(g, "flat", space="smoke")
        kept, _ = prune_by_cost(g, cands)
        kept_knobs = [json.dumps(spec_knobs(s.spec), sort_keys=True,
                                 default=str) for s in kept]
        full = tune(g, "flat", space="smoke", ratio=float("inf"),
                    min_keep=len(cands), iters=2, warmup=1)
        winner = json.dumps(spec_knobs(full.winner), sort_keys=True,
                            default=str)
        if winner not in kept_knobs:
            # noise tolerance: a kept candidate within 10% of the
            # measured best also satisfies the contract
            best_us = full.ranking[0].median_us
            kept_us = [r.median_us for r in full.ranking
                       if json.dumps(spec_knobs(r.spec), sort_keys=True,
                                     default=str) in kept_knobs]
            assert kept_us and min(kept_us) <= best_us * 1.10, \
                f"pruning discarded the measured winner {winner}"


def test_enumerate_candidates_validation():
    g = rmat_graph(5, 4, seed=4)
    cands = enumerate_candidates(g, "flat", space="smoke")
    assert cands and all(c.tuning == "off" for c in cands)
    # the smoke space is a strict subset of the full sweep
    assert len(enumerate_candidates(g, "flat", space="full")) > len(cands)
    with pytest.raises(ValueError, match="space"):
        enumerate_candidates(g, "flat", space="huge")
    with pytest.raises(ValueError, match="modes"):
        enumerate_candidates(g, "stream")


def test_tuning_spec_validation():
    with pytest.raises(ValueError, match="tuning"):
        SolveSpec(mode="flat", tuning="sometimes")
    for v in ("off", "db", "measure"):
        assert SolveSpec(mode="flat", tuning=v).tuning == v


# ---------------------------------------------------------------------------
# plan-cache interaction
# ---------------------------------------------------------------------------

def test_plan_cache_distinguishes_tuning_modes():
    """tuning="db" and "off" must never share a plan-cache entry, even
    when the DB is empty and both resolve to the same knobs — a later
    set_tuning_db must not be masked by a stale cached plan."""
    g = rmat_graph(6, 4, seed=6)
    clear_plan_cache()
    plan(g, SolveSpec(mode="flat", tuning="off"))
    n_after_off = plan_cache_info()[0]
    plan(g, SolveSpec(mode="flat", tuning="db"))
    assert plan_cache_info()[0] == n_after_off + 1
    # same mode+tuning re-plan hits the cache (no new entry)
    plan(g, SolveSpec(mode="flat", tuning="off"))
    plan(g, SolveSpec(mode="flat", tuning="db"))
    assert plan_cache_info()[0] == n_after_off + 1
    clear_plan_cache()


def test_db_entry_changes_resolved_engine_config():
    """A stored winner actually lands in the resolved plan: force a
    shortcut the heuristics would not pick and observe it."""
    g = rmat_graph(6, 4, seed=8)
    heur = SolveSpec(mode="flat", tuning="off").resolve(g)
    forced = "complete" if heur.spec.shortcut != "complete" else "csp"
    db = TuningDB()
    db.put(key_for("flat", g), {"shortcut": forced})
    set_tuning_db(db)
    rs = SolveSpec(mode="flat", tuning="db").resolve(g)
    assert rs.spec.shortcut == forced
    # and the solve still returns the reference forest
    clear_plan_cache()
    r_db = plan(g, SolveSpec(mode="flat", tuning="db")).solve()
    r_off = plan(g, SolveSpec(mode="flat", tuning="off")).solve()
    assert _eids(r_db) == _eids(r_off)
    clear_plan_cache()


# ---------------------------------------------------------------------------
# the CLI validator
# ---------------------------------------------------------------------------

def test_check_tuning_db_cli(tmp_path):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
    try:
        import check_tuning_db
    finally:
        sys.path.pop(0)
    db = TuningDB()
    db.put(_key(), {"pack": True, "shortcut": "csp"})
    good = db.save(str(tmp_path / "good.json"))
    assert check_tuning_db.check(good) == []

    doc = json.load(open(good))
    doc["schema"] = "tuning-db/v0"
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps(doc))
    problems = check_tuning_db.check(str(stale))
    assert problems and "tuning-db/v0" in problems[0]

    doc = json.load(open(good))
    doc["entries"][0]["knobs"]["shortcut"] = "warp-drive"
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(doc))
    problems = check_tuning_db.check(str(bad))
    assert problems and "SolveSpec" in problems[0]
