"""Serving tier (``repro.serve``, DESIGN.md §13): wire-protocol codec
fuzz, server end-to-end over loopback TCP, snapshot-consistency under
concurrent reader/writer contention, admission-control error paths, and
durable kill→restart through the checkpoint store.

The consistency core: every response carries the snapshot version it was
answered from, so a ``connected`` answer at version V must agree with a
flat MSF recompute over the survivor multiset as of V — pinned here with
the same :class:`_SurvivorOracle` the engine property suite replays.
"""
import threading
import time

import numpy as np
import pytest

from repro import serve
from repro.serve import protocol as P
from repro.solve import SolveSpec, plan
from test_msf_properties import _SurvivorOracle


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_executables():
    # This module compiles many small per-capacity engine executables from
    # server worker threads; left cached they push the process's live
    # executable count high enough to destabilize later XLA CPU compiles
    # in a full-suite run. Drop them once the module is done.
    yield
    import gc

    import jax

    jax.clear_caches()
    gc.collect()


# ---------------------------------------------------------------------------
# protocol codec
# ---------------------------------------------------------------------------


def test_frame_roundtrip_and_split_delivery():
    objs = [
        {"schema": P.SCHEMA, "id": i, "op": "connected", "u": [i], "v": [0]}
        for i in range(5)
    ]
    blob = b"".join(P.encode_frame(o) for o in objs)
    # one-shot feed
    dec = P.FrameDecoder()
    assert dec.feed(blob) == objs
    assert dec.pending_bytes == 0
    # byte-at-a-time feed: truncated frames buffer, never error
    dec = P.FrameDecoder()
    out = []
    for i in range(len(blob)):
        out.extend(dec.feed(blob[i:i + 1]))
    assert out == objs
    assert dec.pending_bytes == 0


def test_frame_decoder_bad_json_is_recoverable():
    payload = b"{not json"
    frame = P.HEADER.pack(len(payload)) + payload
    dec = P.FrameDecoder()
    good = {"schema": P.SCHEMA, "id": 1, "op": "status"}
    items = dec.feed(frame + P.encode_frame(good))
    assert isinstance(items[0], P.ProtocolError)
    assert items[0].code == "bad_frame" and items[0].recoverable
    assert items[1] == good  # stream resynchronizes after the bad frame


def test_frame_decoder_oversize_is_unrecoverable():
    dec = P.FrameDecoder(max_payload=64)
    with pytest.raises(P.ProtocolError) as ei:
        dec.feed(P.HEADER.pack(65))  # declared length alone is enough
    assert ei.value.code == "too_large" and not ei.value.recoverable
    with pytest.raises(P.ProtocolError):
        P.encode_frame({"u": list(range(1000))}, max_payload=64)


def test_frame_decoder_fuzz_never_crashes():
    rng = np.random.default_rng(7)
    for trial in range(50):
        dec = P.FrameDecoder(max_payload=1 << 16)
        blob = rng.integers(0, 256, size=int(rng.integers(1, 400))).astype(
            np.uint8).tobytes()
        try:
            for at in range(0, len(blob), 7):
                for item in dec.feed(blob[at:at + 7]):
                    assert isinstance(item, (dict, P.ProtocolError))
        except P.ProtocolError as e:
            assert not e.recoverable  # only the declared-oversize raise
            assert e.code == "too_large"


def test_validate_request_rejects_malformed():
    ok, fields = P.validate_request(
        {"schema": P.SCHEMA, "id": 1, "op": "connected",
         "u": [0, 1], "v": [2, 3]}
    )
    assert ok == "connected" and fields["u"] == [0, 1]
    cases = [
        ({}, "bad_request"),                               # no op
        ({"op": 7}, "bad_request"),                        # non-string op
        ({"op": "frobnicate"}, "unknown_op"),
        ({"op": "connected", "u": [0]}, "bad_request"),    # missing v
        ({"op": "connected", "u": [0], "v": [1, 2]}, "bad_request"),
        ({"op": "connected", "u": "xy", "v": "ab"}, "bad_request"),
        ({"op": "connected", "u": [0.5], "v": [1]}, "bad_request"),
        ({"op": "insert", "u": [0], "v": [1]}, "bad_request"),  # missing w
        ({"op": "connected", "u": [0], "v": [1],
          "deadline_ms": -1}, "bad_request"),
        ({"op": "connected", "u": [0], "v": [1], "id": []}, "bad_request"),
    ]
    for obj, code in cases:
        with pytest.raises(P.ProtocolError) as ei:
            P.validate_request(obj)
        assert ei.value.code == code, obj


def test_response_shapes():
    r = P.response(3, "connected", {"connected": [True]},
                   snapshot_version=9, stale=True, n_unhealed=2)
    assert r["ok"] and r["snapshot_version"] == 9 and r["stale"]
    assert r["schema"] == P.SCHEMA
    e = P.error_response(None, "insert", "overloaded", "queue full")
    assert not e["ok"] and e["error"]["code"] == "overloaded"
    # responses must themselves frame-encode
    dec = P.FrameDecoder()
    assert dec.feed(P.encode_frame(r) + P.encode_frame(e)) == [r, e]


# ---------------------------------------------------------------------------
# server end-to-end (loopback TCP)
# ---------------------------------------------------------------------------


def _stream_plan(n=128, batch_capacity=256):
    return plan(n, SolveSpec(
        mode="stream", batch_capacity=batch_capacity,
        reservoir_capacity=8192, reservoir_per_component=8192,
    ))


@pytest.fixture()
def server():
    p = _stream_plan()
    handle = serve.start_in_thread(
        p, serve.ServeConfig(port=0, micro_batch=64, queue_cap=256)
    )
    yield handle, p
    handle.drain()


def test_server_basic_ops(server):
    handle, p = server
    with serve.ServeClient(handle.address) as c:
        r = c.insert([0, 1, 2], [1, 2, 3], [1.0, 2.0, 3.0])
        assert r["ok"] and r["result"]["n_new"] == 3
        v_ins = r["snapshot_version"]
        r = c.connected([0, 0], [3, 5])
        assert r["result"]["connected"] == [True, False]
        assert r["snapshot_version"] >= v_ins and not r["stale"]
        r = c.component_size([0])
        assert r["result"]["size"] == [4]
        r = c.component_id([0, 1, 5])
        comp = r["result"]["component"]
        assert comp[0] == comp[1] != comp[2]
        r = c.delete([1], [2])
        assert r["ok"] and r["result"]["n_deleted"] == 1
        r = c.connected([0], [3])
        assert r["result"]["connected"] == [False]
        st = c.status(check=True)["result"]
        assert st["status"] == "serving" and st["n"] == 128
        m = c.metrics(check=True)["result"]["metrics"]
        assert m["counters"]["serve.queries"] >= 5
        assert m["counters"]["serve.writes"] == 2


def test_server_error_paths(server):
    handle, _ = server
    srv = handle.server
    with serve.ServeClient(handle.address) as c:
        r = c.call("frobnicate", u=[1])
        assert not r["ok"] and r["error"]["code"] == "unknown_op"
        r = c.connected([0], [128])  # out of range for n=128
        assert r["error"]["code"] == "bad_request"
        r = c.connected([], [])
        assert r["error"]["code"] == "bad_request"
        with pytest.raises(serve.ServeError):
            c.connected([0], [999], check=True)
        # a sub-tick deadline expires in the admission queue every time
        r = c.connected([0], [1], deadline_ms=1e-4)
        assert r["error"]["code"] == "deadline"
        # white-box: a full admission queue answers overloaded...
        srv._admitted_points = srv.config.queue_cap
        r = c.connected([0], [1])
        assert r["error"]["code"] == "overloaded"
        srv._admitted_points = 0
        # ...and a draining server refuses new ops in-band
        srv._draining = True
        try:
            r = c.connected([0], [1])
            assert r["error"]["code"] == "draining"
            assert c.status(check=True)["result"]["status"] == "draining"
        finally:
            srv._draining = False
        # the connection survives every in-band error above
        assert c.connected([0], [1])["ok"]


def test_server_survives_garbage_then_serves_new_connection(server):
    import socket as socketlib

    handle, _ = server
    s = socketlib.create_connection(("127.0.0.1", handle.port), timeout=10)
    s.sendall(P.HEADER.pack(12) + b"{not json!!}")
    s.sendall(P.encode_frame({"schema": P.SCHEMA, "id": 1, "op": "status"}))
    dec = P.FrameDecoder()
    got = []
    while len(got) < 2:
        data = s.recv(1 << 16)
        assert data, "server closed on a recoverable frame error"
        got.extend(dec.feed(data))
    assert got[0]["error"]["code"] == "bad_frame"
    assert got[1]["ok"] and got[1]["op"] == "status"
    # an oversized declared frame is unrecoverable: error, then close
    s.sendall(P.HEADER.pack(P.MAX_PAYLOAD + 1))
    tail = b""
    while True:
        data = s.recv(1 << 16)
        if not data:
            break
        tail += data
    err = P.FrameDecoder().feed(tail)[-1]
    assert err["error"]["code"] == "too_large"
    s.close()
    with serve.ServeClient(handle.address) as c:  # server still healthy
        assert c.status(check=True)["result"]["status"] == "serving"


def test_server_batches_pipelined_queries(server):
    handle, _ = server
    with serve.ServeClient(handle.address) as c:
        c.insert([0], [1], [1.0])
        futs = [c.submit("connected", u=[0], v=[1]) for _ in range(64)]
        for f in futs:
            resp = f.result(timeout=30)
            assert resp["ok"] and resp["result"]["connected"] == [True]
        m = c.metrics(check=True)["result"]["metrics"]
        occ = m["histograms"]["serve.batch_occupancy"]
        # 64 pipelined point queries must not arrive as 64 singleton
        # batches — the micro-batcher has to fuse at least some of them
        assert occ["max"] > 1.0
        assert m["histograms"]["serve.e2e_latency_s"]["count"] >= 64


# ---------------------------------------------------------------------------
# consistency: answers match a recompute at the response's version
# ---------------------------------------------------------------------------


def test_sequential_write_query_consistency():
    n = 64
    p = _stream_plan(n=n)
    oracle = _SurvivorOracle(n)
    handle = serve.start_in_thread(p, serve.ServeConfig(port=0))
    try:
        with serve.ServeClient(handle.address) as c:
            rng = np.random.default_rng(11)
            for step in range(25):
                if rng.random() < 0.7 or not oracle.edges:
                    m = int(rng.integers(1, 12))
                    u = rng.integers(0, n, m)
                    v = rng.integers(0, n, m)
                    w = rng.integers(1, 50, m).astype(np.float64)
                    r = c.insert(u, v, w)
                    assert r["ok"]
                    oracle.insert(u, v, w)
                else:
                    ks = list(oracle.edges)
                    pick = rng.choice(len(ks), size=min(4, len(ks)),
                                      replace=False)
                    uu = np.array([ks[i][0] for i in pick])
                    vv = np.array([ks[i][1] for i in pick])
                    r = c.delete(uu, vv)
                    assert r["ok"]
                    oracle.delete(uu, vv)
                w_true, _, p_true = oracle.recompute()
                assert abs(r["result"]["weight"] - w_true) <= max(
                    1e-3, 1e-6 * abs(w_true)
                ), step
                # no concurrent writer: queries see exactly this version
                qu = rng.integers(0, n, 16)
                qv = rng.integers(0, n, 16)
                qr = c.connected(qu, qv)
                assert qr["snapshot_version"] == r["result"]["version"]
                want = (p_true[qu] == p_true[qv]).tolist()
                assert qr["result"]["connected"] == want, step
    finally:
        handle.drain()


def test_concurrent_readers_during_writer_churn():
    """The contention core: reader connections hammer ``connected``
    while the writer lane churns inserts/deletes. Every response's
    snapshot version must be monotone per connection, and every answer
    at a version published by a *completed* write op must match the
    survivor-multiset recompute at that version."""
    n = 64
    p = _stream_plan(n=n)
    oracle = _SurvivorOracle(n)
    handle = serve.start_in_thread(
        p, serve.ServeConfig(port=0, micro_batch=32, queue_cap=512)
    )
    # version -> canonical partition after each completed write op
    # (delete heals can publish intermediate versions; readers only
    # assert against versions recorded here)
    partitions = {}
    stop = threading.Event()
    writer_err = []

    def writer():
        rng = np.random.default_rng(23)
        try:
            with serve.ServeClient(handle.address) as wc:
                while not stop.is_set():
                    if rng.random() < 0.65 or not oracle.edges:
                        m = int(rng.integers(1, 10))
                        u = rng.integers(0, n, m)
                        v = rng.integers(0, n, m)
                        w = rng.integers(1, 50, m).astype(np.float64)
                        r = wc.insert(u, v, w)
                        assert r["ok"], r
                        oracle.insert(u, v, w)
                    else:
                        ks = list(oracle.edges)
                        pick = rng.choice(len(ks), size=min(3, len(ks)),
                                          replace=False)
                        uu = np.array([ks[i][0] for i in pick])
                        vv = np.array([ks[i][1] for i in pick])
                        r = wc.delete(uu, vv)
                        assert r["ok"], r
                        oracle.delete(uu, vv)
                    _, _, p_true = oracle.recompute()
                    partitions[r["result"]["version"]] = p_true
                    time.sleep(0.002)
        except Exception as e:  # pragma: no cover - surfaced below
            writer_err.append(e)

    observations = []  # (version, u, v, answer) per reader
    reader_err = []

    def reader(seed):
        rng = np.random.default_rng(seed)
        try:
            with serve.ServeClient(handle.address) as rc:
                last_v = -1
                for _ in range(80):
                    u = int(rng.integers(0, n))
                    v = int(rng.integers(0, n))
                    r = rc.connected([u], [v])
                    assert r["ok"], r
                    ver = r["snapshot_version"]
                    # per-connection snapshot monotonicity
                    assert ver >= last_v, (ver, last_v)
                    last_v = ver
                    observations.append(
                        (ver, u, v, r["result"]["connected"][0])
                    )
        except Exception as e:  # pragma: no cover - surfaced below
            reader_err.append(e)

    wt = threading.Thread(target=writer)
    readers = [threading.Thread(target=reader, args=(100 + i,))
               for i in range(3)]
    wt.start()
    for t in readers:
        t.start()
    for t in readers:
        t.join(timeout=120)
    stop.set()
    wt.join(timeout=120)
    handle.drain()
    assert not writer_err, writer_err
    assert not reader_err, reader_err
    assert observations
    # answers must be consistent with SOME published snapshot — checked
    # exactly at every version a completed write op published
    checked = 0
    for ver, u, v, ans in observations:
        p_true = partitions.get(ver)
        if p_true is None:
            continue  # warm state or an intermediate heal version
        assert ans == bool(p_true[u] == p_true[v]), (ver, u, v)
        checked += 1
    assert checked > 0, "no observation landed on a write-published version"


# ---------------------------------------------------------------------------
# graceful drain + durable restart
# ---------------------------------------------------------------------------


def test_drain_refuses_new_work_and_stops():
    p = _stream_plan()
    handle = serve.start_in_thread(p, serve.ServeConfig(port=0))
    with serve.ServeClient(handle.address) as c:
        assert c.insert([0], [1], [1.0])["ok"]
    handle.drain()
    assert handle.server.draining
    with pytest.raises((ConnectionError, OSError)):
        serve.ServeClient(handle.address, timeout=2)


def test_server_checkpoint_restart_resumes_bit_identical(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    spec = SolveSpec(mode="stream", batch_capacity=256,
                     reservoir_capacity=8192, reservoir_per_component=8192)
    n = 96
    p1 = plan(n, spec)
    h1 = serve.start_in_thread(
        p1, serve.ServeConfig(port=0, checkpoint_dir=ckpt)
    )
    rng = np.random.default_rng(5)
    with serve.ServeClient(h1.address) as c:
        for _ in range(6):
            m = 24
            u = rng.integers(0, n, m)
            v = rng.integers(0, n, m)
            w = rng.integers(1, 99, m).astype(np.float64)
            assert c.insert(u, v, w)["ok"]
        flo, fhi, _, _ = p1.engine.forest_edges()
        assert c.delete(flo[:4], fhi[:4])["ok"]
        v_final = c.status(check=True)["snapshot_version"]
    h1.drain()  # kill: the drain checkpoint is the durable state
    weight1 = p1.engine.weight
    gids1 = set(int(g) for g in p1.engine.forest_gids())

    p2 = plan(n, spec)  # fresh process-equivalent: empty engine
    h2 = serve.start_in_thread(
        p2, serve.ServeConfig(port=0, checkpoint_dir=ckpt)
    )
    try:
        assert h2.server.restored_version == v_final
        assert p2.engine.weight == weight1  # bit-identical, not approx
        assert set(int(g) for g in p2.engine.forest_gids()) == gids1
        with serve.ServeClient(h2.address) as c:
            st = c.status(check=True)
            assert st["snapshot_version"] == v_final
            assert st["result"]["restored_version"] == v_final
            # the restored forest answers queries
            qu = rng.integers(0, n, 32)
            qv = rng.integers(0, n, 32)
            want = np.asarray(p1.service.connected(qu, qv))
            got = c.connected(qu, qv)["result"]["connected"]
            assert got == want.tolist()
            # and keeps accepting writes at the resumed gid/version line
            r = c.insert([0, 1], [1, 2], [0.5, 0.25])
            assert r["ok"] and r["result"]["version"] == v_final + 1
    finally:
        h2.drain()


def test_config_mismatch_rejected_on_restore(tmp_path):
    from repro.stream import persist
    from repro.stream.engine import StreamEngine

    eng = StreamEngine(64, batch_capacity=32)
    eng.insert_batch([0, 1], [1, 2], [1.0, 2.0])
    persist.save_stream(str(tmp_path), eng)
    other = StreamEngine(128, batch_capacity=32)  # different n
    with pytest.raises(ValueError, match="config"):
        persist.restore_stream(str(tmp_path), other)
