"""Streaming MSF engine: sparsification identity vs full recompute,
delta dedupe/gid stability, tombstone deletions + compaction, the
snapshot/version protocol, and batched query serving (DESIGN.md §6)."""
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st  # skips cleanly if absent

from repro.core.msf import msf
from repro.graphs.generators import rmat_graph
from repro.graphs.structures import (
    from_edges,
    nx_free_msf_weight,
    nx_free_n_components,
)
from repro.stream import MicroBatcher, QueryService, StreamingMSF, next_pow2


def _random_batches(rng, n, k, per):
    out = []
    for _ in range(k):
        m = int(rng.integers(1, per + 1))
        out.append(
            (
                rng.integers(0, n, m),
                rng.integers(0, n, m),
                rng.integers(1, 256, m).astype(np.float64),
            )
        )
    return out


def _accumulated(batches, n):
    u = np.concatenate([b[0] for b in batches])
    v = np.concatenate([b[1] for b in batches])
    w = np.concatenate([b[2] for b in batches])
    return from_edges(u, v, w, n)


def _same_partition(a, b):
    """Two label vectors induce the same partition (bijective label map)."""
    fwd, bwd = {}, {}
    for x, y in zip(np.asarray(a), np.asarray(b)):
        if fwd.setdefault(int(x), int(y)) != int(y):
            return False
        if bwd.setdefault(int(y), int(x)) != int(x):
            return False
    return True


# ---------------------------------------------------------------------------
# sparsification identity: streaming == from-scratch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,k", [(0, 3), (1, 6), (2, 10)])
def test_stream_matches_full_recompute(seed, k):
    rng = np.random.default_rng(seed)
    n = 256
    eng = StreamingMSF(n, batch_capacity=128)
    batches = _random_batches(rng, n, k, 100)
    for u, v, w in batches:
        eng.insert_batch(u, v, w)
    g = _accumulated(batches, n)
    assert abs(eng.weight - nx_free_msf_weight(g)) < 1e-3
    full = msf(g)
    assert _same_partition(eng.snapshots.acquire().parent, full.parent)
    assert eng.snapshots.acquire().n_components == nx_free_n_components(g)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(4, 60),
    k=st.integers(1, 6),
    per=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_stream_property_sparsification_identity(n, k, per, seed):
    """Property: after k random insert batches the engine's weight and
    partition match msf() on the accumulated edge set."""
    rng = np.random.default_rng(seed)
    eng = StreamingMSF(n, batch_capacity=per)
    batches = _random_batches(rng, n, k, per)
    for u, v, w in batches:
        eng.insert_batch(u, v, w)
    g = _accumulated(batches, n)
    assert abs(eng.weight - nx_free_msf_weight(g)) < 1e-3
    assert _same_partition(eng.snapshots.acquire().parent, msf(g).parent)


# ---------------------------------------------------------------------------
# acceptance: 2^16-vertex RMAT, one executable, bounded union buffer
# ---------------------------------------------------------------------------


def test_stream_rmat_2e16_acceptance():
    """2^16-vertex RMAT stream: forest weight and component labels equal a
    full msf() recompute over the union, with every update executing over
    ≤ (n − 1 + |batch|) padded undirected edges."""
    scale, batch_cap = 16, 8192
    n = 1 << scale
    g_full = rmat_graph(scale, 2, seed=7)
    src = np.asarray(g_full.src)
    dst = np.asarray(g_full.dst)
    w = np.asarray(g_full.w)
    sel = np.asarray(g_full.valid) & (src < dst)
    lo, hi, w = src[sel], dst[sel], w[sel]
    rng = np.random.default_rng(7)
    perm = rng.permutation(len(lo))
    lo, hi, w = lo[perm], hi[perm], w[perm]

    eng = StreamingMSF(n, batch_capacity=batch_cap)
    for k in range(0, len(lo), batch_cap):
        eng.insert_batch(lo[k : k + batch_cap], hi[k : k + batch_cap],
                         w[k : k + batch_cap])
        # traced edge-buffer bound: ≤ (n − 1 + |batch|) undirected slots,
        # i.e. exactly 2 * (n − 1 + batch_capacity) directed entries
        assert eng.last_union_shape == (2 * (n - 1 + batch_cap),)
    full = msf(from_edges(lo, hi, w.astype(np.float64), n))
    assert abs(eng.weight - float(full.weight)) < max(1.0, 1e-6 * eng.weight)
    assert _same_partition(eng.snapshots.acquire().parent, full.parent)


# ---------------------------------------------------------------------------
# delta: dedupe, weight decrease, stable gids
# ---------------------------------------------------------------------------


def test_duplicate_insert_is_dropped_and_decrease_keeps_gid():
    n = 64
    eng = StreamingMSF(n, batch_capacity=16)
    eng.insert_batch([0, 1, 2], [1, 2, 3], [10.0, 20.0, 30.0])
    w0 = eng.weight
    lo, hi, w, gid = eng.forest_edges()
    # re-insert heavier duplicate: dropped entirely
    s = eng.insert_batch([1, 0], [0, 1], [50.0, 99.0])
    assert s.n_new == 0 and s.n_decrease == 0
    assert s.n_drop >= 1  # in-batch dup + live dup both count
    assert eng.weight == w0
    # cheaper duplicate: weight decrease, same gid
    gid_01 = gid[(lo == 0) & (hi == 1)][0]
    s = eng.insert_batch([1], [0], [4.0])
    assert s.n_decrease == 1 and s.n_new == 0
    lo2, hi2, w2, gid2 = eng.forest_edges()
    m = (lo2 == 0) & (hi2 == 1)
    assert w2[m][0] == 4.0 and gid2[m][0] == gid_01
    assert abs(eng.weight - (w0 - 6.0)) < 1e-6


def test_batch_capacity_enforced_and_bad_input_rejected():
    eng = StreamingMSF(16, batch_capacity=2)
    with pytest.raises(ValueError):
        eng.insert_batch([0, 1, 2], [1, 2, 3], [1.0, 2.0, 3.0])
    with pytest.raises(ValueError):
        eng.insert_batch([0], [99], [1.0])  # endpoint out of range


def test_prepare_batch_accepts_scalars_and_empty():
    """0-d/scalar inputs are one-edge batches, not a TypeError; empty
    batches pass through with count 0."""
    from repro.stream import delta

    pb = delta.prepare_batch(3, 5, 1.0, 8)
    assert pb.count == 1 and pb.dropped == 0
    assert (int(pb.lo[0]), int(pb.hi[0]), float(pb.w[0])) == (3, 5, 1.0)
    pb = delta.prepare_batch(np.int64(5), np.int64(3), np.float64(2.0), 8)
    assert pb.count == 1 and int(pb.lo[0]) == 3 and int(pb.hi[0]) == 5
    pb = delta.prepare_batch([], [], [], 8)
    assert pb.count == 0 and pb.dropped == 0
    pb = delta.prepare_batch(2, 2, 1.0, 8)  # scalar self-loop
    assert pb.count == 0 and pb.dropped == 1
    with pytest.raises(ValueError):
        delta.prepare_batch([0, 1], [1], [1.0, 2.0], 8)  # shape mismatch


def test_scalar_insert_and_delete_roundtrip():
    eng = StreamingMSF(8, batch_capacity=4)
    s = eng.insert_batch(0, 1, 1.5)
    assert s.n_new == 1 and abs(eng.weight - 1.5) < 1e-6
    d = eng.delete_batch(1, 0)
    assert d.n_deleted == 1 and eng.weight == 0.0


# ---------------------------------------------------------------------------
# deletions: tombstone, staleness, compaction trigger
# ---------------------------------------------------------------------------


def test_delete_tombstones_then_compaction_splits():
    """Legacy defer mode (exact_deletes=False): tombstone now, split at
    compaction — the old trade-off, kept as an explicit opt-out."""
    n = 8
    eng = StreamingMSF(n, batch_capacity=8, compact_trigger=10.0,
                       exact_deletes=False)  # manual compaction
    # path 0-1-2-3
    eng.insert_batch([0, 1, 2], [1, 2, 3], [1.0, 2.0, 3.0])
    v_before = eng.version
    assert eng.snapshots.acquire().n_components == n - 3
    d = eng.delete_batch([1], [2])
    assert d.n_deleted == 1 and not d.compacted
    snap = eng.snapshots.acquire()
    assert snap.stale and snap.version > v_before
    assert eng.n_forest_edges == 2
    # structural split only lands at compaction
    assert snap.n_components == n - 3
    eng.compact()
    snap = eng.snapshots.acquire()
    assert not snap.stale
    assert snap.n_components == n - 2
    assert abs(snap.weight - 4.0) < 1e-6


def test_delete_auto_compacts_past_trigger():
    eng = StreamingMSF(8, batch_capacity=8, compact_trigger=0.3)
    eng.insert_batch([0, 1, 2], [1, 2, 3], [1.0, 2.0, 3.0])
    d = eng.delete_batch([0], [1])  # 1/3 dead > 0.3 → compact
    assert d.compacted
    assert not eng.snapshots.acquire().stale
    assert eng.snapshots.acquire().n_components == 8 - 2


def test_delete_batch_larger_than_capacity():
    """Deletions are chunked internally — not bounded by batch_capacity."""
    eng = StreamingMSF(16, batch_capacity=2, compact_trigger=10.0)
    eng.insert_batch([0, 1], [1, 2], [1.0, 2.0])
    eng.insert_batch([2, 3], [3, 4], [3.0, 4.0])
    d = eng.delete_batch([0, 1, 2, 7, 9], [1, 2, 3, 8, 10])
    assert d.n_deleted == 3 and d.n_missing == 2


def test_stale_snapshot_weight_matches_live_edges():
    """Between tombstone and compaction the legacy defer mode's snapshot
    is stale in *connectivity* only: weight and edge count always track
    live edges."""
    eng = StreamingMSF(8, batch_capacity=8, compact_trigger=10.0,
                       exact_deletes=False)
    eng.insert_batch([0, 1, 2], [1, 2, 3], [1.0, 2.0, 3.0])
    eng.delete_batch([1], [2])
    snap = eng.snapshots.acquire()
    assert snap.stale
    assert snap.n_forest_edges == 2
    _, _, w_live, _ = eng.forest_edges()
    assert abs(snap.weight - float(w_live.sum())) < 1e-6  # 4.0, not 6.0


def test_delete_missing_edge_counts_missing():
    eng = StreamingMSF(8, batch_capacity=8)
    eng.insert_batch([0], [1], [1.0])
    d = eng.delete_batch([2], [3])
    assert d.n_deleted == 0 and d.n_missing == 1


def test_insert_after_delete_is_consistent():
    """Dead rows never enter the union: the next insert makes state exact."""
    n = 16
    eng = StreamingMSF(n, batch_capacity=8, compact_trigger=10.0)
    eng.insert_batch([0, 1, 2], [1, 2, 3], [1.0, 2.0, 3.0])
    eng.delete_batch([1], [2])
    eng.insert_batch([4], [5], [7.0])
    snap = eng.snapshots.acquire()
    assert not snap.stale
    # retained: (0,1) (2,3) (4,5) → 3 edges, weight 11, n-3 components
    assert eng.n_forest_edges == 3
    assert abs(snap.weight - 11.0) < 1e-6
    assert snap.n_components == n - 3


# ---------------------------------------------------------------------------
# exact deletions: replacement-edge reservoir (DESIGN.md §6.4)
# ---------------------------------------------------------------------------


def test_delete_forest_edge_heals_from_reservoir():
    """Deleting a tree edge promotes the cheapest retained non-tree edge
    crossing the cut — the published snapshot is the true MSF, not stale."""
    n = 8
    eng = StreamingMSF(n, batch_capacity=8)
    # triangle: (0,2) loses the race and lands in the reservoir
    eng.insert_batch([0, 1, 0], [1, 2, 2], [1.0, 2.0, 3.0])
    assert eng.reservoir_size == 1
    d = eng.delete_batch([1], [2])
    assert d.n_deleted == 1 and d.compacted
    assert d.n_replacements == 1 and d.n_unhealed == 0
    snap = eng.snapshots.acquire()
    assert not snap.stale and snap.n_unhealed == 0
    assert snap.n_components == n - 2  # {0,1,2} still connected via (0,2)
    assert abs(snap.weight - 4.0) < 1e-6
    assert eng.reservoir_size == 0  # the replacement was consumed


def test_delete_reservoir_edge_is_exact_without_heal():
    """Deleting a non-tree edge removes it from the reservoir in place —
    the forest is untouched and nothing needs to re-solve."""
    eng = StreamingMSF(8, batch_capacity=8)
    eng.insert_batch([0, 1, 0], [1, 2, 2], [1.0, 2.0, 3.0])
    v0, w0 = eng.version, eng.weight
    d = eng.delete_batch([0], [2])
    assert d.n_deleted == 0 and d.n_reservoir_deleted == 1
    assert d.n_missing == 0 and not d.compacted
    assert eng.reservoir_size == 0 and eng.weight == w0
    snap = eng.snapshots.acquire()
    assert snap.version > v0 and not snap.stale
    # the deleted non-tree edge must NOT come back as a replacement later
    d2 = eng.delete_batch([1], [2])
    assert d2.n_deleted == 1 and d2.n_replacements == 0
    assert eng.snapshots.acquire().n_components == 8 - 1


def test_delete_stats_counter_split():
    """n_missing / n_already_dead / n_dropped are separate counters, and
    prepare_batch's dropped self-loops/duplicates are no longer silently
    discarded on the delete path."""
    eng = StreamingMSF(8, batch_capacity=8)
    eng.insert_batch([0, 1], [1, 2], [1.0, 2.0])
    d = eng.delete_batch([3, 0, 0, 5], [3, 1, 1, 6])
    assert d.n_deleted == 1  # (0,1)
    assert d.n_missing == 1  # (5,6) never present
    assert d.n_dropped == 2  # self-loop (3,3) + duplicate (0,1)
    assert d.n_already_dead == 0


def test_delete_already_dead_counted_in_legacy_mode():
    """In defer mode a tombstoned edge deleted again is n_already_dead,
    not n_missing."""
    eng = StreamingMSF(8, batch_capacity=8, compact_trigger=10.0,
                       exact_deletes=False)
    eng.insert_batch([0, 1], [1, 2], [1.0, 2.0])
    d1 = eng.delete_batch([0], [1])
    assert d1.n_deleted == 1 and d1.n_already_dead == 0
    d2 = eng.delete_batch([0], [1])
    assert d2.n_deleted == 0 and d2.n_already_dead == 1 and d2.n_missing == 0


def test_reservoir_reinsert_revives_stable_gid():
    """Re-inserting a pair that lives in the reservoir pulls it back into
    the race under its original gid at the minimum of the two weights."""
    eng = StreamingMSF(8, batch_capacity=8)
    eng.insert_batch([0, 1, 0], [1, 2, 2], [1.0, 2.0, 3.0])
    _, _, _, gids = eng.forest_edges()
    res_gid = ({0, 1, 2} - set(int(g) for g in gids)).pop()
    s = eng.insert_batch([0], [2], [0.5])  # now the cheapest triangle edge
    assert s.n_revived == 1 and s.n_new == 1
    lo, hi, w, gid = eng.forest_edges()
    m = (lo == 0) & (hi == 2)
    assert m.any() and w[m][0] == 0.5 and gid[m][0] == res_gid
    # the displaced (1,2) edge is retained as a replacement candidate
    assert eng.reservoir_size == 1
    assert abs(eng.weight - 1.5) < 1e-6


def test_reservoir_exhaustion_marks_unhealed_then_recertify_recovers():
    """With retention disabled every eviction is lossy: a forest deletion
    there is unhealed (stale snapshot) until recertify() rebuilds from
    the caller's surviving multiset."""
    n = 8
    eng = StreamingMSF(n, batch_capacity=8, reservoir_capacity=0)
    eng.insert_batch([0, 1, 0], [1, 2, 2], [1.0, 2.0, 3.0])
    assert eng.reservoir_size == 0  # (0,2) was evicted on absorb
    d = eng.delete_batch([1], [2])
    assert d.n_unhealed == 1 and d.n_replacements == 0
    snap = eng.snapshots.acquire()
    assert snap.stale and snap.n_unhealed == 1 and eng.unhealed == 1
    # deletions elsewhere stay stale until recertification
    s = eng.insert_batch([4], [5], [9.0])
    assert eng.snapshots.acquire().stale
    # recovery: replay the surviving multiset from the system of record
    old_gids = set(int(g) for g in eng.forest_gids())
    eng.recertify([0, 0, 4], [1, 2, 5], [1.0, 3.0, 9.0])
    snap = eng.snapshots.acquire()
    assert not snap.stale and snap.n_unhealed == 0 and eng.unhealed == 0
    assert abs(snap.weight - 13.0) < 1e-6
    assert snap.n_components == n - 3  # {0,1,2} reconnected via (0,2)
    # surviving forest edges kept their gids through the rebuild
    assert old_gids <= set(int(g) for g in eng.forest_gids()) | {-1}


def test_per_component_cap_eviction_is_conservative():
    """Evicting past the per-component cap marks the component lossy:
    later forest deletions there report unhealed instead of silently
    serving a wrong forest."""
    eng = StreamingMSF(8, batch_capacity=8, reservoir_per_component=1)
    # K4 on {0..3}: forest keeps 3 edges, 3 losers fight for 1 slot
    eng.insert_batch([0, 0, 0, 1, 1, 2], [1, 2, 3, 2, 3, 3],
                     [1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    assert eng.reservoir_size == 1
    d = eng.delete_batch([0], [1])
    assert d.n_unhealed == 1
    assert eng.snapshots.acquire().stale


def test_chunked_heal_with_many_candidates_is_exact():
    """More replacement candidates than batch_capacity: the heal runs in
    capacity-sized chunks and still lands on the true MSF."""
    rng = np.random.default_rng(7)
    n = 32
    eng = StreamingMSF(n, batch_capacity=8, reservoir_capacity=4096,
                       reservoir_per_component=4096)
    batches = _random_batches(rng, n, 12, 8)
    for u, v, w in batches:
        eng.insert_batch(u, v, w)
    assert eng.reservoir_size > 8  # heal must chunk
    lo, hi, _, _ = eng.forest_edges()
    d = eng.delete_batch([lo[0]], [hi[0]])
    assert d.n_unhealed == 0
    # oracle: full recompute over the surviving multiset
    g = _accumulated(batches, n)
    uu, vv, ww = np.asarray(g.src), np.asarray(g.dst), np.asarray(g.w)
    half = np.asarray(g.valid) & (uu < vv)
    keep = half & ~((np.minimum(uu, vv) == min(lo[0], hi[0]))
                    & (np.maximum(uu, vv) == max(lo[0], hi[0])))
    full = msf(from_edges(uu[keep], vv[keep], ww[keep].astype(np.float64), n))
    snap = eng.snapshots.acquire()
    assert not snap.stale
    assert abs(snap.weight - float(full.weight)) < 1e-3
    assert _same_partition(snap.parent, full.parent)


def test_reservoir_obs_counters():
    """stream.reservoir.{hits,evictions,exhausted} reach the metrics
    registry."""
    from repro.obs.metrics import default_registry

    base = dict(default_registry().snapshot()["counters"])
    eng = StreamingMSF(8, batch_capacity=8, reservoir_capacity=0)
    eng.insert_batch([0, 1, 0], [1, 2, 2], [1.0, 2.0, 3.0])
    eng.delete_batch([1], [2])
    now = default_registry().snapshot()["counters"]

    def delta_of(name):
        return now.get(name, 0) - base.get(name, 0)

    assert delta_of("stream.reservoir.evictions") >= 1
    assert delta_of("stream.reservoir.exhausted") >= 1
    eng2 = StreamingMSF(8, batch_capacity=8)
    eng2.insert_batch([0, 1, 0], [1, 2, 2], [1.0, 2.0, 3.0])
    eng2.delete_batch([1], [2])
    now = default_registry().snapshot()["counters"]
    assert delta_of("stream.reservoir.hits") >= 1


def test_published_weight_exactly_equals_live_sum_after_mixed_workload():
    """Regression (float32 drift): the published weight is recomputed from
    the live rows at publish time, never decremented — bit-exact equality
    with the float64 row sum even after a long insert/delete churn."""
    rng = np.random.default_rng(11)
    n = 64
    eng = StreamingMSF(n, batch_capacity=32)
    inserted = []
    for _ in range(40):
        m = int(rng.integers(1, 16))
        u, v = rng.integers(0, n, m), rng.integers(0, n, m)
        # fractional weights: exactly the regime where -= drifts
        w = rng.random(m) * 10.0 + 0.1
        eng.insert_batch(u, v, w)
        inserted += [(int(a), int(b)) for a, b in zip(u, v) if a != b]
        if inserted and rng.random() < 0.6:
            k = int(rng.integers(1, min(6, len(inserted)) + 1))
            picks = [inserted[i] for i in
                     rng.choice(len(inserted), size=k, replace=False)]
            eng.delete_batch([p[0] for p in picks], [p[1] for p in picks])
        _, _, w_live, _ = eng.forest_edges()
        assert eng.snapshots.acquire().weight == float(
            w_live.sum(dtype=np.float64)
        )


def test_legacy_defer_mode_weight_exact_after_tombstones():
    """The live-row weight recompute also fixes the defer path: tombstone
    a few rows, no compaction, and the published weight still equals the
    float64 live sum exactly."""
    eng = StreamingMSF(16, batch_capacity=8, compact_trigger=10.0,
                       exact_deletes=False)
    eng.insert_batch([0, 1, 2, 3], [1, 2, 3, 4], [0.1, 0.2, 0.3, 0.4])
    eng.delete_batch([1, 3], [2, 4])
    _, _, w_live, _ = eng.forest_edges()
    assert eng.snapshots.acquire().weight == float(w_live.sum(dtype=np.float64))


# ---------------------------------------------------------------------------
# adaptive batch capacity
# ---------------------------------------------------------------------------


def test_adaptive_capacity_grows_and_shrinks_pow2():
    rng = np.random.default_rng(21)
    n = 128
    eng = StreamingMSF(
        n, batch_capacity=256, adaptive_capacity=True, min_capacity=16
    )
    batches = []
    caps = []
    # small → big → sustained small again
    sizes = [4, 6, 200, 5] + [3] * 9
    for m in sizes:
        u, v = rng.integers(0, n, m), rng.integers(0, n, m)
        w = rng.integers(1, 256, m).astype(np.float64)
        s = eng.insert_batch(u, v, w)
        batches.append((u, v, w))
        caps.append(s.batch_capacity)
        # capacity is always a power of two within [min, max]
        assert 16 <= s.batch_capacity <= 256
        assert s.batch_capacity & (s.batch_capacity - 1) == 0
        # union buffer tracks the adaptive capacity exactly
        assert s.union_directed_edges == 2 * (n - 1 + s.batch_capacity)
    assert caps[0] == 16  # starts at the floor
    assert max(caps) == 256  # grew to fit the 200-edge batch
    assert caps[-1] < max(caps)  # shrank back after sustained small batches
    # recompile count is visible and bounded by the pow2 ladder walked
    assert 2 <= eng.recompiles <= 8
    # exactness is untouched by resizing
    g = _accumulated(batches, n)
    assert abs(eng.weight - nx_free_msf_weight(g)) < 1e-3
    assert _same_partition(eng.snapshots.acquire().parent, msf(g).parent)


def test_adaptive_capacity_still_enforces_max():
    eng = StreamingMSF(64, batch_capacity=4, adaptive_capacity=True)
    with pytest.raises(ValueError):
        eng.insert_batch([0, 1, 2, 3, 4], [1, 2, 3, 4, 5], [1.0] * 5)


def test_fixed_capacity_single_compile():
    eng = StreamingMSF(64, batch_capacity=8)
    s1 = eng.insert_batch([0], [1], [1.0])
    s2 = eng.insert_batch([1, 2], [2, 3], [2.0, 3.0])
    assert s1.recompiles == s2.recompiles == 1
    assert s1.batch_capacity == s2.batch_capacity == 8


# ---------------------------------------------------------------------------
# pack32 / Pallas segment-min inner loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("segmin", ["jnp", "pallas"])
def test_stream_pack_segmin_backends_match_oracle(segmin):
    """The Pallas flat segment-min wired into _run_union's inner loop
    (interpret=True on CPU) gives the same forest as the oracle."""
    rng = np.random.default_rng(31)
    n = 96
    eng = StreamingMSF(n, batch_capacity=64, pack=True, segmin=segmin)
    batches = _random_batches(rng, n, 4, 50)
    for u, v, w in batches:
        eng.insert_batch(u, v, w)
    g = _accumulated(batches, n)
    assert abs(eng.weight - nx_free_msf_weight(g)) < 1e-3
    assert _same_partition(eng.snapshots.acquire().parent, msf(g).parent)


def test_pack_auto_falls_back_on_fractional_weights():
    eng = StreamingMSF(32, batch_capacity=8)
    assert eng._use_pack()  # integral weights so far (none)
    eng.insert_batch([0, 1], [1, 2], [0.5, 2.25])
    assert not eng._use_pack()  # permanently unpackable
    eng.insert_batch([2], [3], [1.0])
    assert abs(eng.weight - 3.75) < 1e-6


def test_pack_true_rejects_fractional_weights():
    eng = StreamingMSF(32, batch_capacity=8, pack=True)
    with pytest.raises(ValueError, match="integral"):
        eng.insert_batch([0], [1], [0.5])


# ---------------------------------------------------------------------------
# snapshot protocol
# ---------------------------------------------------------------------------


def test_snapshot_double_buffer_consistency():
    eng = StreamingMSF(32, batch_capacity=8)
    eng.insert_batch([0, 1], [1, 2], [1.0, 2.0])
    held = eng.snapshots.acquire()  # a reader holds version v
    v = held.version
    w_held = held.weight
    parent_held = np.asarray(held.parent).copy()
    eng.insert_batch([5, 6], [6, 7], [3.0, 4.0])  # publish v+1
    assert eng.snapshots.acquire().version == v + 1
    # the held snapshot is untouched: labels, weight, version all from v
    assert held.version == v and held.weight == w_held
    assert np.array_equal(np.asarray(held.parent), parent_held)


def test_versions_monotone_across_all_mutations():
    eng = StreamingMSF(16, batch_capacity=8, compact_trigger=10.0)
    seen = [eng.snapshots.version]
    eng.insert_batch([0, 1], [1, 2], [1.0, 2.0])
    seen.append(eng.snapshots.version)
    eng.delete_batch([0], [1])
    seen.append(eng.snapshots.version)
    eng.compact()
    seen.append(eng.snapshots.version)
    assert seen == sorted(seen) and len(set(seen)) == len(seen)


# ---------------------------------------------------------------------------
# query serving
# ---------------------------------------------------------------------------


def test_query_service_matches_scipy_labels():
    rng = np.random.default_rng(3)
    n = 300
    eng = StreamingMSF(n, batch_capacity=512)
    svc = QueryService(eng.snapshots)
    u = rng.integers(0, n, 500)
    v = rng.integers(0, n, 500)
    w = rng.integers(1, 256, 500).astype(np.float64)
    eng.insert_batch(u, v, w)
    g = from_edges(u, v, w, n)
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csg

    src, dst, val = np.asarray(g.src), np.asarray(g.dst), np.asarray(g.valid)
    a = sp.coo_matrix((np.ones(val.sum()), (src[val], dst[val])), shape=(n, n))
    _, lab = csg.connected_components(a, directed=False)

    qu = rng.integers(0, n, 333)  # deliberately not a power of two
    qv = rng.integers(0, n, 333)
    assert np.array_equal(svc.connected(qu, qv), lab[qu] == lab[qv])
    comp = svc.component_id(qu)
    assert _same_partition(lab[qu], comp)
    sizes = np.bincount(lab, minlength=lab.max() + 1)
    assert np.array_equal(svc.component_size(qu), sizes[lab[qu]])
    assert abs(svc.forest_weight() - eng.weight) < 1e-6


def test_query_padding_and_bounds():
    assert next_pow2(1) == 16 and next_pow2(17) == 32 and next_pow2(64) == 64
    eng = StreamingMSF(8, batch_capacity=4)
    svc = QueryService(eng.snapshots, max_batch=8)
    with pytest.raises(ValueError):
        svc.connected(np.zeros(9, np.int32), np.zeros(9, np.int32))
    with pytest.raises(ValueError):
        svc.connected([0], [8])  # out of range
    assert svc.connected([], []).shape == (0,)


def test_microbatcher_single_snapshot_window():
    eng = StreamingMSF(16, batch_capacity=8)
    eng.insert_batch([0, 1, 4], [1, 2, 5], [1.0, 2.0, 3.0])
    mb = MicroBatcher(QueryService(eng.snapshots))
    t1 = mb.ask_connected(0, 2)
    t2 = mb.ask_connected(0, 4)
    t3 = mb.ask_connected(4, 5)
    res = mb.flush()
    assert res == [True, False, True]
    assert mb.result(t1) and not mb.result(t2) and mb.result(t3)
    # the just-flushed window stays redeemable while the next one opens
    # (retain_windows=1): a ticket's answer stays correct, never wrong
    t4 = mb.ask_connected(0, 1)
    assert mb.result(t1) and mb.result(t4)
    # but once a window ages past the retention horizon it is a
    # KeyError instead of ever serving a stale-window answer
    t5 = mb.ask_connected(0, 1)  # third window opens
    mb.flush()  # retain_windows=1: only this flush stays redeemable
    with pytest.raises(KeyError):
        mb.result(t1)
    with pytest.raises(KeyError):
        mb.result(t4)
    assert mb.result(t5)


def test_microbatcher_concurrent_ask_flush():
    """Threads racing ask_connected against flushes must never lose or
    double-answer a ticket: every ticket redeems exactly once with the
    ground-truth answer, and the queue-depth gauge lands at zero."""
    import threading

    from repro import obs

    n = 64
    eng = StreamingMSF(n, batch_capacity=128)
    rng = np.random.default_rng(17)
    u = rng.integers(0, n, 96)
    v = rng.integers(0, n, 96)
    w = rng.integers(1, 99, 96).astype(np.float64)
    eng.insert_batch(u, v, w)
    svc = QueryService(eng.snapshots)
    truth = {}  # static graph: one recompute is the oracle
    mb = MicroBatcher(svc, max_queue=8, retain_windows=256)

    errors: list = []
    results: dict = {}
    lock = threading.Lock()

    def worker(seed: int) -> None:
        wrng = np.random.default_rng(seed)
        try:
            mine = []
            for _ in range(100):
                qu = int(wrng.integers(0, n))
                qv = int(wrng.integers(0, n))
                mine.append(((qu, qv), mb.ask_connected(qu, qv)))
                if wrng.random() < 0.1:
                    mb.flush()
            mb.flush()
            for (qu, qv), ticket in mine:
                got = mb.result(ticket)  # exactly-once redemption
                with lock:
                    results[ticket] = ((qu, qv), got)
        except Exception as e:  # pragma: no cover - surfaced below
            errors.append(e)

    obs.enable("metrics")
    try:
        threads = [threading.Thread(target=worker, args=(1000 + i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors
        assert len(results) == 400  # no lost, no double-answered tickets
        pairs = np.array([pair for pair, _ in results.values()])
        want = svc.connected(pairs[:, 0], pairs[:, 1])
        got = np.array([ans for _, ans in results.values()])
        assert np.array_equal(got, want)
        depth = obs.metrics_snapshot()["gauges"].get(
            "stream.batcher.queue_depth", 0.0
        )
        assert depth == 0.0  # final flush left nothing admitted
    finally:
        obs.disable()


def test_stream_coarsen_recompute_matches_flat_engine():
    """The coarsen-aware union rebuild (fused levels + sorted dedupe) must
    maintain the exact same forest as the flat recompute engine, and only
    engage past the live-edge threshold."""
    from repro.coarsen import CoarsenConfig
    from repro.launch.serve_graph import undirected_edges

    n = 1 << 11
    g = rmat_graph(11, 4, seed=9)
    lo, hi, w = undirected_edges(g)
    B = 512
    flat_eng = StreamingMSF(n, batch_capacity=B)
    # cutoff below n so the rebuild actually contracts (the default 2048
    # cutoff at n = 2048 would silently degenerate to the flat solve)
    co_eng = StreamingMSF(
        n, batch_capacity=B, coarsen=CoarsenConfig(cutoff=256),
        coarsen_threshold=1024,
    )
    for k in range(len(lo) // B):
        sl = slice(k * B, (k + 1) * B)
        flat_eng.insert_batch(lo[sl], hi[sl], w[sl])
        co_eng.insert_batch(lo[sl], hi[sl], w[sl])
    # the rebuild must have run real contraction levels, not the
    # zero-level degenerate form
    assert co_eng.last_coarsen_stats is not None
    assert len(co_eng.last_coarsen_stats.levels) >= 1
    assert abs(flat_eng.weight - co_eng.weight) < 1e-3
    f1 = sorted(zip(*[a.tolist() for a in flat_eng.forest_edges()[:2]]))
    f2 = sorted(zip(*[a.tolist() for a in co_eng.forest_edges()[:2]]))
    assert f1 == f2
    s1, s2 = flat_eng.snapshots.acquire(), co_eng.snapshots.acquire()
    assert s1.n_components == s2.n_components
    # deletions + compaction still work through the coarsen rebuild
    l0, h0, _, _ = co_eng.forest_edges()
    co_eng.delete_batch(l0[:50], h0[:50])
    co_eng.compact()
    assert co_eng.snapshots.acquire().n_components >= s2.n_components


def test_stream_coarsen_below_threshold_stays_flat():
    """With a huge threshold the coarsen engine must behave exactly like
    the flat one (the flat branch is taken every update)."""
    from repro.coarsen import CoarsenConfig

    n = 256
    eng = StreamingMSF(n, batch_capacity=32,
                       coarsen=CoarsenConfig(cutoff=32),
                       coarsen_threshold=1 << 20)
    rng = np.random.default_rng(3)
    for _ in range(4):
        u = rng.integers(0, n, 32)
        v = rng.integers(0, n, 32)
        eng.insert_batch(u, v, rng.integers(1, 100, 32).astype(float))
    assert eng.last_coarsen_stats is None  # flat branch taken every time
    ref_eng = StreamingMSF(n, batch_capacity=32)
    rng = np.random.default_rng(3)
    for _ in range(4):
        u = rng.integers(0, n, 32)
        v = rng.integers(0, n, 32)
        ref_eng.insert_batch(u, v, rng.integers(1, 100, 32).astype(float))
    assert abs(eng.weight - ref_eng.weight) < 1e-9
