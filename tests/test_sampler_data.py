"""Neighbor sampler invariants + data-pipeline determinism (exact-resume
requirement)."""
import numpy as np

from repro.data.pipeline import (
    LMBatchSource,
    MoleculeBatchSource,
    RecsysBatchSource,
    make_planted_graph_task,
)
from repro.graphs import random_graph, to_csr
from repro.graphs.sampler import NeighborSampler, max_sample_sizes


def test_sampler_subgraph_valid():
    g = random_graph(500, 3000, seed=0)
    indptr, indices, _, _ = to_csr(g)
    s = NeighborSampler(indptr, indices, seed=1)
    seeds = np.arange(32)
    sub = s.sample(seeds, fanouts=(5, 3))
    n_pad, e_pad = max_sample_sizes(32, (5, 3))
    assert sub.src.shape == (e_pad,)
    assert sub.node_ids.shape == (n_pad,)
    # seeds occupy the first slots
    np.testing.assert_array_equal(sub.node_ids[:32], seeds)
    # every sampled edge exists in the original CSR (as dst<-src neighbor)
    adj = {u: set(indices[indptr[u]:indptr[u + 1]]) for u in range(500)}
    for k in np.nonzero(sub.edge_valid)[0]:
        u = sub.node_ids[sub.dst[k]]
        v = sub.node_ids[sub.src[k]]
        assert v in adj[u], (u, v)
    # fanout respected: each node's incoming sampled edges ≤ fanout
    counts = np.bincount(sub.dst[sub.edge_valid], minlength=n_pad)
    assert counts[:32].max() <= 5


def test_sampler_static_shapes_across_draws():
    g = random_graph(300, 2000, seed=2)
    indptr, indices, _, _ = to_csr(g)
    s = NeighborSampler(indptr, indices, seed=1)
    shapes = set()
    for i in range(3):
        sub = s.sample(np.arange(16) + i, fanouts=(4, 2))
        shapes.add((sub.src.shape, sub.node_ids.shape))
    assert len(shapes) == 1  # jit-stable


def test_pipelines_deterministic():
    lm = LMBatchSource(vocab=100, seq_len=16, batch=4, seed=3)
    a1, b1 = lm.batch_at(10)
    a2, b2 = lm.batch_at(10)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    a3, _ = lm.batch_at(11)
    assert not np.array_equal(a1, a3)

    rs = RecsysBatchSource(np.array([0, 10, 30]), np.array([10, 20, 50]), batch=8, seed=4)
    i1, l1 = rs.batch_at(5)
    i2, l2 = rs.batch_at(5)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(l1, l2)

    mo = MoleculeBatchSource(n_atoms=6, n_edges=20, batch=3, seed=5)
    m1 = mo.batch_at(2)
    m2 = mo.batch_at(2)
    np.testing.assert_array_equal(m1["pos"], m2["pos"])
    np.testing.assert_array_equal(m1["energy"], m2["energy"])


def test_planted_graph_learnable_structure():
    t = make_planted_graph_task(100, 400, 16, 4, seed=0)
    assert t["labels"].min() >= 0 and t["labels"].max() < 4
    assert len(t["src"]) == 400
