"""Shim-parity suite: deprecated entry points ≡ the SolveSpec path.

For every graph class the property suite exercises
(``tests/test_msf_properties.py``: tie-heavy, multigraph, isolated,
single-edge, empty, two-component, fully-contracted, float-weight),
assert that

- the deprecated ``msf(...)`` kwarg paths (flat, coarsen, fused) and the
  deprecated ``msf_distributed(...)`` paths (flat driver and coarsen
  driver — the dual-return shim) produce **identical** weight, MSF eid
  set, and component partition to the equivalent ``SolveSpec`` plans;
- each deprecated call emits **exactly one** ``DeprecationWarning``.

This is the contract the tentpole promises: the old entry points are
thin shims over ``repro.solve`` — bit-identical while they live, loud
about their replacement.
"""
import warnings

import numpy as np
import pytest

import test_msf_properties as props
from repro.coarsen import CoarsenConfig
from repro.graphs.partition import partition_edges_2d
from repro.solve import SolveSpec, plan

_CFG = props._CFG  # the property suite's level config (cutoff=4)


def _one_warning(fn, *args, **kw):
    """Run fn, assert exactly one DeprecationWarning, return its result."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = fn(*args, **kw)
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1, (
        f"{getattr(fn, '__name__', fn)} emitted {len(deps)} "
        f"DeprecationWarnings (expected exactly 1): "
        f"{[str(w.message) for w in deps]}"
    )
    return out


def _silent(fn, *args, **kw):
    """Run fn asserting it emits NO DeprecationWarning (the spec path)."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = fn(*args, **kw)
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert not deps, f"spec path warned: {[str(w.message) for w in deps]}"
    return out


def _assert_identical(old, new, g, what: str):
    assert float(old.weight) == float(new.weight), (
        what, float(old.weight), float(new.weight),
    )
    assert props._eids(old) == set(np.asarray(new.msf_eids).tolist()), (
        f"{what}: eid set drifted between shim and spec path"
    )
    assert props._same_partition(old.parent, new.parent), (
        f"{what}: partitions disagree"
    )


def _check_graph(g, dist_mesh, dist_mesh_shape):
    from repro.core.msf import msf
    from repro.core.msf_dist import msf_distributed

    flat_spec = _silent(lambda: plan(g, SolveSpec()).solve())
    _assert_identical(_one_warning(msf, g), flat_spec, g, "flat")

    co_spec = _silent(
        lambda: plan(g, SolveSpec(mode="coarsen", coarsen=_CFG)).solve()
    )
    _assert_identical(_one_warning(msf, g, coarsen=_CFG), co_spec, g, "coarsen")

    fu_spec = _silent(
        lambda: plan(
            g, SolveSpec(mode="coarsen", coarsen=_CFG, fused=True)
        ).solve()
    )
    _assert_identical(
        _one_warning(msf, g, coarsen=_CFG, fused=True), fu_spec, g, "fused"
    )

    rows, cols = dist_mesh_shape
    part = partition_edges_2d(g, rows, cols)
    args = (part.src_row, part.dst_col, part.w, part.eid, part.valid)

    # dual-return shim, branch 1: no coarsen → jitted driver function
    drv = _one_warning(msf_distributed, part, dist_mesh)
    dist_spec = _silent(
        lambda: plan(part, SolveSpec(mode="dist"), mesh=dist_mesh).solve()
    )
    _assert_identical(drv(*args), dist_spec, g, "dist")

    # dual-return shim, branch 2: coarsen → DistCoarsenMSF driver
    cfg = CoarsenConfig(
        rounds_per_level=2, cutoff=4, fused=True, dedupe="device"
    )
    drv2 = _one_warning(msf_distributed, part, dist_mesh, coarsen=cfg)
    dist_co_spec = _silent(
        lambda: plan(
            part, SolveSpec(mode="dist", coarsen=cfg), mesh=dist_mesh
        ).solve()
    )
    _assert_identical(drv2(*args), dist_co_spec, g, "dist_coarsen")
    assert drv2.last_stats.host_roundtrips == dist_co_spec.host_roundtrips


@pytest.mark.parametrize(
    "case", props._FIXED_CASES, ids=[c[0] for c in props._FIXED_CASES]
)
def test_shim_parity_fixed_cases(case, dist_mesh, dist_mesh_shape):
    _check_graph(props._fixed_graph(*case), dist_mesh, dist_mesh_shape)


def test_shim_parity_fully_contracted(dist_mesh, dist_mesh_shape):
    n = 16
    rng = np.random.default_rng(9)
    u = np.arange(1, n)
    v = np.array([rng.integers(0, k) for k in range(1, n)])
    w = rng.integers(1, 4, n - 1).astype(np.float64)
    _check_graph(props.from_edges(u, v, w, n), dist_mesh, dist_mesh_shape)


def test_shim_parity_float_weights(dist_mesh, dist_mesh_shape):
    n, m = 24, 90
    rng = np.random.default_rng(11)
    g = props.from_edges(
        rng.integers(0, n, m), rng.integers(0, n, m), rng.random(m) + 0.25, n
    )
    _check_graph(g, dist_mesh, dist_mesh_shape)


def test_streaming_shim_warns_once_and_matches_plan():
    """StreamingMSF construction warns exactly once; the engine behind it
    is bit-identical to a stream plan fed the same batches."""
    from repro.stream import StreamEngine, StreamingMSF

    rng = np.random.default_rng(3)
    n, m, b = 64, 128, 32
    u = rng.integers(0, n, m)
    v = rng.integers(0, n, m)
    w = rng.integers(1, 6, m).astype(np.float64)

    shim = _one_warning(StreamingMSF, n, batch_capacity=b)
    assert isinstance(shim, StreamEngine)  # same engine, not a fork
    p = _silent(lambda: plan(n, SolveSpec(mode="stream", batch_capacity=b)))
    rep = None
    for k in range(0, m, b):
        sl = slice(k, k + b)
        shim.insert_batch(u[sl], v[sl], w[sl])
        rep = p.update(u[sl], v[sl], w[sl])
    assert shim.weight == rep.weight
    assert shim.version == rep.raw.version
    shim_gids = set(shim.forest_edges()[3].tolist())
    assert shim_gids == set(rep.msf_eids.tolist())


def test_msf_weight_shim_warns():
    from repro.core.msf import msf_weight

    g = props._fixed_graph(*props._FIXED_CASES[0])
    want = plan(g, SolveSpec()).solve().weight
    assert _one_warning(msf_weight, g) == want
