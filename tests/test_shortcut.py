"""Shortcutting strategies: all turn forests into stars; CSP == complete on
the same input; OS threshold behavior; sub-iteration counting."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import shortcut as sc


def _random_forest(n, seed):
    """Random parent forest (acyclic, roots self-loop)."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    p = np.zeros(n, np.int32)
    p[order[0]] = order[0]
    for i in range(1, n):
        # parent is some earlier vertex in the order (acyclic by construction)
        p[order[i]] = order[rng.integers(0, i)]
    return jnp.array(p)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_complete_shortcut_makes_stars(seed):
    p = _random_forest(200, seed)
    q = sc.complete_shortcut(p)
    assert bool(jnp.all(q == q[q]))
    # root of every vertex is preserved
    def root(p, i):
        i = int(i)
        while int(p[i]) != i:
            i = int(p[i])
        return i
    pn = np.asarray(p)
    qn = np.asarray(q)
    for i in range(0, 200, 17):
        assert qn[i] == root(pn, i)


@pytest.mark.parametrize("capacity", [4, 64, 1024])
def test_csp_equals_complete(capacity):
    """CSP (with its fallback) must produce exactly complete_shortcut's
    result, for any changed-set size vs capacity."""
    rng = np.random.default_rng(7)
    n = 300
    p_prev = jnp.arange(n, dtype=jnp.int32)  # all stars (identity forest)
    # hook a random subset of roots onto other roots, acyclically
    order = rng.permutation(n)
    p = np.arange(n, dtype=np.int32)
    for i in range(1, n // 2):
        p[order[i]] = order[rng.integers(0, i)]
    p = jnp.array(p)
    want = sc.complete_shortcut(p)
    got_csp = sc.csp_shortcut(p, p_prev, capacity)
    got_os = sc.optimized_shortcut(p, p_prev, capacity)
    np.testing.assert_array_equal(np.asarray(got_csp), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_os), np.asarray(want))


def test_subiteration_count_log_bound():
    # a path graph compressed by pointer doubling: ceil(log2(depth)) rounds
    n = 257
    p = jnp.array([max(0, i - 1) for i in range(n)], jnp.int32)
    q, k = sc.count_shortcut_subiters(p)
    assert bool(jnp.all(q == 0))
    assert int(k) <= int(np.ceil(np.log2(n))) + 1


def test_build_changed_overflow_flag():
    p_prev = jnp.arange(100, dtype=jnp.int32)
    p = jnp.where(jnp.arange(100) < 50, jnp.int32(99), jnp.arange(100, dtype=jnp.int32))
    ids, vals, count, overflow = sc.build_changed(p, p_prev, 16)
    assert int(count) == 50 - 1 + 1  # vertices 0..49 changed except 99? -> 50
    assert bool(overflow)
    ids2, vals2, count2, overflow2 = sc.build_changed(p, p_prev, 64)
    assert not bool(overflow2)
