"""MINWEIGHT monoid machinery: segment/axis argmin vs numpy, pack32,
binary-combine consistency (hypothesis property tests)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_stub import given, settings, st  # skips cleanly if absent

from repro.core.semiring import (
    EdgeMin,
    combine_edgemin,
    pack32,
    segment_argmin,
    unpack32,
)

IMAX = np.iinfo(np.int32).max


def _np_argmin(w, eid, pay, seg, n, valid):
    minw = np.full(n, np.inf, np.float32)
    mineid = np.full(n, IMAX, np.int64)
    minpay = np.full(n, IMAX, np.int64)
    for i in range(len(w)):
        if not valid[i]:
            continue
        s = seg[i]
        key = (w[i], eid[i])
        if (minw[s], mineid[s]) > key:
            minw[s], mineid[s], minpay[s] = w[i], eid[i], pay[i]
    return minw, mineid, minpay


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 20),
    e=st.integers(0, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_segment_argmin_matches_numpy(n, e, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(1, 50, e).astype(np.float32)  # ties likely
    eid = rng.permutation(e).astype(np.int32)  # distinct tie-break
    pay = rng.integers(0, 1000, e).astype(np.int32)
    seg = rng.integers(0, n, e).astype(np.int32)
    valid = rng.random(e) < 0.8
    got = segment_argmin(
        jnp.array(w), jnp.array(eid), (jnp.array(pay),), jnp.array(seg), n,
        valid=jnp.array(valid),
    )
    want = _np_argmin(w, eid, pay, seg, n, valid)
    np.testing.assert_array_equal(np.asarray(got.w), want[0])
    np.testing.assert_array_equal(np.asarray(got.eid), want[1].astype(np.int32))
    np.testing.assert_array_equal(np.asarray(got.payload[0]), want[2].astype(np.int32))


@settings(max_examples=30, deadline=None)
@given(
    w=st.integers(0, 255),
    idx=st.integers(0, (1 << 24) - 1),
)
def test_pack32_roundtrip_and_order(w, idx):
    k = pack32(jnp.uint32(w), jnp.uint32(idx))
    w2, i2 = unpack32(k)
    assert int(w2) == w and int(i2) == idx
    # order: packing is monotone in (w, idx) lex order
    k2 = pack32(jnp.uint32(min(w + 1, 255)), jnp.uint32(0))
    if w < 255:
        assert int(k) < int(k2)


def test_combine_edgemin_matches_joint_reduction():
    rng = np.random.default_rng(0)
    n = 16
    mk = lambda: EdgeMin(
        w=jnp.array(np.where(rng.random(n) < 0.3, np.inf, rng.integers(1, 9, n)).astype(np.float32)),
        eid=jnp.array(rng.permutation(1000)[:n].astype(np.int32)),
        payload=(jnp.array(rng.integers(0, 99, n).astype(np.int32)),),
    )
    a, b = mk(), mk()
    c = combine_edgemin(a, b)
    # elementwise: c must equal whichever of (a, b) has the lex-smaller key
    for i in range(n):
        ka = (float(a.w[i]), int(a.eid[i]))
        kb = (float(b.w[i]), int(b.eid[i]))
        kc = (float(c.w[i]), int(c.eid[i]))
        assert kc == min(ka, kb)
