"""Algorithm 1 correctness against the scipy MSF oracle, across variants,
shortcut strategies, and graph families — plus hypothesis property tests."""
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st  # skips cleanly if absent

from repro.core import msf
from repro.core.semiring import IMAX
from repro.graphs import grid_road_graph, random_graph, rmat_graph
from repro.graphs.generators import components_graph
from repro.graphs.structures import (
    from_edges,
    nx_free_msf_weight,
    nx_free_n_components,
)

GRAPHS = {
    "random": random_graph(200, 600, seed=1),
    "grid_road": grid_road_graph(12, 17, seed=2),
    "rmat": rmat_graph(8, 4, seed=3),
    "sparse_forest": random_graph(300, 150, seed=4),
    "components": components_graph(5, 40, seed=5),
}


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize(
    "variant,shortcut",
    [
        ("complete", "complete"),
        ("complete", "csp"),
        ("complete", "os"),
        ("paper", "complete"),
        ("pairwise", "complete"),
    ],
)
def test_msf_weight_matches_oracle(gname, variant, shortcut):
    g = GRAPHS[gname]
    r = msf(g, variant=variant, shortcut=shortcut, capacity=64)
    assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3


@pytest.mark.parametrize("gname", list(GRAPHS))
def test_msf_edges_form_spanning_forest(gname):
    """The tracked eids must form a forest with the oracle weight and the
    right component structure."""
    g = GRAPHS[gname]
    r = msf(g)
    n_f = int(r.n_msf_edges)
    eids = np.asarray(r.msf_eids)[:n_f]
    assert len(np.unique(eids)) == n_f, "duplicate MSF edges"
    # reconstruct edge weights/endpoints by eid (first direction)
    src, dst = np.asarray(g.src), np.asarray(g.dst)
    w, eid, valid = np.asarray(g.w), np.asarray(g.eid), np.asarray(g.valid)
    lookup = {}
    for s, d, ww, e, v in zip(src, dst, w, eid, valid):
        if v and e not in lookup:
            lookup[e] = (s, d, ww)
    total = sum(lookup[e][2] for e in eids)
    assert abs(total - nx_free_msf_weight(g)) < 1e-3
    # forest check: n_msf_edges == n - n_components over non-isolated graph
    ncc = nx_free_n_components(g)
    assert n_f == g.n - ncc
    # parent vector labels match component count
    roots = np.unique(np.asarray(r.parent))
    assert len(roots) == ncc


def test_iteration_bound():
    """AS converges in O(log n) iterations (complete-shortcut variant is
    log2-bounded, paper §IV-B)."""
    g = random_graph(512, 2048, seed=7)
    r = msf(g)
    assert int(r.iterations) <= 2 * int(np.log2(512)) + 2


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 60),
    m=st.integers(0, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_msf_property_random(n, m, seed):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m)
    v = rng.integers(0, n, m)
    w = rng.integers(1, 256, m).astype(np.float64)
    g = from_edges(u, v, w, n)
    for variant in ("complete", "paper"):
        r = msf(g, variant=variant)
        assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3


def test_warm_start_parent0():
    """Re-entrant msf: warm-starting from a converged labeling hooks
    nothing new; warm-starting from a partial forest reports only the
    delta weight."""
    g = random_graph(200, 600, seed=13)
    r = msf(g)
    # converged labels in: no new hooks out, same partition
    r2 = msf(g, parent0=r.parent)
    assert float(r2.weight) == 0.0
    assert int(r2.n_msf_edges) == 0
    assert np.array_equal(np.asarray(r2.parent), np.asarray(r.parent))
    # pre-merged vertex pairs: the delta weight only covers cross-pair
    # hooks, and the final partition still has the oracle component count
    import jax.numpy as jnp

    pairs = (np.arange(g.n, dtype=np.int32) // 2) * 2
    r3 = msf(g, parent0=jnp.asarray(pairs))
    assert float(r3.weight) <= nx_free_msf_weight(g)
    # free pair-merges can only coarsen the partition
    roots = np.unique(np.asarray(r3.parent))
    assert len(roots) <= nx_free_n_components(g)


def test_empty_and_singleton():
    g = from_edges(np.array([], np.int64), np.array([], np.int64),
                   np.array([], np.float64), 5)
    r = msf(g)
    assert float(r.weight) == 0.0
    assert int(r.n_msf_edges) == 0
