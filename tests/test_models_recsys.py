"""xDeepFM smoke + EmbeddingBag parity + retrieval correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import recsys as R
from repro.optim.adamw import adamw_init
from repro.train import steps as S


def _ids(cfg, b, seed=0):
    rng = np.random.default_rng(seed)
    offs, sizes = R.field_offsets(cfg)
    vals = rng.integers(0, 4, (b, cfg.n_sparse)) % sizes
    return jnp.array(offs[None, :] + vals, jnp.int32), rng


def test_smoke_train_step():
    cfg = registry.get_config("xdeepfm", smoke=True)
    params = R.init_xdeepfm(jax.random.key(0), cfg)
    ids, rng = _ids(cfg, 64)
    labels = jnp.array(rng.integers(0, 2, 64), jnp.float32)
    opt = adamw_init(params)
    p2, o2, metrics = jax.jit(lambda p, o, i, l: S.recsys_train_step(p, o, i, l, cfg))(
        params, opt, ids, labels
    )
    assert not bool(jnp.isnan(metrics["loss"]))
    logits = R.xdeepfm_logits(params, ids, cfg)
    assert logits.shape == (64,)
    assert not bool(jnp.isnan(logits).any())


def test_embedding_bag_multihot_matches_loop():
    rng = np.random.default_rng(1)
    table = jnp.array(rng.standard_normal((50, 6)), jnp.float32)
    flat_ids = jnp.array(rng.integers(0, 50, 30), jnp.int32)
    bag_ids = jnp.array(np.sort(rng.integers(0, 8, 30)), jnp.int32)
    got = R.embedding_bag_multihot(table, flat_ids, bag_ids, 8)
    want = np.zeros((8, 6), np.float32)
    for i, b in zip(np.asarray(flat_ids), np.asarray(bag_ids)):
        want[b] += np.asarray(table)[i]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_retrieval_topk_matches_numpy():
    cfg = registry.get_config("xdeepfm", smoke=True)
    params = R.init_retrieval(jax.random.key(0), cfg, n_candidates=500)
    ids, _ = _ids(cfg, 3)
    scores, idx = R.retrieval_topk(params, ids, cfg, k=10)
    emb = np.asarray(params["table"])[np.asarray(ids)].reshape(3, -1)
    u = emb @ np.asarray(params["tower_w"])
    full = u @ np.asarray(params["items"]).T
    for b in range(3):
        want = np.sort(full[b])[::-1][:10]
        np.testing.assert_allclose(np.sort(np.asarray(scores[b]))[::-1], want, rtol=1e-5)


def test_cin_interaction_order():
    """CIN layer-1 equals the explicit outer-product formulation."""
    cfg = registry.get_config("xdeepfm", smoke=True)
    params = R.init_xdeepfm(jax.random.key(0), cfg)
    ids, _ = _ids(cfg, 4)
    emb = R.embedding_bag(params["table"], ids)  # [B, F, D]
    b, f, d = emb.shape
    w = np.asarray(params["cin_w0"])  # [F, F, H]
    x0 = np.asarray(emb)
    # explicit: x1[b, h, d] = sum_{i,j} w[i,j,h] * x0[b,i,d] * x0[b,j,d]
    want = np.einsum("ijh,bid,bjd->bhd", w, x0, x0)
    z = jnp.einsum("bhd,bmd->bhmd", emb, emb)
    got = jnp.einsum("bhmd,hmn->bnd", z, params["cin_w0"])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)
