"""Property-based parity suite: every MSF engine agrees on every graph.

For hypothesis-drawn and fixed-seed random weighted graphs — including
multigraphs (duplicate pairs with distinct eids), duplicate weights,
isolated vertices, and fully-contracted inputs — assert that

- flat ``msf``,
- ``msf(coarsen=...)`` (host levels),
- ``msf(coarsen=..., fused=True)`` (one-jit device levels), and
- the distributed fused path (``msf_distributed(part, mesh, coarsen=...)``)

all return the same forest weight and the same global-eid edge set, and
that the chosen edges form a cycle-free spanning forest per component
(union-find acyclicity + exactly n − #components edges), with component
labelings that agree as partitions.

Imports hypothesis through ``tests._hypothesis_stub``: without hypothesis
the ``@given`` cases skip while the fixed-seed cases still run — CI keeps
covering every engine on every graph family either way.
"""
import numpy as np
import pytest

from _hypothesis_stub import given, settings, st
from repro.coarsen import CoarsenConfig
from repro.core.msf import msf
from repro.core.msf_dist import msf_distributed
from repro.graphs.partition import partition_edges_2d
from repro.graphs.structures import Graph, from_edges, nx_free_n_components

_CFG = CoarsenConfig(rounds_per_level=2, cutoff=4)


def _multigraph(u, v, w, n) -> Graph:
    """Symmetric ``Graph`` KEEPING duplicate undirected pairs (distinct
    eids) — the multigraph input ``from_edges`` would collapse; the level
    dedupe has to do it instead. Self-loops are dropped (no engine ever
    selects one: p[src] == p[dst] always)."""
    u = np.asarray(u, np.int64)
    v = np.asarray(v, np.int64)
    w = np.asarray(w, np.float64)
    keep = u != v
    lo = np.minimum(u, v)[keep].astype(np.int32)
    hi = np.maximum(u, v)[keep].astype(np.int32)
    w = w[keep].astype(np.float32)
    m = len(lo)
    eid = np.arange(m, dtype=np.int32)
    return Graph(
        src=np.concatenate([lo, hi]),
        dst=np.concatenate([hi, lo]),
        w=np.concatenate([w, w]),
        eid=np.concatenate([eid, eid]),
        valid=np.ones(2 * m, bool),
        n=int(n),
    )


def _eid_edges(g: Graph):
    """eid → (lo, hi, w) for every valid undirected edge."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w)
    eid = np.asarray(g.eid)
    sel = np.asarray(g.valid) & (src < dst)
    return {
        int(e): (int(s), int(d), float(ww))
        for s, d, ww, e in zip(src[sel], dst[sel], w[sel], eid[sel])
    }


def _eids(r):
    return set(np.asarray(r.msf_eids)[: int(r.n_msf_edges)].tolist())


def _same_partition(a, b):
    fwd, bwd = {}, {}
    for x, y in zip(np.asarray(a), np.asarray(b)):
        if fwd.setdefault(int(x), int(y)) != int(y):
            return False
        if bwd.setdefault(int(y), int(x)) != int(x):
            return False
    return True


def _assert_valid_forest(g: Graph, r, what: str):
    """Chosen eids form a cycle-free spanning forest of every component."""
    edges = _eid_edges(g)
    chosen = sorted(_eids(r))
    parent = list(range(g.n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    total = 0.0
    for e in chosen:
        assert e in edges, f"{what}: unknown eid {e}"
        lo, hi, w = edges[e]
        a, b = find(lo), find(hi)
        assert a != b, f"{what}: eid {e} closes a cycle"
        parent[a] = b
        total += w
    ncomp = nx_free_n_components(g)
    assert len(chosen) == g.n - ncomp, f"{what}: not spanning"
    assert abs(total - float(r.weight)) <= max(1e-3, 1e-6 * abs(total)), (
        f"{what}: weight does not match its own edge set"
    )
    uf_labels = [find(v) for v in range(g.n)]
    assert _same_partition(np.asarray(r.parent), np.asarray(uf_labels)), (
        f"{what}: parent labels disagree with the chosen forest"
    )


def _check_all_engines(g: Graph, dist_mesh, dist_mesh_shape):
    flat = msf(g)
    results = {"flat": flat}
    results["coarsen"] = msf(g, coarsen=_CFG)
    results["fused"] = msf(g, coarsen=_CFG, fused=True)
    rows, cols = dist_mesh_shape
    part = partition_edges_2d(g, rows, cols)
    cfg = CoarsenConfig(
        rounds_per_level=2, cutoff=4, fused=True, dedupe="device"
    )
    drv = msf_distributed(part, dist_mesh, coarsen=cfg)
    results["dist_fused"] = drv(
        part.src_row, part.dst_col, part.w, part.eid, part.valid
    )
    ref = _eids(flat)
    for what, r in results.items():
        assert abs(float(r.weight) - float(flat.weight)) <= max(
            1e-3, 1e-6 * abs(float(flat.weight))
        ), (what, float(r.weight), float(flat.weight))
        assert _eids(r) == ref, f"{what}: MSF edge set drifted"
        _assert_valid_forest(g, r, what)
        assert _same_partition(r.parent, flat.parent), what
    assert drv.last_stats.host_roundtrips == 0


# ---------------------------------------------------------------------------
# fixed seeds — always run, hypothesis or not (the stub only gates @given)
# ---------------------------------------------------------------------------

# (name, n, m, weight levels, multigraph, seed); n fixed per case keeps the
# jit cache keyed on a handful of shapes.
_FIXED_CASES = [
    ("dense_ties", 24, 96, 3, False, 0),
    ("multigraph", 24, 96, 4, True, 1),
    ("sparse_isolated", 32, 20, 8, False, 2),  # most vertices isolated
    ("duplicate_heavy_multi", 16, 80, 2, True, 3),
    ("single_edge", 16, 1, 1, False, 4),
    ("empty", 16, 0, 1, False, 5),
    ("two_cliques", 24, 60, 5, False, 6),
]


def _fixed_graph(name, n, m, wlevels, multi, seed) -> Graph:
    rng = np.random.default_rng(seed)
    if name == "two_cliques":  # two components, no cross edges
        half = n // 2
        u = rng.integers(0, half, m)
        v = rng.integers(0, half, m)
        flip = rng.random(m) < 0.5
        u = np.where(flip, u + half, u)
        v = np.where(flip, v + half, v)
    elif name == "sparse_isolated":
        u = rng.integers(0, n // 4, m)  # edges confined to a quarter
        v = rng.integers(0, n // 4, m)
    else:
        u = rng.integers(0, n, m)
        v = rng.integers(0, n, m)
    w = rng.integers(1, wlevels + 1, m).astype(np.float64)
    if multi:
        return _multigraph(u, v, w, n)
    return from_edges(u, v, w, n)


@pytest.mark.parametrize("case", _FIXED_CASES, ids=[c[0] for c in _FIXED_CASES])
def test_engines_agree_fixed_seed(case, dist_mesh, dist_mesh_shape):
    g = _fixed_graph(*case)
    _check_all_engines(g, dist_mesh, dist_mesh_shape)


def test_engines_agree_fully_contracted(dist_mesh, dist_mesh_shape):
    """A tree contracts completely — some level (or the residual rounds)
    sees zero surviving edges, and every engine must handle it."""
    n = 16
    rng = np.random.default_rng(9)
    u = np.arange(1, n)
    v = np.array([rng.integers(0, k) for k in range(1, n)])  # spanning tree
    w = rng.integers(1, 4, n - 1).astype(np.float64)
    g = from_edges(u, v, w, n)
    _check_all_engines(g, dist_mesh, dist_mesh_shape)


def test_engines_agree_float_weights(dist_mesh, dist_mesh_shape):
    """Non-integral weights disable pack32 everywhere — the 3-pass float
    MINWEIGHT reductions must agree across all four engines too."""
    n, m = 24, 90
    rng = np.random.default_rng(11)
    g = from_edges(
        rng.integers(0, n, m), rng.integers(0, n, m), rng.random(m) + 0.25, n
    )
    _check_all_engines(g, dist_mesh, dist_mesh_shape)


# ---------------------------------------------------------------------------
# hypothesis-drawn graphs (skip cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    n=st.sampled_from([16, 24, 32]),
    m=st.integers(min_value=0, max_value=96),
    wlevels=st.integers(min_value=1, max_value=5),
    multi=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_engines_agree_property(n, m, wlevels, multi, seed, dist_mesh, dist_mesh_shape):
    """Random weighted (multi)graphs, tie-heavy weights, arbitrary isolated
    vertices: all four engines return the same unique (w, eid)-order MSF."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m)
    v = rng.integers(0, n, m)
    w = rng.integers(1, wlevels + 1, m).astype(np.float64)
    g = _multigraph(u, v, w, n) if multi else from_edges(u, v, w, n)
    _check_all_engines(g, dist_mesh, dist_mesh_shape)


# ---------------------------------------------------------------------------
# dynamic-vs-recompute oracle: the stream engine under interleaved
# insert/delete/compact traffic equals flat_msf over the surviving multiset
# (weight, MSF gid set, and component partition) after EVERY published
# snapshot — exact deletions, DESIGN.md §6.4
# ---------------------------------------------------------------------------


class _SurvivorOracle:
    """Mirror of the engine's surviving edge multiset and gid assignment.

    The engine's rules, replayed exactly: ``prepare_batch`` dedupes to
    canonical (lo, hi) pairs in sorted-key order; a pair already known
    (forest or reservoir) keeps its gid and takes the minimum weight; a
    fresh pair gets the next sequential gid in batch order; a deleted
    pair leaves the multiset.
    """

    def __init__(self, n):
        from repro.stream import delta

        self._delta = delta
        self.n = n
        self.edges = {}  # (lo, hi) -> [w, gid]
        self.next_gid = 0

    def insert(self, u, v, w):
        pb = self._delta.prepare_batch(u, v, w, self.n)
        for i in range(pb.count):
            k = (int(pb.lo[i]), int(pb.hi[i]))
            if k in self.edges:
                self.edges[k][0] = min(self.edges[k][0], float(pb.w[i]))
            else:
                self.edges[k] = [float(pb.w[i]), self.next_gid]
                self.next_gid += 1

    def delete(self, u, v):
        zeros = np.zeros(np.atleast_1d(np.asarray(u)).shape[0])
        pb = self._delta.prepare_batch(u, v, zeros, self.n)
        for i in range(pb.count):
            self.edges.pop((int(pb.lo[i]), int(pb.hi[i])), None)

    def recompute(self):
        """(weight, MSF gid set, canonical partition) via flat msf over
        the surviving multiset, gid-ordered so weight ties break the same
        way the engine's union buffer does."""
        n = self.n
        if not self.edges:
            return 0.0, set(), np.arange(n)
        keys = list(self.edges)
        gid = np.array([self.edges[k][1] for k in keys], np.int32)
        order = np.argsort(gid, kind="stable")
        lo = np.array([k[0] for k in keys], np.int32)[order]
        hi = np.array([k[1] for k in keys], np.int32)[order]
        w = np.array([self.edges[k][0] for k in keys], np.float32)[order]
        gid = gid[order]
        m = len(lo)
        cap = 1
        while cap < m:
            cap *= 2
        L = np.zeros(cap, np.int32)
        H = np.zeros(cap, np.int32)
        W = np.full(cap, np.inf, np.float32)
        V = np.zeros(cap, bool)
        L[:m], H[:m], W[:m], V[:m] = lo, hi, w, True
        eid = np.arange(cap, dtype=np.int32)
        g = Graph(
            src=np.concatenate([L, H]),
            dst=np.concatenate([H, L]),
            w=np.concatenate([W, W]),
            eid=np.concatenate([eid, eid]),
            valid=np.concatenate([V, V]),
            n=n,
        )
        r = msf(g)
        sel = np.asarray(r.msf_eids)[: int(r.n_msf_edges)]
        p = np.asarray(r.parent)
        while True:  # canonicalize
            gp = p[p]
            if np.array_equal(gp, p):
                break
            p = gp
        return float(r.weight), set(gid[sel].tolist()), p


def _run_dynamic_trace(n, steps, seed, batch_capacity=32):
    """Interleave random insert / delete / compact ops; after every op the
    published snapshot must equal the recompute oracle."""
    from repro.stream.engine import StreamEngine

    rng = np.random.default_rng(seed)
    eng = StreamEngine(
        n,
        batch_capacity=batch_capacity,
        reservoir_capacity=8192,
        reservoir_per_component=8192,  # lossless retention: always healable
    )
    oracle = _SurvivorOracle(n)
    for step in range(steps):
        op = rng.random()
        if op < 0.5 or not oracle.edges:
            m = int(rng.integers(1, batch_capacity // 2))
            u, v = rng.integers(0, n, m), rng.integers(0, n, m)
            w = rng.integers(1, 50, m).astype(np.float64)
            oracle.insert(u, v, w)
            eng.insert_batch(u, v, w)
        elif op < 0.7:  # delete known pairs (forest and/or reservoir)
            ks = list(oracle.edges)
            pick = rng.choice(len(ks), size=min(5, len(ks)), replace=False)
            uu = np.array([ks[i][0] for i in pick])
            vv = np.array([ks[i][1] for i in pick])
            oracle.delete(uu, vv)
            d = eng.delete_batch(uu, vv)
            assert d.n_unhealed == 0, (step, d)
        elif op < 0.9:  # delete a mix of present and absent pairs
            m = int(rng.integers(1, 6))
            uu, vv = rng.integers(0, n, m), rng.integers(0, n, m)
            oracle.delete(uu, vv)
            eng.delete_batch(uu, vv)
        else:
            eng.compact()
        w_true, gids_true, p_true = oracle.recompute()
        snap = eng.snapshots.acquire()
        assert snap.stale == (eng.unhealed > 0), step
        assert not snap.stale, step  # lossless reservoir: always exact
        assert abs(snap.weight - w_true) <= max(1e-3, 1e-6 * abs(w_true)), (
            step, snap.weight, w_true,
        )
        gids_eng = set(int(g) for g in eng.forest_gids())
        assert gids_eng == gids_true, (
            step, sorted(gids_eng - gids_true), sorted(gids_true - gids_eng),
        )
        assert _same_partition(snap.parent, p_true), step


@pytest.mark.parametrize("n,steps,seed", [(32, 40, 0), (48, 40, 1), (16, 50, 2)])
def test_stream_dynamic_matches_recompute_fixed_seed(n, steps, seed):
    _run_dynamic_trace(n, steps, seed)


@settings(max_examples=5, deadline=None)
@given(
    n=st.sampled_from([16, 24, 40]),
    steps=st.integers(min_value=10, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_stream_dynamic_matches_recompute_property(n, steps, seed):
    """Property: under arbitrary interleaved insert/delete/compact traces
    with lossless retention, every published snapshot IS the MSF of the
    surviving edge multiset — weight, gid set, and partition."""
    _run_dynamic_trace(n, steps, seed)


def test_stream_bounded_reservoir_stale_only_when_unhealed():
    """With a tiny reservoir the engine may lose replacements — but it
    must KNOW: snapshots are stale exactly when unhealed deletions exist,
    and recertify() from the oracle's multiset restores exactness."""
    from repro.stream.engine import StreamEngine

    n, seed = 24, 5
    rng = np.random.default_rng(seed)
    eng = StreamEngine(
        n, batch_capacity=16, reservoir_capacity=2, reservoir_per_component=1
    )
    oracle = _SurvivorOracle(n)
    for _ in range(25):
        if rng.random() < 0.6 or not oracle.edges:
            m = int(rng.integers(1, 8))
            u, v = rng.integers(0, n, m), rng.integers(0, n, m)
            w = rng.integers(1, 20, m).astype(np.float64)
            oracle.insert(u, v, w)
            eng.insert_batch(u, v, w)
        else:
            ks = list(oracle.edges)
            pick = rng.choice(len(ks), size=min(3, len(ks)), replace=False)
            uu = np.array([ks[i][0] for i in pick])
            vv = np.array([ks[i][1] for i in pick])
            oracle.delete(uu, vv)
            eng.delete_batch(uu, vv)
        snap = eng.snapshots.acquire()
        assert snap.stale == (eng.unhealed > 0)
        assert snap.n_unhealed == eng.unhealed
        if not snap.stale:
            # certified snapshots are still exact in weight
            w_true, _, _ = oracle.recompute()
            assert abs(snap.weight - w_true) <= max(1e-3, 1e-6 * abs(w_true))
    # recovery: recertify from the surviving multiset
    keys = list(oracle.edges)
    eng.recertify(
        np.array([k[0] for k in keys]),
        np.array([k[1] for k in keys]),
        np.array([oracle.edges[k][0] for k in keys]),
    )
    snap = eng.snapshots.acquire()
    assert not snap.stale and eng.unhealed == 0
    w_true, _, p_true = oracle.recompute()
    assert abs(snap.weight - w_true) <= max(1e-3, 1e-6 * abs(w_true))
    assert _same_partition(snap.parent, p_true)
