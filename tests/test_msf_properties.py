"""Property-based parity suite: every MSF engine agrees on every graph.

For hypothesis-drawn and fixed-seed random weighted graphs — including
multigraphs (duplicate pairs with distinct eids), duplicate weights,
isolated vertices, and fully-contracted inputs — assert that

- flat ``msf``,
- ``msf(coarsen=...)`` (host levels),
- ``msf(coarsen=..., fused=True)`` (one-jit device levels), and
- the distributed fused path (``msf_distributed(part, mesh, coarsen=...)``)

all return the same forest weight and the same global-eid edge set, and
that the chosen edges form a cycle-free spanning forest per component
(union-find acyclicity + exactly n − #components edges), with component
labelings that agree as partitions.

Imports hypothesis through ``tests._hypothesis_stub``: without hypothesis
the ``@given`` cases skip while the fixed-seed cases still run — CI keeps
covering every engine on every graph family either way.
"""
import numpy as np
import pytest

from _hypothesis_stub import given, settings, st
from repro.coarsen import CoarsenConfig
from repro.core.msf import msf
from repro.core.msf_dist import msf_distributed
from repro.graphs.partition import partition_edges_2d
from repro.graphs.structures import Graph, from_edges, nx_free_n_components

_CFG = CoarsenConfig(rounds_per_level=2, cutoff=4)


def _multigraph(u, v, w, n) -> Graph:
    """Symmetric ``Graph`` KEEPING duplicate undirected pairs (distinct
    eids) — the multigraph input ``from_edges`` would collapse; the level
    dedupe has to do it instead. Self-loops are dropped (no engine ever
    selects one: p[src] == p[dst] always)."""
    u = np.asarray(u, np.int64)
    v = np.asarray(v, np.int64)
    w = np.asarray(w, np.float64)
    keep = u != v
    lo = np.minimum(u, v)[keep].astype(np.int32)
    hi = np.maximum(u, v)[keep].astype(np.int32)
    w = w[keep].astype(np.float32)
    m = len(lo)
    eid = np.arange(m, dtype=np.int32)
    return Graph(
        src=np.concatenate([lo, hi]),
        dst=np.concatenate([hi, lo]),
        w=np.concatenate([w, w]),
        eid=np.concatenate([eid, eid]),
        valid=np.ones(2 * m, bool),
        n=int(n),
    )


def _eid_edges(g: Graph):
    """eid → (lo, hi, w) for every valid undirected edge."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w)
    eid = np.asarray(g.eid)
    sel = np.asarray(g.valid) & (src < dst)
    return {
        int(e): (int(s), int(d), float(ww))
        for s, d, ww, e in zip(src[sel], dst[sel], w[sel], eid[sel])
    }


def _eids(r):
    return set(np.asarray(r.msf_eids)[: int(r.n_msf_edges)].tolist())


def _same_partition(a, b):
    fwd, bwd = {}, {}
    for x, y in zip(np.asarray(a), np.asarray(b)):
        if fwd.setdefault(int(x), int(y)) != int(y):
            return False
        if bwd.setdefault(int(y), int(x)) != int(x):
            return False
    return True


def _assert_valid_forest(g: Graph, r, what: str):
    """Chosen eids form a cycle-free spanning forest of every component."""
    edges = _eid_edges(g)
    chosen = sorted(_eids(r))
    parent = list(range(g.n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    total = 0.0
    for e in chosen:
        assert e in edges, f"{what}: unknown eid {e}"
        lo, hi, w = edges[e]
        a, b = find(lo), find(hi)
        assert a != b, f"{what}: eid {e} closes a cycle"
        parent[a] = b
        total += w
    ncomp = nx_free_n_components(g)
    assert len(chosen) == g.n - ncomp, f"{what}: not spanning"
    assert abs(total - float(r.weight)) <= max(1e-3, 1e-6 * abs(total)), (
        f"{what}: weight does not match its own edge set"
    )
    uf_labels = [find(v) for v in range(g.n)]
    assert _same_partition(np.asarray(r.parent), np.asarray(uf_labels)), (
        f"{what}: parent labels disagree with the chosen forest"
    )


def _check_all_engines(g: Graph, dist_mesh, dist_mesh_shape):
    flat = msf(g)
    results = {"flat": flat}
    results["coarsen"] = msf(g, coarsen=_CFG)
    results["fused"] = msf(g, coarsen=_CFG, fused=True)
    rows, cols = dist_mesh_shape
    part = partition_edges_2d(g, rows, cols)
    cfg = CoarsenConfig(
        rounds_per_level=2, cutoff=4, fused=True, dedupe="device"
    )
    drv = msf_distributed(part, dist_mesh, coarsen=cfg)
    results["dist_fused"] = drv(
        part.src_row, part.dst_col, part.w, part.eid, part.valid
    )
    ref = _eids(flat)
    for what, r in results.items():
        assert abs(float(r.weight) - float(flat.weight)) <= max(
            1e-3, 1e-6 * abs(float(flat.weight))
        ), (what, float(r.weight), float(flat.weight))
        assert _eids(r) == ref, f"{what}: MSF edge set drifted"
        _assert_valid_forest(g, r, what)
        assert _same_partition(r.parent, flat.parent), what
    assert drv.last_stats.host_roundtrips == 0


# ---------------------------------------------------------------------------
# fixed seeds — always run, hypothesis or not (the stub only gates @given)
# ---------------------------------------------------------------------------

# (name, n, m, weight levels, multigraph, seed); n fixed per case keeps the
# jit cache keyed on a handful of shapes.
_FIXED_CASES = [
    ("dense_ties", 24, 96, 3, False, 0),
    ("multigraph", 24, 96, 4, True, 1),
    ("sparse_isolated", 32, 20, 8, False, 2),  # most vertices isolated
    ("duplicate_heavy_multi", 16, 80, 2, True, 3),
    ("single_edge", 16, 1, 1, False, 4),
    ("empty", 16, 0, 1, False, 5),
    ("two_cliques", 24, 60, 5, False, 6),
]


def _fixed_graph(name, n, m, wlevels, multi, seed) -> Graph:
    rng = np.random.default_rng(seed)
    if name == "two_cliques":  # two components, no cross edges
        half = n // 2
        u = rng.integers(0, half, m)
        v = rng.integers(0, half, m)
        flip = rng.random(m) < 0.5
        u = np.where(flip, u + half, u)
        v = np.where(flip, v + half, v)
    elif name == "sparse_isolated":
        u = rng.integers(0, n // 4, m)  # edges confined to a quarter
        v = rng.integers(0, n // 4, m)
    else:
        u = rng.integers(0, n, m)
        v = rng.integers(0, n, m)
    w = rng.integers(1, wlevels + 1, m).astype(np.float64)
    if multi:
        return _multigraph(u, v, w, n)
    return from_edges(u, v, w, n)


@pytest.mark.parametrize("case", _FIXED_CASES, ids=[c[0] for c in _FIXED_CASES])
def test_engines_agree_fixed_seed(case, dist_mesh, dist_mesh_shape):
    g = _fixed_graph(*case)
    _check_all_engines(g, dist_mesh, dist_mesh_shape)


def test_engines_agree_fully_contracted(dist_mesh, dist_mesh_shape):
    """A tree contracts completely — some level (or the residual rounds)
    sees zero surviving edges, and every engine must handle it."""
    n = 16
    rng = np.random.default_rng(9)
    u = np.arange(1, n)
    v = np.array([rng.integers(0, k) for k in range(1, n)])  # spanning tree
    w = rng.integers(1, 4, n - 1).astype(np.float64)
    g = from_edges(u, v, w, n)
    _check_all_engines(g, dist_mesh, dist_mesh_shape)


def test_engines_agree_float_weights(dist_mesh, dist_mesh_shape):
    """Non-integral weights disable pack32 everywhere — the 3-pass float
    MINWEIGHT reductions must agree across all four engines too."""
    n, m = 24, 90
    rng = np.random.default_rng(11)
    g = from_edges(
        rng.integers(0, n, m), rng.integers(0, n, m), rng.random(m) + 0.25, n
    )
    _check_all_engines(g, dist_mesh, dist_mesh_shape)


# ---------------------------------------------------------------------------
# hypothesis-drawn graphs (skip cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    n=st.sampled_from([16, 24, 32]),
    m=st.integers(min_value=0, max_value=96),
    wlevels=st.integers(min_value=1, max_value=5),
    multi=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_engines_agree_property(n, m, wlevels, multi, seed, dist_mesh, dist_mesh_shape):
    """Random weighted (multi)graphs, tie-heavy weights, arbitrary isolated
    vertices: all four engines return the same unique (w, eid)-order MSF."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m)
    v = rng.integers(0, n, m)
    w = rng.integers(1, wlevels + 1, m).astype(np.float64)
    g = _multigraph(u, v, w, n) if multi else from_edges(u, v, w, n)
    _check_all_engines(g, dist_mesh, dist_mesh_shape)
