"""Distributed MSF engine: 1-device mesh parity + real 8-device subprocess
runs of the paper's Fig-2 schedule (all shortcut strategies), plus the
distributed fused coarsening levels (``msf_distributed(coarsen=...)``)."""
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.coarsen import CoarsenConfig
from repro.core.msf import msf
from repro.core.msf_dist import msf_distributed
from repro.graphs import grid_road_graph, random_graph
from repro.graphs.partition import block_global_ids, partition_edges_2d
from repro.graphs.structures import nx_free_msf_weight


@pytest.mark.parametrize("shortcut", ["csp", "baseline", "os"])
def test_distributed_mesh(dist_mesh, dist_mesh_shape, shortcut):
    """1×1 degenerate on a single device; the CI multidevice job forces 8
    host devices so the same test runs the real 2×4 collective schedule."""
    rows, cols = dist_mesh_shape
    g = random_graph(150, 500, seed=3)
    part = partition_edges_2d(g, rows, cols)
    drv = msf_distributed(part, dist_mesh, shortcut=shortcut, capacity=64)
    r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
    assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3


@pytest.mark.parametrize("shortcut", ["os", "csp"])
@pytest.mark.parametrize("capacity", [1, 2, 8])
def test_os_policy_overflow_fallback(dist_mesh, dist_mesh_shape, shortcut, capacity):
    """CSP-overflow fallback (core/msf_dist.py OS policy): with a tiny
    prefetch capacity the first iterations hook far more roots than the
    changed-map holds, so ``lax.cond`` must take the baseline-shortcut
    branch mid-run (later iterations hook few and flip back to CSP) —
    and the result must still match the oracle."""
    rows, cols = dist_mesh_shape
    g = random_graph(200, 700, seed=11)
    part = partition_edges_2d(g, rows, cols)
    drv = msf_distributed(part, dist_mesh, shortcut=shortcut, capacity=capacity)
    r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
    # a connected-ish random graph hooks >> capacity roots in iteration 1,
    # guaranteeing the overflow branch ran at least once
    assert int(r.n_msf_edges) > capacity
    assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3


def test_os_policy_overflow_fallback_high_diameter(dist_mesh, dist_mesh_shape):
    """Grid graphs drive many shortcut sub-iterations — the worst case for
    the baseline fallback loop; exercised with capacity below the first
    hook wave."""
    rows, cols = dist_mesh_shape
    g = grid_road_graph(14, 15, seed=4)
    part = partition_edges_2d(g, rows, cols)
    drv = msf_distributed(part, dist_mesh, shortcut="os", capacity=2)
    r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
    assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3


# ---------------------------------------------------------------------------
# distributed fused coarsening levels (repro.coarsen.dist, DESIGN.md §8)
# ---------------------------------------------------------------------------


def _eids(r):
    return set(np.asarray(r.msf_eids)[: int(r.n_msf_edges)].tolist())


def test_block_global_ids_inverts_partition(dist_mesh_shape):
    """Global-id recovery from the 2D block offsets reproduces the valid
    edge multiset exactly — the level-0 re-keying of the fused path."""
    rows, cols = dist_mesh_shape
    g = random_graph(150, 500, seed=3)
    part = partition_edges_2d(g, rows, cols)
    sg, dg = block_global_ids(part.src_row, part.dst_col, part.shard_size)
    got = sorted(zip(sg[part.valid].tolist(), dg[part.valid].tolist()))
    valid = np.asarray(g.valid)
    want = sorted(
        zip(np.asarray(g.src)[valid].tolist(), np.asarray(g.dst)[valid].tolist())
    )
    assert got == want


@pytest.mark.parametrize("dedupe", ["device", "host"])
def test_distributed_fused_coarsen_parity(dist_mesh, dist_mesh_shape, dedupe):
    """Acceptance: the in-mesh fused levels return the identical MSF (weight,
    global-eid edge set, canonical parent labels) as the host fused engine
    and the flat solver — with zero per-level host round-trips on the
    device-dedupe path, L on the explicit host fallback."""
    rows, cols = dist_mesh_shape
    g = random_graph(300, 1000, seed=29)
    part = partition_edges_2d(g, rows, cols)
    cfg = CoarsenConfig(rounds_per_level=2, cutoff=16, fused=True, dedupe=dedupe)
    drv = msf_distributed(part, dist_mesh, coarsen=cfg)
    r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
    flat = msf(g)
    host = msf(g, coarsen=CoarsenConfig(rounds_per_level=2, cutoff=16), fused=True)
    assert _eids(r) == _eids(flat) == _eids(host)
    assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3
    np.testing.assert_array_equal(np.asarray(r.parent), np.asarray(host.parent))
    st = drv.last_stats
    assert len(st.levels) >= 1  # contraction actually ran in-mesh
    expected = 0 if dedupe == "device" else len(st.levels)
    assert st.host_roundtrips == expected
    assert int(r.iterations) == 2 * len(st.levels) + st.residual_iters


def test_distributed_fused_float_path(dist_mesh, dist_mesh_shape):
    """Non-integral weights force the 3-pass float MINWEIGHT combine across
    the mesh (no pack32) — same MSF as the flat solver."""
    from repro.graphs.structures import from_edges

    rows, cols = dist_mesh_shape
    rng = np.random.default_rng(41)
    n, m = 220, 700
    g = from_edges(
        rng.integers(0, n, m), rng.integers(0, n, m), rng.random(m) + 0.5, n
    )
    part = partition_edges_2d(g, rows, cols)
    cfg = CoarsenConfig(rounds_per_level=2, cutoff=16, fused=True, dedupe="device")
    drv = msf_distributed(part, dist_mesh, coarsen=cfg)
    r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
    flat = msf(g)
    assert _eids(r) == _eids(flat)
    assert abs(float(r.weight) - float(flat.weight)) < 1e-3


def test_distributed_fused_below_cutoff_residual_only(dist_mesh, dist_mesh_shape):
    """n ≤ cutoff: zero levels — the in-mesh residual rounds solve the whole
    graph (the globally-keyed hook loop alone must be exact)."""
    rows, cols = dist_mesh_shape
    g = grid_road_graph(10, 12, seed=7)
    part = partition_edges_2d(g, rows, cols)
    drv = msf_distributed(
        part, dist_mesh, coarsen=CoarsenConfig(cutoff=4096, fused=True)
    )
    r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
    assert len(drv.last_stats.levels) == 0
    assert _eids(r) == _eids(msf(g))
    assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3


_SUBPROCESS = r"""
import jax
from repro.core.msf_dist import msf_distributed
from repro.graphs import grid_road_graph, random_graph
from repro.graphs.partition import partition_edges_2d
from repro.graphs.structures import nx_free_msf_weight

assert jax.device_count() == 8, jax.device_count()
import numpy as np
from repro.coarsen import CoarsenConfig
from repro.compat import make_mesh
from repro.core.msf import msf
mesh = make_mesh((2, 4), ("data", "model"))
for g in [random_graph(500, 1500, seed=1), grid_road_graph(20, 25, seed=2)]:
    part = partition_edges_2d(g, 2, 4)
    for sc in ["csp", "baseline", "os"]:
        drv = msf_distributed(part, mesh, shortcut=sc, capacity=4096)
        r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
        assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3, (sc, float(r.weight))
    # distributed fused coarsening levels on the real 2x4 collective schedule
    flat = msf(g)
    eids = set(np.asarray(flat.msf_eids)[: int(flat.n_msf_edges)].tolist())
    for dedupe in ["device", "host"]:
        cfg = CoarsenConfig(rounds_per_level=2, cutoff=16, fused=True, dedupe=dedupe)
        drv = msf_distributed(part, mesh, coarsen=cfg)
        r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
        assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3, (dedupe, float(r.weight))
        got = set(np.asarray(r.msf_eids)[: int(r.n_msf_edges)].tolist())
        assert got == eids, (dedupe, "eid set drift")
        st = drv.last_stats
        assert st.host_roundtrips == (0 if dedupe == "device" else len(st.levels))
print("MSF_DIST_8DEV_OK")
"""


def test_distributed_8_devices():
    import os

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS],
        capture_output=True, text=True, env=env, timeout=420, cwd=".",
    )
    assert "MSF_DIST_8DEV_OK" in out.stdout, out.stdout + out.stderr
