"""Distributed MSF engine: 1-device mesh parity + real 8-device subprocess
runs of the paper's Fig-2 schedule (all shortcut strategies)."""
import subprocess
import sys

import jax
import pytest

from repro.core.msf_dist import msf_distributed
from repro.graphs import grid_road_graph, random_graph
from repro.graphs.partition import partition_edges_2d
from repro.graphs.structures import nx_free_msf_weight


@pytest.mark.parametrize("shortcut", ["csp", "baseline", "os"])
def test_distributed_mesh(dist_mesh, dist_mesh_shape, shortcut):
    """1×1 degenerate on a single device; the CI multidevice job forces 8
    host devices so the same test runs the real 2×4 collective schedule."""
    rows, cols = dist_mesh_shape
    g = random_graph(150, 500, seed=3)
    part = partition_edges_2d(g, rows, cols)
    drv = msf_distributed(part, dist_mesh, shortcut=shortcut, capacity=64)
    r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
    assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3


@pytest.mark.parametrize("shortcut", ["os", "csp"])
@pytest.mark.parametrize("capacity", [1, 2, 8])
def test_os_policy_overflow_fallback(dist_mesh, dist_mesh_shape, shortcut, capacity):
    """CSP-overflow fallback (core/msf_dist.py OS policy): with a tiny
    prefetch capacity the first iterations hook far more roots than the
    changed-map holds, so ``lax.cond`` must take the baseline-shortcut
    branch mid-run (later iterations hook few and flip back to CSP) —
    and the result must still match the oracle."""
    rows, cols = dist_mesh_shape
    g = random_graph(200, 700, seed=11)
    part = partition_edges_2d(g, rows, cols)
    drv = msf_distributed(part, dist_mesh, shortcut=shortcut, capacity=capacity)
    r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
    # a connected-ish random graph hooks >> capacity roots in iteration 1,
    # guaranteeing the overflow branch ran at least once
    assert int(r.n_msf_edges) > capacity
    assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3


def test_os_policy_overflow_fallback_high_diameter(dist_mesh, dist_mesh_shape):
    """Grid graphs drive many shortcut sub-iterations — the worst case for
    the baseline fallback loop; exercised with capacity below the first
    hook wave."""
    rows, cols = dist_mesh_shape
    g = grid_road_graph(14, 15, seed=4)
    part = partition_edges_2d(g, rows, cols)
    drv = msf_distributed(part, dist_mesh, shortcut="os", capacity=2)
    r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
    assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3


_SUBPROCESS = r"""
import jax
from repro.core.msf_dist import msf_distributed
from repro.graphs import grid_road_graph, random_graph
from repro.graphs.partition import partition_edges_2d
from repro.graphs.structures import nx_free_msf_weight

assert jax.device_count() == 8, jax.device_count()
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
for g in [random_graph(500, 1500, seed=1), grid_road_graph(20, 25, seed=2)]:
    part = partition_edges_2d(g, 2, 4)
    for sc in ["csp", "baseline", "os"]:
        drv = msf_distributed(part, mesh, shortcut=sc, capacity=4096)
        r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
        assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3, (sc, float(r.weight))
print("MSF_DIST_8DEV_OK")
"""


def test_distributed_8_devices():
    import os

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS],
        capture_output=True, text=True, env=env, timeout=420, cwd=".",
    )
    assert "MSF_DIST_8DEV_OK" in out.stdout, out.stdout + out.stderr
