"""Distributed MSF engine: 1-device mesh parity + real 8-device subprocess
runs of the paper's Fig-2 schedule (all shortcut strategies)."""
import subprocess
import sys

import jax
import pytest

from repro.core.msf_dist import msf_distributed
from repro.graphs import grid_road_graph, random_graph
from repro.graphs.partition import partition_edges_2d
from repro.graphs.structures import nx_free_msf_weight


@pytest.mark.parametrize("shortcut", ["csp", "baseline", "os"])
def test_distributed_single_device(host_mesh, shortcut):
    g = random_graph(150, 500, seed=3)
    part = partition_edges_2d(g, 1, 1)
    drv = msf_distributed(part, host_mesh, shortcut=shortcut, capacity=64)
    r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
    assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3


_SUBPROCESS = r"""
import jax
from repro.core.msf_dist import msf_distributed
from repro.graphs import grid_road_graph, random_graph
from repro.graphs.partition import partition_edges_2d
from repro.graphs.structures import nx_free_msf_weight

assert jax.device_count() == 8, jax.device_count()
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
for g in [random_graph(500, 1500, seed=1), grid_road_graph(20, 25, seed=2)]:
    part = partition_edges_2d(g, 2, 4)
    for sc in ["csp", "baseline", "os"]:
        drv = msf_distributed(part, mesh, shortcut=sc, capacity=4096)
        r = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
        assert abs(float(r.weight) - nx_free_msf_weight(g)) < 1e-3, (sc, float(r.weight))
print("MSF_DIST_8DEV_OK")
"""


def test_distributed_8_devices():
    import os

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS],
        capture_output=True, text=True, env=env, timeout=420, cwd=".",
    )
    assert "MSF_DIST_8DEV_OK" in out.stdout, out.stdout + out.stderr
