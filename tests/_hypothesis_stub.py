"""Graceful degradation when ``hypothesis`` is not installed.

Test modules import ``given``/``settings``/``st`` from here instead of
from hypothesis directly. With hypothesis present this is a pure
re-export; without it, ``@given`` replaces the property test with a
zero-arg test that calls ``pytest.skip`` — so the suite *degrades*
(property tests skip, example-based tests still run) instead of erroring
at collection time. Equivalent in spirit to ``pytest.importorskip``, but
scoped to the property tests rather than skipping whole modules.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any ``st.<name>(...)`` chain; values are never drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: self

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed")

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco
