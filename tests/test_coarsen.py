"""Coarsening subsystem: contract/relabel/filter units, end-to-end parity
with the flat solver (weight, MSF edge set in global eids, partition),
pack32/Pallas dedupe backends, the msf(coarsen=) dispatcher, and the
Partition2D-aware distributed pre-contraction hook (DESIGN.md §7)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.coarsen import (
    CoarsenConfig,
    CoarsenMSF,
    coarsen_msf,
    contract_level,
    filter_level,
    merge_distributed,
    precontract_partition,
    rank_relabel,
)
from repro.coarsen.filter import filter_level_host
from repro.core.msf import msf
from repro.graphs import grid_road_graph, random_graph, rmat_graph
from repro.graphs.generators import components_graph
from repro.graphs.structures import (
    from_edges,
    nx_free_msf_weight,
    nx_free_n_components,
)

GRAPHS = {
    "random": random_graph(300, 900, seed=1),
    "grid_road": grid_road_graph(18, 20, seed=2),
    "rmat": rmat_graph(9, 4, seed=3),
    "components": components_graph(6, 50, seed=5),
}


def _eids(r):
    return set(np.asarray(r.msf_eids)[: int(r.n_msf_edges)].tolist())


def _same_partition(a, b):
    fwd, bwd = {}, {}
    for x, y in zip(np.asarray(a), np.asarray(b)):
        if fwd.setdefault(int(x), int(y)) != int(y):
            return False
        if bwd.setdefault(int(y), int(x)) != int(x):
            return False
    return True


# ---------------------------------------------------------------------------
# units
# ---------------------------------------------------------------------------


def test_rank_relabel_dense_prefix_sum():
    p = jnp.array([0, 0, 2, 2, 4, 4, 4, 7], jnp.int32)  # roots 0, 2, 4, 7
    new_ids, n_next = rank_relabel(p)
    assert int(n_next) == 4
    np.testing.assert_array_equal(
        np.asarray(new_ids), [0, 0, 1, 1, 2, 2, 2, 3]
    )


def test_filter_drops_self_loops_and_keeps_min_parallel():
    # two supervertices {0,1} and {2,3}; three cross edges, one internal
    lo = jnp.array([0, 0, 1, 0], jnp.int32)
    hi = jnp.array([2, 3, 2, 1], jnp.int32)
    w = jnp.array([5.0, 3.0, 9.0, 1.0], jnp.float32)
    eid = jnp.array([10, 11, 12, 13], jnp.int32)
    valid = jnp.ones(4, bool)
    new_ids = jnp.array([0, 0, 1, 1], jnp.int32)
    fr = filter_level(lo, hi, w, eid, valid, new_ids, n=4)
    m = int(fr.m_new)
    assert m == 1  # one unique supervertex pair survives
    assert bool(fr.valid[0]) and int(fr.eid[0]) == 11  # min-weight rep, eid kept
    assert float(fr.w[0]) == 3.0
    # host twin agrees
    l2, h2, w2, e2 = filter_level_host(lo, hi, w, eid, valid, new_ids, 4)
    assert len(l2) == 1 and e2[0] == 11 and w2[0] == 3.0


@pytest.mark.parametrize("pack", [False, True])
def test_filter_equal_weight_ties_break_on_eid_not_position(pack):
    """Regression: equal-weight parallel edges whose array order disagrees
    with eid order must still dedupe to the smaller *eid* (the (w, eid)
    total order) — position-based tie-breaks diverge from flat msf once
    filter output order stops tracking eid order (level ≥ 2)."""
    lo = jnp.array([0, 0], jnp.int32)
    hi = jnp.array([2, 3], jnp.int32)
    w = jnp.array([7.0, 7.0], jnp.float32)
    eid = jnp.array([20, 10], jnp.int32)  # larger eid first in the array
    valid = jnp.ones(2, bool)
    new_ids = jnp.array([0, 0, 1, 1], jnp.int32)
    fr = filter_level(lo, hi, w, eid, valid, new_ids, n=4, pack=pack)
    assert int(fr.m_new) == 1 and int(fr.eid[0]) == 10
    _, _, _, e2 = filter_level_host(lo, hi, w, eid, valid, new_ids, 4)
    assert e2[0] == 10


@pytest.mark.parametrize("pack", [False, True])
def test_filter_device_host_parity(pack):
    rng = np.random.default_rng(7)
    n, m = 64, 256
    lo = rng.integers(0, n, m).astype(np.int32)
    hi = rng.integers(0, n, m).astype(np.int32)
    # few weight levels + shuffled eids: the dedupe must break the many
    # resulting ties on eid, not on array position
    w = rng.integers(1, 8, m).astype(np.float32)
    eid = rng.permutation(m).astype(np.int32)
    valid = rng.random(m) < 0.9
    new_ids = rng.integers(0, 16, n).astype(np.int32)
    fr = filter_level(lo, hi, w, eid, valid, new_ids, n=n, pack=pack)
    m_dev = int(fr.m_new)
    dev = sorted(
        zip(
            np.asarray(fr.lo)[:m_dev].tolist(),
            np.asarray(fr.hi)[:m_dev].tolist(),
            np.asarray(fr.eid)[:m_dev].tolist(),
        )
    )
    l2, h2, _, e2 = filter_level_host(lo, hi, w, eid, valid, new_ids, n)
    host = sorted(zip(l2.tolist(), h2.tolist(), e2.tolist()))
    assert dev == host


def test_contract_level_rounds_shrink():
    g = random_graph(256, 1024, seed=11)
    res1 = contract_level(
        g.src, g.dst, g.w, g.eid, g.valid, n=g.n, rounds=1
    )
    res2 = contract_level(
        g.src, g.dst, g.w, g.eid, g.valid, n=g.n, rounds=2
    )
    # each round at least halves every component with outgoing edges
    assert int(res1.n_next) <= g.n // 2 + 1
    assert int(res2.n_next) <= int(res1.n_next)
    # hooked edges are real MSF edges: subset of the flat solver's picks
    flat = _eids(msf(g))
    assert _eids(res2).issubset(flat)


def test_config_validation():
    with pytest.raises(ValueError):
        CoarsenConfig(rounds_per_level=0)
    with pytest.raises(ValueError):
        CoarsenConfig(cutoff=0)
    with pytest.raises(ValueError):
        CoarsenConfig(dedupe="gpu")


# ---------------------------------------------------------------------------
# end-to-end parity with the flat solver
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("dedupe", ["host", "device"])
def test_coarsen_matches_flat(gname, dedupe):
    """Acceptance: same weight AND same MSF edge set (global eids) as the
    flat solver, under the distinct (w, eid) total order."""
    g = GRAPHS[gname]
    flat = msf(g)
    cfg = CoarsenConfig(rounds_per_level=2, cutoff=16, dedupe=dedupe)
    co = coarsen_msf(g, config=cfg)
    assert _eids(co) == _eids(flat)
    assert int(co.n_msf_edges) == int(flat.n_msf_edges)
    assert abs(float(co.weight) - nx_free_msf_weight(g)) < 1e-3
    assert _same_partition(co.parent, flat.parent)
    # coarsen parent labels are canonical original-vertex representatives
    roots = np.unique(np.asarray(co.parent))
    assert len(roots) == nx_free_n_components(g)
    assert all(np.asarray(co.parent)[r] == r for r in roots)


def test_multiple_levels_run_and_shrink():
    g = rmat_graph(10, 4, seed=13)
    eng = CoarsenMSF(CoarsenConfig(rounds_per_level=1, cutoff=8, max_levels=8))
    r = eng(g)
    st = eng.last_stats
    assert len(st.levels) >= 2
    ns = [l.n for l in st.levels] + [st.residual_n]
    assert all(a > b for a, b in zip(ns, ns[1:]))  # strict vertex shrink
    assert _eids(r) == _eids(msf(g))


@pytest.mark.parametrize(
    "pack,segmin",
    [(True, None), (True, "jnp"), (True, "pallas"), (False, None)],
)
def test_pack_and_segmin_backends(pack, segmin):
    g = random_graph(200, 700, seed=17)
    cfg = CoarsenConfig(cutoff=16, pack=pack, segmin=segmin, dedupe="device")
    co = coarsen_msf(g, config=cfg)
    assert _eids(co) == _eids(msf(g))


def test_large_n_lexsort_key_path():
    """n > 2^16 leaves the packed uint32 pair-key regime: the device
    filter must take the lexsort branch (int64 keys need x64) and still
    agree with the host twin and the flat solver."""
    n = (1 << 16) + 512
    rng = np.random.default_rng(37)
    m = 3000
    g = from_edges(
        rng.integers(0, n, m), rng.integers(0, n, m),
        rng.integers(1, 256, m).astype(np.float64), n,
    )
    flat = msf(g)
    for dd in ("device", "host"):
        co = coarsen_msf(g, config=CoarsenConfig(cutoff=1024, dedupe=dd))
        assert _eids(co) == _eids(flat)


def test_msf_coarsen_dispatcher():
    g = random_graph(150, 500, seed=19)
    r1 = msf(g, coarsen=True)
    r2 = msf(g, coarsen=CoarsenConfig(cutoff=8))
    assert _eids(r1) == _eids(r2) == _eids(msf(g))
    with pytest.raises(ValueError):
        msf(g, coarsen=True, parent0=jnp.zeros(g.n, jnp.int32))


def test_empty_and_edgeless():
    g = from_edges(
        np.array([], np.int64), np.array([], np.int64),
        np.array([], np.float64), 40,
    )
    r = coarsen_msf(g, config=CoarsenConfig(cutoff=4))
    assert float(r.weight) == 0.0 and int(r.n_msf_edges) == 0
    np.testing.assert_array_equal(np.asarray(r.parent), np.arange(40))


def test_weight_below_cutoff_is_flat():
    """n ≤ cutoff: zero levels, pure flat solve, identical result."""
    g = random_graph(100, 300, seed=23)
    eng = CoarsenMSF(CoarsenConfig(cutoff=1024))
    r = eng(g)
    assert len(eng.last_stats.levels) == 0
    assert _eids(r) == _eids(msf(g))


# ---------------------------------------------------------------------------
# fused one-jit level pipeline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("dedupe", ["host", "device"])
def test_fused_matches_flat(gname, dedupe):
    """Acceptance: the fused level pipeline returns the identical MSF edge
    set (global eids, (w, eid) total order) as the flat solver, for both
    the in-jit device dedupe and the zero-copy host-callback dedupe."""
    g = GRAPHS[gname]
    flat = msf(g)
    cfg = CoarsenConfig(rounds_per_level=2, cutoff=16, dedupe=dedupe)
    co = msf(g, coarsen=cfg, fused=True)
    assert _eids(co) == _eids(flat)
    assert int(co.n_msf_edges) == int(flat.n_msf_edges)
    assert abs(float(co.weight) - nx_free_msf_weight(g)) < 1e-3
    assert _same_partition(co.parent, flat.parent)


@pytest.mark.parametrize("segmin", [None, "jnp", "pallas", "sorted"])
def test_fused_pack_segmin_backends(segmin):
    """Every packed segment-min backend — including the sorted-segment
    Pallas kernel the dedupe step now supports — through the fused path."""
    g = random_graph(200, 700, seed=17)
    cfg = CoarsenConfig(
        cutoff=16, pack=True, segmin=segmin, dedupe="device", fused=True
    )
    co = coarsen_msf(g, config=cfg)
    assert _eids(co) == _eids(msf(g))


def test_fused_one_executable_per_level_shape():
    """Acceptance: re-running graphs whose level shapes were already seen
    must not grow the fused_level jit cache — exactly one compiled
    executable per (n, edge-capacity, n0) level shape."""
    from repro.coarsen.engine import fused_level

    cfg = CoarsenConfig(rounds_per_level=2, cutoff=16, fused=True)
    eng = CoarsenMSF(cfg)
    g1 = random_graph(300, 900, seed=1)
    r1 = eng(g1)
    warm = fused_level._cache_size()
    assert warm >= len(eng.last_stats.levels) >= 1
    r1b = eng(g1)  # identical graph: every level shape already compiled
    g2 = random_graph(300, 900, seed=77)  # same shapes, different topology
    eng(g2)
    assert fused_level._cache_size() == warm
    assert _eids(r1) == _eids(r1b)


def test_fused_multiple_levels_device_resident_bookkeeping():
    g = rmat_graph(10, 4, seed=13)
    eng = CoarsenMSF(
        CoarsenConfig(rounds_per_level=1, cutoff=8, max_levels=8, fused=True)
    )
    r = eng(g)
    st = eng.last_stats
    assert len(st.levels) >= 2
    ns = [l.n for l in st.levels] + [st.residual_n]
    assert all(a > b for a, b in zip(ns, ns[1:]))  # strict vertex shrink
    ms = [l.m for l in st.levels] + [st.residual_m]
    assert all(a >= b for a, b in zip(ms, ms[1:]))  # filter never grows m
    assert _eids(r) == _eids(msf(g))


def test_fused_large_n_lexsort_key_path():
    """n > 2^16 through the fused device dedupe (two-key variadic sort)."""
    n = (1 << 16) + 512
    rng = np.random.default_rng(37)
    m = 3000
    g = from_edges(
        rng.integers(0, n, m), rng.integers(0, n, m),
        rng.integers(1, 256, m).astype(np.float64), n,
    )
    flat = msf(g)
    for dd in ("device", "host"):
        cfg = CoarsenConfig(cutoff=1024, dedupe=dd, fused=True)
        assert _eids(coarsen_msf(g, config=cfg)) == _eids(flat)


def test_msf_fused_dispatcher_validation():
    g = random_graph(150, 500, seed=19)
    r = msf(g, coarsen=CoarsenConfig(cutoff=8), fused=True)
    assert _eids(r) == _eids(msf(g))
    with pytest.raises(ValueError):
        msf(g, fused=True)  # fused requires coarsen=
    with pytest.raises(ValueError):
        msf(g, pack=True, segmin="sorted")  # dedupe-only backend


def test_filter_level_empty_input():
    """Regression (this PR): a fully contracted level hands the filter a
    zero-length edge array; it must return an empty residual instead of
    building boundary flags against a zero-length sort."""
    from repro.coarsen.filter import filter_level_callback

    z = jnp.zeros((0,), jnp.int32)
    zw = jnp.zeros((0,), jnp.float32)
    zb = jnp.zeros((0,), bool)
    new_ids = jnp.zeros((4,), jnp.int32)
    for fn in (filter_level, filter_level_callback):
        fr = fn(z, z, zw, z, zb, new_ids, n=4)
        assert int(fr.m_new) == 0
        assert fr.lo.shape == (0,) and fr.valid.shape == (0,)


def test_contract_level_und_matches_directed():
    """The undirected two-direction contraction must be bit-identical to
    the concatenated directed form (same hooks, eids, weight, relabel)."""
    from repro.coarsen.contract import contract_level_und

    g = random_graph(256, 1024, seed=11)
    # build canonical undirected arrays from the symmetric graph
    src, dst = np.asarray(g.src), np.asarray(g.dst)
    w, eid, valid = np.asarray(g.w), np.asarray(g.eid), np.asarray(g.valid)
    sel = valid & (src < dst)
    lo, hi, wu, eu = src[sel], dst[sel], w[sel], eid[sel]
    vu = np.ones(len(lo), bool)
    for pack in (False, True):
        und = contract_level_und(
            lo, hi, wu, eu, vu,
            n=g.n, eid_capacity=1024, rounds=2, pack=pack,
        )
        cat = contract_level(
            np.concatenate([lo, hi]), np.concatenate([hi, lo]),
            np.concatenate([wu, wu]), np.concatenate([eu, eu]),
            np.concatenate([vu, vu]), n=g.n, rounds=2, pack=pack,
        )
        np.testing.assert_array_equal(np.asarray(und.parent), np.asarray(cat.parent))
        np.testing.assert_array_equal(np.asarray(und.new_ids), np.asarray(cat.new_ids))
        assert int(und.n_next) == int(cat.n_next)
        assert float(und.weight) == float(cat.weight)
        assert _eids(und) == _eids(cat)


# ---------------------------------------------------------------------------
# distributed pre-contraction hook
# ---------------------------------------------------------------------------


def test_merge_distributed_iterations_bookkeeping(dist_mesh, dist_mesh_shape):
    """Regression (this PR): ``merge_distributed`` hard-coded one round per
    level, so ``MSFResult.iterations`` under-reported whenever
    rounds_per_level > 1. The real count now rides on
    ``CoarsenPrelude.level_iters``."""
    from repro.core.msf_dist import msf_distributed

    rows, cols = dist_mesh_shape
    g = random_graph(300, 1000, seed=29)
    cfg = CoarsenConfig(rounds_per_level=2, cutoff=16)
    part, prelude = precontract_partition(g, rows, cols, config=cfg)
    n_levels = len(prelude.stats.levels)
    assert n_levels >= 1
    assert prelude.level_iters == 2 * n_levels
    drv = msf_distributed(part, dist_mesh, shortcut="csp", capacity=512)
    dist = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
    merged = merge_distributed(prelude, dist)
    assert int(merged.iterations) == 2 * n_levels + int(dist.iterations)
    # the host engine reports the same arithmetic for the same config
    eng = CoarsenMSF(cfg)
    eng(g)
    assert len(eng.last_stats.levels) == n_levels


def test_precontract_partition_merge(dist_mesh, dist_mesh_shape):
    from repro.core.msf_dist import msf_distributed

    rows, cols = dist_mesh_shape
    g = random_graph(300, 1000, seed=29)
    part, prelude = precontract_partition(
        g, rows, cols, config=CoarsenConfig(rounds_per_level=2, cutoff=16)
    )
    assert part.n_pad >= prelude.stats.residual_n
    assert len(prelude.stats.levels) >= 1  # contraction actually ran
    drv = msf_distributed(part, dist_mesh, shortcut="csp", capacity=512)
    dist = drv(part.src_row, part.dst_col, part.w, part.eid, part.valid)
    merged = merge_distributed(prelude, dist)
    flat = msf(g)
    assert _eids(merged) == _eids(flat)
    assert abs(float(merged.weight) - nx_free_msf_weight(g)) < 1e-3
    assert _same_partition(merged.parent, flat.parent)
