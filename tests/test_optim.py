"""Optimizer + gradient compression convergence properties."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import adamw_init, adamw_update, cosine_lr, global_norm
from repro.optim.compress import compress_with_error_feedback, init_error_state


def _quadratic_problem(seed=0, d=20):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((d, d))
    h = a @ a.T / d + np.eye(d)
    x_star = rng.standard_normal(d)

    def loss(x):
        r = x - jnp.array(x_star)
        return 0.5 * r @ jnp.array(h) @ r

    return loss, x_star


def test_adamw_converges_on_quadratic():
    loss, x_star = _quadratic_problem()
    params = {"x": jnp.zeros(20)}
    opt = adamw_init(params)
    for _ in range(400):
        g = jax.grad(lambda p: loss(p["x"]))(params)
        params, opt, _ = adamw_update(g, opt, params, jnp.float32(0.05), weight_decay=0.0)
    assert float(loss(params["x"])) < 1e-2


def test_compressed_grads_converge_with_error_feedback():
    loss, x_star = _quadratic_problem(seed=1)
    for compress in (False, True):
        params = {"x": jnp.zeros(20)}
        opt = adamw_init(params)
        err = init_error_state(params)
        for _ in range(400):
            g = jax.grad(lambda p: loss(p["x"]))(params)
            if compress:
                g, err = compress_with_error_feedback(g, err)
            params, opt, _ = adamw_update(g, opt, params, jnp.float32(0.05), weight_decay=0.0)
        final = float(loss(params["x"]))
        assert final < 2e-2, (compress, final)


def test_int8_quantization_error_bounded():
    rng = np.random.default_rng(0)
    g = {"a": jnp.array(rng.standard_normal(1000), jnp.float32)}
    err = init_error_state(g)
    deq, err2 = compress_with_error_feedback(g, err)
    scale = float(jnp.max(jnp.abs(g["a"]))) / 127
    assert float(jnp.max(jnp.abs(deq["a"] - g["a"]))) <= scale * 0.5 + 1e-6
    # error feedback: residual equals the quantization error exactly
    np.testing.assert_allclose(
        np.asarray(err2["a"]), np.asarray(g["a"] - deq["a"]), atol=1e-6
    )


def test_grad_clip_applied():
    params = {"x": jnp.zeros(4)}
    opt = adamw_init(params)
    g = {"x": jnp.full(4, 1e6, jnp.float32)}
    p2, opt2, gnorm = adamw_update(g, opt, params, jnp.float32(0.1), clip_norm=1.0,
                                   weight_decay=0.0)
    assert float(gnorm) > 1e5  # reported pre-clip norm
    # post-clip update magnitude is bounded by lr * O(1)
    assert float(jnp.max(jnp.abs(p2["x"]))) < 0.2


def test_cosine_lr_schedule():
    lr0 = cosine_lr(jnp.int32(0), peak=1.0, warmup=10, total=100)
    lr_peak = cosine_lr(jnp.int32(10), peak=1.0, warmup=10, total=100)
    lr_end = cosine_lr(jnp.int32(100), peak=1.0, warmup=10, total=100)
    assert float(lr0) == 0.0
    assert abs(float(lr_peak) - 1.0) < 1e-5
    assert float(lr_end) < 0.11
