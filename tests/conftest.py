import jax
import pytest


@pytest.fixture(scope="session")
def host_mesh():
    # 1×1 mesh: smoke tests see the single CPU device (the 512-device
    # override belongs ONLY to the dry-run, per its module header).
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
