import jax
import pytest

from repro.compat import make_mesh


@pytest.fixture(scope="session")
def host_mesh():
    # 1×1 mesh: smoke tests see the single CPU device (the 512-device
    # override belongs ONLY to the dry-run, per its module header).
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def dist_mesh_shape():
    """(rows, cols) for the distributed-engine tests: the largest 2D grid
    the available devices support. Single-device runs degrade to 1×1; the
    CI multidevice job forces 8 host devices so the shard_map collectives
    actually execute across a 2×4 grid."""
    n = jax.device_count()
    if n >= 8:
        return (2, 4)
    if n >= 4:
        return (2, 2)
    if n >= 2:
        return (1, 2)
    return (1, 1)


@pytest.fixture(scope="session")
def dist_mesh(dist_mesh_shape):
    return make_mesh(dist_mesh_shape, ("data", "model"))
