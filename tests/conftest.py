import pytest

from repro.compat import make_mesh


@pytest.fixture(scope="session")
def host_mesh():
    # 1×1 mesh: smoke tests see the single CPU device (the 512-device
    # override belongs ONLY to the dry-run, per its module header).
    return make_mesh((1, 1), ("data", "model"))
