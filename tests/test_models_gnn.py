"""Per-GNN-arch smoke tests + equivariance property tests for NequIP and
permutation/isolation invariants of the message-passing substrate."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import gnn as G
from repro.models.o3 import _random_rotation, clebsch_gordan, tp_paths, wigner_d_np
from repro.train import steps as S

GNN_ARCHS = [a for a in registry.arch_ids() if registry.family_of(a) == "gnn"]


def _graph(n=40, e=160, seed=0):
    rng = np.random.default_rng(seed)
    return (
        jnp.array(rng.integers(0, n, e), jnp.int32),
        jnp.array(rng.integers(0, n, e), jnp.int32),
        jnp.array(rng.random(e) < 0.9),
        rng,
    )


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_smoke_train_step(arch):
    cfg = registry.get_config(arch, smoke=True)
    n, e = 40, 160
    src, dst, ev, rng = _graph(n, e, seed=1)
    key = jax.random.key(0)
    if cfg.kind == "nequip":
        params = G.init_nequip(key, cfg)
        batch = dict(
            species=jnp.array(rng.integers(0, 4, n), jnp.int32),
            pos=jnp.array(rng.standard_normal((n, 3)), jnp.float32),
            src=src, dst=dst, edge_valid=ev,
            graph_ids=jnp.zeros(n, jnp.int32),
            energy=jnp.zeros(1, jnp.float32),
        )
    else:
        x = jnp.array(rng.standard_normal((n, cfg.d_in)), jnp.float32)
        batch = dict(x=x, src=src, dst=dst, edge_valid=ev,
                     node_mask=jnp.ones(n, jnp.float32))
        if cfg.kind == "gat":
            params = G.init_gat(key, cfg)
            batch["labels"] = jnp.array(rng.integers(0, cfg.n_classes, n), jnp.int32)
        elif cfg.kind == "gatedgcn":
            params = G.init_gatedgcn(key, cfg)
            batch["e_feat"] = jnp.ones((e, 1), jnp.float32)
            batch["labels"] = jnp.array(rng.integers(0, cfg.n_classes, n), jnp.int32)
        else:
            params = G.init_meshgraphnet(key, cfg)
            batch["e_feat"] = jnp.array(rng.standard_normal((e, 4)), jnp.float32)
            batch["targets"] = jnp.array(rng.standard_normal((n, cfg.d_out)), jnp.float32)

    from repro.optim.adamw import adamw_init

    opt = adamw_init(params)
    p2, o2, metrics = jax.jit(lambda p, o, b: S.gnn_train_step(p, o, b, cfg, 1))(params, opt, batch)
    assert not bool(jnp.isnan(metrics["loss"]))
    out = S.gnn_apply(params, batch, cfg, 1)
    assert not bool(jnp.isnan(out).any())
    if cfg.kind == "gat":
        assert out.shape == (n, cfg.n_classes)
    elif cfg.kind == "meshgraphnet":
        assert out.shape == (n, cfg.d_out)


def test_nequip_energy_invariance_force_equivariance():
    cfg = registry.get_config("nequip", smoke=True)
    rng = np.random.default_rng(3)
    n = 16
    species = jnp.array(rng.integers(0, 4, n), jnp.int32)
    pos = jnp.array(rng.standard_normal((n, 3)) * 2, jnp.float32)
    src = jnp.array(rng.integers(0, n, 48), jnp.int32)
    dst = jnp.array(rng.integers(0, n, 48), jnp.int32)
    ev = src != dst
    gid = jnp.zeros(n, jnp.int32)
    params = G.init_nequip(jax.random.key(0), cfg)

    def energy(p):
        return G.apply_nequip(params, species, p, src, dst, ev, gid, 1, cfg)[0]

    r = jnp.array(_random_rotation(np.random.default_rng(9)), jnp.float32)
    e1, e2 = energy(pos), energy(pos @ r.T)
    assert abs(float(e1 - e2)) < 1e-4 * max(1.0, abs(float(e1)))
    f1 = jax.grad(energy)(pos)
    f2 = jax.grad(energy)(pos @ r.T)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f1 @ r.T), atol=1e-4)
    # translation invariance
    e3 = energy(pos + jnp.array([1.0, -2.0, 0.5]))
    assert abs(float(e1 - e3)) < 1e-4 * max(1.0, abs(float(e1)))


def test_cg_all_paths_equivariant():
    rng = np.random.default_rng(5)
    for (l1, l2, l3) in tp_paths(2):
        c = clebsch_gordan(l1, l2, l3)
        r = _random_rotation(rng)
        d1, d2, d3 = wigner_d_np(r, l1), wigner_d_np(r, l2), wigner_d_np(r, l3)
        x = rng.standard_normal(2 * l1 + 1)
        y = rng.standard_normal(2 * l2 + 1)
        lhs = np.einsum("pqr,q,r->p", c, d1 @ x, d2 @ y)
        rhs = d3 @ np.einsum("pqr,q,r->p", c, x, y)
        np.testing.assert_allclose(lhs, rhs, atol=1e-10)


def test_message_passing_ignores_invalid_edges():
    """Padded edges must not affect any GNN output (static-shape invariant
    the whole dry-run relies on)."""
    cfg = registry.get_config("gatedgcn", smoke=True)
    params = G.init_gatedgcn(jax.random.key(0), cfg)
    n = 30
    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((n, cfg.d_in)), jnp.float32)
    src = jnp.array(rng.integers(0, n, 100), jnp.int32)
    dst = jnp.array(rng.integers(0, n, 100), jnp.int32)
    ef = jnp.ones((100, 1), jnp.float32)
    ev = jnp.array(rng.random(100) < 0.5)
    out1 = G.apply_gatedgcn(params, x, ef, src, dst, ev, cfg)
    # scramble the invalid edges' endpoints — output must be identical
    src2 = jnp.where(ev, src, (src + 7) % n)
    dst2 = jnp.where(ev, dst, (dst + 3) % n)
    out2 = G.apply_gatedgcn(params, x, ef, src2, dst2, ev, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)
