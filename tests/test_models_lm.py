"""Per-LM-arch smoke tests (reduced same-family configs): one train step on
CPU asserting shapes + no NaNs, prefill/decode parity, loss-path parity."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import transformer as T

LM_ARCHS = [a for a in registry.arch_ids() if registry.family_of(a) == "lm"]


def _data(cfg, b=2, s=32):
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab)
    return toks, labels


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_train_step(arch, host_mesh):
    cfg = registry.get_config(arch, smoke=True)
    params = T.init_lm(jax.random.key(0), cfg)
    toks, labels = _data(cfg)
    loss, grads = jax.jit(
        lambda p, t, l: jax.value_and_grad(T.lm_loss)(p, t, l, cfg, host_mesh)
    )(params, toks, labels)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    flat = jax.tree.leaves(grads)
    assert all(not bool(jnp.isnan(g).any()) for g in flat)
    # shapes preserved through the optimizer
    from repro.optim.adamw import adamw_init, adamw_update

    opt = adamw_init(params)
    p2, opt2, gnorm = adamw_update(grads, opt, params, jnp.float32(1e-3))
    assert jax.tree.structure(p2) == jax.tree.structure(params)
    assert not bool(jnp.isnan(gnorm))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_decode_parity(arch, host_mesh):
    """Last-token logits from a full prefill == decode of the last token on
    a cache prefilled with the S-1 prefix."""
    cfg = registry.get_config(arch, smoke=True)
    params = T.init_lm(jax.random.key(0), cfg)
    toks, _ = _data(cfg, b=2, s=32)
    prefill = jax.jit(lambda p, t: T.lm_prefill(p, t, cfg, host_mesh))
    decode = jax.jit(lambda p, tok, c, pos: T.lm_decode_step(p, tok, c, pos, cfg, host_mesh))
    logits_full, _ = prefill(params, toks)
    _, cache = prefill(params, toks[:, :-1])
    want_t = cfg.sliding_window or 32
    t_have = cache["k"].shape[2]
    if t_have < min(want_t, 32):
        pad = min(want_t, 32) - t_have
        cache = {k: jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))) for k, v in cache.items()}
    logits_dec, _ = decode(params, toks[:, -1], cache, jnp.int32(31))
    err = float(jnp.max(jnp.abs(logits_full - logits_dec)))
    assert err < 3e-2, err  # bf16 path noise


def test_vocab_chunked_loss_parity(host_mesh):
    cfg = registry.get_config("qwen2-7b", smoke=True)
    params = T.init_lm(jax.random.key(0), cfg)
    toks, labels = _data(cfg)
    base = T.lm_loss(params, toks, labels, cfg, host_mesh)
    cfgc = dataclasses.replace(cfg, vocab_chunk=128)
    chunked = T.lm_loss(params, toks, labels, cfgc, host_mesh)
    assert abs(float(base) - float(chunked)) < 1e-4


def test_triangle_skip_parity(host_mesh):
    cfg = registry.get_config("command-r-35b", smoke=True)
    params = T.init_lm(jax.random.key(0), cfg)
    toks, labels = _data(cfg, s=64)
    x1 = T.lm_forward(params, toks, cfg, host_mesh, triangle_skip=False)
    x2 = T.lm_forward(params, toks, cfg, host_mesh, triangle_skip=True)
    assert float(jnp.max(jnp.abs(x1.astype(jnp.float32) - x2.astype(jnp.float32)))) < 1e-2


def test_param_count_matches_init():
    """Analytic param_count (used for 6ND roofline) == actual init size."""
    for arch in LM_ARCHS:
        cfg = registry.get_config(arch, smoke=True)
        params = jax.eval_shape(lambda k: T.init_lm(k, cfg), jax.random.key(0))
        total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(total - analytic) / total < 0.02, (arch, total, analytic)


import numpy as np  # noqa: E402
