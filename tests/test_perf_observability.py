"""Tier-1 coverage of the continuous-performance-observability stack
(DESIGN.md §11): the structured ``Measurement`` bench schema, the
append-only history store, the regression sentinel's decision rule
(including the acceptance gate — an injected synthetic 2x slowdown must
fail), analytic ``SolveReport.cost`` on flat/fused plans with obs off,
the MicroBatcher admission metrics, and an open-loop loadgen smoke run
with a concurrently mutating graph."""
from __future__ import annotations

import copy
import json
import sys
from pathlib import Path

import numpy as np
import pytest

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))  # benchmarks/ namespace package


def _sentinel():
    sys.path.insert(0, str(_ROOT / "tools"))
    try:
        import check_bench_regression as m
    finally:
        sys.path.pop(0)
    return m


def _doc(medians: dict, *, iqr=0.0, backend="cpu", devices=1, unit="us"):
    from benchmarks.common import Measurement, document

    rows = [
        Measurement(name=k, median=v, iqr=iqr, min=v, max=v, iters=3,
                    unit=unit)
        for k, v in medians.items()
    ]
    doc = document(rows)
    doc["env"]["backend"] = backend
    doc["env"]["device_count"] = devices
    doc["backend"], doc["device_count"] = backend, devices
    return doc


# ---------------------------------------------------------------------------
# Measurement / bench-rows/v2 schema
# ---------------------------------------------------------------------------


def test_measurement_from_samples_stats_and_csv_compat():
    from benchmarks.common import from_samples

    m = from_samples("t", [1e-3, 2e-3, 3e-3, 4e-3], warmup=2,
                     derived="k=v")
    assert m.unit == "us" and m.iters == 4 and m.warmup == 2
    assert m.median == pytest.approx(2500.0)
    assert m.min == pytest.approx(1000.0) and m.max == pytest.approx(4000.0)
    assert m.iqr == pytest.approx(1500.0)  # q75(3250) - q25(1750)
    # printed row stays v1-CSV shaped: name,us,derived
    assert str(m) == "t,2500.0,k=v"
    # ``per`` divides each sample (per-call reporting)
    assert from_samples("t", [2e-3], per=2).median == pytest.approx(1000.0)
    with pytest.raises(ValueError):
        from_samples("t", [])


def test_document_schema_and_write_json(tmp_path):
    from benchmarks.common import SCHEMA, point, write_json

    rows = [point("speedup", 3.5, "x", derived="a=b")]
    p = tmp_path / "BENCH_x.json"
    write_json(str(p), rows)
    doc = json.loads(p.read_text())
    assert doc["schema"] == SCHEMA == "bench-rows/v2"
    for key in ("jax", "backend", "device_count", "python", "machine"):
        assert key in doc["env"]
    (r,) = doc["rows"]
    assert r["name"] == "speedup" and r["unit"] == "x"
    assert r["median"] == 3.5 and "metrics" not in r  # obs off -> dropped
    # names with commas survive (the v1 CSV schema corrupted them)
    from benchmarks.common import Measurement, document

    d2 = document([Measurement(name="a,b", median=1.0)])
    assert d2["rows"][0]["name"] == "a,b"


def test_measurement_carries_obs_snapshot_when_metrics_on():
    from benchmarks.common import point
    from repro import obs

    obs.enable("metrics")
    try:
        obs.metrics_reset()
        obs.counter("x.y").inc(3)
        m = point("p", 1.0, "count")
        assert m.metrics is not None
        assert m.metrics["counters"]["x.y"] == 3
        assert m.as_dict()["metrics"]["counters"]["x.y"] == 3
    finally:
        obs.disable()
        obs.reset()
        obs.metrics_reset()


# ---------------------------------------------------------------------------
# history store
# ---------------------------------------------------------------------------


def test_history_append_and_load_streams_by_env(tmp_path):
    from benchmarks import history

    d_cpu = _doc({"a": 100.0})
    d_tpu = _doc({"a": 5.0}, backend="tpu", devices=8)
    p1 = history.append(str(tmp_path), "suite one", d_cpu, timestamp=1.0)
    history.append(str(tmp_path), "suite one", d_cpu, timestamp=2.0)
    p2 = history.append(str(tmp_path), "suite one", d_tpu, timestamp=3.0)
    assert p1 != p2  # different env -> different stream by construction
    assert Path(p1).name == "suite_one__cpu__1.jsonl"
    got = history.load(str(tmp_path), "suite one", "cpu", 1)
    assert [d["ts"] for d in got] == [1.0, 2.0]
    assert got[0]["suite"] == "suite_one" or got[0]["suite"] == "suite one"
    assert history.load(str(tmp_path), "absent", "cpu", 1) == []


# ---------------------------------------------------------------------------
# regression sentinel: decision rule
# ---------------------------------------------------------------------------


def test_sentinel_passes_unchanged_and_fails_2x_slowdown():
    m = _sentinel()
    base = _doc({"solve": 1000.0, "stream": 50.0}, iqr=100.0)
    status, _ = m.check_doc(base, copy.deepcopy(base))
    assert status == "ok"
    slow = _doc({"solve": 2100.0, "stream": 50.0}, iqr=100.0)
    status, msgs = m.check_doc(base, slow)
    assert status == "regression"
    assert any("REGRESSION solve" in s for s in msgs)
    assert any("ok       stream" in s for s in msgs)


def test_sentinel_needs_both_gates():
    m = _sentinel()
    base = _doc({"b": 100.0}, iqr=30.0)
    # +40%: inside tolerance (50%) -> ok even though outside IQR
    assert m.check_doc(base, _doc({"b": 140.0}))[0] == "ok"
    # +60%: outside tolerance AND outside median+iqr=130 -> regression
    assert m.check_doc(base, _doc({"b": 160.0}))[0] == "regression"
    # +60% but baseline IQR 80 covers it (160 <= 180) -> noise, ok
    wide = _doc({"b": 100.0}, iqr=80.0)
    assert m.check_doc(wide, _doc({"b": 160.0}))[0] == "ok"
    # tighter tolerance flips the +40% case
    assert m.check_doc(base, _doc({"b": 140.0}), tolerance=0.1)[0] == (
        "regression"
    )


def test_sentinel_skips_env_mismatch_and_ignores_non_time_rows():
    m = _sentinel()
    base = _doc({"b": 100.0})
    status, msgs = m.check_doc(base, _doc({"b": 500.0}, devices=8))
    assert status == "env-skip" and "env mismatch" in msgs[0]
    status, _ = m.check_doc(base, _doc({"b": 500.0}, backend="tpu"))
    assert status == "env-skip"
    # speedup rows are provenance, not gates — a 10x change passes
    s_base = _doc({"speedup": 8.0}, unit="x")
    assert m.check_doc(s_base, _doc({"speedup": 0.8}, unit="x"))[0] == "ok"


def test_sentinel_reports_new_and_gone_rows_without_failing():
    m = _sentinel()
    status, msgs = m.check_doc(_doc({"a": 1.0}), _doc({"b": 2.0}))
    assert status == "ok"
    assert any("new-row" in s and "b" in s for s in msgs)
    assert any("gone-row" in s and "a" in s for s in msgs)


# ---------------------------------------------------------------------------
# regression sentinel: CLI (first-run, --update, exit codes)
# ---------------------------------------------------------------------------


def test_sentinel_cli_first_run_update_then_2x_fails(tmp_path):
    m = _sentinel()
    bdir, cdir = tmp_path / "baselines", tmp_path / "run"
    cdir.mkdir()
    cur = _doc({"solve": 1000.0}, iqr=50.0)
    (cdir / "BENCH_solve_smoke.json").write_text(json.dumps(cur))

    # first-run without --update: pass, no baseline written
    assert m.main(["--baseline", str(bdir), "--current", str(cdir)]) == 0
    assert not (bdir / "solve_smoke.json").exists()
    # --update creates it (BENCH_ prefix stripped)
    assert m.main(["--baseline", str(bdir), "--current", str(cdir),
                   "--update"]) == 0
    assert (bdir / "solve_smoke.json").exists()
    # unchanged run passes
    assert m.main(["--baseline", str(bdir), "--current", str(cdir)]) == 0
    # the acceptance gate: synthetic 2x slowdown must exit 1
    slow = copy.deepcopy(cur)
    for r in slow["rows"]:
        r["median"] *= 2.1
    (cdir / "BENCH_solve_smoke.json").write_text(json.dumps(slow))
    assert m.main(["--baseline", str(bdir), "--current", str(cdir)]) == 1
    # usage errors exit 2
    assert m.main(["--baseline", str(bdir)]) == 2
    assert m.main(["--baseline", str(bdir), "--current", str(cdir),
                   "--tolerance", "-1"]) == 2


def test_sentinel_cli_history_appends(tmp_path):
    m = _sentinel()
    from benchmarks import history

    bdir, cdir, hdir = (tmp_path / d for d in ("b", "c", "h"))
    cdir.mkdir()
    (cdir / "BENCH_s.json").write_text(json.dumps(_doc({"a": 1.0})))
    m.main(["--baseline", str(bdir), "--current", str(cdir),
            "--update", "--history", str(hdir)])
    m.main(["--baseline", str(bdir), "--current", str(cdir),
            "--history", str(hdir)])
    assert len(history.load(str(hdir), "s", "cpu", 1)) == 2


def test_committed_baselines_match_sentinel_naming():
    """Every committed baseline must be loadable and carry the env the
    CI job that produces its BENCH_ file runs under."""
    m = _sentinel()
    bdir = _ROOT / "benchmarks" / "baselines"
    files = sorted(bdir.glob("*.json")) if bdir.exists() else []
    assert files, "no committed baselines under benchmarks/baselines"
    for p in files:
        doc = m._load(str(p))
        backend, devices = m._env(doc)
        assert backend == "cpu" and devices in (1, 8), p.name
        assert m._time_rows(doc), f"{p.name}: no time rows to gate on"


# ---------------------------------------------------------------------------
# SolveReport.cost (acceptance: flops > 0 for flat and fused, obs off)
# ---------------------------------------------------------------------------


def test_plan_cost_flat_and_fused_with_obs_off():
    from repro import obs
    from repro.coarsen.config import CoarsenConfig
    from repro.graphs.generators import random_graph
    from repro.solve import SolveSpec, plan

    assert not obs.metrics_active()
    g = random_graph(64, 256, seed=7)
    p_flat = plan(g, SolveSpec())
    rep = p_flat.solve()
    c = rep.cost
    assert c is not None and c.analyzed == "flat"
    assert c.flops > 0 and c.bytes > 0
    assert c.flops == c.dot_flops + c.ew_flops
    assert p_flat.cost is c  # plan exposes the same analysis
    # cached plan for the same (spec, shape) reuses the memoized cost
    assert plan(g, SolveSpec()).solve().cost is c

    cfg = CoarsenConfig(cutoff=16, fused=True)
    p_fused = plan(g, SolveSpec(mode="coarsen", coarsen=cfg))
    cf = p_fused.solve().cost
    assert cf is not None and cf.analyzed == "coarsen.level0.fused"
    assert cf.flops > 0 and cf.bytes > 0


def test_plan_cost_absent_for_stream_mode():
    from repro.solve import SolveSpec, plan

    p = plan(64, SolveSpec(mode="stream", batch_capacity=64))
    assert p.cost is None
    u, v = np.asarray([0, 1]), np.asarray([2, 3])
    rep = p.update(u, v, np.asarray([1.0, 2.0]))
    assert rep.cost is None


# ---------------------------------------------------------------------------
# MicroBatcher admission metrics
# ---------------------------------------------------------------------------


def test_microbatcher_obs_counters_and_gauge():
    from repro import obs
    from repro.solve import SolveSpec, plan
    from repro.stream.service import MicroBatcher, QueryService

    p = plan(32, SolveSpec(mode="stream", batch_capacity=64))
    u = np.arange(31, dtype=np.int32)
    p.update(u, u + 1, np.ones(31))  # a path: everything connected

    obs.enable("metrics")
    try:
        obs.metrics_reset()
        svc = QueryService(p.engine.snapshots)
        b = MicroBatcher(svc, max_queue=4)
        for i in range(9):  # 2 overflow auto-flushes + 1 open query
            b.ask_connected(i % 32, (i + 1) % 32)
        snap = obs.metrics_snapshot()
        assert snap["counters"]["stream.batcher.overflow"] == 2
        assert snap["counters"]["stream.batcher.flush"] == 2
        assert snap["counters"]["stream.batcher.flushed_queries"] == 8
        assert snap["gauges"]["stream.batcher.queue_depth"] == 1
        b.flush()
        snap = obs.metrics_snapshot()
        assert snap["counters"]["stream.batcher.flush"] == 3
        assert snap["counters"]["stream.batcher.flushed_queries"] == 9
        assert snap["gauges"]["stream.batcher.queue_depth"] == 0
    finally:
        obs.disable()
        obs.reset()
        obs.metrics_reset()


def test_microbatcher_silent_when_obs_off():
    from repro import obs
    from repro.solve import SolveSpec, plan
    from repro.stream.service import MicroBatcher, QueryService

    obs.metrics_reset()
    p = plan(16, SolveSpec(mode="stream", batch_capacity=16))
    p.update(np.asarray([0, 1]), np.asarray([1, 2]), np.ones(2))
    b = MicroBatcher(QueryService(p.engine.snapshots), max_queue=2)
    b.ask_connected(0, 1)
    b.ask_connected(0, 2)  # auto-flush
    assert b.result((0, 0)) is True
    snap = obs.metrics_snapshot()
    assert "stream.batcher.overflow" not in snap["counters"]
    assert "stream.batcher.queue_depth" not in snap["gauges"]


# ---------------------------------------------------------------------------
# loadgen smoke: open loop against a concurrently mutating graph
# ---------------------------------------------------------------------------


def test_loadgen_smoke_slo_report(tmp_path):
    from repro import obs
    from repro.launch import loadgen

    out = tmp_path / "SLO_smoke.json"
    try:
        rc = loadgen.main([
            "--qps", "120", "--duration", "1.5", "--scale", "8",
            "--micro-batch", "32", "--writer-batch", "256",
            "--seed", "0", "--out", str(out),
            # lenient targets: this asserts mechanism, not machine speed
            "--slo-p50-ms", "5000", "--slo-p99-ms", "20000",
            "--max-drop-frac", "0.9", "--min-qps-frac", "0.01",
        ])
    finally:
        obs.disable()
        obs.reset()
        obs.metrics_reset()
    assert rc == 0
    d = json.loads(out.read_text())
    assert d["schema"] == "slo-report/v1"
    q = d["queries"]
    assert q["answered"] > 0 and q["offered"] >= q["answered"]
    # open loop under a mutating graph: latency must be real, not zero
    lat = d["latency_ms"]
    assert lat["count"] == q["answered"]
    assert lat["p99"] >= lat["p95"] >= lat["p50"] > 0.0
    assert d["writer"]["updates"] > 0 and d["writer"]["snapshot_version"] > 0
    assert d["batcher"].get("flush", 0) > 0
    assert d["slo"]["passed"] and d["slo"]["failures"] == []
    assert d["achieved_qps"] > 0


def test_loadgen_exits_nonzero_on_missed_slo(tmp_path):
    from repro import obs
    from repro.launch import loadgen

    out = tmp_path / "SLO_fail.json"
    try:
        rc = loadgen.main([
            "--qps", "80", "--duration", "1.0", "--scale", "8",
            "--micro-batch", "32", "--out", str(out),
            "--slo-p50-ms", "0.000001",  # impossible target
        ])
    finally:
        obs.disable()
        obs.reset()
        obs.metrics_reset()
    assert rc == 1
    d = json.loads(out.read_text())
    assert not d["slo"]["passed"]
    assert any("p50" in f for f in d["slo"]["failures"])
