"""AS/SV connectivity (the LACC-style baseline) vs scipy."""
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st  # skips cleanly if absent

from repro.core import connected_components, msf
from repro.graphs import grid_road_graph, random_graph, rmat_graph
from repro.graphs.generators import components_graph
from repro.graphs.structures import from_edges, nx_free_n_components


@pytest.mark.parametrize(
    "g",
    [
        random_graph(200, 600, seed=1),
        grid_road_graph(12, 17, seed=2),
        rmat_graph(8, 4, seed=3),
        random_graph(300, 150, seed=4),
        components_graph(5, 40, seed=5),
    ],
    ids=["random", "grid", "rmat", "sparse", "components"],
)
def test_cc_count_matches_scipy(g):
    cc = connected_components(g)
    assert int(cc.n_components) == nx_free_n_components(g)


def test_cc_partition_matches_msf_parents():
    """MSF parent labels and CC labels induce the same partition."""
    g = rmat_graph(8, 4, seed=11)
    cc = connected_components(g)
    r = msf(g)
    a = np.asarray(cc.parent)
    b = np.asarray(r.parent)
    # same partition ⇔ label maps are consistent in both directions
    import collections

    fwd, bwd = {}, {}
    for x, y in zip(a, b):
        assert fwd.setdefault(x, y) == y
        assert bwd.setdefault(y, x) == x


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 50), m=st.integers(0, 120), seed=st.integers(0, 2**31 - 1))
def test_cc_property(n, m, seed):
    rng = np.random.default_rng(seed)
    g = from_edges(rng.integers(0, n, m), rng.integers(0, n, m),
                   rng.integers(1, 256, m).astype(np.float64), n)
    cc = connected_components(g)
    assert int(cc.n_components) == nx_free_n_components(g)
