"""End-to-end system behaviour: training reduces loss on planted tasks,
fault-injected runs resume exactly, multilinear paths agree, and the
dry-run machinery compiles representative cells on a multi-device mesh."""
import os
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def _train_args(**kw):
    d = dict(arch="qwen2-7b", steps=30, seed=0, ckpt_dir=None, ckpt_every=10,
             fault_at=None, supervise=False)
    d.update(kw)
    return types.SimpleNamespace(**d)


def test_lm_training_reduces_loss():
    from repro.launch.train import run

    out = run(_train_args(arch="qwen2-7b", steps=60))
    assert out["last_loss"] < out["first_loss"] - 0.01


def test_recsys_training_reduces_loss():
    from repro.launch.train import run

    out = run(_train_args(arch="xdeepfm", steps=60))
    assert out["last_loss"] < out["first_loss"]


def test_fault_injection_resume_is_exact(tmp_path):
    """Crash at step k, restart from checkpoint → identical final loss to an
    uninterrupted run (step-keyed data + deterministic steps)."""
    from repro.launch.train import FaultInjected, run

    base = run(_train_args(arch="gat-cora", steps=30))
    ck = str(tmp_path / "ck")
    args = _train_args(arch="gat-cora", steps=30, ckpt_dir=ck, ckpt_every=5,
                       fault_at=17)
    with pytest.raises(FaultInjected):
        run(args)
    args.fault_at = None
    resumed = run(args)
    assert abs(resumed["last_loss"] - base["last_loss"]) < 1e-5


def test_multilinear_paths_agree():
    """COO (production) and dense (reference) give the same
    minimum-outgoing-edge reductions."""
    from repro.core.multilinear import min_outgoing_coo, min_outgoing_dense
    from repro.graphs import random_graph

    g = random_graph(80, 300, seed=2)
    p = jnp.array((np.arange(80) * 7) % 13 % 80, jnp.int32)
    em_coo = min_outgoing_coo(p, g.src, g.dst, g.w, g.eid, g.valid, 80,
                              segment="vertex")
    a = np.full((80, 80), np.inf, np.float32)
    for s, d, w in zip(np.asarray(g.src), np.asarray(g.dst), np.asarray(g.w)):
        a[s, d] = min(a[s, d], w)
    em_dense = min_outgoing_dense(p, jnp.array(a))
    np.testing.assert_array_equal(np.asarray(em_coo.w), np.asarray(em_dense.w))
    np.testing.assert_array_equal(
        np.asarray(em_coo.payload[0]), np.asarray(em_dense.payload[0])
    )


_DRYRUN_SMOKE = r"""
import jax
from repro.launch.mesh import make_mesh
from repro.launch.cells import build_cell, build_msf_cell, lower_cell
from repro.configs.base import ShapeCell
mesh = make_mesh((2, 4), ("data", "model"))
cells = [("qwen2-7b", "train_4k"), ("mixtral-8x7b", "long_500k"),
         ("gatedgcn", "full_graph_sm"), ("xdeepfm", "train_batch")]
for arch, shape in cells:
    cell = build_cell(arch, shape, mesh)
    co = lower_cell(cell).compile()
    assert co.memory_analysis().argument_size_in_bytes > 0
s = ShapeCell(name="msf", kind="msf", n_nodes=1 << 14, n_edges=(1 << 14) * 4)
c = build_msf_cell(s, mesh)
c.fn.lower(*c.abstract_args).compile()
print("DRYRUN_SMOKE_OK")
"""


def test_dryrun_cells_compile_multidevice():
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", _DRYRUN_SMOKE],
                         capture_output=True, text=True, env=env,
                         timeout=560, cwd=".")
    assert "DRYRUN_SMOKE_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]
