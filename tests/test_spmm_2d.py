"""2D multilinear SpMM (paper's Fig-2 schedule with ⊕ = sum) vs the plain
segment_sum oracle, on a real 8-device mesh."""
import os
import subprocess
import sys

_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.multilinear import spmm_sum_2d
from repro.graphs import random_graph
from repro.graphs.partition import partition_edges_2d

from repro.compat import make_mesh, shard_map
R, C = 2, 4
mesh = make_mesh((R, C), ("data", "model"))
g = random_graph(300, 1200, seed=3)
part = partition_edges_2d(g, R, C)
h = 5
rng = np.random.default_rng(0)
x = rng.standard_normal((part.n_pad, h)).astype(np.float32)

def run(x, src_row, dst_col, valid):
    src_row = src_row.reshape(-1)
    dst_col = dst_col.reshape(-1)
    valid = valid.reshape(-1)
    return spmm_sum_2d(x, src_row, dst_col, valid,
                       row_axis="data", col_axis="model",
                       shard_size=part.shard_size,
                       col_block_size=R * part.shard_size)

mapped = jax.jit(shard_map(
    run, mesh=mesh,
    in_specs=(P(("data", "model"), None), P("data", "model", None),
              P("data", "model", None), P("data", "model", None)),
    out_specs=P(("data", "model"), None),
))
got = np.asarray(mapped(x, part.src_row, part.dst_col, part.valid))
# oracle: plain segment-sum over the original COO
want = np.zeros((part.n_pad, h), np.float32)
src, dst, v = np.asarray(g.src), np.asarray(g.dst), np.asarray(g.valid)
np.add.at(want, dst[v], x[src[v]])
np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
print("SPMM2D_OK")
"""


def test_spmm_2d_matches_segment_sum():
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", _CODE], capture_output=True,
                         text=True, env=env, timeout=420, cwd=".")
    assert "SPMM2D_OK" in out.stdout, out.stdout + out.stderr[-3000:]
