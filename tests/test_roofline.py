"""HLO analyzer: exactness on known programs (loop multipliers, dot flops,
collective bytes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_analyzer import analyze


def test_scan_dot_flops_exact():
    n, steps = 128, 7
    def f(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]
    co = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((steps, n, n), jnp.float32),
    ).compile()
    res = analyze(co.as_text())
    assert res["dot_flops"] == steps * 2 * n**3
    assert res["dynamic_loops"] == 0


def test_nested_scan_multiplies():
    n, outer, inner = 64, 3, 5
    def f(x, ws):
        def obody(c, _):
            c2 = jax.lax.scan(lambda c, w: (c @ w, None), c, ws)[0]
            return c2, None
        return jax.lax.scan(obody, x, None, length=outer)[0]
    co = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((inner, n, n), jnp.float32),
    ).compile()
    res = analyze(co.as_text())
    assert res["dot_flops"] == outer * inner * 2 * n**3


def test_dynamic_while_flagged():
    def f(x):
        return jax.lax.while_loop(lambda c: c[0, 0] < 100.0, lambda c: c @ c, x)
    co = jax.jit(f).lower(jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile()
    res = analyze(co.as_text())
    assert res["dynamic_loops"] >= 1
    assert res["dot_flops"] == 2 * 16**3  # per-iteration unit


def test_collective_bytes_psum():
    import subprocess, sys, os
    code = r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.analysis.hlo_analyzer import analyze
from repro.compat import make_mesh, shard_map
mesh = make_mesh((8,), ("d",))
def f(x):
    return shard_map(lambda a: jax.lax.psum(a, "d"), mesh=mesh,
                     in_specs=P("d"), out_specs=P())(x)
co = jax.jit(f).lower(jax.ShapeDtypeStruct((8 * 1024,), jnp.float32)).compile()
res = analyze(co.as_text())
# all-reduce of a 1024-element f32 shard = 4096 operand bytes per device
assert res["collective_bytes"] == 4096, res
print("COLL_OK")
"""
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=240, cwd=".")
    assert "COLL_OK" in out.stdout, out.stdout + out.stderr
