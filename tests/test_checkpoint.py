"""Checkpointing: roundtrip, async, atomicity, latest-step discovery, and
elastic restore (different device count) in a subprocess."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    wait_for_saves,
)


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.int32), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, async_save=False)
    assert latest_step(str(tmp_path)) == 7
    r = restore_checkpoint(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path):
    t = _tree()
    for s in (5, 10, 15):
        save_checkpoint(str(tmp_path), s, t, async_save=True)
    wait_for_saves()
    assert latest_step(str(tmp_path)) == 15


def test_incomplete_checkpoint_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t, async_save=False)
    # simulate a crash mid-save: tmp dir without DONE
    os.makedirs(tmp_path / "step_000000009.tmp")
    # and a finished dir missing its DONE marker
    os.makedirs(tmp_path / "step_000000008")
    assert latest_step(str(tmp_path)) == 3


_ELASTIC = r"""
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import restore_checkpoint, save_checkpoint, latest_step
import sys
path = sys.argv[1]
mode = sys.argv[2]
from repro.compat import make_mesh
mesh = make_mesh((jax.device_count(),), ("data",))
sh = NamedSharding(mesh, P("data"))
t = {"w": jnp.arange(64, dtype=jnp.float32)}
if mode == "save":
    t = {"w": jax.device_put(t["w"], sh)}
    save_checkpoint(path, 1, t, async_save=False)
    print("SAVED", jax.device_count())
else:
    r = restore_checkpoint(path, 1, t, shardings={"w": sh})
    assert r["w"].sharding.num_devices == jax.device_count()
    np.testing.assert_array_equal(np.asarray(r["w"]), np.arange(64, dtype=np.float32))
    print("RESTORED", jax.device_count())
"""


def test_elastic_restore_different_device_count(tmp_path):
    """Save sharded over 8 devices, restore sharded over 4 — elastic
    scaling via reshard-on-restore."""
    env = dict(os.environ, PYTHONPATH="src")
    for count, mode in [(8, "save"), (4, "load")]:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={count}"
        out = subprocess.run(
            [sys.executable, "-c", _ELASTIC, str(tmp_path), mode],
            capture_output=True, text=True, env=env, timeout=240, cwd=".",
        )
        assert out.returncode == 0, out.stderr


# ---------------------------------------------------------------------------
# stream-engine durable restart (repro.stream.persist, DESIGN.md §13.4)
# ---------------------------------------------------------------------------


def _churned_engine(n=96, seed=3):
    """An engine with real history: inserts, exact deletions (reservoir
    promotions), and a live replacement reservoir."""
    from repro.stream.engine import StreamEngine

    rng = np.random.default_rng(seed)
    eng = StreamEngine(
        n, batch_capacity=128,
        reservoir_capacity=4096, reservoir_per_component=4096,
    )
    for _ in range(5):
        m = 48
        u, v = rng.integers(0, n, m), rng.integers(0, n, m)
        w = rng.integers(1, 99, m).astype(np.float64)
        eng.insert_batch(u, v, w)
    flo, fhi, _, _ = eng.forest_edges()
    pick = rng.choice(len(flo), size=6, replace=False)
    eng.delete_batch(flo[pick], fhi[pick])
    return eng, rng


def test_stream_persist_exact_resume(tmp_path):
    """save_stream → fresh engine → restore_stream must resume
    bit-identical: forest weight, MSF gid set, canonical labels,
    reservoir contents, and — the real bar — identical results for
    identical subsequent updates."""
    from repro.stream import persist
    from repro.stream.engine import StreamEngine

    eng, rng = _churned_engine()
    step = persist.save_stream(str(tmp_path), eng)
    assert step == eng.version
    assert persist.latest_stream_step(str(tmp_path)) == step

    eng2 = StreamEngine(
        96, batch_capacity=128,
        reservoir_capacity=4096, reservoir_per_component=4096,
    )
    assert persist.restore_stream(str(tmp_path), eng2) == eng.version
    assert eng2.version == eng.version
    assert eng2.weight == eng.weight  # bit-identical, not approx
    assert set(eng2.forest_gids().tolist()) == set(eng.forest_gids().tolist())
    np.testing.assert_array_equal(
        np.asarray(eng2.snapshots.acquire().parent),
        np.asarray(eng.snapshots.acquire().parent),
    )
    assert eng2.reservoir_size == eng.reservoir_size
    assert eng2.unhealed == eng.unhealed

    # identical future ops → identical trajectories (gid line resumed)
    n = 96
    for _ in range(3):
        m = 32
        u, v = rng.integers(0, n, m), rng.integers(0, n, m)
        w = rng.integers(1, 99, m).astype(np.float64)
        s1 = eng.insert_batch(u, v, w)
        s2 = eng2.insert_batch(u, v, w)
        assert s1.weight == s2.weight and s1.version == s2.version
        assert s1.n_new == s2.n_new and s1.n_revived == s2.n_revived
    flo, fhi, _, _ = eng.forest_edges()
    d1 = eng.delete_batch(flo[:3], fhi[:3])
    d2 = eng2.delete_batch(flo[:3], fhi[:3])
    assert d1.n_deleted == d2.n_deleted
    assert d1.n_replacements == d2.n_replacements
    assert eng.weight == eng2.weight
    assert set(eng.forest_gids().tolist()) == set(eng2.forest_gids().tolist())


def test_stream_persist_async_and_latest(tmp_path):
    from repro.stream import persist

    eng, _ = _churned_engine(seed=9)
    persist.save_stream(str(tmp_path), eng, async_save=True)
    persist.wait_for_saves()
    assert persist.latest_stream_step(str(tmp_path)) == eng.version
    with pytest.raises(FileNotFoundError):
        persist.restore_stream(str(tmp_path / "empty"), eng)
