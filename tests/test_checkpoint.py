"""Checkpointing: roundtrip, async, atomicity, latest-step discovery, and
elastic restore (different device count) in a subprocess."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    wait_for_saves,
)


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.int32), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, async_save=False)
    assert latest_step(str(tmp_path)) == 7
    r = restore_checkpoint(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path):
    t = _tree()
    for s in (5, 10, 15):
        save_checkpoint(str(tmp_path), s, t, async_save=True)
    wait_for_saves()
    assert latest_step(str(tmp_path)) == 15


def test_incomplete_checkpoint_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t, async_save=False)
    # simulate a crash mid-save: tmp dir without DONE
    os.makedirs(tmp_path / "step_000000009.tmp")
    # and a finished dir missing its DONE marker
    os.makedirs(tmp_path / "step_000000008")
    assert latest_step(str(tmp_path)) == 3


_ELASTIC = r"""
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import restore_checkpoint, save_checkpoint, latest_step
import sys
path = sys.argv[1]
mode = sys.argv[2]
from repro.compat import make_mesh
mesh = make_mesh((jax.device_count(),), ("data",))
sh = NamedSharding(mesh, P("data"))
t = {"w": jnp.arange(64, dtype=jnp.float32)}
if mode == "save":
    t = {"w": jax.device_put(t["w"], sh)}
    save_checkpoint(path, 1, t, async_save=False)
    print("SAVED", jax.device_count())
else:
    r = restore_checkpoint(path, 1, t, shardings={"w": sh})
    assert r["w"].sharding.num_devices == jax.device_count()
    np.testing.assert_array_equal(np.asarray(r["w"]), np.arange(64, dtype=np.float32))
    print("RESTORED", jax.device_count())
"""


def test_elastic_restore_different_device_count(tmp_path):
    """Save sharded over 8 devices, restore sharded over 4 — elastic
    scaling via reshard-on-restore."""
    env = dict(os.environ, PYTHONPATH="src")
    for count, mode in [(8, "save"), (4, "load")]:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={count}"
        out = subprocess.run(
            [sys.executable, "-c", _ELASTIC, str(tmp_path), mode],
            capture_output=True, text=True, env=env, timeout=240, cwd=".",
        )
        assert out.returncode == 0, out.stderr
