"""Unit tests of the unified solver API (``repro.solve``, DESIGN.md §9).

Covers: SolveSpec validation (the raise sites consolidated out of the
engines), CoarsenConfig validation (segmin regression), resolve()
auto-detection, the bounded plan cache (engine + executable reuse), the
engine registry extension point, the SolveReport schema across modes,
and the stream plan surfaces.
"""
import dataclasses

import numpy as np
import pytest

from repro.coarsen import CoarsenConfig
from repro.graphs.structures import from_edges
from repro.solve import (
    PLAN_CACHE_MAXSIZE,
    SolveSpec,
    clear_plan_cache,
    plan,
    plan_cache_info,
    register_engine,
)


def _graph(n=32, m=64, seed=0, wlevels=5, float_w=False):
    rng = np.random.default_rng(seed)
    w = rng.random(m) + 0.25 if float_w else rng.integers(1, wlevels + 1, m)
    return from_edges(
        rng.integers(0, n, m), rng.integers(0, n, m), w.astype(np.float64), n
    )


# ---------------------------------------------------------------------------
# SolveSpec validation — the consolidated raise sites
# ---------------------------------------------------------------------------

def test_spec_rejects_unknown_enums():
    with pytest.raises(ValueError, match="unknown mode"):
        SolveSpec(mode="bogus")
    with pytest.raises(ValueError, match="unknown variant"):
        SolveSpec(variant="bogus")
    with pytest.raises(ValueError, match="shortcut"):
        SolveSpec(shortcut="bogus")
    with pytest.raises(ValueError, match="segmin"):
        SolveSpec(segmin="bogus")
    with pytest.raises(ValueError, match="dedupe"):
        SolveSpec(dedupe="bogus")


def test_spec_mode_specific_shortcuts():
    # "baseline" is a distributed-only strategy; "complete" single-device.
    with pytest.raises(ValueError, match="shortcut"):
        SolveSpec(mode="flat", shortcut="baseline")
    with pytest.raises(ValueError, match="shortcut"):
        SolveSpec(mode="dist", shortcut="complete")
    assert SolveSpec(mode="dist", shortcut="baseline").shortcut == "baseline"


def test_spec_flat_rejects_fused_and_sorted():
    with pytest.raises(ValueError, match="fused=True requires coarsen"):
        SolveSpec(mode="flat", fused=True)
    with pytest.raises(ValueError, match="sorted"):
        SolveSpec(mode="flat", segmin="sorted")
    with pytest.raises(ValueError, match="pack=True inner loop"):
        SolveSpec(mode="flat", pack=False, segmin="pallas")
    with pytest.raises(ValueError, match="mode='coarsen'"):
        SolveSpec(mode="flat", coarsen=CoarsenConfig())


def test_spec_coarsen_true_normalizes_and_hashes():
    s = SolveSpec(mode="coarsen", coarsen=True)
    assert isinstance(s.coarsen, CoarsenConfig)
    # frozen + hashable: usable as a cache key
    assert hash(s) == hash(SolveSpec(mode="coarsen", coarsen=CoarsenConfig()))
    d = {s: 1}
    assert d[SolveSpec(mode="coarsen", coarsen=CoarsenConfig())] == 1


def test_coarsen_config_validates_segmin():
    """Regression: an unknown segmin used to survive __post_init__ and
    blow up only inside make_packed_segmin, deep in a level kernel."""
    with pytest.raises(ValueError, match="segmin"):
        CoarsenConfig(segmin="bogus")
    with pytest.raises(ValueError, match="dedupe"):
        CoarsenConfig(dedupe="bogus")
    for ok in (None, "auto", "jnp", "pallas", "sorted"):
        CoarsenConfig(segmin=ok)


def test_spec_stream_static_validation():
    with pytest.raises(ValueError, match="batch_capacity"):
        SolveSpec(mode="stream", batch_capacity=0)
    # pack=True union-eid overflow is data-dependent → resolve-time
    big = SolveSpec(mode="stream", pack=True, batch_capacity=1 << 23)
    with pytest.raises(ValueError, match="pack32 index field"):
        big.resolve(1 << 23)


def test_stream_resolve_keeps_pack_auto_for_graph_targets():
    """Regression: stream mode must NOT auto-detect pack from a Graph
    target's integral weights — the engine tracks packability per batch
    and degrades near the pack32 bound; a data-probed pack=True used to
    trip the union-overflow guard spuriously."""
    g = _graph(seed=2)  # integral weights
    rs = SolveSpec(mode="stream", batch_capacity=1 << 24).resolve(g)
    assert rs.pack is None  # left to the engine's running conjunction
    # and the overflow guard only fires for an explicit pack=True
    SolveSpec(mode="stream", batch_capacity=1 << 24).resolve(g.n)


def test_stream_plan_accepts_numpy_vertex_counts():
    """Regression: StreamingMSF(np.int64(n)) worked; the plan target
    must too (n often comes off array shapes / int32 fields)."""
    p = plan(np.int64(32), SolveSpec(mode="stream", batch_capacity=8))
    rep = p.update(np.arange(4), np.arange(1, 5), np.ones(4))
    assert rep.n_msf_edges == 4


def test_resolve_does_not_fold_pack_into_coarsen_config():
    """Regression: the deprecated pack kwarg steered only the residual
    solve; the levels keep config.pack (None = per-level auto)."""
    g = _graph(seed=6)  # integral → levels auto-detect pack themselves
    rs = SolveSpec(mode="coarsen", pack=False).resolve(g)
    assert rs.pack is False  # residual honors the explicit knob
    assert rs.coarsen.pack is None  # levels keep their own auto-detect


# ---------------------------------------------------------------------------
# resolve() — the centralized auto-detect
# ---------------------------------------------------------------------------

def test_resolve_auto_pack_from_graph_data():
    g_int = _graph(seed=1)
    g_float = _graph(seed=1, float_w=True)
    assert SolveSpec().resolve(g_int).pack is True
    assert SolveSpec().resolve(g_float).pack is False
    # explicit pack wins over the data
    assert SolveSpec(pack=False).resolve(g_int).pack is False


def test_resolve_concrete_dedupe_and_shortcut():
    rs = SolveSpec(mode="coarsen").resolve(_graph())
    assert rs.dedupe in ("device", "host")
    assert rs.shortcut == "complete"
    assert SolveSpec(mode="dist").resolve(None).shortcut == "csp"


def test_resolve_folds_spec_knobs_into_coarsen_config():
    cfg = CoarsenConfig(cutoff=64)
    rs = SolveSpec(
        mode="coarsen", coarsen=cfg, fused=True, segmin="jnp", dedupe="host"
    ).resolve(_graph())
    assert rs.coarsen.fused is True
    assert rs.coarsen.segmin == "jnp"
    assert rs.coarsen.dedupe == "host"
    assert rs.coarsen.cutoff == 64  # non-overridden fields survive
    # without spec overrides the embedded config passes through untouched
    rs2 = SolveSpec(mode="coarsen", coarsen=cfg).resolve(_graph())
    assert rs2.coarsen == cfg


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_plan_cache_same_spec_same_shape_reuses_executable():
    from repro.core.msf import _msf_jit

    clear_plan_cache()
    g = _graph(n=48, m=31, seed=3)
    spec = SolveSpec(max_iters=37)  # unique static → fresh executable
    p1 = plan(g, spec)
    p1.solve()
    warm_exec = _msf_jit._cache_size()
    warm_plans = plan_cache_info()[0]
    p2 = plan(g, spec)
    assert p2._engine is p1._engine, "same (spec, shapes) must hit the cache"
    p2.solve()
    assert _msf_jit._cache_size() == warm_exec, "cache hit still re-traced"
    assert plan_cache_info()[0] == warm_plans
    # same shapes, different *data* resolving identically also hits
    g_same = from_edges(
        np.asarray(g.src[: g.num_directed_edges // 2]),
        np.asarray(g.dst[: g.num_directed_edges // 2]),
        np.asarray(g.w[: g.num_directed_edges // 2]) % 7 + 1,
        g.n,
    )
    assert g_same.num_directed_edges == g.num_directed_edges
    assert plan(g_same, spec)._engine is p1._engine


def test_plan_cache_misses_on_shape_spec_or_resolution():
    clear_plan_cache()
    spec = SolveSpec(max_iters=37)
    g = _graph(n=48, m=31, seed=3)
    p1 = plan(g, spec)
    assert plan(_graph(n=48, m=17, seed=3), spec)._engine is not p1._engine
    assert plan(g, SolveSpec(max_iters=38))._engine is not p1._engine
    # same shapes but float weights resolve pack differently → must miss
    # (a shared engine would run pack32 kernels on float data)
    g_float = _graph(n=48, m=31, seed=3, float_w=True)
    p_f = plan(g_float, spec)
    assert p_f._engine is not p1._engine
    assert p_f.resolved.pack is False and p1.resolved.pack is True


def test_plan_cache_is_bounded():
    clear_plan_cache()
    g = _graph(n=16, m=8)
    for i in range(PLAN_CACHE_MAXSIZE + 16):
        plan(g, SolveSpec(max_iters=1000 + i))  # build only, no solve
    assert plan_cache_info()[0] <= PLAN_CACHE_MAXSIZE


def test_stream_plans_are_not_cached():
    clear_plan_cache()
    spec = SolveSpec(mode="stream", batch_capacity=16)
    p1, p2 = plan(64, spec), plan(64, spec)
    assert p1._engine is not p2._engine, "stream engines are stateful"
    assert plan_cache_info()[0] == 0


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------

def test_register_engine_extension_point():
    from repro.solve import planner
    from repro.solve import spec as spec_mod

    seen = {}

    class _Echo:
        def __init__(self, rs):
            self.rs = rs

        def solve(self, target, **kw):
            seen["target"] = target
            return ("echo", self.rs.spec.mode)

    register_engine("echo", lambda t, rs, mesh: _Echo(rs))
    try:
        s = SolveSpec(mode="echo")  # registered modes become legal specs
        assert plan(_graph(), s).solve() == ("echo", "echo")
        assert seen["target"].n == 32
        assert "echo" in planner.registered_modes()
    finally:
        planner._engines.pop("echo", None)
        spec_mod.EXTRA_MODES.discard("echo")
    with pytest.raises(ValueError, match="unknown mode"):
        SolveSpec(mode="echo")


def test_plan_unknown_mode_and_missing_mesh_errors():
    with pytest.raises(ValueError, match="needs a mesh"):
        plan(None, SolveSpec(mode="dist"))


# ---------------------------------------------------------------------------
# SolveReport schema across modes
# ---------------------------------------------------------------------------

def test_report_schema_flat_vs_coarsen():
    g = _graph(n=64, m=160, seed=7)
    flat = plan(g, SolveSpec()).solve()
    co = plan(
        g, SolveSpec(mode="coarsen", coarsen=CoarsenConfig(cutoff=4))
    ).solve()
    assert flat.mode == "flat" and co.mode == "coarsen"
    for rep in (flat, co):
        assert isinstance(rep.weight, float)
        assert rep.msf_eids.shape == (rep.n_msf_edges,)  # trimmed, no padding
        assert rep.parent.shape == (g.n,)
        assert rep.host_roundtrips >= 0 and rep.recompiles >= 0
    assert flat.levels == ()
    assert len(co.levels) >= 1
    assert abs(flat.weight - co.weight) < 1e-3
    assert set(flat.msf_eids.tolist()) == set(co.msf_eids.tolist())
    assert flat.n_components == co.n_components


def test_report_dist_mode(dist_mesh, dist_mesh_shape):
    from repro.graphs.partition import partition_edges_2d

    g = _graph(n=48, m=128, seed=5)
    part = partition_edges_2d(g, *dist_mesh_shape)
    rep = plan(part, SolveSpec(mode="dist"), mesh=dist_mesh).solve()
    flat = plan(g, SolveSpec()).solve()
    assert rep.mode == "dist"
    assert abs(rep.weight - flat.weight) < 1e-3
    assert set(rep.msf_eids.tolist()) == set(flat.msf_eids.tolist())
    cfg = CoarsenConfig(cutoff=4, fused=True, dedupe="device")
    rep2 = plan(
        part, SolveSpec(mode="dist", coarsen=cfg), mesh=dist_mesh
    ).solve()
    assert rep2.host_roundtrips == 0
    assert set(rep2.msf_eids.tolist()) == set(flat.msf_eids.tolist())


def test_stream_plan_surfaces():
    rng = np.random.default_rng(11)
    n, m = 128, 256
    u = rng.integers(0, n, m)
    v = rng.integers(0, n, m)
    w = rng.integers(1, 8, m).astype(np.float64)
    p = plan(n, SolveSpec(mode="stream", batch_capacity=64))
    rep = None
    for k in range(0, m, 64):
        rep = p.update(u[k : k + 64], v[k : k + 64], w[k : k + 64])
    assert rep.mode == "stream"
    flat = plan(from_edges(u, v, w, n), SolveSpec()).solve()
    assert abs(rep.weight - flat.weight) <= max(1.0, 1e-6 * flat.weight)
    assert rep.recompiles >= 1
    assert rep.raw.version == p._engine.engine.version
    conn = p.query(u[:16], v[:16])
    assert conn.shape == (16,) and conn.dtype == bool
    assert conn.all()  # inserted endpoints are connected
    # update()/query() are stream-only surfaces
    with pytest.raises(ValueError, match="stream-mode"):
        plan(_graph(), SolveSpec()).update(u, v, w)
    # solve() on a stream plan reports current state without recompute
    state = p.solve()
    assert state.weight == rep.weight
    assert state.n_msf_edges == rep.n_msf_edges


def test_stream_plan_delete_and_compact():
    p = plan(32, SolveSpec(mode="stream", batch_capacity=16))
    u = np.arange(0, 8)
    v = np.arange(1, 9)
    p.update(u, v, np.ones(8))
    rep = p.delete(u[:2], v[:2])
    assert rep.n_msf_edges == 6
    rep2 = p.compact()
    assert rep2.n_msf_edges == 6
    assert rep2.weight == 6.0


def test_plan_overrides_shorthand():
    g = _graph(n=32, m=64, seed=13)
    rep = plan(g, mode="coarsen", coarsen=CoarsenConfig(cutoff=4)).solve()
    flat = plan(g).solve()
    assert abs(rep.weight - flat.weight) < 1e-3
    base = SolveSpec()
    p = plan(g, base, variant="paper")
    assert p.spec.variant == "paper"
