"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
+ cross-check against the core (non-Pallas) implementation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.multilinear import min_outgoing_dense
from repro.core.semiring import pack32
from repro.kernels import ops, ref


def _random_dense(n, m, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    a = np.full((n, n), np.inf, dtype)
    u, v = rng.integers(0, n, m), rng.integers(0, n, m)
    w = rng.integers(1, 256, m).astype(dtype)
    a[u, v] = np.minimum(a[u, v], w)
    np.fill_diagonal(a, np.inf)
    p = rng.integers(0, max(1, n // 3), n).astype(np.int32)
    return p, a


@pytest.mark.parametrize("n", [8, 100, 128, 257, 384])
@pytest.mark.parametrize("blocks", [(8, 128), (128, 128), (64, 256)])
def test_multilinear_dense_kernel_sweep(n, blocks):
    p, a = _random_dense(n, 4 * n, seed=n)
    bi, bj = blocks
    got = ops.multilinear_dense(jnp.array(p), jnp.array(a), block_i=bi, block_j=bj)
    want = ref.multilinear_dense_ref(jnp.array(p), jnp.array(a))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_multilinear_dense_kernel_vs_core():
    """Kernel output == the core library's dense multilinear (EdgeMin)."""
    p, a = _random_dense(96, 300, seed=5)
    minw, mincol, minpay = ops.multilinear_dense(jnp.array(p), jnp.array(a))
    em = min_outgoing_dense(jnp.array(p), jnp.array(a))
    np.testing.assert_array_equal(np.asarray(minw), np.asarray(em.w))
    np.testing.assert_array_equal(np.asarray(mincol), np.asarray(em.eid))
    np.testing.assert_array_equal(np.asarray(minpay), np.asarray(em.payload[0]))


@pytest.mark.parametrize("n,e", [(128, 0), (128, 500), (300, 2000), (1024, 10000)])
def test_segment_min_bucketed_sweep(n, e):
    rng = np.random.default_rng(e + n)
    seg = rng.integers(0, n, e)
    keys = np.asarray(
        pack32(jnp.array(rng.integers(1, 256, e)), jnp.array(rng.integers(0, 1 << 20, e)))
    ).astype(np.uint32)
    kb, rb = ops.bucket_edges_by_row_block(seg, keys, n, 128)
    got = np.asarray(ops.segment_min_bucketed(jnp.array(kb), jnp.array(rb)))
    want = np.asarray(ref.segment_min_bucketed_ref(jnp.array(kb), jnp.array(rb), 128))
    np.testing.assert_array_equal(got, want)
    direct = np.full(kb.shape[0] * 128, 0xFFFFFFFF, np.uint64)
    if e:
        np.minimum.at(direct, seg, keys.astype(np.uint64))
    np.testing.assert_array_equal(got.astype(np.uint64), direct)


def test_kernel_full_msf_hook_step():
    """One hooking step computed by the Pallas kernel agrees with the COO
    path used by the MSF driver."""
    from repro.core.multilinear import min_outgoing_coo
    from repro.graphs import random_graph

    g = random_graph(64, 200, seed=9)
    p = jnp.arange(64, dtype=jnp.int32)
    em = min_outgoing_coo(p, g.src, g.dst, g.w, g.eid, g.valid, 64, segment="vertex")
    # dense adjacency with the same tie-breaking: eid == column order differs,
    # so compare weights only (argmin weight is unique per (w, col) lex on
    # distinct (w, eid) inputs when weights are distinct per row pair)
    a = np.full((64, 64), np.inf, np.float32)
    src, dst, w = np.asarray(g.src), np.asarray(g.dst), np.asarray(g.w)
    for s, d, ww in zip(src, dst, w):
        a[s, d] = min(a[s, d], ww)
    minw, _, _ = ops.multilinear_dense(p, jnp.array(a))
    np.testing.assert_allclose(np.asarray(minw), np.asarray(em.w))
