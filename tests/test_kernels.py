"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
+ cross-check against the core (non-Pallas) implementation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.multilinear import min_outgoing_dense
from repro.core.semiring import pack32
from repro.kernels import ops, ref


def _random_dense(n, m, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    a = np.full((n, n), np.inf, dtype)
    u, v = rng.integers(0, n, m), rng.integers(0, n, m)
    w = rng.integers(1, 256, m).astype(dtype)
    a[u, v] = np.minimum(a[u, v], w)
    np.fill_diagonal(a, np.inf)
    p = rng.integers(0, max(1, n // 3), n).astype(np.int32)
    return p, a


@pytest.mark.parametrize("n", [8, 100, 128, 257, 384])
@pytest.mark.parametrize("blocks", [(8, 128), (128, 128), (64, 256)])
def test_multilinear_dense_kernel_sweep(n, blocks):
    p, a = _random_dense(n, 4 * n, seed=n)
    bi, bj = blocks
    got = ops.multilinear_dense(jnp.array(p), jnp.array(a), block_i=bi, block_j=bj)
    want = ref.multilinear_dense_ref(jnp.array(p), jnp.array(a))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_multilinear_dense_kernel_vs_core():
    """Kernel output == the core library's dense multilinear (EdgeMin)."""
    p, a = _random_dense(96, 300, seed=5)
    minw, mincol, minpay = ops.multilinear_dense(jnp.array(p), jnp.array(a))
    em = min_outgoing_dense(jnp.array(p), jnp.array(a))
    np.testing.assert_array_equal(np.asarray(minw), np.asarray(em.w))
    np.testing.assert_array_equal(np.asarray(mincol), np.asarray(em.eid))
    np.testing.assert_array_equal(np.asarray(minpay), np.asarray(em.payload[0]))


@pytest.mark.parametrize("n,e", [(128, 0), (128, 500), (300, 2000), (1024, 10000)])
def test_segment_min_bucketed_sweep(n, e):
    rng = np.random.default_rng(e + n)
    seg = rng.integers(0, n, e)
    keys = np.asarray(
        pack32(jnp.array(rng.integers(1, 256, e)), jnp.array(rng.integers(0, 1 << 20, e)))
    ).astype(np.uint32)
    kb, rb = ops.bucket_edges_by_row_block(seg, keys, n, 128)
    got = np.asarray(ops.segment_min_bucketed(jnp.array(kb), jnp.array(rb)))
    want = np.asarray(ref.segment_min_bucketed_ref(jnp.array(kb), jnp.array(rb), 128))
    np.testing.assert_array_equal(got, want)
    direct = np.full(kb.shape[0] * 128, 0xFFFFFFFF, np.uint64)
    if e:
        np.minimum.at(direct, seg, keys.astype(np.uint64))
    np.testing.assert_array_equal(got.astype(np.uint64), direct)


@pytest.mark.parametrize("n_seg,e", [(64, 0), (128, 500), (300, 2000), (37, 129)])
def test_segment_min_flat_sweep(n_seg, e):
    """Flat-layout kernel vs the pure-jnp oracle on arbitrary (unsorted)
    segment ids and non-multiple shapes (wrapper pads both dims)."""
    rng = np.random.default_rng(e + n_seg)
    seg = rng.integers(0, n_seg, e).astype(np.int32)
    keys = np.asarray(
        pack32(jnp.array(rng.integers(1, 256, e)), jnp.array(rng.integers(0, 1 << 20, e)))
    ).astype(np.uint32)
    got = np.asarray(
        ops.segment_min_flat(jnp.array(keys), jnp.array(seg), num_segments=n_seg)
    )
    want = np.asarray(ref.segment_min_flat_ref(jnp.array(keys), jnp.array(seg), n_seg))
    np.testing.assert_array_equal(got, want)
    direct = np.full(n_seg, 0xFFFFFFFF, np.uint64)
    if e:
        np.minimum.at(direct, seg, keys.astype(np.uint64))
    np.testing.assert_array_equal(got.astype(np.uint64), direct)


def test_segment_min_sorted_segments_matches():
    """The coarsening dedupe feeds *sorted* segment ids — same result."""
    rng = np.random.default_rng(3)
    e, n_seg = 700, 256
    seg = np.sort(rng.integers(0, n_seg, e)).astype(np.int32)
    keys = rng.integers(0, 1 << 32, e, dtype=np.uint64).astype(np.uint32)
    got = np.asarray(
        ops.segment_min_flat(jnp.array(keys), jnp.array(seg), num_segments=n_seg)
    )
    want = np.asarray(ref.segment_min_flat_ref(jnp.array(keys), jnp.array(seg), n_seg))
    np.testing.assert_array_equal(got, want)


def test_segment_min_kernel_validation():
    """Satellite: mis-shaped inputs raise loud ValueErrors instead of
    producing silently wrong output shapes."""
    from repro.kernels.segment_min_bucketed import (
        segment_min_bucketed_pallas,
        segment_min_flat_pallas,
    )

    ku = jnp.zeros((2, 128), jnp.uint32)
    ri = jnp.zeros((2, 128), jnp.int32)
    with pytest.raises(ValueError, match="shape mismatch"):
        segment_min_bucketed_pallas(ku, jnp.zeros((2, 256), jnp.int32))
    with pytest.raises(ValueError, match="uint32"):
        segment_min_bucketed_pallas(ku.astype(jnp.int32), ri)
    with pytest.raises(ValueError, match="int32"):
        segment_min_bucketed_pallas(ku, ri.astype(jnp.uint32))
    with pytest.raises(ValueError, match="multiple of 8"):
        segment_min_bucketed_pallas(ku, ri, block_rows=100)
    with pytest.raises(ValueError, match="empty bucket"):
        segment_min_bucketed_pallas(
            jnp.zeros((0, 128), jnp.uint32), jnp.zeros((0, 128), jnp.int32)
        )
    with pytest.raises(ValueError, match="multiple of 128 lanes"):
        segment_min_bucketed_pallas(
            jnp.zeros((2, 100), jnp.uint32), jnp.zeros((2, 100), jnp.int32)
        )
    kf = jnp.zeros((512,), jnp.uint32)
    sf = jnp.zeros((512,), jnp.int32)
    with pytest.raises(ValueError, match="flat"):
        segment_min_flat_pallas(ku, ri, num_segments=128)
    with pytest.raises(ValueError, match="multiple of block_edges"):
        segment_min_flat_pallas(kf[:100], sf[:100], num_segments=128)
    with pytest.raises(ValueError, match="num_segments"):
        segment_min_flat_pallas(kf, sf, num_segments=100)
    with pytest.raises(ValueError, match="empty edge array"):
        segment_min_flat_pallas(kf[:0], sf[:0], num_segments=128)


def test_make_packed_segmin_backends_agree_and_cache():
    from repro.kernels.ops import make_packed_segmin

    rng = np.random.default_rng(5)
    keys = jnp.array(rng.integers(0, 1 << 32, 300, dtype=np.uint64).astype(np.uint32))
    seg = jnp.array(rng.integers(0, 50, 300).astype(np.int32))
    a = make_packed_segmin("jnp")(keys, seg, 50)
    b = make_packed_segmin("pallas")(keys, seg, 50)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # identity-stable for jit-static reuse
    assert make_packed_segmin("pallas") is make_packed_segmin("pallas")
    with pytest.raises(ValueError):
        make_packed_segmin("cuda")


def test_kernel_full_msf_hook_step():
    """One hooking step computed by the Pallas kernel agrees with the COO
    path used by the MSF driver."""
    from repro.core.multilinear import min_outgoing_coo
    from repro.graphs import random_graph

    g = random_graph(64, 200, seed=9)
    p = jnp.arange(64, dtype=jnp.int32)
    em = min_outgoing_coo(p, g.src, g.dst, g.w, g.eid, g.valid, 64, segment="vertex")
    # dense adjacency with the same tie-breaking: eid == column order differs,
    # so compare weights only (argmin weight is unique per (w, col) lex on
    # distinct (w, eid) inputs when weights are distinct per row pair)
    a = np.full((64, 64), np.inf, np.float32)
    src, dst, w = np.asarray(g.src), np.asarray(g.dst), np.asarray(g.w)
    for s, d, ww in zip(src, dst, w):
        a[s, d] = min(a[s, d], ww)
    minw, _, _ = ops.multilinear_dense(p, jnp.array(a))
    np.testing.assert_allclose(np.asarray(minw), np.asarray(em.w))


# ---------------------------------------------------------------------------
# sorted-segment kernel (scalar-prefetched contiguous ranges)
# ---------------------------------------------------------------------------


def _sorted_case(e, n_seg, seg):
    """Run the sorted kernel (interpret mode on CPU) against the oracle and
    a direct numpy scatter-min."""
    rng = np.random.default_rng(e * 7 + n_seg)
    keys = rng.integers(0, 1 << 32, e, dtype=np.uint64).astype(np.uint32)
    got = np.asarray(
        ops.segment_min_sorted(jnp.array(keys), jnp.array(seg), num_segments=n_seg)
    )
    want = np.asarray(
        ref.segment_min_sorted_ref(jnp.array(keys), jnp.array(seg), n_seg)
    )
    np.testing.assert_array_equal(got, want)
    direct = np.full(n_seg, 0xFFFFFFFF, np.uint64)
    if e:
        np.minimum.at(direct, seg, keys.astype(np.uint64))
    np.testing.assert_array_equal(got.astype(np.uint64), direct)


def test_segment_min_sorted_single_segment():
    """Every edge in one segment — one row block, all edge blocks walked."""
    e = 1500  # spans 3 × 512-lane edge blocks
    _sorted_case(e, 1, np.zeros(e, np.int32))
    _sorted_case(e, 64, np.full(e, 63, np.int32))  # last segment only


def test_segment_min_sorted_all_singletons():
    """seg = arange: segment count == edge count (the dedupe's worst case —
    exactly the num_segments = E shape the flat kernel rescans on)."""
    for e in [128, 513, 2048]:
        _sorted_case(e, e, np.arange(e, dtype=np.int32))


def test_segment_min_sorted_segment_spanning_blocks():
    """One giant segment straddles several 512-lane edge blocks between
    ordinary neighbors — exercises the per-row-block block-range walk."""
    e = 4 * 512
    seg = np.concatenate(
        [np.zeros(100), np.full(1500, 1), np.full(e - 1600, 2)]
    ).astype(np.int32)
    _sorted_case(e, 384, seg)


def test_segment_min_sorted_non_lane_multiple_tails():
    """Edge counts and segment counts that are NOT multiples of the lane /
    sublane tiles — the wrapper pads both dims and slices back."""
    rng = np.random.default_rng(5)
    for e, n_seg in [(1, 1), (129, 37), (513, 130), (1000, 999), (777, 5)]:
        seg = np.sort(rng.integers(0, n_seg, e)).astype(np.int32)
        _sorted_case(e, n_seg, seg)


def test_segment_min_sorted_empty_segments_and_gaps():
    """Row blocks with no segments at all must still initialize to the
    identity (first-touch init steps), including trailing empty blocks."""
    e = 600
    rng = np.random.default_rng(9)
    # occupy only segments [256, 300): blocks 0, 1 and 2.3+ stay empty
    seg = np.sort(rng.integers(256, 300, e)).astype(np.int32)
    _sorted_case(e, 1024, seg)
    _sorted_case(0, 256, np.zeros(0, np.int32))  # fully empty input


def test_segment_min_sorted_random_sweep():
    rng = np.random.default_rng(11)
    for e, n_seg in [(500, 128), (2000, 300), (4096, 4096)]:
        seg = np.sort(rng.integers(0, n_seg, e)).astype(np.int32)
        _sorted_case(e, n_seg, seg)


def test_segment_min_sorted_straddle_three_plus_blocks():
    """Satellite: single segments spanning ≥ 3 full 512-lane edge blocks
    (with ordinary neighbors on both sides) — the per-row-block
    block-range walk must min-accumulate across every straddled block."""
    be = 512
    for span in (3 * be + 1, 4 * be, 5 * be + 137):
        e = span + 300
        seg = np.concatenate(
            [np.zeros(150), np.full(span, 1), np.full(e - span - 150, 2)]
        ).astype(np.int32)
        _sorted_case(e, 64, seg)


def test_segment_min_sorted_all_empty_row_blocks():
    """Row blocks with zero segments before, between, and after the
    occupied band — all must first-touch-init to the identity."""
    rng = np.random.default_rng(17)
    e = 400
    # band confined to segments [520, 560): row blocks 0-3 and 5+ empty
    seg = np.sort(rng.integers(520, 560, e)).astype(np.int32)
    _sorted_case(e, 2048, seg)
    # two disjoint bands with an empty gap of whole row blocks between
    seg = np.sort(
        np.concatenate(
            [rng.integers(0, 8, 200), rng.integers(1500, 1530, 200)]
        )
    ).astype(np.int32)
    _sorted_case(400, 2048, seg)


def test_segment_min_sorted_max_lane_tails():
    """Edge counts at the padding extremes: exactly full blocks (no pad),
    one short of a block (511 pad lanes), one past a block (e_pad − e =
    block − 1) — and segment counts at the sublane-tile boundaries."""
    rng = np.random.default_rng(19)
    for e in (512, 1024, 511, 513, 1023, 1025):
        for n_seg in (127, 128, 129):
            seg = np.sort(rng.integers(0, n_seg, e)).astype(np.int32)
            _sorted_case(e, n_seg, seg)


def test_segment_min_sorted_fuzz_adversarial():
    """Fuzz cross-check against the oracle on randomized adversarial
    layouts: run-length constructed segment ids mixing singleton runs,
    multi-block giants, and empty-band jumps, at random non-aligned edge
    and segment counts."""
    rng = np.random.default_rng(2024)
    for _ in range(12):
        n_seg = int(rng.integers(1, 1400))
        runs, cur, total = [], 0, 0
        while total < int(rng.integers(200, 1800)) and cur < n_seg:
            kind = rng.random()
            if kind < 0.15:  # giant run straddling blocks
                ln = int(rng.integers(512, 1300))
            elif kind < 0.5:  # singleton
                ln = 1
            else:
                ln = int(rng.integers(1, 40))
            runs.append(np.full(ln, cur, np.int32))
            total += ln
            # occasional jump leaves whole row blocks empty
            cur += int(rng.integers(1, 300)) if rng.random() < 0.2 else int(
                rng.integers(1, 4)
            )
        seg = np.concatenate(runs) if runs else np.zeros(0, np.int32)
        seg = np.minimum(seg, n_seg - 1)
        _sorted_case(len(seg), n_seg, seg)


def test_segment_min_sorted_backend_resolution():
    """make_packed_segmin('sorted') routes through the sorted kernel and is
    cached (same callable per backend — jit-static identity)."""
    fn = ops.make_packed_segmin("sorted")
    assert fn is ops.make_packed_segmin("sorted")
    assert fn is not ops.make_packed_segmin("jnp")
    rng = np.random.default_rng(13)
    e, n_seg = 700, 301
    seg = np.sort(rng.integers(0, n_seg, e)).astype(np.int32)
    keys = rng.integers(0, 1 << 32, e, dtype=np.uint64).astype(np.uint32)
    got = np.asarray(fn(jnp.array(keys), jnp.array(seg), n_seg))
    want = np.asarray(
        ref.segment_min_sorted_ref(jnp.array(keys), jnp.array(seg), n_seg)
    )
    np.testing.assert_array_equal(got, want)


def test_segment_min_sorted_validation():
    from repro.kernels.segment_min_sorted import segment_min_sorted_pallas

    kf = jnp.zeros((512,), jnp.uint32)
    sf = jnp.zeros((512,), jnp.int32)
    with pytest.raises(ValueError, match="flat"):
        segment_min_sorted_pallas(
            jnp.zeros((2, 128), jnp.uint32), jnp.zeros((2, 128), jnp.int32),
            num_segments=128,
        )
    with pytest.raises(ValueError, match="multiple of block_edges"):
        segment_min_sorted_pallas(kf[:100], sf[:100], num_segments=128)
    with pytest.raises(ValueError, match="num_segments"):
        segment_min_sorted_pallas(kf, sf, num_segments=100)
    with pytest.raises(ValueError, match="empty edge array"):
        segment_min_sorted_pallas(kf[:0], sf[:0], num_segments=128)
