"""Tier-1 wrapper around the deprecated-entry-point lint (CI also runs
``tools/check_deprecated_calls.py`` as a standalone build gate): no
``src/`` module outside the shims may call ``msf`` / ``msf_weight`` /
``msf_distributed`` / ``StreamingMSF`` / ``coarsen_msf`` — internal code
routes through ``repro.solve`` so the shims stay thin and internal calls
never trip the DeprecationWarning."""
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent


def test_src_free_of_deprecated_entry_point_calls():
    sys.path.insert(0, str(_ROOT / "tools"))
    try:
        from check_deprecated_calls import check
    finally:
        sys.path.pop(0)
    violations = check(_ROOT)
    assert not violations, "\n".join(violations)
