"""End-to-end driver for the paper's system: distributed MSF on an R-MAT
graph with millions of edges, on a real (host-device) mesh, with the Fig-2
communication schedule — verified against the scipy oracle. Every solve
goes through the unified ``repro.solve`` API.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/msf_at_scale.py
"""
import time

import jax

n_dev = jax.device_count()
rows = 2 if n_dev >= 8 else 1
cols = n_dev // rows

from repro.graphs import rmat_graph  # noqa: E402
from repro.graphs.partition import partition_edges_2d  # noqa: E402
from repro.graphs.structures import nx_free_msf_weight  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.solve import SolveSpec, plan  # noqa: E402

SCALE, EDGE_FACTOR = 16, 16  # ~1M directed edges; raise on bigger hosts
print(f"devices={n_dev}, mesh=({rows},{cols})")
g = rmat_graph(SCALE, EDGE_FACTOR, seed=0)
print(f"graph: n={g.n} directed_edges={g.num_directed_edges}")

mesh = make_mesh((rows, cols), ("data", "model"))
part = partition_edges_2d(g, rows, cols)
print(f"2D partition: {part.rows}x{part.cols} blocks, E_max/device={part.e_max}")

for shortcut in ("csp", "baseline"):
    p = plan(
        part,
        SolveSpec(mode="dist", shortcut=shortcut, capacity=1 << 16),
        mesh=mesh,
    )
    r = p.solve()  # compile + run
    t0 = time.perf_counter()
    r = p.solve()
    dt = time.perf_counter() - t0
    print(f"[{shortcut:8s}] weight={r.weight:.0f} iters={r.iterations} "
          f"time={dt*1e3:.0f}ms ({g.num_directed_edges/dt/1e6:.1f} Medges/s)")

oracle = nx_free_msf_weight(g)
print(f"oracle={oracle:.0f} -> {'MATCH' if abs(oracle - r.weight) < 1e-3 else 'MISMATCH'}")

# single-device reference path for comparison
t0 = time.perf_counter()
r1 = plan(g, SolveSpec()).solve()
print(f"[single  ] weight={r1.weight:.0f} iters={r1.iterations} "
      f"time={(time.perf_counter()-t0)*1e3:.0f}ms (incl. compile)")
