"""Quickstart: the paper's algorithm through the unified solver API.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import connected_components
from repro.graphs import rmat_graph
from repro.graphs.structures import nx_free_msf_weight
from repro.solve import SolveSpec, plan

# An R-MAT graph with integer weights 1..255 (the paper's §VII setup).
g = rmat_graph(scale=12, edge_factor=8, seed=0)

# A SolveSpec is a frozen description of *which* engine and *how*;
# plan() compiles it against the graph (cached per spec + shapes).
result = plan(g, SolveSpec()).solve()  # algebraic Awerbuch-Shiloach
print(f"graph: n={g.n}, undirected edges={g.num_directed_edges // 2}")
print(f"MSF weight      : {result.weight:.0f}")
print(f"scipy oracle    : {nx_free_msf_weight(g):.0f}")
print(f"AS iterations   : {result.iterations}")
print(f"MSF edges       : {result.n_msf_edges}")

cc = connected_components(g)
print(f"components      : {int(cc.n_components)} (CC baseline, §II-D)")

# the three shortcut strategies from §IV-B produce identical forests
for strategy in ("complete", "csp", "os"):
    r = plan(g, SolveSpec(shortcut=strategy)).solve()
    assert abs(r.weight - result.weight) < 1e-3
print("shortcut strategies agree: complete == csp == os")

# the coarsening engine (Borůvka contract-and-filter levels, DESIGN.md §7)
# is one spec field away — same forest, geometrically smaller levels
r = plan(g, SolveSpec(mode="coarsen", fused=True)).solve()
assert abs(r.weight - result.weight) < 1e-3
print(f"coarsen levels  : {len(r.levels)} "
      f"({'|'.join(str(l.n) + '>' + str(l.n_next) for l in r.levels)})")
