"""Quickstart: the paper's algorithm in five lines of public API.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import connected_components, msf
from repro.graphs import rmat_graph
from repro.graphs.structures import nx_free_msf_weight

# An R-MAT graph with integer weights 1..255 (the paper's §VII setup).
g = rmat_graph(scale=12, edge_factor=8, seed=0)

result = msf(g)  # algebraic Awerbuch-Shiloach, complete shortcutting
print(f"graph: n={g.n}, undirected edges={g.num_directed_edges // 2}")
print(f"MSF weight      : {float(result.weight):.0f}")
print(f"scipy oracle    : {nx_free_msf_weight(g):.0f}")
print(f"AS iterations   : {int(result.iterations)}")
print(f"MSF edges       : {int(result.n_msf_edges)}")

cc = connected_components(g)
print(f"components      : {int(cc.n_components)} (CC baseline, §II-D)")

# the three shortcut strategies from §IV-B produce identical forests
for strategy in ("complete", "csp", "os"):
    r = msf(g, shortcut=strategy)
    assert abs(float(r.weight) - float(result.weight)) < 1e-3
print("shortcut strategies agree: complete == csp == os")
