"""Train GAT on a planted node-classification task (Cora-shaped) until the
accuracy beats the feature-only baseline — exercises the shared
message-passing substrate (the paper's multilinear form with ⊕ = softmax-
weighted sum).

  PYTHONPATH=src python examples/train_gnn.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data.pipeline import make_planted_graph_task
from repro.models import gnn as G
from repro.optim.adamw import adamw_init, adamw_update
from repro.train import steps as S

cfg = dataclasses.replace(
    registry.get_config("gat-cora", smoke=True), d_in=32, n_classes=4,
    d_hidden=16, n_heads=4,
)
task = make_planted_graph_task(n=400, m=2000, d_feat=32, n_classes=4, seed=0)
batch = dict(
    x=jnp.asarray(task["x"]),
    src=jnp.asarray(task["src"]),
    dst=jnp.asarray(task["dst"]),
    edge_valid=jnp.asarray(task["edge_valid"]),
    labels=jnp.asarray(task["labels"]),
    node_mask=jnp.ones(400, jnp.float32),
)
params = G.init_gat(jax.random.key(0), cfg)
opt = adamw_init(params)


@jax.jit
def step(params, opt, batch):
    loss, grads = jax.value_and_grad(S.gnn_loss)(params, batch, cfg, 1)
    params, opt, _ = adamw_update(grads, opt, params, jnp.float32(5e-3))
    return params, opt, loss


def acc(params):
    logits = S.gnn_apply(params, batch, cfg, 1)
    return float((jnp.argmax(logits, -1) == batch["labels"]).mean())


print(f"initial accuracy: {acc(params):.3f} (chance = 0.25)")
for i in range(300):
    params, opt, loss = step(params, opt, batch)
    if i % 50 == 0:
        print(f"step {i:4d} loss {float(loss):.4f} acc {acc(params):.3f}")
final = acc(params)
print(f"final accuracy: {final:.3f}")
assert final > 0.6, "GAT failed to learn the planted neighborhood structure"
