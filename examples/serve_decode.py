"""Batched serving (prefill + greedy decode with KV cache) — thin wrapper
over the production serving path.

  PYTHONPATH=src python examples/serve_decode.py
"""
import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve",
     "--arch", "mixtral-8x7b", "--batch", "4", "--prompt-len", "32",
     "--tokens", "12"],
    check=True,
)
