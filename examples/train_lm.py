"""Train a small LM end-to-end (synthetic Markov data, loss decreases),
with checkpointing — thin wrapper over the production launcher.

  PYTHONPATH=src python examples/train_lm.py
"""
import types

from repro.launch.train import run

out = run(types.SimpleNamespace(
    arch="qwen2-7b", steps=100, seed=0,
    ckpt_dir="/tmp/repro_lm_ckpt", ckpt_every=25,
    fault_at=None, supervise=False,
))
assert out["last_loss"] < out["first_loss"], out
print("LM training reduced loss:", out)
