"""GNN architectures on the shared sparse substrate (DESIGN.md §4).

Message passing = the paper's multilinear form ``⊕_j f(x_i, a_ij, x_j)``:
edge-wise ``f`` + ``segment_*`` reduction, the same machinery the MSF
engine uses (``jax.ops.segment_sum`` over an edge index — JAX has no
CSR/CSC, so this scatter-based formulation IS the system's sparse layer).

Models: GAT (SDDMM → edge-softmax → SpMM), MeshGraphNet (edge-MLP MPNN),
GatedGCN (gated aggregation), NequIP (E(3) tensor-product interactions via
``repro.models.o3``).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.models.o3 import bessel_basis_np, clebsch_gordan, sph_harm_np, tp_paths

NEG_INF = jnp.float32(-jnp.inf)


def _mlp_init(rng, sizes, name, params, ln=True):
    keys = jax.random.split(rng, len(sizes))
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        params[f"{name}_w{i}"] = jax.random.normal(keys[i], (a, b)) * math.sqrt(2.0 / a)
        params[f"{name}_b{i}"] = jnp.zeros((b,))
    if ln:
        params[f"{name}_ln"] = jnp.ones((sizes[-1],))


def _mlp_apply(params, name, x, n_layers, ln=True, act=jax.nn.relu):
    for i in range(n_layers):
        x = x @ params[f"{name}_w{i}"] + params[f"{name}_b{i}"]
        if i < n_layers - 1:
            x = act(x)
    if ln:
        mu = x.mean(-1, keepdims=True)
        sd = jnp.sqrt(((x - mu) ** 2).mean(-1, keepdims=True) + 1e-6)
        x = (x - mu) / sd * params[f"{name}_ln"]
    return x


def _edge_softmax(scores, dst, n, edge_valid):
    """Numerically-stable softmax over incoming edges per destination."""
    scores = jnp.where(edge_valid[:, None], scores, NEG_INF)
    mx = jax.ops.segment_max(scores, dst, num_segments=n)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.where(edge_valid[:, None], jnp.exp(scores - mx[dst]), 0.0)
    denom = jax.ops.segment_sum(ex, dst, num_segments=n)
    return ex / jnp.maximum(denom[dst], 1e-9)


# ---------------------------------------------------------------------------
# GAT
# ---------------------------------------------------------------------------

def init_gat(rng, cfg: GNNConfig) -> Dict[str, Any]:
    h, heads = cfg.d_hidden, cfg.n_heads
    dims = [cfg.d_in] + [h * heads] * (cfg.n_layers - 1) + [cfg.n_classes]
    params: Dict[str, Any] = {}
    keys = jax.random.split(rng, 3 * cfg.n_layers)
    for i in range(cfg.n_layers):
        d_in = dims[i]
        d_out = h if i < cfg.n_layers - 1 else cfg.n_classes
        params[f"w{i}"] = jax.random.normal(keys[3 * i], (d_in, heads, d_out)) * math.sqrt(
            2.0 / d_in
        )
        params[f"a_src{i}"] = jax.random.normal(keys[3 * i + 1], (heads, d_out)) * 0.1
        params[f"a_dst{i}"] = jax.random.normal(keys[3 * i + 2], (heads, d_out)) * 0.1
    return params


def apply_gat(params, x, src, dst, edge_valid, cfg: GNNConfig):
    n = x.shape[0]
    for i in range(cfg.n_layers):
        h = jnp.einsum("nd,dhk->nhk", x, params[f"w{i}"])  # [N, H, K]
        s_src = (h * params[f"a_src{i}"][None]).sum(-1)  # [N, H]
        s_dst = (h * params[f"a_dst{i}"][None]).sum(-1)
        e = jax.nn.leaky_relu(s_src[src] + s_dst[dst], 0.2)  # [E, H]
        alpha = _edge_softmax(e, dst, n, edge_valid)
        msg = alpha[..., None] * h[src]  # [E, H, K]
        agg = jax.ops.segment_sum(msg, dst, num_segments=n)
        if i < cfg.n_layers - 1:
            x = jax.nn.elu(agg.reshape(n, -1))
        else:
            x = agg.mean(axis=1)  # average heads for the output layer
    return x  # [N, n_classes]


# ---------------------------------------------------------------------------
# MeshGraphNet
# ---------------------------------------------------------------------------

def init_meshgraphnet(rng, cfg: GNNConfig, d_edge_in: int = 4) -> Dict[str, Any]:
    h = cfg.d_hidden
    params: Dict[str, Any] = {}
    keys = jax.random.split(rng, 2 * cfg.n_layers + 3)
    _mlp_init(keys[0], [cfg.d_in, h, h], "enc_node", params)
    _mlp_init(keys[1], [d_edge_in, h, h], "enc_edge", params)
    for i in range(cfg.n_layers):
        _mlp_init(keys[2 + 2 * i], [3 * h, h, h], f"edge{i}", params)
        _mlp_init(keys[3 + 2 * i], [2 * h, h, h], f"node{i}", params)
    _mlp_init(keys[-1], [h, h, cfg.d_out], "dec", params, ln=False)
    return params


def apply_meshgraphnet(params, x, e_feat, src, dst, edge_valid, cfg: GNNConfig):
    n = x.shape[0]
    h = _mlp_apply(params, "enc_node", x, 2)
    e = _mlp_apply(params, "enc_edge", e_feat, 2)
    ev = edge_valid[:, None]
    for i in range(cfg.n_layers):
        e_in = jnp.concatenate([e, h[src], h[dst]], axis=-1)
        e = e + _mlp_apply(params, f"edge{i}", e_in, 2)
        agg = jax.ops.segment_sum(jnp.where(ev, e, 0.0), dst, num_segments=n)
        h = h + _mlp_apply(params, f"node{i}", jnp.concatenate([h, agg], -1), 2)
    return _mlp_apply(params, "dec", h, 2, ln=False)


# ---------------------------------------------------------------------------
# GatedGCN
# ---------------------------------------------------------------------------

def init_gatedgcn(rng, cfg: GNNConfig) -> Dict[str, Any]:
    h = cfg.d_hidden
    params: Dict[str, Any] = {}
    keys = jax.random.split(rng, 6 * cfg.n_layers + 3)
    params["embed_node"] = jax.random.normal(keys[0], (cfg.d_in, h)) * math.sqrt(1.0 / cfg.d_in)
    params["embed_edge"] = jax.random.normal(keys[1], (1, h)) * 0.1
    for i in range(cfg.n_layers):
        for j, nm in enumerate(["A1", "A2", "A3", "U", "V"]):
            params[f"{nm}{i}"] = jax.random.normal(
                keys[2 + 6 * i + j], (h, h)
            ) * math.sqrt(1.0 / h)
        params[f"ln_h{i}"] = jnp.ones((h,))
        params[f"ln_e{i}"] = jnp.ones((h,))
    params["out_w"] = jax.random.normal(keys[-1], (h, cfg.n_classes)) * math.sqrt(1.0 / h)
    params["out_b"] = jnp.zeros((cfg.n_classes,))
    return params


def _ln(x, g):
    mu = x.mean(-1, keepdims=True)
    sd = jnp.sqrt(((x - mu) ** 2).mean(-1, keepdims=True) + 1e-6)
    return (x - mu) / sd * g


def apply_gatedgcn(params, x, e_feat, src, dst, edge_valid, cfg: GNNConfig):
    n = x.shape[0]
    h = x @ params["embed_node"]
    e = e_feat @ params["embed_edge"]
    ev = edge_valid[:, None]
    for i in range(cfg.n_layers):
        e_new = h[src] @ params[f"A1{i}"] + h[dst] @ params[f"A2{i}"] + e @ params[f"A3{i}"]
        eta = jax.nn.sigmoid(e_new)
        msg = jnp.where(ev, eta * (h[src] @ params[f"V{i}"]), 0.0)
        num = jax.ops.segment_sum(msg, dst, num_segments=n)
        den = jax.ops.segment_sum(jnp.where(ev, eta, 0.0), dst, num_segments=n)
        h = h + jax.nn.relu(_ln(h @ params[f"U{i}"] + num / (den + 1e-6), params[f"ln_h{i}"]))
        e = e + jax.nn.relu(_ln(e_new, params[f"ln_e{i}"]))
    return h @ params["out_w"] + params["out_b"]


# ---------------------------------------------------------------------------
# NequIP (simplified; structurally faithful TP interactions, see o3.py)
# ---------------------------------------------------------------------------

def _nequip_paths(l_max):
    return tp_paths(l_max)


def init_nequip(rng, cfg: GNNConfig, n_species: int = 4) -> Dict[str, Any]:
    mul, lm = cfg.d_hidden, cfg.l_max
    paths = _nequip_paths(lm)
    params: Dict[str, Any] = {"species_embed": jax.random.normal(rng, (n_species, mul)) * 0.5}
    keys = jax.random.split(rng, 4 * cfg.n_layers + 2)
    for i in range(cfg.n_layers):
        # radial MLP: n_rbf -> mul weights per TP path
        _mlp_init(keys[4 * i], [cfg.n_rbf, 32, len(paths) * mul], f"radial{i}", params, ln=False)
        for l in range(lm + 1):
            params[f"self{i}_l{l}"] = jax.random.normal(
                keys[4 * i + 1 + (l % 3)], (mul, mul)
            ) * math.sqrt(1.0 / mul)
        params[f"gate{i}"] = jax.random.normal(keys[4 * i + 2], (mul, lm * mul)) * 0.1
    _mlp_init(keys[-1], [mul, 16, 1], "readout", params, ln=False)
    return params


def apply_nequip(params, species, pos, src, dst, edge_valid, graph_ids, n_graphs, cfg: GNNConfig):
    """species int32 [N]; pos f32 [N, 3]; returns per-graph energy [G]."""
    n = species.shape[0]
    mul, lm = cfg.d_hidden, cfg.l_max
    paths = _nequip_paths(lm)
    basis = bessel_basis_np(cfg.n_rbf, cfg.cutoff)

    rel = pos[dst] - pos[src]  # [E, 3]
    # safe norm: sqrt(max(|x|², ε²)) keeps the gradient finite at rel = 0
    # (padded edges) — plain norm() has a NaN vjp there.
    r = jnp.sqrt(jnp.maximum(jnp.sum(rel * rel, axis=-1), 1e-18))
    rbf = basis(r) * edge_valid[:, None]
    # spherical harmonics of edge directions (jnp mirror of o3.sph_harm_np)
    sh = {l: _sph_harm_jnp(rel, l) for l in range(lm + 1)}
    cgs = {p: jnp.asarray(clebsch_gordan(*p), jnp.float32) for p in paths}

    feats = {0: jnp.take(params["species_embed"], species, axis=0, mode='clip')[..., None]}
    for l in range(1, lm + 1):
        feats[l] = jnp.zeros((n, mul, 2 * l + 1))

    for i in range(cfg.n_layers):
        w_all = _mlp_apply(params, f"radial{i}", rbf, 2, ln=False)  # [E, P*mul]
        w_all = w_all.reshape(-1, len(paths), mul)
        msgs = {l: 0.0 for l in range(lm + 1)}
        for pi, (l1, l2, l3) in enumerate(paths):
            hj = feats[l1][src]  # [E, mul, 2l1+1]
            y = sh[l2]  # [E, 2l2+1]
            w = w_all[:, pi, :] * edge_valid[:, None]  # [E, mul]
            m = jnp.einsum("pqr,emq,er,em->emp", cgs[(l1, l2, l3)], hj, y, w)
            msgs[l3] = msgs[l3] + m
        new = {}
        for l in range(lm + 1):
            agg = jax.ops.segment_sum(msgs[l], dst, num_segments=n)
            mixed = jnp.einsum("nmp,mk->nkp", agg, params[f"self{i}_l{l}"])
            new[l] = feats[l] + mixed
        # gate nonlinearity: scalars via silu, l>0 gated by learned scalars
        scal = new[0][..., 0]
        gates = jax.nn.sigmoid(scal @ params[f"gate{i}"]).reshape(n, lm, mul)
        out = {0: jax.nn.silu(scal)[..., None]}
        for l in range(1, lm + 1):
            out[l] = new[l] * gates[:, l - 1, :, None]
        feats = out

    e_atom = _mlp_apply(params, "readout", feats[0][..., 0], 2, ln=False)[..., 0]  # [N]
    return jax.ops.segment_sum(e_atom, graph_ids, num_segments=n_graphs)


def _sph_harm_jnp(vec, l):
    n = jnp.sqrt(jnp.maximum(jnp.sum(vec * vec, axis=-1, keepdims=True), 1e-18))
    v = vec / n
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    if l == 0:
        return jnp.full(v.shape[:-1] + (1,), 0.5 / np.sqrt(np.pi))
    if l == 1:
        c = np.sqrt(3.0 / (4 * np.pi))
        return jnp.stack([c * y, c * z, c * x], axis=-1)
    if l == 2:
        c = np.sqrt(15.0 / (4 * np.pi))
        c0 = np.sqrt(5.0 / (16 * np.pi))
        return jnp.stack(
            [c * x * y, c * y * z, c0 * (3 * z * z - 1.0), c * x * z, 0.5 * c * (x * x - y * y)],
            axis=-1,
        )
    raise NotImplementedError
