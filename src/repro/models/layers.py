"""Transformer building blocks (pure functions, GSPMD-friendly).

Conventions: params are plain dicts of f32 arrays; compute casts to
``cfg.dtype`` (bf16) with f32 softmax/norm/logit accumulation. Attention is
blockwise (flash-style double scan) so no [S, S] score matrix is ever
materialized — required for the 32k prefill cells.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = jnp.float32(-jnp.inf)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, hd]; positions broadcastable to [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


class _QBlock(NamedTuple):
    q: jax.Array  # [B, qc, KV, G, hd]
    pos0: jax.Array  # scalar start position (traced or python int)


def flash_attention(
    q: jax.Array,  # [B, Sq, KV, G, hd]
    k: jax.Array,  # [B, T, KV, hd]
    v: jax.Array,  # [B, T, KV, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 2048,
    kv_chunk: int = 2048,
    triangle_skip: bool = False,
) -> jax.Array:
    """Blockwise softmax attention with running (max, denom, acc) state.

    ``triangle_skip``: unroll the query-chunk loop in Python and bound each
    inner KV scan at the causal frontier — skips strictly-upper-triangle
    chunk pairs entirely (≈2× fewer attention FLOPs at long S; §Perf knob).
    """
    b, sq, nkv, g, hd = q.shape
    t = k.shape[1]
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, t)
    sq_orig, t_orig = sq, t
    if sq % qc:  # pad queries; padded rows are sliced off at the end
        pad = qc - sq % qc
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        sq += pad
    if t % kc:  # pad keys/values; masked out via kpos < t_orig below
        pad = kc - t % kc
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        t += pad
    nq, nk = sq // qc, t // kc
    scale = np.float32(1.0 / np.sqrt(hd))

    qr = q.reshape(b, nq, qc, nkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(b, nk, kc, nkv, hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, kc, nkv, hd).transpose(1, 0, 2, 3, 4)

    def kv_step(qblk: _QBlock, carry, inputs):
        m, l, acc = carry  # [B,KV,G,qc] f32, [B,KV,G,qc] f32, [B,KV,G,qc,hd] f32
        kj, kblk, vblk = inputs
        logits = (
            jnp.einsum("bqkgd,bskd->bkgqs", qblk.q, kblk).astype(jnp.float32)
            * scale
        )
        qpos = qblk.pos0 + jnp.arange(qc)
        kpos = kj * kc + jnp.arange(kc)
        msk = (kpos[None, :] < t_orig) & jnp.ones((qc, 1), bool)
        if causal:
            msk = msk & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            msk = msk & (kpos[None, :] > qpos[:, None] - window)
        mskb = msk[None, None, None, :, :]
        logits = jnp.where(mskb, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        diff = jnp.where(mskb, logits - m_new[..., None], NEG_INF)
        pexp = jnp.exp(diff)
        l_new = l * alpha + pexp.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", pexp.astype(vblk.dtype), vblk
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    def q_block(qblk: _QBlock, nk_bound: int):
        init = (
            jnp.full((b, nkv, g, qc), NEG_INF, jnp.float32),
            jnp.zeros((b, nkv, g, qc), jnp.float32),
            jnp.zeros((b, nkv, g, qc, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            lambda c, inp: kv_step(qblk, c, inp),
            init,
            (jnp.arange(nk_bound), kr[:nk_bound], vr[:nk_bound]),
        )
        return acc / jnp.maximum(l, 1e-30)[..., None]  # [B,KV,G,qc,hd]

    if triangle_skip and causal:
        outs = []
        for qi in range(nq):
            nk_bound = min(nk, -(-((qi + 1) * qc) // kc))
            outs.append(q_block(_QBlock(qr[qi], qi * qc), nk_bound))
        out = jnp.stack(outs, axis=0)  # [nq, B, KV, G, qc, hd]
    else:

        def outer(_, inp):
            qi, qblk = inp
            return None, q_block(_QBlock(qblk, qi * qc), nk)

        _, out = jax.lax.scan(outer, None, (jnp.arange(nq), qr))

    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, nkv, g, hd)
    return out[:, :sq_orig].astype(q.dtype)


def decode_attention(
    q: jax.Array,  # [B, KV, G, hd] — single new token
    cache_k: jax.Array,  # [B, T, KV, hd] (post-RoPE keys)
    cache_v: jax.Array,  # [B, T, KV, hd]
    pos: jax.Array,  # scalar: index of the new token
) -> jax.Array:
    t = cache_k.shape[1]
    scale = np.float32(1.0 / np.sqrt(q.shape[-1]))
    logits = (
        jnp.einsum("bkgd,bskd->bkgs", q, cache_k).astype(jnp.float32) * scale
    )
    valid = jnp.arange(t) <= pos
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w.astype(cache_v.dtype), cache_v)
    return out.astype(q.dtype)
