"""Decoder-only LM (dense + MoE) with train / prefill / decode paths.

Distribution: GSPMD (pjit) with Megatron-style tensor parallelism over the
``model`` mesh axis and batch data-parallelism over (``pod``, ``data``);
optional FSDP shards params over the dp axes too (kimi-k2 needs it). The
MoE FFN is an explicit ``shard_map`` island: expert-parallel when
n_experts % model_size == 0 (kimi-k2: 384/16), expert-tensor-parallel
otherwise (mixtral: 8 experts < 16 shards → shard d_ff). Layers run under
``lax.scan`` with stacked params (compile-time O(1) in depth) + remat.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import optimization_barrier, shard_map
from repro.configs.base import LMConfig
from repro.models.layers import decode_attention, flash_attention, rms_norm, rope


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------

def dp_axis_names(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def wsc(x, mesh, spec: P):
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec)
    )


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axis_names(mesh):
        s *= mesh.shape[a]
    return s


def model_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_lm(rng: jax.Array, cfg: LMConfig) -> Dict[str, Any]:
    pdt = jnp.dtype(cfg.param_dtype)
    d, hd, hq, kv, l = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.n_layers
    keys = jax.random.split(rng, 16)

    def nrm(key, shape, scale=0.02):
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(pdt)

    layers: Dict[str, jax.Array] = {
        "wq": nrm(keys[0], (l, d, hq * hd)),
        "wk": nrm(keys[1], (l, d, kv * hd)),
        "wv": nrm(keys[2], (l, d, kv * hd)),
        "wo": nrm(keys[3], (l, hq * hd, d), 0.02 / math.sqrt(2 * l)),
        "ln1": jnp.ones((l, d), pdt),
        "ln2": jnp.ones((l, d), pdt),
    }
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((l, hq * hd), pdt)
        layers["bk"] = jnp.zeros((l, kv * hd), pdt)
        layers["bv"] = jnp.zeros((l, kv * hd), pdt)
    if cfg.qk_norm:
        layers["q_norm"] = jnp.ones((l, hd), pdt)
        layers["k_norm"] = jnp.ones((l, hd), pdt)
    if cfg.moe is None:
        layers["wi"] = nrm(keys[4], (l, d, cfg.d_ff))
        layers["wg"] = nrm(keys[5], (l, d, cfg.d_ff))
        layers["wo_ff"] = nrm(keys[6], (l, cfg.d_ff, d), 0.02 / math.sqrt(2 * l))
    else:
        e = cfg.moe.n_experts
        layers["router"] = nrm(keys[7], (l, d, e))
        layers["ewi"] = nrm(keys[8], (l, e, d, cfg.d_ff))
        layers["ewg"] = nrm(keys[9], (l, e, d, cfg.d_ff))
        layers["ewo"] = nrm(keys[10], (l, e, cfg.d_ff, d), 0.02 / math.sqrt(2 * l))
        if cfg.moe.n_shared:
            s = cfg.moe.n_shared
            layers["swi"] = nrm(keys[11], (l, d, s * cfg.d_ff))
            layers["swg"] = nrm(keys[12], (l, d, s * cfg.d_ff))
            layers["swo"] = nrm(keys[13], (l, s * cfg.d_ff, d), 0.02 / math.sqrt(2 * l))

    return {
        "embed": nrm(keys[14], (cfg.vocab, d)),
        "unembed": nrm(keys[15], (d, cfg.vocab)),
        "final_norm": jnp.ones((d,), pdt),
        "layers": layers,
    }


def lm_param_specs(cfg: LMConfig, mesh) -> Dict[str, Any]:
    """PartitionSpec pytree matching ``init_lm`` output."""
    dp = dp_axis_names(mesh)
    fs = dp if cfg.fsdp else None  # FSDP: shard the big dim over dp too
    m = "model"

    layers: Dict[str, P] = {
        "wq": P(None, fs, m),
        "wk": P(None, fs, m),
        "wv": P(None, fs, m),
        "wo": P(None, m, fs),
        "ln1": P(None, None),
        "ln2": P(None, None),
    }
    if cfg.qkv_bias:
        layers.update(bq=P(None, m), bk=P(None, m), bv=P(None, m))
    if cfg.qk_norm:
        layers.update(q_norm=P(None, None), k_norm=P(None, None))
    if cfg.moe is None:
        layers.update(
            wi=P(None, fs, m), wg=P(None, fs, m), wo_ff=P(None, m, fs)
        )
    else:
        ep = cfg.moe.n_experts % model_size(mesh) == 0 and cfg.moe.n_experts >= model_size(mesh)
        if ep:
            layers.update(
                router=P(None, None, None),
                ewi=P(None, m, fs, None),
                ewg=P(None, m, fs, None),
                ewo=P(None, m, None, fs),
            )
        else:
            layers.update(
                router=P(None, None, None),
                ewi=P(None, None, fs, m),
                ewg=P(None, None, fs, m),
                ewo=P(None, None, m, fs),
            )
        if cfg.moe.n_shared:
            layers.update(swi=P(None, fs, m), swg=P(None, fs, m), swo=P(None, m, fs))

    return {
        "embed": P(m, fs),
        "unembed": P(fs, m),
        "final_norm": P(None),
        "layers": layers,
    }


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _dense_ffn(x, wi, wg, wo):
    dt = x.dtype
    h = jax.nn.silu(x @ wg.astype(dt)) * (x @ wi.astype(dt))
    return h @ wo.astype(dt)


def moe_block(x: jax.Array, lp: Dict[str, jax.Array], cfg: LMConfig, mesh) -> jax.Array:
    """Expert FFN as a shard_map island (see module docstring)."""
    moe = cfg.moe
    dp = dp_axis_names(mesh)
    dsz, msz = dp_size(mesh), model_size(mesh)
    b, s, d = x.shape
    shard_batch = dsz > 1 and b % dsz == 0
    b_loc = b // dsz if shard_batch else b
    t_loc = b_loc * s
    e = moe.n_experts
    ep = e % msz == 0 and e >= msz
    cap = int(t_loc * moe.top_k / e * moe.capacity_factor + 0.999)
    cap = min(t_loc, max(8, -(-cap // 8) * 8))

    x_spec = P(dp, None, None) if shard_batch else P(None, None, None)
    fs = dp if (cfg.fsdp and dp) else None  # FSDP: expert weights stay
    # dp-sharded INTO the shard_map and are all-gathered per expert inside
    # the expert loop (streaming FSDP) — otherwise the replication implied
    # by the in_specs makes GSPMD materialize every layer's full expert
    # weights outside the layer scan (>150 GiB for kimi-k2).
    if ep:
        especs = (P("model", fs, None), P("model", fs, None), P("model", None, fs))
    else:
        especs = (P(None, fs, "model"), P(None, fs, "model"), P(None, "model", fs))

    def local_fn(x_loc, router_w, wi, wg, wo):
        dt = x_loc.dtype
        xl = x_loc.reshape(-1, d)  # [t_loc, d]
        # Router matmul in the compute dtype; only the [t, E] logits are
        # upcast. Upcasting xl itself creates a full-activation f32 copy
        # that AD saves per layer (107 GiB for kimi-k2 — see EXPERIMENTS
        # §Perf iteration log).
        logits = (xl @ router_w.astype(dt)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gval, gidx = jax.lax.top_k(probs, moe.top_k)
        gval = gval / jnp.maximum(gval.sum(-1, keepdims=True), 1e-9)
        e_loc = wi.shape[0]
        e0 = jax.lax.axis_index("model") * e_loc if ep else 0

        def expert_step(out, ew):
            wi_e, wg_e, wo_e, e_rel = ew
            if fs is not None:
                # cast BEFORE the gather: the FSDP weight all-gather is the
                # dominant collective for MoE decode — f32 wire format would
                # double it (§Perf: kimi-k2 decode 258 GB/dev → 129 GB/dev)
                wi_e = jax.lax.all_gather(wi_e.astype(dt), fs, axis=0, tiled=True)
                wg_e = jax.lax.all_gather(wg_e.astype(dt), fs, axis=0, tiled=True)
                wo_e = jax.lax.all_gather(wo_e.astype(dt), fs, axis=1, tiled=True)
            e_glob = e0 + e_rel
            gate_e = jnp.sum(jnp.where(gidx == e_glob, gval, 0.0), axis=-1)  # [t]
            topv, topi = jax.lax.top_k(gate_e, cap)
            xe = xl[topi]
            h = jax.nn.silu(xe @ wg_e.astype(dt)) * (xe @ wi_e.astype(dt))
            ye = (h @ wo_e.astype(dt)) * topv[:, None].astype(dt)
            return out.at[topi].add(ye), None

        out0 = jnp.zeros_like(xl)
        out, _ = jax.lax.scan(
            expert_step,
            out0,
            (wi, wg, wo, jnp.arange(wi.shape[0], dtype=jnp.int32)),
        )
        out = jax.lax.psum(out, "model")
        return out.reshape(x_loc.shape)

    out = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(x_spec, P(None, None)) + especs,
        out_specs=x_spec,
        check_vma=False,
    )(x, lp["router"], lp["ewi"], lp["ewg"], lp["ewo"])

    if moe.n_shared:
        out = out + _dense_ffn(x, lp["swi"], lp["swg"], lp["swo"])
    return out


def _qkv(x, lp, cfg: LMConfig, positions):
    b = x.shape[0]
    s = x.shape[1]
    hd, hq, kvh = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    g = hq // kvh
    dt = x.dtype
    q = x @ lp["wq"].astype(dt)
    k = x @ lp["wk"].astype(dt)
    v = x @ lp["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(dt)
        k = k + lp["bk"].astype(dt)
        v = v + lp["bv"].astype(dt)
    q = q.reshape(b, s, kvh * g, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = q.reshape(b, s, kvh, g, hd)
    return q, k, v


def attention_block(x, lp, cfg: LMConfig, positions, triangle_skip=False):
    b, s, _ = x.shape
    q, k, v = _qkv(x, lp, cfg, positions)
    o = flash_attention(
        q,
        k,
        v,
        causal=True,
        window=cfg.sliding_window,
        q_chunk=cfg.attn_q_chunk,
        kv_chunk=cfg.attn_kv_chunk,
        triangle_skip=triangle_skip,
    )
    o = o.reshape(b, s, cfg.n_heads * cfg.hd)
    return o @ lp["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# forward paths
# ---------------------------------------------------------------------------

def _ffn(x, lp, cfg: LMConfig, mesh):
    if cfg.moe is None:
        return _dense_ffn(x, lp["wi"], lp["wg"], lp["wo_ff"])
    return moe_block(x, lp, cfg, mesh)


def _layer_specs(cfg: LMConfig, mesh):
    """Per-layer weight specs (stacked specs minus the leading L dim)."""
    return {
        k: P(*v[1:]) for k, v in lm_param_specs(cfg, mesh)["layers"].items()
    }


def _constrain_layer(lp, cfg: LMConfig, mesh):
    """Re-pin the scan body's sliced weights to their sharded layout.

    Without this, GSPMD hoists the FSDP all-gather of the *whole stacked*
    parameter tree out of the layer scan — materializing every layer's
    full weights on every device (for kimi-k2 that is >150 GiB of temp).
    Constraining inside the body forces the gather to happen per layer.
    """
    if not cfg.fsdp:
        return lp
    specs = _layer_specs(cfg, mesh)
    return {k: wsc(v, mesh, specs[k]) for k, v in lp.items()}


def lm_forward(params, tokens, cfg: LMConfig, mesh, *, triangle_skip=False):
    """Shared trunk: tokens [B, S] → final hidden states [B, S, d]."""
    dp = dp_axis_names(mesh)
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0, mode='clip').astype(dt)
    x = wsc(x, mesh, P(dp, None, None))
    positions = jnp.arange(tokens.shape[1])

    def layer(x, lp):
        # Barrier: without it XLA hoists the rematted bf16→f32 convert of
        # the saved activation out of the backward loop, materializing the
        # whole [L, B, S, d] stack in f32 (2× remat memory; 107 GiB for
        # kimi-k2). The barrier pins the convert inside the loop body.
        x = optimization_barrier(x)
        lp = _constrain_layer(lp, cfg, mesh)
        h = attention_block(
            rms_norm(x, lp["ln1"], cfg.norm_eps), lp, cfg, positions,
            triangle_skip=triangle_skip,
        )
        x = x + h
        h2 = _ffn(rms_norm(x, lp["ln2"], cfg.norm_eps), lp, cfg, mesh)
        x = x + h2
        x = wsc(x, mesh, P(dp, None, None))
        return x, None

    # prevent_cse=False: scan already isolates iterations; the default
    # barriers make XLA keep an extra f32 copy of the saved activation
    # stack (2× remat memory for free).
    body = jax.checkpoint(layer, prevent_cse=False) if cfg.remat else layer
    x, _ = jax.lax.scan(body, x, params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def lm_loss(params, tokens, labels, cfg: LMConfig, mesh) -> jax.Array:
    x = lm_forward(params, tokens, cfg, mesh)
    return softmax_xent(x, params["unembed"], labels, cfg)


def softmax_xent(x, unembed, labels, cfg: LMConfig) -> jax.Array:
    """Token-mean cross entropy; optional vocab-chunked logsumexp (perf
    knob: avoids the [B, S, V] f32 logit buffer)."""
    b, s, d = x.shape
    v = unembed.shape[1]
    if cfg.vocab_chunk is None:
        logits = (x @ unembed.astype(x.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - ll)
    vc = cfg.vocab_chunk
    assert v % vc == 0
    nchunks = v // vc
    un = unembed.reshape(d, nchunks, vc)

    def chunk(carry, inp):
        m, ssum, ll = carry
        ci, w = inp
        lg = (x @ w.astype(x.dtype)).astype(jnp.float32)  # [B, S, vc]
        m_new = jnp.maximum(m, lg.max(-1))
        ssum = ssum * jnp.exp(m - m_new) + jnp.exp(lg - m_new[..., None]).sum(-1)
        rel = labels - ci * vc
        inside = (rel >= 0) & (rel < vc)
        lab = jnp.take_along_axis(lg, jnp.clip(rel, 0, vc - 1)[..., None], axis=-1)[..., 0]
        ll = jnp.where(inside, lab, ll)
        return (m_new, ssum, ll), None

    init = (
        jnp.full((b, s), -jnp.inf, jnp.float32),
        jnp.zeros((b, s), jnp.float32),
        jnp.zeros((b, s), jnp.float32),
    )
    (m, ssum, ll), _ = jax.lax.scan(
        chunk, init, (jnp.arange(nchunks), un.transpose(1, 0, 2))
    )
    lse = m + jnp.log(ssum)
    return jnp.mean(lse - ll)


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def cache_shape(cfg: LMConfig, batch: int, cache_len: int):
    t = cache_len if cfg.sliding_window is None else min(cache_len, cfg.sliding_window)
    shp = (cfg.n_layers, batch, t, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jax.ShapeDtypeStruct(shp, jnp.dtype(cfg.dtype)),
        "v": jax.ShapeDtypeStruct(shp, jnp.dtype(cfg.dtype)),
    }


def cache_specs(cfg: LMConfig, mesh, batch: int):
    dp = dp_axis_names(mesh)
    if batch % max(dp_size(mesh), 1) == 0 and dp_size(mesh) > 1:
        spec = P(None, dp, "model", None, None)
    else:
        # tiny-batch long-context: shard the sequence dim over everything
        spec = P(None, None, (dp + ("model",)) if dp else "model", None, None)
    return {"k": spec, "v": spec}


def lm_prefill(params, tokens, cfg: LMConfig, mesh):
    """tokens [B, S] → (last-token logits [B, V], cache)."""
    dp = dp_axis_names(mesh)
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0, mode='clip').astype(dt)
    positions = jnp.arange(tokens.shape[1])

    def layer(x, lp):
        lp = _constrain_layer(lp, cfg, mesh)
        xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
        b, s, _ = xn.shape
        q, k, v = _qkv(xn, lp, cfg, positions)
        o = flash_attention(
            q, k, v,
            causal=True,
            window=cfg.sliding_window,
            q_chunk=cfg.attn_q_chunk,
            kv_chunk=cfg.attn_kv_chunk,
        )
        o = o.reshape(b, s, cfg.n_heads * cfg.hd) @ lp["wo"].astype(x.dtype)
        x = x + o
        x = x + _ffn(rms_norm(x, lp["ln2"], cfg.norm_eps), lp, cfg, mesh)
        if cfg.sliding_window is not None and s > cfg.sliding_window:
            # Rolling layout: token p lives at slot p % W, matching
            # lm_decode_step's write index so decode can continue the cache.
            w = cfg.sliding_window
            k = jnp.roll(k[:, -w:], shift=s % w, axis=1)
            v = jnp.roll(v[:, -w:], shift=s % w, axis=1)
        return x, {"k": k, "v": v}

    x, cache = jax.lax.scan(layer, x, params["layers"])
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = (x @ params["unembed"].astype(x.dtype)).astype(jnp.float32)
    return logits[:, 0], cache


def lm_decode_step(params, token, cache, pos, cfg: LMConfig, mesh):
    """token [B] int32; cache {'k','v': [L, B, T, KV, hd]}; pos scalar index
    of the new token. Returns (logits [B, V], new cache)."""
    dt = jnp.dtype(cfg.dtype)
    b = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0, mode='clip').astype(dt)  # [B,1,d]
    t_cache = cache["k"].shape[2]
    write_idx = pos % t_cache if cfg.sliding_window is not None else pos
    positions = pos[None] if jnp.ndim(pos) == 0 else pos

    def layer(x, lp_cache):
        lp, kc, vc = lp_cache
        lp = _constrain_layer(lp, cfg, mesh)
        xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = _qkv(xn, lp, cfg, jnp.reshape(positions, (1,)))
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, write_idx, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, write_idx, 0, 0))
        mask_pos = jnp.minimum(pos, t_cache - 1)
        o = decode_attention(q[:, 0], kc, vc, mask_pos)
        o = o.reshape(b, 1, cfg.n_heads * cfg.hd) @ lp["wo"].astype(x.dtype)
        x = x + o
        x = x + _ffn(rms_norm(x, lp["ln2"], cfg.norm_eps), lp, cfg, mesh)
        return x, {"k": kc, "v": vc}

    x, new_cache = jax.lax.scan(layer, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["unembed"].astype(x.dtype)).astype(jnp.float32)
    return logits[:, 0], new_cache
