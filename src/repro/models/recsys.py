"""xDeepFM (CIN + DNN + linear) with sharded embedding tables.

The embedding lookup is the hot path: JAX has no EmbeddingBag, so it is
built from ``jnp.take`` + ``jax.ops.segment_sum`` over a flat
offset-indexed table (DESIGN.md §4) — the same gather/segment substrate as
the MSF engine. The table rows shard over the ``model`` axis; batch shards
over dp. ``retrieval`` scores one query against 10⁶ candidates with a
sharded batched dot + top-k (no loops).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecsysConfig


def field_offsets(cfg: RecsysConfig) -> np.ndarray:
    """Per-field row offsets into the single flat embedding table. Field
    vocab sizes follow a Criteo-like power-law split of total_vocab; the
    largest field absorbs rounding so offsets+sizes never exceed the table."""
    raw = np.logspace(0, 6, cfg.n_sparse)
    sizes = np.maximum((raw / raw.sum() * cfg.total_vocab).astype(np.int64), 4)
    overflow = sizes.sum() - cfg.total_vocab
    if overflow > 0:
        sizes[-1] -= overflow
        assert sizes[-1] >= 4, "total_vocab too small for n_sparse fields"
    return np.concatenate([[0], np.cumsum(sizes)])[:-1], sizes


def init_xdeepfm(rng, cfg: RecsysConfig) -> Dict[str, Any]:
    keys = jax.random.split(rng, 8 + 2 * len(cfg.cin_layers) + 2 * len(cfg.mlp_layers))
    f, d = cfg.n_sparse, cfg.embed_dim
    params: Dict[str, Any] = {
        "table": jax.random.normal(keys[0], (cfg.total_vocab, d)) * 0.01,
        "lin_table": jax.random.normal(keys[1], (cfg.total_vocab, 1)) * 0.01,
        "bias": jnp.zeros(()),
    }
    h_prev = f
    ki = 2
    for i, h in enumerate(cfg.cin_layers):
        params[f"cin_w{i}"] = jax.random.normal(keys[ki], (h_prev, f, h)) * math.sqrt(
            2.0 / (h_prev * f)
        )
        ki += 1
        h_prev = h
    params["cin_out"] = jax.random.normal(keys[ki], (sum(cfg.cin_layers), 1)) * 0.1
    ki += 1
    dims = [f * d] + list(cfg.mlp_layers) + [1]
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"mlp_w{i}"] = jax.random.normal(keys[ki], (a, b)) * math.sqrt(2.0 / a)
        params[f"mlp_b{i}"] = jnp.zeros((b,))
        ki += 1
    return params


def embedding_bag(table: jax.Array, ids: jax.Array) -> jax.Array:
    """ids [B, F] (absolute row ids) → [B, F, d]. For multi-hot bags the
    same op runs on flattened (bag_ids, segment_sum) — exposed for reuse.
    mode="clip": jnp.take's default OOB mode is 'fill' (NaN for floats) —
    a single corrupt id must never poison a training step."""
    return jnp.take(table, ids, axis=0, mode="clip")


def embedding_bag_multihot(
    table: jax.Array, flat_ids: jax.Array, bag_ids: jax.Array, n_bags: int
) -> jax.Array:
    """EmbeddingBag(sum): gather + segment-sum (the torch-parity op)."""
    rows = jnp.take(table, flat_ids, axis=0, mode='clip')
    return jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)


def _cin(params, x0: jax.Array, cfg: RecsysConfig) -> jax.Array:
    """Compressed Interaction Network. x0 [B, F, D]."""
    b, f, d = x0.shape
    xk = x0
    pooled = []
    for i, h in enumerate(cfg.cin_layers):
        # outer product along field dims, compressed by conv weights
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)  # [B, Hk, F, D]
        xk = jnp.einsum("bhmd,hmn->bnd", z, params[f"cin_w{i}"])  # [B, H, D]
        pooled.append(xk.sum(-1))  # [B, H]
    p = jnp.concatenate(pooled, axis=-1)  # [B, sum(H)]
    return p @ params["cin_out"]  # [B, 1]


def xdeepfm_logits(params, ids: jax.Array, cfg: RecsysConfig) -> jax.Array:
    """ids [B, F] absolute row indices → logits [B]."""
    emb = embedding_bag(params["table"], ids)  # [B, F, D]
    lin = embedding_bag(params["lin_table"], ids)[..., 0].sum(-1)  # [B]
    cin = _cin(params, emb, cfg)[..., 0]
    h = emb.reshape(emb.shape[0], -1)
    n_mlp = len(cfg.mlp_layers) + 1
    for i in range(n_mlp):
        h = h @ params[f"mlp_w{i}"] + params[f"mlp_b{i}"]
        if i < n_mlp - 1:
            h = jax.nn.relu(h)
    return lin + cin + h[..., 0] + params["bias"]


def xdeepfm_loss(params, ids, labels, cfg: RecsysConfig) -> jax.Array:
    logits = xdeepfm_logits(params, ids, cfg)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


# ---------------------------------------------------------------------------
# retrieval: 1 query vs n_candidates, sharded dot + top-k
# ---------------------------------------------------------------------------

def init_retrieval(rng, cfg: RecsysConfig, n_candidates: int) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(rng, 3)
    f, d, r = cfg.n_sparse, cfg.embed_dim, cfg.retrieval_dim
    return {
        "table": jax.random.normal(k1, (cfg.total_vocab, d)) * 0.01,
        "tower_w": jax.random.normal(k2, (f * d, r)) * math.sqrt(2.0 / (f * d)),
        "items": jax.random.normal(k3, (n_candidates, r)) * 0.1,
    }


def retrieval_topk(params, ids: jax.Array, cfg: RecsysConfig, k: int = 100):
    """ids [B, F] (user features) → (scores [B, k], indices [B, k])."""
    emb = embedding_bag(params["table"], ids).reshape(ids.shape[0], -1)
    u = emb @ params["tower_w"]  # [B, r]
    scores = u @ params["items"].T  # [B, n_candidates]
    return jax.lax.top_k(scores, k)
