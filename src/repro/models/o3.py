"""Minimal real-spherical-harmonic O(3) machinery for NequIP (l ≤ 2).

Clebsch-Gordan coefficients for the *real* SH basis are computed
numerically at model-build time: the coupling tensor C(l1,l2→l3) is the
(1-dimensional) null space of the equivariance constraint
``C = D3ᵀ C (D1 ⊗ D2)`` stacked over random rotations, where the Wigner-D
matrices for real SH are themselves recovered by least squares from
``Y_l(R x) = D_l(R) Y_l(x)``. Exact to ~1e-12 and — unlike Gaunt-integral
couplings — includes the antisymmetric paths (e.g. 1⊗1→1, the cross
product). Cached per (l1, l2, l3).
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np

_SQRT_PI = np.sqrt(np.pi)


def sph_harm_np(vec: np.ndarray, l: int) -> np.ndarray:
    """Real spherical harmonics (orthonormal), vec [N, 3] need not be unit."""
    v = vec / np.maximum(np.linalg.norm(vec, axis=-1, keepdims=True), 1e-12)
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    if l == 0:
        return np.full(v.shape[:-1] + (1,), 0.5 / _SQRT_PI)
    if l == 1:
        c = np.sqrt(3.0 / (4 * np.pi))
        return np.stack([c * y, c * z, c * x], axis=-1)
    if l == 2:
        c = np.sqrt(15.0 / (4 * np.pi))
        c0 = np.sqrt(5.0 / (16 * np.pi))
        return np.stack(
            [
                c * x * y,
                c * y * z,
                c0 * (3 * z * z - 1.0),
                c * x * z,
                0.5 * c * (x * x - y * y),
            ],
            axis=-1,
        )
    raise NotImplementedError(f"l={l}")


def _random_rotation(rng: np.random.Generator) -> np.ndarray:
    q, _ = np.linalg.qr(rng.standard_normal((3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return q


def wigner_d_np(r: np.ndarray, l: int, rng=None) -> np.ndarray:
    """D_l(R) with Y_l(R x) = D_l(R) Y_l(x), by least squares."""
    if l == 0:
        return np.ones((1, 1))
    rng = rng or np.random.default_rng(0)
    n = 8 * (2 * l + 1)
    x = rng.standard_normal((n, 3))
    x /= np.linalg.norm(x, axis=-1, keepdims=True)
    a = sph_harm_np(x, l)  # [n, m]
    b = sph_harm_np(x @ r.T, l)  # [n, m] — rows Y(Rx)
    d, *_ = np.linalg.lstsq(a, b, rcond=None)
    return d.T  # b = a @ d  =>  Y(Rx) = D Y(x) with D = d.T


@lru_cache(maxsize=None)
def clebsch_gordan(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis coupling tensor C [2l3+1, 2l1+1, 2l2+1], ||C|| = 1."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        raise ValueError(f"triangle violation ({l1},{l2},{l3})")
    rng = np.random.default_rng(42)
    m1, m2, m3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    rows = []
    for _ in range(4):
        r = _random_rotation(rng)
        d1 = wigner_d_np(r, l1, rng)
        d2 = wigner_d_np(r, l2, rng)
        d3 = wigner_d_np(r, l3, rng)
        # constraint: C[p,q,r] - sum_{a,b,c} D3[a,p] C[a,b,c] D1[b,q] D2[c,r] = 0
        op = np.einsum("ap,bq,cr->pqrabc", d3, d1, d2).reshape(
            m3 * m1 * m2, m3 * m1 * m2
        )
        rows.append(op - np.eye(m3 * m1 * m2))
    mat = np.concatenate(rows, axis=0)
    _, s, vt = np.linalg.svd(mat)
    null = vt[-1]
    if s[-1] > 1e-6:
        raise RuntimeError(f"no equivariant coupling for ({l1},{l2},{l3})")
    c = null.reshape(m3, m1, m2)
    # Fix sign: first max-magnitude entry positive.
    flat = c.ravel()
    c = c * np.sign(flat[np.argmax(np.abs(flat))])
    return c / np.linalg.norm(c)


def tp_paths(l_max: int):
    """All (l1, l2, l3) tensor-product paths with every l ≤ l_max."""
    paths = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l_max, l1 + l2) + 1):
                paths.append((l1, l2, l3))
    return paths


def bessel_basis_np(n_rbf: int, cutoff: float):
    """Returns f(r [E]) -> [E, n_rbf]: NequIP's Bessel radial basis with a
    polynomial cutoff envelope (computed in jnp at trace time)."""
    import jax.numpy as jnp

    freqs = np.arange(1, n_rbf + 1) * np.pi / cutoff

    def basis(r):
        rc = jnp.clip(r, 1e-6, cutoff)
        b = jnp.sin(rc[..., None] * freqs) / rc[..., None]
        # smooth cutoff envelope (p=6 polynomial, NequIP default family)
        u = jnp.clip(r / cutoff, 0.0, 1.0)
        env = 1 - 28 * u**6 + 48 * u**7 - 21 * u**8
        return b * env[..., None]

    return basis
