"""Mixtral 8x7B — 8 experts top-2, SWA [arXiv:2401.04088; hf]."""
from repro.configs.base import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="mixtral-8x7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2),
)

SMOKE = LMConfig(
    name="mixtral-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=512,
    sliding_window=32, moe=MoEConfig(n_experts=4, top_k=2),
    attn_q_chunk=32, attn_kv_chunk=32,
)
