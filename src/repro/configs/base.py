"""Config dataclasses for every architecture family + shape cells.

Every assigned architecture gets a module ``repro.configs.<id>`` exporting
``CONFIG`` (the exact published configuration) and ``SMOKE`` (a reduced
same-family config for CPU smoke tests). ``repro.configs.registry`` maps
``--arch`` ids to them and enumerates the (arch × shape) dry-run cells.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    n_shared: int = 0


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_out_bias: bool = False
    sliding_window: Optional[int] = None  # SWA window (Mixtral: 4096)
    moe: Optional[MoEConfig] = None
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    fsdp: bool = False  # additionally shard params over the dp axes
    remat: bool = True
    attn_q_chunk: int = 2048  # blockwise-attention query chunk
    attn_kv_chunk: int = 2048
    vocab_chunk: Optional[int] = None  # chunked CE loss (perf knob)
    grad_accum: int = 1  # microbatches per step (divides activation memory)
    triangle_skip: bool = True  # skip above-diagonal attention chunk pairs

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline bookkeeping)."""
        d, hd = self.d_model, self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.moe:
            ffn = self.moe.n_experts * 3 * d * self.d_ff + d * self.moe.n_experts
            ffn += self.moe.n_shared * 3 * d * self.d_ff
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * (
            (self.moe.n_experts - 0) * 3 * d * self.d_ff
        )
        active_ffn = self.n_layers * (self.moe.top_k + self.moe.n_shared) * 3 * d * self.d_ff
        return dense + active_ffn - self.n_layers * self.moe.n_shared * 3 * d * self.d_ff


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str  # "gat" | "meshgraphnet" | "gatedgcn" | "nequip"
    n_layers: int
    d_hidden: int
    n_heads: int = 1
    aggregator: str = "sum"  # sum | attn | gated
    mlp_layers: int = 2
    # nequip-specific
    l_max: int = 0
    n_rbf: int = 0
    cutoff: float = 0.0
    d_in: int = 0  # input feature dim (set per shape)
    n_classes: int = 0  # classification heads; 0 → regression
    d_out: int = 1
    dtype: str = "float32"
    param_dtype: str = "float32"
    predict_forces: bool = False


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    n_sparse: int
    embed_dim: int
    cin_layers: Tuple[int, ...]
    mlp_layers: Tuple[int, ...]
    total_vocab: int
    n_dense: int = 0
    retrieval_dim: int = 32
    dtype: str = "float32"
    param_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MSFConfig:
    """Shape cell config for the MSF engine itself (the paper's system)."""

    name: str
    n: int
    m_directed: int  # total directed edge slots (2× undirected, padded)
    shortcut: str = "csp"
    capacity: int = 1 << 20


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture × input-shape) dry-run cell."""

    name: str
    kind: str  # train | prefill | decode | serve | retrieval | ...
    # LM shapes
    seq_len: int = 0
    global_batch: int = 0
    # GNN shapes
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    batch_graphs: int = 0
    # recsys shapes
    batch: int = 0
    n_candidates: int = 0


LM_SHAPES = (
    ShapeCell(name="train_4k", kind="train", seq_len=4096, global_batch=256),
    ShapeCell(name="prefill_32k", kind="prefill", seq_len=32768, global_batch=32),
    ShapeCell(name="decode_32k", kind="decode", seq_len=32768, global_batch=128),
    ShapeCell(name="long_500k", kind="decode", seq_len=524288, global_batch=1),
)

GNN_SHAPES = (
    ShapeCell(name="full_graph_sm", kind="train", n_nodes=2708, n_edges=10556, d_feat=1433),
    ShapeCell(
        name="minibatch_lg",
        kind="train",
        n_nodes=232965,
        n_edges=114615892,
        batch_nodes=1024,
        fanout=(15, 10),
        d_feat=602,
    ),
    ShapeCell(name="ogb_products", kind="train", n_nodes=2449029, n_edges=61859140, d_feat=100),
    ShapeCell(name="molecule", kind="train", n_nodes=30, n_edges=64, batch_graphs=128, d_feat=4),
)

RECSYS_SHAPES = (
    ShapeCell(name="train_batch", kind="train", batch=65536),
    ShapeCell(name="serve_p99", kind="serve", batch=512),
    ShapeCell(name="serve_bulk", kind="serve", batch=262144),
    ShapeCell(name="retrieval_cand", kind="retrieval", batch=1, n_candidates=1_000_000),
)

MSF_SHAPES = (
    ShapeCell(name="road_like", kind="msf", n_nodes=23_947_347, n_edges=28_854_312),
    ShapeCell(name="rmat_s23_e8", kind="msf", n_nodes=1 << 23, n_edges=(1 << 23) * 8),
    ShapeCell(name="rmat_s23_e128", kind="msf", n_nodes=1 << 23, n_edges=(1 << 23) * 128),
    ShapeCell(name="friendster_like", kind="msf", n_nodes=65_600_000, n_edges=1_800_000_000),
)
