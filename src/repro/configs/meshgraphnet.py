"""MeshGraphNet [arXiv:2010.03409; unverified]."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="meshgraphnet", kind="meshgraphnet",
    n_layers=15, d_hidden=128, aggregator="sum", mlp_layers=2,
    d_out=3,
)

SMOKE = GNNConfig(
    name="meshgraphnet-smoke", kind="meshgraphnet",
    n_layers=2, d_hidden=16, aggregator="sum", mlp_layers=2,
    d_in=8, d_out=3,
)
