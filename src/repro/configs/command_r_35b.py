"""Command-R 35B — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="command-r-35b",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab=256000,
)

SMOKE = LMConfig(
    name="command-r-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    attn_q_chunk=32, attn_kv_chunk=32,
)
