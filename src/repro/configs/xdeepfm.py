"""xDeepFM (CIN) [arXiv:1803.05170; paper]. Criteo-scale embedding tables."""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="xdeepfm",
    n_sparse=39, embed_dim=10,
    cin_layers=(200, 200, 200), mlp_layers=(400, 400),
    total_vocab=120_000_000,  # Criteo-scale; rows shard over `model`
)

SMOKE = RecsysConfig(
    name="xdeepfm-smoke",
    n_sparse=8, embed_dim=4,
    cin_layers=(8, 8), mlp_layers=(16, 16),
    total_vocab=2048,
)
