"""NequIP — O(3)-equivariant interatomic potential [arXiv:2101.03164; paper]."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="nequip", kind="nequip",
    n_layers=5, d_hidden=32, l_max=2, n_rbf=8, cutoff=5.0,
    aggregator="sum",
)

SMOKE = GNNConfig(
    name="nequip-smoke", kind="nequip",
    n_layers=2, d_hidden=8, l_max=2, n_rbf=8, cutoff=5.0,
    aggregator="sum",
)
