"""Qwen3-32B — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="qwen3-32b",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
    d_ff=25600, vocab=151936,
    head_dim=128, qk_norm=True,
)

SMOKE = LMConfig(
    name="qwen3-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=32, qk_norm=True, attn_q_chunk=32, attn_kv_chunk=32,
)
