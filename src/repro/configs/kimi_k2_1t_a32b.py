"""Kimi K2 — trillion-param MoE (paper-table) [arXiv:2501.kimi2; unverified]."""
from repro.configs.base import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840,
    moe=MoEConfig(n_experts=384, top_k=8),
    fsdp=True,  # 1T params: weights/opt-state must shard over dp too
    grad_accum=4,  # divides the remat activation stack (EXPERIMENTS §Perf K.3)
)

SMOKE = LMConfig(
    name="kimi-k2-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=64, vocab=512,
    moe=MoEConfig(n_experts=8, top_k=2),
    attn_q_chunk=32, attn_kv_chunk=32,
)
