"""Arch registry: ``--arch <id>`` → (CONFIG, SMOKE, family, shape cells)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Tuple

from repro.configs.base import (
    GNN_SHAPES,
    LM_SHAPES,
    MSF_SHAPES,
    RECSYS_SHAPES,
    ShapeCell,
)

_ARCHS = {
    # id -> (module, family)
    "kimi-k2-1t-a32b": ("repro.configs.kimi_k2_1t_a32b", "lm"),
    "mixtral-8x7b": ("repro.configs.mixtral_8x7b", "lm"),
    "qwen3-32b": ("repro.configs.qwen3_32b", "lm"),
    "command-r-35b": ("repro.configs.command_r_35b", "lm"),
    "qwen2-7b": ("repro.configs.qwen2_7b", "lm"),
    "gat-cora": ("repro.configs.gat_cora", "gnn"),
    "meshgraphnet": ("repro.configs.meshgraphnet", "gnn"),
    "gatedgcn": ("repro.configs.gatedgcn", "gnn"),
    "nequip": ("repro.configs.nequip", "gnn"),
    "xdeepfm": ("repro.configs.xdeepfm", "recsys"),
}

SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES, "msf": MSF_SHAPES}


def arch_ids():
    return list(_ARCHS)


def family_of(arch: str) -> str:
    return _ARCHS[arch][1]


def get_config(arch: str, smoke: bool = False):
    mod, _ = _ARCHS[arch]
    m = importlib.import_module(mod)
    return m.SMOKE if smoke else m.CONFIG


def shapes_for(arch: str) -> Tuple[ShapeCell, ...]:
    return SHAPES[family_of(arch)]


def get_shape(arch: str, shape_name: str) -> ShapeCell:
    for s in shapes_for(arch):
        if s.name == shape_name:
            return s
    raise KeyError(f"{arch} has no shape {shape_name}")


def all_cells():
    """Every (arch, shape) dry-run cell — 10 archs × 4 shapes = 40."""
    out = []
    for a in _ARCHS:
        for s in shapes_for(a):
            out.append((a, s.name))
    return out
