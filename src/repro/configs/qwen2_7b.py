"""Qwen2-7B — GQA, QKV bias [arXiv:2407.10671; hf]."""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="qwen2-7b",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064,
    qkv_bias=True,
)

SMOKE = LMConfig(
    name="qwen2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    qkv_bias=True, attn_q_chunk=32, attn_kv_chunk=32,
)
