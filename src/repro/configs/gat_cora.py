"""GAT on Cora [arXiv:1710.10903; paper]."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gat-cora", kind="gat",
    n_layers=2, d_hidden=8, n_heads=8, aggregator="attn",
    n_classes=7,
)

SMOKE = GNNConfig(
    name="gat-smoke", kind="gat",
    n_layers=2, d_hidden=4, n_heads=2, aggregator="attn",
    d_in=16, n_classes=3,
)
