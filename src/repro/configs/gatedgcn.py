"""GatedGCN [arXiv:2003.00982; paper]."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gatedgcn", kind="gatedgcn",
    n_layers=16, d_hidden=70, aggregator="gated",
    n_classes=10,
)

SMOKE = GNNConfig(
    name="gatedgcn-smoke", kind="gatedgcn",
    n_layers=3, d_hidden=12, aggregator="gated",
    d_in=16, n_classes=4,
)
