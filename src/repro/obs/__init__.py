# Observability substrate (DESIGN.md §10): span tracing with Chrome-
# trace/Perfetto export (repro.obs.trace) + a process-global metrics
# registry of counters / gauges / fixed-bucket latency histograms
# (repro.obs.metrics). Leaf package — imported by every layer (core,
# coarsen, stream, solve, launch, benchmarks), so it must not import any
# of them; jax is only touched lazily at span exit (block_until_ready).
#
#     from repro import obs
#     obs.enable("trace")
#     with obs.span("solve", n=graph.n) as sp:
#         sp.attach(run(graph))
#     obs.export_trace("trace.json")
#
# The declarative route is `SolveSpec(obs="trace")` — the plan layer
# scopes the mode around each solve and fills `SolveReport.timings`.
from repro.obs.metrics import (
    DEFAULT_LATENCY_BUCKETS,
    DEFAULT_REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
)
from repro.obs.trace import (
    MODES,
    NOOP_SPAN,
    collect_timings,
    disable,
    enable,
    enabled,
    export_trace,
    metrics_active,
    mode,
    reset,
    span,
    sync_active,
    trace_active,
    trace_events,
)

__all__ = [
    # tracing
    "MODES",
    "NOOP_SPAN",
    "collect_timings",
    "disable",
    "enable",
    "enabled",
    "export_trace",
    "metrics_active",
    "mode",
    "reset",
    "span",
    "sync_active",
    "trace_active",
    "trace_events",
    # metrics
    "DEFAULT_LATENCY_BUCKETS",
    "DEFAULT_REGISTRY",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "counter",
    "gauge",
    "histogram",
    "metrics_snapshot",
    "metrics_reset",
]


def counter(name: str) -> Counter:
    """Named counter in the process-global registry."""
    return DEFAULT_REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return DEFAULT_REGISTRY.gauge(name)


def histogram(name: str, bounds=DEFAULT_LATENCY_BUCKETS) -> Histogram:
    return DEFAULT_REGISTRY.histogram(name, bounds)


def metrics_snapshot() -> dict:
    """JSON-safe snapshot of the process-global registry."""
    return DEFAULT_REGISTRY.snapshot()


def metrics_reset() -> None:
    DEFAULT_REGISTRY.reset()
