"""Process-wide metrics registry (DESIGN.md §10.2).

Three instrument kinds, all thread-safe and allocation-light on the hot
path:

- :class:`Counter` — monotonically increasing int (cache hits, all-reduce
  passes, reduced-element volume);
- :class:`Gauge` — last-written float (current batch capacity, live edge
  count);
- :class:`Histogram` — **fixed-bucket** latency histogram. Observations
  land in log-spaced buckets chosen at construction; quantiles
  (p50/p95/p99) are recovered by linear interpolation inside the
  containing bucket, clamped to the observed [min, max]. Fixed buckets
  keep ``observe()`` O(log #buckets) with zero per-sample allocation —
  the same trade every serving-metrics system makes (Prometheus,
  OpenTelemetry): quantiles are approximate to one bucket's width, while
  count/sum/min/max stay exact.

A process-global default registry backs the ``repro.obs`` module-level
helpers (``counter()`` / ``gauge()`` / ``histogram()`` /
``metrics_snapshot()`` / ``metrics_reset()``); the span tracer feeds
span durations into it as ``span.<name>`` histograms whenever
observability is enabled (``repro.obs.trace``).
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Dict, Tuple

#: Default latency buckets (seconds): log-spaced from 10 µs to ~100 s —
#: covers a fused query gather through a full distributed solve.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = tuple(
    10.0 ** (e / 3.0) for e in range(-15, 7)  # 1e-5 .. ~100 s, 3 per decade
)


class Counter:
    """Monotonic counter. ``inc`` accepts any non-negative increment."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge for deltas")
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Last-written value."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket histogram with interpolated quantile summaries.

    ``bounds`` are the strictly-increasing upper edges of the first
    ``len(bounds)`` buckets; one overflow bucket catches everything
    beyond the last edge. Observations are O(log #buckets) (bisect) under
    a lock; no per-sample storage.
    """

    __slots__ = ("_lock", "bounds", "_counts", "_count", "_sum", "_min", "_max")

    def __init__(self, bounds: Tuple[float, ...] = DEFAULT_LATENCY_BUCKETS):
        bounds = tuple(float(b) for b in bounds)
        if not bounds or any(
            b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])
        ):
            raise ValueError("bucket bounds must be non-empty and increasing")
        self._lock = threading.Lock()
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, x: float) -> None:
        x = float(x)
        i = bisect.bisect_left(self.bounds, x)  # bucket i: value <= bounds[i]
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += x
            if x < self._min:
                self._min = x
            if x > self._max:
                self._max = x

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, q: float) -> float:
        """Interpolated q-th percentile (q in [0, 100]).

        Walks the cumulative bucket counts to the bucket containing the
        target rank, linearly interpolates inside it (lower edge =
        previous bound, or the observed min for the first occupied
        bucket; upper edge = the bound, or the observed max for the
        overflow bucket), and clamps to [min, max] — so a single-valued
        stream reports that exact value at every quantile.
        """
        if not 0.0 <= q <= 100.0:
            raise ValueError("percentile q must be in [0, 100]")
        with self._lock:
            count = self._count
            counts = list(self._counts)
            lo_obs, hi_obs = self._min, self._max
        if count == 0:
            return 0.0
        rank = q / 100.0 * count
        cum = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else lo_obs
                hi = self.bounds[i] if i < len(self.bounds) else hi_obs
                frac = (rank - cum) / c if c else 0.0
                return float(min(max(lo + (hi - lo) * frac, lo_obs), hi_obs))
            cum += c
        return float(hi_obs)

    def summary(self) -> Dict[str, float]:
        with self._lock:
            count, total = self._count, self._sum
            lo, hi = self._min, self._max
        if count == 0:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {
            "count": count,
            "sum": total,
            "min": lo,
            "max": hi,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Named instruments, created on first use. ``snapshot()`` renders
    every instrument to plain dicts (JSON-safe); ``reset()`` drops all
    instruments (callers re-create on next use — handles held across a
    reset keep recording into orphaned instruments, so re-fetch by
    name)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def histogram(
        self, name: str, bounds: Tuple[float, ...] = DEFAULT_LATENCY_BUCKETS
    ) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(bounds)
            return h

    def snapshot(self) -> Dict[str, Dict]:
        """{"counters": {name: int}, "gauges": {name: float},
        "histograms": {name: {count/sum/min/max/p50/p95/p99}}}."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {
                k: h.summary() for k, h in sorted(histograms.items())
            },
        }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


#: The process-global registry every instrumented module records into.
DEFAULT_REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return DEFAULT_REGISTRY
