"""Lightweight span tracer with Chrome-trace/Perfetto export (DESIGN.md §10.1).

Usage::

    from repro import obs

    obs.enable("trace")                 # or "metrics"; process-global
    with obs.span("hook_rounds", level=0) as sp:
        out = jitted_fn(...)
        sp.attach(out)                  # block_until_ready on exit (sync mode)
    obs.export_trace("trace.json")      # open in ui.perfetto.dev

Three modes, escalating cost:

- ``"off"`` (default): :func:`span` returns a shared no-op context
  manager — the disabled path is **one branch and zero allocation**, so
  instrumentation can stay unconditionally in hot loops;
- ``"metrics"``: span durations feed ``span.<name>`` fixed-bucket
  histograms in the default :mod:`repro.obs.metrics` registry (p50/p95/
  p99 summaries); no event buffer;
- ``"trace"``: additionally every span is recorded as a Chrome-trace
  complete event (``ph: "X"`` with microsecond ``ts``/``dur``) in a
  bounded in-process buffer, exported by :func:`export_trace`. Nesting
  falls out of timestamps: Perfetto stacks same-thread spans whose
  intervals contain each other.

Device-sync timing: jax dispatch is asynchronous, so a span around a
jitted call measures dispatch, not execution. ``sp.attach(value)`` marks
a pytree to ``jax.block_until_ready`` *before* the span closes (enabled
by default, ``enable(..., sync=False)`` opts out) — the exported
duration then covers the device work, at the cost of the sync point the
profiler itself introduces. Spans are thread-safe (per-thread ids in the
export; the buffer appends under a lock).
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager

from repro.obs import metrics as _metrics

MODES = ("off", "metrics", "trace")
_MODE_RANK = {m: i for i, m in enumerate(MODES)}

#: Bounded event buffer — a runaway traced loop degrades to dropped-event
#: accounting (surfaced in the export metadata), never unbounded memory.
MAX_EVENTS = 1_000_000

_lock = threading.Lock()
_mode: str = "off"
_enabled: bool = False  # _mode != "off" — the single hot-path branch
_sync: bool = True
_events: list = []  # (name, t0_ns, dur_ns, tid, attrs | None)
_dropped: int = 0
_tls = threading.local()  # .collectors: list[dict] of active aggregators


def _check_mode(mode: str) -> str:
    if mode not in MODES:
        raise ValueError(f"unknown obs mode {mode!r} (expected one of {MODES})")
    return mode


def mode() -> str:
    """Current process-global observability mode."""
    return _mode


def trace_active() -> bool:
    return _mode == "trace"


def metrics_active() -> bool:
    """True in both "metrics" and "trace" modes."""
    return _enabled


def sync_active() -> bool:
    return _enabled and _sync


def enable(mode: str = "trace", *, sync: bool = True) -> None:
    """Set the process-global mode (until :func:`disable`)."""
    global _mode, _enabled, _sync
    _check_mode(mode)
    with _lock:
        _mode = mode
        _enabled = mode != "off"
        _sync = bool(sync)


def disable() -> None:
    enable("off")


@contextmanager
def enabled(mode: str = "trace", *, sync: bool | None = None):
    """Scoped enable: raise the mode for the duration, restore after.

    Upgrade-only — ``enabled("metrics")`` inside a process already in
    "trace" mode keeps tracing (a spec-level knob never silences a
    global ``obs.enable``); ``enabled("off")`` is a no-op context.
    """
    global _mode, _enabled, _sync
    _check_mode(mode)
    if _MODE_RANK[mode] <= _MODE_RANK[_mode]:
        yield
        return
    with _lock:
        prev = (_mode, _enabled, _sync)
        _mode = mode
        _enabled = True
        if sync is not None:
            _sync = bool(sync)
    try:
        yield
    finally:
        with _lock:
            _mode, _enabled, _sync = prev


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

class _NoopSpan:
    """Shared disabled-mode span: every call is a no-op, ``span()``
    returns this one instance — zero allocation on the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def attach(self, value):
        return value

    def set(self, **attrs):
        return None


NOOP_SPAN = _NoopSpan()


class _Span:
    __slots__ = ("name", "attrs", "_t0", "_pending")

    def __init__(self, name: str, attrs: dict | None):
        self.name = name
        self.attrs = attrs
        self._pending = None

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def attach(self, value):
        """Mark a jax pytree to block on before the span closes (sync
        mode) so the duration covers the device work, not the dispatch."""
        self._pending = value
        return value

    def set(self, **attrs):
        """Add attributes after entry (e.g. results only known inside)."""
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)

    def __exit__(self, *exc):
        if self._pending is not None and _sync:
            import jax

            jax.block_until_ready(self._pending)
        t1 = time.perf_counter_ns()
        _record(self.name, self._t0, t1 - self._t0, self.attrs)
        return False


def span(name: str, **attrs) -> _Span | _NoopSpan:
    """Context manager timing one region. Disabled mode: one branch,
    returns the shared no-op instance."""
    if not _enabled:
        return NOOP_SPAN
    return _Span(name, attrs or None)


def _record(name: str, t0_ns: int, dur_ns: int, attrs) -> None:
    global _dropped
    dur_s = dur_ns * 1e-9
    collectors = getattr(_tls, "collectors", None)
    if collectors:
        for d in collectors:
            d[name] = d.get(name, 0.0) + dur_s
    _metrics.DEFAULT_REGISTRY.histogram(f"span.{name}").observe(dur_s)
    if _mode == "trace":
        with _lock:
            if len(_events) < MAX_EVENTS:
                _events.append(
                    (name, t0_ns, dur_ns, threading.get_ident(), attrs)
                )
            else:
                _dropped += 1


@contextmanager
def collect_timings():
    """Aggregate same-thread span durations by name for the duration.

    Yields a dict that fills with ``{span name: total seconds}`` —
    nested spans each contribute their own name (a parent's time
    includes its children's, as in any trace viewer). Empty when
    observability is off. This is what populates
    ``SolveReport.timings``.
    """
    d: dict = {}
    if not _enabled:
        yield d
        return
    stack = getattr(_tls, "collectors", None)
    if stack is None:
        stack = _tls.collectors = []
    stack.append(d)
    try:
        yield d
    finally:
        stack.remove(d)


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------

def trace_events() -> list:
    """Copy of the recorded raw events (name, t0_ns, dur_ns, tid, attrs)."""
    with _lock:
        return list(_events)


def reset() -> None:
    """Drop every recorded event (mode is unchanged)."""
    global _dropped
    with _lock:
        _events.clear()
        _dropped = 0


def export_trace(path: str) -> dict:
    """Write the buffer as Chrome-trace JSON (Perfetto / chrome://tracing).

    Complete events (``ph: "X"``) with microsecond ``ts`` (relative to
    the first recorded span) and ``dur``, one ``tid`` per recording
    thread, span attributes under ``args``. Returns the document (also
    handy for tests). The buffer is kept — call :func:`reset` to start a
    fresh window.
    """
    with _lock:
        events = list(_events)
        dropped = _dropped
    t_base = min((e[1] for e in events), default=0)
    tids = {}
    trace_events_out = []
    for name, t0_ns, dur_ns, tid_raw, attrs in events:
        tid = tids.setdefault(tid_raw, len(tids))
        ev = {
            "name": name,
            "ph": "X",
            "ts": (t0_ns - t_base) / 1e3,
            "dur": dur_ns / 1e3,
            "pid": 0,
            "tid": tid,
        }
        if attrs:
            ev["args"] = {k: _json_safe(v) for k, v in attrs.items()}
        trace_events_out.append(ev)
    meta = [
        {"name": "process_name", "ph": "M", "pid": 0,
         "args": {"name": "repro"}},
    ] + [
        {"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
         "args": {"name": f"thread-{tid}"}}
        for tid in sorted(tids.values())
    ]
    doc = {
        "traceEvents": meta + trace_events_out,
        "displayTimeUnit": "ms",
        "otherData": {"dropped_events": dropped, "source": "repro.obs"},
    }
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def _json_safe(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    try:
        return int(v)  # numpy / jax scalars
    except (TypeError, ValueError):
        return str(v)
