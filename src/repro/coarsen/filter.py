"""Edge filtering between contraction levels (DESIGN.md §7.3).

Relabels the edge list into supervertex space, drops self-loops (edges
internal to a contracted component) and deduplicates parallel edges
keeping the minimum-(w, eid)-lex representative. Dropping the heavier
parallels is *exact* under the distinct (w, eid) total order: parallel
supervertex edges close a cycle through the two contracted components,
and the cycle property excludes every non-minimal one from the MSF.

All-device, single jitted call with static shapes:

1. canonical pair keys — packed uint32 ``lo << 16 | hi`` when n ≤ 2^16,
   the (lo, hi) pair beyond (int64 keys are unavailable without
   jax_enable_x64) — lexsorted with (w, eid) as trailing keys so each
   pair run leads with its (w, eid)-lex minimum;
2. sort → duplicate pairs become adjacent; segment ids by boundary-flag
   prefix-sum (≤ E segments, independent of n′² — invalid entries sort
   last into one dead segment, so live segments are already
   front-compacted);
3. per-segment MINWEIGHT via the pack32 segment-min in the
   integer-weight regime, the 3-pass masked float reduction
   (``semiring.segment_argmin``) otherwise. The segment ids here are
   *sorted* (a prefix-sum over sort-order boundary flags), so the
   matching Pallas backend is ``kernels.segment_min_sorted`` — O(E)
   lanes via scalar-prefetched per-row-block offsets, vs the flat
   kernel's O(E²/block_rows) rescan at ``num_segments = E``
   (``segmin=None``/"jnp" keeps this step at O(E) via segment_min);
4. gather the winners' (lo, hi, w, global eid).

Original global eids ride through untouched — the level output is still
expressed in input-graph edge ids.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.semiring import (
    IMAX,
    INF,
    PACK_IDENTITY,
    pack32,
    segment_argmin,
    unpack32,
)
from repro.coarsen.relabel import relabel_edges

#: largest vertex count for the packed uint32 pair-key sort path
PAIR_PACK_LIMIT = 1 << 16


class FilterResult(NamedTuple):
    """Deduped canonical edges, indexed by segment (front-packed: entries
    [0, m_new) are the live unique pairs, the rest carry valid=False)."""

    lo: jax.Array  # int32 [E]
    hi: jax.Array  # int32 [E]
    w: jax.Array  # float32 [E]
    eid: jax.Array  # int32 [E] — original global eids
    valid: jax.Array  # bool [E]
    m_new: jax.Array  # int32 scalar: number of unique live pairs


@partial(jax.jit, static_argnames=("n", "pack", "segmin"))
def filter_level(
    und_lo: jax.Array,
    und_hi: jax.Array,
    w: jax.Array,
    eid: jax.Array,
    valid: jax.Array,
    new_ids: jax.Array,
    *,
    n: int,
    pack: bool = False,
    segmin=None,
) -> FilterResult:
    """Jitted wrapper around :func:`filter_level_impl` (same contract)."""
    return filter_level_impl(
        und_lo, und_hi, w, eid, valid, new_ids, n=n, pack=pack, segmin=segmin
    )


def filter_level_impl(
    und_lo: jax.Array,
    und_hi: jax.Array,
    w: jax.Array,
    eid: jax.Array,
    valid: jax.Array,
    new_ids: jax.Array,
    *,
    n: int,
    pack: bool = False,
    segmin=None,
) -> FilterResult:
    """Relabel into supervertex space, drop self-loops, dedupe parallels.

    Unjitted trace body — the distributed fused level calls this directly
    *inside* ``shard_map`` on its local [Emax] edge block (each device
    sort-dedupes its own block; cross-device parallels survive, which is
    exact — they are non-minimal on a cycle and the hook reduction's
    cross-device combine never picks them while the lighter copy lives).
    Standalone callers use the jitted :func:`filter_level`.

    Takes the *undirected* canonical arrays (one entry per edge, not the
    symmetric directed form) — both directions relabel to the same
    canonical pair, so sorting the directed form would double the
    dominant argsort for no information. ``n`` is the previous level's
    (static) vertex count — the bound on relabeled ids used for sort
    sentinels. ``pack`` requires integral weights in [0, 255] and global
    eids < 2^24 − 1 (the (w, eid) pair is packed jointly, so the sort
    only orders the pair key and the segment-min settles the winner).

    Output entries beyond ``m_new`` are sanitized to the identity
    (lo = hi = 0, w = +inf, eid = IMAX, valid = False) so the arrays can
    feed the next level — or a device residual — without a host pass.
    """
    e = und_lo.shape[0]
    if e == 0:
        # Fully contracted level: nothing to sort — the boundary flag
        # construction below would otherwise build a length-1 array
        # against zero-length sort keys. Return the empty residual.
        z_i = jnp.zeros((0,), jnp.int32)
        return FilterResult(
            lo=z_i,
            hi=z_i,
            w=jnp.zeros((0,), w.dtype),
            eid=z_i,
            valid=jnp.zeros((0,), bool),
            m_new=jnp.int32(0),
        )
    ns, nd = relabel_edges(new_ids, und_lo, und_hi)
    lo = jnp.minimum(ns, nd)
    hi = jnp.maximum(ns, nd)
    real = valid & (lo != hi)

    if pack:
        # Pack (w, eid) into one min-reducible value: the sort then only
        # has to make duplicate pairs adjacent (single pair key — the
        # dominant cost at CPU sort speeds), and the segment-min picks
        # the (w, eid)-lex representative without position bookkeeping.
        w_int = jnp.where(real, w, 0.0).astype(jnp.uint32)
        wkey = jnp.where(real, pack32(w_int, eid), PACK_IDENTITY)
        if n <= PAIR_PACK_LIMIT:
            # Two-operand variadic sort: the pair key orders, the packed
            # value rides along — no order permutation to materialize and
            # the winning pair decodes straight from the key.
            key = (lo.astype(jnp.uint32) << 16) | hi.astype(jnp.uint32)
            key = jnp.where(real, key, jnp.uint32(0xFFFFFFFF))
            key_s, wkey_s = jax.lax.sort((key, wkey), num_keys=1)
            boundary = jnp.concatenate(
                [jnp.ones((1,), bool), key_s[1:] != key_s[:-1]]
            )
            seg = jnp.cumsum(boundary.astype(jnp.int32)) - 1  # [0, E) ranks
            if segmin is None:
                minkey = jax.ops.segment_min(wkey_s, seg, num_segments=e)
            else:
                minkey = segmin(wkey_s, seg, e)
            seg_live = minkey != PACK_IDENTITY
            w_min, eid_min = unpack32(minkey)
            # Every member of a segment carries the identical pair key, so
            # a duplicate-index scatter is deterministic and recovers it.
            keyseg = jnp.zeros((e,), jnp.uint32).at[seg].set(key_s)
            lo_out = (keyseg >> 16).astype(jnp.int32)
            hi_out = (keyseg & jnp.uint32(0xFFFF)).astype(jnp.int32)
        else:
            lo_k = jnp.where(real, lo, jnp.int32(n))
            hi_k = jnp.where(real, hi, jnp.int32(n))
            lo_s, hi_s, wkey_s = jax.lax.sort((lo_k, hi_k, wkey), num_keys=2)
            boundary = jnp.concatenate(
                [
                    jnp.ones((1,), bool),
                    (lo_s[1:] != lo_s[:-1]) | (hi_s[1:] != hi_s[:-1]),
                ]
            )
            seg = jnp.cumsum(boundary.astype(jnp.int32)) - 1
            if segmin is None:
                minkey = jax.ops.segment_min(wkey_s, seg, num_segments=e)
            else:
                minkey = segmin(wkey_s, seg, e)
            seg_live = minkey != PACK_IDENTITY
            w_min, eid_min = unpack32(minkey)
            lo_out = jnp.zeros((e,), jnp.int32).at[seg].set(lo_s)
            hi_out = jnp.zeros((e,), jnp.int32).at[seg].set(hi_s)
        return FilterResult(
            lo=jnp.where(seg_live, lo_out, 0),
            hi=jnp.where(seg_live, hi_out, 0),
            w=jnp.where(seg_live, w_min.astype(w.dtype), INF),
            eid=jnp.where(seg_live, eid_min, IMAX),
            valid=seg_live,
            m_new=jnp.sum(seg_live.astype(jnp.int32)),
        )

    # Float path: sort by (pair key, w, eid) so within each pair run the
    # (w, eid)-lex minimum comes first and the min-*position* winner IS
    # the representative — position alone would tie-break equal weights
    # by array order, which stops tracking eid order after the first
    # level.
    if n <= PAIR_PACK_LIMIT:
        key = (lo.astype(jnp.uint32) << 16) | hi.astype(jnp.uint32)
        key = jnp.where(real, key, jnp.uint32(0xFFFFFFFF))
        order = jnp.lexsort((eid, w, key))
        key_s = key[order]
        boundary = jnp.concatenate(
            [jnp.ones((1,), bool), key_s[1:] != key_s[:-1]]
        )
    else:
        lo_k = jnp.where(real, lo, jnp.int32(n))
        hi_k = jnp.where(real, hi, jnp.int32(n))
        order = jnp.lexsort((eid, w, hi_k, lo_k))
        lo_ks, hi_ks = lo_k[order], hi_k[order]
        boundary = jnp.concatenate(
            [
                jnp.ones((1,), bool),
                (lo_ks[1:] != lo_ks[:-1]) | (hi_ks[1:] != hi_ks[:-1]),
            ]
        )
    lo_s, hi_s = lo[order], hi[order]
    w_s, eid_s = w[order], eid[order]
    real_s = real[order]
    seg = jnp.cumsum(boundary.astype(jnp.int32)) - 1  # [0, E) ranks
    pos = jnp.arange(e, dtype=jnp.int32)

    em = segment_argmin(w_s, pos, (), seg, e, valid=real_s)
    winner = em.eid
    seg_live = em.w < INF

    sel = jnp.clip(winner, 0, e - 1)
    return FilterResult(
        lo=jnp.where(seg_live, lo_s[sel], 0),
        hi=jnp.where(seg_live, hi_s[sel], 0),
        w=jnp.where(seg_live, w_s[sel], INF),
        eid=jnp.where(seg_live, eid_s[sel], IMAX),
        valid=seg_live,
        m_new=jnp.sum(seg_live.astype(jnp.int32)),
    )


def filter_level_callback(
    und_lo: jax.Array,
    und_hi: jax.Array,
    w: jax.Array,
    eid: jax.Array,
    valid: jax.Array,
    new_ids: jax.Array,
    *,
    n: int,
) -> FilterResult:
    """:func:`filter_level` twin that routes the dedupe through the host
    (``jax.pure_callback`` around :func:`filter_level_host`), with the
    same static-capacity padded outputs.

    This is the CPU materialization of the *fused* level's dedupe stage:
    on CPU backends device and host share memory, so the callback is a
    plain function call (no transfer), and numpy's radix/lexsort beats
    XLA's CPU sort ~5×. The trace stays a single jitted executable; on
    TPU the engine picks :func:`filter_level` instead (the sort and the
    sorted-segment Pallas kernel stay on device — a host hop there would
    cost a PCIe round-trip per level, the very thing fusion removes).
    """
    e = und_lo.shape[0]
    if e == 0:
        z_i = jnp.zeros((0,), jnp.int32)
        return FilterResult(
            lo=z_i,
            hi=z_i,
            w=jnp.zeros((0,), w.dtype),
            eid=z_i,
            valid=jnp.zeros((0,), bool),
            m_new=jnp.int32(0),
        )

    def _host(lo_h, hi_h, w_h, eid_h, valid_h, new_ids_h):
        import numpy as np

        l2, h2, w2, e2 = filter_level_host(
            lo_h, hi_h, w_h, eid_h, valid_h, new_ids_h, n
        )
        m = len(l2)
        out_lo = np.zeros(e, np.int32)
        out_hi = np.zeros(e, np.int32)
        out_w = np.full(e, np.inf, np.float32)
        out_eid = np.full(e, np.iinfo(np.int32).max, np.int32)
        out_lo[:m], out_hi[:m] = l2, h2
        out_w[:m], out_eid[:m] = w2, e2
        return out_lo, out_hi, out_w, out_eid, np.int32(m)

    s = jax.ShapeDtypeStruct
    lo2, hi2, w2, eid2, m_new = jax.pure_callback(
        _host,
        (
            s((e,), jnp.int32),
            s((e,), jnp.int32),
            s((e,), jnp.float32),
            s((e,), jnp.int32),
            s((), jnp.int32),
        ),
        und_lo, und_hi, w, eid, valid, new_ids,
    )
    return FilterResult(
        lo=lo2,
        hi=hi2,
        w=w2.astype(w.dtype),
        eid=eid2,
        valid=jnp.arange(e) < m_new,
        m_new=m_new,
    )


def filter_level_host(lo, hi, w, eid, valid, new_ids, n: int):
    """Host (numpy) twin of :func:`filter_level` — same policy, returns
    compact unpadded arrays (lo, hi, w, eid).

    The engine is host-driven between levels anyway, and numpy's lexsort
    beats XLA's CPU sort by an order of magnitude, so this is the CPU
    backend of the ``dedupe="auto"`` switch (the jitted pipeline is the
    TPU path, where the sort and the pack32 segment-min stay on device).
    """
    import numpy as np

    from repro.graphs.structures import canonical_edges, edge_keys

    new_ids = np.asarray(new_ids)
    ns, nd = new_ids[np.asarray(lo)], new_ids[np.asarray(hi)]
    l, h, keep = canonical_edges(ns, nd)
    real = np.asarray(valid) & keep
    l, h = l[real], h[real]
    w, eid = np.asarray(w)[real], np.asarray(eid)[real]
    key = edge_keys(l, h, n)  # shared collision-free pair key
    order = np.lexsort((eid, w, key))  # per pair: min (w, eid) first
    key_s = key[order]
    first = np.ones(len(key_s), bool)
    first[1:] = key_s[1:] != key_s[:-1]
    idx = order[first]
    return l[idx], h[idx], w[idx], eid[idx]
