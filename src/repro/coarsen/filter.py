"""Edge filtering between contraction levels (DESIGN.md §7.3).

Relabels the edge list into supervertex space, drops self-loops (edges
internal to a contracted component) and deduplicates parallel edges
keeping the minimum-(w, eid)-lex representative. Dropping the heavier
parallels is *exact* under the distinct (w, eid) total order: parallel
supervertex edges close a cycle through the two contracted components,
and the cycle property excludes every non-minimal one from the MSF.

All-device, single jitted call with static shapes:

1. canonical pair keys — packed uint32 ``lo << 16 | hi`` when n ≤ 2^16,
   the (lo, hi) pair beyond (int64 keys are unavailable without
   jax_enable_x64) — lexsorted with (w, eid) as trailing keys so each
   pair run leads with its (w, eid)-lex minimum;
2. sort → duplicate pairs become adjacent; segment ids by boundary-flag
   prefix-sum (≤ E segments, independent of n′² — invalid entries sort
   last into one dead segment, so live segments are already
   front-compacted);
3. per-segment MINWEIGHT via the pack32 segment-min (Pallas flat kernel
   or ``jax.ops.segment_min``) in the integer-weight regime, the 3-pass
   masked float reduction (``semiring.segment_argmin``) otherwise.
   Caveat: this reduction has ``num_segments = E``, so the flat Pallas
   kernel's compare-broadcast sweep costs O(E²/block_rows) lanes here —
   acceptable only for modest levels; the segment ids are *sorted*, and
   a contiguous-range kernel exploiting that is a ROADMAP follow-up
   (``segmin=None``/"jnp" keeps this step at O(E) via segment_min);
4. gather the winners' (lo, hi, w, global eid).

Original global eids ride through untouched — the level output is still
expressed in input-graph edge ids.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.semiring import INF, PACK_IDENTITY, pack32, unpack32, segment_argmin
from repro.coarsen.relabel import relabel_edges

#: largest vertex count for the packed uint32 pair-key sort path
PAIR_PACK_LIMIT = 1 << 16


class FilterResult(NamedTuple):
    """Deduped canonical edges, indexed by segment (front-packed: entries
    [0, m_new) are the live unique pairs, the rest carry valid=False)."""

    lo: jax.Array  # int32 [E]
    hi: jax.Array  # int32 [E]
    w: jax.Array  # float32 [E]
    eid: jax.Array  # int32 [E] — original global eids
    valid: jax.Array  # bool [E]
    m_new: jax.Array  # int32 scalar: number of unique live pairs


@partial(jax.jit, static_argnames=("n", "pack", "segmin"))
def filter_level(
    und_lo: jax.Array,
    und_hi: jax.Array,
    w: jax.Array,
    eid: jax.Array,
    valid: jax.Array,
    new_ids: jax.Array,
    *,
    n: int,
    pack: bool = False,
    segmin=None,
) -> FilterResult:
    """Relabel into supervertex space, drop self-loops, dedupe parallels.

    Takes the *undirected* canonical arrays (one entry per edge, not the
    symmetric directed form) — both directions relabel to the same
    canonical pair, so sorting the directed form would double the
    dominant argsort for no information. ``n`` is the previous level's
    (static) vertex count — the bound on relabeled ids used for sort
    sentinels. ``pack`` requires integral weights in [0, 255] and
    E < 2^24 − 1 (the position index is packed).
    """
    e = und_lo.shape[0]
    ns, nd = relabel_edges(new_ids, und_lo, und_hi)
    lo = jnp.minimum(ns, nd)
    hi = jnp.maximum(ns, nd)
    real = valid & (lo != hi)

    # Sort by (pair key, w, eid): duplicates become adjacent AND within
    # each pair run the (w, eid)-lex minimum comes first, so the
    # min-*position* winner below IS the (w, eid)-min representative —
    # position alone would tie-break equal weights by array order, which
    # stops tracking eid order after the first level.
    if n <= PAIR_PACK_LIMIT:
        key = (lo.astype(jnp.uint32) << 16) | hi.astype(jnp.uint32)
        key = jnp.where(real, key, jnp.uint32(0xFFFFFFFF))
        order = jnp.lexsort((eid, w, key))
        key_s = key[order]
        boundary = jnp.concatenate(
            [jnp.ones((1,), bool), key_s[1:] != key_s[:-1]]
        )
    else:
        lo_k = jnp.where(real, lo, jnp.int32(n))
        hi_k = jnp.where(real, hi, jnp.int32(n))
        order = jnp.lexsort((eid, w, hi_k, lo_k))
        lo_ks, hi_ks = lo_k[order], hi_k[order]
        boundary = jnp.concatenate(
            [
                jnp.ones((1,), bool),
                (lo_ks[1:] != lo_ks[:-1]) | (hi_ks[1:] != hi_ks[:-1]),
            ]
        )
    lo_s, hi_s = lo[order], hi[order]
    w_s, eid_s = w[order], eid[order]
    real_s = real[order]
    seg = jnp.cumsum(boundary.astype(jnp.int32)) - 1  # [0, E) ranks
    pos = jnp.arange(e, dtype=jnp.int32)

    if pack:
        w_int = jnp.where(real_s, w_s, 0.0).astype(jnp.uint32)
        kmin = jnp.where(real_s, pack32(w_int, pos), PACK_IDENTITY)
        if segmin is None:
            minkey = jax.ops.segment_min(kmin, seg, num_segments=e)
        else:
            minkey = segmin(kmin, seg, e)
        _, winner = unpack32(minkey)
        seg_live = minkey != PACK_IDENTITY
    else:
        em = segment_argmin(w_s, pos, (), seg, e, valid=real_s)
        winner = em.eid
        seg_live = em.w < INF

    sel = jnp.clip(winner, 0, e - 1)
    return FilterResult(
        lo=lo_s[sel],
        hi=hi_s[sel],
        w=w_s[sel],
        eid=eid_s[sel],
        valid=seg_live,
        m_new=jnp.sum(seg_live.astype(jnp.int32)),
    )


def filter_level_host(lo, hi, w, eid, valid, new_ids, n: int):
    """Host (numpy) twin of :func:`filter_level` — same policy, returns
    compact unpadded arrays (lo, hi, w, eid).

    The engine is host-driven between levels anyway, and numpy's lexsort
    beats XLA's CPU sort by an order of magnitude, so this is the CPU
    backend of the ``dedupe="auto"`` switch (the jitted pipeline is the
    TPU path, where the sort and the pack32 segment-min stay on device).
    """
    import numpy as np

    from repro.graphs.structures import canonical_edges, edge_keys

    new_ids = np.asarray(new_ids)
    ns, nd = new_ids[np.asarray(lo)], new_ids[np.asarray(hi)]
    l, h, keep = canonical_edges(ns, nd)
    real = np.asarray(valid) & keep
    l, h = l[real], h[real]
    w, eid = np.asarray(w)[real], np.asarray(eid)[real]
    key = edge_keys(l, h, n)  # shared collision-free pair key
    order = np.lexsort((eid, w, key))  # per pair: min (w, eid) first
    key_s = key[order]
    first = np.ones(len(key_s), bool)
    first[1:] = key_s[1:] != key_s[:-1]
    idx = order[first]
    return l[idx], h[idx], w[idx], eid[idx]
