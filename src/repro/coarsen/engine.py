"""Coarsening engine: alternate contract and filter levels, then hand the
residual graph to the flat AS solver (DESIGN.md §7).

Each level runs K hook+shortcut rounds (``contract.contract_level``), a
device-side rank/relabel, and the sort-dedupe edge filter
(``filter.filter_level``). Both n and m shrink geometrically, so the
dense O(n) vector work and the O(m) multilinear sweeps of the flat
solver only ever touch the *current* level's padded arrays. When the
supervertex count drops below ``cutoff`` (or edges run out, or a level
stops making progress), the residual graph goes to ``core.msf``.

Shapes are re-padded to powers of two between levels (host-driven, like
the streaming engine), so compiled executables are bounded by
log2(E) × levels rather than one per input.

Invariants (DESIGN.md §7.4):
- every hooked edge is an MSF edge of the *original* graph (cut property
  under the distinct (w, eid) total order), recorded by global eid;
- filtering is exact: a dropped parallel edge closes a cycle on which it
  is not the (w, eid)-minimum (cycle property);
- ``label_map`` composes the per-level relabelings, so original-vertex
  component labels are a single gather at the end.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import numpy as np

from repro.coarsen.contract import contract_level
from repro.coarsen.filter import filter_level, filter_level_host
from repro.core.msf import MSFResult, msf as _flat_msf
from repro.core.semiring import PACK_IDX_MASK
from repro.graphs.partition import Partition2D, partition_edges_2d
from repro.graphs.structures import Graph, graph_from_canonical
from repro.stream.service import next_pow2

_IMAX = np.int32(np.iinfo(np.int32).max)


@dataclasses.dataclass(frozen=True)
class CoarsenConfig:
    """Static knobs of the contract-and-filter pipeline (hashable — safe
    to thread through jit-static plumbing)."""

    rounds_per_level: int = 2  # K hook+shortcut rounds per level
    cutoff: int = 2048  # hand off to core.msf when n ≤ cutoff
    max_levels: int = 16
    pack: bool | None = None  # pack32 level kernels; None = auto-detect
    segmin: str | None = None  # packed segment-min backend ("jnp"/"pallas"/"auto")
    # Edge-dedupe backend: the jitted sort + pack32 segment-min pipeline
    # ("device", the TPU path) or the numpy lexsort twin ("host" — the
    # engine is host-driven between levels, and numpy's sort beats XLA's
    # CPU sort by ~10x). "auto" picks by jax.default_backend().
    dedupe: str = "auto"

    def __post_init__(self):
        if self.rounds_per_level < 1:
            raise ValueError("rounds_per_level must be >= 1")
        if self.cutoff < 1:
            raise ValueError("cutoff must be >= 1")
        if self.dedupe not in ("auto", "device", "host"):
            raise ValueError(f"unknown dedupe backend {self.dedupe!r}")


class LevelStats(NamedTuple):
    n: int  # vertices entering the level
    m: int  # undirected edges entering the level
    n_next: int  # supervertices after contraction
    m_next: int  # unique live pairs after filtering
    hooked: int  # MSF edges recorded this level


class CoarsenStats(NamedTuple):
    levels: Tuple[LevelStats, ...]
    residual_n: int
    residual_m: int


class CoarsenPrelude(NamedTuple):
    """Everything the contraction levels decided, residual not yet solved."""

    weight: float  # MSF weight hooked across all levels
    msf_eids: np.ndarray  # global eids of level-hooked MSF edges
    label_map: np.ndarray  # int32 [n0]: original vertex → residual vertex id
    residual: Graph  # canonical symmetric residual graph
    stats: CoarsenStats


def _next_pow2(k: int) -> int:
    return next_pow2(k, floor=8)  # edge buffers tolerate a smaller floor


def _auto_pack(w: np.ndarray, eid: np.ndarray, valid: np.ndarray, e_dir: int) -> bool:
    """pack32 applies when weights are integral in [0, 255] and both the
    global eids and the per-level position indices fit 24 bits strictly."""
    if e_dir >= PACK_IDX_MASK:
        return False
    wv = w[valid]
    if wv.size == 0:
        return True
    if not (np.all(wv == np.floor(wv)) and wv.min() >= 0 and wv.max() <= 255):
        return False
    return int(eid[valid].max()) < PACK_IDX_MASK


def _canonical_host(graph: Graph):
    """Host copies of the undirected (lo < hi) edge set, pow2-padded."""
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    w = np.asarray(graph.w)
    eid = np.asarray(graph.eid)
    valid = np.asarray(graph.valid)
    sel = valid & (src < dst)
    m0 = int(sel.sum())
    pad = _next_pow2(m0)
    lo = np.zeros(pad, np.int32)
    hi = np.zeros(pad, np.int32)
    ww = np.full(pad, np.inf, np.float32)
    ee = np.full(pad, _IMAX, np.int32)
    vv = np.zeros(pad, bool)
    lo[:m0], hi[:m0] = src[sel], dst[sel]
    ww[:m0], ee[:m0] = w[sel], eid[sel]
    vv[:m0] = True
    return lo, hi, ww, ee, vv, m0


def run_levels(graph: Graph, config: CoarsenConfig | None = None) -> CoarsenPrelude:
    """Contract-and-filter until the cutoff; return the residual + prelude."""
    cfg = config or CoarsenConfig()
    n0 = graph.n
    lo, hi, w, eid, valid, m_cur = _canonical_host(graph)
    use_pack = (
        _auto_pack(np.asarray(graph.w), np.asarray(graph.eid),
                   np.asarray(graph.valid), 2 * len(lo))
        if cfg.pack is None
        else cfg.pack
    )
    segmin_fn = None
    if use_pack and cfg.segmin not in (None, "jnp"):
        from repro.kernels.ops import make_packed_segmin

        segmin_fn = make_packed_segmin(cfg.segmin)
    dedupe = cfg.dedupe
    if dedupe == "auto":
        dedupe = "device" if jax.default_backend() == "tpu" else "host"

    label_map = np.arange(n0, dtype=np.int32)
    weight = 0.0
    eids_acc: list[np.ndarray] = []
    stats: list[LevelStats] = []
    n_cur = n0

    while len(stats) < cfg.max_levels and n_cur > cfg.cutoff and m_cur > 0:
        # Vertex dim is jit-static: pad to pow2 so executables are keyed
        # by (pow2 n, pow2 E) buckets and reused across levels/graphs
        # instead of one compile per exact supervertex count. Padding
        # vertices are isolated → they stay roots; their ranks trail the
        # real ones (padding ids sit above every real id, and the rank
        # prefix-sum only counts roots at smaller ids), so real
        # supervertex ids remain contiguous in [0, R).
        n_pad = next_pow2(n_cur, floor=8)
        src = np.concatenate([lo, hi])
        dst = np.concatenate([hi, lo])
        w2 = np.concatenate([w, w])
        eid2 = np.concatenate([eid, eid])
        valid2 = np.concatenate([valid, valid])
        res = contract_level(
            src, dst, w2, eid2, valid2,
            n=n_pad, rounds=cfg.rounds_per_level,
            pack=use_pack, segmin=segmin_fn,
        )
        n_next = int(res.n_next) - (n_pad - n_cur)  # drop padding roots
        if n_next == n_cur:  # every component already complete
            break
        n_f = int(res.n_msf_edges)
        eids_acc.append(np.asarray(res.msf_eids[:n_f]))
        weight += float(res.weight)
        if dedupe == "host":
            l2, h2, w2_, e2_ = filter_level_host(
                lo, hi, w, eid, valid, res.new_ids, n_cur
            )
            m_next = len(l2)
            pad = _next_pow2(m_next)
            lo = np.zeros(pad, np.int32)
            hi = np.zeros(pad, np.int32)
            w = np.full(pad, np.inf, np.float32)
            eid = np.full(pad, _IMAX, np.int32)
            lo[:m_next], hi[:m_next] = l2, h2
            w[:m_next], eid[:m_next] = w2_, e2_
        else:
            fr = filter_level(
                lo, hi, w, eid, valid, res.new_ids,
                n=n_pad, pack=use_pack, segmin=segmin_fn,
            )
            m_next = int(fr.m_new)
            pad = _next_pow2(m_next)
            lo = np.asarray(fr.lo[:pad])
            hi = np.asarray(fr.hi[:pad])
            w = np.asarray(fr.w[:pad])
            eid = np.asarray(fr.eid[:pad])
        label_map = np.asarray(res.new_ids)[label_map]
        stats.append(LevelStats(n=n_cur, m=m_cur, n_next=n_next,
                                m_next=m_next, hooked=n_f))
        valid = np.arange(pad) < m_next  # filter output is front-packed
        n_cur, m_cur = n_next, m_next

    # Residual n is pow2-padded too (padding vertices are isolated
    # singleton components, never referenced by label_map) — the flat
    # solve and the 2D partition then also reuse executables across
    # similar graphs instead of compiling per exact supervertex count.
    residual = graph_from_canonical(
        lo, hi, w, eid, valid, next_pow2(n_cur, floor=8)
    )
    return CoarsenPrelude(
        weight=weight,
        msf_eids=(
            np.concatenate(eids_acc) if eids_acc else np.zeros(0, np.int32)
        ),
        label_map=label_map,
        residual=residual,
        stats=CoarsenStats(levels=tuple(stats), residual_n=n_cur,
                           residual_m=m_cur),
    )


def _finalize(
    prelude: CoarsenPrelude,
    residual_parent: np.ndarray,
    residual_eids: np.ndarray,
    residual_weight: float,
    residual_iters: int,
    n0: int,
    rounds_per_level: int,
) -> MSFResult:
    """Merge level picks with the residual solve into one MSFResult in
    original-graph vertex/edge ids."""
    all_eids = np.concatenate([prelude.msf_eids, residual_eids])
    msf_eids = np.full(n0, _IMAX, np.int32)
    msf_eids[: len(all_eids)] = all_eids
    comp = residual_parent[prelude.label_map]  # [n0] residual-space labels
    # Canonical original-vertex labels: min original vertex per component.
    reps = np.full(len(residual_parent), n0, np.int64)
    np.minimum.at(reps, comp, np.arange(n0))
    parent = reps[comp].astype(np.int32)
    return MSFResult(
        weight=np.float32(prelude.weight + residual_weight),
        parent=parent,
        msf_eids=msf_eids,
        n_msf_edges=np.int32(len(all_eids)),
        iterations=np.int32(
            len(prelude.stats.levels) * rounds_per_level + residual_iters
        ),
    )


class CoarsenMSF:
    """Reusable engine front-end: holds a config, records per-run stats.

    ``msf_kw`` (variant/shortcut/capacity/pack/segmin/...) is forwarded
    to the residual ``core.msf`` call; ``config`` controls the levels.
    The result is expressed in input-graph ids: ``msf_eids`` are global
    eids, and ``parent`` labels components by their minimum original
    vertex.
    """

    def __init__(self, config: CoarsenConfig | None = None, **msf_kw):
        self.config = config or CoarsenConfig()
        # segmin only parameterizes the pack=True inner loop of core.msf;
        # for a float residual it would be rejected there, so keep it for
        # the levels (via config) but only forward alongside pack=True.
        if not msf_kw.get("pack"):
            msf_kw.pop("segmin", None)
        self.msf_kw = msf_kw
        self.last_stats: CoarsenStats | None = None

    def __call__(self, graph: Graph) -> MSFResult:
        prelude = run_levels(graph, self.config)
        r = _flat_msf(prelude.residual, **self.msf_kw)
        self.last_stats = prelude.stats
        return _finalize(
            prelude,
            np.asarray(r.parent),
            np.asarray(r.msf_eids)[: int(r.n_msf_edges)],
            float(r.weight),
            int(r.iterations),
            graph.n,
            self.config.rounds_per_level,
        )


def coarsen_msf(
    graph: Graph,
    *,
    config: CoarsenConfig | None = None,
    segmin: str | None = None,
    **msf_kw,
) -> MSFResult:
    """One-shot form of :class:`CoarsenMSF`; ``segmin`` (when given)
    applies to the level kernels — overriding ``config.segmin`` — and,
    with ``pack=True``, the residual. Callers that need the per-level
    :class:`CoarsenStats` should hold a :class:`CoarsenMSF` instance
    (its ``last_stats`` is per-instance, not shared global state)."""
    cfg = config or CoarsenConfig()
    if segmin is not None:
        cfg = dataclasses.replace(cfg, segmin=segmin)
    return CoarsenMSF(cfg, segmin=segmin, **msf_kw)(graph)


# ---------------------------------------------------------------------------
# Partition2D-aware pre-contraction for the distributed engine
# ---------------------------------------------------------------------------

def precontract_partition(
    graph: Graph,
    rows: int,
    cols: int,
    *,
    config: CoarsenConfig | None = None,
) -> Tuple[Partition2D, CoarsenPrelude]:
    """Coarsen first, then 2D-partition only the residual graph.

    The paper's Fig-2 schedule pays all_gathers proportional to n and
    local work proportional to the device's edge block — both shrink with
    the contracted residual, so the distributed solve runs on a graph
    whose n/m the levels already cut geometrically. Use
    :func:`merge_distributed` to fold the ``msf_distributed`` result back
    into original-graph ids.
    """
    prelude = run_levels(graph, config)
    part = partition_edges_2d(prelude.residual, rows, cols)
    return part, prelude


def merge_distributed(prelude: CoarsenPrelude, dist_result) -> MSFResult:
    """Combine a ``DistMSFResult`` over the residual with the prelude."""
    cfg_rounds = 1  # iterations bookkeeping only; levels already counted
    return _finalize(
        prelude,
        np.asarray(dist_result.parent),
        np.asarray(dist_result.msf_eids)[: int(dist_result.n_msf_edges)],
        float(dist_result.weight),
        int(dist_result.iterations),
        len(prelude.label_map),
        cfg_rounds,
    )
