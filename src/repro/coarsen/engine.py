"""Coarsening engine: alternate contract and filter levels, then hand the
residual graph to the flat AS solver (DESIGN.md §7).

Each level runs K hook+shortcut rounds (``contract.contract_level``), a
device-side rank/relabel, and the sort-dedupe edge filter
(``filter.filter_level``). Both n and m shrink geometrically, so the
dense O(n) vector work and the O(m) multilinear sweeps of the flat
solver only ever touch the *current* level's padded arrays. When the
supervertex count drops below ``cutoff`` (or edges run out, or a level
stops making progress), the residual graph goes to ``core.msf``.

Shapes are re-padded to powers of two between levels (host-driven, like
the streaming engine), so compiled executables are bounded by
log2(E) × levels rather than one per input.

Invariants (DESIGN.md §7.4):
- every hooked edge is an MSF edge of the *original* graph (cut property
  under the distinct (w, eid) total order), recorded by global eid;
- filtering is exact: a dropped parallel edge closes a cycle on which it
  is not the (w, eid)-minimum (cycle property);
- ``label_map`` composes the per-level relabelings, so original-vertex
  component labels are a single gather at the end.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.coarsen.config import CoarsenConfig
from repro.coarsen.contract import (
    ContractResult,
    contract_level,
    contract_level_und,
    hook_rounds,
    make_und_reduce,
)
from repro.coarsen.filter import (
    filter_level,
    filter_level_callback,
    filter_level_host,
)
from repro.coarsen.relabel import rank_relabel
from repro.core.msf import MSFResult, flat_msf as _flat_msf
from repro.graphs.partition import Partition2D, partition_edges_2d
from repro.graphs.structures import Graph, graph_from_canonical
from repro.solve.spec import auto_pack, resolve_dedupe, resolve_level_segmins
from repro.stream.service import next_pow2

_IMAX = np.int32(np.iinfo(np.int32).max)


class LevelStats(NamedTuple):
    n: int  # vertices entering the level
    m: int  # undirected edges entering the level
    n_next: int  # supervertices after contraction
    m_next: int  # unique live pairs after filtering
    hooked: int  # MSF edges recorded this level


class CoarsenStats(NamedTuple):
    levels: Tuple[LevelStats, ...]
    residual_n: int
    residual_m: int


class CoarsenPrelude(NamedTuple):
    """Everything the contraction levels decided, residual not yet solved."""

    weight: float  # MSF weight hooked across all levels
    msf_eids: np.ndarray  # global eids of level-hooked MSF edges
    label_map: np.ndarray  # int32 [n0]: original vertex → residual vertex id
    residual: Graph  # canonical symmetric residual graph
    stats: CoarsenStats
    # hook+shortcut rounds the levels actually ran (levels × rounds_per_level)
    # — threaded so finalizers report true iteration counts instead of
    # re-deriving them from a config they may not see (merge_distributed
    # used to hard-code 1 round per level and under-report).
    level_iters: int = 0


def _next_pow2(k: int) -> int:
    return next_pow2(k, floor=8)  # edge buffers tolerate a smaller floor


def _eid_capacity(eid: np.ndarray, m0: int) -> int:
    """Static pow2 bound on the global eids carried by the levels — sizes
    the eid→position hook-payload table of ``contract_level_und``."""
    if m0 == 0:
        return 8
    return _next_pow2(int(np.asarray(eid[:m0]).max()) + 1)


def _canonical_host(graph: Graph):
    """Host copies of the undirected (lo < hi) edge set, pow2-padded."""
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    w = np.asarray(graph.w)
    eid = np.asarray(graph.eid)
    valid = np.asarray(graph.valid)
    sel = valid & (src < dst)
    m0 = int(sel.sum())
    pad = _next_pow2(m0)
    lo = np.zeros(pad, np.int32)
    hi = np.zeros(pad, np.int32)
    ww = np.full(pad, np.inf, np.float32)
    ee = np.full(pad, _IMAX, np.int32)
    vv = np.zeros(pad, bool)
    lo[:m0], hi[:m0] = src[sel], dst[sel]
    ww[:m0], ee[:m0] = w[sel], eid[sel]
    vv[:m0] = True
    return lo, hi, ww, ee, vv, m0


class FusedLevel(NamedTuple):
    """One coarsening level's outputs, all device-resident, edge arrays at
    the (static) input capacity with live entries front-packed."""

    lo: jax.Array  # int32 [E] — supervertex pairs, lo < hi
    hi: jax.Array  # int32 [E]
    w: jax.Array  # float32 [E]; +inf beyond m_new
    eid: jax.Array  # int32 [E] — original global eids; IMAX beyond m_new
    valid: jax.Array  # bool [E]
    m_new: jax.Array  # int32 scalar: unique live pairs
    new_ids: jax.Array  # int32 [n]: vertex → supervertex rank
    n_next: jax.Array  # int32 scalar: supervertex count (incl. padding roots)
    weight: jax.Array  # float32 scalar: weight hooked this level
    msf_eids: jax.Array  # int32 [n]: global eids hooked (front-packed)
    n_msf_edges: jax.Array  # int32 scalar
    label_map: jax.Array  # int32 [n0]: original vertex → supervertex id


@partial(
    jax.jit,
    static_argnames=(
        "n", "eid_capacity", "rounds", "pack", "segmin", "segmin_dedupe",
        "dedupe_host",
    ),
)
def fused_level(
    lo: jax.Array,
    hi: jax.Array,
    w: jax.Array,
    eid: jax.Array,
    valid: jax.Array,
    label_map: jax.Array,
    *,
    n: int,
    eid_capacity: int,
    rounds: int = 2,
    pack: bool = False,
    segmin=None,
    segmin_dedupe=None,
    dedupe_host: bool = False,
) -> FusedLevel:
    """One whole coarsening level under a single jit (DESIGN.md §7.6).

    contract (K hook+shortcut rounds) → rank_relabel → sort → sorted-
    segment dedupe → compaction, with zero host round-trips inside the
    level. Compaction is device-side and comes out of the dedupe's
    prefix-sum: segment ids are ranks of the sorted pair keys (a cumsum
    over boundary flags), invalid entries sort last, so scattering each
    segment's winner to its rank front-packs the live edges — the
    engine's between-level re-pad is then a device slice, not a host
    gather. Dead tail slots are sanitized to the sort sentinels
    (w = +inf, eid = IMAX) so the next level's dedupe ordering stays
    exact under the (w, eid) total order.

    Inputs are the *undirected* canonical arrays at a static pow2
    capacity; ``label_map`` is the [n0] original-vertex composition,
    threaded through so it too stays device-resident. One executable per
    (n, edge-capacity, n0) shape triple.

    ``dedupe_host=True`` swaps the dedupe stage for the zero-copy host
    callback (:func:`filter_level_callback`) — the CPU backend of
    ``dedupe="auto"``, where XLA's sort loses ~5× to numpy's; on TPU the
    engine keeps the device pipeline (sort + sorted-segment Pallas
    kernel) so the level never leaves the accelerator.
    """
    res = contract_level_und(
        lo, hi, w, eid, valid,
        n=n, eid_capacity=eid_capacity, rounds=rounds, pack=pack, segmin=segmin,
    )
    if dedupe_host:
        fr = filter_level_callback(
            lo, hi, w, eid, valid, res.new_ids, n=n
        )
    else:
        fr = filter_level(
            lo, hi, w, eid, valid, res.new_ids, n=n, pack=pack,
            segmin=segmin_dedupe,
        )
    return FusedLevel(
        lo=fr.lo,  # filter sanitizes dead slots to the sort identities
        hi=fr.hi,
        w=fr.w,
        eid=fr.eid,
        valid=fr.valid,
        m_new=fr.m_new,
        new_ids=res.new_ids,
        n_next=res.n_next,
        weight=res.weight,
        msf_eids=res.msf_eids,
        n_msf_edges=res.n_msf_edges,
        label_map=res.new_ids[label_map],
    )


@partial(
    jax.jit,
    static_argnames=("n", "eid_capacity", "rounds", "pack", "segmin"),
)
def _hook_rounds_und(
    lo, hi, w, eid, valid, *, n, eid_capacity, rounds, pack, segmin=None
):
    """The contraction phase of :func:`contract_level_und` alone — K
    hook+shortcut rounds, no relabel tail. Only the obs trace path uses
    this; the production level keeps the single fused executable."""
    reduce_fn = make_und_reduce(
        lo, hi, w, eid, valid,
        n=n, eid_capacity=eid_capacity, pack=pack, segmin=segmin,
    )
    return hook_rounds(reduce_fn, n, rounds)


_rank_relabel_jit = jax.jit(rank_relabel)


def _traced_contract(lo, hi, w, eid, valid, *, n, eid_capacity, rounds,
                     pack, segmin) -> ContractResult:
    """contract → relabel as two spanned, synced executables. Same
    numbers as :func:`contract_level_und` (identical kernel composition);
    the split exists so Perfetto shows the phases (DESIGN.md §10.3)."""
    with obs.span("coarsen.contract", n=n, rounds=rounds) as sp:
        p, weight, msf_eids, n_f = sp.attach(_hook_rounds_und(
            lo, hi, w, eid, valid,
            n=n, eid_capacity=eid_capacity, rounds=rounds, pack=pack,
            segmin=segmin,
        ))
    with obs.span("coarsen.relabel", n=n) as sp:
        new_ids, n_next = sp.attach(_rank_relabel_jit(p))
    return ContractResult(
        parent=p, new_ids=new_ids, n_next=n_next, weight=weight,
        msf_eids=msf_eids, n_msf_edges=n_f,
    )


def _traced_fused_level(
    lo, hi, w, eid, valid, label_map, *, n, eid_capacity, rounds, pack,
    segmin, segmin_dedupe, dedupe_host,
) -> FusedLevel:
    """Trace-mode twin of :func:`fused_level`: the same level computation
    as three separately-dispatched executables (contract, relabel,
    filter), each under a device-synced span. Bit-identical outputs —
    every phase is the same jitted piece the fused executable inlines —
    at the cost of per-phase dispatch+sync; that asymmetry is the
    documented profiler contract (obs="trace" measures phase costs,
    obs="metrics"/"off" keep the one-jit production path)."""
    res = _traced_contract(
        lo, hi, w, eid, valid,
        n=n, eid_capacity=eid_capacity, rounds=rounds, pack=pack,
        segmin=segmin,
    )
    with obs.span("coarsen.filter", n=n, host=dedupe_host) as sp:
        if dedupe_host:
            fr = filter_level_callback(
                lo, hi, w, eid, valid, res.new_ids, n=n
            )
        else:
            fr = filter_level(
                lo, hi, w, eid, valid, res.new_ids, n=n, pack=pack,
                segmin=segmin_dedupe,
            )
        fr = sp.attach(fr)
    return FusedLevel(
        lo=fr.lo, hi=fr.hi, w=fr.w, eid=fr.eid, valid=fr.valid,
        m_new=fr.m_new, new_ids=res.new_ids, n_next=res.n_next,
        weight=res.weight, msf_eids=res.msf_eids,
        n_msf_edges=res.n_msf_edges, label_map=res.new_ids[label_map],
    )


def _run_levels_fused(
    graph: Graph, cfg: CoarsenConfig, use_pack: bool, canon
) -> CoarsenPrelude:
    """Level loop over :func:`fused_level`: edge arrays and ``label_map``
    stay on device across levels; only per-level scalars (n_next, m_new)
    and the hooked eids cross to the host for loop control/bookkeeping."""
    segmin_hook, segmin_dedupe = resolve_level_segmins(cfg.segmin, use_pack)
    dedupe = resolve_dedupe(cfg.dedupe)
    n0 = graph.n
    lo_h, hi_h, w_h, eid_h, valid_h, m_cur = canon
    eid_cap = _eid_capacity(eid_h, m_cur)
    lo, hi = jnp.asarray(lo_h), jnp.asarray(hi_h)
    w, eid, valid = jnp.asarray(w_h), jnp.asarray(eid_h), jnp.asarray(valid_h)
    label_map = jnp.arange(n0, dtype=jnp.int32)

    weight = 0.0
    eids_acc: list[np.ndarray] = []
    stats: list[LevelStats] = []
    n_cur = n0

    traced = obs.trace_active()
    while len(stats) < cfg.max_levels and n_cur > cfg.cutoff and m_cur > 0:
        n_pad = next_pow2(n_cur, floor=8)
        with obs.span("coarsen.level", level=len(stats), n=n_cur,
                      m=m_cur) as lsp:
            level_fn = _traced_fused_level if traced else fused_level
            res = lsp.attach(level_fn(
                lo, hi, w, eid, valid, label_map,
                n=n_pad, eid_capacity=eid_cap, rounds=cfg.rounds_per_level,
                pack=use_pack, segmin=segmin_hook,
                segmin_dedupe=segmin_dedupe, dedupe_host=dedupe == "host",
            ))
        n_next = int(res.n_next) - (n_pad - n_cur)  # drop padding roots
        if n_next == n_cur:  # every component already complete
            break
        n_f = int(res.n_msf_edges)
        eids_acc.append(np.asarray(res.msf_eids[:n_f]))
        weight += float(res.weight)
        m_next = int(res.m_new)
        pad = _next_pow2(m_next)
        lo, hi, w, eid, valid = (
            res.lo[:pad], res.hi[:pad], res.w[:pad], res.eid[:pad],
            res.valid[:pad],
        )
        label_map = res.label_map
        stats.append(LevelStats(n=n_cur, m=m_cur, n_next=n_next,
                                m_next=m_next, hooked=n_f))
        n_cur, m_cur = n_next, m_next

    residual = graph_from_canonical(
        lo, hi, w, eid, valid, next_pow2(n_cur, floor=8)
    )
    return CoarsenPrelude(
        weight=weight,
        msf_eids=(
            np.concatenate(eids_acc) if eids_acc else np.zeros(0, np.int32)
        ),
        label_map=np.asarray(label_map),
        residual=residual,
        stats=CoarsenStats(levels=tuple(stats), residual_n=n_cur,
                           residual_m=m_cur),
        level_iters=len(stats) * cfg.rounds_per_level,
    )


def run_levels(graph: Graph, config: CoarsenConfig | None = None) -> CoarsenPrelude:
    """Contract-and-filter until the cutoff; return the residual + prelude."""
    cfg = config or CoarsenConfig()
    n0 = graph.n
    lo, hi, w, eid, valid, m_cur = _canonical_host(graph)
    use_pack = (
        auto_pack(np.asarray(graph.w), np.asarray(graph.eid),
                  np.asarray(graph.valid), 2 * len(lo))
        if cfg.pack is None
        else cfg.pack
    )
    if cfg.fused:
        return _run_levels_fused(
            graph, cfg, use_pack, (lo, hi, w, eid, valid, m_cur)
        )
    segmin_fn, segmin_dedupe_fn = resolve_level_segmins(cfg.segmin, use_pack)
    dedupe = resolve_dedupe(cfg.dedupe)
    eid_cap = _eid_capacity(eid, m_cur)

    label_map = np.arange(n0, dtype=np.int32)
    weight = 0.0
    eids_acc: list[np.ndarray] = []
    stats: list[LevelStats] = []
    n_cur = n0

    while len(stats) < cfg.max_levels and n_cur > cfg.cutoff and m_cur > 0:
        # Vertex dim is jit-static: pad to pow2 so executables are keyed
        # by (pow2 n, pow2 E) buckets and reused across levels/graphs
        # instead of one compile per exact supervertex count. Padding
        # vertices are isolated → they stay roots; their ranks trail the
        # real ones (padding ids sit above every real id, and the rank
        # prefix-sum only counts roots at smaller ids), so real
        # supervertex ids remain contiguous in [0, R).
        n_pad = next_pow2(n_cur, floor=8)
        with obs.span("coarsen.level", level=len(stats), n=n_cur, m=m_cur):
            if obs.trace_active():
                res = _traced_contract(
                    lo, hi, w, eid, valid,
                    n=n_pad, eid_capacity=eid_cap,
                    rounds=cfg.rounds_per_level, pack=use_pack,
                    segmin=segmin_fn,
                )
            else:
                res = contract_level_und(
                    lo, hi, w, eid, valid,
                    n=n_pad, eid_capacity=eid_cap,
                    rounds=cfg.rounds_per_level,
                    pack=use_pack, segmin=segmin_fn,
                )
            n_next = int(res.n_next) - (n_pad - n_cur)  # drop padding roots
            if n_next == n_cur:  # every component already complete
                break
            n_f = int(res.n_msf_edges)
            eids_acc.append(np.asarray(res.msf_eids[:n_f]))
            weight += float(res.weight)
            with obs.span("coarsen.filter", n=n_pad,
                          host=dedupe == "host") as fsp:
                if dedupe == "host":
                    l2, h2, w2_, e2_ = filter_level_host(
                        lo, hi, w, eid, valid, res.new_ids, n_cur
                    )
                    m_next = len(l2)
                    pad = _next_pow2(m_next)
                    lo = np.zeros(pad, np.int32)
                    hi = np.zeros(pad, np.int32)
                    w = np.full(pad, np.inf, np.float32)
                    eid = np.full(pad, _IMAX, np.int32)
                    lo[:m_next], hi[:m_next] = l2, h2
                    w[:m_next], eid[:m_next] = w2_, e2_
                else:
                    fr = fsp.attach(filter_level(
                        lo, hi, w, eid, valid, res.new_ids,
                        n=n_pad, pack=use_pack, segmin=segmin_dedupe_fn,
                    ))
                    m_next = int(fr.m_new)
                    pad = _next_pow2(m_next)
                    lo = np.asarray(fr.lo[:pad])
                    hi = np.asarray(fr.hi[:pad])
                    w = np.asarray(fr.w[:pad])
                    eid = np.asarray(fr.eid[:pad])
            label_map = np.asarray(res.new_ids)[label_map]
            stats.append(LevelStats(n=n_cur, m=m_cur, n_next=n_next,
                                    m_next=m_next, hooked=n_f))
            valid = np.arange(pad) < m_next  # filter is front-packed
            n_cur, m_cur = n_next, m_next

    # Residual n is pow2-padded too (padding vertices are isolated
    # singleton components, never referenced by label_map) — the flat
    # solve and the 2D partition then also reuse executables across
    # similar graphs instead of compiling per exact supervertex count.
    residual = graph_from_canonical(
        lo, hi, w, eid, valid, next_pow2(n_cur, floor=8)
    )
    return CoarsenPrelude(
        weight=weight,
        msf_eids=(
            np.concatenate(eids_acc) if eids_acc else np.zeros(0, np.int32)
        ),
        label_map=label_map,
        residual=residual,
        stats=CoarsenStats(levels=tuple(stats), residual_n=n_cur,
                           residual_m=m_cur),
        level_iters=len(stats) * cfg.rounds_per_level,
    )


def _finalize(
    prelude: CoarsenPrelude,
    residual_parent: np.ndarray,
    residual_eids: np.ndarray,
    residual_weight: float,
    residual_iters: int,
    n0: int,
) -> MSFResult:
    """Merge level picks with the residual solve into one MSFResult in
    original-graph vertex/edge ids."""
    from repro.coarsen.relabel import canonical_minvertex_labels

    all_eids = np.concatenate([prelude.msf_eids, residual_eids])
    msf_eids = np.full(n0, _IMAX, np.int32)
    msf_eids[: len(all_eids)] = all_eids
    comp = residual_parent[prelude.label_map]  # [n0] residual-space labels
    return MSFResult(
        weight=np.float32(prelude.weight + residual_weight),
        parent=canonical_minvertex_labels(comp, len(residual_parent)),
        msf_eids=msf_eids,
        n_msf_edges=np.int32(len(all_eids)),
        iterations=np.int32(prelude.level_iters + residual_iters),
    )


class CoarsenMSF:
    """Reusable engine front-end: holds a config, records per-run stats.

    ``msf_kw`` (variant/shortcut/capacity/pack/segmin/...) is forwarded
    to the residual ``core.msf`` call; ``config`` controls the levels.
    The result is expressed in input-graph ids: ``msf_eids`` are global
    eids, and ``parent`` labels components by their minimum original
    vertex.
    """

    def __init__(self, config: CoarsenConfig | None = None, **msf_kw):
        self.config = config or CoarsenConfig()
        # segmin only parameterizes the pack=True inner loop of core.msf;
        # for a float residual it would be ignored there, so keep it for
        # the levels (via config) but only forward alongside pack=True.
        # (The residual call goes through ``core.msf.flat_msf``, whose
        # backend resolution — including the "sorted"-degrades rule for
        # unsorted hook segments — lives in ``repro.solve.spec``.)
        if not msf_kw.get("pack"):
            msf_kw.pop("segmin", None)
        self.msf_kw = msf_kw
        self.last_stats: CoarsenStats | None = None

    def __call__(self, graph: Graph) -> MSFResult:
        with obs.span("coarsen.levels", n=graph.n):
            prelude = run_levels(graph, self.config)
        with obs.span("coarsen.residual", n=prelude.residual.n,
                      m=prelude.stats.residual_m) as sp:
            r = sp.attach(_flat_msf(prelude.residual, **self.msf_kw))
        self.last_stats = prelude.stats
        return _finalize(
            prelude,
            np.asarray(r.parent),
            np.asarray(r.msf_eids)[: int(r.n_msf_edges)],
            float(r.weight),
            int(r.iterations),
            graph.n,
        )


def coarsen_msf(
    graph: Graph,
    *,
    config: CoarsenConfig | None = None,
    segmin: str | None = None,
    fused: bool | None = None,
    **msf_kw,
) -> MSFResult:
    """One-shot form of :class:`CoarsenMSF`; ``segmin`` (when given)
    applies to the level kernels — overriding ``config.segmin`` — and,
    with ``pack=True``, the residual; ``fused`` (when given) overrides
    ``config.fused``. Callers that need the per-level
    :class:`CoarsenStats` should hold a :class:`CoarsenMSF` instance
    (its ``last_stats`` is per-instance, not shared global state)."""
    cfg = config or CoarsenConfig()
    if segmin is not None:
        cfg = dataclasses.replace(cfg, segmin=segmin)
    if fused is not None:
        cfg = dataclasses.replace(cfg, fused=fused)
    return CoarsenMSF(cfg, segmin=segmin, **msf_kw)(graph)


# ---------------------------------------------------------------------------
# Partition2D-aware pre-contraction for the distributed engine
# ---------------------------------------------------------------------------

def precontract_partition(
    graph: Graph,
    rows: int,
    cols: int,
    *,
    config: CoarsenConfig | None = None,
) -> Tuple[Partition2D, CoarsenPrelude]:
    """Coarsen on the host first, then 2D-partition only the residual.

    The paper's Fig-2 schedule pays all_gathers proportional to n and
    local work proportional to the device's edge block — both shrink with
    the contracted residual, so the distributed solve runs on a graph
    whose n/m the levels already cut geometrically. Use
    :func:`merge_distributed` to fold the ``msf_distributed`` result back
    into original-graph ids.

    This is the **host-prelude** pipeline (every level round-trips edge
    arrays off-device); the production distributed path is
    ``msf_distributed(part_of_original_graph, mesh, coarsen=config)``,
    which runs the same levels inside ``shard_map`` with zero per-level
    host re-partitions (``repro.coarsen.dist``, DESIGN.md §8) and keeps
    this pipeline as its measured baseline.
    """
    prelude = run_levels(graph, config)
    part = partition_edges_2d(prelude.residual, rows, cols)
    return part, prelude


def merge_distributed(prelude: CoarsenPrelude, dist_result) -> MSFResult:
    """Combine a ``DistMSFResult`` over the residual with the prelude.

    ``iterations`` adds the rounds the levels actually ran
    (``prelude.level_iters``) to the distributed solve's count — it used
    to hard-code one round per level and under-report whenever
    ``rounds_per_level > 1``.
    """
    return _finalize(
        prelude,
        np.asarray(dist_result.parent),
        np.asarray(dist_result.msf_eids)[: int(dist_result.n_msf_edges)],
        float(dist_result.weight),
        int(dist_result.iterations),
        len(prelude.label_map),
    )
