"""Device-side supervertex rank/relabel pass (DESIGN.md §7.2).

After K hook+shortcut rounds every tree is a star, so the parent vector
``p`` is a component labeling by *root vertex id*. Contraction renames
each root to its **rank** — a dense prefix-sum over root indicators —
producing contiguous supervertex ids in [0, n′). Fully jittable: one
cumsum + two gathers, no host round-trip.

Invariant threading: ``new_ids[v]`` is defined for every vertex (its
root's rank), so edge relabeling and the original-vertex → supervertex
``label_map`` composition are plain gathers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rank_relabel(p: jax.Array):
    """Star-canonical parent vector → (new_ids, n_next).

    new_ids: int32 [n], the supervertex id (root rank) of every vertex;
    n_next: int32 scalar, the number of supervertices (= number of roots,
    including isolated vertices, which stay their own supervertex).
    """
    n = p.shape[0]
    i = jnp.arange(n, dtype=p.dtype)
    is_root = p == i
    rank = jnp.cumsum(is_root.astype(jnp.int32)) - 1  # root v ↦ #roots ≤ v − 1
    new_ids = rank[p]  # every vertex inherits its root's rank
    return new_ids, jnp.sum(is_root.astype(jnp.int32))


def relabel_edges(new_ids: jax.Array, src: jax.Array, dst: jax.Array):
    """Edge endpoints in the previous level's vertex space → supervertex ids."""
    return new_ids[src], new_ids[dst]


def compose_labels(label_map: jax.Array, new_ids: jax.Array) -> jax.Array:
    """original vertex → current-level id, composed with one more level.

    ``new_ids`` already routes through the level's parent vector
    (new_ids[v] = rank of v's root), so composition is a single gather.
    """
    return new_ids[label_map]


def canonical_minvertex_labels(comp, comp_space: int):
    """Host-side canonical component labels: each original vertex gets the
    *minimum original vertex* of its component.

    ``comp`` is an int [n0] numpy array of component ids (any id space of
    size ``comp_space``, e.g. residual-solve root ids gathered through the
    level ``label_map``). Shared by the coarsening finalizer and the
    distributed fused engine so both report identical ``parent`` vectors.
    """
    import numpy as np

    comp = np.asarray(comp)
    n0 = len(comp)
    reps = np.full(comp_space, n0, np.int64)
    np.minimum.at(reps, comp, np.arange(n0))
    return reps[comp].astype(np.int32)
