"""Distributed fused coarsening levels under ``shard_map`` (DESIGN.md §8).

The PR-2 distributed hook (`precontract_partition`) coarsens on the host
and only then 2D-partitions the residual: every level round-trips the
edge arrays off-device — the exact cost `fused_level` removed for the
single-device path. This module runs the same contract → relabel →
filter level **inside the mesh**, on the `Partition2D` [R, C, Emax] edge
blocks, so nothing but control scalars and the hooked eids ever leaves
the devices:

- edges are re-keyed once from block-local offsets to **global** vertex
  ids (`graphs.partition.block_global_ids`) — after the first relabel the
  (row_of, col_of) block alignment is gone, so the Fig-2 row/col-block
  gathers stop applying and each round instead reduces local per-root
  partials into a dense [n] accumulator combined across the mesh by the
  existing MINWEIGHT semiring (`make_und_reduce` with an
  all-reduce(min) ``combine`` — DESIGN.md §2's masked passes);
- the supervertex rank vector (`rank_relabel` of the replicated parent)
  is materialized once per level and each device re-keys its block
  locally, then sort-dedupes it in place (`filter_level_impl` on the
  local [Emax] block — the sorted-segment Pallas segmin on TPU).
  Cross-device parallels survive local dedupe; that is exact (they are
  non-minimal on a cycle, and the hook combine never selects them while
  the lighter copy lives), and the per-block m still shrinks
  geometrically, so between-level capacity cuts are device-side slices
  of the blocks' (unsharded) edge dim — zero host re-partitions;
- after the levels stop (cutoff / no progress / max_levels), the
  **residual solve stays in-mesh too**: hook+shortcut rounds over the
  same globally-keyed blocks in one `lax.while_loop` until no root
  hooks. The parent vector is replicated per level (n has shrunk
  geometrically by then), so shortcutting is local pointer-jumping —
  the CSP/OS machinery of `core.msf_dist` addresses the big-n regime
  this path contracts away.

``dedupe="host"`` keeps a per-level host fallback for CPU CI: contraction
still runs in-mesh, but the blocks hop to the host for the numpy
lexsort dedupe (`filter_level_host` per block) — L round-trips, counted
in ``DistCoarsenStats.host_roundtrips`` (0 for the in-mesh path).

Entry point: ``core.msf_dist.msf_distributed(part, mesh, coarsen=cfg)``
returns a :class:`DistCoarsenMSF` driver with the same call signature as
the flat distributed driver; results are an ``MSFResult`` in
original-graph vertex/edge ids (directly comparable to
``msf(graph, coarsen=cfg, fused=True)``).
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.compat import shard_map
from repro.coarsen.contract import contract_rounds, make_und_reduce
from repro.coarsen.config import CoarsenConfig
from repro.coarsen.engine import LevelStats, _next_pow2
from repro.coarsen.filter import filter_level_host, filter_level_impl
from repro.coarsen.relabel import canonical_minvertex_labels
from repro.core.msf import MSFResult, hook_and_tiebreak, record_edges
from repro.core.semiring import IMAX
from repro.core.shortcut import complete_shortcut
from repro.graphs.partition import Partition2D, block_global_ids
from repro.solve.spec import auto_pack, resolve_dedupe, resolve_level_segmins

_IMAX_NP = np.int32(np.iinfo(np.int32).max)


def _account_allreduce(rounds: int, n_pad: int, pack: bool) -> None:
    """Analytic all-reduce volume of ``rounds`` cross-device contract
    rounds over a dense [n_pad] accumulator: the pack path combines two
    dense passes per round (packed minkey + payload), the float path
    three (minw, mineid, payload) — exactly the ``combine`` call sites of
    :func:`make_und_reduce`. Host-side schedule accounting, not a device
    measurement: the counters mirror what the compiled program does."""
    if not obs.metrics_active():
        return
    passes = (2 if pack else 3) * rounds
    obs.counter("dist.allreduce.passes").inc(passes)
    obs.counter("dist.allreduce.elements").inc(passes * n_pad)


class DistCoarsenStats(NamedTuple):
    """Per-run surface of the distributed fused level pipeline.

    ``m`` counts are *block entries*: directed copies at level 0 (each
    undirected edge enters twice, wherever the 2D partition put its two
    directions), per-block-unique canonical pairs afterwards — a pair
    duplicated across devices counts once per device (local dedupe only).
    """

    levels: Tuple[LevelStats, ...]
    residual_n: int
    residual_m: int  # block entries handed to the in-mesh residual solve
    residual_iters: int  # hook+shortcut rounds the residual solve ran
    host_roundtrips: int  # per-level block round-trips (0 = in-mesh dedupe)


def _mesh_min(x, row_axis, col_axis):
    """All-reduce(min) over the whole mesh — one masked MINWEIGHT pass."""
    return lax.pmin(lax.pmin(x, col_axis), row_axis)


def _flat(a):
    return a.reshape(a.shape[-1:])


@lru_cache(maxsize=None)
def _level_driver(
    mesh, row_axis, col_axis, n, eid_capacity, rounds, pack,
    segmin_hook, segmin_dedupe, with_filter,
):
    """Jitted shard_map'ed level: K cross-device contract rounds +
    rank/relabel (replicated) + local per-block re-key/sort-dedupe.

    Cached per static signature so repeat levels of the same (n, capacity)
    shape reuse one executable, exactly like the single-device
    ``fused_level`` (jax.jit handles the per-edge-capacity retraces).
    """

    def fn(lo, hi, w, eid, valid, label_map):
        shp = lo.shape
        lo1, hi1, w1 = _flat(lo), _flat(hi), _flat(w)
        eid1, valid1 = _flat(eid), _flat(valid)
        reduce_fn = make_und_reduce(
            lo1, hi1, w1, eid1, valid1,
            n=n, eid_capacity=eid_capacity, pack=pack, segmin=segmin_hook,
            combine=partial(_mesh_min, row_axis=row_axis, col_axis=col_axis),
        )
        res = contract_rounds(reduce_fn, n, rounds)
        if with_filter:
            fr = filter_level_impl(
                lo1, hi1, w1, eid1, valid1, res.new_ids,
                n=n, pack=pack, segmin=segmin_dedupe,
            )
            m_local = fr.m_new
            out = (fr.lo, fr.hi, fr.w, fr.eid, fr.valid)
        else:  # dedupe="host": blocks pass through untouched
            m_local = jnp.sum(valid1.astype(jnp.int32))
            out = (lo1, hi1, w1, eid1, valid1)
        m_max = lax.pmax(lax.pmax(m_local, col_axis), row_axis)
        m_total = lax.psum(lax.psum(m_local, col_axis), row_axis)
        return (
            tuple(a.reshape(shp) for a in out)
            + (res.new_ids[label_map], res.new_ids, res.n_next, res.weight,
               res.msf_eids, res.n_msf_edges, m_max, m_total)
        )

    specs_e = P(row_axis, col_axis, None)
    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(specs_e,) * 5 + (P(),),
        out_specs=(specs_e,) * 5 + (P(),) * 8,
        check_vma=False,
    )
    return jax.jit(mapped)


@lru_cache(maxsize=None)
def _residual_driver(
    mesh, row_axis, col_axis, n, eid_capacity, pack, segmin_hook, limit,
):
    """In-mesh residual solve: hook+shortcut rounds over the globally-keyed
    blocks until no root hooks (or ``limit``), one ``lax.while_loop``."""

    def fn(lo, hi, w, eid, valid):
        lo1, hi1, w1 = _flat(lo), _flat(hi), _flat(w)
        eid1, valid1 = _flat(eid), _flat(valid)
        reduce_fn = make_und_reduce(
            lo1, hi1, w1, eid1, valid1,
            n=n, eid_capacity=eid_capacity, pack=pack, segmin=segmin_hook,
            combine=partial(_mesh_min, row_axis=row_axis, col_axis=col_axis),
        )

        def body(state):
            p, total, msf_eids, n_f, it, _ = state
            r = reduce_fn(p)
            p_h, keep, _ = hook_and_tiebreak(p, r.w, r.eid, r.payload[0])
            total = total + jnp.sum(jnp.where(keep, r.w, 0.0))
            msf_eids, n_f = record_edges(msf_eids, n_f, keep, r.eid)
            p_next = complete_shortcut(p_h)
            done = ~jnp.any(keep)
            return p_next, total, msf_eids, n_f, it + 1, done

        def cond(state):
            return jnp.logical_and(~state[5], state[4] < limit)

        init = (
            jnp.arange(n, dtype=jnp.int32),
            jnp.float32(0.0),
            jnp.full((n,), IMAX, jnp.int32),
            jnp.int32(0),
            jnp.int32(0),
            jnp.bool_(False),
        )
        p, total, msf_eids, n_f, it, _ = lax.while_loop(cond, body, init)
        return p, total, msf_eids, n_f, it

    specs_e = P(row_axis, col_axis, None)
    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(specs_e,) * 5,
        out_specs=(P(),) * 5,
        check_vma=False,
    )
    return jax.jit(mapped)


def _host_filter_blocks(lo, hi, w, eid, valid, new_ids, n_pad):
    """dedupe="host" level tail: numpy lexsort dedupe per block, repacked
    to a shared pow2 capacity (the explicit CPU-CI round-trip path)."""
    rows, cols = lo.shape[0], lo.shape[1]
    parts = [
        filter_level_host(
            lo[r, s], hi[r, s], w[r, s], eid[r, s], valid[r, s],
            new_ids, n_pad,
        )
        for r in range(rows)
        for s in range(cols)
    ]
    m_max = max(len(p[0]) for p in parts)
    cap = _next_pow2(m_max)
    lo2 = np.zeros((rows, cols, cap), np.int32)
    hi2 = np.zeros((rows, cols, cap), np.int32)
    w2 = np.full((rows, cols, cap), np.inf, np.float32)
    eid2 = np.full((rows, cols, cap), _IMAX_NP, np.int32)
    valid2 = np.zeros((rows, cols, cap), bool)
    m_total = 0
    for k, (l_, h_, w_, e_) in enumerate(parts):
        r, s, m = k // cols, k % cols, len(l_)
        lo2[r, s, :m], hi2[r, s, :m] = l_, h_
        w2[r, s, :m], eid2[r, s, :m] = w_, e_
        valid2[r, s, :m] = True
        m_total += m
    return lo2, hi2, w2, eid2, valid2, m_total


class DistCoarsenMSF:
    """Distributed fused coarsen-and-solve driver over a 2D partition.

    Built by ``msf_distributed(part, mesh, coarsen=config)``; call with
    the partition's block arrays (same signature as the flat distributed
    driver). Returns an :class:`repro.core.msf.MSFResult` in
    original-graph ids; per-run :class:`DistCoarsenStats` land on
    ``last_stats``.

    Config knobs follow the single-device engine: ``dedupe`` "auto"
    resolves to the in-mesh device pipeline on TPU and the per-level host
    fallback elsewhere ("device"/"host" force either); ``pack`` None
    auto-detects the pack32 regime; ``segmin`` picks the packed
    segment-min backends (the dedupe site takes the sorted-segment Pallas
    kernel). ``max_iters`` bounds the residual solve's rounds.
    """

    def __init__(
        self,
        part: Partition2D,
        mesh,
        config: CoarsenConfig | None = None,
        *,
        row_axis: str = "data",
        col_axis: str = "model",
        max_iters: int | None = None,
    ):
        self.part = part
        self.mesh = mesh
        self.config = config or CoarsenConfig()
        self.row_axis = row_axis
        self.col_axis = col_axis
        self.max_iters = max_iters
        self.last_stats: DistCoarsenStats | None = None
        self._prep = None  # last (input refs) → re-keyed blocks + statics

    def _prepare(self, src_row, dst_col, w, eid, valid):
        """Re-key blocks to global ids and derive eid_cap / pack — all
        deterministic functions of the inputs, memoized on the exact input
        arrays (the common case: the driver is called repeatedly with the
        partition's own arrays, e.g. benchmark loops) so repeat calls skip
        the O(E) host scans and the re-keyed upload."""
        refs = (src_row, dst_col, w, eid, valid)
        if self._prep is not None and all(
            a is b for a, b in zip(self._prep[0], refs)
        ):
            return self._prep[1]
        src_g, dst_g = block_global_ids(
            np.asarray(src_row), np.asarray(dst_col), self.part.shard_size
        )
        w_np = np.asarray(w, np.float32)
        eid_np = np.asarray(eid, np.int32)
        valid_np = np.asarray(valid, bool)
        eids_live = eid_np[valid_np]
        eid_cap = (
            _next_pow2(int(eids_live.max()) + 1) if eids_live.size else 8
        )
        use_pack = (
            auto_pack(w_np, eid_np, valid_np, eid_cap)
            if self.config.pack is None
            else self.config.pack
        )
        prep = (src_g, dst_g, w_np, eid_np, valid_np, eid_cap, use_pack)
        self._prep = (refs, prep)
        return prep

    def __call__(self, src_row, dst_col, w, eid, valid) -> MSFResult:
        part, cfg = self.part, self.config
        n0 = part.n
        src_g, dst_g, w_np, eid_np, valid_np, eid_cap, use_pack = (
            self._prepare(src_row, dst_col, w, eid, valid)
        )
        segmin_hook, segmin_dedupe = resolve_level_segmins(cfg.segmin, use_pack)
        in_mesh = resolve_dedupe(cfg.dedupe) != "host"

        lo, hi, w_b, eid_b, valid_b = src_g, dst_g, w_np, eid_np, valid_np
        if in_mesh:
            lo, hi = jnp.asarray(lo), jnp.asarray(hi)
            w_b, eid_b = jnp.asarray(w_b), jnp.asarray(eid_b)
            valid_b = jnp.asarray(valid_b)
            label_map = jnp.arange(n0, dtype=jnp.int32)
        else:
            label_map = np.arange(n0, dtype=np.int32)

        mesh_key = (self.mesh, self.row_axis, self.col_axis)
        n_cur = n0
        m_cur = int(valid_np.sum())
        weight = 0.0
        eids_acc: list[np.ndarray] = []
        stats: list[LevelStats] = []
        roundtrips = 0

        while len(stats) < cfg.max_levels and n_cur > cfg.cutoff and m_cur > 0:
            n_pad = _next_pow2(n_cur)
            drv = _level_driver(
                *mesh_key, n_pad, eid_cap, cfg.rounds_per_level, use_pack,
                segmin_hook, segmin_dedupe, in_mesh,
            )
            with obs.span("dist.level", level=len(stats), n=n_cur,
                          m=m_cur) as lsp:
                out = lsp.attach(drv(lo, hi, w_b, eid_b, valid_b, label_map))
            _account_allreduce(cfg.rounds_per_level, n_pad, use_pack)
            n_next = int(out[7]) - (n_pad - n_cur)  # drop padding roots
            if n_next == n_cur:  # every component already complete
                break
            n_f = int(out[10])
            eids_acc.append(np.asarray(out[9][:n_f]))
            weight += float(out[8])
            if in_mesh:
                m_max, m_total = int(out[11]), int(out[12])
                cap = _next_pow2(m_max)
                lo, hi = out[0][..., :cap], out[1][..., :cap]
                w_b, eid_b = out[2][..., :cap], out[3][..., :cap]
                valid_b = out[4][..., :cap]
                label_map = out[5]
            else:
                new_ids = np.asarray(out[6])
                lo, hi, w_b, eid_b, valid_b, m_total = _host_filter_blocks(
                    np.asarray(lo), np.asarray(hi), np.asarray(w_b),
                    np.asarray(eid_b), np.asarray(valid_b), new_ids, n_pad,
                )
                label_map = new_ids[label_map]
                roundtrips += 1
            stats.append(LevelStats(n=n_cur, m=m_cur, n_next=n_next,
                                    m_next=m_total, hooked=n_f))
            n_cur, m_cur = n_next, m_total

        n_res_pad = _next_pow2(n_cur)
        limit = int(
            self.max_iters
            if self.max_iters is not None
            else 2 * int(n_res_pad).bit_length() + 8
        )
        rdrv = _residual_driver(
            *mesh_key, n_res_pad, eid_cap, use_pack, segmin_hook, limit
        )
        with obs.span("dist.residual", n=n_cur, m=m_cur) as rsp:
            p_res, r_weight, r_eids, r_nf, r_it = rsp.attach(
                rdrv(lo, hi, w_b, eid_b, valid_b)
            )
        # Residual rounds run the same per-round combine schedule.
        _account_allreduce(int(r_it), n_res_pad, use_pack)

        all_eids = np.concatenate(
            eids_acc + [np.asarray(r_eids[: int(r_nf)])]
        ) if eids_acc or int(r_nf) else np.zeros(0, np.int32)
        msf_eids = np.full(n0, _IMAX_NP, np.int32)
        msf_eids[: len(all_eids)] = all_eids
        comp = np.asarray(p_res)[np.asarray(label_map)]
        self.last_stats = DistCoarsenStats(
            levels=tuple(stats),
            residual_n=n_cur,
            residual_m=m_cur,
            residual_iters=int(r_it),
            host_roundtrips=roundtrips,
        )
        return MSFResult(
            weight=np.float32(weight + float(r_weight)),
            parent=canonical_minvertex_labels(comp, n_res_pad),
            msf_eids=msf_eids,
            n_msf_edges=np.int32(len(all_eids)),
            iterations=np.int32(
                len(stats) * cfg.rounds_per_level + int(r_it)
            ),
        )
