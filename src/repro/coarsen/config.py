"""Static configuration of the contract-and-filter pipeline.

Leaf module — imported by the engine, the distributed driver, the
streaming rebuild hook, and the ``repro.solve`` spec layer alike, so it
must not import any of them.
"""
from __future__ import annotations

import dataclasses

#: Every segment-min backend any level kernel understands. "sorted" is
#: dedupe-only (contiguous-range kernel); the hook reductions degrade it
#: to "auto" (`repro.solve.spec.resolve_level_segmins`).
SEGMIN_BACKENDS = (None, "auto", "jnp", "pallas", "sorted")

#: Edge-dedupe backends: "device" = the jitted sort + pack32 segment-min
#: pipeline, "host" = the numpy lexsort twin, "auto" = pick by
#: ``jax.default_backend()`` (resolved in `repro.solve.spec`).
DEDUPE_BACKENDS = ("auto", "device", "host")


@dataclasses.dataclass(frozen=True)
class CoarsenConfig:
    """Static knobs of the contract-and-filter pipeline (hashable — safe
    to thread through jit-static plumbing)."""

    rounds_per_level: int = 2  # K hook+shortcut rounds per level
    cutoff: int = 2048  # hand off to core.msf when n ≤ cutoff
    max_levels: int = 16
    pack: bool | None = None  # pack32 level kernels; None = auto-detect
    # Packed segment-min backend ("jnp"/"pallas"/"sorted"/"auto"). The
    # hook reduction's segment ids are unsorted, so "sorted" there means
    # "auto"; the *dedupe* step's ids are sorted, so "pallas"/"sorted"
    # both select the contiguous-range sorted kernel for it.
    segmin: str | None = None
    # Edge-dedupe backend: the jitted sort + pack32 segment-min pipeline
    # ("device", the TPU path) or the numpy lexsort twin ("host" — the
    # CPU backend, where numpy's sort beats XLA's CPU sort ~5-10x).
    # "auto" picks by jax.default_backend(). Under ``fused=True`` the
    # whole level lives in one jit, and "host" means the dedupe stage
    # hops through a ``pure_callback`` (zero-copy on CPU — device and
    # host share memory there) while everything else stays compiled.
    dedupe: str = "auto"
    # Run each level as one jitted call (contract → relabel → sort-dedupe
    # → device compaction) with static edge-capacity padding, instead of
    # the separate contract jit + host/device filter per level.
    fused: bool = False

    def __post_init__(self):
        if self.rounds_per_level < 1:
            raise ValueError("rounds_per_level must be >= 1")
        if self.cutoff < 1:
            raise ValueError("cutoff must be >= 1")
        if self.max_levels < 1:
            raise ValueError("max_levels must be >= 1")
        if self.dedupe not in DEDUPE_BACKENDS:
            raise ValueError(f"unknown dedupe backend {self.dedupe!r}")
        # segmin used to survive unvalidated until make_packed_segmin blew
        # up deep inside a level kernel; validate it next to dedupe.
        if self.segmin not in SEGMIN_BACKENDS:
            raise ValueError(
                f"unknown segmin backend {self.segmin!r} "
                f"(expected one of {SEGMIN_BACKENDS})"
            )
