# Borůvka contraction + edge-filter coarsening engine (DESIGN.md §7):
# contract-and-filter levels feeding the AS multilinear MSF solver.
from repro.coarsen.contract import (
    ContractResult,
    contract_level,
    contract_level_und,
)
from repro.coarsen.config import CoarsenConfig
from repro.coarsen.engine import (
    CoarsenMSF,
    CoarsenPrelude,
    CoarsenStats,
    FusedLevel,
    LevelStats,
    coarsen_msf,
    fused_level,
    merge_distributed,
    precontract_partition,
    run_levels,
)
from repro.coarsen.dist import DistCoarsenMSF, DistCoarsenStats
from repro.coarsen.filter import (
    FilterResult,
    filter_level,
    filter_level_callback,
    filter_level_host,
)
from repro.coarsen.relabel import compose_labels, rank_relabel, relabel_edges
