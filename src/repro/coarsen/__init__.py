# Borůvka contraction + edge-filter coarsening engine (DESIGN.md §7):
# contract-and-filter levels feeding the AS multilinear MSF solver.
from repro.coarsen.contract import ContractResult, contract_level
from repro.coarsen.engine import (
    CoarsenConfig,
    CoarsenMSF,
    CoarsenPrelude,
    CoarsenStats,
    LevelStats,
    coarsen_msf,
    merge_distributed,
    precontract_partition,
    run_levels,
)
from repro.coarsen.filter import FilterResult, filter_level
from repro.coarsen.relabel import compose_labels, rank_relabel, relabel_edges
