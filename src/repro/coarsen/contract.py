"""Borůvka-style contraction rounds via the AS multilinear kernel (§7.1).

One *level* = K hook+shortcut rounds of the existing multilinear MSF
machinery (``min_outgoing_coo`` → ``hook_and_tiebreak`` →
``complete_shortcut``) starting from singleton stars, followed by the
rank/relabel pass. Each round merges every component with its minimum
outgoing (w, eid)-lex edge — the classic Borůvka step expressed with the
paper's kernels — so K rounds shrink the vertex count by ≥ 2^K wherever
edges remain, and every hooked edge is an MSF edge (cut property under
the distinct (w, eid) total order).

The recorded eids are the graph's *global* edge ids, threaded unchanged
through relabeling and filtering by the engine.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import shortcut as sc
from repro.core.msf import hook_and_tiebreak, record_edges
from repro.core.multilinear import min_outgoing_coo, min_outgoing_coo_packed
from repro.core.semiring import IMAX
from repro.coarsen.relabel import rank_relabel


class ContractResult(NamedTuple):
    parent: jax.Array  # int32 [n]: star-canonical labels after K rounds
    new_ids: jax.Array  # int32 [n]: vertex → supervertex rank in [0, n_next)
    n_next: jax.Array  # int32 scalar: supervertex count
    weight: jax.Array  # float32 scalar: weight hooked this level
    msf_eids: jax.Array  # int32 [n]: global eids chosen this level (front-packed)
    n_msf_edges: jax.Array  # int32 scalar


def hook_rounds(reduce_fn, n: int, rounds: int):
    """K hook+shortcut rounds from singleton stars, *without* the
    rank/relabel tail: ``(parent, weight, msf_eids, n_msf_edges)``.

    Split out of :func:`contract_rounds` so obs trace mode can run the
    contraction and the relabel as separately-timed executables
    (``repro.coarsen.engine`` DESIGN.md §10.3) — both paths compose the
    identical pieces."""
    p = jnp.arange(n, dtype=jnp.int32)
    total = jnp.float32(0.0)
    msf_eids = jnp.full((n,), IMAX, jnp.int32)
    n_f = jnp.int32(0)
    for _ in range(rounds):
        r = reduce_fn(p)
        p_h, keep, _ = hook_and_tiebreak(p, r.w, r.eid, r.payload[0])
        total = total + jnp.sum(jnp.where(keep, r.w, 0.0))
        msf_eids, n_f = record_edges(msf_eids, n_f, keep, r.eid)
        p = sc.complete_shortcut(p_h)
    return p, total, msf_eids, n_f


def contract_rounds(reduce_fn, n: int, rounds: int) -> ContractResult:
    """Shared K-round hook+shortcut driver; ``reduce_fn(p)`` yields the
    per-root MINWEIGHT EdgeMin for the current parent vector.

    Public: the distributed fused level (``repro.coarsen.dist``) runs the
    same rounds inside ``shard_map`` with a cross-device reduce_fn — all
    the per-round bookkeeping (hook, tie-break, eid recording, shortcut,
    rank/relabel) operates on replicated dense vectors and is shared."""
    p, total, msf_eids, n_f = hook_rounds(reduce_fn, n, rounds)
    new_ids, n_next = rank_relabel(p)
    return ContractResult(
        parent=p,
        new_ids=new_ids,
        n_next=n_next,
        weight=total,
        msf_eids=msf_eids,
        n_msf_edges=n_f,
    )


@partial(jax.jit, static_argnames=("n", "rounds", "pack", "segmin"))
def contract_level(
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    eid: jax.Array,
    valid: jax.Array,
    *,
    n: int,
    rounds: int = 2,
    pack: bool = False,
    segmin=None,
) -> ContractResult:
    """Run K hook+shortcut rounds and rank-relabel the surviving roots.

    ``rounds`` is static and small (the engine's ``rounds_per_level``), so
    the loop unrolls — each round is exactly the complete-variant MSF body
    and preserves the every-tree-a-star invariant at its top.
    """
    if pack:
        def reduce_fn(p):
            return min_outgoing_coo_packed(
                p, src, dst, w, eid, valid, n, segmin=segmin
            )
    else:
        def reduce_fn(p):
            return min_outgoing_coo(p, src, dst, w, eid, valid, n, segment="root")
    return contract_rounds(reduce_fn, n, rounds)


@partial(
    jax.jit,
    static_argnames=("n", "eid_capacity", "rounds", "pack", "segmin"),
)
def contract_level_und(
    lo: jax.Array,
    hi: jax.Array,
    w: jax.Array,
    eid: jax.Array,
    valid: jax.Array,
    *,
    n: int,
    eid_capacity: int,
    rounds: int = 2,
    pack: bool = False,
    segmin=None,
) -> ContractResult:
    """:func:`contract_level` over the *undirected* canonical arrays.

    Two structural savings over feeding the symmetric 2E concatenation:

    - the ``outgoing`` mask is symmetric (p[lo] ≠ p[hi]), so ONE masked
      MINWEIGHT key array serves both directions; the per-root partials
      are two segment-mins (segments p[lo], then p[hi]) ⊕-combined
      elementwise — no 2E intermediates ever materialize;
    - the hook payload (the winner's other-endpoint parent) is recovered
      by *gathering the winning edge back through an eid→position table*
      (one [eid_capacity] scatter per level, reused across rounds)
      instead of a second masked segment reduction per direction.

    Identical results to :func:`contract_level` on the concatenated form:
    the monoid is commutative and the (w, eid) order total, so the
    per-root minimum is direction-agnostic, and the payload is a pure
    function of the winning edge. ``eid_capacity`` is a static bound with
    eid < eid_capacity for every valid edge (the engine passes the padded
    original edge capacity).
    """
    reduce_fn = make_und_reduce(
        lo, hi, w, eid, valid,
        n=n, eid_capacity=eid_capacity, pack=pack, segmin=segmin,
    )
    return contract_rounds(reduce_fn, n, rounds)


def make_und_reduce(
    lo: jax.Array,
    hi: jax.Array,
    w: jax.Array,
    eid: jax.Array,
    valid: jax.Array,
    *,
    n: int,
    eid_capacity: int,
    pack: bool = False,
    segmin=None,
    combine=None,
):
    """Build ``reduce_fn(p) → EdgeMin`` over the undirected canonical arrays.

    ``combine`` is applied to every dense [n] partial *before* winner
    selection: identity (``None``) for the single-shard engine, the
    cross-device all-reduce(min) over the mesh axes for the distributed
    fused level — the MINWEIGHT ⊕-combine of DESIGN.md §2, where each pass
    is one masked min-reduction. With a combine the arrays may be one
    device's *shard* of the edge set: the per-root minimum is the global
    one after the combine, winner masks only fire on shards that hold the
    winning edge, and the payload lookup is masked by locality (the
    eid→position table marks absent eids with −1) so remote shards
    contribute the identity.
    """
    from repro.core.semiring import EdgeMin, INF, PACK_IDENTITY, pack32, unpack32

    if combine is None:
        combine = lambda x: x  # noqa: E731 — identity for the local engine
    e = lo.shape[0]
    pos_of_eid = jnp.full((eid_capacity,), -1, jnp.int32).at[
        jnp.where(valid, eid, eid_capacity)
    ].set(jnp.arange(e, dtype=jnp.int32), mode="drop")
    i_n = jnp.arange(n, dtype=jnp.int32)

    def payload_from_eid(p, mineid, empty):
        pos = pos_of_eid[jnp.clip(mineid, 0, eid_capacity - 1)]
        local = (pos >= 0) & ~empty  # this shard holds the winning edge
        safe = jnp.clip(pos, 0, max(e - 1, 0))
        plo, phi = p[lo[safe]], p[hi[safe]]
        pd = jnp.where(plo == i_n, phi, plo)
        return combine(jnp.where(local, pd, IMAX))

    if pack:
        def reduce_fn(p):
            plo, phi = p[lo], p[hi]
            out = (plo != phi) & valid
            # Mask weights BEFORE the uint32 cast (padding carries +inf).
            w_int = jnp.where(out, w, 0.0).astype(jnp.uint32)
            key = jnp.where(out, pack32(w_int, eid), PACK_IDENTITY)
            if segmin is None:
                m1 = jax.ops.segment_min(key, plo, num_segments=n)
                m2 = jax.ops.segment_min(key, phi, num_segments=n)
            else:
                m1 = segmin(key, plo, n)
                m2 = segmin(key, phi, n)
            minkey = combine(jnp.minimum(m1, m2))
            w_out, eid_out = unpack32(minkey)
            empty = minkey == PACK_IDENTITY
            return EdgeMin(
                w=jnp.where(empty, INF, w_out.astype(jnp.float32)),
                eid=jnp.where(empty, IMAX, eid_out),
                payload=(payload_from_eid(p, eid_out, empty),),
            )
    else:
        def reduce_fn(p):
            plo, phi = p[lo], p[hi]
            out = (plo != phi) & valid
            wm = jnp.where(out, w, INF)
            minw = combine(jnp.minimum(
                jax.ops.segment_min(wm, plo, num_segments=n),
                jax.ops.segment_min(wm, phi, num_segments=n),
            ))
            on1 = out & (wm == minw[plo])
            on2 = out & (wm == minw[phi])
            mineid = combine(jnp.minimum(
                jax.ops.segment_min(
                    jnp.where(on1, eid, IMAX), plo, num_segments=n
                ),
                jax.ops.segment_min(
                    jnp.where(on2, eid, IMAX), phi, num_segments=n
                ),
            ))
            empty = minw == INF
            return EdgeMin(
                w=minw,
                eid=mineid,
                payload=(payload_from_eid(p, mineid, empty),),
            )
    return reduce_fn
