"""Borůvka-style contraction rounds via the AS multilinear kernel (§7.1).

One *level* = K hook+shortcut rounds of the existing multilinear MSF
machinery (``min_outgoing_coo`` → ``hook_and_tiebreak`` →
``complete_shortcut``) starting from singleton stars, followed by the
rank/relabel pass. Each round merges every component with its minimum
outgoing (w, eid)-lex edge — the classic Borůvka step expressed with the
paper's kernels — so K rounds shrink the vertex count by ≥ 2^K wherever
edges remain, and every hooked edge is an MSF edge (cut property under
the distinct (w, eid) total order).

The recorded eids are the graph's *global* edge ids, threaded unchanged
through relabeling and filtering by the engine.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import shortcut as sc
from repro.core.msf import hook_and_tiebreak, record_edges
from repro.core.multilinear import min_outgoing_coo, min_outgoing_coo_packed
from repro.core.semiring import IMAX
from repro.coarsen.relabel import rank_relabel


class ContractResult(NamedTuple):
    parent: jax.Array  # int32 [n]: star-canonical labels after K rounds
    new_ids: jax.Array  # int32 [n]: vertex → supervertex rank in [0, n_next)
    n_next: jax.Array  # int32 scalar: supervertex count
    weight: jax.Array  # float32 scalar: weight hooked this level
    msf_eids: jax.Array  # int32 [n]: global eids chosen this level (front-packed)
    n_msf_edges: jax.Array  # int32 scalar


@partial(jax.jit, static_argnames=("n", "rounds", "pack", "segmin"))
def contract_level(
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    eid: jax.Array,
    valid: jax.Array,
    *,
    n: int,
    rounds: int = 2,
    pack: bool = False,
    segmin=None,
) -> ContractResult:
    """Run K hook+shortcut rounds and rank-relabel the surviving roots.

    ``rounds`` is static and small (the engine's ``rounds_per_level``), so
    the loop unrolls — each round is exactly the complete-variant MSF body
    and preserves the every-tree-a-star invariant at its top.
    """
    p = jnp.arange(n, dtype=jnp.int32)
    total = jnp.float32(0.0)
    msf_eids = jnp.full((n,), IMAX, jnp.int32)
    n_f = jnp.int32(0)
    for _ in range(rounds):
        if pack:
            r = min_outgoing_coo_packed(p, src, dst, w, eid, valid, n, segmin=segmin)
        else:
            r = min_outgoing_coo(p, src, dst, w, eid, valid, n, segment="root")
        p_h, keep, _ = hook_and_tiebreak(p, r.w, r.eid, r.payload[0])
        total = total + jnp.sum(jnp.where(keep, r.w, 0.0))
        msf_eids, n_f = record_edges(msf_eids, n_f, keep, r.eid)
        p = sc.complete_shortcut(p_h)
    new_ids, n_next = rank_relabel(p)
    return ContractResult(
        parent=p,
        new_ids=new_ids,
        n_next=n_next,
        weight=total,
        msf_eids=msf_eids,
        n_msf_edges=n_f,
    )
