"""Algebraic Awerbuch-Shiloach minimum spanning forest (paper Algorithm 1).

Two algorithm variants:

- ``variant="complete"`` (production default, paper §IV-B): complete
  shortcutting keeps every tree a star at the top of each iteration, so the
  starcheck disappears and hooking can fuse the line-10 projection into the
  multilinear kernel (segment ids = p[src] are root ids).
- ``variant="paper"`` (faithful Algorithm 1): starcheck, per-vertex
  multilinear kernel (line 9), separate projection to roots (line 10), one
  shortcut round per iteration (line 15).

Plus the *pairwise* formulation (paper §IV-A "Pairwise") used as the Fig-8
baseline: first materialize m_ij = (a_ij, p_j) (the nnz extra writes), then
reduce f(p_i, m_ij) — algebraically identical, strictly more data movement.

Termination uses FastSV's grandparent-convergence condition (paper §V): stop
when hooking makes no progress, checked on the parent vector after complete
shortcutting.

Outputs: total MSF weight, the MSF edge set (global eids), parent vector
(connected-component labels), and iteration count.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import shortcut as sc
from repro.core.multilinear import (
    min_outgoing_coo,
    min_outgoing_coo_packed,
    project_to_roots,
)
from repro.core.semiring import INF, IMAX
from repro.graphs.structures import Graph


class MSFResult(NamedTuple):
    weight: jax.Array  # float32 scalar: total MSF weight
    parent: jax.Array  # int32 [n]: component representative per vertex
    msf_eids: jax.Array  # int32 [n]: global eids of MSF edges, IMAX padded
    n_msf_edges: jax.Array  # int32 scalar
    iterations: jax.Array  # int32 scalar


def starcheck(p: jax.Array) -> jax.Array:
    """AS starcheck (paper §II-C): s_i = does vertex i belong to a star."""
    n = p.shape[0]
    i = jnp.arange(n, dtype=p.dtype)
    gp = p[p]
    s = jnp.ones(n, bool)
    nonstar = gp != p
    # Vertex i informs its grandparent the tree is not a star.
    tgt = jnp.where(nonstar, gp, n)  # out-of-bounds dropped
    s = s.at[tgt].set(False, mode="drop")
    s = s & ~nonstar
    # Remaining vertices query their parent.
    return s & s[p]


def hook_and_tiebreak(p, r_w, r_eid, r_parent):
    """Lines 11-13: hook star roots with their min outgoing edge, then break
    the 2-cycles hooking introduces (larger root keeps the hook).

    Public because the coarsening engine (``repro.coarsen.contract``) runs
    the same hook rounds outside the full MSF driver loop."""
    n = p.shape[0]
    i = jnp.arange(n, dtype=p.dtype)
    hooked = r_w < INF  # only roots receive a valid r entry
    p_h = jnp.where(hooked, r_parent, p)
    # Tie break: i was a (hooked) root, i < p_i, and p_{p_i} == i.
    t = hooked & (i < p_h) & (p_h[p_h] == i)
    p_new = jnp.where(t, i, p_h)
    keep = hooked & ~t  # roots whose hook survives contribute their edge
    return p_new, keep, t


def record_edges(msf_eids, n_f, keep, r_eid):
    """Append the surviving hook edges' eids to the MSF buffer."""
    n = keep.shape[0]
    pos = n_f + jnp.cumsum(keep.astype(jnp.int32)) - 1
    tgt = jnp.where(keep, pos, n)  # drop non-winners
    msf_eids = msf_eids.at[tgt].set(r_eid, mode="drop")
    return msf_eids, n_f + jnp.sum(keep.astype(jnp.int32))


def _make_msf_body(graph: Graph, variant, shortcut_fn, pack, segmin):
    """One hook+shortcut round as ``body(state) -> state`` over the
    6-tuple ``(p, total, msf_eids, n_f, it, done)``.

    Shared by the jitted while_loop driver (:func:`_msf_jit`) and the
    host-driven traced driver (:func:`_msf_traced`), so the two paths run
    the *same* per-round computation — the obs parity contract (enabling
    tracing never changes solver output) reduces to "one round is one
    round" regardless of who owns the loop.
    """
    n = graph.n
    src, dst, w, eid, valid = graph.src, graph.dst, graph.w, graph.eid, graph.valid

    def body_complete(state):
        p, total, msf_eids, n_f, it, _ = state
        p_prev = p
        if variant == "pairwise":
            # Paper §IV-A pairwise baseline: materialize m = (a_ij, p_j)
            # into an nnz-sized buffer (the extra writes), then reduce with
            # f(p_i, m_ij). Algebraically identical to the fused kernel.
            # ``optimization_barrier`` forces the materialization XLA would
            # otherwise fuse away — CTF's pairwise path writes the updated
            # adjacency tensor to memory, which is exactly the cost the
            # paper's all-at-once kernel removes.
            m_w, m_pd, m_eid = jax.lax.optimization_barrier(
                (
                    jnp.where(valid, w, INF),  # materialized weight field
                    jnp.where(valid, p[dst], IMAX),  # materialized parents
                    jnp.where(valid, eid, IMAX),
                )
            )
            ps = p[src]
            outgoing = (ps != m_pd) & valid
            from repro.core.semiring import segment_argmin

            r = segment_argmin(m_w, m_eid, (m_pd,), ps, n, valid=outgoing)
        elif pack:
            r = min_outgoing_coo_packed(
                p, src, dst, w, eid, valid, n, segmin=segmin
            )
        else:
            r = min_outgoing_coo(p, src, dst, w, eid, valid, n, segment="root")
        p_h, keep, _ = hook_and_tiebreak(p, r.w, r.eid, r.payload[0])
        total = total + jnp.sum(jnp.where(keep, r.w, 0.0))
        msf_eids, n_f = record_edges(msf_eids, n_f, keep, r.eid)
        p_next = shortcut_fn(p_h, p_prev)
        done = jnp.all(p_next == p_prev)
        return p_next, total, msf_eids, n_f, it + 1, done

    def body_paper(state):
        p, total, msf_eids, n_f, it, _ = state
        p_prev = p
        s = starcheck(p)
        q = min_outgoing_coo(p, src, dst, w, eid, valid, n, segment="vertex", star=s)
        r = project_to_roots(q, p, n)
        p_h, keep, _ = hook_and_tiebreak(p, r.w, r.eid, r.payload[0])
        total = total + jnp.sum(jnp.where(keep, r.w, 0.0))
        msf_eids, n_f = record_edges(msf_eids, n_f, keep, r.eid)
        s2 = starcheck(p_h)
        p_next = sc.shortcut_once(p_h, s2)
        done = jnp.all(p_next == p_prev)
        return p_next, total, msf_eids, n_f, it + 1, done

    return body_paper if variant == "paper" else body_complete


def _msf_init(graph: Graph, parent0):
    if parent0 is None:
        p0 = jnp.arange(graph.n, dtype=jnp.int32)
    else:
        # Canonicalize: the hooking kernels rely on the every-tree-a-star
        # invariant at the top of each iteration.
        p0 = sc.complete_shortcut(parent0.astype(jnp.int32))
    return (
        p0,
        jnp.float32(0.0),
        jnp.full((graph.n,), IMAX, jnp.int32),
        jnp.int32(0),
        jnp.int32(0),
        jnp.bool_(False),
    )


def _msf_limit(n: int, max_iters) -> int:
    return int(max_iters if max_iters is not None else 2 * int(n).bit_length() + 8)


@partial(
    jax.jit,
    static_argnames=(
        "variant",
        "shortcut",
        "capacity",
        "max_iters",
        "unroll_guard",
        "pack",
        "segmin",
    ),
)
def _msf_jit(
    graph: Graph,
    *,
    parent0: jax.Array | None = None,
    variant: str = "complete",
    shortcut: str = "complete",
    capacity: int = 1 << 16,
    max_iters: int | None = None,
    unroll_guard: bool = True,
    pack: bool = False,
    segmin=None,
) -> MSFResult:
    """Jitted MSF driver — see :func:`msf` for the public entry point."""
    limit = jnp.int32(_msf_limit(graph.n, max_iters))
    shortcut_fn = sc.make_shortcut_fn(shortcut, capacity) if variant != "paper" else None
    body = _make_msf_body(graph, variant, shortcut_fn, pack, segmin)

    def cond(state):
        _, _, _, _, it, done = state
        guard = it < limit if unroll_guard else True
        return jnp.logical_and(~done, guard)

    init = _msf_init(graph, parent0)
    p, total, msf_eids, n_f, it, _ = jax.lax.while_loop(cond, body, init)
    p = sc.complete_shortcut(p)  # canonical labels (complete variant: no-op)
    return MSFResult(weight=total, parent=p, msf_eids=msf_eids, n_msf_edges=n_f, iterations=it)


@partial(
    jax.jit,
    static_argnames=("variant", "shortcut", "capacity", "pack", "segmin"),
)
def _msf_round(
    graph: Graph,
    state,
    *,
    variant: str,
    shortcut: str,
    capacity: int,
    pack: bool,
    segmin=None,
):
    """One hook+shortcut round as its own executable — the traced
    driver's per-round step (the while_loop body, loop hoisted out)."""
    shortcut_fn = sc.make_shortcut_fn(shortcut, capacity) if variant != "paper" else None
    return _make_msf_body(graph, variant, shortcut_fn, pack, segmin)(state)


def _msf_traced(
    graph: Graph,
    *,
    parent0=None,
    variant: str = "complete",
    shortcut: str = "complete",
    capacity: int = 1 << 16,
    max_iters: int | None = None,
    unroll_guard: bool = True,
    pack: bool = False,
    segmin=None,
) -> MSFResult:
    """Host-driven twin of :func:`_msf_jit` with one obs span per
    hook/shortcut round (DESIGN.md §10.3).

    A ``lax.while_loop`` hides the per-round timing from the host, so
    trace mode moves the loop to Python: the same body
    (:func:`_make_msf_body`) runs as one executable per round
    (:func:`_msf_round`) with a ``msf.round`` span — device-synced via
    ``attach`` — around each. Same rounds, same termination rule
    (``done`` then the unroll guard), bit-identical result; the cost is
    one dispatch + sync per round, which is exactly what a profiler is
    allowed to spend.
    """
    from repro import obs

    limit = _msf_limit(graph.n, max_iters)
    state = _msf_init(graph, parent0)
    with obs.span("msf.flat", n=graph.n, variant=variant) as sp:
        while not bool(state[5]) and (
            not unroll_guard or int(state[4]) < limit
        ):
            with obs.span("msf.round", round=int(state[4])) as rsp:
                state = rsp.attach(_msf_round(
                    graph, state,
                    variant=variant, shortcut=shortcut, capacity=capacity,
                    pack=pack, segmin=segmin,
                ))
        p = sp.attach(sc.complete_shortcut(state[0]))
        sp.set(iterations=int(state[4]))
    return MSFResult(
        weight=state[1], parent=p, msf_eids=state[2],
        n_msf_edges=state[3], iterations=state[4],
    )


def run_flat(graph: Graph, **kw) -> MSFResult:
    """Flat-driver dispatch for callers holding a *resolved* segmin
    callable (the ``repro.solve`` flat engine, :func:`flat_msf`):
    the jitted while_loop driver normally, the span-per-round host
    driver when obs trace mode is active."""
    from repro import obs

    if obs.trace_active():
        return _msf_traced(graph, **kw)
    return _msf_jit(graph, **kw)


def flat_msf(graph: Graph, *, pack: bool = False, segmin: str | None = None,
             **kw) -> MSFResult:
    """Internal flat AS solve — the non-deprecated twin of the old
    ``msf()`` kwarg path, used by the ``repro.solve`` engines and the
    residual/union solves of the coarsen and stream stacks.

    ``segmin`` is the *string* backend request; resolution (including
    the "sorted"-degrades-to-"auto" rule for unsorted hook segments)
    lives in ``repro.solve.spec.resolve_flat_segmin``. No validation —
    public callers go through ``SolveSpec``, which validates once.
    """
    from repro.solve.spec import resolve_flat_segmin  # lazy: layer cycle

    return run_flat(graph, pack=pack, segmin=resolve_flat_segmin(segmin, pack), **kw)


def msf(
    graph: Graph,
    *,
    coarsen=None,
    segmin: str | None = None,
    fused: bool | None = None,
    **kw,
) -> MSFResult:
    """Deprecated: compute the MSF of ``graph`` (kwarg-dispatch form).

    .. deprecated::
        Use the declarative API instead::

            from repro.solve import SolveSpec, plan
            plan(graph, SolveSpec()).solve()                    # flat
            plan(graph, SolveSpec(mode="coarsen",               # levels
                                  coarsen=cfg, fused=True)).solve()

        This shim builds the equivalent ``SolveSpec``, routes through
        ``repro.solve.plan``, and returns the engine-native
        ``MSFResult`` — bit-identical to the historical behavior (the
        4-way property suite pins it). It will be removed once the
        deprecation window closes; see DESIGN.md §9.

    variant: "complete" | "paper" | "pairwise"
    shortcut (complete variant only): "complete" | "csp" | "os"
    parent0: optional warm-start parent vector — the re-entrant form for
      callers that maintain their own component labels (e.g. an incremental
      connectivity refresh). Hooking starts from these components instead
      of singletons, so the returned ``weight``/``msf_eids`` cover only the
      edges hooked *during this call*. Any forest labeling works — it is
      canonicalized to stars first.
    pack: use the pack32 single-reduction inner loop (integer weights in
      [0, 255], eids < 2^24 − 1 — the paper's evaluation regime).
    segmin: packed segment-min backend for ``pack=True`` — "jnp",
      "pallas", or "auto" / None.
    coarsen: None for the flat solver, or a
      ``repro.coarsen.CoarsenConfig`` (or ``True`` for defaults) to run
      Borůvka contract-and-filter levels first (DESIGN.md §7).
      Incompatible with ``parent0``.
    fused: with ``coarsen=``, one-jit device-resident levels
      (DESIGN.md §7.6); overrides ``CoarsenConfig.fused``.
    """
    import warnings

    warnings.warn(
        "msf(...) is deprecated; build a repro.solve.SolveSpec and call "
        "plan(graph, spec).solve() instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro import solve  # lazy: core must not import the plan layer eagerly

    parent0 = kw.pop("parent0", None)
    use_coarsen = coarsen is not None and coarsen is not False
    if use_coarsen:
        if parent0 is not None:
            raise ValueError("coarsen= cannot be combined with parent0=")
        spec = solve.SolveSpec(
            mode="coarsen",
            coarsen=True if coarsen is True else coarsen,
            segmin=segmin,
            fused=fused,
            pack=kw.pop("pack", None),
            **kw,
        )
        return solve.plan(graph, spec).solve().raw
    spec = solve.SolveSpec(
        mode="flat",
        segmin=segmin,
        fused=True if fused else None,  # surfaces the old ValueError
        pack=kw.pop("pack", False),
        **kw,
    )
    return solve.plan(graph, spec).solve(parent0=parent0).raw


def msf_weight(graph: Graph, **kw) -> float:
    """Deprecated alongside :func:`msf` (it delegates to it)."""
    return float(msf(graph, **kw).weight)
