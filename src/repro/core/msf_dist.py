"""Distributed MSF engine — the paper's Fig-2 schedule on a JAX mesh.

Per outer iteration (all inside one ``shard_map``-ped ``while_loop``):

1. **Multilinear kernel** (paper §IV-A): gather the parent-vector row block
   (all_gather over the column axis) and column block (all_gather over the
   row axis) — the redistribute+broadcast stage; apply
   f(p_i, a_ij, p_j) all-at-once over the local 2D edge block; local
   segment-argmin into a dense accumulator; MINWEIGHT ⊕-combine across the
   mesh (masked all-reduce(min) passes).
2. **Hook + tie-break** entirely from the replicated reduction result: with
   the complete-shortcutting invariant every tree is a star, so a root's
   post-hook parent is known from r alone — zero extra communication.
3. **Shortcut**:
   - ``baseline``: one full all_gather of p per sub-iteration, pointer jump
     locally, repeat (the paper's unoptimized remote-read loop);
   - ``csp``: the changed map (hooked roots → new parents) is already
     device-local; compress it by pointer doubling (local reads only) and
     apply in one pass — Algorithm 2 with the gather folded into the
     kernel's ⊕-combine. This is the communication the paper's Fig 3/4
     measure: n words × sub-iterations vs none.
   - ``os``: csp when |changed| ≤ capacity else baseline.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.multilinear import min_outgoing_2d, min_outgoing_2d_packed
from repro.core.semiring import INF, IMAX
from repro.graphs.partition import Partition2D


class DistMSFResult(NamedTuple):
    weight: jax.Array
    parent: jax.Array  # [n_pad] sharded
    msf_eids: jax.Array  # [n_pad] replicated, IMAX padded
    n_msf_edges: jax.Array
    iterations: jax.Array


def _csp_apply(keep, r_parent, p_local, n_pad, capacity):
    """Build the changed map from the replicated hook results, compress it
    locally (pointer doubling over at most ceil(log2 chain) rounds), apply
    to the local parent shard in one pass."""
    i = jnp.arange(n_pad, dtype=jnp.int32)
    key = jnp.where(keep, i, IMAX)
    ids = -lax.top_k(-key, capacity)[0]  # smallest `capacity` changed ids
    safe = jnp.clip(ids, 0, n_pad - 1)
    vals = jnp.where(ids == IMAX, IMAX, r_parent[safe])

    def lookup(x):
        j = jnp.clip(jnp.searchsorted(ids, x), 0, capacity - 1)
        hit = (ids[j] == x) & (x != IMAX)
        return jnp.where(hit, vals[j], x), hit

    def cond(v):
        _, hit = lookup(v)
        return jnp.any(hit)

    def body(v):
        nxt, _ = lookup(v)
        return nxt

    vals = lax.while_loop(cond, body, vals)
    out, _ = lookup(p_local)
    return out


def _flat_axes(row_axis, col_axis):
    return (
        tuple(row_axis) if isinstance(row_axis, tuple) else (row_axis,)
    ) + (col_axis,)


def _baseline_shortcut(p_local, row_axis, col_axis):
    """Per-sub-iteration full gather + jump (the paper's baseline)."""
    axes = _flat_axes(row_axis, col_axis)

    def body(state):
        p_loc, _ = state
        p_full = lax.all_gather(p_loc, axes, tiled=True)
        p_new = p_full[p_loc]
        moved = jnp.any(p_new != p_loc).astype(jnp.int32)
        cont = lax.pmax(moved, axes)
        return p_new, cont

    def cond(state):
        return state[1] > 0

    p_final, _ = lax.while_loop(cond, body, (p_local, jnp.int32(1)))
    return p_final


def msf_distributed(
    part: Partition2D,
    mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    shortcut: str = "csp",
    capacity: int = 1 << 16,
    max_iters: int | None = None,
    pack: bool = False,
    coarsen=None,
):
    """Deprecated: build the distributed MSF driver (kwarg-dispatch form).

    .. deprecated::
        This entry point has a **dual return type** — a jitted block
        driver function without ``coarsen=``, a
        ``repro.coarsen.dist.DistCoarsenMSF`` instance with it — which is
        exactly the kind of kwarg-keyed dispatch ``repro.solve``
        replaces. Use::

            from repro.solve import SolveSpec, plan
            p = plan(part, SolveSpec(mode="dist"), mesh=mesh)       # flat
            p = plan(part, SolveSpec(mode="dist", coarsen=cfg),     # fused
                     mesh=mesh)                                     # levels
            report = p.solve()          # uniform SolveReport, either way

        Removal path: this shim now routes **both** branches through
        ``repro.solve.plan`` (so repeated builds share the plan cache)
        and returns the plan's engine-native driver for call-pattern
        compatibility; when the deprecation window closes the shim and
        its dual return type disappear, and ``plan(...).solve()`` —
        whose report is uniform across both branches — is the only
        surface. See DESIGN.md §9.

    Shapes: edges [R, C, Emax] sharded over (row_axis, col_axis); parent
    vector [n_pad] sharded over the flattened mesh. ``coarsen``: ``None``
    for the flat Fig-2 solve, or a ``CoarsenConfig`` (``True`` for
    defaults) to run contract-and-filter levels inside the mesh first
    (DESIGN.md §8); ``shortcut``/``capacity`` only apply to the flat
    solve, ``pack`` is governed by the config under ``coarsen=``.
    """
    import warnings

    warnings.warn(
        "msf_distributed(...) is deprecated; use repro.solve.plan(part, "
        "SolveSpec(mode='dist', ...), mesh=mesh) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro import solve  # lazy: core must not import the plan layer eagerly

    use_coarsen = coarsen is not None and coarsen is not False
    spec = solve.SolveSpec(
        mode="dist",
        coarsen=(True if coarsen is True else coarsen) if use_coarsen else None,
        shortcut=None if use_coarsen else shortcut,
        capacity=capacity,
        max_iters=max_iters,
        pack=None if use_coarsen else pack,  # coarsen: config governs pack
        row_axis=row_axis,
        col_axis=col_axis,
    )
    return solve.plan(part, spec, mesh=mesh).driver


def build_dist_driver(
    part: Partition2D,
    mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    shortcut: str = "csp",
    capacity: int = 1 << 16,
    max_iters: int | None = None,
    pack: bool = False,
):
    """Internal: the flat Fig-2 distributed driver builder.

    Returns a jitted function (src_row, dst_col, w, eid, valid) →
    ``DistMSFResult``. Only reads the partition's *static* fields
    (``n_pad``, ``cols``, ``shard_size``), so one driver serves every
    same-shape partition — which is what the ``repro.solve`` plan cache
    keys on. Public callers go through ``plan(part, SolveSpec
    (mode="dist"), mesh=...)``; the in-mesh coarsening variant lives in
    ``repro.coarsen.dist.DistCoarsenMSF``.
    """
    n_pad = part.n_pad
    capacity = min(capacity, n_pad)
    limit = jnp.int32(
        max_iters if max_iters is not None else 2 * int(n_pad).bit_length() + 8
    )

    def step(src_row, dst_col, w, eid, valid, p_local, state):
        total, msf_eids, n_f, it = state
        kernel = min_outgoing_2d_packed if pack else min_outgoing_2d
        r = kernel(
            p_local,
            src_row,
            dst_col,
            w,
            eid,
            valid,
            n_pad,
            row_axis=row_axis,
            col_axis=col_axis,
        )
        r_w, r_eid, r_parent = r.w, r.eid, r.payload[0]
        hooked = r_w < INF
        i = jnp.arange(n_pad, dtype=jnp.int32)
        # Post-hook parent of any *root* j is r_parent[j] if hooked else j —
        # derivable from the replicated reduction alone (stars invariant).
        tgt = jnp.clip(r_parent, 0, n_pad - 1)
        tgt_parent = jnp.where(hooked[tgt], r_parent[tgt], tgt)
        t = hooked & (i < r_parent) & (tgt_parent == i)
        keep = hooked & ~t
        total = total + jnp.sum(jnp.where(keep, r_w, 0.0))
        # Record MSF edges (replicated bookkeeping).
        pos = n_f + jnp.cumsum(keep.astype(jnp.int32)) - 1
        msf_eids = msf_eids.at[jnp.where(keep, pos, n_pad)].set(r_eid, mode="drop")
        n_f = n_f + jnp.sum(keep.astype(jnp.int32))
        #

        # Apply hooks to the local shard, then shortcut.
        shard_ix = _shard_index(row_axis, col_axis, part.cols)
        base = shard_ix * part.shard_size
        loc = base + jnp.arange(part.shard_size, dtype=jnp.int32)
        keep_loc = keep[loc]
        p_hooked = jnp.where(keep_loc, r_parent[loc], p_local)

        if shortcut == "baseline":
            p_next = _baseline_shortcut(p_hooked, row_axis, col_axis)
        elif shortcut in ("csp", "os"):
            # CSP is only exact when the changed set fits the prefetch
            # buffer; on overflow fall back to the baseline remote-read loop
            # (this *is* the paper's OS policy — CSP differs only in that the
            # paper sizes the gather dynamically, which XLA cannot).
            n_changed = jnp.sum(keep.astype(jnp.int32))

            def do_csp(pl):
                return _csp_apply(keep, r_parent, pl, n_pad, capacity)

            def do_base(pl):
                return _baseline_shortcut(pl, row_axis, col_axis)

            p_next = lax.cond(n_changed <= capacity, do_csp, do_base, p_hooked)
        else:
            raise ValueError(f"unknown distributed shortcut {shortcut!r}")

        done = ~jnp.any(keep)
        return p_next, (total, msf_eids, n_f, it + 1), done

    def run(src_row, dst_col, w, eid, valid, p0_local):
        src_row = src_row.reshape(src_row.shape[-1:])
        dst_col = dst_col.reshape(dst_col.shape[-1:])
        w = w.reshape(w.shape[-1:])
        eid = eid.reshape(eid.shape[-1:])
        valid = valid.reshape(valid.shape[-1:])

        init_state = (
            jnp.float32(0.0),
            jnp.full((n_pad,), IMAX, jnp.int32),
            jnp.int32(0),
            jnp.int32(0),
        )

        def body_fn(carry):
            p_loc, state, _ = carry
            p_next, state, done = step(src_row, dst_col, w, eid, valid, p_loc, state)
            return p_next, state, done

        def cond_fn(carry):
            _, state, done = carry
            return jnp.logical_and(~done, state[3] < limit)

        carry0 = (p0_local, init_state, jnp.bool_(False))
        p_loc, state, _ = lax.while_loop(cond_fn, body_fn, carry0)
        total, msf_eids, n_f, it = state
        return total, p_loc, msf_eids, n_f, it

    specs_edges = P(row_axis, col_axis, None)
    flat_axes = (
        tuple(row_axis) if isinstance(row_axis, tuple) else (row_axis,)
    ) + (col_axis,)
    mapped = shard_map(
        run,
        mesh=mesh,
        in_specs=(specs_edges,) * 5 + (P(flat_axes),),
        out_specs=(P(), P(flat_axes), P(), P(), P()),
        check_vma=False,
    )

    @jax.jit
    def driver(src_row, dst_col, w, eid, valid):
        p0 = jnp.arange(n_pad, dtype=jnp.int32)
        total, p, msf_eids, n_f, it = mapped(src_row, dst_col, w, eid, valid, p0)
        return DistMSFResult(
            weight=total, parent=p, msf_eids=msf_eids, n_msf_edges=n_f, iterations=it
        )

    return driver


def _axis_index_flat(axes):
    """axis_index generalized to a tuple of mesh axes (row-major)."""
    if isinstance(axes, str):
        return lax.axis_index(axes)
    idx = lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * lax.psum(1, a) + lax.axis_index(a)
    return idx


def _shard_index(row_axis, col_axis, cols: int):
    """Flat shard index r*C + s of the executing device."""
    r = _axis_index_flat(row_axis)
    s = _axis_index_flat(col_axis)
    return (r * cols + s).astype(jnp.int32)
