"""Algebraic structures for the MSF formulation (paper §II-A, §III).

The paper's ``(EDGE, MINWEIGHT)`` monoid has elements
``EDGE = (weight, parent)`` and combine = "keep the pair with the least
weight" (CRCW min-write in the PRAM model, a custom MPI reduction in CTF).

TPU adaptation (DESIGN.md §2): we avoid 64-bit packed atomics and instead
implement deterministic *argmin-with-payload* as a small fixed number of
32-bit masked min-reductions, exploiting that effective weights
``(w, eid)`` are lexicographically distinct:

  pass 1:  minw  = min_seg w
  pass 2:  mineid = min_seg (eid   | masked to w == minw)
  pass 3+: payload = min_seg (payload | masked to eid == mineid)

This works for segment reductions (``jax.ops.segment_min``), for dense
axis reductions, and — crucially — for *cross-device* combines, where each
pass is one ``all-reduce(min)`` (see ``repro.core.multilinear``).

A ``pack32`` fast path covers the paper's own evaluation regime (integer
weights 1..255): key = w << 24 | idx for idx < 2^24 — a single reduction,
and the layout the Pallas kernels use.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)
IMAX = jnp.int32(jnp.iinfo(jnp.int32).max)


class EdgeMin(NamedTuple):
    """Per-segment result of a MINWEIGHT reduction."""

    w: jax.Array  # float32 [n]; +inf where the segment is empty
    eid: jax.Array  # int32 [n]; IMAX where empty
    payload: Tuple[jax.Array, ...]  # int32 [n] each; IMAX where empty


def segment_argmin(
    w: jax.Array,
    eid: jax.Array,
    payloads: Sequence[jax.Array],
    segment_ids: jax.Array,
    num_segments: int,
    valid: jax.Array | None = None,
) -> EdgeMin:
    """MINWEIGHT reduction by segment, with deterministic (w, eid) tie-break.

    All inputs are edge-indexed [E]. Invalid entries contribute the monoid
    identity (inf, IMAX, ...).
    """
    if valid is not None:
        w = jnp.where(valid, w, INF)
    minw = jax.ops.segment_min(w, segment_ids, num_segments=num_segments)
    on_min = w == minw[segment_ids]  # inf==inf at empty segments is harmless
    if valid is not None:
        on_min = on_min & valid
    eid_m = jnp.where(on_min, eid, IMAX)
    mineid = jax.ops.segment_min(eid_m, segment_ids, num_segments=num_segments)
    winner = on_min & (eid == mineid[segment_ids])
    outs = []
    for p in payloads:
        pm = jnp.where(winner, p, IMAX)
        outs.append(jax.ops.segment_min(pm, segment_ids, num_segments=num_segments))
    return EdgeMin(w=minw, eid=mineid, payload=tuple(outs))


def axis_argmin(
    w: jax.Array,
    eid: jax.Array,
    payloads: Sequence[jax.Array],
    axis: int,
) -> EdgeMin:
    """MINWEIGHT reduction along a dense array axis (used by the dense
    multilinear reference and the Pallas oracle)."""
    minw = jnp.min(w, axis=axis)
    on_min = w == jnp.expand_dims(minw, axis)
    eid_m = jnp.where(on_min, eid, IMAX)
    mineid = jnp.min(eid_m, axis=axis)
    winner = on_min & (eid == jnp.expand_dims(mineid, axis))
    outs = tuple(
        jnp.min(jnp.where(winner, p, IMAX), axis=axis) for p in payloads
    )
    return EdgeMin(w=minw, eid=mineid, payload=outs)


def combine_edgemin(a: EdgeMin, b: EdgeMin) -> EdgeMin:
    """Binary MINWEIGHT combine of two EdgeMin fields (elementwise)."""
    w = jnp.minimum(a.w, b.w)
    a_on = a.w == w
    b_on = b.w == w
    eid = jnp.minimum(jnp.where(a_on, a.eid, IMAX), jnp.where(b_on, b.eid, IMAX))
    a_win = a_on & (a.eid == eid)
    b_win = b_on & (b.eid == eid)
    payload = tuple(
        jnp.minimum(jnp.where(a_win, pa, IMAX), jnp.where(b_win, pb, IMAX))
        for pa, pb in zip(a.payload, b.payload)
    )
    return EdgeMin(w=w, eid=eid, payload=payload)


def allreduce_argmin(em: EdgeMin, axis_name) -> EdgeMin:
    """Cross-device MINWEIGHT combine inside ``shard_map``.

    This is the paper's ⊕-reduction over processor-grid columns (§IV-A),
    expressed as 2+len(payload) masked all-reduce(min)s over ``axis_name``.
    """
    minw = jax.lax.pmin(em.w, axis_name)
    on_min = em.w == minw
    mineid = jax.lax.pmin(jnp.where(on_min, em.eid, IMAX), axis_name)
    winner = on_min & (em.eid == mineid)
    payload = tuple(
        jax.lax.pmin(jnp.where(winner, p, IMAX), axis_name) for p in em.payload
    )
    return EdgeMin(w=minw, eid=mineid, payload=payload)


# ---------------------------------------------------------------------------
# pack32 fast path (paper's integer-weight regime: w in [1, 255], idx < 2^24)
# ---------------------------------------------------------------------------

PACK_IDX_BITS = 24
PACK_IDX_MASK = (1 << PACK_IDX_BITS) - 1
PACK_MAX_W = (1 << (32 - PACK_IDX_BITS)) - 1  # 255 weight levels (paper's regime)
PACK_IDENTITY = jnp.uint32(0xFFFFFFFF)


def pack32(w_int: jax.Array, idx: jax.Array) -> jax.Array:
    """Pack (small int weight, index) into one uint32 min-reducible key."""
    return (w_int.astype(jnp.uint32) << PACK_IDX_BITS) | (
        idx.astype(jnp.uint32) & PACK_IDX_MASK
    )


def unpack32(key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    return (key >> PACK_IDX_BITS).astype(jnp.int32), (
        key & PACK_IDX_MASK
    ).astype(jnp.int32)


def packable(n: int, max_w: int) -> bool:
    return n <= PACK_IDX_MASK + 1 and max_w <= PACK_MAX_W


# Tropical semiring helpers (used by the Bellman-Ford showcase, paper §II-B).
def tropical_spmv(d: jax.Array, src, dst, w, num_segments: int) -> jax.Array:
    """One Bellman-Ford relaxation: d'_j = min(d_j, min_i d_i + w_ij)."""
    cand = d[src] + w
    relaxed = jax.ops.segment_min(cand, dst, num_segments=num_segments)
    return jnp.minimum(d, relaxed)
