"""The paper's multilinear kernel (§III-A, §IV-A).

Computes ``w_i ← ⊕_j f(x_i, a_ij, y_j)`` *all-at-once*: vertex updates use
information from an edge and BOTH adjacent vertex values simultaneously,
without materializing an updated adjacency matrix (the pairwise
formulation's extra ``nnz`` writes — paper §IV-A, Fig 8).

Three execution paths:

- ``multilinear_coo``   — sparse edge-list path (production, single shard)
- ``multilinear_dense`` — dense-matrix path (reference; Pallas oracle)
- ``multilinear_2d``    — the paper's distributed schedule (Fig 2): edges on
  a 2D (row, col) device grid, vertex vectors 1D; broadcast x along rows and
  y along columns (``all_gather``), local all-at-once compute, ⊕-reduce over
  columns (masked ``all-reduce(min)``). Call inside ``shard_map``.

The MSF instantiation is ``f(p_i, a_ij, p_j) = (a_ij, p_j) if p_i ≠ p_j
else (∞, 0)`` over the MINWEIGHT monoid; the generic entry points also take
arbitrary ``f``/monoid for reuse by the GNN substrate (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.semiring import (
    EdgeMin,
    INF,
    IMAX,
    allreduce_argmin,
    axis_argmin,
    segment_argmin,
)


# ---------------------------------------------------------------------------
# MSF instantiation: minimum outgoing edge per (star root) segment
# ---------------------------------------------------------------------------

def min_outgoing_coo(
    p: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    eid: jax.Array,
    valid: jax.Array,
    n: int,
    *,
    segment: str = "root",
    star: jax.Array | None = None,
) -> EdgeMin:
    """All-at-once kernel for Algorithm 1 line 9(+10).

    f(p_i, a_ij, p_j) = (a_ij, p_j) if p_i != p_j else identity, reduced by
    ``segment``:
      - "root":   segment ids = p[src]  (fuses line 9 with the line-10
                  projection r_{p_i} ← q_i — valid when every tree is a
                  star, the complete-shortcutting invariant)
      - "vertex": segment ids = src     (the paper's literal line 9; use
                  with a separate ``project_to_roots`` for line 10)

    Returns EdgeMin over [n] with payload (p_dst,).
    """
    ps = p[src]
    pd = p[dst]
    outgoing = (ps != pd) & valid
    if star is not None:
        outgoing = outgoing & star[src]
    seg = ps if segment == "root" else src
    return segment_argmin(w, eid, (pd,), seg, n, valid=outgoing)


def project_to_roots(q: EdgeMin, p: jax.Array, n: int) -> EdgeMin:
    """Line 10: r_{p_i} ← MINWEIGHT_j { q_j : p_j = i } (vertex-indexed q)."""
    return segment_argmin(q.w, q.eid, q.payload, p, n, valid=q.w < INF)


def min_outgoing_coo_packed(
    p: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    eid: jax.Array,
    valid: jax.Array,
    n: int,
    *,
    segmin=None,
) -> EdgeMin:
    """pack32 fast path of :func:`min_outgoing_coo` (root-segment form).

    Valid in the paper's integer-weight regime: ``w`` integral in
    [0, 255] and ``eid < 2^24 - 1`` (strict — pack32(255, 2^24-1) would
    collide with the 0xFFFFFFFF identity). The (w, eid) MINWEIGHT key
    packs into one uint32, so the per-iteration reduction is a SINGLE
    segment-min on the packed key plus one masked payload pass — and
    ``segmin`` lets callers swap in the Pallas flat kernel
    (``kernels.ops.make_packed_segmin``) for that dominant reduction.
    """
    from repro.core.semiring import PACK_IDENTITY, pack32, unpack32

    ps = p[src]
    pd = p[dst]
    outgoing = (ps != pd) & valid
    # Mask weights BEFORE the uint32 cast: padding carries +inf, whose
    # float→uint conversion is implementation-defined.
    w_int = jnp.where(outgoing, w, 0.0).astype(jnp.uint32)
    key = jnp.where(outgoing, pack32(w_int, eid), PACK_IDENTITY)
    if segmin is None:
        minkey = jax.ops.segment_min(key, ps, num_segments=n)
    else:
        minkey = segmin(key, ps, n)
    w_out, eid_out = unpack32(minkey)
    winner = outgoing & (key == minkey[ps])
    pay = jax.ops.segment_min(jnp.where(winner, pd, IMAX), ps, num_segments=n)
    empty = minkey == PACK_IDENTITY
    return EdgeMin(
        w=jnp.where(empty, INF, w_out.astype(jnp.float32)),
        eid=jnp.where(empty, IMAX, eid_out),
        payload=(pay,),
    )


def min_outgoing_dense(
    p: jax.Array, a: jax.Array, star: jax.Array | None = None
) -> EdgeMin:
    """Dense-adjacency version (a[i, j] = w or +inf). Used as the oracle for
    the Pallas multilinear kernel and for small-graph validation."""
    n = a.shape[0]
    neq = p[:, None] != p[None, :]
    if star is not None:
        neq = neq & star[:, None]
    w = jnp.where(neq, a, INF)
    eid = jnp.where(w < INF, jnp.arange(n, dtype=jnp.int32)[None, :], IMAX)
    pd = jnp.where(w < INF, p[None, :].astype(jnp.int32), IMAX)
    return axis_argmin(w, eid, (pd,), axis=1)


# ---------------------------------------------------------------------------
# Generic multilinear (GNN substrate reuse)
# ---------------------------------------------------------------------------

def multilinear_coo(
    x: jax.Array,
    y: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    a: jax.Array | None,
    f: Callable,
    *,
    num_segments: int,
    reduce: str = "sum",
) -> jax.Array:
    """w_i = ⊕_{(i,j) ∈ E} f(x_i, a_ij, y_j) with ⊕ in {sum, min, max}.

    ``x``/``y`` may be [n] or [n, d]; ``f`` is applied vectorized over the
    edge dimension.
    """
    xi = x[src]
    yj = y[dst]
    vals = f(xi, a, yj) if a is not None else f(xi, None, yj)
    op = {
        "sum": jax.ops.segment_sum,
        "min": jax.ops.segment_min,
        "max": jax.ops.segment_max,
    }[reduce]
    return op(vals, src, num_segments=num_segments)


def spmm_sum_2d(
    x_local: jax.Array,  # [n/P, h] — 1D-sharded node features
    src_row: jax.Array,  # [E_loc] local src offsets into the row block
    dst_col: jax.Array,  # [E_loc] local dst offsets into the column block
    valid: jax.Array,
    *,
    row_axis: str,
    col_axis: str,
    shard_size: int,
    col_block_size: int,
) -> jax.Array:
    """GNN aggregation (⊕ = sum) on the paper's Fig-2 schedule.

    The same 2D edge partition + row/col vector gathers as the MSF kernel,
    with segment-sum instead of MINWEIGHT: gather the row block of x
    (all_gather over cols, n/R words), aggregate the local edge block by
    destination, ⊕-reduce partials over rows (psum, n/C words), then each
    device slices its own 1D shard out of its column block — zero
    additional resharding. Communication per layer ≈ n/R + n/C words vs the
    1D baseline's full-n feature all-gather (§Perf Cell 4).
    """
    x_row = jax.lax.all_gather(x_local, col_axis, tiled=True)  # [n/R, h]
    msgs = jnp.where(valid[:, None], x_row[src_row], 0.0)
    y_partial = jax.ops.segment_sum(msgs, dst_col, num_segments=col_block_size)
    y_col = jax.lax.psum(y_partial, row_axis)  # [n/C, h]
    r = jax.lax.axis_index(row_axis)
    return jax.lax.dynamic_slice(
        y_col, (r * shard_size, 0), (shard_size, x_local.shape[1])
    )


# ---------------------------------------------------------------------------
# Distributed schedule (paper Fig 2) — call inside shard_map
# ---------------------------------------------------------------------------

def gather_row_col_vectors(
    p_local: jax.Array, row_axis: str | tuple, col_axis: str | tuple
):
    """Redistribute + broadcast step of the paper's kernel.

    The global parent vector is 1D-sharded over (row, col) devices in
    row-major order: device (r, s) owns shard index r*C + s. Gathering over
    ``col_axis`` therefore concatenates the shards of row block r →
    x^(r) ("broadcast x over processes (r, t)"); gathering over
    ``row_axis`` yields the *strided* column block y^(s).

    Returns (x_row_block [n/R], y_col_block [n/C]) as locally dense arrays.
    """
    x_row = jax.lax.all_gather(p_local, col_axis, tiled=True)
    y_col = jax.lax.all_gather(p_local, row_axis, tiled=True)
    return x_row, y_col


def min_outgoing_2d_packed(
    p_local: jax.Array,
    src_row: jax.Array,
    dst_col: jax.Array,
    w: jax.Array,
    eid: jax.Array,
    valid: jax.Array,
    n: int,
    *,
    row_axis,
    col_axis,
) -> EdgeMin:
    """pack32 fast path of the distributed kernel (§Perf variant).

    Valid when weights fit 8 bits (the paper's integer 1..255 regime) and
    undirected edge ids fit 24 bits: the (w, eid) MINWEIGHT key packs into
    one uint32, so the cross-device ⊕-combine needs TWO all-reduce(min)
    passes (packed key + masked payload) instead of three — a 33% cut in
    the dominant collective, with bit-identical winners.
    """
    from repro.core.semiring import pack32, unpack32

    x_row, y_col = gather_row_col_vectors(p_local, row_axis, col_axis)
    ps = x_row[src_row]
    pd = y_col[dst_col]
    outgoing = (ps != pd) & valid
    key = jnp.where(outgoing, pack32(w.astype(jnp.uint32), eid), jnp.uint32(0xFFFFFFFF))
    # segment-min on the packed key (single pass), local then global
    minkey = jax.ops.segment_min(key, ps, num_segments=n)
    minkey = jax.lax.pmin(jax.lax.pmin(minkey, col_axis), row_axis)
    w_out, eid_out = unpack32(minkey)
    # masked payload combine: only the devices holding the winning edge
    # contribute their p_dst
    winner = outgoing & (key == minkey[ps])
    pay = jax.ops.segment_min(jnp.where(winner, pd, IMAX), ps, num_segments=n)
    pay = jax.lax.pmin(jax.lax.pmin(pay, col_axis), row_axis)
    empty = minkey == jnp.uint32(0xFFFFFFFF)
    return EdgeMin(
        w=jnp.where(empty, INF, w_out.astype(jnp.float32)),
        eid=jnp.where(empty, IMAX, eid_out),
        payload=(pay,),
    )


def min_outgoing_2d(
    p_local: jax.Array,
    src_row: jax.Array,  # local edge src, as offset into the row block
    dst_col: jax.Array,  # local edge dst, as offset into the column block
    w: jax.Array,
    eid: jax.Array,
    valid: jax.Array,
    n: int,
    *,
    row_axis,
    col_axis,
    seg_global: jax.Array | None = None,
) -> EdgeMin:
    """The paper's distributed multilinear kernel, fused with the root
    projection: each device owns an edge block A^(r,s); after the row/col
    vector gathers it computes local per-root minima into a dense [n]
    accumulator, then ⊕-combines over the column axis *and* the row axis so
    every device holds r (the paper reduces over columns only because its
    output is row-distributed; our parent updates need r replicated, which
    costs one extra all-reduce round over rows — noted in EXPERIMENTS.md).

    ``seg_global``: optional precomputed global segment ids (defaults to
    p[src] looked up in the gathered row block → root ids).
    """
    x_row, y_col = gather_row_col_vectors(p_local, row_axis, col_axis)
    ps = x_row[src_row]
    pd = y_col[dst_col]
    outgoing = (ps != pd) & valid
    seg = ps if seg_global is None else seg_global
    local = segment_argmin(w, eid, (pd,), seg, n, valid=outgoing)
    combined = allreduce_argmin(local, col_axis)
    return allreduce_argmin(combined, row_axis)
