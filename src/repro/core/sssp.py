"""Algebraic Bellman-Ford SSSP (paper §II-B — the motivating example for
algebraic graph algorithms): n−1 tropical-semiring SpMVs with early exit
on convergence. Included for completeness of the algebraic toolkit; uses
the same COO substrate as the MSF engine."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.semiring import tropical_spmv
from repro.graphs.structures import Graph

INF = jnp.float32(jnp.inf)


@partial(jax.jit, static_argnames=("max_iters",))
def sssp(graph: Graph, source: int, *, max_iters: int | None = None):
    """Single-source shortest path distances d [n] (inf = unreachable)."""
    n = graph.n
    src = graph.src
    dst = graph.dst
    w = jnp.where(graph.valid, graph.w, INF)
    d0 = jnp.full((n,), INF).at[source].set(0.0)
    limit = jnp.int32(max_iters if max_iters is not None else n - 1)

    def body(state):
        d, it, _ = state
        d_new = tropical_spmv(d, src, dst, w, n)
        return d_new, it + 1, jnp.all(d_new == d)

    def cond(state):
        _, it, done = state
        return jnp.logical_and(~done, it < limit)

    d, it, _ = jax.lax.while_loop(cond, body, (d0, jnp.int32(0), jnp.bool_(False)))
    return d, it
