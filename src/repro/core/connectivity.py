"""Algebraic Awerbuch-Shiloach / Shiloach-Vishkin connectivity (paper §II-D).

The paper's closest related work (LACC [4], FastSV [36]) implements this
CC variant: hooking uses *any* outgoing edge (the min-parent-id neighbor),
split into conditional hooking (only onto smaller parent ids — acyclic by
construction) and unconditional hooking (for stagnant stars), with the same
shortcutting step as MSF. We implement it both as a correctness
cross-check for the MSF component labels and as the baseline the paper's
MSF algorithm is contrasted against (MSF cannot use cond/uncond hooking —
§II-D — which is exactly why the multilinear kernel is needed).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import shortcut as sc
from repro.core.msf import starcheck
from repro.graphs.structures import Graph


class CCResult(NamedTuple):
    parent: jax.Array
    n_components: jax.Array
    iterations: jax.Array


@partial(jax.jit, static_argnames=("max_iters",))
def connected_components(graph: Graph, *, max_iters: int | None = None) -> CCResult:
    n = graph.n
    src, dst, valid = graph.src, graph.dst, graph.valid
    p0 = jnp.arange(n, dtype=jnp.int32)
    limit = jnp.int32(max_iters if max_iters is not None else 2 * int(n).bit_length() + 8)

    def body(state):
        p, it, _ = state
        p_prev = p
        s = starcheck(p)
        # Conditional hooking (Azad-Buluc form): star vertices scan their
        # neighborhood for the smallest neighbor parent, scatter-min onto
        # their root, accepting only hooks to smaller ids.
        ph_edge = jnp.where(valid & s[src], p[dst], jnp.int32(jnp.iinfo(jnp.int32).max))
        ph = jax.ops.segment_min(ph_edge, p[src], num_segments=n)
        i = jnp.arange(n, dtype=jnp.int32)
        cond_ok = ph < i  # root i hooks only onto a smaller parent id
        p = jnp.where(cond_ok & (p == i), ph, p)
        # Unconditional hooking: stars that stayed stagnant hook anywhere.
        s2 = starcheck(p)
        stagnant = s2 & (p == p_prev)
        ph2_edge = jnp.where(
            valid & stagnant[src] & (p[src] != p[dst]),
            p[dst],
            jnp.int32(jnp.iinfo(jnp.int32).max),
        )
        ph2 = jax.ops.segment_min(ph2_edge, p[src], num_segments=n)
        has2 = ph2 < jnp.int32(jnp.iinfo(jnp.int32).max)
        hooked2 = has2 & (p == i)
        p = jnp.where(hooked2, ph2, p)
        # Mutual unconditional hooks form 2-cycles (and, because the hook
        # target is a min-reduction over ids, cycles longer than 2 are
        # impossible — same argument as the paper's distinct-weight proof,
        # with vertex ids as the total order). Break them like MSF line 12.
        t = hooked2 & (i < p) & (p[p] == i)
        p = jnp.where(t, i, p)
        # Shortcut.
        p = sc.complete_shortcut(p)
        done = jnp.all(p == p_prev)
        return p, it + 1, done

    def cond_fn(state):
        _, it, done = state
        return jnp.logical_and(~done, it < limit)

    p, it, _ = jax.lax.while_loop(cond_fn, body, (p0, jnp.int32(0), jnp.bool_(False)))
    ncc = jnp.sum((p == jnp.arange(n, dtype=jnp.int32)).astype(jnp.int32))
    return CCResult(parent=p, n_components=ncc, iterations=it)
