"""Shortcutting strategies (paper §IV-B, Algorithm 2).

- ``shortcut_once``      — the original AS step: p_i ← p_{p_i} for non-star i.
- ``complete_shortcut``  — iterate p ← p[p] until every tree is a star
                           (removes the starcheck; ≥ half the trees then hook
                           each iteration → log2(n) outer iterations).
- ``csp_shortcut``       — Complete Shortcutting with Prefetching: gather the
                           ``changed = {(i, p_i) : p_i ≠ p_i^prev}`` pairs
                           once (the only vertices whose parent moved are
                           star roots that hooked), compress that map to its
                           fixpoint by pointer doubling *within the map*
                           (local reads only), then apply it in one pass.
- ``optimized_shortcut`` — the paper's OS policy: CSP when |changed| fits the
                           prefetch budget, plain complete shortcut otherwise
                           (empirical threshold, paper uses 1310k ≈ 20 MB).

All functions are jit-safe (static shapes; ``lax.while_loop`` inner loops).
The distributed variants live in ``repro.core.msf_dist`` — there CSP's
all-gather-once vs per-sub-iteration remote reads is the real win.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

IMAX = jnp.int32(jnp.iinfo(jnp.int32).max)


def shortcut_once(p: jax.Array, star: jax.Array) -> jax.Array:
    """AS step (iii): p_i ← p_{p_i} for each vertex not in a star."""
    return jnp.where(star, p, p[p])


def complete_shortcut(p: jax.Array) -> jax.Array:
    """Pointer-jump until p == p[p] (every tree a star)."""

    def cond(p):
        return jnp.any(p != p[p])

    def body(p):
        return p[p]

    return jax.lax.while_loop(cond, body, p)


def count_shortcut_subiters(p: jax.Array):
    """complete_shortcut that also reports sub-iteration count (benchmarks)."""

    def cond(state):
        p, _ = state
        return jnp.any(p != p[p])

    def body(state):
        p, k = state
        return p[p], k + 1

    return jax.lax.while_loop(cond, body, (p, jnp.int32(0)))


def _compress_changed_map(ids: jax.Array, vals: jax.Array):
    """Pointer-double the changed map to its fixpoint using only local reads.

    ids: sorted changed vertex ids (padded with IMAX), vals: their new
    parents. After compression, vals[k] is outside the map (or a fixpoint),
    so one application resolves any chain.
    """

    def lookup(x):
        j = jnp.searchsorted(ids, x)
        j = jnp.clip(j, 0, ids.shape[0] - 1)
        # x == IMAX are padding entries — never a hit (else the fixpoint
        # iteration would spin on padding looking itself up).
        hit = (ids[j] == x) & (x != IMAX)
        return jnp.where(hit, vals[j], x), hit

    def cond(vals_cur):
        nxt, hit = lookup(vals_cur)
        del nxt
        return jnp.any(hit & (ids != IMAX))

    def body(vals_cur):
        nxt, _ = lookup(vals_cur)
        return nxt

    # Chains over the changed roots halve each doubling step.
    vals = jax.lax.while_loop(cond, body, vals)
    return ids, vals


def build_changed(p: jax.Array, p_prev: jax.Array, capacity: int):
    """Fixed-capacity (ids, vals) buffer of vertices whose parent changed.

    Returns (ids sorted asc padded IMAX, vals, count, overflowed).
    XLA needs static shapes: ``capacity`` plays the role of the paper's
    20 MB gather threshold.
    """
    n = p.shape[0]
    capacity = min(capacity, n)
    changed = p != p_prev
    count = jnp.sum(changed.astype(jnp.int32))
    key = jnp.where(changed, jnp.arange(n, dtype=jnp.int32), IMAX)
    ids = jax.lax.top_k(-key, capacity)[0] * -1  # smallest `capacity` ids
    safe = jnp.clip(ids, 0, n - 1)
    vals = jnp.where(ids == IMAX, IMAX, p[safe])
    return ids, vals, count, count > capacity


def csp_shortcut(p: jax.Array, p_prev: jax.Array, capacity: int) -> jax.Array:
    """Algorithm 2, single-shard semantics (the distributed version replaces
    ``build_changed`` with one all-gather)."""
    ids, vals, _, overflow = build_changed(p, p_prev, capacity)
    ids, vals = _compress_changed_map(ids, vals)
    j = jnp.clip(jnp.searchsorted(ids, p), 0, ids.shape[0] - 1)
    hit = ids[j] == p
    p_csp = jnp.where(hit, vals[j], p)
    # Overflow ⇒ the buffer silently dropped entries; fall back (OS policy
    # makes this explicit, but csp alone must stay correct).
    return jax.lax.cond(overflow, complete_shortcut, lambda q: p_csp, p)


def optimized_shortcut(
    p: jax.Array, p_prev: jax.Array, capacity: int
) -> jax.Array:
    """Paper's OS: invoke CSP only when |changed| ≤ capacity."""
    ids, vals, count, overflow = build_changed(p, p_prev, capacity)

    def use_csp(_):
        cids, cvals = _compress_changed_map(ids, vals)
        j = jnp.clip(jnp.searchsorted(cids, p), 0, cids.shape[0] - 1)
        hit = cids[j] == p
        return jnp.where(hit, cvals[j], p)

    def use_plain(_):
        return complete_shortcut(p)

    return jax.lax.cond(overflow, use_plain, use_csp, None)


def make_shortcut_fn(strategy: str, capacity: int = 1 << 16):
    """strategy ∈ {baseline, complete, csp, os}. ``baseline`` = one jump
    round (only valid inside the faithful AS variant which starchecks)."""
    if strategy == "complete":
        return lambda p, p_prev: complete_shortcut(p)
    if strategy == "csp":
        return partial(csp_shortcut, capacity=capacity)
    if strategy == "os":
        return partial(optimized_shortcut, capacity=capacity)
    raise ValueError(f"unknown shortcut strategy {strategy!r}")
