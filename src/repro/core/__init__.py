# The paper's primary contribution: the multilinear kernel (§III-A),
# the algebraic Awerbuch-Shiloach MSF algorithm (§III-B), shortcutting
# optimizations (§IV-B), and the AS/SV connectivity baseline (§II-D).
from repro.core.msf import msf, msf_weight, MSFResult, starcheck
from repro.core.connectivity import connected_components, CCResult
from repro.core.multilinear import (
    min_outgoing_coo,
    min_outgoing_dense,
    multilinear_coo,
    project_to_roots,
)
from repro.core.semiring import EdgeMin, segment_argmin, axis_argmin, pack32, unpack32
from repro.core import shortcut
