"""Deterministic, step-keyed synthetic data pipelines.

Every source is a pure function of (seed, step) — no iterator state — so a
restart from checkpoint step k replays exactly the batches the crashed run
would have seen (fault-tolerance requirement, DESIGN.md §5). Each source
plants learnable structure so end-to-end training demonstrably reduces
loss:

- LM: order-1 Markov chain over the vocab (learnable bigram statistics).
- Recsys: logistic ground-truth model over field embeddings.
- Molecules: pairwise Morse-like potential energies.
- GNN: feature-correlated node labels on a fixed graph.
"""
from __future__ import annotations

import numpy as np


class LMBatchSource:
    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0, order: int = 1):
        self.vocab, self.seq_len, self.batch = vocab, seq_len, batch
        rng = np.random.default_rng(seed)
        # sparse-ish transition matrix: each token has ~8 likely successors
        k = min(8, vocab)
        self.succ = rng.integers(0, vocab, size=(vocab, k))
        self.seed = seed

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed, step))
        toks = np.zeros((self.batch, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.batch)
        choices = rng.integers(0, self.succ.shape[1], (self.batch, self.seq_len))
        noise = rng.random((self.batch, self.seq_len)) < 0.1
        rand_tok = rng.integers(0, self.vocab, (self.batch, self.seq_len))
        for t in range(self.seq_len):
            nxt = self.succ[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return toks[:, :-1], toks[:, 1:]


class RecsysBatchSource:
    def __init__(self, offsets: np.ndarray, sizes: np.ndarray, batch: int, seed: int = 0):
        self.offsets, self.sizes, self.batch = offsets, sizes, batch
        rng = np.random.default_rng(seed)
        self.true_w = {  # planted per-field value weights (hashed)
            "a": rng.standard_normal(len(offsets)),
            "b": rng.standard_normal(1024),
        }
        self.seed = seed

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed, step, 1))
        f = len(self.offsets)
        vals = (rng.pareto(1.2, size=(self.batch, f)) * 3).astype(np.int64) % self.sizes
        ids = (self.offsets[None, :] + vals).astype(np.int32)
        logit = (self.true_w["b"][ids.astype(np.int64) % 1024] * self.true_w["a"][None, :]).sum(-1)
        labels = (rng.random(self.batch) < 1 / (1 + np.exp(-logit))).astype(np.float32)
        return ids, labels


class MoleculeBatchSource:
    def __init__(self, n_atoms: int, n_edges: int, batch: int, n_species: int = 4,
                 cutoff: float = 5.0, seed: int = 0):
        self.n_atoms, self.n_edges, self.batch = n_atoms, n_edges, batch
        self.n_species, self.cutoff, self.seed = n_species, cutoff, seed
        rng = np.random.default_rng(seed)
        self.pair_eps = rng.uniform(0.5, 1.5, (n_species, n_species))
        self.pair_eps = (self.pair_eps + self.pair_eps.T) / 2

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed, step, 2))
        b, na = self.batch, self.n_atoms
        species = rng.integers(0, self.n_species, (b, na)).astype(np.int32)
        pos = rng.standard_normal((b, na, 3)).astype(np.float32) * 1.5
        # radius-graph edges, padded to n_edges per molecule
        src = np.zeros((b, self.n_edges), np.int32)
        dst = np.zeros((b, self.n_edges), np.int32)
        valid = np.zeros((b, self.n_edges), bool)
        energy = np.zeros(b, np.float32)
        for g in range(b):
            d = np.linalg.norm(pos[g][:, None] - pos[g][None, :], axis=-1)
            iu, ju = np.nonzero((d < self.cutoff) & (d > 0))
            k = min(len(iu), self.n_edges)
            sel = rng.permutation(len(iu))[:k]
            src[g, :k], dst[g, :k] = iu[sel], ju[sel]
            valid[g, :k] = True
            eps = self.pair_eps[species[g][iu], species[g][ju]]
            r = d[iu, ju]
            energy[g] = 0.5 * np.sum(eps * (np.exp(-2 * (r - 1)) - 2 * np.exp(-(r - 1))))
        # flatten into one batched graph with offsets
        off = (np.arange(b) * na)[:, None]
        flat = dict(
            species=species.reshape(-1),
            pos=pos.reshape(-1, 3),
            src=(src + off).reshape(-1),
            dst=(dst + off).reshape(-1),
            edge_valid=valid.reshape(-1),
            graph_ids=np.repeat(np.arange(b, dtype=np.int32), na),
            energy=energy,
        )
        return flat


def make_planted_graph_task(n: int, m: int, d_feat: int, n_classes: int, seed: int = 0):
    """Fixed graph + features whose labels depend on neighborhood features —
    learnable by one round of message passing."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    x = rng.standard_normal((n, d_feat)).astype(np.float32)
    w_true = rng.standard_normal((d_feat, n_classes))
    # label from own + mean-neighbor features
    agg = np.zeros((n, d_feat), np.float32)
    np.add.at(agg, dst, x[src])
    deg = np.maximum(np.bincount(dst, minlength=n), 1)[:, None]
    labels = np.argmax((x + agg / deg) @ w_true, axis=-1).astype(np.int32)
    return dict(
        src=src, dst=dst, edge_valid=np.ones(m, bool), x=x, labels=labels
    )
