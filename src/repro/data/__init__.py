from repro.data.pipeline import (
    LMBatchSource,
    RecsysBatchSource,
    MoleculeBatchSource,
    make_planted_graph_task,
)
