"""`SolveReport` — the one result schema every engine maps onto.

The deprecated entry points each reported a different type
(``MSFResult`` / ``DistMSFResult`` / ``CoarsenStats`` /
``DistCoarsenStats`` / ``UpdateStats``); a :class:`SolveReport` carries
the union of what callers actually consume — forest weight, the chosen
global eids, component labels, iteration count, the per-level coarsening
rows, and the two operational counters (host round-trips, recompiles) —
plus the engine-native result under ``raw`` for anything mode-specific.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import numpy as np


class SolveReport(NamedTuple):
    """Uniform result of ``Plan.solve()`` (and ``Plan.update()``)."""

    mode: str  # engine that produced this report
    weight: float  # total forest weight
    msf_eids: np.ndarray  # int32 [n_msf_edges] chosen edge ids, trimmed
    parent: np.ndarray  # int32 [n] component representative per vertex
    n_msf_edges: int
    iterations: int  # hook/shortcut rounds (levels + residual)
    levels: Tuple  # per-level LevelStats rows; () when no levels ran
    host_roundtrips: int  # per-level host round-trips (0 = device-resident)
    recompiles: int  # distinct executables compiled (stream mode)
    raw: Any  # engine-native result (MSFResult / UpdateStats / ...)

    @property
    def n_components(self) -> int:
        return int(len(np.unique(np.asarray(self.parent))))


def _trim_eids(msf_eids, n_msf_edges) -> np.ndarray:
    return np.asarray(msf_eids)[: int(n_msf_edges)].astype(np.int32)


def report_from_msf_result(
    mode: str,
    r,
    *,
    levels: Tuple = (),
    host_roundtrips: int = 0,
    recompiles: int = 0,
) -> SolveReport:
    """Adapt an ``MSFResult``/``DistMSFResult``-shaped record."""
    return SolveReport(
        mode=mode,
        weight=float(r.weight),
        msf_eids=_trim_eids(r.msf_eids, r.n_msf_edges),
        parent=np.asarray(r.parent),
        n_msf_edges=int(r.n_msf_edges),
        iterations=int(r.iterations),
        levels=tuple(levels),
        host_roundtrips=int(host_roundtrips),
        recompiles=int(recompiles),
        raw=r,
    )
