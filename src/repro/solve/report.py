"""`SolveReport` — the one result schema every engine maps onto.

The deprecated entry points each reported a different type
(``MSFResult`` / ``DistMSFResult`` / ``CoarsenStats`` /
``DistCoarsenStats`` / ``UpdateStats``); a :class:`SolveReport` carries
the union of what callers actually consume — forest weight, the chosen
global eids, component labels, iteration count, the per-level coarsening
rows, the two operational counters (host round-trips, recompiles), and
the per-phase wall-clock breakdown (``timings``, filled when the spec's
``obs`` knob is on — DESIGN.md §10), and the analytic ``cost`` of the
plan's executable (``repro.solve.cost.PlanCost``, computed once at
``plan.build`` — DESIGN.md §11) — plus the engine-native result under
``raw`` for anything mode-specific.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import numpy as np


class SolveReport(NamedTuple):
    """Uniform result of ``Plan.solve()`` (and ``Plan.update()``)."""

    mode: str  # engine that produced this report
    weight: float  # total forest weight
    msf_eids: np.ndarray  # int32 [n_msf_edges] chosen edge ids, trimmed
    parent: np.ndarray  # int32 [n] component representative per vertex
    n_msf_edges: int
    iterations: int  # hook/shortcut rounds (levels + residual)
    levels: Tuple  # per-level LevelStats rows; () when no levels ran
    host_roundtrips: int  # per-level host round-trips (0 = device-resident)
    recompiles: int  # distinct executables compiled (stream mode)
    raw: Any  # engine-native result (MSFResult / UpdateStats / ...)
    timings: Dict[str, float] = {}  # span name -> seconds; {} when obs off
    cost: Any = None  # PlanCost of the plan's executable; None off-scope
    stale: bool = False  # stream mode: snapshot may diverge from true MSF
    n_unhealed: int = 0  # stream mode: deletions not certifiably healed

    @property
    def n_components(self) -> int:
        """Component count from *canonical roots* — the number of
        vertices satisfying ``parent[v] == v`` after pointer-jumping the
        vector to fixpoint. Counting ``np.unique(parent)`` directly
        over-reports on non-canonical labelings (a chain ``2 → 1 → 0``
        has two distinct parent values but one component), and nothing
        in the engine contract promises canonical output."""
        return int(np.count_nonzero(_canonicalize(self.parent)
                                    == np.arange(len(self.parent))))


def _canonicalize(parent) -> np.ndarray:
    """Pointer-jump a parent vector to its root fixpoint (host-side)."""
    p = np.asarray(parent)
    while True:
        gp = p[p]
        if np.array_equal(gp, p):
            return p
        p = gp


def _trim_eids(msf_eids, n_msf_edges) -> np.ndarray:
    return np.asarray(msf_eids)[: int(n_msf_edges)].astype(np.int32)


def report_from_msf_result(
    mode: str,
    r,
    *,
    levels: Tuple = (),
    host_roundtrips: int = 0,
    recompiles: int = 0,
) -> SolveReport:
    """Adapt an ``MSFResult``/``DistMSFResult``-shaped record."""
    return SolveReport(
        mode=mode,
        weight=float(r.weight),
        msf_eids=_trim_eids(r.msf_eids, r.n_msf_edges),
        parent=np.asarray(r.parent),
        n_msf_edges=int(r.n_msf_edges),
        iterations=int(r.iterations),
        levels=tuple(levels),
        host_roundtrips=int(host_roundtrips),
        recompiles=int(recompiles),
        raw=r,
    )
