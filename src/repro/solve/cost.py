"""Analytic cost of a compiled plan — ``SolveReport.cost``.

At ``plan.build`` time the planner asks this module for a
:class:`PlanCost`: the static flop/byte/collective-byte counts of the
executable the engine will actually run, obtained by abstract-lowering
the jitted driver (no real arrays — ``ShapeDtypeStruct`` stand-ins with
the resolved statics) and running :mod:`repro.analysis.hlo_analyzer`
over the compiled HLO text. Bench rows then carry measured-vs-roofline
fractions, and a regression flagged by the sentinel is attributable to
"got slower" vs "does more work" (the counts changed).

Scope follows the executables the analyzer can see whole:

- **flat** — the ``_msf_jit`` while-loop driver. Its convergence loop is
  dynamic, so ``dynamic_loops > 0`` and the counts are *per iteration*
  (the paper's own unit, Figs 3/4); multiply by ``report.iterations``.
- **coarsen** — the level-0 executable (``fused_level`` under
  ``fused=True``, ``contract_level_und`` otherwise), the shape-dominant
  level of the pipeline. When the target is already at/below the cutoff
  the whole solve is the flat residual and the flat cost is reported.
- **dist / stream** — ``None``: the shard_map program would need a
  second full compile (the lowered executable does not share jax's call
  cache), and stream engines recompile per batch shape.

Analyses are memoized process-wide on (backend, statics, shapes) —
engines rebuilt with the same resolved spec and padded shapes (plan
cache misses after ``clear_plan_cache()``, same-shape sweeps) pay the
lower+compile once. Everything is best-effort: any failure yields
``cost=None`` rather than a failed plan.
"""
from __future__ import annotations

import threading
from typing import NamedTuple, Optional

import numpy as np

_lock = threading.Lock()
_memo: dict = {}


class PlanCost(NamedTuple):
    """Static cost of the plan's dominant executable (per device)."""

    flops: float  # dot_flops + ew_flops
    dot_flops: float
    ew_flops: float
    bytes: float  # HBM traffic under the producer-consumer model
    collective_bytes: float  # inter-device volume (0 off-mesh)
    dynamic_loops: int  # > 0: counts are per-iteration of those loops
    analyzed: str  # which executable the counts describe

    def as_dict(self) -> dict:
        d = self._asdict()
        d["dynamic_loops"] = int(self.dynamic_loops)
        return d


def predicted_time_s(
    cost: Optional[PlanCost], *, iterations: int = 1
) -> Optional[float]:
    """Analytic roofline time of a plan's executable on the reference
    accelerator (TPU v5e constants — the same chip every bench row's
    ``roofline_frac`` is quoted against), in seconds.

    Per-iteration costs (``dynamic_loops > 0``) are multiplied by the
    ``iterations`` hint. This is the autotuner's pre-measurement pruning
    metric (DESIGN.md §12): only the *ordering* matters, and only at
    order-of-magnitude granularity — the tuner's generous keep-ratio
    absorbs the model error. ``None`` in, ``None`` out.
    """
    if cost is None:
        return None
    from repro.analysis.roofline import TPU_V5E

    mult = max(int(iterations), 1) if cost.dynamic_loops else 1
    return mult * max(
        cost.flops / TPU_V5E["peak_flops_bf16"],
        cost.bytes / TPU_V5E["hbm_bw"],
    )


def _from_analysis(c: dict, analyzed: str) -> PlanCost:
    return PlanCost(
        flops=float(c["flops"]),
        dot_flops=float(c["dot_flops"]),
        ew_flops=float(c["ew_flops"]),
        bytes=float(c["bytes"]),
        collective_bytes=float(c["collective_bytes"]),
        dynamic_loops=int(c["dynamic_loops"]),
        analyzed=analyzed,
    )


def _analyze_lowered(lowered, analyzed: str) -> PlanCost:
    from repro.analysis.hlo_analyzer import analyze

    return _from_analysis(analyze(lowered.compile().as_text()), analyzed)


def _abstract(shape, dtype):
    import jax

    return jax.ShapeDtypeStruct(tuple(shape), np.dtype(dtype))


# ---------------------------------------------------------------------------
# per-mode analyses
# ---------------------------------------------------------------------------

def _flat_cost(n: int, e: int, rs) -> PlanCost:
    from repro.core.msf import _msf_jit
    from repro.graphs.structures import Graph

    s = rs.spec
    g = Graph(
        src=_abstract((e,), np.int32),
        dst=_abstract((e,), np.int32),
        w=_abstract((e,), np.float32),
        eid=_abstract((e,), np.int32),
        valid=_abstract((e,), np.bool_),
        n=n,
    )
    lowered = _msf_jit.lower(
        g,
        variant=s.variant,
        shortcut=rs.shortcut,
        capacity=s.capacity,
        max_iters=s.max_iters,
        unroll_guard=s.unroll_guard,
        pack=bool(rs.pack),
        segmin=rs.segmin_flat,
    )
    return _analyze_lowered(lowered, "flat")


def _coarsen_cost(target, rs) -> PlanCost:
    from repro.coarsen.engine import (
        _canonical_host,
        _eid_capacity,
        _next_pow2,
        fused_level,
    )
    from repro.coarsen.contract import contract_level_und
    from repro.solve.spec import resolve_dedupe, resolve_level_segmins
    from repro.stream.service import next_pow2

    cfg = rs.coarsen
    n0 = int(target.n)
    lo, hi, w, eid, valid, m0 = _canonical_host(target)
    if n0 <= cfg.cutoff or m0 == 0:
        # no levels run — the whole solve is the flat residual
        return _flat_cost(n0, int(np.asarray(target.src).shape[0]), rs)

    use_pack = bool(rs.pack)
    segmin_hook, segmin_dedupe = resolve_level_segmins(cfg.segmin, use_pack)
    pad = len(lo)
    n_pad = next_pow2(n0, floor=8)
    eid_cap = _eid_capacity(eid, m0)
    args = (
        _abstract((pad,), np.int32),  # lo
        _abstract((pad,), np.int32),  # hi
        _abstract((pad,), np.float32),  # w
        _abstract((pad,), np.int32),  # eid
        _abstract((pad,), np.bool_),  # valid
    )
    if cfg.fused:
        lowered = fused_level.lower(
            *args,
            _abstract((n0,), np.int32),  # label_map
            n=n_pad, eid_capacity=eid_cap, rounds=cfg.rounds_per_level,
            pack=use_pack, segmin=segmin_hook, segmin_dedupe=segmin_dedupe,
            dedupe_host=resolve_dedupe(cfg.dedupe) == "host",
        )
        return _analyze_lowered(lowered, "coarsen.level0.fused")
    lowered = contract_level_und.lower(
        *args,
        n=n_pad, eid_capacity=eid_cap, rounds=cfg.rounds_per_level,
        pack=use_pack, segmin=segmin_hook,
    )
    return _analyze_lowered(lowered, "coarsen.level0")


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def _memo_key(mode: str, target, rs):
    s = rs.spec
    common = (mode, rs.backend, rs.shortcut, s.capacity, s.max_iters,
              s.variant, bool(rs.pack), s.segmin)
    if mode == "flat":
        return common + (int(target.n), int(np.asarray(target.src).shape[0]))
    if mode == "coarsen":
        return common + (int(target.n), int(np.asarray(target.src).shape[0]),
                         rs.coarsen)
    return None


def plan_cost(mode: str, target, rs) -> Optional[PlanCost]:
    """Best-effort :class:`PlanCost` for a freshly built engine; ``None``
    when out of scope (dist/stream) or on any analysis failure."""
    try:
        if mode not in ("flat", "coarsen") or target is None:
            return None
        if getattr(target, "src", None) is None:  # int n / Partition2D
            return None
        key = _memo_key(mode, target, rs)
        with _lock:
            if key in _memo:
                return _memo[key]
        if mode == "flat":
            cost = _flat_cost(
                int(target.n), int(np.asarray(target.src).shape[0]), rs
            )
        else:
            cost = _coarsen_cost(target, rs)
        with _lock:
            _memo[key] = cost
        return cost
    except Exception:
        return None
