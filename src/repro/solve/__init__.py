# Unified solver API (DESIGN.md §9): declarative SolveSpec → resolve →
# plan → SolveReport across the flat / coarsen / dist / stream engines.
#
#     from repro.solve import SolveSpec, plan
#     report = plan(graph, SolveSpec(mode="coarsen")).solve()
#
# The spec/report layers import eagerly (leaf dependencies only); the
# plan compiler and its engine registry load lazily on first attribute
# access so `import repro.solve` never drags the whole engine stack in
# (and the engines themselves can import `repro.solve.spec` without a
# cycle).
from repro.solve.report import SolveReport, report_from_msf_result
from repro.solve.spec import ResolvedSpec, SolveSpec

_PLANNER_NAMES = (
    "plan",
    "Plan",
    "register_engine",
    "registered_modes",
    "plan_cache_info",
    "clear_plan_cache",
    "PLAN_CACHE_MAXSIZE",
)

# Autotuner surface (repro.solve.tune, DESIGN.md §12) — lazy like the
# planner so `import repro.solve` stays engine-free. The `tune()`
# entry point itself lives on the submodule (`repro.solve.tune.tune`):
# re-exporting it here would shadow the submodule attribute of the
# same name.
_TUNE_NAMES = (
    "TuningDB",
    "TuningDBError",
    "TuneKey",
    "set_tuning_db",
    "get_tuning_db",
)

__all__ = [
    "SolveSpec",
    "ResolvedSpec",
    "SolveReport",
    "report_from_msf_result",
    *_PLANNER_NAMES,
    *_TUNE_NAMES,
]


def __getattr__(name):
    if name in _PLANNER_NAMES:
        from repro.solve import engines as _  # noqa: F401 — registers built-ins
        from repro.solve import planner

        return getattr(planner, name)
    if name in _TUNE_NAMES:
        import importlib

        return getattr(importlib.import_module("repro.solve.tune"), name)
    raise AttributeError(f"module 'repro.solve' has no attribute {name!r}")
