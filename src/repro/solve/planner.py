"""``plan(target, spec)`` — compile a :class:`SolveSpec` into a ``Plan``.

The plan compiler resolves the spec against the target (concrete backend
choices), looks the (resolved spec, static shapes, jax backend, mesh)
key up in a bounded per-process cache, and wraps the cached engine in a
cheap :class:`Plan` handle with the uniform surface:

    p = plan(graph, SolveSpec(mode="coarsen", coarsen=cfg))
    report = p.solve()          # -> SolveReport, every mode
    p.update(u, v, w)           # stream mode only
    p.query(u, v)               # stream mode only

Engines are **target-free**: the cache stores compiled machinery
(jitted drivers, level pipelines), never the target's arrays, so two
graphs of the same padded shape share executables — the repeated-solve
path never re-traces. Stream plans are stateful (they own a forest) and
are deliberately *not* cached: every ``plan()`` call builds a fresh
engine, while the underlying jitted union solve still shares the global
jit cache.

``register_engine(mode, builder)`` is the extension point the next
engines (sharded-parent level-0 schedule, all_to_all dedupe) plug into
instead of growing another kwarg on a deprecated entry point.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Callable, NamedTuple

import numpy as np

from repro import obs
from repro.solve.report import SolveReport
from repro.solve.spec import MODES, ResolvedSpec, SolveSpec

PLAN_CACHE_MAXSIZE = 64

_lock = threading.Lock()
_cache: "OrderedDict[Any, Any]" = OrderedDict()  # key -> engine (LRU)


class _EngineDef(NamedTuple):
    mode: str
    builder: Callable  # (target, resolved, mesh) -> engine
    cacheable: bool


_engines: dict[str, _EngineDef] = {}


def register_engine(mode: str, builder: Callable, *, cacheable: bool = False):
    """Register a solver engine for ``mode``.

    ``builder(target, resolved, mesh)`` must return an object with
    ``solve(target, *args, **kw) -> SolveReport`` (plus ``update`` /
    ``query`` for streaming-style engines). Set ``cacheable=True`` only
    if the engine is target-free and safe to share across plans of the
    same (resolved spec, shapes, backend, mesh) key. Registering a mode
    also makes it a legal ``SolveSpec.mode`` value.
    """
    from repro.solve import spec as _spec_mod

    _engines[mode] = _EngineDef(mode, builder, cacheable)
    if mode not in MODES:
        _spec_mod.EXTRA_MODES.add(mode)
    return builder


def registered_modes() -> tuple:
    return tuple(_engines)


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def _shape_key(target) -> tuple:
    """Static-shape fingerprint of a plan target (never its data)."""
    if target is None:
        return ("none",)
    if isinstance(target, (int, np.integer)):
        return ("n", int(target))
    shard = getattr(target, "shard_size", None)
    if shard is not None:  # Partition2D
        return (
            "part2d", target.rows, target.cols, target.e_max,
            target.n, target.n_pad, shard,
        )
    src = getattr(target, "src", None)
    if src is not None:  # Graph
        return ("graph", target.n, int(src.shape[0]))
    raise TypeError(f"cannot plan against target of type {type(target).__name__}")


def _cache_get(key):
    with _lock:
        eng = _cache.get(key)
        if eng is not None:
            _cache.move_to_end(key)
        return eng


def _cache_put(key, engine):
    with _lock:
        _cache[key] = engine
        _cache.move_to_end(key)
        while len(_cache) > PLAN_CACHE_MAXSIZE:
            _cache.popitem(last=False)


def plan_cache_info() -> tuple:
    """(current entries, max entries) of the per-process plan cache."""
    with _lock:
        return len(_cache), PLAN_CACHE_MAXSIZE


def clear_plan_cache() -> None:
    with _lock:
        _cache.clear()


# ---------------------------------------------------------------------------
# the compiler
# ---------------------------------------------------------------------------

def plan(target, spec: SolveSpec | None = None, *, mesh=None, **overrides) -> "Plan":
    """Compile ``spec`` against ``target`` into a reusable :class:`Plan`.

    ``target``: a ``Graph`` (flat / coarsen), a ``Partition2D`` of the
    original graph plus ``mesh=`` (dist), or an ``int`` vertex count or
    ``Graph`` (stream — only ``n`` is read). ``spec`` defaults to
    ``SolveSpec()``; keyword ``overrides`` are folded into it
    (``plan(g, mode="coarsen")`` is shorthand for
    ``plan(g, SolveSpec(mode="coarsen"))``).
    """
    from repro.solve import engines as _  # noqa: F401 — registers built-ins

    if spec is None:
        spec = SolveSpec(**overrides)
    elif overrides:
        spec = dataclasses.replace(spec, **overrides)
    edef = _engines.get(spec.mode)
    if edef is None:
        raise ValueError(
            f"no engine registered for mode {spec.mode!r} "
            f"(registered: {registered_modes()})"
        )
    if spec.mode == "dist" and mesh is None:
        raise ValueError("mode='dist' needs a mesh= (jax Mesh over the 2D grid)")
    with obs.enabled(spec.obs):
        with obs.span("plan.resolve", mode=spec.mode):
            # mesh only keys the tuning-DB lookup (dist entries are
            # bucketed per mesh shape); heuristic resolution ignores it.
            resolved = spec.resolve(target, mesh=mesh)
        engine = None
        key = None
        if edef.cacheable:
            # The key carries the *resolved* spec (concrete pack/segmin/
            # dedupe choices), not just the user spec: two same-shape
            # targets whose data resolves differently (e.g. integral vs
            # float weights under pack=None) must not share an engine.
            key = (resolved, _shape_key(target), mesh)
            engine = _cache_get(key)
            if obs.metrics_active():
                obs.counter(
                    "plan.cache.hit" if engine is not None
                    else "plan.cache.miss"
                ).inc()
        if engine is None:
            # The compile span: builders construct/trace the jitted
            # drivers (dist mode traces the whole shard_map program here).
            with obs.span("plan.build", mode=spec.mode):
                engine = edef.builder(target, resolved, mesh)
                # Analytic cost of the executable this engine runs
                # (flat / coarsen scope; best-effort). Stored on the
                # engine so cache hits reuse the analysis with the
                # compiled machinery.
                from repro.solve.cost import plan_cost

                engine._plan_cost = plan_cost(spec.mode, target, resolved)
            if key is not None:
                _cache_put(key, engine)
    return Plan(spec=spec, resolved=resolved, target=target, mesh=mesh, engine=engine)


class Plan:
    """A compiled solve: spec + resolved backends + a (possibly shared)
    engine, bound to one target. Handles are cheap; the engine inside is
    what the plan cache reuses across same-shape targets."""

    def __init__(self, *, spec, resolved, target, mesh, engine):
        self.spec: SolveSpec = spec
        self.resolved: ResolvedSpec = resolved
        self.target = target
        self.mesh = mesh
        self._engine = engine

    @property
    def mode(self) -> str:
        return self.spec.mode

    @property
    def driver(self):
        """The engine-native callable (dist mode: the jitted block driver
        or the ``DistCoarsenMSF`` instance) — what the deprecated
        ``msf_distributed`` shim hands back for bit-identical call
        patterns. ``None`` for engines without one."""
        return getattr(self._engine, "driver", None)

    @property
    def engine(self):
        """The engine-native stateful object, for introspection beyond
        the report schema (stream mode: the ``StreamEngine`` —
        ``forest_edges()``, ``union_edge_capacity``, ...). Public so
        callers never reach through plan internals; the uniform surface
        is still ``solve()``/``update()``/``query()``."""
        return getattr(self._engine, "engine", self._engine)

    @property
    def service(self):
        """Stream mode: the engine's shared
        :class:`~repro.stream.service.QueryService` — reads from the
        published snapshot store, safe to call from any thread while the
        single writer applies ``update()``/``delete()``. The serving
        tier (``repro.serve.MSFServer``) batches through this seam."""
        svc = getattr(self._stream(), "service", None)
        if svc is None:
            raise ValueError(
                f"service is a stream-mode surface; this plan's mode "
                f"is {self.mode!r}"
            )
        return svc

    @property
    def cost(self):
        """Analytic :class:`~repro.solve.cost.PlanCost` of this plan's
        executable, computed once at build (``None`` when out of the
        analyzer's scope — dist/stream — or on analysis failure)."""
        return getattr(self._engine, "_plan_cost", None)

    def _attach_cost(self, rep):
        if isinstance(rep, SolveReport) and rep.cost is None:
            c = self.cost
            if c is not None:
                rep = rep._replace(cost=c)
        return rep

    def _observed(self, what: str, call):
        """Run one engine call under this spec's ``obs`` scope: a
        ``solve.<mode>[.<what>]`` span, and — for SolveReport-shaped
        results — the per-phase ``timings`` aggregation. The fully-off
        path (global mode off, spec knob off) is two attribute checks
        plus the zero-work cost attach (a NamedTuple ``_replace``)."""
        if not obs.metrics_active() and self.spec.obs == "off":
            return self._attach_cost(call())
        name = f"solve.{self.spec.mode}" + (f".{what}" if what else "")
        with obs.enabled(self.spec.obs):
            with obs.collect_timings() as t, obs.span(name):
                rep = call()
            if t and isinstance(rep, SolveReport):
                rep = rep._replace(timings=dict(t))
        return self._attach_cost(rep)

    def solve(self, *args, **kw) -> SolveReport:
        """Run the full solve for this plan's target. Dist plans accept
        the five block arrays positionally to override the target's own
        (the deprecated driver call pattern); flat plans accept
        ``parent0=`` for warm starts."""
        return self._observed(
            "", lambda: self._engine.solve(self.target, *args, **kw)
        )

    # -- stream-mode surfaces -------------------------------------------

    def _stream(self):
        if not hasattr(self._engine, "update"):
            raise ValueError(
                f"update()/query() are stream-mode surfaces; this plan's "
                f"mode is {self.mode!r}"
            )
        return self._engine

    def update(self, u, v, w) -> SolveReport:
        """Stream mode: apply one batch of edge insertions."""
        eng = self._stream()
        return self._observed("update", lambda: eng.update(u, v, w))

    def delete(self, u, v) -> SolveReport:
        """Stream mode: delete a batch of edges (exact replacement-edge
        search by default; tombstones under ``exact_deletes=False``)."""
        eng = self._stream()
        return self._observed("delete", lambda: eng.delete(u, v))

    def recertify(self, u, v, w) -> SolveReport:
        """Stream mode: rebuild forest + reservoir exactly from a
        caller-supplied surviving edge multiset — the recovery path when
        ``SolveReport.n_unhealed > 0`` after reservoir exhaustion."""
        eng = self._stream()
        if not hasattr(eng, "recertify"):
            raise ValueError(
                f"recertify() is a stream-mode surface; this plan's "
                f"mode is {self.mode!r}"
            )
        return self._observed("recertify", lambda: eng.recertify(u, v, w))

    def query(self, u, v):
        """Stream mode: batched connectivity queries against the latest
        published snapshot; returns a bool array."""
        eng = self._stream()
        return self._observed("query", lambda: eng.query(u, v))

    def compact(self) -> SolveReport:
        """Stream mode: drop tombstones and rebuild the forest."""
        eng = self._stream()
        return self._observed("compact", lambda: eng.compact())

    def __repr__(self):
        return (
            f"Plan(mode={self.mode!r}, target={_shape_key(self.target)}, "
            f"pack={self.resolved.pack}, dedupe={self.resolved.dedupe!r})"
        )
