"""Built-in engines behind ``repro.solve.plan`` — one per ``mode``.

Each builder adapts one existing solver stack (flat AS driver, the
coarsening level pipeline, the distributed Fig-2 / in-mesh fused
drivers, the streaming forest) to the uniform engine protocol:
``solve(target, ...) -> SolveReport`` (plus ``update``/``delete``/
``query``/``compact`` for stream). Builders receive a *resolved* spec —
every backend choice is already concrete; engines never auto-detect.

Imports of the engine stacks are lazy (inside the builders) so that
importing ``repro.solve`` stays cheap and cycle-free.
"""
from __future__ import annotations

import numpy as np

from repro.solve.planner import register_engine
from repro.solve.report import SolveReport, report_from_msf_result
from repro.solve.spec import ResolvedSpec


# ---------------------------------------------------------------------------
# flat
# ---------------------------------------------------------------------------

class _FlatEngine:
    def __init__(self, rs: ResolvedSpec):
        self._rs = rs

    def solve(self, graph, parent0=None) -> SolveReport:
        from repro.core.msf import run_flat

        rs, s = self._rs, self._rs.spec
        r = run_flat(
            graph,
            parent0=parent0,
            variant=s.variant,
            shortcut=rs.shortcut,
            capacity=s.capacity,
            max_iters=s.max_iters,
            unroll_guard=s.unroll_guard,
            pack=bool(rs.pack),
            segmin=rs.segmin_flat,
        )
        return report_from_msf_result("flat", r)


def _build_flat(target, rs: ResolvedSpec, mesh):
    return _FlatEngine(rs)


# ---------------------------------------------------------------------------
# coarsen
# ---------------------------------------------------------------------------

class _CoarsenEngine:
    def __init__(self, rs: ResolvedSpec):
        from repro.coarsen.engine import CoarsenMSF

        s = rs.spec
        msf_kw = dict(
            variant=s.variant,
            shortcut=rs.shortcut,
            capacity=s.capacity,
            pack=bool(rs.pack),
        )
        if s.max_iters is not None:
            msf_kw["max_iters"] = s.max_iters
        if rs.pack:
            msf_kw["segmin"] = s.segmin
        self._eng = CoarsenMSF(rs.coarsen, **msf_kw)

    def solve(self, graph) -> SolveReport:
        r = self._eng(graph)
        st = self._eng.last_stats
        return report_from_msf_result(
            "coarsen", r, levels=st.levels if st is not None else ()
        )


def _build_coarsen(target, rs: ResolvedSpec, mesh):
    return _CoarsenEngine(rs)


# ---------------------------------------------------------------------------
# dist
# ---------------------------------------------------------------------------

class _DistEngine:
    def __init__(self, part, rs: ResolvedSpec, mesh):
        s = rs.spec
        self._coarsen = rs.coarsen is not None
        if self._coarsen:
            from repro.coarsen.dist import DistCoarsenMSF

            # DistCoarsenMSF only reads the partition's *static* fields
            # (n, rows/cols, shard_size) outside __call__, so sharing the
            # engine across same-shape partitions is sound.
            self.driver = DistCoarsenMSF(
                part, mesh, rs.coarsen,
                row_axis=s.row_axis, col_axis=s.col_axis,
                max_iters=s.max_iters,
            )
        else:
            from repro.core.msf_dist import build_dist_driver

            self.driver = build_dist_driver(
                part, mesh,
                row_axis=s.row_axis, col_axis=s.col_axis,
                shortcut=rs.shortcut, capacity=s.capacity,
                max_iters=s.max_iters, pack=bool(rs.pack),
            )

    def solve(self, part, src_row=None, dst_col=None, w=None, eid=None,
              valid=None) -> SolveReport:
        if src_row is None:
            args = (part.src_row, part.dst_col, part.w, part.eid, part.valid)
        else:
            args = (src_row, dst_col, w, eid, valid)
        r = self.driver(*args)
        if self._coarsen:
            st = self.driver.last_stats
            return report_from_msf_result(
                "dist", r, levels=st.levels,
                host_roundtrips=st.host_roundtrips,
            )
        return report_from_msf_result("dist", r)


def _build_dist(target, rs: ResolvedSpec, mesh):
    return _DistEngine(target, rs, mesh)


# ---------------------------------------------------------------------------
# stream
# ---------------------------------------------------------------------------

class _StreamPlanEngine:
    def __init__(self, n: int, rs: ResolvedSpec):
        from repro.stream.engine import StreamEngine

        s = rs.spec
        self.engine = StreamEngine(
            n,
            batch_capacity=s.batch_capacity,
            adaptive_capacity=s.adaptive_capacity,
            min_capacity=s.min_capacity,
            compact_trigger=s.compact_trigger,
            pack=s.pack,  # None = per-batch auto, tracked by the engine
            segmin=s.segmin or "auto",
            coarsen=rs.coarsen,
            coarsen_threshold=s.coarsen_threshold,
            reservoir_capacity=s.reservoir_capacity,
            reservoir_per_component=s.reservoir_per_component,
            exact_deletes=s.exact_deletes,
            variant=s.variant,
            shortcut=rs.shortcut,
            capacity=s.capacity,
        )
        self._service = None
        self._last = None  # most recent UpdateStats/DeleteStats

    # -- reports --------------------------------------------------------

    def _report(self, iterations: int = 0) -> SolveReport:
        eng = self.engine
        snap = eng.snapshots.acquire()
        st = eng.last_coarsen_stats
        gid = eng.forest_gids()
        return SolveReport(
            mode="stream",
            weight=float(eng.weight),
            msf_eids=np.asarray(gid, np.int32),
            parent=np.asarray(snap.parent),
            n_msf_edges=int(len(gid)),
            iterations=int(iterations),
            levels=tuple(st.levels) if st is not None else (),
            host_roundtrips=0,
            recompiles=int(eng.recompiles),
            raw=self._last,
            stale=bool(snap.stale),
            n_unhealed=int(eng.unhealed),
        )

    # -- engine protocol ------------------------------------------------

    def solve(self, target) -> SolveReport:
        """Report the current forest state (no recompute)."""
        return self._report()

    def update(self, u, v, w) -> SolveReport:
        stats = self.engine.insert_batch(u, v, w)
        self._last = stats
        return self._report(iterations=stats.iterations)

    def delete(self, u, v) -> SolveReport:
        self._last = self.engine.delete_batch(u, v)
        return self._report()

    def compact(self) -> SolveReport:
        stats = self.engine.compact()
        self._last = stats
        return self._report(iterations=stats.iterations)

    def recertify(self, u, v, w) -> SolveReport:
        stats = self.engine.recertify(u, v, w)
        self._last = stats
        return self._report(iterations=stats.iterations)

    @property
    def service(self):
        """The shared :class:`~repro.stream.service.QueryService` over
        this engine's snapshot store — the read seam the serving tier
        (``repro.serve``) batches through."""
        if self._service is None:
            from repro.stream.service import QueryService

            self._service = QueryService(self.engine.snapshots)
        return self._service

    def query(self, u, v):
        return self.service.connected(u, v)


def _build_stream(target, rs: ResolvedSpec, mesh):
    from repro.solve.spec import _stream_n

    return _StreamPlanEngine(_stream_n(target), rs)


register_engine("flat", _build_flat, cacheable=True)
register_engine("coarsen", _build_coarsen, cacheable=True)
register_engine("dist", _build_dist, cacheable=True)
register_engine("stream", _build_stream, cacheable=False)
