"""SolveSpec autotuner + persisted plan database (DESIGN.md §12).

``SolveSpec.resolve()`` picks pack / segmin / dedupe / fused / shortcut
via hand-written heuristics; no single configuration wins across graph
classes (Durbhakula 2020, PAPERS.md). This module closes the loop the
spec (PR 5) and measurement (PR 7) layers opened:

1. **enumerate** — candidate ``SolveSpec``s for one (shape-class,
   weights-class, mode, backend, device_count, mesh) key
   (:func:`enumerate_candidates`);
2. **prune** — rank candidates by the analytic
   :func:`repro.solve.cost.predicted_time_s` before any measurement and
   drop the clearly-dominated tail (:func:`prune_by_cost` — generous by
   design: the model orders, it does not decide);
3. **measure** — time ``plan(target, candidate).solve()`` under the
   noise-tolerant median/IQR statistics of ``benchmarks.common``
   (:func:`tune`), asserting every candidate's forest weight + MSF edge
   set agree (a tuner must never trade correctness for speed);
4. **persist** — winners land in an on-disk **``tuning-db/v1``**
   database (:class:`TuningDB`), keyed on the bucketed shape class and
   environment-fingerprinted like the bench history;
5. **look up** — ``SolveSpec.resolve(target)`` with ``tuning="db"``
   consults the active database first (exact key, then nearest shape
   bucket under a compatibility check) and falls back to the existing
   heuristics on a missing / invalid / non-matching DB
   (:func:`resolve_overrides`). ``tuning="measure"`` tunes the target
   on first resolve and caches the winner in-process.

The database only ever *fills auto knobs*: a knob the user pinned
explicitly (``pack=False``, ``segmin="jnp"``, …) always wins over the
stored entry, so pinning behavior for parity suites needs nothing
beyond the spec itself.

Import discipline: sits next to ``spec.py`` below the engines; the
planner and the benchmarks harness are imported lazily inside functions
(``benchmarks`` lives at the repo root, not under ``src`` — a local
timing twin keeps the tuner usable when only ``src`` is importable).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import re
import threading
import time
import warnings
from typing import Any, NamedTuple, Optional

import numpy as np

SCHEMA = "tuning-db/v1"
#: Environment variable naming the default on-disk database consulted by
#: ``tuning="db"`` when no DB was set programmatically.
DB_ENV_VAR = "REPRO_TUNING_DB"
#: Spec knobs a tuning entry may override (plus the nested "coarsen"
#: block: cutoff / rounds_per_level / max_levels).
TUNABLE_KNOBS = ("pack", "segmin", "dedupe", "fused", "shortcut")
_COARSEN_KNOBS = ("cutoff", "rounds_per_level", "max_levels")
#: Nearest-bucket lookups never jump further than this Manhattan
#: distance in (log2 n, log2 degree) space — beyond it the winner was
#: measured on a graph too unlike the target to trust.
MAX_BUCKET_DISTANCE = 2

_SHAPE_RE = re.compile(r"^n(\d+)d(\d+)$")


class TuningDBError(ValueError):
    """A tuning database that cannot be trusted (wrong schema / malformed
    entries). Raised loudly by :meth:`TuningDB.load`; resolve-time
    consultation converts it into a one-time warning + heuristic
    fallback (a bad cache must never fail a solve)."""


# ---------------------------------------------------------------------------
# keys: shape-class bucketing + environment
# ---------------------------------------------------------------------------

class TuneKey(NamedTuple):
    """One tuning-database bucket. ``shape_class`` is the coarse
    ``n<log2 n>d<log2 avg-degree>`` bucket; everything else must match
    exactly for an entry to apply (the compatibility half of the
    nearest-bucket rule)."""

    shape_class: str
    weights: str  # "int" (pack32 regime) | "float" | "na" (no edge data)
    mode: str
    backend: str
    device_count: int
    mesh: str  # "RxC" for dist plans, "" otherwise


def shape_class(n: int, m: int) -> str:
    """Bucket a graph's (vertices, directed edges) into the DB key.

    Rounded log2 buckets: graphs within ~sqrt(2)x in both size and
    average degree share a bucket — the paper's own sweep granularity
    (scale steps of 1).
    """
    n = max(int(n), 1)
    m = max(int(m), 0)
    bn = int(round(math.log2(n))) if n > 1 else 0
    deg = m / n if n else 0.0
    bd = int(round(math.log2(deg))) if deg > 1.0 else 0
    return f"n{bn}d{bd}"


def parse_shape_class(s: str) -> Optional[tuple[int, int]]:
    m = _SHAPE_RE.match(s)
    return (int(m.group(1)), int(m.group(2))) if m else None


def weights_class(target) -> str:
    """"int" when the target's live weights sit in the pack32 regime,
    "float" otherwise, "na" when the target carries no edge data."""
    from repro.solve.spec import _pack_probe_arrays, weights_packable

    arrays = _pack_probe_arrays(target)
    if arrays is None:
        return "na"
    w, _, valid, _ = arrays
    return "int" if weights_packable(w[valid]) else "float"


def _mesh_label(mesh) -> str:
    if mesh is None:
        return ""
    shape = getattr(getattr(mesh, "devices", None), "shape", None)
    return "x".join(str(int(d)) for d in shape) if shape else ""


def _target_nm(target) -> Optional[tuple[int, int]]:
    if target is None:
        return None
    if isinstance(target, (int, np.integer)):
        return int(target), 0
    src = getattr(target, "src", None)
    if src is not None:  # Graph
        return int(target.n), int(np.asarray(src).shape[0])
    if getattr(target, "shard_size", None) is not None:  # Partition2D
        return int(target.n), int(target.rows * target.cols * target.e_max)
    return None


def key_for(mode: str, target, *, backend: str | None = None,
            mesh=None, device_count: int | None = None) -> TuneKey:
    """The database key of ``target`` under ``mode`` in this process's
    environment. Raises ``ValueError`` for targets without a shape."""
    import jax

    nm = _target_nm(target)
    if nm is None:
        raise ValueError(
            f"cannot derive a tuning key from target of type "
            f"{type(target).__name__}"
        )
    return TuneKey(
        shape_class=shape_class(*nm),
        weights=weights_class(target),
        mode=mode,
        backend=backend or jax.default_backend(),
        device_count=int(device_count if device_count is not None
                         else jax.device_count()),
        mesh=_mesh_label(mesh),
    )


def db_env_fingerprint() -> dict:
    """Provenance of a database build — the same fields as the bench
    history fingerprint (``benchmarks.common.env_fingerprint``), kept
    ``src``-standalone so the resolve path never imports benchmarks."""
    import platform

    import jax

    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count(),
    }


# ---------------------------------------------------------------------------
# the database
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TuningEntry:
    """One persisted winner: the knob overrides and the measurement that
    elected them."""

    key: TuneKey
    knobs: dict  # tunable-knob values (+ optional "coarsen" sub-dict)
    stats: dict  # median_us/iqr_us/iters/candidates/measured/pruned/...

    def as_dict(self) -> dict:
        return {
            "key": self.key._asdict(),
            "knobs": self.knobs,
            "stats": self.stats,
        }


class TuningDB:
    """In-memory view of one ``tuning-db/v1`` document."""

    def __init__(self, entries: dict[TuneKey, TuningEntry] | None = None,
                 env: dict | None = None, created: float | None = None):
        self.entries: dict[TuneKey, TuningEntry] = dict(entries or {})
        self.env = dict(env) if env is not None else db_env_fingerprint()
        self.created = time.time() if created is None else float(created)

    # -- mutation -------------------------------------------------------

    def put(self, key: TuneKey, knobs: dict, stats: dict | None = None):
        self.entries[key] = TuningEntry(key, dict(knobs), dict(stats or {}))

    # -- lookup ---------------------------------------------------------

    def lookup(self, key: TuneKey, *,
               max_distance: int = MAX_BUCKET_DISTANCE
               ) -> Optional[tuple[TuningEntry, bool]]:
        """``(entry, exact)`` for ``key`` — the exact bucket first, then
        the nearest compatible one (all non-shape fields equal, Manhattan
        distance in (log2 n, log2 degree) ≤ ``max_distance``); ``None``
        when nothing compatible exists."""
        entry = self.entries.get(key)
        if entry is not None:
            return entry, True
        want = parse_shape_class(key.shape_class)
        if want is None:
            return None
        compat = (key.weights, key.mode, key.backend,
                  key.device_count, key.mesh)
        best: Optional[tuple[tuple, TuningEntry]] = None
        for k, e in self.entries.items():
            if (k.weights, k.mode, k.backend, k.device_count, k.mesh) != compat:
                continue
            got = parse_shape_class(k.shape_class)
            if got is None:
                continue
            d = abs(got[0] - want[0]) + abs(got[1] - want[1])
            if d > max_distance:
                continue
            rank = (d, k.shape_class)  # deterministic tie-break
            if best is None or rank < best[0]:
                best = (rank, e)
        return (best[1], False) if best is not None else None

    # -- (de)serialization ----------------------------------------------

    def to_doc(self) -> dict:
        return {
            "schema": SCHEMA,
            "created": self.created,
            "env": self.env,
            "entries": [
                e.as_dict()
                for _, e in sorted(self.entries.items())
            ],
        }

    def save(self, path: str) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_doc(), f, indent=1, sort_keys=True)
        return path

    @classmethod
    def from_doc(cls, doc: Any) -> "TuningDB":
        if not isinstance(doc, dict):
            raise TuningDBError("tuning DB document is not an object")
        schema = doc.get("schema")
        if schema != SCHEMA:
            raise TuningDBError(
                f"unsupported tuning DB schema {schema!r} "
                f"(this build reads {SCHEMA!r})"
            )
        raw = doc.get("entries")
        if not isinstance(raw, list):
            raise TuningDBError("tuning DB has no entries list")
        entries: dict[TuneKey, TuningEntry] = {}
        for i, item in enumerate(raw):
            try:
                kd = dict(item["key"])
                key = TuneKey(
                    shape_class=str(kd["shape_class"]),
                    weights=str(kd["weights"]),
                    mode=str(kd["mode"]),
                    backend=str(kd["backend"]),
                    device_count=int(kd["device_count"]),
                    mesh=str(kd.get("mesh", "")),
                )
                knobs = item["knobs"]
                if not isinstance(knobs, dict):
                    raise TypeError("knobs is not a dict")
            except (KeyError, TypeError, ValueError) as e:
                raise TuningDBError(f"malformed tuning entry #{i}: {e}")
            entries[key] = TuningEntry(key, dict(knobs),
                                       dict(item.get("stats", {})))
        return cls(entries, env=doc.get("env"), created=doc.get("created"))

    @classmethod
    def load(cls, path: str) -> "TuningDB":
        try:
            with open(path) as f:
                doc = json.load(f)
        except OSError as e:
            raise TuningDBError(f"cannot read tuning DB {path}: {e}")
        except ValueError as e:
            raise TuningDBError(f"cannot parse tuning DB {path}: {e}")
        return cls.from_doc(doc)

    def __len__(self) -> int:
        return len(self.entries)


# ---------------------------------------------------------------------------
# process-global active database
# ---------------------------------------------------------------------------

_lock = threading.Lock()
_active: Optional[TuningDB] = None
_active_explicit = False  # set_tuning_db was called (incl. with None)
_env_loaded: dict[str, Optional[TuningDB]] = {}  # path -> db/None (memoized)
_warned: set = set()


def _warn_once(tag: str, msg: str) -> None:
    with _lock:
        if tag in _warned:
            return
        _warned.add(tag)
    warnings.warn(msg, RuntimeWarning, stacklevel=3)


def set_tuning_db(db: "TuningDB | str | None") -> Optional[TuningDB]:
    """Install the process-wide database ``tuning="db"`` consults.

    Accepts a :class:`TuningDB`, a path (loaded now — invalid files
    raise :class:`TuningDBError` loudly here, unlike the resolve-time
    path which falls back), or ``None`` to clear (resolve reverts to the
    ``REPRO_TUNING_DB`` environment variable, re-checked per resolve).
    """
    global _active, _active_explicit
    if isinstance(db, str):
        db = TuningDB.load(db)
    with _lock:
        _active = db
        _active_explicit = db is not None
        _env_loaded.clear()
        _warned.clear()
    return db


def get_tuning_db() -> Optional[TuningDB]:
    """The active database: the one installed via :func:`set_tuning_db`,
    else the ``REPRO_TUNING_DB`` file (loaded once per path; invalid
    files warn once and read as missing)."""
    with _lock:
        if _active_explicit or _active is not None:
            return _active
    path = os.environ.get(DB_ENV_VAR)
    if not path:
        return None
    with _lock:
        if path in _env_loaded:
            return _env_loaded[path]
    try:
        db = TuningDB.load(path)
    except TuningDBError as e:
        db = None
        _warn_once(
            f"env:{path}",
            f"ignoring tuning DB from {DB_ENV_VAR}: {e} — "
            f"SolveSpec.resolve() falls back to heuristics",
        )
    with _lock:
        _env_loaded[path] = db
    return db


# ---------------------------------------------------------------------------
# resolve-time consultation (the spec layer's hook)
# ---------------------------------------------------------------------------

def spec_knobs(spec) -> dict:
    """The tunable-knob values of ``spec`` — what :func:`tune` persists
    for a winning candidate."""
    knobs = {k: getattr(spec, k) for k in TUNABLE_KNOBS}
    if spec.coarsen is not None:
        knobs["coarsen"] = {
            k: getattr(spec.coarsen, k) for k in _COARSEN_KNOBS
        }
    return knobs


def _apply_knobs(spec, target, knobs: dict):
    """``spec`` with the stored winner folded into its *auto* knobs —
    explicit user choices always win; a stored ``pack=True`` is dropped
    unless the target's data actually sits in the pack32 regime (the
    nearest-bucket jump may cross the 24-bit index bound)."""
    from repro.coarsen.config import CoarsenConfig
    from repro.solve.spec import _pack_probe_arrays, auto_pack

    upd: dict = {}
    v = knobs.get("pack")
    if spec.pack is None and v is not None:
        if v:
            arrays = _pack_probe_arrays(target)
            if arrays is not None and auto_pack(*arrays):
                upd["pack"] = True
        else:
            upd["pack"] = False
    if spec.segmin is None and knobs.get("segmin") is not None:
        upd["segmin"] = knobs["segmin"]
    if spec.dedupe == "auto" and knobs.get("dedupe") not in (None, "auto"):
        upd["dedupe"] = knobs["dedupe"]
    if spec.fused is None and knobs.get("fused") is not None:
        upd["fused"] = bool(knobs["fused"])
    if spec.shortcut is None and knobs.get("shortcut") is not None:
        upd["shortcut"] = knobs["shortcut"]
    co = knobs.get("coarsen")
    if co and spec.mode == "coarsen" and spec.coarsen is None:
        upd["coarsen"] = CoarsenConfig(
            **{k: co[k] for k in _COARSEN_KNOBS if k in co}
        )
    if not upd:
        return spec
    # replace() re-runs __post_init__ — a stored combination illegal for
    # this mode raises here and the caller falls back to heuristics.
    return dataclasses.replace(spec, **upd)


def _count(name: str) -> None:
    from repro import obs

    if obs.metrics_active():
        obs.counter(name).inc()


def resolve_overrides(spec, target, backend: str, mesh=None):
    """The hook ``SolveSpec.resolve`` calls for ``tuning != "off"``.

    Returns the *effective* spec (auto knobs filled from the database
    winner) or ``None`` to keep the heuristic resolution. Never raises:
    every failure mode (no DB, stale schema, no compatible bucket,
    corrupt knobs) warns at most once and falls back.
    """
    try:
        key = key_for(spec.mode, target, backend=backend, mesh=mesh)
    except Exception:
        return None  # shapeless target (e.g. resolve(None)) — nothing to key on
    entry = None
    exact = False
    db = get_tuning_db()
    if db is not None:
        found = db.lookup(key)
        if found is not None:
            entry, exact = found
    if spec.tuning == "measure" and not exact and target is not None:
        entry = _measure_into_active_db(spec, target, mesh, key, db)
    if entry is None:
        _count("tune.db.miss")
        return None
    try:
        eff = _apply_knobs(spec, target, entry.knobs)
    except Exception as e:
        _count("tune.db.fallback")
        _warn_once(
            f"knobs:{key}",
            f"tuning DB entry for {key} is incompatible with the current "
            f"SolveSpec ({e}) — falling back to heuristics",
        )
        return None
    _count("tune.db.hit" if exact else "tune.db.near_hit")
    return eff if eff is not spec else None


def _measure_into_active_db(spec, target, mesh, key: TuneKey,
                            db: Optional[TuningDB]) -> Optional[TuningEntry]:
    """``tuning="measure"``: tune the target now, persist the winner
    into the active in-process DB so subsequent resolves hit exactly."""
    global _active, _active_explicit
    if spec.mode not in ("flat", "coarsen", "dist"):
        return None
    try:
        target_db = db if db is not None else TuningDB()
        tune(target, spec.mode, mesh=mesh, db=target_db,
             space="smoke", iters=2, warmup=1)
        if db is None:
            with _lock:
                _active = target_db
                _active_explicit = True
        return target_db.entries.get(key)
    except Exception as e:
        _warn_once(
            f"measure:{key}",
            f'tuning="measure" failed for {key} ({e}) — '
            f"falling back to heuristics",
        )
        return None


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------

def enumerate_candidates(target, mode: str = "flat", *,
                         backend: str | None = None,
                         space: str = "smoke") -> list:
    """Deterministic candidate ``SolveSpec`` list for ``target``.

    Candidates are always built with ``tuning="off"`` (the tuner must
    never recurse into itself) and ``obs="off"``; only combinations that
    pass static validation and the target's own data constraints (the
    pack32 regime) are emitted. ``space="smoke"`` is the CI-sized sweep,
    ``"full"`` the weekly one.
    """
    import jax

    from repro.coarsen.config import CoarsenConfig
    from repro.solve.spec import SolveSpec, _pack_probe_arrays, auto_pack

    if space not in ("smoke", "full"):
        raise ValueError(f"unknown candidate space {space!r}")
    backend = backend or jax.default_backend()
    arrays = _pack_probe_arrays(target)
    packable = arrays is not None and auto_pack(*arrays)
    nm = _target_nm(target)
    n = nm[0] if nm else 1

    out: list = []
    if mode == "flat":
        shortcuts = ("complete", "csp") if space == "smoke" else (
            "complete", "csp", "os")
        segmins = (None,) if space == "smoke" else (None, "jnp", "pallas")
        for pack in ((True, False) if packable else (False,)):
            for sc in shortcuts:
                for sm in (segmins if pack else (None,)):
                    out.append(SolveSpec(
                        mode="flat", pack=pack, segmin=sm, shortcut=sc,
                        tuning="off",
                    ))
    elif mode == "coarsen":
        cutoff = max(8, n // 8)
        rounds = (1, 2) if space == "smoke" else (1, 2, 3)
        segmins = (None,) if space == "smoke" else (None, "pallas")
        for fused in (True, False):
            for dd in ("device", "host"):
                for r in rounds:
                    for sm in segmins:
                        out.append(SolveSpec(
                            mode="coarsen",
                            coarsen=CoarsenConfig(
                                cutoff=cutoff, rounds_per_level=r),
                            fused=fused, dedupe=dd, segmin=sm,
                            tuning="off",
                        ))
    elif mode == "dist":
        for sc in ("csp", "os") if space == "smoke" else ("csp", "os", "baseline"):
            for pack in ((True, False) if packable else (False,)):
                out.append(SolveSpec(
                    mode="dist", shortcut=sc, pack=pack, tuning="off",
                ))
    else:
        raise ValueError(
            f"tuning sweeps cover modes flat/coarsen/dist, not {mode!r}"
        )
    return out


# ---------------------------------------------------------------------------
# cost pruning
# ---------------------------------------------------------------------------

class ScoredCandidate(NamedTuple):
    spec: Any  # SolveSpec
    predicted_s: Optional[float]  # None = model out of scope, never pruned


def prune_by_cost(target, candidates, *, ratio: float = 16.0,
                  min_keep: int = 4) -> tuple[list, int]:
    """``(kept, n_pruned)`` — candidates worth measuring.

    The analytic model ranks, measurement decides: a candidate is
    dropped only when its predicted time exceeds ``ratio`` × the best
    prediction *and* it is outside the ``min_keep`` best ranks.
    Unpredictable candidates (``PlanCost`` out of scope) are always
    kept. The generous ``ratio`` is the safety margin behind the
    "pruning never discards the measured winner" contract — the model
    only has to be right about order-of-magnitude losers.
    """
    from repro.solve.cost import plan_cost, predicted_time_s

    nm = _target_nm(target)
    # Convergence-loop iteration proxy for per-iteration (dynamic) costs:
    # the AS driver converges in O(log n) hook+shortcut rounds.
    iters_hint = max(1, int(math.ceil(math.log2(max(nm[0], 2))))) if nm else 1
    scored: list[ScoredCandidate] = []
    for c in candidates:
        try:
            rs = c.resolve(target)
            t = predicted_time_s(
                plan_cost(c.mode, target, rs), iterations=iters_hint
            )
        except Exception:
            t = None
        scored.append(ScoredCandidate(c, t))
    known = [s.predicted_s for s in scored if s.predicted_s is not None]
    if not known:
        return scored, 0
    best = min(known)
    order = sorted(
        range(len(scored)),
        key=lambda i: (scored[i].predicted_s is not None,
                       scored[i].predicted_s or 0.0),
    )
    rank = {i: r for r, i in enumerate(order)}
    kept = [
        s for i, s in enumerate(scored)
        if s.predicted_s is None
        or s.predicted_s <= best * ratio
        or rank[i] < min_keep
    ]
    return kept, len(scored) - len(kept)


# ---------------------------------------------------------------------------
# measurement + the tuner
# ---------------------------------------------------------------------------

def _measure_samples(fn, *, warmup: int, iters: int) -> list[float]:
    """Wall-clock seconds per call, blocking on device results — the
    ``benchmarks.common.measure_samples`` harness when importable (the
    repo-root layout), a behavior-identical twin otherwise."""
    try:
        from benchmarks.common import measure_samples

        return measure_samples(fn, warmup=warmup, iters=iters)
    except ImportError:
        import jax

        for _ in range(warmup):
            jax.block_until_ready(fn())
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return ts


def _median_iqr(samples_s) -> tuple[float, float]:
    us = np.asarray(samples_s, dtype=np.float64) * 1e6
    if us.size > 1:
        q25, q75 = np.percentile(us, [25, 75])
    else:
        q25 = q75 = us[0]
    return float(np.median(us)), float(q75 - q25)


class CandidateResult(NamedTuple):
    spec: Any  # the candidate SolveSpec
    median_us: float
    iqr_us: float
    predicted_s: Optional[float]


class TuneResult(NamedTuple):
    key: TuneKey
    winner: Any  # SolveSpec
    ranking: tuple  # CandidateResult, fastest first
    pruned: int  # candidates the cost model dropped before measurement
    entry: Optional[TuningEntry]  # what was persisted (None when db=None)


def _eid_set(rep) -> frozenset:
    eids = np.asarray(rep.msf_eids)
    return frozenset(eids[: int(rep.n_msf_edges)].tolist())


def tune(target, mode: str = "flat", *, mesh=None, backend: str | None = None,
         db: Optional[TuningDB] = None, space: str = "smoke",
         iters: int = 3, warmup: int = 1, seed: int = 0,
         ratio: float = 16.0, min_keep: int = 4,
         timer=None) -> TuneResult:
    """Enumerate → cost-prune → measure → (optionally) persist.

    Measurement order is shuffled with ``seed`` to decorrelate warmup /
    allocator drift from the enumeration order; the final ranking sorts
    on (median, IQR, canonical knob repr), so a fixed seed yields an
    identical ranking across runs given identical timings. ``timer``
    (``timer(spec, solve_fn) -> [seconds]``) overrides the real clock —
    the determinism tests' injection point. Every measured candidate's
    forest weight and MSF edge set are asserted identical: the tuner
    refuses to elect a "fast" configuration that changed the answer.

    ``db.put`` stores the winner under :func:`key_for`'s key; the caller
    owns ``db.save``.
    """
    from repro.solve.planner import plan

    key = key_for(mode, target, backend=backend, mesh=mesh)
    candidates = enumerate_candidates(
        target, mode, backend=backend, space=space)
    kept, n_pruned = prune_by_cost(
        target, candidates, ratio=ratio, min_keep=min_keep)
    if not kept:
        raise ValueError(f"no measurable candidates for {key}")

    order = list(range(len(kept)))
    np.random.default_rng(seed).shuffle(order)
    ref_weight = None
    ref_eids = None
    results: list[CandidateResult] = []
    for i in order:
        cand, predicted = kept[i]
        p = plan(target, cand, mesh=mesh)
        rep = p.solve()  # correctness probe (and first warmup)
        if ref_weight is None:
            ref_weight, ref_eids = float(rep.weight), _eid_set(rep)
        else:
            tol = max(1.0, 1e-6 * abs(ref_weight))
            if abs(float(rep.weight) - ref_weight) > tol or \
                    _eid_set(rep) != ref_eids:
                raise AssertionError(
                    f"candidate {spec_knobs(cand)} changed the MSF "
                    f"(weight {rep.weight} vs {ref_weight}) — refusing "
                    f"to tune over non-parity configurations"
                )
        if timer is not None:
            samples = timer(cand, p.solve)
        else:
            samples = _measure_samples(
                p.solve, warmup=max(warmup - 1, 0), iters=iters)
        med, iqr = _median_iqr(samples)
        results.append(CandidateResult(cand, med, iqr, predicted))

    results.sort(key=lambda r: (
        r.median_us, r.iqr_us,
        json.dumps(spec_knobs(r.spec), sort_keys=True, default=str),
    ))
    winner = results[0]
    entry = None
    if db is not None:
        stats = {
            "median_us": winner.median_us,
            "iqr_us": winner.iqr_us,
            "predicted_s": winner.predicted_s,
            "iters": int(iters),
            "warmup": int(warmup),
            "candidates": len(candidates),
            "measured": len(results),
            "pruned": int(n_pruned),
            "space": space,
        }
        db.put(key, spec_knobs(winner.spec), stats)
        entry = db.entries[key]
    return TuneResult(key, winner.spec, tuple(results), n_pruned, entry)
