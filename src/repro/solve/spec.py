"""Declarative solver specification — the single front door (DESIGN.md §9).

``SolveSpec`` is a frozen, hashable description of *which* MSF engine to
run (``mode``: flat / coarsen / dist / stream) and *how* (backend knobs:
pack / segmin / dedupe / fused / shortcut / variant, plus the mode's own
parameters). Validation that used to live in scattered ``raise`` sites
(``core.msf.msf``, ``coarsen.engine``, ``coarsen.dist``,
``stream.engine``) happens once, in ``__post_init__`` (static rules) and
:meth:`SolveSpec.resolve` (data-dependent rules).

This module is also the single home of every **backend auto-detect
rule** the engines used to duplicate:

- :func:`auto_pack` / :func:`weights_packable` — the pack32 regime test
  (integral weights in [0, 255], 24-bit indices);
- :func:`resolve_dedupe` — ``dedupe="auto"`` → device on TPU, host
  elsewhere;
- :func:`resolve_flat_segmin` / :func:`resolve_level_segmins` — segment-
  min backend selection for flat (unsorted-segment) reductions and for
  the coarsening level kernels (hook + dedupe sites), delegating the
  kernel-choice callables to ``repro.kernels.ops``.

Engines call these helpers; the public API calls
:meth:`SolveSpec.resolve`, which orchestrates all of them and returns a
concrete :class:`ResolvedSpec`. No engine re-implements a rule.

Import discipline: this module sits *below* the engines (they import
it), so its module-level imports stop at leaf layers
(``core.semiring``); ``coarsen.config`` and ``kernels.ops`` are pulled
lazily inside functions (importing ``repro.coarsen.config`` runs the
``repro.coarsen`` package init, whose engine imports this module back).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import numpy as np

from repro.core.semiring import PACK_IDX_MASK

MODES = ("flat", "coarsen", "dist", "stream")
#: Observability levels of the ``obs`` knob (DESIGN.md §10): "off" = the
#: one-branch no-op path, "metrics" = span-duration histograms + counters
#: in the process-global registry, "trace" = additionally record Chrome-
#: trace events (and take per-phase device-sync'd code paths where a
#: fused executable would otherwise hide the phases).
OBS_MODES = ("off", "metrics", "trace")
#: Tuning-database consultation levels of the ``tuning`` knob
#: (DESIGN.md §12): "off" = the hand-written heuristics below, "db" =
#: consult the active ``tuning-db/v1`` database first (exact key, then
#: nearest shape bucket) and fall back to the heuristics when it is
#: missing/invalid/non-matching, "measure" = tune the target on first
#: resolve and cache the winner in the in-process database. The knob is
#: part of the spec (and therefore of every resolved plan-cache key), so
#: parity suites can pin behavior with ``tuning="off"``.
TUNING_MODES = ("off", "db", "measure")
#: Modes added by ``repro.solve.register_engine`` beyond the built-ins.
#: Mode-specific validation below only applies to the built-in modes; a
#: registered engine owns its own validation.
EXTRA_MODES: set = set()
VARIANTS = ("complete", "paper", "pairwise")
#: Shortcut strategies per driver family. ``None`` in a spec means "the
#: mode's default": "complete" for the single-device drivers, "csp" for
#: the distributed Fig-2 solve.
FLAT_SHORTCUTS = (None, "complete", "csp", "os")
DIST_SHORTCUTS = (None, "csp", "os", "baseline")


# ---------------------------------------------------------------------------
# backend auto-detect rules (the engines' former duplicated copies)
# ---------------------------------------------------------------------------

def weights_packable(w) -> bool:
    """The pack32 weight regime: integral values in [0, 255] (paper §VII).

    The streaming engine applies this per insert batch (its packability
    is a running conjunction); :func:`auto_pack` applies it to a whole
    edge array at once.
    """
    w = np.asarray(w)
    if w.size == 0:
        return True
    return bool(np.all(w == np.floor(w)) and w.min() >= 0 and w.max() <= 255)


def auto_pack(w, eid, valid, e_capacity: int) -> bool:
    """pack32 applies when weights are integral in [0, 255] and both the
    global eids and the per-level position indices fit 24 bits strictly."""
    if e_capacity >= PACK_IDX_MASK:
        return False
    w = np.asarray(w)
    eid = np.asarray(eid)
    valid = np.asarray(valid)
    wv = w[valid]
    if wv.size == 0:
        return True
    if not weights_packable(wv):
        return False
    return int(eid[valid].max()) < PACK_IDX_MASK


def resolve_dedupe(dedupe: str, backend: str | None = None) -> str:
    """``dedupe="auto"`` → the in-jit device pipeline on TPU, the numpy
    lexsort twin elsewhere (XLA's CPU sort loses ~5× to numpy's)."""
    if dedupe != "auto":
        return dedupe
    backend = backend or jax.default_backend()
    return "device" if backend == "tpu" else "host"


def resolve_flat_segmin(segmin: str | None, pack: bool):
    """Packed segment-min callable for a *flat* reduction site (the MSF
    hook loops, the residual solve — unsorted segment ids).

    "sorted" is dedupe-only (the contiguous-range kernel silently loses
    out-of-order contributions) and degrades to "auto" here; with
    ``pack=False`` there is no packed reduction and the request is
    ignored. Returns a callable for ``core.msf._msf_jit``'s ``segmin``
    static, or ``None``.
    """
    if not pack:
        return None
    from repro.kernels.ops import flat_segmin_backend, make_packed_segmin

    return make_packed_segmin(flat_segmin_backend(segmin) or "auto")


def resolve_level_segmins(segmin: str | None, use_pack: bool):
    """(hook segmin, dedupe segmin) callables for the coarsening level
    kernels.

    The hook reduction (``coarsen.contract``) sees *unsorted* segment ids
    (roots of the current parent vector), so "sorted" degrades to "auto"
    there. The dedupe's ids are the boundary prefix-sum over sorted pair
    keys — its resolution delegates to
    ``kernels.ops.dedupe_segmin_backend`` (shared with the distributed
    fused level).
    """
    if not use_pack:
        return None, None
    from repro.kernels.ops import (
        dedupe_segmin_backend,
        flat_segmin_backend,
        make_packed_segmin,
    )

    hook = None
    if segmin not in (None, "jnp"):
        hook = make_packed_segmin(flat_segmin_backend(segmin))
    return hook, dedupe_segmin_backend(segmin)


# ---------------------------------------------------------------------------
# the spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SolveSpec:
    """Frozen, hashable description of one MSF solve configuration.

    ``mode`` selects the engine; the backend knobs (``pack``, ``segmin``,
    ``dedupe``, ``fused``, ``shortcut``, ``variant``) mean the same thing
    in every mode; the trailing blocks parameterize one mode each and are
    ignored by the others. ``None`` for a knob means "auto": concrete
    values are chosen by :meth:`resolve` against the target's data.
    """

    mode: str = "flat"
    # algorithm knobs (flat driver + coarsen/stream residual solves)
    variant: str = "complete"
    shortcut: str | None = None  # None = mode default (complete / csp)
    capacity: int = 1 << 16  # CSP/OS changed-map capacity
    max_iters: int | None = None
    unroll_guard: bool = True
    # backend knobs
    pack: bool | None = None  # pack32 inner loops; None = auto-detect
    segmin: str | None = None  # packed segment-min backend request
    dedupe: str = "auto"  # coarsen dedupe: "auto" | "device" | "host"
    fused: bool | None = None  # one-jit device-resident levels
    # coarsening levels ("coarsen" mode; optional prelude for dist/stream)
    coarsen: CoarsenConfig | None = None
    # stream mode
    batch_capacity: int = 1024
    adaptive_capacity: bool = False
    min_capacity: int = 16
    compact_trigger: float = 0.25
    coarsen_threshold: int = 1 << 15
    reservoir_capacity: int = 4096
    reservoir_per_component: int = 256
    exact_deletes: bool = True
    # dist mode
    row_axis: str = "data"
    col_axis: str = "model"
    # observability: "off" | "metrics" | "trace" (DESIGN.md §10). Scoped
    # around every Plan.solve()/update()/query() of this spec; "trace"
    # also fills SolveReport.timings and the exportable trace buffer.
    obs: str = "off"
    # tuning-database consultation: "off" | "db" | "measure"
    # (DESIGN.md §12, ``repro.solve.tune``).
    tuning: str = "off"

    def __post_init__(self):
        from repro.coarsen.config import (
            DEDUPE_BACKENDS,
            SEGMIN_BACKENDS,
            CoarsenConfig,
        )

        if self.mode not in MODES and self.mode not in EXTRA_MODES:
            raise ValueError(f"unknown mode {self.mode!r} (expected one of {MODES})")
        # obs is infrastructure, not engine policy — validated for
        # registered modes too (the plan layer applies it uniformly).
        if self.obs not in OBS_MODES:
            raise ValueError(
                f"unknown obs mode {self.obs!r} (expected one of {OBS_MODES})"
            )
        # tuning is resolve-layer infrastructure, validated for
        # registered modes too (the lookup is keyed by mode string).
        if self.tuning not in TUNING_MODES:
            raise ValueError(
                f"unknown tuning mode {self.tuning!r} "
                f"(expected one of {TUNING_MODES})"
            )
        if self.coarsen is True:  # convenience: True → defaults
            object.__setattr__(self, "coarsen", CoarsenConfig())
        if self.coarsen is not None and not isinstance(self.coarsen, CoarsenConfig):
            raise ValueError(
                f"coarsen must be a CoarsenConfig, True, or None; "
                f"got {self.coarsen!r}"
            )
        if self.mode not in MODES:
            return  # registered engines own their mode-specific rules
        if self.variant not in VARIANTS:
            raise ValueError(
                f"unknown variant {self.variant!r} (expected one of {VARIANTS})"
            )
        allowed = DIST_SHORTCUTS if self.mode == "dist" else FLAT_SHORTCUTS
        if self.shortcut not in allowed:
            raise ValueError(
                f"unknown {self.mode} shortcut {self.shortcut!r} "
                f"(expected one of {allowed})"
            )
        if self.segmin not in SEGMIN_BACKENDS:
            raise ValueError(
                f"unknown segmin backend {self.segmin!r} "
                f"(expected one of {SEGMIN_BACKENDS})"
            )
        if self.dedupe not in DEDUPE_BACKENDS:
            raise ValueError(f"unknown dedupe backend {self.dedupe!r}")
        if self.mode == "flat":
            if self.coarsen is not None:
                raise ValueError(
                    "coarsen levels need mode='coarsen' (or 'dist'/'stream' "
                    "with a coarsen prelude), not mode='flat'"
                )
            if self.fused:
                raise ValueError(
                    "fused=True requires coarsen= (it fuses the levels)"
                )
            if self.segmin == "sorted":
                raise ValueError(
                    "segmin='sorted' needs sorted segment ids — only the "
                    "coarsen dedupe provides them; the flat hook loop's ids "
                    "are unsorted (use 'pallas'/'jnp'/'auto' here)"
                )
            if self.pack is False and self.segmin not in (None, "auto"):
                raise ValueError(
                    "segmin= only applies to the pack=True inner loop"
                )
        if self.mode == "stream":
            if self.batch_capacity < 1:
                raise ValueError("batch_capacity must be >= 1")
            if self.min_capacity < 1:
                raise ValueError("min_capacity must be >= 1")
            if self.coarsen_threshold < 0:
                raise ValueError("coarsen_threshold must be >= 0")
            if self.reservoir_capacity < 0:
                raise ValueError("reservoir_capacity must be >= 0")
            if self.reservoir_per_component < 1:
                raise ValueError("reservoir_per_component must be >= 1")
        if self.capacity < 1:
            raise ValueError("capacity must be >= 1")

    # ------------------------------------------------------------------

    def resolve(
        self, target=None, *, backend: str | None = None, mesh=None
    ) -> "ResolvedSpec":
        """Turn auto knobs into concrete backend choices for ``target``.

        ``target`` is whatever :func:`repro.solve.plan` compiles against:
        a ``Graph`` (flat/coarsen/stream), a ``Partition2D`` (dist), an
        ``int`` vertex count (stream), or ``None`` (static resolution
        only). Every data-dependent validation and auto-detection lives
        here — engines receive concrete values. With ``tuning != "off"``
        the persisted tuning database is consulted first
        (``repro.solve.tune``, DESIGN.md §12): a compatible winner fills
        the knobs the user left on auto, and everything below resolves
        the *effective* spec; on any DB failure the heuristics run
        untouched. ``mesh`` only keys the tuning lookup (dist plans).
        """
        from repro.coarsen.config import CoarsenConfig

        backend = backend or jax.default_backend()
        eff = self
        if self.tuning != "off":
            from repro.solve.tune import resolve_overrides

            tuned = resolve_overrides(self, target, backend, mesh)
            if tuned is not None:
                eff = tuned
        pack = eff.pack
        if pack is None:
            if self.mode == "stream":
                # Stream keeps None — its engine tracks packability per
                # batch (a running conjunction over the insert stream),
                # degrading automatically near the pack32 index bound; a
                # Graph target only contributes its n here.
                pass
            else:
                arrays = _pack_probe_arrays(target)
                # No data to probe: the conservative float path.
                pack = auto_pack(*arrays) if arrays is not None else False
        if self.mode == "stream" and pack is True and target is not None:
            n = _stream_n(target)
            union = (n - 1) + eff.batch_capacity
            if union >= PACK_IDX_MASK:
                raise ValueError(
                    f"pack=True needs union eids < 2^24 - 1; (n - 1) + "
                    f"batch_capacity = {union} overflows the pack32 index "
                    f"field"
                )
        shortcut = eff.shortcut or ("csp" if self.mode == "dist" else "complete")
        coarsen = eff.coarsen
        if coarsen is None and self.mode in ("coarsen",):
            coarsen = CoarsenConfig()
        if coarsen is not None:
            # Spec-level segmin/fused override the embedded config — the
            # precedence the deprecated kwarg paths had — and dedupe joins
            # them (the old paths had no dedupe kwarg). spec.pack is
            # deliberately NOT folded in: historically the pack kwarg
            # steered only the residual/union solve while the levels kept
            # config.pack (usually None = per-level auto-detect), and
            # forcing an explicit pack onto the level kernels would run
            # pack32 on data the levels never validated.
            merged = {}
            if eff.segmin is not None:
                merged["segmin"] = eff.segmin
            if eff.dedupe != "auto":
                merged["dedupe"] = eff.dedupe
            if eff.fused is not None:
                merged["fused"] = eff.fused
            if merged:
                coarsen = dataclasses.replace(coarsen, **merged)
        # spec=eff, not self: engines read knobs through rs.spec, and the
        # plan-cache key must reflect the knobs actually in effect (eff
        # keeps self.tuning, so "db" and "off" never share a key even
        # when the database is empty).
        return ResolvedSpec(
            spec=eff,
            backend=backend,
            pack=pack,
            shortcut=shortcut,
            segmin_flat=resolve_flat_segmin(eff.segmin, bool(pack)),
            dedupe=resolve_dedupe(eff.dedupe, backend),
            coarsen=coarsen,
        )


class ResolvedSpec(NamedTuple):
    """Concrete backend choices for one (spec, target, jax backend)."""

    spec: SolveSpec
    backend: str  # jax backend the choices were made for
    pack: bool | None  # None only in stream mode (tracked per batch)
    shortcut: str
    segmin_flat: Any  # packed-segmin callable for flat hook loops, or None
    dedupe: str  # "device" | "host"
    coarsen: CoarsenConfig | None  # effective config, spec knobs folded in


def _pack_probe_arrays(target):
    """(w, eid, valid, e_capacity) host views for :func:`auto_pack`, or
    ``None`` when the target carries no edge data (int n / None)."""
    if target is None or isinstance(target, (int, np.integer)):
        return None
    w = getattr(target, "w", None)
    eid = getattr(target, "eid", None)
    valid = getattr(target, "valid", None)
    if w is None or eid is None or valid is None:
        return None
    w = np.asarray(w).reshape(-1)
    eid = np.asarray(eid).reshape(-1)
    valid = np.asarray(valid).reshape(-1)
    return w, eid, valid, int(eid.shape[0])


def _stream_n(target) -> int:
    if isinstance(target, (int, np.integer)):
        return int(target)
    n = getattr(target, "n", None)
    if n is None:
        raise ValueError(
            "stream mode needs a vertex count: pass an int n or a Graph"
        )
    return int(n)
