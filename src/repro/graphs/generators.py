"""Synthetic graph generators mirroring the paper's evaluation inputs.

- uniform random graphs (paper §VII-C weak scaling),
- R-MAT graphs (paper §VII-B, S=scale, E=edge factor),
- 2D grid "road" graphs (high-diameter proxies for road_usa/road_central),
- integer weights uniform in [1, 255] (paper §VII: "we generate uniformly
  distributed integers from 1 through 255 as edge weights", consistent with
  the GAP suite and Graph500 SSSP).
"""
from __future__ import annotations

import numpy as np

from repro.graphs.structures import Graph, from_edges

WEIGHT_LO, WEIGHT_HI = 1, 255


def assign_distinct_weights(rng: np.random.Generator, m: int) -> np.ndarray:
    """Integer weights 1..255; distinctness comes from (w, eid) lex order."""
    return rng.integers(WEIGHT_LO, WEIGHT_HI + 1, size=m).astype(np.float64)


def random_graph(n: int, m: int, seed: int = 0) -> Graph:
    """Uniform random graph with ~m undirected edges (paper Fig 7 inputs)."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, size=m)
    v = rng.integers(0, n, size=m)
    w = assign_distinct_weights(rng, m)
    return from_edges(u, v, w, n)


def rmat_graph(
    scale: int,
    edge_factor: int,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
) -> Graph:
    """R-MAT generator (Graph500 parameters by default). n = 2**scale."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    u = np.zeros(m, np.int64)
    v = np.zeros(m, np.int64)
    ab = a + b
    abc = a + b + c
    for bit in range(scale):
        r = rng.random(m)
        right = r >= ab  # bottom half for the row bit
        r2 = rng.random(m)
        # Conditional column split given the row choice.
        col_p = np.where(right, (abc - ab) / (1.0 - ab), a / ab)
        down = r2 >= col_p
        u |= right.astype(np.int64) << bit
        v |= down.astype(np.int64) << bit
    w = assign_distinct_weights(rng, m)
    return from_edges(u, v, w, n)


def grid_road_graph(rows: int, cols: int, seed: int = 0) -> Graph:
    """2D grid graph: high diameter, degree ≤ 4 — a road-network proxy."""
    n = rows * cols
    idx = np.arange(n).reshape(rows, cols)
    right_u = idx[:, :-1].ravel()
    right_v = idx[:, 1:].ravel()
    down_u = idx[:-1, :].ravel()
    down_v = idx[1:, :].ravel()
    u = np.concatenate([right_u, down_u])
    v = np.concatenate([right_v, down_v])
    rng = np.random.default_rng(seed)
    w = assign_distinct_weights(rng, len(u))
    return from_edges(u, v, w, n)


def components_graph(n_components: int, comp_size: int, seed: int = 0) -> Graph:
    """Disjoint union of random connected components — exercises the *forest*
    (not tree) case of MSF."""
    rng = np.random.default_rng(seed)
    us, vs = [], []
    for k in range(n_components):
        base = k * comp_size
        # random spanning tree + extra edges
        perm = rng.permutation(comp_size)
        for i in range(1, comp_size):
            us.append(base + perm[i])
            vs.append(base + perm[rng.integers(0, i)])
        extra = comp_size // 2
        us.extend(base + rng.integers(0, comp_size, extra))
        vs.extend(base + rng.integers(0, comp_size, extra))
    u = np.array(us, np.int64)
    v = np.array(vs, np.int64)
    w = assign_distinct_weights(rng, len(u))
    return from_edges(u, v, w, n_components * comp_size)
