"""Graph containers used throughout the framework.

The canonical representation is a *symmetric* COO edge list: every
undirected edge {u, v} appears twice, as (u, v) and (v, u), sharing one
global edge id ``eid``.  Distinct effective weights (required by
Awerbuch-Shiloach, paper §II) are guaranteed lexicographically by the
pair ``(w, eid)`` — see ``repro.core.semiring``.

Arrays may be padded to a static size; ``valid`` marks real edges.
``Graph`` is registered as a JAX pytree with ``n`` (vertex count) static,
so it can be passed straight through ``jax.jit`` boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """Symmetric COO graph. ``src/dst/eid`` int32 [E], ``w`` float32 [E]."""

    src: jax.Array
    dst: jax.Array
    w: jax.Array
    eid: jax.Array
    valid: jax.Array  # bool [E]; False for padding entries
    n: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_directed_edges(self) -> int:
        return int(self.src.shape[0])

    def pad_to(self, e_pad: int) -> "Graph":
        e = self.src.shape[0]
        if e_pad < e:
            raise ValueError(f"pad_to({e_pad}) smaller than E={e}")
        pad = e_pad - e

        def _pad(a, fill):
            return np.concatenate([np.asarray(a), np.full((pad,), fill, np.asarray(a).dtype)])

        return Graph(
            src=_pad(self.src, 0),
            dst=_pad(self.dst, 0),
            w=_pad(self.w, np.float32(np.inf)),
            eid=_pad(self.eid, np.iinfo(np.int32).max),
            valid=_pad(self.valid, False),
            n=self.n,
        )


def canonical_edges(u, v):
    """Canonical undirected endpoint order: (lo, hi, keep) with lo < hi.

    ``keep`` masks out self-loops. Works on numpy and jax arrays alike
    (elementwise min/max/compare only).
    """
    xp = np
    if not isinstance(u, np.ndarray):  # jax inputs: stay on device
        import jax.numpy as jnp

        xp = jnp
    lo, hi = xp.minimum(u, v), xp.maximum(u, v)
    return lo, hi, lo != hi


def edge_keys(lo, hi, n: int) -> np.ndarray:
    """Collision-free int64 key ``lo * n + hi`` for canonical (lo < hi) pairs.

    Host-side (int64) form — the streaming delta layer packs the same key
    into uint32 for its on-device sorted-lookup when n ≤ 2^16
    (``repro.stream.delta``).
    """
    lo = np.asarray(lo, np.int64)
    hi = np.asarray(hi, np.int64)
    return lo * np.int64(n) + hi


def dedupe_canonical(lo, hi, w, n: int):
    """Collapse duplicate canonical pairs, keeping the smallest weight
    (ties: smallest original index) — the same policy as ``from_edges``.

    Returns (lo, hi, w) host arrays sorted by key with one entry per pair.
    """
    lo = np.asarray(lo, np.int64)
    hi = np.asarray(hi, np.int64)
    w = np.asarray(w, np.float64)
    key = edge_keys(lo, hi, n)
    order = np.lexsort((w, key))
    key, lo, hi, w = key[order], lo[order], hi[order], w[order]
    first = np.ones(len(key), bool)
    first[1:] = key[1:] != key[:-1]
    return lo[first], hi[first], w[first]


def from_edges(u: np.ndarray, v: np.ndarray, w: np.ndarray, n: int) -> Graph:
    """Build a symmetric ``Graph`` from one direction of each undirected edge.

    Self-loops are dropped; duplicate undirected pairs are collapsed
    (keeping the smallest weight, then smallest original index).
    """
    u = np.asarray(u, np.int64)
    v = np.asarray(v, np.int64)
    w = np.asarray(w, np.float64)
    lo, hi, keep = canonical_edges(u, v)
    lo, hi, w = dedupe_canonical(lo[keep], hi[keep], w[keep], n)
    m = len(lo)
    eid = np.arange(m, dtype=np.int32)
    src = np.concatenate([lo, hi]).astype(np.int32)
    dst = np.concatenate([hi, lo]).astype(np.int32)
    ww = np.concatenate([w, w]).astype(np.float32)
    ee = np.concatenate([eid, eid])
    return Graph(
        src=src,
        dst=dst,
        w=ww,
        eid=ee.astype(np.int32),
        valid=np.ones(2 * m, bool),
        n=int(n),
    )


def graph_from_canonical(lo, hi, w, eid, valid, n: int) -> Graph:
    """Symmetric ``Graph`` from canonical undirected arrays, preserving the
    caller's global eids (unlike :func:`from_edges`, which renumbers).

    Used by the coarsening engine: contracted levels carry the *original*
    input-graph eids through relabel/filter so the final MSF edge set is
    reported in input ids. Arrays may be padded (``valid`` masks).
    """
    lo = np.asarray(lo, np.int32)
    hi = np.asarray(hi, np.int32)
    w = np.asarray(w, np.float32)
    eid = np.asarray(eid, np.int32)
    valid = np.asarray(valid, bool)
    return Graph(
        src=np.concatenate([lo, hi]),
        dst=np.concatenate([hi, lo]),
        w=np.concatenate([w, w]),
        eid=np.concatenate([eid, eid]),
        valid=np.concatenate([valid, valid]),
        n=int(n),
    )


def to_csr(graph: Graph) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Return (indptr, indices, weights, eids) CSR views of the valid edges."""
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    w = np.asarray(graph.w)
    eid = np.asarray(graph.eid)
    valid = np.asarray(graph.valid)
    src, dst, w, eid = src[valid], dst[valid], w[valid], eid[valid]
    order = np.argsort(src, kind="stable")
    src, dst, w, eid = src[order], dst[order], w[order], eid[order]
    indptr = np.zeros(graph.n + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, dst, w, eid


def nx_free_msf_weight(graph: Graph) -> float:
    """Oracle MSF weight via scipy (total weight is unique across all MSFs)."""
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csg

    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    w = np.asarray(graph.w)
    valid = np.asarray(graph.valid)
    src, dst, w = src[valid], dst[valid], w[valid]
    a = sp.coo_matrix((w, (src, dst)), shape=(graph.n, graph.n)).tocsr()
    t = csg.minimum_spanning_tree(a)
    return float(t.sum())


def nx_free_n_components(graph: Graph) -> int:
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csg

    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    valid = np.asarray(graph.valid)
    src, dst = src[valid], dst[valid]
    a = sp.coo_matrix(
        (np.ones(len(src)), (src, dst)), shape=(graph.n, graph.n)
    ).tocsr()
    ncc, _ = csg.connected_components(a, directed=False)
    return int(ncc)
