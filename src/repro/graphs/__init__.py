from repro.graphs.structures import (
    Graph,
    canonical_edges,
    dedupe_canonical,
    edge_keys,
    from_edges,
    graph_from_canonical,
    to_csr,
)
from repro.graphs.generators import (
    random_graph,
    rmat_graph,
    grid_road_graph,
    assign_distinct_weights,
)
