"""Edge/vertex partitioning for the distributed MSF engine (paper §IV-A).

Vertex layout: n is padded to a multiple of R*C shards of size S; shard
k = r*C + s lives on device (r, s); global vertex v belongs to shard
``v // S``. Row block r (the paper's x^(r)) is the *contiguous* range
[r*C*S, (r+1)*C*S) — an ``all_gather`` of the shards of devices (r, :).
Column block s (y^(s)) is the strided shard set {k : k % C == s}, i.e. an
``all_gather`` over devices (:, s); the local offset of v inside it is
(v // S // C) * S + v % S.

Edge (u, v) is assigned to device (row_of(u), col_of(v)) — the 2D √p×√p
distribution of A from the paper's Fig 2. Per-device edge lists are padded
to the global max so shapes stay static under XLA.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.graphs.structures import Graph


@dataclasses.dataclass(frozen=True)
class Partition2D:
    """Host-side partition result; arrays are [R, C, Emax]."""

    src_row: np.ndarray  # int32 — src offset within the device's row block
    dst_col: np.ndarray  # int32 — dst offset within the device's column block
    w: np.ndarray  # float32
    eid: np.ndarray  # int32
    valid: np.ndarray  # bool
    rows: int
    cols: int
    shard_size: int
    n: int
    n_pad: int

    @property
    def e_max(self) -> int:
        return int(self.src_row.shape[-1])


def pad_n(n: int, rows: int, cols: int) -> Tuple[int, int]:
    p = rows * cols
    shard = -(-n // p)
    return shard * p, shard


def partition_edges_2d(graph: Graph, rows: int, cols: int) -> Partition2D:
    n_pad, S = pad_n(graph.n, rows, cols)
    src = np.asarray(graph.src, np.int64)
    dst = np.asarray(graph.dst, np.int64)
    w = np.asarray(graph.w)
    eid = np.asarray(graph.eid)
    valid = np.asarray(graph.valid)
    src, dst, w, eid = src[valid], dst[valid], w[valid], eid[valid]

    shard_of_src = src // S
    shard_of_dst = dst // S
    r = shard_of_src // cols
    s = shard_of_dst % cols
    dev = r * cols + s
    counts = np.bincount(dev, minlength=rows * cols)
    e_max = max(1, int(counts.max()))

    src_row = np.zeros((rows, cols, e_max), np.int32)
    dst_col = np.zeros((rows, cols, e_max), np.int32)
    w_out = np.full((rows, cols, e_max), np.inf, np.float32)
    eid_out = np.full((rows, cols, e_max), np.iinfo(np.int32).max, np.int32)
    valid_out = np.zeros((rows, cols, e_max), bool)

    order = np.argsort(dev, kind="stable")
    src, dst, w, eid, dev = src[order], dst[order], w[order], eid[order], dev[order]
    # Local offsets.
    row_off = src - (src // (cols * S)) * (cols * S)
    col_off = (dst // S // cols) * S + dst % S
    starts = np.concatenate([[0], np.cumsum(counts)])
    for d in range(rows * cols):
        lo, hi = starts[d], starts[d + 1]
        k = hi - lo
        rr, ss = d // cols, d % cols
        src_row[rr, ss, :k] = row_off[lo:hi]
        dst_col[rr, ss, :k] = col_off[lo:hi]
        w_out[rr, ss, :k] = w[lo:hi]
        eid_out[rr, ss, :k] = eid[lo:hi]
        valid_out[rr, ss, :k] = True

    return Partition2D(
        src_row=src_row,
        dst_col=dst_col,
        w=w_out,
        eid=eid_out,
        valid=valid_out,
        rows=rows,
        cols=cols,
        shard_size=S,
        n=graph.n,
        n_pad=n_pad,
    )


def block_global_ids(src_row, dst_col, shard_size: int):
    """Recover **global** vertex ids from a :class:`Partition2D`'s local
    offsets — [R, C, Emax] arrays in, int32 [R, C, Emax] arrays out.

    Inverse of the layout in the module docstring: row block r is the
    contiguous range [r*C*S, (r+1)*C*S), so a row offset o decodes as
    ``r*C*S + o``; column block s is the strided shard set {k : k % C == s},
    so a column offset o sits in shard ``(o // S)*C + s`` at element
    ``o % S``. The distributed fused coarsening levels key edges globally
    (the per-level relabeling breaks the (row_of, col_of) block alignment,
    so the Fig-2 row/col-block gathers stop applying after level 0) — this
    is the one-time re-keying at level entry. Works on numpy and jax
    arrays alike (elementwise arithmetic + broadcasting only).
    """
    xp = np
    if not isinstance(src_row, np.ndarray):
        import jax.numpy as jnp

        xp = jnp
    rows, cols = src_row.shape[0], src_row.shape[1]
    r = xp.arange(rows, dtype=xp.int32)[:, None, None]
    s = xp.arange(cols, dtype=xp.int32)[None, :, None]
    src_g = r * (cols * shard_size) + src_row.astype(xp.int32)
    dst_g = (
        (dst_col.astype(xp.int32) // shard_size * cols + s) * shard_size
        + dst_col.astype(xp.int32) % shard_size
    )
    return src_g, dst_g


def partition_edges_1d(graph: Graph, parts: int) -> dict:
    """1D (flat) edge partition — the simpler distribution used by the GNN
    full-graph path and as an MSF ablation."""
    src = np.asarray(graph.src)
    valid = np.asarray(graph.valid)
    idx = np.nonzero(valid)[0]
    e = len(idx)
    e_max = -(-e // parts)
    out = {}
    for name, arr, fill in [
        ("src", graph.src, 0),
        ("dst", graph.dst, 0),
        ("w", graph.w, np.float32(np.inf)),
        ("eid", graph.eid, np.iinfo(np.int32).max),
    ]:
        a = np.asarray(arr)[idx]
        padded = np.full(parts * e_max, fill, a.dtype)
        padded[:e] = a
        out[name] = padded.reshape(parts, e_max)
    v = np.zeros(parts * e_max, bool)
    v[:e] = True
    out["valid"] = v.reshape(parts, e_max)
    return out
