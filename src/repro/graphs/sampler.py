"""CSR neighbor sampler for minibatch GNN training (GraphSAGE-style).

Host-side (numpy): given seed nodes and per-hop fanouts, samples a k-hop
neighborhood, relabels it into a compact padded subgraph, and returns
static-shape arrays suitable for a jitted train step. The GNN model then
runs *all* of its layers on the induced subgraph with the loss taken on the
seed nodes (standard practice for deep GNNs under fanout sampling).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SampledSubgraph:
    """Padded, relabelled subgraph. Seeds occupy node slots [0, n_seeds)."""

    src: np.ndarray  # int32 [E_pad]
    dst: np.ndarray  # int32 [E_pad]
    edge_valid: np.ndarray  # bool [E_pad]
    node_ids: np.ndarray  # int32 [N_pad] — original ids, -1 for padding
    node_valid: np.ndarray  # bool [N_pad]
    n_seeds: int


def max_sample_sizes(batch_nodes: int, fanouts: Sequence[int]) -> Tuple[int, int]:
    """Static (N_pad, E_pad) upper bounds for a fanout schedule."""
    n = batch_nodes
    e = 0
    frontier = batch_nodes
    for f in fanouts:
        e += frontier * f
        frontier = frontier * f
        n += frontier
    return n, e


class NeighborSampler:
    def __init__(self, indptr: np.ndarray, indices: np.ndarray, seed: int = 0):
        self.indptr = indptr
        self.indices = indices
        self.rng = np.random.default_rng(seed)

    def sample(
        self, seeds: np.ndarray, fanouts: Sequence[int]
    ) -> SampledSubgraph:
        seeds = np.asarray(seeds, np.int64)
        n_pad, e_pad = max_sample_sizes(len(seeds), fanouts)
        srcs, dsts = [], []
        nodes = list(seeds)
        pos = {int(v): k for k, v in enumerate(seeds)}
        frontier = seeds
        for f in fanouts:
            next_frontier = []
            for u in frontier:
                lo, hi = self.indptr[u], self.indptr[u + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = min(f, deg)
                sel = self.rng.choice(deg, size=take, replace=False) + lo
                for v in self.indices[sel]:
                    v = int(v)
                    if v not in pos:
                        pos[v] = len(nodes)
                        nodes.append(v)
                        next_frontier.append(v)
                    # message flows v -> u (aggregate neighbors into u)
                    srcs.append(pos[v])
                    dsts.append(pos[int(u)])
            frontier = np.array(next_frontier, np.int64)
            if len(frontier) == 0:
                break

        n, e = len(nodes), len(srcs)
        out_src = np.zeros(e_pad, np.int32)
        out_dst = np.zeros(e_pad, np.int32)
        ev = np.zeros(e_pad, bool)
        out_src[:e] = srcs
        out_dst[:e] = dsts
        ev[:e] = True
        node_ids = np.full(n_pad, -1, np.int32)
        node_ids[:n] = nodes
        nv = np.zeros(n_pad, bool)
        nv[:n] = True
        return SampledSubgraph(
            src=out_src,
            dst=out_dst,
            edge_valid=ev,
            node_ids=node_ids,
            node_valid=nv,
            n_seeds=len(seeds),
        )
