"""Fault-tolerant checkpointing with async save and reshard-on-restore.

- Saves are atomic: write to ``step_<n>.tmp/``, fsync, rename to
  ``step_<n>/`` with a ``DONE`` marker — a crash mid-save can never corrupt
  the latest restorable state.
- Async: the device→host transfer happens on the caller thread (cheap),
  serialization runs on a background thread; ``wait_for_saves`` joins.
- Restore reshards: arrays are stored whole and ``device_put`` with the
  *current* mesh's shardings, so a job can restart on a different device
  count (elastic scaling). At multi-host scale this becomes per-shard files
  keyed by shard index with the same DONE-marker protocol; the single-file
  layout here is the single-process specialization.
- The data pipeline is step-keyed (stateless), so restore ⇒ exact resume.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_PENDING: list[threading.Thread] = []


def _flatten(tree) -> dict:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *, async_save: bool = True):
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays, _ = _flatten(tree)
    # Pull to host synchronously (cheap vs serialization), serialize async.
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"

    def write():
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step}, f)
        with open(os.path.join(tmp, "DONE"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_save:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _PENDING.append(t)
    else:
        write()


def wait_for_saves():
    while _PENDING:
        _PENDING.pop().join()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "DONE")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, target: Any, shardings: Any = None):
    """``target`` supplies the pytree structure (values ignored);
    ``shardings`` (optional, same structure) reshards onto the current mesh."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}", "arrays.npz")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(shardings)[0]
    leaves = []
    for i, (kpath, leaf) in enumerate(flat):
        key = "/".join(str(p) for p in kpath)
        arr = data[key]
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
