"""Network serving tier over the stream engine (DESIGN.md §13).

``repro.serve`` turns a ``SolveSpec(mode="stream")`` plan into a TCP
service: an asyncio server that fuses concurrent point queries into
single padded device batches (one published snapshot per batch, its
version stamped on every response) while one writer task applies
inserts/deletes — the network-facing form of the single-writer /
snapshot-reader architecture the stream engine already enforces
in-process.

    from repro import serve
    handle = serve.start_in_thread(plan, serve.ServeConfig(port=0))
    with serve.ServeClient(handle.address) as c:
        c.connected([0], [1])
    handle.drain()

Ships: :mod:`~repro.serve.protocol` (the ``serve/v1`` wire codec),
:mod:`~repro.serve.server` (:class:`MSFServer`), and
:mod:`~repro.serve.client` (:class:`ServeClient`, the pipelined client
``repro.launch.loadgen --target`` drives).
"""
from repro.serve.client import ServeClient, ServeError, parse_target
from repro.serve.protocol import (
    SCHEMA,
    FrameDecoder,
    ProtocolError,
    decode_payload,
    encode_frame,
    error_response,
    response,
    validate_request,
)
from repro.serve.server import (
    MSFServer,
    ServeConfig,
    ServerHandle,
    serve_forever,
    start_in_thread,
)

__all__ = [
    "SCHEMA",
    "FrameDecoder",
    "MSFServer",
    "ProtocolError",
    "ServeClient",
    "ServeConfig",
    "ServeError",
    "ServerHandle",
    "decode_payload",
    "encode_frame",
    "error_response",
    "parse_target",
    "response",
    "serve_forever",
    "start_in_thread",
    "validate_request",
]
