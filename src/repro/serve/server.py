"""Asyncio TCP server fronting a ``mode="stream"`` solve plan
(DESIGN.md §13.2).

Dataflow — admission → fused batch → snapshot pin → response:

- every connection gets one reader coroutine that decodes ``serve/v1``
  frames (:mod:`repro.serve.protocol`) and routes them by op class;
- **query ops** (connected / component_id / component_size) land in one
  *bounded* admission queue (``queue_cap`` query points; a full queue
  answers ``overloaded`` immediately — backpressure, never unbounded
  buffering). The batcher task drains up to ``micro_batch`` points per
  event-loop tick, drops entries whose per-op deadline expired while
  queued (``deadline`` errors), and answers the rest through
  :meth:`QueryService.answer` as **one fused padded batch pinned to one
  published snapshot** — every response in the batch carries that
  snapshot's ``snapshot_version`` / ``stale`` / ``n_unhealed``. The
  fused device call runs on a dedicated thread so the event loop keeps
  admitting while XLA works;
- **write ops** (insert / delete) go to a single-consumer write queue
  applied by *the one writer task* via ``plan.update`` / ``plan.delete``
  on its own thread — the engine keeps its single-writer contract while
  readers serve from the double-buffered snapshots, which is the whole
  point of the snapshot protocol (DESIGN.md §6.3). Oversized insert
  batches are chunked to the engine's ``batch_capacity``;
- **admin ops**: ``status`` is the ``/healthz`` probe (version, weight,
  queue depths, draining flag), ``metrics`` returns the ``repro.obs``
  registry snapshot (query p50/p95/p99 via the ``serve.e2e_latency_s``
  histogram, queue depth gauge, batch occupancy, reservoir counters).

Graceful drain (SIGTERM/SIGINT under :func:`serve_forever`, or
:meth:`MSFServer.drain`): stop accepting connections, answer queued
queries and writes already admitted, refuse new ops with ``draining``,
checkpoint to ``checkpoint_dir`` when configured, then stop. A
checkpointed server warm-starts: construction restores the newest
completed checkpoint and resumes serving at the saved snapshot version
with a bit-identical forest (``repro.stream.persist``).

Obs surface (metrics mode is enabled at server start): counters
``serve.requests`` / ``serve.queries`` / ``serve.writes`` /
``serve.errors.<code>``, gauge ``serve.queue_depth``, histograms
``serve.e2e_latency_s`` (admission → host-resident answer) and
``serve.batch_occupancy`` (fused points per flush).
"""
from __future__ import annotations

import asyncio
import contextlib
import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import NamedTuple, Optional

import numpy as np

from repro import obs
from repro.serve import protocol as P

#: fused-points-per-flush histogram bucket bounds (powers of two)
_OCCUPANCY_BOUNDS = tuple(float(1 << k) for k in range(15))


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static configuration of one :class:`MSFServer`."""

    host: str = "127.0.0.1"
    port: int = 0  # 0 = ephemeral; read MSFServer.port after start()
    micro_batch: int = 256  # fused query points per batcher flush
    queue_cap: int = 8192  # admission bound in query points
    write_queue_cap: int = 64  # pending write ops before overload
    deadline_ms: float = 1000.0  # default per-query deadline in the queue
    max_payload: int = P.MAX_PAYLOAD
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0  # writes between autosaves (0 = drain only)
    drain_timeout_s: float = 10.0

    def __post_init__(self):
        if self.micro_batch < 1:
            raise ValueError("micro_batch must be >= 1")
        if self.queue_cap < self.micro_batch:
            raise ValueError("queue_cap must be >= micro_batch")
        if self.write_queue_cap < 1:
            raise ValueError("write_queue_cap must be >= 1")
        if self.deadline_ms <= 0:
            raise ValueError("deadline_ms must be > 0")


class _Conn:
    """Per-connection send side: a writer + an asyncio lock so batcher,
    writer task and the reader's own error responses never interleave
    partial frames on one socket."""

    def __init__(self, writer: asyncio.StreamWriter):
        self.writer = writer
        self.lock = asyncio.Lock()
        self.open = True

    async def send(self, obj: dict, *, max_payload: int) -> None:
        if not self.open:
            return
        try:
            frame = P.encode_frame(obj, max_payload=max_payload)
        except P.ProtocolError:
            # a response we cannot frame (pathological batch): drop it —
            # the client's timeout handles the rest
            obs.counter("serve.errors.response_too_large").inc()
            return
        async with self.lock:
            if not self.open:
                return
            try:
                self.writer.write(frame)
                await self.writer.drain()
            except (ConnectionError, RuntimeError):
                self.open = False


class _PendingQuery(NamedTuple):
    conn: _Conn
    req_id: object
    op: str
    u: np.ndarray
    v: np.ndarray
    deadline: float  # absolute loop time
    t_admit: float


class _PendingWrite(NamedTuple):
    conn: _Conn
    req_id: object
    op: str
    fields: dict


class MSFServer:
    """One stream plan behind one TCP listener (see module docstring)."""

    def __init__(self, plan, config: ServeConfig = ServeConfig()):
        if not hasattr(plan, "update"):
            raise ValueError(
                "MSFServer needs a stream-mode plan "
                "(repro.solve.plan(n, SolveSpec(mode='stream', ...)))"
            )
        self.plan = plan
        self.config = config
        self.service = plan.service
        self._engine = plan.engine
        self._admission: deque = deque()  # _PendingQuery entries
        self._admitted_points = 0
        self._admit_event: Optional[asyncio.Event] = None
        self._writeq: Optional[asyncio.Queue] = None
        self._server: Optional[asyncio.base_events.Server] = None
        self._tasks: list = []
        self._draining = False
        self._stopped: Optional[asyncio.Event] = None
        self._t0 = time.monotonic()
        self._served_queries = 0
        self._served_writes = 0
        self._writes_since_ckpt = 0
        self.restored_version: Optional[int] = None
        # One thread each: queries fuse into one device call at a time,
        # and the engine's single-writer contract maps to a 1-thread pool.
        self._query_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-query"
        )
        self._write_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-write"
        )

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> None:
        obs.enable("metrics")
        if self.config.checkpoint_dir:
            from repro.stream import persist

            if persist.latest_stream_step(self.config.checkpoint_dir) is not None:
                self.restored_version = persist.restore_stream(
                    self.config.checkpoint_dir, self._engine
                )
        self._admit_event = asyncio.Event()
        self._writeq = asyncio.Queue(maxsize=self.config.write_queue_cap)
        self._stopped = asyncio.Event()
        self._server = await asyncio.start_server(
            self._handle_conn, self.config.host, self.config.port
        )
        # cache: the listener's socket list empties once drain closes it
        self._port = self._server.sockets[0].getsockname()[1]
        self._t0 = time.monotonic()
        self._tasks = [
            asyncio.create_task(self._batch_loop(), name="serve-batcher"),
            asyncio.create_task(self._write_loop(), name="serve-writer"),
        ]

    @property
    def port(self) -> int:
        assert self._server is not None, "server not started"
        return self._port

    @property
    def draining(self) -> bool:
        return self._draining

    async def wait_stopped(self) -> None:
        await self._stopped.wait()

    async def drain(self) -> None:
        """Graceful shutdown: answer what was admitted, refuse the rest,
        checkpoint, stop. Idempotent."""
        if self._draining:
            await self._stopped.wait()
            return
        self._draining = True
        self._server.close()
        await self._server.wait_closed()
        deadline = time.monotonic() + self.config.drain_timeout_s
        while (self._admission or not self._writeq.empty()) \
                and time.monotonic() < deadline:
            self._admit_event.set()
            await asyncio.sleep(0.01)
        # anything still queued past the timeout is refused, not dropped
        while self._admission:
            q = self._admission.popleft()
            self._admitted_points -= len(q.u)
            await self._error(q.conn, q.req_id, q.op, "draining",
                              "server drained before this query ran")
        for t in self._tasks:
            t.cancel()
        for t in self._tasks:
            with contextlib.suppress(asyncio.CancelledError):
                await t
        if self.config.checkpoint_dir:
            from repro.stream import persist

            await asyncio.get_running_loop().run_in_executor(
                self._write_pool,
                lambda: persist.save_stream(
                    self.config.checkpoint_dir, self._engine
                ),
            )
        self._query_pool.shutdown(wait=True)
        self._write_pool.shutdown(wait=True)
        self._stopped.set()

    # -- connection handling ----------------------------------------------

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        conn = _Conn(writer)
        decoder = P.FrameDecoder(max_payload=self.config.max_payload)
        try:
            while True:
                data = await reader.read(1 << 16)
                if not data:
                    break
                try:
                    items = decoder.feed(data)
                except P.ProtocolError as e:
                    # unrecoverable framing violation: answer, then close
                    obs.counter(f"serve.errors.{e.code}").inc()
                    await conn.send(
                        P.error_response(None, None, e.code, str(e)),
                        max_payload=self.config.max_payload,
                    )
                    break
                for item in items:
                    if isinstance(item, P.ProtocolError):
                        obs.counter(f"serve.errors.{item.code}").inc()
                        await conn.send(
                            P.error_response(None, None, item.code, str(item)),
                            max_payload=self.config.max_payload,
                        )
                        continue
                    await self._route(conn, item)
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            conn.open = False
            with contextlib.suppress(Exception):
                writer.close()
                await writer.wait_closed()

    async def _route(self, conn: _Conn, obj: dict) -> None:
        obs.counter("serve.requests").inc()
        req_id = obj.get("id") if isinstance(obj.get("id"), (int, str)) else None
        try:
            op, fields = P.validate_request(obj)
        except P.ProtocolError as e:
            obs.counter(f"serve.errors.{e.code}").inc()
            await conn.send(
                P.error_response(req_id, obj.get("op"), e.code, str(e)),
                max_payload=self.config.max_payload,
            )
            return
        if op in P.ADMIN_OPS:
            await self._answer_admin(conn, req_id, op)
            return
        if self._draining:
            await self._error(conn, req_id, op, "draining",
                              "server is draining; not accepting new ops")
            return
        if op in P.QUERY_OPS:
            await self._admit_query(conn, req_id, op, fields)
        else:
            await self._admit_write(conn, req_id, op, fields)

    async def _error(self, conn: _Conn, req_id, op, code: str,
                     message: str) -> None:
        obs.counter(f"serve.errors.{code}").inc()
        snap = self._engine.snapshots.acquire()
        await conn.send(
            P.error_response(
                req_id, op, code, message,
                snapshot_version=snap.version, stale=snap.stale,
                n_unhealed=snap.n_unhealed,
            ),
            max_payload=self.config.max_payload,
        )

    # -- query lane --------------------------------------------------------

    async def _admit_query(self, conn: _Conn, req_id, op: str,
                           fields: dict) -> None:
        u = np.asarray(fields["u"], np.int64)
        v = np.asarray(fields.get("v", fields["u"]), np.int64)
        k = len(u)
        if k == 0 or k > self.service.max_batch:
            await self._error(
                conn, req_id, op, "bad_request",
                f"query batch must have 1..{self.service.max_batch} points",
            )
            return
        n = self._engine.n
        if u.min() < 0 or v.min() < 0 or max(u.max(), v.max()) >= n:
            await self._error(conn, req_id, op, "bad_request",
                              f"query vertex out of range [0, {n})")
            return
        if self._admitted_points + k > self.config.queue_cap:
            await self._error(conn, req_id, op, "overloaded",
                              "admission queue full; retry with backoff")
            return
        now = time.monotonic()
        deadline_ms = fields.get("deadline_ms", self.config.deadline_ms)
        self._admission.append(_PendingQuery(
            conn, req_id, op, u.astype(np.int32), v.astype(np.int32),
            deadline=now + deadline_ms / 1e3, t_admit=now,
        ))
        self._admitted_points += k
        obs.gauge("serve.queue_depth").set(self._admitted_points)
        self._admit_event.set()

    async def _batch_loop(self) -> None:
        """Micro-batched admission: one fused padded batch per tick."""
        cfg = self.config
        loop = asyncio.get_running_loop()
        while True:
            await self._admit_event.wait()
            self._admit_event.clear()
            # let same-tick arrivals join this flush before assembling
            await asyncio.sleep(0)
            while self._admission:
                batch: list[_PendingQuery] = []
                points = 0
                now = time.monotonic()
                while self._admission and points < cfg.micro_batch:
                    q = self._admission.popleft()
                    self._admitted_points -= len(q.u)
                    if now > q.deadline:
                        await self._error(
                            q.conn, q.req_id, q.op, "deadline",
                            "query deadline expired in the admission queue",
                        )
                        continue
                    batch.append(q)
                    points += len(q.u)
                obs.gauge("serve.queue_depth").set(self._admitted_points)
                if not batch:
                    continue
                u = np.concatenate([q.u for q in batch])
                v = np.concatenate([q.v for q in batch])
                obs.histogram(
                    "serve.batch_occupancy", _OCCUPANCY_BOUNDS
                ).observe(float(len(u)))
                # the fused device call off the loop: admission continues
                ans = await loop.run_in_executor(
                    self._query_pool, self.service.answer, u, v
                )
                t_done = time.monotonic()
                hist = obs.histogram("serve.e2e_latency_s")
                snap = ans.snapshot
                at = 0
                for q in batch:
                    k = len(q.u)
                    sl = slice(at, at + k)
                    at += k
                    if q.op == "connected":
                        result = {
                            "connected": [bool(x) for x in ans.connected[sl]]
                        }
                    elif q.op == "component_id":
                        result = {
                            "component": [int(x) for x in ans.component[sl]]
                        }
                    else:
                        result = {"size": [int(x) for x in ans.size[sl]]}
                    self._served_queries += k
                    obs.counter("serve.queries").inc(k)
                    hist.observe(t_done - q.t_admit)
                    await q.conn.send(
                        P.response(
                            q.req_id, q.op, result,
                            snapshot_version=snap.version, stale=snap.stale,
                            n_unhealed=snap.n_unhealed,
                        ),
                        max_payload=cfg.max_payload,
                    )

    # -- write lane --------------------------------------------------------

    async def _admit_write(self, conn: _Conn, req_id, op: str,
                           fields: dict) -> None:
        try:
            self._writeq.put_nowait(_PendingWrite(conn, req_id, op, fields))
        except asyncio.QueueFull:
            await self._error(conn, req_id, op, "overloaded",
                              "write queue full; retry with backoff")

    def _apply_write(self, op: str, fields: dict) -> dict:
        """Runs on the single writer thread — the only engine mutator."""
        u = np.asarray(fields["u"], np.int64)
        v = np.asarray(fields["v"], np.int64)
        if op == "insert":
            w = np.asarray(fields["w"], np.float64)
            cap = self._engine.batch_capacity
            n_new = n_drop = 0
            rep = None
            for at in range(0, len(u), cap):
                rep = self.plan.update(u[at:at + cap], v[at:at + cap],
                                       w[at:at + cap])
                n_new += rep.raw.n_new
                n_drop += rep.raw.n_drop
            return {
                "n_edges": int(len(u)),
                "n_new": int(n_new),
                "n_drop": int(n_drop),
                "weight": float(rep.weight) if rep is not None
                else float(self._engine.weight),
                "version": int(self._engine.version),
            }
        rep = self.plan.delete(u, v)
        raw = rep.raw
        return {
            "n_deleted": int(raw.n_deleted),
            "n_missing": int(raw.n_missing),
            "n_replacements": int(raw.n_replacements),
            "n_unhealed_new": int(raw.n_unhealed),
            "weight": float(rep.weight),
            "version": int(self._engine.version),
        }

    async def _write_loop(self) -> None:
        loop = asyncio.get_running_loop()
        cfg = self.config
        while True:
            wr: _PendingWrite = await self._writeq.get()
            try:
                result = await loop.run_in_executor(
                    self._write_pool, self._apply_write, wr.op, wr.fields
                )
            except Exception as e:  # engine rejection → in-band error
                await self._error(wr.conn, wr.req_id, wr.op, "internal", str(e))
                continue
            self._served_writes += 1
            obs.counter("serve.writes").inc()
            snap = self._engine.snapshots.acquire()
            await wr.conn.send(
                P.response(
                    wr.req_id, wr.op, result,
                    snapshot_version=snap.version, stale=snap.stale,
                    n_unhealed=snap.n_unhealed,
                ),
                max_payload=cfg.max_payload,
            )
            if cfg.checkpoint_dir and cfg.checkpoint_every > 0:
                self._writes_since_ckpt += 1
                if self._writes_since_ckpt >= cfg.checkpoint_every:
                    self._writes_since_ckpt = 0
                    from repro.stream import persist

                    await loop.run_in_executor(
                        self._write_pool,
                        lambda: persist.save_stream(
                            cfg.checkpoint_dir, self._engine, async_save=True
                        ),
                    )

    # -- admin lane --------------------------------------------------------

    async def _answer_admin(self, conn: _Conn, req_id, op: str) -> None:
        snap = self._engine.snapshots.acquire()
        if op == "status":
            result = {
                "status": "draining" if self._draining else "serving",
                "uptime_s": time.monotonic() - self._t0,
                "n": int(self._engine.n),
                "weight": float(snap.weight),
                "n_forest_edges": int(snap.n_forest_edges),
                "n_components": int(snap.n_components),
                "reservoir_size": int(self._engine.reservoir_size),
                "queue_depth": int(self._admitted_points),
                "write_queue_depth": int(self._writeq.qsize()),
                "served_queries": int(self._served_queries),
                "served_writes": int(self._served_writes),
                "restored_version": self.restored_version,
                "checkpoint_dir": self.config.checkpoint_dir,
            }
        else:
            result = {"metrics": obs.metrics_snapshot()}
        await conn.send(
            P.response(
                req_id, op, result,
                snapshot_version=snap.version, stale=snap.stale,
                n_unhealed=snap.n_unhealed,
            ),
            max_payload=self.config.max_payload,
        )


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


async def _serve_until_signalled(plan, config: ServeConfig) -> None:
    import signal

    server = MSFServer(plan, config)
    await server.start()
    loop = asyncio.get_running_loop()
    for sig in (signal.SIGTERM, signal.SIGINT):
        with contextlib.suppress(NotImplementedError, RuntimeError):
            loop.add_signal_handler(
                sig, lambda: asyncio.ensure_future(server.drain())
            )
    print(f"# serving tcp://{config.host}:{server.port} "
          f"(micro_batch={config.micro_batch}, queue_cap={config.queue_cap}"
          + (f", restored v{server.restored_version}"
             if server.restored_version is not None else "")
          + ")", flush=True)
    await server.wait_stopped()


def serve_forever(plan, config: ServeConfig) -> None:
    """Run one server until SIGTERM/SIGINT completes the graceful drain
    (the ``repro.launch.serve_graph --serve`` entry)."""
    asyncio.run(_serve_until_signalled(plan, config))


class ServerHandle:
    """A server running on a background thread with its own event loop —
    the in-process harness the tests and notebooks drive."""

    def __init__(self, server: MSFServer, loop: asyncio.AbstractEventLoop,
                 thread: threading.Thread):
        self.server = server
        self._loop = loop
        self._thread = thread

    @property
    def port(self) -> int:
        return self.server.port

    @property
    def address(self) -> str:
        return f"tcp://{self.server.config.host}:{self.port}"

    def drain(self, timeout: float = 30.0) -> None:
        """Trigger the graceful drain and join the loop thread."""
        fut = asyncio.run_coroutine_threadsafe(self.server.drain(), self._loop)
        fut.result(timeout=timeout)
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout=timeout)


def start_in_thread(plan, config: ServeConfig = ServeConfig()) -> ServerHandle:
    """Start an :class:`MSFServer` on a dedicated event-loop thread and
    block until it accepts connections; ``handle.drain()`` shuts it down."""
    loop = asyncio.new_event_loop()
    server = MSFServer(plan, config)
    started = threading.Event()
    boot_err: list = []

    def runner():
        asyncio.set_event_loop(loop)

        async def boot():
            try:
                await server.start()
            except Exception as e:  # surface construction failures
                boot_err.append(e)
            finally:
                started.set()

        loop.run_until_complete(boot())
        if not boot_err:
            loop.run_forever()
        loop.close()

    thread = threading.Thread(target=runner, daemon=True,
                              name="serve-loop")
    thread.start()
    started.wait(timeout=30.0)
    if boot_err:
        raise boot_err[0]
    return ServerHandle(server, loop, thread)
