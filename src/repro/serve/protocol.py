"""Wire protocol of the serving tier — versioned ``serve/v1`` frames
(DESIGN.md §13.1).

Framing is length-prefixed binary: a 4-byte big-endian unsigned payload
length followed by that many bytes of UTF-8 JSON. The JSON body keeps the
protocol debuggable (``nc`` + a hex header is a working client) while the
prefix makes message boundaries exact — no sentinel scanning, and a
decoder that never over-reads. One request object per frame, one response
frame per request, ordered per operation class (the server answers query
frames in admission order and write frames in arrival order, but a
pipelined client must match on ``id``, not arrival order, because query
and write lanes drain independently).

Request objects::

    {"op": "connected",      "id": 7, "u": [0, 5], "v": [3, 2],
     "deadline_ms": 250}                      # deadline is optional
    {"op": "component_id",   "id": 8, "u": [0, 5]}
    {"op": "component_size", "id": 9, "u": [0]}
    {"op": "insert", "id": 10, "u": [...], "v": [...], "w": [...]}
    {"op": "delete", "id": 11, "u": [...], "v": [...]}
    {"op": "status",  "id": 12}               # /healthz-style probe
    {"op": "metrics", "id": 13}               # repro.obs snapshot

Every response carries the schema tag, the echoed ``id`` and ``op``, and
the **snapshot coordinates** the answer was computed against — queries
pin one published :class:`~repro.stream.snapshot.Snapshot` per fused
batch, so ``snapshot_version`` / ``stale`` / ``n_unhealed`` let a client
reason about exactly which forest state it observed::

    {"schema": "serve/v1", "id": 7, "op": "connected", "ok": true,
     "result": {"connected": [true, false]},
     "snapshot_version": 42, "stale": false, "n_unhealed": 0}

Failures are in-band (``ok: false`` + ``error.code``), never a dropped
connection, except for framing violations the stream cannot recover from
(an oversized declared length) where the server answers once and closes.

Error codes: ``bad_frame`` (undecodable payload), ``bad_request``
(well-formed JSON, invalid fields), ``unknown_op``, ``too_large``
(declared frame length above the negotiated cap), ``overloaded``
(admission or write queue full — the backpressure signal), ``deadline``
(query expired in the admission queue), ``draining`` (server is in
graceful shutdown), ``internal`` (engine raised; message carries the
exception text).
"""
from __future__ import annotations

import json
import struct
from typing import Iterator, List, Tuple, Union

SCHEMA = "serve/v1"

HEADER = struct.Struct("!I")
HEADER_SIZE = HEADER.size
#: default cap on one frame's JSON payload (requests and responses)
MAX_PAYLOAD = 8 << 20

QUERY_OPS = ("connected", "component_id", "component_size")
WRITE_OPS = ("insert", "delete")
ADMIN_OPS = ("status", "metrics")
OPS = QUERY_OPS + WRITE_OPS + ADMIN_OPS

#: required array fields per op (validated to be same-length int/float lists)
_OP_FIELDS = {
    "connected": ("u", "v"),
    "component_id": ("u",),
    "component_size": ("u",),
    "insert": ("u", "v", "w"),
    "delete": ("u", "v"),
    "status": (),
    "metrics": (),
}


class ProtocolError(ValueError):
    """A malformed frame or request.

    ``code`` is the wire error code; ``recoverable`` says whether the
    byte stream is still frame-aligned after the failure (bad JSON inside
    a correctly-framed payload: yes; an oversized declared length whose
    body we refuse to buffer: no — the server answers and closes).
    """

    def __init__(self, code: str, message: str, *, recoverable: bool = True):
        super().__init__(message)
        self.code = code
        self.recoverable = recoverable


def encode_frame(obj: dict, *, max_payload: int = MAX_PAYLOAD) -> bytes:
    """Serialize one request/response object into a length-prefixed frame."""
    payload = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    if len(payload) > max_payload:
        raise ProtocolError(
            "too_large",
            f"frame payload {len(payload)} bytes exceeds cap {max_payload}",
        )
    return HEADER.pack(len(payload)) + payload


def decode_payload(payload: bytes) -> dict:
    """Decode one frame payload into a request/response object."""
    try:
        obj = json.loads(payload.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ProtocolError("bad_frame", f"undecodable frame payload: {e}")
    if not isinstance(obj, dict):
        raise ProtocolError(
            "bad_frame", f"frame payload must be a JSON object, got "
            f"{type(obj).__name__}"
        )
    return obj


class FrameDecoder:
    """Incremental frame decoder over an arbitrary byte-chunk stream.

    ``feed(data)`` returns the objects completed by ``data`` — each entry
    either a decoded ``dict`` or a *recoverable* :class:`ProtocolError`
    (bad JSON inside a well-framed payload: the stream stays aligned, the
    caller answers with ``error.code`` and keeps reading). Unrecoverable
    violations — a declared length above ``max_payload``, which this
    decoder refuses to buffer — raise instead; the connection must close.
    """

    def __init__(self, *, max_payload: int = MAX_PAYLOAD):
        self.max_payload = int(max_payload)
        self._buf = bytearray()

    def feed(self, data: bytes) -> List[Union[dict, ProtocolError]]:
        self._buf.extend(data)
        out: List[Union[dict, ProtocolError]] = []
        while True:
            if len(self._buf) < HEADER_SIZE:
                return out
            (length,) = HEADER.unpack_from(self._buf)
            if length > self.max_payload:
                raise ProtocolError(
                    "too_large",
                    f"declared frame length {length} exceeds cap "
                    f"{self.max_payload}",
                    recoverable=False,
                )
            if len(self._buf) < HEADER_SIZE + length:
                return out
            payload = bytes(self._buf[HEADER_SIZE : HEADER_SIZE + length])
            del self._buf[: HEADER_SIZE + length]
            try:
                out.append(decode_payload(payload))
            except ProtocolError as e:
                out.append(e)

    @property
    def pending_bytes(self) -> int:
        """Bytes buffered toward the next (incomplete) frame."""
        return len(self._buf)


def iter_frames(data: bytes, *, max_payload: int = MAX_PAYLOAD) -> Iterator[dict]:
    """Decode a complete byte string of concatenated frames (tests)."""
    dec = FrameDecoder(max_payload=max_payload)
    for item in dec.feed(data):
        if isinstance(item, ProtocolError):
            raise item
        yield item
    if dec.pending_bytes:
        raise ProtocolError(
            "bad_frame", f"{dec.pending_bytes} trailing bytes after the "
            "last complete frame"
        )


def _as_number_list(obj: dict, op: str, field: str) -> list:
    # vertex endpoints must be integers; only weights ('w') take floats
    kinds = (int, float) if field == "w" else (int,)
    val = obj.get(field)
    if not isinstance(val, list) or not all(
        isinstance(x, kinds) and not isinstance(x, bool) for x in val
    ):
        want = "numbers" if field == "w" else "integers"
        raise ProtocolError(
            "bad_request", f"op {op!r} needs {field!r} as a list of {want}"
        )
    return val


def validate_request(obj: dict) -> Tuple[str, dict]:
    """Validate one decoded request object → ``(op, fields)``.

    ``fields`` holds the op's array arguments (plain lists) plus the
    optional ``deadline_ms`` float. Raises :class:`ProtocolError` with
    ``unknown_op`` / ``bad_request`` on anything else.
    """
    op = obj.get("op")
    if not isinstance(op, str):
        raise ProtocolError("bad_request", "request needs a string 'op'")
    if op not in OPS:
        raise ProtocolError(
            "unknown_op", f"unknown op {op!r} (known: {', '.join(OPS)})"
        )
    req_id = obj.get("id")
    if req_id is not None and not isinstance(req_id, (int, str)):
        raise ProtocolError("bad_request", "'id' must be an int or string")
    fields: dict = {}
    lengths = set()
    for field in _OP_FIELDS[op]:
        fields[field] = _as_number_list(obj, op, field)
        lengths.add(len(fields[field]))
    if len(lengths) > 1:
        raise ProtocolError(
            "bad_request", f"op {op!r} array fields must have equal lengths"
        )
    deadline = obj.get("deadline_ms")
    if deadline is not None:
        if not isinstance(deadline, (int, float)) or isinstance(deadline, bool) \
                or deadline <= 0:
            raise ProtocolError(
                "bad_request", "'deadline_ms' must be a positive number"
            )
        fields["deadline_ms"] = float(deadline)
    return op, fields


def response(
    req_id, op: str, result: dict, *,
    snapshot_version: int = -1, stale: bool = False, n_unhealed: int = 0,
) -> dict:
    """A successful ``serve/v1`` response object."""
    return {
        "schema": SCHEMA,
        "id": req_id,
        "op": op,
        "ok": True,
        "result": result,
        "snapshot_version": int(snapshot_version),
        "stale": bool(stale),
        "n_unhealed": int(n_unhealed),
    }


def error_response(
    req_id, op, code: str, message: str, *,
    snapshot_version: int = -1, stale: bool = False, n_unhealed: int = 0,
) -> dict:
    """An in-band ``serve/v1`` failure response object."""
    return {
        "schema": SCHEMA,
        "id": req_id,
        "op": op,
        "ok": False,
        "error": {"code": code, "message": message},
        "snapshot_version": int(snapshot_version),
        "stale": bool(stale),
        "n_unhealed": int(n_unhealed),
    }
