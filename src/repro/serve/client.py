"""Pipelined blocking client for the ``serve/v1`` protocol
(DESIGN.md §13.3).

One socket, many in-flight requests: ``call`` assigns a request id,
frames the request, and parks a ``Future``; a single reader thread
decodes response frames and resolves futures by id. Because the server
answers query ops out of fused micro-batches, a client that pipelines —
sending the next request before the previous answer lands — is what
actually exercises the batching path; ``repro.launch.loadgen --target``
drives exactly this client from many threads (the client is
thread-safe: a send lock orders request frames, the reader thread owns
the receive side).

    with ServeClient("tcp://127.0.0.1:9012") as c:
        c.insert([0, 1], [1, 2], [0.5, 0.25])
        resp = c.connected([0], [2])
        resp["result"]["connected"], resp["snapshot_version"]

Every returned dict is the full wire response (``ok``, ``result`` or
``error``, ``snapshot_version``, ``stale``, ``n_unhealed``). In-band
errors do **not** raise by default — serving-tier callers usually want
to count ``overloaded`` / ``deadline`` rather than crash; pass
``check=True`` to get :class:`ServeError` instead.
"""
from __future__ import annotations

import itertools
import socket
import threading
from concurrent.futures import Future
from typing import Dict, Optional, Sequence

from repro.serve import protocol as P


def parse_target(target: str) -> tuple:
    """``"tcp://host:port"`` → ``(host, port)``; bare ``host:port`` works
    too."""
    if target.startswith("tcp://"):
        target = target[len("tcp://"):]
    host, sep, port = target.rpartition(":")
    if not sep or not port.isdigit():
        raise ValueError(
            f"target must look like tcp://host:port, got {target!r}"
        )
    return host or "127.0.0.1", int(port)


class ServeError(RuntimeError):
    """An in-band error response, surfaced when ``check=True``."""

    def __init__(self, response: dict):
        err = response.get("error") or {}
        super().__init__(f"{err.get('code')}: {err.get('message')}")
        self.code = err.get("code")
        self.response = response


class ServeClient:
    """Thread-safe pipelined connection to one :class:`MSFServer`."""

    def __init__(self, target: str, *, timeout: float = 30.0):
        self.host, self.port = parse_target(target)
        self.timeout = timeout
        self._sock = socket.create_connection((self.host, self.port),
                                              timeout=timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._send_lock = threading.Lock()
        self._ids = itertools.count(1)
        self._pending: Dict[int, Future] = {}
        self._pending_lock = threading.Lock()
        self._closed = False
        self._reader = threading.Thread(
            target=self._read_loop, daemon=True, name="serve-client-reader"
        )
        self._reader.start()

    # -- plumbing ----------------------------------------------------------

    def _read_loop(self) -> None:
        decoder = P.FrameDecoder()
        try:
            while True:
                data = self._sock.recv(1 << 16)
                if not data:
                    break
                for item in decoder.feed(data):
                    if isinstance(item, P.ProtocolError):
                        continue  # server never sends malformed frames
                    self._resolve(item)
        except (OSError, P.ProtocolError):
            pass
        finally:
            self._fail_pending(ConnectionError("server connection closed"))

    def _resolve(self, resp: dict) -> None:
        req_id = resp.get("id")
        with self._pending_lock:
            fut = self._pending.pop(req_id, None)
        if fut is not None:
            fut.set_result(resp)
        # id-less responses (framing errors for unparseable requests) are
        # dropped here; submit() futures for them time out at the caller.

    def _fail_pending(self, exc: Exception) -> None:
        with self._pending_lock:
            pending, self._pending = dict(self._pending), {}
        for fut in pending.values():
            if not fut.done():
                fut.set_exception(exc)

    # -- request API -------------------------------------------------------

    def submit(self, op: str, **fields) -> Future:
        """Pipeline one request; the Future resolves to the response dict."""
        if self._closed:
            raise ConnectionError("client is closed")
        req_id = next(self._ids)
        req = {"schema": P.SCHEMA, "id": req_id, "op": op, **fields}
        frame = P.encode_frame(req)
        fut: Future = Future()
        with self._pending_lock:
            self._pending[req_id] = fut
        try:
            with self._send_lock:
                self._sock.sendall(frame)
        except OSError:
            with self._pending_lock:
                self._pending.pop(req_id, None)
            raise
        return fut

    def call(self, op: str, *, check: bool = False,
             timeout: Optional[float] = None, **fields) -> dict:
        """Send one request and block for its response dict."""
        resp = self.submit(op, **fields).result(
            timeout=self.timeout if timeout is None else timeout
        )
        if check and not resp.get("ok"):
            raise ServeError(resp)
        return resp

    # -- convenience ops ---------------------------------------------------
    # numpy arrays / scalars are welcome: endpoints coerce to python ints
    # (json won't serialize np.int32) and weights to floats.

    @staticmethod
    def _ints(xs: Sequence[int]) -> list:
        return [int(x) for x in xs]

    def connected(self, u: Sequence[int], v: Sequence[int], **kw) -> dict:
        return self.call("connected", u=self._ints(u), v=self._ints(v), **kw)

    def component_id(self, u: Sequence[int], **kw) -> dict:
        return self.call("component_id", u=self._ints(u), **kw)

    def component_size(self, u: Sequence[int], **kw) -> dict:
        return self.call("component_size", u=self._ints(u), **kw)

    def insert(self, u: Sequence[int], v: Sequence[int],
               w: Sequence[float], **kw) -> dict:
        return self.call("insert", u=self._ints(u), v=self._ints(v),
                         w=[float(x) for x in w], **kw)

    def delete(self, u: Sequence[int], v: Sequence[int], **kw) -> dict:
        return self.call("delete", u=self._ints(u), v=self._ints(v), **kw)

    def status(self, **kw) -> dict:
        return self.call("status", **kw)

    def metrics(self, **kw) -> dict:
        return self.call("metrics", **kw)

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        self._reader.join(timeout=5.0)
        self._fail_pending(ConnectionError("client closed"))

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
