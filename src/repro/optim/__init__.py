from repro.optim.adamw import adamw_init, adamw_update, cosine_lr
from repro.optim.compress import compress_with_error_feedback, init_error_state
