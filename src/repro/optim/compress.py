"""int8 gradient compression with error feedback (distributed-optimization
trick; DESIGN.md §5).

At real scale the quantized tensors are what crosses the wire in the
gradient all-reduce (8× fewer bytes than f32, 2× fewer than bf16); on this
CPU container we run the full quantize → dequantize round trip so the
*numerics* (including the error-feedback correction that makes it converge)
are exactly what a TPU deployment would see. Per-tensor symmetric scales.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_with_error_feedback(grads, err_state):
    """Returns (dequantized grads as seen post-all-reduce, new error state)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    out = jax.tree.map(one, grads, err_state)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err
