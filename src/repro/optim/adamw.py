"""AdamW with global-norm clipping and fp32 master statistics.

Optimizer states inherit the parameter sharding specs (ZeRO-1 by
construction under FSDP; with ``fsdp=False`` states follow the TP layout).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(
        mu=zeros,
        nu=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        step=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        u = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (u + weight_decay * p32)
        return p_new.astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    params_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    mu_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    nu_new = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return params_new, AdamWState(mu=mu_new, nu=nu_new, step=step), gnorm


def cosine_lr(step, *, peak: float, warmup: int, total: int, floor_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)
