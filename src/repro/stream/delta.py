"""Batch ingestion for the streaming MSF engine (DESIGN.md §6.2).

Responsibilities:

- **Canonicalize** an incoming undirected batch: drop self-loops, collapse
  in-batch duplicates keeping the minimum weight (host side, exact — same
  policy as ``graphs.structures.from_edges``).
- **Dedupe against the live edge set** (the current forest): live edges are
  kept as a *sorted* array of packed ``(min, max)`` endpoint keys; batch
  keys are binary-searched against it. When ``n ≤ 2^16`` the key packs
  into one uint32 (``lo << 16 | hi``) and the lookup runs on-device as a
  single jitted kernel over the fixed-capacity buffers (one executable per
  engine configuration); larger ``n`` falls back to the host int64 path of
  ``graphs.structures.edge_keys``.
- **Classify** each batch edge as NEW (absent from the live set), DECREASE
  (present, strictly cheaper than the live weight) or DROP (present, not
  cheaper).
- **Stable global edge ids**: a NEW edge is assigned the next gid and keeps
  it for as long as it lives in the forest, so MSF edge ids remain
  meaningful across versions; a DECREASE keeps the live edge's gid.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.structures import canonical_edges, dedupe_canonical, edge_keys

#: largest vertex count for which the packed-uint32 on-device lookup applies
PACK_LIMIT = 1 << 16
#: sorted-buffer padding sentinel; above every real key (lo < hi ≤ 2^16 - 1
#: ⇒ key ≤ 0xFFFEFFFF < 0xFFFFFFFF)
KEY_PAD = np.uint32(0xFFFFFFFF)


def pack_key_u32(lo, hi):
    """uint32 key ``lo << 16 | hi`` for canonical pairs, n ≤ 2^16."""
    return (lo.astype(jnp.uint32) << 16) | hi.astype(jnp.uint32)


class PreparedBatch(NamedTuple):
    """A canonicalized, in-batch-deduped undirected edge batch (host arrays,
    sorted by (lo, hi) key)."""

    lo: np.ndarray  # int32 [count]
    hi: np.ndarray  # int32 [count]
    w: np.ndarray  # float32 [count]
    count: int
    dropped: int  # self-loops + in-batch duplicates removed


def prepare_batch(u, v, w, n: int) -> PreparedBatch:
    """Canonicalize one incoming batch. Exact host-side pass."""
    u = np.asarray(u, np.int64)
    v = np.asarray(v, np.int64)
    w = np.asarray(w, np.float64)
    if not (u.shape == v.shape == w.shape):
        raise ValueError("u, v, w must have identical shapes")
    if u.size and (u.min() < 0 or v.min() < 0 or max(u.max(), v.max()) >= n):
        raise ValueError(f"edge endpoints out of range [0, {n})")
    raw = len(u)
    lo, hi, keep = canonical_edges(u, v)
    lo, hi, w = lo[keep], hi[keep], w[keep]
    lo, hi, w = dedupe_canonical(lo, hi, w, n)
    return PreparedBatch(
        lo=lo.astype(np.int32),
        hi=hi.astype(np.int32),
        w=w.astype(np.float32),
        count=len(lo),
        dropped=raw - len(lo),
    )


class BatchPlan(NamedTuple):
    """Classification of a prepared batch against the live edge set."""

    is_new: np.ndarray  # bool [count]
    is_decrease: np.ndarray  # bool [count]: present and strictly cheaper
    live_pos: np.ndarray  # int32 [count]: index into the *sorted* live order
    n_new: int
    n_decrease: int
    n_drop: int


@jax.jit
def _match_device(batch_lo, batch_hi, batch_valid, live_keys_sorted):
    """On-device membership probe: batch keys vs the sorted live key buffer.

    ``live_keys_sorted`` is uint32 [forest_capacity], KEY_PAD beyond the
    live count, so one ``searchsorted`` per batch resolves membership.
    """
    keys = pack_key_u32(batch_lo, batch_hi)
    j = jnp.searchsorted(live_keys_sorted, keys)
    j = jnp.clip(j, 0, live_keys_sorted.shape[0] - 1)
    found = batch_valid & (live_keys_sorted[j] == keys)
    return found, j.astype(jnp.int32)


def classify_batch(
    batch: PreparedBatch,
    live_keys_sorted: np.ndarray,
    live_w_sorted: np.ndarray,
    n: int,
    capacity: int | None = None,
) -> BatchPlan:
    """Split a prepared batch into NEW / DECREASE / DROP vs the live set.

    ``live_keys_sorted``: sorted live keys — uint32-packed (device path,
    n ≤ PACK_LIMIT) or int64 ``edge_keys`` (host path), padded with the
    respective sentinel. ``live_w_sorted``: float32 weights in the same
    order. ``capacity``: pad the batch to this length before the device
    probe so every batch size reuses one compiled lookup kernel.
    """
    if batch.count == 0:
        z = np.zeros(0, bool)
        return BatchPlan(z, z, np.zeros(0, np.int32), 0, 0, 0)
    if n <= PACK_LIMIT:
        cap = capacity if capacity is not None else batch.count
        lo_p = np.zeros(cap, np.int32)
        hi_p = np.zeros(cap, np.int32)
        valid_p = np.zeros(cap, bool)
        lo_p[: batch.count] = batch.lo
        hi_p[: batch.count] = batch.hi
        valid_p[: batch.count] = True
        found, pos = _match_device(
            jnp.asarray(lo_p),
            jnp.asarray(hi_p),
            jnp.asarray(valid_p),
            jnp.asarray(live_keys_sorted),
        )
        found = np.asarray(found)[: batch.count]
        pos = np.asarray(pos)[: batch.count]
    else:
        keys = edge_keys(batch.lo, batch.hi, n)
        pos = np.searchsorted(live_keys_sorted, keys).astype(np.int32)
        pos = np.clip(pos, 0, max(len(live_keys_sorted) - 1, 0))
        found = (
            live_keys_sorted[pos] == keys
            if len(live_keys_sorted)
            else np.zeros(batch.count, bool)
        )
    cheaper = np.zeros(batch.count, bool)
    if len(live_w_sorted):
        # pos is only meaningful where found; clip so misses stay in bounds.
        safe = np.clip(pos, 0, len(live_w_sorted) - 1)
        cheaper = found & (batch.w < live_w_sorted[safe])
    is_new = ~found
    return BatchPlan(
        is_new=is_new,
        is_decrease=cheaper,
        live_pos=pos,
        n_new=int(is_new.sum()),
        n_decrease=int(cheaper.sum()),
        n_drop=int((found & ~cheaper).sum()),
    )


def build_live_index(lo, hi, w, n: int, capacity: int):
    """Sorted (keys, weights, rows) index over the live forest edges.

    Returns (keys_sorted padded to ``capacity``, w_sorted, rows_sorted)
    where ``rows_sorted`` maps a sorted position back to the store row.
    The key dtype matches what :func:`classify_batch` expects for this n.
    """
    lo = np.asarray(lo, np.int64)
    hi = np.asarray(hi, np.int64)
    keys = edge_keys(lo, hi, n)
    order = np.argsort(keys, kind="stable")
    keys_sorted = keys[order]
    if n <= PACK_LIMIT:
        packed = (lo[order].astype(np.uint32) << 16) | hi[order].astype(np.uint32)
        buf = np.full(capacity, KEY_PAD, np.uint32)
        buf[: len(packed)] = packed
    else:
        buf = np.full(capacity, np.iinfo(np.int64).max, np.int64)
        buf[: len(keys_sorted)] = keys_sorted
    return buf, np.asarray(w, np.float32)[order], order.astype(np.int32)
