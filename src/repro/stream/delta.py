"""Batch ingestion for the streaming MSF engine (DESIGN.md §6.2).

Responsibilities:

- **Canonicalize** an incoming undirected batch: drop self-loops, collapse
  in-batch duplicates keeping the minimum weight (host side, exact — same
  policy as ``graphs.structures.from_edges``).
- **Dedupe against the live edge set** (the current forest): live edges are
  kept as a *sorted* array of packed ``(min, max)`` endpoint keys; batch
  keys are binary-searched against it. When ``n ≤ 2^16`` the key packs
  into one uint32 (``lo << 16 | hi``) and the lookup runs on-device as a
  single jitted kernel over the fixed-capacity buffers (one executable per
  engine configuration); larger ``n`` falls back to the host int64 path of
  ``graphs.structures.edge_keys``.
- **Classify** each batch edge as NEW (absent from the live set), DECREASE
  (present, strictly cheaper than the live weight) or DROP (present, not
  cheaper).
- **Stable global edge ids**: a NEW edge is assigned the next gid and keeps
  it for as long as it lives in the forest, so MSF edge ids remain
  meaningful across versions; a DECREASE keeps the live edge's gid.
- **Replacement-edge reservoir** (:class:`Reservoir`, DESIGN.md §6.4): the
  bounded per-component store of non-tree edges that lost an MSF race.
  Entries keep their stable gid, are capped cheapest-first per component
  (then globally), and carry their own sorted key index so the engine can
  probe membership on delete/re-insert with the same searchsorted pattern
  as the live forest index.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.structures import canonical_edges, dedupe_canonical, edge_keys

#: largest vertex count for which the packed-uint32 on-device lookup applies
PACK_LIMIT = 1 << 16
#: sorted-buffer padding sentinel; above every real key (lo < hi ≤ 2^16 - 1
#: ⇒ key ≤ 0xFFFEFFFF < 0xFFFFFFFF)
KEY_PAD = np.uint32(0xFFFFFFFF)


def pack_key_u32(lo, hi):
    """uint32 key ``lo << 16 | hi`` for canonical pairs, n ≤ 2^16."""
    return (lo.astype(jnp.uint32) << 16) | hi.astype(jnp.uint32)


class PreparedBatch(NamedTuple):
    """A canonicalized, in-batch-deduped undirected edge batch (host arrays,
    sorted by (lo, hi) key)."""

    lo: np.ndarray  # int32 [count]
    hi: np.ndarray  # int32 [count]
    w: np.ndarray  # float32 [count]
    count: int
    dropped: int  # self-loops + in-batch duplicates removed


def prepare_batch(u, v, w, n: int) -> PreparedBatch:
    """Canonicalize one incoming batch. Exact host-side pass.

    Scalars / 0-d arrays are promoted to one-element batches
    (``np.atleast_1d``), so ``prepare_batch(3, 5, 1.0, n)`` is the
    single-edge batch rather than a ``TypeError`` on ``len``.
    """
    u = np.atleast_1d(np.asarray(u, np.int64))
    v = np.atleast_1d(np.asarray(v, np.int64))
    w = np.atleast_1d(np.asarray(w, np.float64))
    if not (u.shape == v.shape == w.shape):
        raise ValueError("u, v, w must have identical shapes")
    if u.size and (u.min() < 0 or v.min() < 0 or max(u.max(), v.max()) >= n):
        raise ValueError(f"edge endpoints out of range [0, {n})")
    raw = len(u)
    lo, hi, keep = canonical_edges(u, v)
    lo, hi, w = lo[keep], hi[keep], w[keep]
    lo, hi, w = dedupe_canonical(lo, hi, w, n)
    return PreparedBatch(
        lo=lo.astype(np.int32),
        hi=hi.astype(np.int32),
        w=w.astype(np.float32),
        count=len(lo),
        dropped=raw - len(lo),
    )


class BatchPlan(NamedTuple):
    """Classification of a prepared batch against the live edge set."""

    is_new: np.ndarray  # bool [count]
    is_decrease: np.ndarray  # bool [count]: present and strictly cheaper
    live_pos: np.ndarray  # int32 [count]: index into the *sorted* live order
    n_new: int
    n_decrease: int
    n_drop: int


@jax.jit
def _match_device(batch_lo, batch_hi, batch_valid, live_keys_sorted):
    """On-device membership probe: batch keys vs the sorted live key buffer.

    ``live_keys_sorted`` is uint32 [forest_capacity], KEY_PAD beyond the
    live count, so one ``searchsorted`` per batch resolves membership.
    """
    keys = pack_key_u32(batch_lo, batch_hi)
    j = jnp.searchsorted(live_keys_sorted, keys)
    j = jnp.clip(j, 0, live_keys_sorted.shape[0] - 1)
    found = batch_valid & (live_keys_sorted[j] == keys)
    return found, j.astype(jnp.int32)


def classify_batch(
    batch: PreparedBatch,
    live_keys_sorted: np.ndarray,
    live_w_sorted: np.ndarray,
    n: int,
    capacity: int | None = None,
) -> BatchPlan:
    """Split a prepared batch into NEW / DECREASE / DROP vs the live set.

    ``live_keys_sorted``: sorted live keys — uint32-packed (device path,
    n ≤ PACK_LIMIT) or int64 ``edge_keys`` (host path), padded with the
    respective sentinel. ``live_w_sorted``: float32 weights in the same
    order. ``capacity``: pad the batch to this length before the device
    probe so every batch size reuses one compiled lookup kernel.
    """
    if batch.count == 0:
        z = np.zeros(0, bool)
        return BatchPlan(z, z, np.zeros(0, np.int32), 0, 0, 0)
    if n <= PACK_LIMIT:
        cap = capacity if capacity is not None else batch.count
        lo_p = np.zeros(cap, np.int32)
        hi_p = np.zeros(cap, np.int32)
        valid_p = np.zeros(cap, bool)
        lo_p[: batch.count] = batch.lo
        hi_p[: batch.count] = batch.hi
        valid_p[: batch.count] = True
        found, pos = _match_device(
            jnp.asarray(lo_p),
            jnp.asarray(hi_p),
            jnp.asarray(valid_p),
            jnp.asarray(live_keys_sorted),
        )
        found = np.asarray(found)[: batch.count]
        pos = np.asarray(pos)[: batch.count]
    else:
        keys = edge_keys(batch.lo, batch.hi, n)
        pos = np.searchsorted(live_keys_sorted, keys).astype(np.int32)
        pos = np.clip(pos, 0, max(len(live_keys_sorted) - 1, 0))
        found = (
            live_keys_sorted[pos] == keys
            if len(live_keys_sorted)
            else np.zeros(batch.count, bool)
        )
    cheaper = np.zeros(batch.count, bool)
    if len(live_w_sorted):
        # pos is only meaningful where found; clip so misses stay in bounds.
        safe = np.clip(pos, 0, len(live_w_sorted) - 1)
        cheaper = found & (batch.w < live_w_sorted[safe])
    is_new = ~found
    return BatchPlan(
        is_new=is_new,
        is_decrease=cheaper,
        live_pos=pos,
        n_new=int(is_new.sum()),
        n_decrease=int(cheaper.sum()),
        n_drop=int((found & ~cheaper).sum()),
    )


def build_live_index(lo, hi, w, n: int, capacity: int):
    """Sorted (keys, weights, rows) index over the live forest edges.

    Returns (keys_sorted padded to ``capacity``, w_sorted, rows_sorted)
    where ``rows_sorted`` maps a sorted position back to the store row.
    The key dtype matches what :func:`classify_batch` expects for this n.
    """
    lo = np.asarray(lo, np.int64)
    hi = np.asarray(hi, np.int64)
    keys = edge_keys(lo, hi, n)
    order = np.argsort(keys, kind="stable")
    keys_sorted = keys[order]
    if n <= PACK_LIMIT:
        packed = (lo[order].astype(np.uint32) << 16) | hi[order].astype(np.uint32)
        buf = np.full(capacity, KEY_PAD, np.uint32)
        buf[: len(packed)] = packed
    else:
        buf = np.full(capacity, np.iinfo(np.int64).max, np.int64)
        buf[: len(keys_sorted)] = keys_sorted
    return buf, np.asarray(w, np.float32)[order], order.astype(np.int32)


class Reservoir:
    """Bounded per-component store of non-tree edges (DESIGN.md §6.4).

    Edges that lose an MSF race in the engine's union solve land here
    instead of being discarded, so a later forest-edge deletion can pull
    them back as replacement candidates. Entries carry their stable gid
    and the canonical component root of their endpoints (non-tree edges
    are always intra-component).

    Capacity policy: ``per_component`` entries per component, then
    ``capacity`` entries total, both retained **cheapest-first** under
    the strict ``(w, gid)`` order the MSF itself uses. Any entry evicted
    by either cap makes its component *lossy* — the engine tracks that
    and refuses to certify deletions inside lossy components
    (``DeleteStats.n_unhealed``).

    A sorted int64 ``edge_keys`` index over the stored pairs backs O(log
    count) membership probes (:meth:`lookup`) — the reservoir twin of
    :func:`build_live_index`.
    """

    def __init__(self, n: int, capacity: int, per_component: int):
        if capacity < 0:
            raise ValueError("reservoir capacity must be >= 0")
        if per_component < 1:
            raise ValueError("reservoir per-component cap must be >= 1")
        self.n = int(n)
        self.capacity = int(capacity)
        self.per_component = int(per_component)
        self._lo = np.zeros(capacity, np.int32)
        self._hi = np.zeros(capacity, np.int32)
        self._w = np.zeros(capacity, np.float32)
        self._gid = np.full(capacity, -1, np.int32)
        self._comp = np.zeros(capacity, np.int32)
        self._count = 0
        self._keys_sorted = np.zeros(0, np.int64)
        self._rows_sorted = np.zeros(0, np.int64)

    def __len__(self) -> int:
        return self._count

    def edges(self):
        """Copies of the stored rows: (lo, hi, w, gid, comp)."""
        c = self._count
        return (
            self._lo[:c].copy(),
            self._hi[:c].copy(),
            self._w[:c].copy(),
            self._gid[:c].copy(),
            self._comp[:c].copy(),
        )

    # ------------------------------------------------------------------

    def _reindex(self) -> None:
        c = self._count
        keys = edge_keys(self._lo[:c], self._hi[:c], self.n)
        order = np.argsort(keys, kind="stable")
        self._keys_sorted = keys[order]
        self._rows_sorted = order.astype(np.int64)

    def _set(self, lo, hi, w, gid, comp) -> None:
        c = len(lo)
        self._lo[:c] = lo
        self._hi[:c] = hi
        self._w[:c] = w
        self._gid[:c] = gid
        self._comp[:c] = comp
        self._count = c
        self._reindex()

    # ------------------------------------------------------------------

    def lookup(self, lo, hi) -> np.ndarray:
        """Row index of each canonical (lo, hi) query pair, −1 on miss."""
        lo = np.asarray(lo)
        hi = np.asarray(hi)
        out = np.full(len(lo), -1, np.int64)
        if self._count == 0 or len(lo) == 0:
            return out
        keys = edge_keys(lo, hi, self.n)
        j = np.searchsorted(self._keys_sorted, keys)
        j = np.clip(j, 0, len(self._keys_sorted) - 1)
        found = self._keys_sorted[j] == keys
        out[found] = self._rows_sorted[j[found]]
        return out

    def remove_rows(self, rows):
        """Remove ``rows`` and return their (lo, hi, w, gid) in row order."""
        rows = np.asarray(rows, np.int64)
        out = (
            self._lo[rows].copy(),
            self._hi[rows].copy(),
            self._w[rows].copy(),
            self._gid[rows].copy(),
        )
        if len(rows):
            keep = np.ones(self._count, bool)
            keep[rows] = False
            idx = np.flatnonzero(keep)
            self._set(
                self._lo[idx], self._hi[idx], self._w[idx],
                self._gid[idx], self._comp[idx],
            )
        return out

    def take_components(self, comps):
        """Remove and return every entry bucketed under one of ``comps``
        (canonical component roots) — the replacement-candidate pull of a
        forest-edge deletion."""
        comps = np.asarray(comps)
        if self._count == 0 or len(comps) == 0:
            z = np.zeros(0, np.int32)
            return z, z, np.zeros(0, np.float32), z
        rows = np.flatnonzero(np.isin(self._comp[: self._count], comps))
        return self.remove_rows(rows)

    def state_dict(self) -> dict:
        """Full-capacity column copies + live count — the durable state
        of :mod:`repro.stream.persist` (fixed shapes, so a checkpoint
        restores into any reservoir of the same capacity)."""
        return {
            "lo": self._lo.copy(),
            "hi": self._hi.copy(),
            "w": self._w.copy(),
            "gid": self._gid.copy(),
            "comp": self._comp.copy(),
            "count": np.int64(self._count),
        }

    def restore_state(self, state: dict) -> None:
        """Inverse of :meth:`state_dict`; rebuilds the sorted key index."""
        lo = np.asarray(state["lo"], np.int32)
        if lo.shape != self._lo.shape:
            raise ValueError(
                f"reservoir state capacity {lo.shape[0]} does not match "
                f"this reservoir's capacity {self.capacity}"
            )
        count = int(state["count"])
        if not 0 <= count <= self.capacity:
            raise ValueError(f"reservoir state count {count} out of range")
        self._lo = lo.copy()
        self._hi = np.asarray(state["hi"], np.int32).copy()
        self._w = np.asarray(state["w"], np.float32).copy()
        self._gid = np.asarray(state["gid"], np.int32).copy()
        self._comp = np.asarray(state["comp"], np.int32).copy()
        self._count = count
        self._reindex()

    def rebucket(self, canon: np.ndarray) -> None:
        """Re-label every entry's component from canonical labels
        (entries are intra-component: ``canon[lo]`` is the bucket)."""
        c = self._count
        if c:
            self._comp[:c] = np.asarray(canon, np.int32)[self._lo[:c]]

    def clear(self) -> None:
        self._count = 0
        self._reindex()

    def absorb(self, lo, hi, w, gid, comp):
        """Merge a batch of race losers into the store, enforcing both
        caps cheapest-first. Returns ``(evicted_comps, n_evicted)`` —
        the unique component roots that lost at least one entry (the
        engine marks them lossy) and the total eviction count."""
        lo = np.asarray(lo, np.int32)
        hi = np.asarray(hi, np.int32)
        w = np.asarray(w, np.float32)
        gid = np.asarray(gid, np.int32)
        comp = np.asarray(comp, np.int32)
        if len(lo) == 0:
            return np.zeros(0, np.int32), 0
        if self.capacity == 0:
            return np.unique(comp), len(lo)
        c = self._count
        lo = np.concatenate([self._lo[:c], lo])
        hi = np.concatenate([self._hi[:c], hi])
        w = np.concatenate([self._w[:c], w])
        gid = np.concatenate([self._gid[:c], gid])
        comp = np.concatenate([self._comp[:c], comp])
        # Defensive key dedupe (losers are disjoint from the store by
        # construction): keep the (w, gid)-min copy of a pair.
        keys = edge_keys(lo, hi, self.n)
        order = np.lexsort((gid, w, keys))
        keys = keys[order]
        first = np.ones(len(keys), bool)
        first[1:] = keys[1:] != keys[:-1]
        idx = order[first]
        lo, hi, w, gid, comp = lo[idx], hi[idx], w[idx], gid[idx], comp[idx]
        m = len(lo)
        # Per-component cap: rank entries cheapest-first inside each
        # component, drop ranks past the cap.
        order = np.lexsort((gid, w, comp))
        comp_sorted = comp[order]
        pos = np.arange(m, dtype=np.int64)
        starts = np.ones(m, bool)
        starts[1:] = comp_sorted[1:] != comp_sorted[:-1]
        group_start = np.maximum.accumulate(np.where(starts, pos, 0))
        within = (pos - group_start) < self.per_component
        keep = np.zeros(m, bool)
        keep[order[within]] = True
        # Global cap: among survivors keep the (w, gid)-cheapest overall.
        n_keep = int(keep.sum())
        if n_keep > self.capacity:
            surv = np.flatnonzero(keep)
            cheap = surv[np.lexsort((gid[surv], w[surv]))[: self.capacity]]
            keep = np.zeros(m, bool)
            keep[cheap] = True
        n_evicted = m - int(keep.sum())
        evicted_comps = np.unique(comp[~keep])
        idx = np.flatnonzero(keep)
        self._set(lo[idx], hi[idx], w[idx], gid[idx], comp[idx])
        return evicted_comps.astype(np.int32), n_evicted
