"""Batched connectivity query serving (DESIGN.md §6.5).

Queries are answered from a published :class:`~repro.stream.snapshot.Snapshot`
— never from the engine's in-flight state — via one *fused* jitted gather
kernel (parent labels, pair equality and component sizes come out of a
single compiled call). Incoming query batches are padded to the next power
of two, so the number of compiled executables is bounded by
``log2(max_batch)`` regardless of traffic shape.

Two entry styles:

- :class:`QueryService` — array-in/array-out batched calls (the serving
  hot path; used by ``launch/serve_graph.py`` and the benchmarks);
- :class:`MicroBatcher` — accumulates point queries and answers them all
  in one fused padded batch on ``flush()`` (the microbatching layer a
  request frontend would sit on).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.stream.snapshot import Snapshot, SnapshotStore


def next_pow2(k: int, floor: int = 16) -> int:
    """Smallest power of two ≥ max(k, floor)."""
    return max(floor, 1 << (max(int(k), 1) - 1).bit_length())


@jax.jit
def _answer_fused(parent, comp_size, u, v):
    """One kernel for every query type: gathers fused by XLA.

    Returns (connected[u,v], component_id[u], component_size[u]).
    """
    pu = parent[u]
    pv = parent[v]
    return pu == pv, pu, comp_size[u]


class BatchAnswer(NamedTuple):
    """One fused batch's answers plus the snapshot they were pinned to.

    The serving tier needs the *coordinates* of every answer — which
    published version it reflects, whether that version was stale and how
    many deletions were unhealed — so responses can carry them on the
    wire (``serve/v1``). ``snapshot`` is the exact immutable
    :class:`~repro.stream.snapshot.Snapshot` the whole batch was answered
    from (one ``acquire()`` per batch, never per query).
    """

    connected: np.ndarray  # bool [k]
    component: np.ndarray  # int32 [k]: canonical component label of u[i]
    size: np.ndarray  # int32 [k]: component size of u[i]
    snapshot: Snapshot


class QueryService:
    """Answer connectivity queries from the latest published snapshot."""

    def __init__(self, store: SnapshotStore, *, max_batch: int = 1 << 14,
                 pad_floor: int = 16):
        self.store = store
        self.max_batch = int(max_batch)
        self.pad_floor = int(pad_floor)

    # -- batched query API -------------------------------------------------

    def connected(self, u, v) -> np.ndarray:
        """bool [k]: are u[i] and v[i] in the same component?"""
        conn, _, _, _ = self._run(u, v)
        return conn

    def component_id(self, u) -> np.ndarray:
        """int32 [k]: canonical component label of each u[i]."""
        _, comp, _, _ = self._run(u, u)
        return comp

    def component_size(self, u) -> np.ndarray:
        """int32 [k]: size of the component containing each u[i]."""
        _, _, size, _ = self._run(u, u)
        return size

    def answer(self, u, v) -> BatchAnswer:
        """All three answer columns *and* the pinned snapshot, one fused
        call — the serving-tier entry (``repro.serve.server``)."""
        conn, comp, size, snap = self._run(u, v)
        return BatchAnswer(conn, comp, size, snap)

    def forest_weight(self) -> float:
        return self.store.acquire().weight

    def snapshot_version(self) -> int:
        return self.store.version

    # -- internals ---------------------------------------------------------

    def _run(self, u, v) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Snapshot]:
        from repro import obs  # leaf package; import here keeps service light

        with obs.span("stream.query"):
            snap = self.store.acquire()  # one consistent version per batch
            u = np.asarray(u, np.int32)
            v = np.asarray(v, np.int32)
            if u.shape != v.shape or u.ndim != 1:
                raise ValueError(
                    "query endpoints must be 1-d arrays of equal length"
                )
            k = len(u)
            if k == 0:
                z = np.zeros(0, np.int32)
                return np.zeros(0, bool), z, z, snap
            if k > self.max_batch:
                raise ValueError(
                    f"query batch {k} exceeds max_batch={self.max_batch}"
                )
            n = snap.parent.shape[0]
            if u.min() < 0 or v.min() < 0 or max(u.max(), v.max()) >= n:
                raise ValueError(f"query vertex out of range [0, {n})")
            pad = next_pow2(k, self.pad_floor)
            u_p = np.zeros(pad, np.int32)
            v_p = np.zeros(pad, np.int32)
            u_p[:k], v_p[:k] = u, v
            conn, comp, size = _answer_fused(
                snap.parent, snap.comp_size, u_p, v_p
            )
            # np.asarray blocks on the device result, so the span closes
            # only after the answer is host-resident — the user-visible
            # latency, which is what the p50/p95/p99 summary should show.
            return (
                np.asarray(conn)[:k],
                np.asarray(comp)[:k],
                np.asarray(size)[:k],
                snap,
            )


class MicroBatcher:
    """Accumulate point queries; answer them in one fused padded batch.

    ``ask_connected(u, v)`` returns an opaque ticket; ``flush()`` answers
    every queued query against a *single* snapshot version and returns the
    list of results in ticket order. Auto-flushes when the queue reaches
    ``max_queue``; asking again after a flush starts a new window. Results
    of the last ``retain_windows`` flushed windows (default 1 — exactly
    the just-flushed window, the historical behavior) stay redeemable via
    ``result``; tickets from windows past the retention horizon raise
    ``KeyError`` instead of ever serving a wrong answer.

    Thread-safe: ``ask_connected`` / ``flush`` / ``result`` may be called
    concurrently from any number of threads (one re-entrant lock guards
    the window state; the fused device call runs under it, so two racing
    flushes never double-answer a window). A multi-threaded frontend
    should raise ``retain_windows`` so a thread that asked right before
    another thread's flush can still redeem its ticket.

    When ``repro.obs`` metrics mode is on, the batcher reports its
    admission state (DESIGN.md §11): ``stream.batcher.queue_depth``
    (gauge — pending queries in the open window),
    ``stream.batcher.overflow`` (counter — windows force-flushed at
    ``max_queue``, the backpressure events that were invisible before),
    and ``stream.batcher.flush`` / ``stream.batcher.flushed_queries``
    (counters). The loadgen SLO report surfaces them.
    """

    def __init__(self, service: QueryService, max_queue: int = 4096, *,
                 retain_windows: int = 1):
        if retain_windows < 1:
            raise ValueError("retain_windows must be >= 1")
        self.service = service
        self.max_queue = int(max_queue)
        self.retain_windows = int(retain_windows)
        self._lock = threading.RLock()
        self._window = 0
        self._pairs: List[Tuple[int, int]] = []
        self._results: List[bool] | None = None
        #: window id -> results of already-flushed windows (bounded LRU)
        self._done: "OrderedDict[int, List[bool]]" = OrderedDict()

    def ask_connected(self, u: int, v: int) -> Tuple[int, int]:
        from repro import obs

        with self._lock:
            if self._results is not None:  # start a new window
                self._window += 1
                self._pairs, self._results = [], None
            self._pairs.append((int(u), int(v)))
            ticket = (self._window, len(self._pairs) - 1)
            if obs.metrics_active():
                obs.gauge("stream.batcher.queue_depth").set(len(self._pairs))
            if len(self._pairs) >= self.max_queue:
                if obs.metrics_active():
                    obs.counter("stream.batcher.overflow").inc()
                self.flush()
            return ticket

    def flush(self) -> List[bool]:
        from repro import obs

        with self._lock:
            if self._results is not None:
                return self._results
            if not self._pairs:
                self._results = []
            else:
                arr = np.asarray(self._pairs, np.int32)
                conn = self.service.connected(arr[:, 0], arr[:, 1])
                self._results = [bool(x) for x in conn]
            self._done[self._window] = self._results
            while len(self._done) > self.retain_windows:
                self._done.popitem(last=False)
            if obs.metrics_active() and self._results:
                obs.counter("stream.batcher.flush").inc()
                obs.counter("stream.batcher.flushed_queries").inc(
                    len(self._results)
                )
                obs.gauge("stream.batcher.queue_depth").set(0)
            return self._results

    def result(self, ticket: Tuple[int, int]) -> bool:
        """Result for a ticket; raises ``KeyError`` once its window has
        aged past the retention horizon."""
        window, idx = ticket
        with self._lock:
            if window == self._window:
                if self._results is None:
                    self.flush()
                return self._results[idx]
            done = self._done.get(window)
            if done is None:
                raise KeyError(
                    f"ticket from window {window} is stale (current window "
                    f"{self._window}, retaining {self.retain_windows} "
                    f"flushed windows)"
                )
            return done[idx]
