"""Durable stream-engine checkpoints over ``repro.checkpoint``
(DESIGN.md §13.4).

A serving node must survive restart without replaying its whole edge
stream. :meth:`~repro.stream.engine.StreamEngine.state_dict` exposes the
engine's complete durable state as a flat fixed-shape numpy pytree
(forest columns, replacement-edge reservoir, gid counter, canonical
labels, certification state); this module routes that tree through the
repo's atomic checkpoint store (``step_<n>/`` + ``DONE`` marker, async
writes, crash-safe renames) keyed by the engine's snapshot **version** —
so ``latest_step`` is also "the newest published state on disk", and a
restore resumes serving at exactly the version the saved node last
published (bit-identical forest weight, MSF gid set and labels; pinned
by the exact-resume test in ``tests/test_checkpoint.py``).

    from repro.stream import persist
    persist.save_stream(ckpt_dir, engine)            # writer side
    ...
    version = persist.restore_stream(ckpt_dir, eng2) # warm restart

The restored engine must be constructed with the same
``(n, batch_capacity, exact_deletes, reservoir_*)`` configuration — the
state tree carries a config fingerprint and ``restore_state`` rejects
mismatches loudly rather than resuming a corrupt forest.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    wait_for_saves,
)


def save_stream(ckpt_dir: str, engine, *, async_save: bool = False) -> int:
    """Checkpoint ``engine`` under ``ckpt_dir`` at its current snapshot
    version; returns the step (= version) written.

    ``async_save=True`` serializes on a background thread (join via
    :func:`repro.checkpoint.wait_for_saves`) — the engine state is copied
    synchronously first, so the writer may keep mutating immediately.
    """
    step = engine.version
    save_checkpoint(ckpt_dir, step, engine.state_dict(), async_save=async_save)
    return step


def latest_stream_step(ckpt_dir: str) -> Optional[int]:
    """Newest restorable checkpoint step (snapshot version), or None."""
    return latest_step(ckpt_dir)


def restore_stream(ckpt_dir: str, engine, step: Optional[int] = None) -> int:
    """Load the checkpoint at ``step`` (default: newest) into ``engine``.

    Returns the restored snapshot version. Raises ``FileNotFoundError``
    when the directory holds no completed checkpoint, and ``ValueError``
    when the stored config fingerprint does not match the engine.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(
                f"no completed stream checkpoint under {ckpt_dir!r}"
            )
    # The engine's own state tree is the restore template: same config ⇒
    # identical structure and shapes, so the load is shape-checked by
    # construction and config mismatches surface in restore_state.
    template = engine.state_dict()
    restored = restore_checkpoint(ckpt_dir, step, template)
    engine.restore_state(
        {k: np.asarray(v) for k, v in restored.items()}
    )
    return engine.version


__all__ = [
    "latest_stream_step",
    "restore_stream",
    "save_stream",
    "wait_for_saves",
]
