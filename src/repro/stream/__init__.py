# Streaming MSF subsystem (DESIGN.md §6): incremental forest maintenance
# via the sparsification identity + snapshot-isolated batched query serving.
from repro.stream.engine import StreamEngine, StreamingMSF, UpdateStats, DeleteStats
from repro.stream.snapshot import Snapshot, SnapshotStore, make_snapshot
from repro.stream.service import QueryService, MicroBatcher, next_pow2
from repro.stream import delta
