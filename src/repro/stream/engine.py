"""Streaming minimum spanning forest engine (DESIGN.md §6.1).

Maintains the MSF of an edge stream under **batch insertions** and **batch
deletions**, serving consistent snapshots to the query layer while updates
are in flight.

Insertions are *exact* via the sparsification identity

    MSF(G ∪ B) = MSF(MSF(G) ∪ B)

(Sanders & Schimek 2023, §2; Kopelowitz et al. 2018): the engine never
stores more than the current forest (≤ n − 1 undirected edges), so an
insert batch of size |B| runs the already-jitted ``repro.core.msf`` kernel
over a *fixed-capacity* union buffer of exactly

    forest_capacity + batch_capacity  =  (n − 1) + B_cap

undirected slots — O(n + |B|) instead of O(m) work, and one compiled
executable for every batch size (padding, not re-tracing). With
``adaptive_capacity`` the batch slots instead track observed batch sizes
by powers of two (bounded recompiles, reported via
``UpdateStats.recompiles``). The MSF inner loop runs the pack32
single-reduction path whenever weights stay in the paper's integral
[0, 255] regime, with the packed segment-min swappable for the Pallas
flat kernel (``segmin="pallas"``; ``interpret=True`` is selected
automatically off ``jax.default_backend()``).

Deletions are **tombstoned**: the edge is marked dead, excluded from the
live index, and the published snapshot is re-issued with ``stale=True``.
The structural effect (component splits) becomes visible at the next
*compaction* — triggered automatically when the tombstoned fraction
exceeds ``compact_trigger`` or by calling :meth:`compact` — or implicitly
at the next insert batch (dead rows never enter the union buffer, and the
store is rewritten from the MSF result). Because non-forest edges were
discarded by sparsification, a deleted forest edge is *not* replaced by a
previously-seen non-forest edge; this is the standard trade-off of
forest-only streaming (documented in DESIGN.md §6.4).
"""
from __future__ import annotations

import warnings
from typing import NamedTuple

import numpy as np

from repro import obs
from repro.core.msf import flat_msf
from repro.core.semiring import PACK_IDX_MASK
from repro.graphs.structures import Graph
from repro.solve.spec import weights_packable
from repro.stream import delta
from repro.stream.service import next_pow2
from repro.stream.snapshot import SnapshotStore, make_snapshot


def _spanned(name):
    """Wrap a method in an ``obs.span(name)`` — the per-op latency
    surface of DESIGN.md §10.4 (span durations land in the
    ``span.<name>`` histogram of the default registry when metrics are
    on; one extra frame + one branch when obs is off)."""
    import functools

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            with obs.span(name):
                return fn(*a, **kw)
        return wrapper
    return deco


class UpdateStats(NamedTuple):
    version: int
    weight: float
    n_components: int
    n_forest_edges: int
    n_new: int  # batch edges absent from the live set
    n_decrease: int  # batch edges that lowered a live weight
    n_drop: int  # batch duplicates that changed nothing
    iterations: int  # MSF hook/shortcut iterations for this update
    union_directed_edges: int  # traced edge-buffer size of the update
    batch_capacity: int = 0  # padded batch slots used for this update
    recompiles: int = 0  # cumulative distinct union-buffer shapes compiled


class DeleteStats(NamedTuple):
    version: int
    n_deleted: int
    n_missing: int  # requested deletions not present in the forest
    compacted: bool


class StreamEngine:
    """Incremental MSF over an undirected edge stream.

    This is the engine behind ``repro.solve``'s ``mode="stream"`` plans
    (``plan(n, SolveSpec(mode="stream")).update/query/...``); the
    :class:`StreamingMSF` name below is its deprecated direct-construction
    shim.

    Parameters
    ----------
    n: vertex count (static — defines every buffer shape).
    batch_capacity: max undirected edges per insert batch; without
        ``adaptive_capacity`` also the pad target, so every batch reuses
        one compiled MSF executable.
    adaptive_capacity: grow/shrink the padded batch slots by powers of two
        tracking observed batch sizes (floor ``min_capacity``, ceiling
        ``batch_capacity``). Small batches then pay for a small union
        buffer at the cost of a bounded number of recompiles
        (≤ log2(batch_capacity / min_capacity) shapes each way), surfaced
        as ``UpdateStats.recompiles``.
    compact_trigger: tombstoned-fraction threshold that forces compaction.
    pack: use the pack32 single-reduction MSF inner loop. ``None`` (auto)
        enables it while every inserted weight has been integral in
        [0, 255] (the paper's regime — tracked incrementally, so one
        fractional batch permanently falls back to the 3-pass float
        reduction); ``True`` asserts it and rejects unpackable batches.
    segmin: packed segment-min backend for the inner loop — "jnp",
        "pallas" (the flat Pallas kernel, ``interpret=True`` selected
        automatically off ``jax.default_backend()``), "sorted" (the
        contiguous-range kernel; only meaningful for the coarsen
        recompute's dedupe — the flat hook loop falls back to "auto") or
        "auto" (Pallas only on TPU — interpreted Pallas on CPU is orders
        of magnitude slower than XLA's segment_min).
    coarsen: ``None`` (always the flat union recompute), ``True`` or a
        ``repro.coarsen.CoarsenConfig`` — rebuild via **fused**
        contract-and-filter levels (one jit per level, sorted-segment
        dedupe) whenever the union holds at least ``coarsen_threshold``
        live edges. The level dedupe is where the sorted Pallas kernel
        applies: its segment ids are sorted after the device sort.
    coarsen_threshold: live undirected union edges (forest + batch) at
        which the coarsen recompute kicks in; below it the flat solve is
        cheaper than the level machinery.
    variant / shortcut / capacity: forwarded to ``repro.core.msf``.
    """

    def __init__(
        self,
        n: int,
        batch_capacity: int = 1024,
        *,
        adaptive_capacity: bool = False,
        min_capacity: int = 16,
        compact_trigger: float = 0.25,
        pack: bool | None = None,
        segmin: str = "auto",
        coarsen=None,
        coarsen_threshold: int = 1 << 15,
        variant: str = "complete",
        shortcut: str = "complete",
        capacity: int = 1 << 16,
    ):
        if n < 2:
            raise ValueError("the streaming MSF engine needs n >= 2")
        if batch_capacity < 1:
            raise ValueError("batch_capacity must be >= 1")
        self.n = int(n)
        self.batch_capacity = int(batch_capacity)
        self.forest_capacity = self.n - 1
        self.compact_trigger = float(compact_trigger)
        self._msf_opts = dict(variant=variant, shortcut=shortcut, capacity=capacity)
        self._pack = pack
        self._segmin = segmin
        self._coarsen_cfg = None
        if coarsen is not None and coarsen is not False:
            from repro.coarsen.engine import CoarsenConfig  # lazy: layer cycle
            import dataclasses

            cfg = CoarsenConfig() if coarsen is True else coarsen
            # The union rebuild always takes the fused device-resident
            # levels; the sorted-dedupe backend follows ``segmin``.
            self._coarsen_cfg = dataclasses.replace(
                cfg, fused=True, segmin=segmin
            )
        self.coarsen_threshold = int(coarsen_threshold)
        #: CoarsenStats of the latest update when the coarsen rebuild ran,
        #: None when the flat recompute was taken (or never enabled).
        self.last_coarsen_stats = None
        self._packable = True  # conjunction over every inserted batch
        self.adaptive_capacity = bool(adaptive_capacity)
        self._min_capacity = min(next_pow2(min_capacity, 1), self.batch_capacity)
        self._cap_cur = (
            self._min_capacity if adaptive_capacity else self.batch_capacity
        )
        self._recent: list[int] = []  # last few observed batch sizes
        self._union_shapes: set = set()  # distinct compiled union shapes
        if pack is True and self.forest_capacity + self.batch_capacity >= PACK_IDX_MASK:
            raise ValueError(
                f"pack=True needs union eids < 2^24 - 1; (n - 1) + "
                f"batch_capacity = {self.forest_capacity + self.batch_capacity} "
                f"overflows the pack32 index field"
            )

        fc = self.forest_capacity
        # Host-side forest store (compact: rows [0, _count) are live-or-dead).
        self._lo = np.zeros(fc, np.int32)
        self._hi = np.zeros(fc, np.int32)
        self._w = np.zeros(fc, np.float32)
        self._gid = np.full(fc, -1, np.int32)
        self._dead = np.zeros(fc, bool)
        self._count = 0
        self._n_dead = 0
        self._weight = 0.0
        self._next_gid = 0
        self._version = 0

        self.snapshots = SnapshotStore()
        self.last_union_shape: tuple | None = None
        self._publish(stale=False, parent=np.arange(self.n, dtype=np.int32))
        self._refresh_live_index()

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    @property
    def union_edge_capacity(self) -> int:
        """Undirected slots per update — the (n − 1) + B_cur bound (B_cur
        follows observed batch sizes under ``adaptive_capacity``)."""
        return self.forest_capacity + self._cap_cur

    @property
    def recompiles(self) -> int:
        """Distinct (union-buffer shape, pack mode) executables compiled
        so far — 1 at fixed capacity and stable pack mode; the auto-pack
        flip after a fractional batch adds one, and adaptive capacity
        adds one per newly-visited pow2 size. Oscillating between
        already-seen keys hits jit's executable cache and does not
        count."""
        return len(self._union_shapes)

    @property
    def version(self) -> int:
        return self._version

    @property
    def weight(self) -> float:
        return self._weight

    @property
    def n_forest_edges(self) -> int:
        return self._count - self._n_dead

    def forest_edges(self):
        """Copies of the live forest rows: (lo, hi, w, gid)."""
        live = ~self._dead[: self._count]
        idx = np.flatnonzero(live)
        return (
            self._lo[idx].copy(),
            self._hi[idx].copy(),
            self._w[idx].copy(),
            self._gid[idx].copy(),
        )

    def forest_gids(self) -> np.ndarray:
        """Stable gids of the live forest edges only — the cheap column
        for per-update reporting (``repro.solve``'s stream reports build
        one per batch; copying all four forest columns there would tax
        the insert hot path)."""
        return self._gid[np.flatnonzero(~self._dead[: self._count])]

    @_spanned("stream.update")
    def insert_batch(self, u, v, w) -> UpdateStats:
        """Apply one batch of undirected weighted edge insertions.

        Exact MSF maintenance: duplicates of live edges are dropped (or
        treated as weight decreases, keeping the stable gid), new edges
        get fresh gids, and the forest is recomputed over forest ∪ batch.
        """
        pb = delta.prepare_batch(u, v, w, self.n)
        if pb.count > self.batch_capacity:
            raise ValueError(
                f"batch of {pb.count} unique edges exceeds batch_capacity="
                f"{self.batch_capacity}; split the batch or raise the capacity"
            )
        self._note_batch(pb)
        plan = delta.classify_batch(
            pb, self._live_keys, self._live_w, self.n, self.batch_capacity
        )
        # Weight decreases: update the live row in place; gid is unchanged.
        if plan.n_decrease:
            rows = self._live_rows[plan.live_pos[plan.is_decrease]]
            self._w[rows] = np.minimum(self._w[rows], pb.w[plan.is_decrease])
        # New edges: assign stable gids.
        new_lo = pb.lo[plan.is_new]
        new_hi = pb.hi[plan.is_new]
        new_w = pb.w[plan.is_new]
        new_gid = np.arange(
            self._next_gid, self._next_gid + plan.n_new, dtype=np.int32
        )
        self._next_gid += plan.n_new
        r = self._run_union(new_lo, new_hi, new_w, new_gid)
        return UpdateStats(
            version=self._version,
            weight=self._weight,
            n_components=self.snapshots.acquire().n_components,
            n_forest_edges=self._count,
            n_new=plan.n_new,
            n_decrease=plan.n_decrease,
            n_drop=plan.n_drop + pb.dropped,
            iterations=int(r.iterations),
            union_directed_edges=self.last_union_shape[0],
            batch_capacity=self._cap_cur,
            recompiles=self.recompiles,
        )

    @_spanned("stream.delete")
    def delete_batch(self, u, v) -> DeleteStats:
        """Tombstone a batch of undirected edges (by endpoints).

        Edges not currently in the forest are counted as missing (either
        never inserted, or discarded as non-forest edges by
        sparsification). The snapshot is republished with ``stale=True``;
        compaction (automatic past ``compact_trigger``, or explicit) makes
        the component splits visible.
        """
        pb = delta.prepare_batch(u, v, np.zeros(len(np.asarray(u))), self.n)
        # Deletions are not bounded by batch_capacity (nothing enters the
        # union buffer); probe the live index in capacity-sized chunks so
        # the device lookup kernel keeps its one compiled shape.
        n_deleted = 0
        for k in range(0, pb.count, self.batch_capacity):
            chunk = delta.PreparedBatch(
                lo=pb.lo[k : k + self.batch_capacity],
                hi=pb.hi[k : k + self.batch_capacity],
                w=pb.w[k : k + self.batch_capacity],
                count=min(self.batch_capacity, pb.count - k),
                dropped=0,
            )
            plan = delta.classify_batch(
                chunk, self._live_keys, self._live_w, self.n, self.batch_capacity
            )
            found = ~plan.is_new
            rows = self._live_rows[plan.live_pos[found]]
            newly_dead = rows[~self._dead[rows]]
            self._dead[newly_dead] = True
            self._n_dead += len(newly_dead)
            # Keep the reported weight equal to the *live* edge sum so a
            # stale snapshot is stale in connectivity only, never in weight.
            self._weight -= float(self._w[newly_dead].sum())
            n_deleted += len(newly_dead)
        n_missing = pb.count - n_deleted
        compacted = False
        if self._n_dead and self._n_dead >= self.compact_trigger * max(
            1, self._count
        ):
            self.compact()
            compacted = True
        else:
            self._version += 1
            self._publish(stale=self._n_dead > 0)
            self._refresh_live_index()
        return DeleteStats(
            version=self._version,
            n_deleted=n_deleted,
            n_missing=n_missing,
            compacted=compacted,
        )

    @_spanned("stream.compact")
    def compact(self) -> UpdateStats:
        """Drop tombstoned rows and rebuild labels/weight from the retained
        forest edges (the rebuild-from-retained compaction path)."""
        empty = np.zeros(0, np.int32)
        r = self._run_union(empty, empty, np.zeros(0, np.float32), empty)
        return UpdateStats(
            version=self._version,
            weight=self._weight,
            n_components=self.snapshots.acquire().n_components,
            n_forest_edges=self._count,
            n_new=0,
            n_decrease=0,
            n_drop=0,
            iterations=int(r.iterations),
            union_directed_edges=self.last_union_shape[0],
            batch_capacity=self._cap_cur,
            recompiles=self.recompiles,
        )

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------

    def _note_batch(self, pb) -> None:
        """Track packability and (if adaptive) resize the padded batch
        slots by powers of two off the observed batch sizes."""
        if pb.count:
            # The pack32 regime test lives in repro.solve.spec (shared
            # with the coarsen auto-detect); here it is a running
            # conjunction over the insert stream.
            ok = weights_packable(pb.w)
            if not ok and self._pack is True:
                raise ValueError(
                    "pack=True requires integral weights in [0, 255]; "
                    "construct with pack=None/False for general weights"
                )
            self._packable = self._packable and ok
        if not self.adaptive_capacity:
            return
        self._recent.append(pb.count)
        del self._recent[:-8]  # sliding window
        need = min(next_pow2(pb.count, self._min_capacity), self.batch_capacity)
        if need > self._cap_cur:
            self._cap_cur = need  # grow immediately: the batch must fit
        elif (
            self._cap_cur > self._min_capacity
            and max(self._recent) <= self._cap_cur // 4
        ):
            # Shrink one step with 4x hysteresis so an oscillating load
            # doesn't thrash executables.
            self._cap_cur = max(self._min_capacity, self._cap_cur // 2)

    def _use_pack(self) -> bool:
        if self._pack is not None:
            return self._pack
        # Local union eids stay < U; strict 24-bit bound avoids the
        # pack32(255, 2^24−1) == identity collision.
        return self._packable and self.union_edge_capacity < PACK_IDX_MASK

    @_spanned("stream.union_solve")
    def _run_union(self, b_lo, b_hi, b_w, b_gid):
        """MSF over (live forest ∪ batch) in the fixed-capacity union
        buffer; rewrite the store from the result and publish a snapshot."""
        U = self.union_edge_capacity
        lo_u = np.zeros(U, np.int32)
        hi_u = np.zeros(U, np.int32)
        w_u = np.full(U, np.inf, np.float32)
        gid_u = np.full(U, -1, np.int32)
        valid_u = np.zeros(U, bool)

        live = np.flatnonzero(~self._dead[: self._count])
        f = len(live)
        lo_u[:f], hi_u[:f] = self._lo[live], self._hi[live]
        w_u[:f], gid_u[:f] = self._w[live], self._gid[live]
        valid_u[:f] = True
        b = len(b_lo)
        sl = slice(self.forest_capacity, self.forest_capacity + b)
        lo_u[sl], hi_u[sl], w_u[sl], gid_u[sl] = b_lo, b_hi, b_w, b_gid
        valid_u[sl] = True

        local_eid = np.arange(U, dtype=np.int32)
        g = Graph(
            src=np.concatenate([lo_u, hi_u]),
            dst=np.concatenate([hi_u, lo_u]),
            w=np.concatenate([w_u, w_u]),
            eid=np.concatenate([local_eid, local_eid]),
            valid=np.concatenate([valid_u, valid_u]),
            n=self.n,
        )
        use_pack = self._use_pack()
        # pack is a jit-static arg: flipping it re-traces even at an
        # already-seen buffer shape, so it is part of the executable key.
        self._union_shapes.add((tuple(g.src.shape), use_pack))
        self.last_union_shape = tuple(g.src.shape)
        if self._coarsen_cfg is not None and f + b >= self.coarsen_threshold:
            from repro.coarsen.engine import CoarsenMSF  # lazy: layer cycle

            eng = CoarsenMSF(
                self._coarsen_cfg,
                pack=use_pack,
                segmin=self._segmin if use_pack else None,
                **self._msf_opts,
            )
            r = eng(g)
            self.last_coarsen_stats = eng.last_stats
        else:
            # flat_msf's backend resolution (repro.solve.spec) degrades
            # "sorted" — a dedupe-only backend — to "auto" for the flat
            # hook loop's unsorted segment ids.
            self.last_coarsen_stats = None
            r = flat_msf(
                g,
                pack=use_pack,
                segmin=self._segmin if use_pack else None,
                **self._msf_opts,
            )

        n_f = int(r.n_msf_edges)
        sel = np.asarray(r.msf_eids)[:n_f]  # local union indices → rows
        self._lo[:n_f], self._hi[:n_f] = lo_u[sel], hi_u[sel]
        self._w[:n_f], self._gid[:n_f] = w_u[sel], gid_u[sel]
        self._dead[:] = False
        self._count = n_f
        self._n_dead = 0
        self._weight = float(r.weight)
        self._version += 1
        self._publish(stale=False, parent=r.parent)
        self._refresh_live_index()
        return r

    def _publish(self, *, stale: bool, parent=None):
        if parent is None:
            parent = self.snapshots.acquire().parent
        self.snapshots.publish(
            make_snapshot(
                self._version,
                parent,
                self._weight,
                self.n_forest_edges,
                stale=stale,
            )
        )

    def _refresh_live_index(self):
        live = np.flatnonzero(~self._dead[: self._count])
        keys, w_sorted, order = delta.build_live_index(
            self._lo[live],
            self._hi[live],
            self._w[live],
            self.n,
            self.forest_capacity,
        )
        self._live_keys = keys
        self._live_w = w_sorted
        self._live_rows = live[order] if len(live) else np.zeros(0, np.int64)


class StreamingMSF(StreamEngine):
    """Deprecated direct-construction shim over :class:`StreamEngine`.

    .. deprecated::
        Use the declarative API instead::

            from repro.solve import SolveSpec, plan
            p = plan(n, SolveSpec(mode="stream", batch_capacity=1024))
            p.update(u, v, w)       # -> SolveReport
            p.query(qu, qv)         # -> bool [k]

        The shim is the same engine (same state layout, same snapshots,
        bit-identical forests); it only adds this warning. It will be
        removed once the deprecation window closes; see DESIGN.md §9.
    """

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "StreamingMSF is deprecated; use repro.solve.plan(n, "
            "SolveSpec(mode='stream', ...)) and its update()/query() "
            "surfaces instead",
            DeprecationWarning,
            stacklevel=2,
        )
        super().__init__(*args, **kwargs)
