"""Streaming minimum spanning forest engine (DESIGN.md §6.1).

Maintains the MSF of an edge stream under **batch insertions** and **batch
deletions**, serving consistent snapshots to the query layer while updates
are in flight.

Insertions are *exact* via the sparsification identity

    MSF(G ∪ B) = MSF(MSF(G) ∪ B)

(Sanders & Schimek 2023, §2; Kopelowitz et al. 2018): an insert batch of
size |B| runs the already-jitted ``repro.core.msf`` kernel over a
*fixed-capacity* union buffer of exactly

    forest_capacity + batch_capacity  =  (n − 1) + B_cap

undirected slots — O(n + |B|) instead of O(m) work, and one compiled
executable for every batch size (padding, not re-tracing). With
``adaptive_capacity`` the batch slots instead track observed batch sizes
by powers of two (bounded recompiles, reported via
``UpdateStats.recompiles``). The MSF inner loop runs the pack32
single-reduction path whenever weights stay in the paper's integral
[0, 255] regime, with the packed segment-min swappable for the Pallas
flat kernel (``segmin="pallas"``; ``interpret=True`` is selected
automatically off ``jax.default_backend()``).

Deletions are **exact** too (DESIGN.md §6.4): edges that lose an MSF race
are no longer discarded by sparsification — they are retained in a bounded
per-component **replacement-edge reservoir**
(:class:`repro.stream.delta.Reservoir`, Kopelowitz, Porat & Rosenmutter
2018's non-tree candidate framing). Deleting a forest edge triggers
replacement-edge search: the reservoir entries bucketed under the split
component re-enter the union solve, so the republished snapshot is the
true MSF of the surviving edge multiset. A snapshot stays ``stale=True``
only while deletions remain *unhealed* — a deleted forest edge lived in a
component whose reservoir had evicted entries past its caps
(``DeleteStats.n_unhealed``, ``stream.reservoir.{hits,evictions,
exhausted}`` obs counters); :meth:`StreamEngine.recertify` rebuilds
forest + reservoir exactly from a caller-supplied edge source
(coarsen-assisted past ``coarsen_threshold``) and clears the condition.
``exact_deletes=False`` restores the legacy forest-only tombstone
semantics (deferred splits, conservative forests) for callers that want
the old trade-off.
"""
from __future__ import annotations

import warnings
from typing import NamedTuple

import numpy as np

from repro import obs
from repro.core.msf import flat_msf
from repro.core.semiring import PACK_IDX_MASK
from repro.graphs.structures import Graph, edge_keys
from repro.solve.spec import weights_packable
from repro.stream import delta
from repro.stream.service import next_pow2
from repro.stream.snapshot import SnapshotStore, make_snapshot


def _spanned(name):
    """Wrap a method in an ``obs.span(name)`` — the per-op latency
    surface of DESIGN.md §10.4 (span durations land in the
    ``span.<name>`` histogram of the default registry when metrics are
    on; one extra frame + one branch when obs is off)."""
    import functools

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            with obs.span(name):
                return fn(*a, **kw)
        return wrapper
    return deco


def _canonical_labels(parent) -> np.ndarray:
    """Pointer-jump a parent vector to its root fixpoint (host-side)."""
    p = np.asarray(parent, np.int32)
    while True:
        gp = p[p]
        if np.array_equal(gp, p):
            return p
        p = gp


class UpdateStats(NamedTuple):
    version: int
    weight: float
    n_components: int
    n_forest_edges: int
    n_new: int  # batch edges absent from the live forest
    n_decrease: int  # batch edges that lowered a live weight
    n_drop: int  # batch duplicates that changed nothing
    iterations: int  # MSF hook/shortcut iterations for this update
    union_directed_edges: int  # traced edge-buffer size of the update
    batch_capacity: int = 0  # padded batch slots used for this update
    recompiles: int = 0  # cumulative distinct union-buffer shapes compiled
    n_revived: int = 0  # n_new edges matched in the reservoir (gid kept)
    reservoir_size: int = 0  # non-tree edges retained after this update


class DeleteStats(NamedTuple):
    version: int
    n_deleted: int  # forest edges removed
    n_missing: int  # requested pairs never present (forest or reservoir)
    compacted: bool  # a union solve ran (replacement search / trigger)
    n_reservoir_deleted: int = 0  # non-tree reservoir entries removed
    n_already_dead: int = 0  # pairs already tombstoned (legacy defer mode)
    n_dropped: int = 0  # self-loops / in-batch duplicates of the request
    n_unhealed: int = 0  # forest deletions not certifiably healed
    n_replacements: int = 0  # reservoir edges promoted into the forest


class StreamEngine:
    """Incremental MSF over an undirected edge stream.

    This is the engine behind ``repro.solve``'s ``mode="stream"`` plans
    (``plan(n, SolveSpec(mode="stream")).update/query/...``); the
    :class:`StreamingMSF` name below is its deprecated direct-construction
    shim.

    Parameters
    ----------
    n: vertex count (static — defines every buffer shape).
    batch_capacity: max undirected edges per insert batch; without
        ``adaptive_capacity`` also the pad target, so every batch reuses
        one compiled MSF executable.
    adaptive_capacity: grow/shrink the padded batch slots by powers of two
        tracking observed batch sizes (floor ``min_capacity``, ceiling
        ``batch_capacity``). Small batches then pay for a small union
        buffer at the cost of a bounded number of recompiles
        (≤ log2(batch_capacity / min_capacity) shapes each way), surfaced
        as ``UpdateStats.recompiles``.
    compact_trigger: tombstoned-fraction threshold that forces compaction
        (legacy ``exact_deletes=False`` mode only; exact deletions compact
        as part of every replacement search).
    pack: use the pack32 single-reduction MSF inner loop. ``None`` (auto)
        enables it while every inserted weight has been integral in
        [0, 255] (the paper's regime — tracked incrementally, so one
        fractional batch permanently falls back to the 3-pass float
        reduction); ``True`` asserts it and rejects unpackable batches.
    segmin: packed segment-min backend for the inner loop — "jnp",
        "pallas" (the flat Pallas kernel, ``interpret=True`` selected
        automatically off ``jax.default_backend()``), "sorted" (the
        contiguous-range kernel; only meaningful for the coarsen
        recompute's dedupe — the flat hook loop falls back to "auto") or
        "auto" (Pallas only on TPU — interpreted Pallas on CPU is orders
        of magnitude slower than XLA's segment_min).
    coarsen: ``None`` (always the flat union recompute), ``True`` or a
        ``repro.coarsen.CoarsenConfig`` — rebuild via **fused**
        contract-and-filter levels (one jit per level, sorted-segment
        dedupe) whenever the union holds at least ``coarsen_threshold``
        live edges. The level dedupe is where the sorted Pallas kernel
        applies: its segment ids are sorted after the device sort.
    coarsen_threshold: live undirected union edges (forest + batch) at
        which the coarsen recompute kicks in; below it the flat solve is
        cheaper than the level machinery. :meth:`recertify` applies the
        same threshold to the supplied edge count (the coarsen-assisted
        recertification path).
    reservoir_capacity: total non-tree edges retained across components
        (0 disables retention — every loser eviction immediately marks
        its component lossy, so forest deletions there are unhealed).
    reservoir_per_component: retained-entry cap per component
        (cheapest-first under the MSF's own (w, gid) order).
    exact_deletes: ``True`` (default) runs replacement-edge search on
        every forest-edge deletion, publishing the true MSF;
        ``False`` restores the legacy tombstone semantics (republish
        ``stale=True``, splits land at compaction, lost replacements are
        never recovered).
    variant / shortcut / capacity: forwarded to ``repro.core.msf``.
    """

    def __init__(
        self,
        n: int,
        batch_capacity: int = 1024,
        *,
        adaptive_capacity: bool = False,
        min_capacity: int = 16,
        compact_trigger: float = 0.25,
        pack: bool | None = None,
        segmin: str = "auto",
        coarsen=None,
        coarsen_threshold: int = 1 << 15,
        reservoir_capacity: int = 4096,
        reservoir_per_component: int = 256,
        exact_deletes: bool = True,
        variant: str = "complete",
        shortcut: str = "complete",
        capacity: int = 1 << 16,
    ):
        if n < 2:
            raise ValueError("the streaming MSF engine needs n >= 2")
        if batch_capacity < 1:
            raise ValueError("batch_capacity must be >= 1")
        self.n = int(n)
        self.batch_capacity = int(batch_capacity)
        self.forest_capacity = self.n - 1
        self.compact_trigger = float(compact_trigger)
        self._msf_opts = dict(variant=variant, shortcut=shortcut, capacity=capacity)
        self._pack = pack
        self._segmin = segmin
        self._coarsen_cfg = None
        if coarsen is not None and coarsen is not False:
            from repro.coarsen.engine import CoarsenConfig  # lazy: layer cycle
            import dataclasses

            cfg = CoarsenConfig() if coarsen is True else coarsen
            # The union rebuild always takes the fused device-resident
            # levels; the sorted-dedupe backend follows ``segmin``.
            self._coarsen_cfg = dataclasses.replace(
                cfg, fused=True, segmin=segmin
            )
        self.coarsen_threshold = int(coarsen_threshold)
        #: CoarsenStats of the latest update when the coarsen rebuild ran,
        #: None when the flat recompute was taken (or never enabled).
        self.last_coarsen_stats = None
        self._packable = True  # conjunction over every inserted batch
        self.adaptive_capacity = bool(adaptive_capacity)
        self._min_capacity = min(next_pow2(min_capacity, 1), self.batch_capacity)
        self._cap_cur = (
            self._min_capacity if adaptive_capacity else self.batch_capacity
        )
        self._recent: list[int] = []  # last few observed batch sizes
        self._union_shapes: set = set()  # distinct compiled union shapes
        if pack is True and self.forest_capacity + self.batch_capacity >= PACK_IDX_MASK:
            raise ValueError(
                f"pack=True needs union eids < 2^24 - 1; (n - 1) + "
                f"batch_capacity = {self.forest_capacity + self.batch_capacity} "
                f"overflows the pack32 index field"
            )

        fc = self.forest_capacity
        # Host-side forest store (compact: rows [0, _count) are live-or-dead).
        self._lo = np.zeros(fc, np.int32)
        self._hi = np.zeros(fc, np.int32)
        self._w = np.zeros(fc, np.float32)
        self._gid = np.full(fc, -1, np.int32)
        self._dead = np.zeros(fc, bool)
        self._count = 0
        self._n_dead = 0
        self._weight = 0.0
        self._next_gid = 0
        self._version = 0

        # Replacement-edge reservoir (DESIGN.md §6.4): race losers stay
        # available as deletion replacements; ``_lossy`` marks vertices of
        # components whose reservoir evicted entries (deletions there are
        # not certifiable); ``_unhealed`` counts uncertified deletions
        # since the last recertification.
        self.exact_deletes = bool(exact_deletes)
        self._reservoir = delta.Reservoir(
            self.n, reservoir_capacity, reservoir_per_component
        )
        self._lossy = np.zeros(self.n, bool)
        self._canon = np.arange(self.n, dtype=np.int32)
        self._unhealed = 0

        self.snapshots = SnapshotStore()
        self.last_union_shape: tuple | None = None
        self._publish(stale=False, parent=np.arange(self.n, dtype=np.int32))
        self._refresh_live_index()

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    @property
    def union_edge_capacity(self) -> int:
        """Undirected slots per update — the (n − 1) + B_cur bound (B_cur
        follows observed batch sizes under ``adaptive_capacity``)."""
        return self.forest_capacity + self._cap_cur

    @property
    def recompiles(self) -> int:
        """Distinct (union-buffer shape, pack mode) executables compiled
        so far — 1 at fixed capacity and stable pack mode; the auto-pack
        flip after a fractional batch adds one, and adaptive capacity
        adds one per newly-visited pow2 size. Oscillating between
        already-seen keys hits jit's executable cache and does not
        count."""
        return len(self._union_shapes)

    @property
    def version(self) -> int:
        return self._version

    @property
    def weight(self) -> float:
        return self._weight

    @property
    def n_forest_edges(self) -> int:
        return self._count - self._n_dead

    @property
    def unhealed(self) -> int:
        """Forest deletions not certifiably healed since the last
        recertification — snapshots stay ``stale`` while this is > 0."""
        return self._unhealed

    @property
    def reservoir_size(self) -> int:
        """Non-tree edges currently retained as replacement candidates."""
        return len(self._reservoir)

    def forest_edges(self):
        """Copies of the live forest rows: (lo, hi, w, gid)."""
        live = ~self._dead[: self._count]
        idx = np.flatnonzero(live)
        return (
            self._lo[idx].copy(),
            self._hi[idx].copy(),
            self._w[idx].copy(),
            self._gid[idx].copy(),
        )

    def forest_gids(self) -> np.ndarray:
        """Stable gids of the live forest edges only — the cheap column
        for per-update reporting (``repro.solve``'s stream reports build
        one per batch; copying all four forest columns there would tax
        the insert hot path)."""
        return self._gid[np.flatnonzero(~self._dead[: self._count])]

    @_spanned("stream.update")
    def insert_batch(self, u, v, w) -> UpdateStats:
        """Apply one batch of undirected weighted edge insertions.

        Exact MSF maintenance: duplicates of live forest edges are
        dropped (or treated as weight decreases, keeping the stable gid),
        duplicates of reservoir entries are *revived* — pulled back into
        the union solve at the minimum of the two weights, keeping the
        reservoir gid — new edges get fresh gids, and the forest is
        recomputed over forest ∪ batch.
        """
        pb = delta.prepare_batch(u, v, w, self.n)
        if pb.count > self.batch_capacity:
            raise ValueError(
                f"batch of {pb.count} unique edges exceeds batch_capacity="
                f"{self.batch_capacity}; split the batch or raise the capacity"
            )
        self._note_batch(pb)
        plan = delta.classify_batch(
            pb, self._live_keys, self._live_w, self.n, self.batch_capacity
        )
        # Weight decreases: update the live row in place; gid is unchanged.
        if plan.n_decrease:
            rows = self._live_rows[plan.live_pos[plan.is_decrease]]
            self._w[rows] = np.minimum(self._w[rows], pb.w[plan.is_decrease])
        # Edges absent from the forest: revive reservoir duplicates
        # (stable gid, min weight — a cheaper re-insert may displace a
        # forest edge, so it must re-enter the race), fresh gids for the
        # truly new.
        new_lo = pb.lo[plan.is_new]
        new_hi = pb.hi[plan.is_new]
        new_w = pb.w[plan.is_new].copy()
        new_gid = np.empty(plan.n_new, np.int32)
        res_rows = self._reservoir.lookup(new_lo, new_hi)
        revived = res_rows >= 0
        n_revived = int(revived.sum())
        if n_revived:
            _, _, r_w, r_gid = self._reservoir.remove_rows(res_rows[revived])
            new_w[revived] = np.minimum(new_w[revived], r_w)
            new_gid[revived] = r_gid
        n_fresh = plan.n_new - n_revived
        new_gid[~revived] = np.arange(
            self._next_gid, self._next_gid + n_fresh, dtype=np.int32
        )
        self._next_gid += n_fresh
        r = self._run_union(new_lo, new_hi, new_w, new_gid)
        return UpdateStats(
            version=self._version,
            weight=self._weight,
            n_components=self.snapshots.acquire().n_components,
            n_forest_edges=self._count,
            n_new=plan.n_new,
            n_decrease=plan.n_decrease,
            n_drop=plan.n_drop + pb.dropped,
            iterations=int(r.iterations),
            union_directed_edges=self.last_union_shape[0],
            batch_capacity=self._cap_cur,
            recompiles=self.recompiles,
            n_revived=n_revived,
            reservoir_size=len(self._reservoir),
        )

    @_spanned("stream.delete")
    def delete_batch(self, u, v) -> DeleteStats:
        """Delete a batch of undirected edges (by endpoints) — exactly.

        Forest edges are tombstoned and immediately *healed*: the
        reservoir entries bucketed under each split component re-enter a
        union solve (chunked to the padded batch capacity, so the
        executable shapes stay bounded), and the republished snapshot is
        the true MSF of the surviving edge multiset. Reservoir entries
        named by the batch are removed in place (non-tree removals never
        change the forest). A deletion is **unhealed** — and the snapshot
        stays ``stale`` — only when the split component's reservoir had
        evicted entries (``n_unhealed``; recover via :meth:`recertify`).
        With ``exact_deletes=False`` the legacy semantics apply:
        tombstone, republish ``stale=True``, splits land at compaction.
        """
        u_arr = np.atleast_1d(np.asarray(u))
        pb = delta.prepare_batch(
            u_arr, v, np.zeros(u_arr.shape[0]), self.n
        )
        n_forest_deleted = 0
        n_already_dead = 0
        n_reservoir_deleted = 0
        n_missing = 0
        dead_comps: list[np.ndarray] = []  # one comp root per deleted edge
        # Deletions are not bounded by batch_capacity (nothing enters the
        # union buffer); probe the live index in capacity-sized chunks so
        # the device lookup kernel keeps its one compiled shape.
        for k in range(0, pb.count, self.batch_capacity):
            chunk = delta.PreparedBatch(
                lo=pb.lo[k : k + self.batch_capacity],
                hi=pb.hi[k : k + self.batch_capacity],
                w=pb.w[k : k + self.batch_capacity],
                count=min(self.batch_capacity, pb.count - k),
                dropped=0,
            )
            plan = delta.classify_batch(
                chunk, self._live_keys, self._live_w, self.n, self.batch_capacity
            )
            found = ~plan.is_new
            rows = self._live_rows[plan.live_pos[found]]
            alive = ~self._dead[rows]
            newly_dead = rows[alive]
            n_already_dead += int((~alive).sum())
            self._dead[newly_dead] = True
            self._n_dead += len(newly_dead)
            n_forest_deleted += len(newly_dead)
            if len(newly_dead):
                dead_comps.append(self._canon[self._lo[newly_dead]])
            # Misses against the live forest: already-tombstoned rows
            # (legacy defer mode), then the reservoir, else truly missing.
            miss_lo = chunk.lo[plan.is_new]
            miss_hi = chunk.hi[plan.is_new]
            if len(miss_lo):
                in_dead = np.zeros(len(miss_lo), bool)
                dead_rows = np.flatnonzero(self._dead[: self._count])
                if len(dead_rows):
                    dk = edge_keys(
                        self._lo[dead_rows], self._hi[dead_rows], self.n
                    )
                    in_dead = np.isin(
                        edge_keys(miss_lo, miss_hi, self.n), dk
                    )
                    # rows tombstoned by *this* call were still in the
                    # live index above, so matches here are prior dead
                    n_already_dead += int(in_dead.sum())
                rem = np.flatnonzero(~in_dead)
                res_rows = self._reservoir.lookup(
                    miss_lo[rem], miss_hi[rem]
                )
                hit = res_rows >= 0
                if hit.any():
                    self._reservoir.remove_rows(res_rows[hit])
                n_reservoir_deleted += int(hit.sum())
                n_missing += int((~hit).sum())
        if n_forest_deleted:
            # Keep the reported weight equal to the *live* edge sum —
            # recomputed from the rows, never decremented (float32
            # decrements drift over long delete/insert cycles).
            self._weight = self._live_weight()
        n_unhealed_new = 0
        n_replacements = 0
        compacted = False
        if n_forest_deleted and self.exact_deletes:
            per_edge = np.concatenate(dead_comps)
            if self._lossy.any():
                lossy_comp = np.zeros(self.n, bool)
                lossy_comp[np.unique(self._canon[self._lossy])] = True
                n_unhealed_new = int(lossy_comp[per_edge].sum())
            self._unhealed += n_unhealed_new
            if n_unhealed_new:
                obs.counter("stream.reservoir.exhausted").inc(n_unhealed_new)
            # Replacement-edge search: every reservoir entry of a split
            # component re-enters the union solve (cheapest-first across
            # capacity-sized chunks — the sparsification identity makes
            # the chunked result identical to one big solve).
            cl, ch, cw, cg = self._reservoir.take_components(
                np.unique(per_edge)
            )
            if len(cl):
                obs.counter("stream.reservoir.hits").inc(len(cl))
                order = np.argsort(cw, kind="stable")
                for k in range(0, len(cl), self._cap_cur):
                    sl = order[k : k + self._cap_cur]
                    self._run_union(cl[sl], ch[sl], cw[sl], cg[sl])
                live_gids = self._gid[: self._count][
                    ~self._dead[: self._count]
                ]
                n_replacements = int(np.isin(cg, live_gids).sum())
            else:
                empty = np.zeros(0, np.int32)
                self._run_union(empty, empty, np.zeros(0, np.float32), empty)
            compacted = True
        elif (
            n_forest_deleted
            and self._n_dead
            and self._n_dead >= self.compact_trigger * max(1, self._count)
        ):
            self.compact()
            compacted = True
        else:
            self._version += 1
            self._publish(stale=self._n_dead > 0 or self._unhealed > 0)
            self._refresh_live_index()
        return DeleteStats(
            version=self._version,
            n_deleted=n_forest_deleted,
            n_missing=n_missing,
            compacted=compacted,
            n_reservoir_deleted=n_reservoir_deleted,
            n_already_dead=n_already_dead,
            n_dropped=pb.dropped,
            n_unhealed=n_unhealed_new,
            n_replacements=n_replacements,
        )

    @_spanned("stream.compact")
    def compact(self) -> UpdateStats:
        """Drop tombstoned rows and rebuild labels/weight from the retained
        forest edges (the rebuild-from-retained compaction path)."""
        empty = np.zeros(0, np.int32)
        r = self._run_union(empty, empty, np.zeros(0, np.float32), empty)
        return UpdateStats(
            version=self._version,
            weight=self._weight,
            n_components=self.snapshots.acquire().n_components,
            n_forest_edges=self._count,
            n_new=0,
            n_decrease=0,
            n_drop=0,
            iterations=int(r.iterations),
            union_directed_edges=self.last_union_shape[0],
            batch_capacity=self._cap_cur,
            recompiles=self.recompiles,
            n_revived=0,
            reservoir_size=len(self._reservoir),
        )

    @_spanned("stream.recertify")
    def recertify(self, u, v, w) -> UpdateStats:
        """Rebuild forest + reservoir exactly from a caller-supplied edge
        source — the recovery path after unhealed deletions.

        ``(u, v, w)`` is the full surviving edge multiset (e.g. replayed
        from the system of record). Gids stay stable: supplied pairs that
        match a live forest or reservoir entry keep that entry's gid;
        unmatched pairs — exactly the edges the bounded reservoir had
        evicted — get fresh ones. The solve is coarsen-assisted past
        ``coarsen_threshold`` edges (the fused contract-and-filter
        levels) and flat below it; the buffer pads to the next power of
        two so repeated recertifications reuse executables. Afterwards
        the reservoir is refilled from the race losers, lossy marks are
        reset (modulo refill evictions), ``unhealed`` drops to 0 and the
        published snapshot is exact (``stale=False``).
        """
        pb = delta.prepare_batch(u, v, w, self.n)
        # Thread stable gids through by canonical pair key.
        live = np.flatnonzero(~self._dead[: self._count])
        r_lo, r_hi, _, r_gid, _ = self._reservoir.edges()
        known_keys = np.concatenate(
            [
                edge_keys(self._lo[live], self._hi[live], self.n),
                edge_keys(r_lo, r_hi, self.n),
            ]
        )
        known_gids = np.concatenate([self._gid[live], r_gid])
        order = np.argsort(known_keys, kind="stable")
        known_keys, known_gids = known_keys[order], known_gids[order]
        kq = edge_keys(pb.lo, pb.hi, self.n)
        gid = np.empty(pb.count, np.int32)
        match = np.zeros(pb.count, bool)
        if len(known_keys) and pb.count:
            j = np.clip(np.searchsorted(known_keys, kq), 0, len(known_keys) - 1)
            match = known_keys[j] == kq
            gid[match] = known_gids[j[match]]
        n_fresh = int((~match).sum())
        gid[~match] = np.arange(
            self._next_gid, self._next_gid + n_fresh, dtype=np.int32
        )
        self._next_gid += n_fresh
        # The supplied multiset replaces the engine's history, so
        # packability restarts from it instead of the running conjunction.
        ok = weights_packable(pb.w)
        if not ok and self._pack is True:
            raise ValueError(
                "pack=True requires integral weights in [0, 255]; "
                "construct with pack=None/False for general weights"
            )
        self._packable = ok
        cap = next_pow2(max(pb.count, 1), 1)
        use_pack = (
            self._pack
            if self._pack is not None
            else self._packable and cap < PACK_IDX_MASK
        )
        if use_pack and cap >= PACK_IDX_MASK:
            raise ValueError(
                f"pack=True needs local eids < 2^24 - 1; recertify over "
                f"{pb.count} edges overflows the pack32 index field"
            )
        lo_u = np.zeros(cap, np.int32)
        hi_u = np.zeros(cap, np.int32)
        w_u = np.full(cap, np.inf, np.float32)
        gid_u = np.full(cap, -1, np.int32)
        valid_u = np.zeros(cap, bool)
        # gid-ordered slots, as in _run_union: ties resolve to the
        # strict (w, gid) order, so the rebuilt forest is the same one
        # incremental maintenance over this multiset would have produced
        order = np.argsort(gid, kind="stable")
        lo_u[: pb.count], hi_u[: pb.count] = pb.lo[order], pb.hi[order]
        w_u[: pb.count], gid_u[: pb.count] = pb.w[order], gid[order]
        valid_u[: pb.count] = True
        g = self._union_graph(lo_u, hi_u, w_u, valid_u)
        self._union_shapes.add((tuple(g.src.shape), bool(use_pack)))
        self.last_union_shape = tuple(g.src.shape)
        r = self._solve_graph(g, pb.count, bool(use_pack))
        self._unhealed = 0
        self._commit(r, lo_u, hi_u, w_u, gid_u, valid_u, reset_reservoir=True)
        return UpdateStats(
            version=self._version,
            weight=self._weight,
            n_components=self.snapshots.acquire().n_components,
            n_forest_edges=self._count,
            n_new=n_fresh,
            n_decrease=0,
            n_drop=pb.dropped,
            iterations=int(r.iterations),
            union_directed_edges=self.last_union_shape[0],
            batch_capacity=self._cap_cur,
            recompiles=self.recompiles,
            n_revived=int(match.sum()),
            reservoir_size=len(self._reservoir),
        )

    # ------------------------------------------------------------------
    # durable state (repro.stream.persist / DESIGN.md §13.4)
    # ------------------------------------------------------------------

    def state_dict(self) -> dict:
        """The engine's complete durable state as a flat numpy pytree.

        Everything incremental correctness depends on is here: the forest
        store (full-capacity columns + live count/tombstones), the
        replacement-edge reservoir, the gid counter, the canonical labels
        behind the published snapshot, lossy/unhealed certification
        state, the packability conjunction and the adaptive-capacity
        position. Shapes are fixed by the engine configuration, so the
        tree restores into any engine constructed with the same
        ``(n, batch_capacity, reservoir_*)`` — ``config`` fingerprints
        that and :meth:`restore_state` rejects mismatches loudly.
        """
        recent = np.full(8, -1, np.int64)
        recent[: len(self._recent)] = self._recent[-8:]
        snap = self.snapshots.acquire()
        state = {
            "config": np.asarray(
                [
                    self.n,
                    self.batch_capacity,
                    self.forest_capacity,
                    int(self.exact_deletes),
                    self._reservoir.capacity,
                    self._reservoir.per_component,
                ],
                np.int64,
            ),
            "lo": self._lo.copy(),
            "hi": self._hi.copy(),
            "w": self._w.copy(),
            "gid": self._gid.copy(),
            "dead": self._dead.copy(),
            "count": np.int64(self._count),
            "n_dead": np.int64(self._n_dead),
            "weight": np.float64(self._weight),
            "next_gid": np.int64(self._next_gid),
            "version": np.int64(self._version),
            "packable": np.bool_(self._packable),
            "cap_cur": np.int64(self._cap_cur),
            "recent": recent,
            "lossy": self._lossy.copy(),
            "canon": self._canon.copy(),
            "unhealed": np.int64(self._unhealed),
            "stale": np.bool_(snap.stale),
        }
        for k, v in self._reservoir.state_dict().items():
            state[f"reservoir/{k}"] = v
        return state

    def restore_state(self, state: dict) -> None:
        """Inverse of :meth:`state_dict`: adopt a saved engine state.

        Rebuilds the live index and the reservoir's key index, then
        publishes a snapshot at the saved version — queries resume
        against exactly the forest the saved engine was serving
        (bit-identical weight, gid set and canonical labels).
        """
        cfg = np.asarray(state["config"], np.int64)
        want = [
            self.n,
            self.batch_capacity,
            self.forest_capacity,
            int(self.exact_deletes),
            self._reservoir.capacity,
            self._reservoir.per_component,
        ]
        if list(cfg) != want:
            raise ValueError(
                f"checkpoint config {list(map(int, cfg))} does not match "
                f"this engine's config {want}; construct the engine with "
                "the same (n, batch_capacity, exact_deletes, reservoir_*)"
            )
        self._lo = np.asarray(state["lo"], np.int32).copy()
        self._hi = np.asarray(state["hi"], np.int32).copy()
        self._w = np.asarray(state["w"], np.float32).copy()
        self._gid = np.asarray(state["gid"], np.int32).copy()
        self._dead = np.asarray(state["dead"], bool).copy()
        self._count = int(state["count"])
        self._n_dead = int(state["n_dead"])
        self._weight = float(state["weight"])
        self._next_gid = int(state["next_gid"])
        self._version = int(state["version"])
        self._packable = bool(state["packable"])
        self._cap_cur = int(state["cap_cur"])
        recent = np.asarray(state["recent"], np.int64)
        self._recent = [int(x) for x in recent if x >= 0]
        self._lossy = np.asarray(state["lossy"], bool).copy()
        self._canon = np.asarray(state["canon"], np.int32).copy()
        self._unhealed = int(state["unhealed"])
        self._reservoir.restore_state(
            {
                k.split("/", 1)[1]: v
                for k, v in state.items()
                if k.startswith("reservoir/")
            }
        )
        self._publish(stale=bool(state["stale"]), parent=self._canon)
        self._refresh_live_index()

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------

    def _note_batch(self, pb) -> None:
        """Track packability and (if adaptive) resize the padded batch
        slots by powers of two off the observed batch sizes."""
        if pb.count:
            # The pack32 regime test lives in repro.solve.spec (shared
            # with the coarsen auto-detect); here it is a running
            # conjunction over the insert stream.
            ok = weights_packable(pb.w)
            if not ok and self._pack is True:
                raise ValueError(
                    "pack=True requires integral weights in [0, 255]; "
                    "construct with pack=None/False for general weights"
                )
            self._packable = self._packable and ok
        if not self.adaptive_capacity:
            return
        self._recent.append(pb.count)
        del self._recent[:-8]  # sliding window
        need = min(next_pow2(pb.count, self._min_capacity), self.batch_capacity)
        if need > self._cap_cur:
            self._cap_cur = need  # grow immediately: the batch must fit
        elif (
            self._cap_cur > self._min_capacity
            and max(self._recent) <= self._cap_cur // 4
        ):
            # Shrink one step with 4x hysteresis so an oscillating load
            # doesn't thrash executables.
            self._cap_cur = max(self._min_capacity, self._cap_cur // 2)

    def _use_pack(self) -> bool:
        if self._pack is not None:
            return self._pack
        # Local union eids stay < U; strict 24-bit bound avoids the
        # pack32(255, 2^24−1) == identity collision.
        return self._packable and self.union_edge_capacity < PACK_IDX_MASK

    def _live_weight(self) -> float:
        """Exact live-row weight sum (float64 accumulate — the published
        weight is always recomputed from the rows, never decremented)."""
        live = ~self._dead[: self._count]
        return float(self._w[: self._count][live].sum(dtype=np.float64))

    def _union_graph(self, lo_u, hi_u, w_u, valid_u) -> Graph:
        local_eid = np.arange(len(lo_u), dtype=np.int32)
        return Graph(
            src=np.concatenate([lo_u, hi_u]),
            dst=np.concatenate([hi_u, lo_u]),
            w=np.concatenate([w_u, w_u]),
            eid=np.concatenate([local_eid, local_eid]),
            valid=np.concatenate([valid_u, valid_u]),
            n=self.n,
        )

    def _solve_graph(self, g: Graph, live_edges: int, use_pack: bool):
        """MSF over one padded union graph — fused coarsen levels past the
        live-edge threshold, the flat solve below it."""
        if self._coarsen_cfg is not None and live_edges >= self.coarsen_threshold:
            from repro.coarsen.engine import CoarsenMSF  # lazy: layer cycle

            eng = CoarsenMSF(
                self._coarsen_cfg,
                pack=use_pack,
                segmin=self._segmin if use_pack else None,
                **self._msf_opts,
            )
            r = eng(g)
            self.last_coarsen_stats = eng.last_stats
        else:
            # flat_msf's backend resolution (repro.solve.spec) degrades
            # "sorted" — a dedupe-only backend — to "auto" for the flat
            # hook loop's unsorted segment ids.
            self.last_coarsen_stats = None
            r = flat_msf(
                g,
                pack=use_pack,
                segmin=self._segmin if use_pack else None,
                **self._msf_opts,
            )
        return r

    @_spanned("stream.union_solve")
    def _run_union(self, b_lo, b_hi, b_w, b_gid):
        """MSF over (live forest ∪ batch) in the fixed-capacity union
        buffer; rewrite the store from the result and publish a snapshot."""
        U = self.union_edge_capacity
        lo_u = np.zeros(U, np.int32)
        hi_u = np.zeros(U, np.int32)
        w_u = np.full(U, np.inf, np.float32)
        gid_u = np.full(U, -1, np.int32)
        valid_u = np.zeros(U, bool)

        live = np.flatnonzero(~self._dead[: self._count])
        f = len(live)
        b = len(b_lo)
        m = f + b
        # Fill slots [0, m) in gid order: the MSF kernel breaks weight
        # ties by minimum local eid, so gid-ordered slots make the solve
        # implement the strict (w, gid) total order — the MSF is then
        # *unique*, which is what keeps reservoir entries non-tree under
        # insertions and makes chunked heals order-independent.
        lo_m = np.concatenate([self._lo[live], b_lo])
        hi_m = np.concatenate([self._hi[live], b_hi])
        w_m = np.concatenate([self._w[live], b_w])
        gid_m = np.concatenate([self._gid[live], b_gid])
        order = np.argsort(gid_m, kind="stable")
        lo_u[:m], hi_u[:m] = lo_m[order], hi_m[order]
        w_u[:m], gid_u[:m] = w_m[order], gid_m[order]
        valid_u[:m] = True

        g = self._union_graph(lo_u, hi_u, w_u, valid_u)
        use_pack = self._use_pack()
        # pack is a jit-static arg: flipping it re-traces even at an
        # already-seen buffer shape, so it is part of the executable key.
        self._union_shapes.add((tuple(g.src.shape), use_pack))
        self.last_union_shape = tuple(g.src.shape)
        r = self._solve_graph(g, f + b, use_pack)
        self._commit(r, lo_u, hi_u, w_u, gid_u, valid_u)
        return r

    def _commit(
        self, r, lo_u, hi_u, w_u, gid_u, valid_u, *, reset_reservoir=False
    ):
        """Rewrite the store from one MSF result over a padded union
        buffer, retain the race losers in the reservoir, and publish."""
        n_f = int(r.n_msf_edges)
        sel = np.asarray(r.msf_eids)[:n_f]  # local union indices → rows
        canon = _canonical_labels(r.parent)
        self._canon = canon
        # Non-tree retention: every valid union slot that lost the race
        # goes to the reservoir under its (intra-)component bucket.
        win = np.zeros(len(valid_u), bool)
        win[sel] = True
        lose = np.flatnonzero(valid_u & ~win)
        if reset_reservoir:
            self._reservoir.clear()
            self._lossy[:] = False
        else:
            # existing entries move to their merged components first, so
            # the per-component caps see the post-solve partition
            self._reservoir.rebucket(canon)
        evicted, n_evicted = self._reservoir.absorb(
            lo_u[lose], hi_u[lose], w_u[lose], gid_u[lose], canon[lo_u[lose]]
        )
        if n_evicted:
            obs.counter("stream.reservoir.evictions").inc(n_evicted)
            self._lossy |= np.isin(canon, evicted)
        if self._lossy.any():
            # Lossiness is a component property: normalize per-vertex
            # marks so merges inherit it and later splits keep both sides
            # conservatively flagged.
            comp_lossy = np.zeros(self.n, bool)
            comp_lossy[np.unique(canon[self._lossy])] = True
            self._lossy = comp_lossy[canon]
        self._lo[:n_f], self._hi[:n_f] = lo_u[sel], hi_u[sel]
        self._w[:n_f], self._gid[:n_f] = w_u[sel], gid_u[sel]
        self._dead[:] = False
        self._count = n_f
        self._n_dead = 0
        self._weight = self._live_weight()
        self._version += 1
        self._publish(stale=self._unhealed > 0, parent=canon)
        self._refresh_live_index()

    def _publish(self, *, stale: bool, parent=None):
        if parent is None:
            parent = self.snapshots.acquire().parent
        self.snapshots.publish(
            make_snapshot(
                self._version,
                parent,
                self._weight,
                self.n_forest_edges,
                stale=stale,
                n_unhealed=self._unhealed,
            )
        )

    def _refresh_live_index(self):
        live = np.flatnonzero(~self._dead[: self._count])
        keys, w_sorted, order = delta.build_live_index(
            self._lo[live],
            self._hi[live],
            self._w[live],
            self.n,
            self.forest_capacity,
        )
        self._live_keys = keys
        self._live_w = w_sorted
        self._live_rows = live[order] if len(live) else np.zeros(0, np.int64)


class StreamingMSF(StreamEngine):
    """Deprecated direct-construction shim over :class:`StreamEngine`.

    .. deprecated::
        Use the declarative API instead::

            from repro.solve import SolveSpec, plan
            p = plan(n, SolveSpec(mode="stream", batch_capacity=1024))
            p.update(u, v, w)       # -> SolveReport
            p.query(qu, qv)         # -> bool [k]

        The shim is the same engine (same state layout, same snapshots,
        bit-identical forests); it only adds this warning. It will be
        removed once the deprecation window closes; see DESIGN.md §9.
    """

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "StreamingMSF is deprecated; use repro.solve.plan(n, "
            "SolveSpec(mode='stream', ...)) and its update()/query() "
            "surfaces instead",
            DeprecationWarning,
            stacklevel=2,
        )
        super().__init__(*args, **kwargs)
