"""Versioned, double-buffered forest snapshots (DESIGN.md §6.3).

The streaming engine mutates its edge store between MSF runs; queries must
never observe that in-flight state. The protocol:

- a :class:`Snapshot` is an *immutable* value: version counter, canonical
  parent labels, per-vertex component sizes, component count, total forest
  weight, forest edge count, a ``stale`` bit (exact-delete mode: set only
  while deletions remain unhealed, see ``n_unhealed``; legacy defer mode:
  set between a tombstone batch and the compaction that makes its effect
  visible), and the ``n_unhealed`` count behind it;
- the :class:`SnapshotStore` keeps two slots. A publisher writes the fresh
  snapshot into the *inactive* slot and then flips the active index — a
  single reference swap, so a reader that ``acquire()``-d the old snapshot
  keeps a fully consistent view (labels, sizes and weight all from one
  version) for as long as it holds the object, while new readers see the
  new version immediately.

Single writer (the engine), any number of readers (query services).
"""
from __future__ import annotations

import threading
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class Snapshot(NamedTuple):
    version: int
    parent: jax.Array  # int32 [n]: canonical (star-root) component labels
    comp_size: jax.Array  # int32 [n]: size of the component containing i
    n_components: int
    weight: float  # total forest weight
    n_forest_edges: int
    stale: bool = False  # True ⇒ forest may diverge from the true MSF
    n_unhealed: int = 0  # deletions not certifiably healed (exact mode)


@jax.jit
def _component_stats(parent: jax.Array):
    """Per-vertex component sizes + component count from canonical labels."""
    n = parent.shape[0]
    sizes = jax.ops.segment_sum(
        jnp.ones_like(parent), parent, num_segments=n
    )
    ncc = jnp.sum(parent == jnp.arange(n, dtype=parent.dtype))
    return sizes[parent], ncc


def make_snapshot(
    version: int,
    parent: jax.Array,
    weight: float,
    n_forest_edges: int,
    stale: bool = False,
    n_unhealed: int = 0,
) -> Snapshot:
    comp_size, ncc = _component_stats(jnp.asarray(parent, jnp.int32))
    return Snapshot(
        version=int(version),
        parent=jnp.asarray(parent, jnp.int32),
        comp_size=comp_size,
        n_components=int(ncc),
        weight=float(weight),
        n_forest_edges=int(n_forest_edges),
        stale=bool(stale),
        n_unhealed=int(n_unhealed),
    )


class SnapshotStore:
    """Double-buffered single-writer snapshot publication."""

    def __init__(self):
        self._slots: list[Optional[Snapshot]] = [None, None]
        self._active = 0
        self._publish_lock = threading.Lock()

    def publish(self, snap: Snapshot) -> None:
        """Install ``snap`` as the current snapshot (writer side)."""
        with self._publish_lock:
            nxt = 1 - self._active
            self._slots[nxt] = snap
            self._active = nxt  # the flip: readers switch atomically

    def acquire(self) -> Snapshot:
        """Return the current snapshot (reader side, lock-free)."""
        snap = self._slots[self._active]
        if snap is None:
            raise RuntimeError("no snapshot published yet")
        return snap

    @property
    def version(self) -> int:
        snap = self._slots[self._active]
        return -1 if snap is None else snap.version
