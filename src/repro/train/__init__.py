from repro.train import steps
