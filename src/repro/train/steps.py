"""Train / serve step factories for every architecture family.

These are the functions the dry-run lowers and the launcher executes:
full train steps (fwd + bwd + AdamW + LR schedule, optional gradient
compression), prefill/decode serve steps, and recsys serving/retrieval.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig, LMConfig, RecsysConfig
from repro.models import gnn as G
from repro.models import recsys as R
from repro.models import transformer as T
from repro.optim.adamw import adamw_update, cosine_lr
from repro.optim.compress import compress_with_error_feedback

LR = dict(peak=3e-4, warmup=100, total=10000)


def _apply_opt(params, opt_state, grads, step, *, compress=False, err_state=None):
    lr = cosine_lr(step, **LR)
    if compress:
        grads, err_state = compress_with_error_feedback(grads, err_state)
    params, opt_state, gnorm = adamw_update(grads, opt_state, params, lr)
    return params, opt_state, gnorm, err_state


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

def lm_loss_and_grad(params, tokens, labels, cfg: LMConfig, mesh, *,
                     triangle_skip: bool | None = None):
    """Loss+grad with optional microbatch gradient accumulation
    (``cfg.grad_accum``): each microbatch's activations live only for its
    own fwd+bwd, dividing activation-stack memory by the accumulation
    factor while keeping the global batch (the lever that fits kimi-k2
    train on fewer chips — §Perf)."""
    tskip = cfg.triangle_skip if triangle_skip is None else triangle_skip

    def loss_fn(p, t, l):
        x = T.lm_forward(p, t, cfg, mesh, triangle_skip=tskip)
        return T.softmax_xent(x, p["unembed"], l, cfg)

    k = cfg.grad_accum
    if k <= 1:
        return jax.value_and_grad(loss_fn)(params, tokens, labels)
    b = tokens.shape[0]
    assert b % k == 0, (b, k)
    tks = tokens.reshape(k, b // k, -1)
    lbs = labels.reshape(k, b // k, -1)

    def mb(carry, inp):
        g_acc, loss_acc = carry
        t, l = inp
        loss, g = jax.value_and_grad(loss_fn)(params, t, l)
        g_acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), g_acc, g)
        return (g_acc, loss_acc + loss), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss), _ = jax.lax.scan(mb, (g0, jnp.float32(0.0)), (tks, lbs))
    inv = 1.0 / k
    grads = jax.tree.map(lambda g: g * inv, grads)
    return loss * inv, grads


def lm_train_step(params, opt_state, tokens, labels, cfg: LMConfig, mesh):
    loss, grads = lm_loss_and_grad(params, tokens, labels, cfg, mesh)
    params, opt_state, gnorm, _ = _apply_opt(params, opt_state, grads, opt_state.step)
    return params, opt_state, {"loss": loss, "gnorm": gnorm}


def lm_prefill_step(params, tokens, cfg: LMConfig, mesh):
    logits, cache = T.lm_prefill(params, tokens, cfg, mesh)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tok, cache


def lm_decode_step(params, token, cache, pos, cfg: LMConfig, mesh):
    logits, cache = T.lm_decode_step(params, token, cache, pos, cfg, mesh)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tok, cache


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

def gnn_apply(params, batch: Dict[str, Any], cfg: GNNConfig, n_graphs: int = 1):
    if cfg.kind == "gat":
        return G.apply_gat(params, batch["x"], batch["src"], batch["dst"],
                           batch["edge_valid"], cfg)
    if cfg.kind == "meshgraphnet":
        return G.apply_meshgraphnet(params, batch["x"], batch["e_feat"], batch["src"],
                                    batch["dst"], batch["edge_valid"], cfg)
    if cfg.kind == "gatedgcn":
        return G.apply_gatedgcn(params, batch["x"], batch["e_feat"], batch["src"],
                                batch["dst"], batch["edge_valid"], cfg)
    if cfg.kind == "nequip":
        return G.apply_nequip(params, batch["species"], batch["pos"], batch["src"],
                              batch["dst"], batch["edge_valid"], batch["graph_ids"],
                              n_graphs, cfg)
    raise ValueError(cfg.kind)


def gnn_loss(params, batch, cfg: GNNConfig, n_graphs: int = 1):
    if cfg.kind == "nequip":
        energy = gnn_apply(params, batch, cfg, n_graphs)
        loss = jnp.mean((energy - batch["energy"]) ** 2)
        if cfg.predict_forces:
            def e_of_pos(pos):
                return gnn_apply(params, dict(batch, pos=pos), cfg, n_graphs).sum()
            forces = -jax.grad(e_of_pos)(batch["pos"])
            loss = loss + jnp.mean((forces - batch["forces"]) ** 2)
        return loss
    out = gnn_apply(params, batch, cfg)
    mask = batch.get("node_mask")
    if cfg.n_classes:
        logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
        if mask is not None:
            return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1)
        return jnp.mean(nll)
    err = (out - batch["targets"]) ** 2
    if mask is not None:
        return jnp.sum(err * mask[:, None]) / jnp.maximum(mask.sum() * err.shape[-1], 1)
    return jnp.mean(err)


def gnn_train_step(params, opt_state, batch, cfg: GNNConfig, n_graphs: int = 1):
    loss, grads = jax.value_and_grad(gnn_loss)(params, batch, cfg, n_graphs)
    params, opt_state, gnorm, _ = _apply_opt(params, opt_state, grads, opt_state.step)
    return params, opt_state, {"loss": loss, "gnorm": gnorm}


# ---------------------------------------------------------------------------
# recsys
# ---------------------------------------------------------------------------

def recsys_train_step(params, opt_state, ids, labels, cfg: RecsysConfig):
    loss, grads = jax.value_and_grad(R.xdeepfm_loss)(params, ids, labels, cfg)
    params, opt_state, gnorm, _ = _apply_opt(params, opt_state, grads, opt_state.step)
    return params, opt_state, {"loss": loss, "gnorm": gnorm}


def recsys_serve_step(params, ids, cfg: RecsysConfig):
    return jax.nn.sigmoid(R.xdeepfm_logits(params, ids, cfg))


def recsys_retrieval_step(params, ids, cfg: RecsysConfig, k: int = 100):
    return R.retrieval_topk(params, ids, cfg, k=k)
