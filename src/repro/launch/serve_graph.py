"""Streaming MSF serving demo: replay a synthetic insert/query workload.

Generates an R-MAT edge stream, feeds it to a ``repro.solve`` stream
plan (``SolveSpec(mode="stream")``) in fixed-size insert batches, and
interleaves batched connectivity queries answered from the published
snapshots — then reports update latency percentiles, query throughput,
and verifies the final forest against a from-scratch flat plan over the
accumulated edge set.

  PYTHONPATH=src python -m repro.launch.serve_graph --scale 12 --edge-factor 8 \
      --batch-size 2048 --queries-per-batch 8192

``--loadgen`` switches to the open-loop SLO harness instead (all other
flags are forwarded to ``repro.launch.loadgen``, DESIGN.md §11).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def undirected_edges(g):
    """Recover the (lo, hi, w) undirected edge list from a symmetric Graph."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w)
    sel = np.asarray(g.valid) & (src < dst)
    return src[sel], dst[sel], w[sel]


def main(argv=None):
    argv = sys.argv[1:] if argv is None else list(argv)
    if "--loadgen" in argv:
        from repro.launch.loadgen import main as loadgen_main

        raise SystemExit(
            loadgen_main([a for a in argv if a != "--loadgen"])
        )
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12, help="n = 2**scale vertices")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=2048)
    ap.add_argument("--queries-per-batch", type=int, default=8192)
    ap.add_argument("--delete-every", type=int, default=0,
                    help="if >0, tombstone a small batch after every k-th insert")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="export a Chrome-trace/Perfetto JSON of the run")
    ap.add_argument("--metrics-every", type=int, default=0, metavar="K",
                    help="if >0, dump the obs metrics snapshot (incl. "
                         "query-latency p50/p95/p99) every K batches")
    args = ap.parse_args(argv)
    if args.batch_size < 1:
        ap.error("--batch-size must be >= 1")
    if args.queries_per_batch < 1:
        ap.error("--queries-per-batch must be >= 1")

    from repro import obs
    from repro.graphs.generators import rmat_graph
    from repro.graphs.structures import from_edges
    from repro.solve import SolveSpec, plan

    if args.trace:
        obs.enable("trace")
    elif args.metrics_every:
        obs.enable("metrics")

    n = 1 << args.scale
    g_full = rmat_graph(args.scale, args.edge_factor, seed=args.seed)
    lo, hi, w = undirected_edges(g_full)
    rng = np.random.default_rng(args.seed)
    perm = rng.permutation(len(lo))
    lo, hi, w = lo[perm], hi[perm], w[perm]
    n_batches = (len(lo) + args.batch_size - 1) // args.batch_size

    stream = plan(
        n, SolveSpec(mode="stream", batch_capacity=args.batch_size)
    )
    engine = stream.engine  # forest introspection for --delete-every
    print(
        f"# n={n} edges={len(lo)} batches={n_batches} "
        f"union_buffer={2 * engine.union_edge_capacity} directed slots"
    )

    up_lat, q_tp = [], []
    for k in range(n_batches):
        sl = slice(k * args.batch_size, (k + 1) * args.batch_size)
        t0 = time.perf_counter()
        rep = stream.update(lo[sl], hi[sl], w[sl])
        up_lat.append(time.perf_counter() - t0)
        if args.delete_every and (k + 1) % args.delete_every == 0:
            flo, fhi, _, _ = engine.forest_edges()
            kill = rng.integers(0, len(flo), size=min(8, len(flo)))
            stream.delete(flo[kill], fhi[kill])
        qu = rng.integers(0, n, args.queries_per_batch)
        qv = rng.integers(0, n, args.queries_per_batch)
        t0 = time.perf_counter()
        stream.query(qu, qv)
        q_tp.append(args.queries_per_batch / (time.perf_counter() - t0))
        if k % max(1, n_batches // 10) == 0:
            print(
                f"batch {k:4d}: v{rep.raw.version} weight={rep.weight:.0f} "
                f"ncc={rep.n_components} update={up_lat[-1] * 1e3:.1f}ms "
                f"queries={q_tp[-1] / 1e6:.2f}M/s"
            )
        if args.metrics_every and (k + 1) % args.metrics_every == 0:
            snap = obs.metrics_snapshot()["histograms"]
            qs = snap.get("span.stream.query")
            us = snap.get("span.stream.update")
            parts = [f"# metrics @batch {k}:"]
            for tag, s in (("query", qs), ("update", us)):
                if s:
                    parts.append(
                        f"{tag} p50={s['p50'] * 1e3:.2f}ms "
                        f"p95={s['p95'] * 1e3:.2f}ms "
                        f"p99={s['p99'] * 1e3:.2f}ms n={s['count']}"
                    )
            print(" ".join(parts))

    lat = np.asarray(up_lat[1:] or up_lat)  # drop the compile call
    print(
        f"updates: p50={np.percentile(lat, 50) * 1e3:.1f}ms "
        f"p95={np.percentile(lat, 95) * 1e3:.1f}ms "
        f"({args.batch_size / np.median(lat):.0f} edges/s sustained)"
    )
    print(f"queries: median {np.median(q_tp) / 1e6:.2f}M/s "
          f"(batch={args.queries_per_batch})")
    if args.trace:
        obs.export_trace(args.trace)
        print(f"# trace written to {args.trace} "
              f"({len(obs.trace_events())} spans) — open in ui.perfetto.dev")

    if not args.delete_every:
        full = plan(
            from_edges(lo, hi, w.astype(np.float64), n), SolveSpec()
        ).solve()
        weight = stream.solve().weight
        ok = abs(full.weight - weight) < max(1.0, 1e-6 * weight)
        print(f"verify vs full recompute: weight {weight:.0f} vs "
              f"{full.weight:.0f} -> {'OK' if ok else 'MISMATCH'}")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
