"""Streaming MSF serving demo: replay a synthetic insert/query workload.

Three entry modes:

- default — in-process replay: generates an R-MAT edge stream, feeds it
  to a ``repro.solve`` stream plan (``SolveSpec(mode="stream")``) in
  fixed-size insert batches, interleaves batched connectivity queries
  answered from the published snapshots, then reports update latency
  percentiles, query throughput, and verifies the final forest against
  a from-scratch flat plan::

    PYTHONPATH=src python -m repro.launch.serve_graph --scale 12 \
        --edge-factor 8 --batch-size 2048 --queries-per-batch 8192

- ``--loadgen`` — the open-loop SLO harness instead (all other flags
  forward to ``repro.launch.loadgen``, DESIGN.md §11);

- ``--serve`` — the network serving tier (DESIGN.md §13): wire a stream
  plan into :class:`repro.serve.MSFServer`, warm it with the first
  ``--warm-frac`` of the deterministic edge stream, and serve ``serve/v1``
  TCP until SIGTERM/SIGINT completes the graceful drain. The loadgen's
  ``--target`` mode is the matching client::

    # terminal 1 — the server (port 0 = pick an ephemeral port)
    PYTHONPATH=src python -m repro.launch.serve_graph --serve \
        --scale 10 --port 9012 --checkpoint-dir /tmp/msf-ckpt

    # terminal 2 — open-loop load over the wire
    PYTHONPATH=src python -m repro.launch.loadgen \
        --target tcp://127.0.0.1:9012 --qps 200 --duration 5 \
        --delete-frac 0.25 --out SLO_serve.json

  Server and loadgen regenerate the same shuffled edge stream from
  (``--scale``, ``--edge-factor``, ``--seed``), so the loadgen's writer
  continues exactly where the server's warm-up stopped (``--warm-frac``
  must match; duplicate inserts are MSF no-ops, so drift is benign).
  With ``--checkpoint-dir`` the server warm-starts from the newest
  checkpoint (skipping the warm-up replay) and checkpoints again on
  drain; ``--metrics-out`` dumps the final ``repro.obs`` metrics
  snapshot JSON on shutdown.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def undirected_edges(g):
    """Recover the (lo, hi, w) undirected edge list from a symmetric Graph."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w)
    sel = np.asarray(g.valid) & (src < dst)
    return src[sel], dst[sel], w[sel]


def edge_stream(scale: int, edge_factor: int, seed: int):
    """The canonical shuffled undirected R-MAT edge stream for
    ``(scale, edge_factor, seed)`` — deterministic, so a server and a
    remote loadgen regenerating it independently see identical edges in
    identical order (the coordination contract of ``--serve`` +
    ``--target``)."""
    from repro.graphs.generators import rmat_graph

    g = rmat_graph(scale, edge_factor, seed=seed)
    lo, hi, w = undirected_edges(g)
    perm = np.random.default_rng(seed).permutation(len(lo))
    return lo[perm], hi[perm], w[perm]


# ---------------------------------------------------------------------------
# --serve mode
# ---------------------------------------------------------------------------

def _serve_main(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="serve_graph --serve",
        description="serve a stream plan over serve/v1 TCP",
    )
    ap.add_argument("--scale", type=int, default=10, help="n = 2**scale")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--warm-frac", type=float, default=0.25,
                    help="fraction of the edge stream inserted before "
                         "serving (skipped on checkpoint warm-start)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral (printed at startup)")
    ap.add_argument("--batch-capacity", type=int, default=512,
                    help="stream-engine insert batch capacity")
    ap.add_argument("--micro-batch", type=int, default=256,
                    help="fused query points per server flush")
    ap.add_argument("--queue-cap", type=int, default=8192)
    ap.add_argument("--deadline-ms", type=float, default=1000.0)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="durable engine state: warm-start from the "
                         "newest checkpoint here, checkpoint on drain")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="autosave every K write ops (0 = drain only)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final obs metrics snapshot JSON "
                         "here on drain")
    args = ap.parse_args(argv)

    from repro import obs, serve
    from repro.solve import SolveSpec, plan
    from repro.stream import persist

    n = 1 << args.scale
    stream = plan(
        n, SolveSpec(mode="stream", batch_capacity=args.batch_capacity)
    )
    warm_start = bool(
        args.checkpoint_dir
        and persist.latest_stream_step(args.checkpoint_dir) is not None
    )
    if not warm_start and args.warm_frac > 0:
        lo, hi, w = edge_stream(args.scale, args.edge_factor, args.seed)
        warm = int(len(lo) * args.warm_frac)
        cap = args.batch_capacity
        for at in range(0, warm, cap):
            end = min(at + cap, warm)
            stream.update(lo[at:end], hi[at:end], w[at:end])
        print(f"# warmed with {warm} edges "
              f"(v{stream.engine.version}, weight={stream.engine.weight:.0f})",
              flush=True)

    cfg = serve.ServeConfig(
        host=args.host, port=args.port, micro_batch=args.micro_batch,
        queue_cap=args.queue_cap, deadline_ms=args.deadline_ms,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
    )
    serve.serve_forever(stream, cfg)  # blocks until drain completes

    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(obs.metrics_snapshot(), f, indent=1, sort_keys=True)
        print(f"# metrics snapshot written to {args.metrics_out}")
    print(f"# drained at v{stream.engine.version} "
          f"weight={stream.engine.weight:.0f}")
    return 0


# ---------------------------------------------------------------------------
# default replay mode
# ---------------------------------------------------------------------------

def main(argv=None):
    argv = sys.argv[1:] if argv is None else list(argv)
    if "--loadgen" in argv:
        from repro.launch.loadgen import main as loadgen_main

        raise SystemExit(
            loadgen_main([a for a in argv if a != "--loadgen"])
        )
    if "--serve" in argv:
        raise SystemExit(
            _serve_main([a for a in argv if a != "--serve"])
        )
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12, help="n = 2**scale vertices")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=2048)
    ap.add_argument("--queries-per-batch", type=int, default=8192)
    ap.add_argument("--delete-every", type=int, default=0,
                    help="if >0, tombstone a small batch after every k-th insert")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="export a Chrome-trace/Perfetto JSON of the run")
    ap.add_argument("--metrics-every", type=int, default=0, metavar="K",
                    help="if >0, dump the obs metrics snapshot (incl. "
                         "query-latency p50/p95/p99) every K batches")
    args = ap.parse_args(argv)
    if args.batch_size < 1:
        ap.error("--batch-size must be >= 1")
    if args.queries_per_batch < 1:
        ap.error("--queries-per-batch must be >= 1")

    from repro import obs
    from repro.graphs.structures import from_edges
    from repro.solve import SolveSpec, plan

    if args.trace:
        obs.enable("trace")
    elif args.metrics_every:
        obs.enable("metrics")

    n = 1 << args.scale
    lo, hi, w = edge_stream(args.scale, args.edge_factor, args.seed)
    rng = np.random.default_rng(args.seed)
    n_batches = (len(lo) + args.batch_size - 1) // args.batch_size

    stream = plan(
        n, SolveSpec(mode="stream", batch_capacity=args.batch_size)
    )
    engine = stream.engine  # forest introspection for --delete-every
    print(
        f"# n={n} edges={len(lo)} batches={n_batches} "
        f"union_buffer={2 * engine.union_edge_capacity} directed slots"
    )

    up_lat, q_tp = [], []
    for k in range(n_batches):
        sl = slice(k * args.batch_size, (k + 1) * args.batch_size)
        t0 = time.perf_counter()
        rep = stream.update(lo[sl], hi[sl], w[sl])
        up_lat.append(time.perf_counter() - t0)
        if args.delete_every and (k + 1) % args.delete_every == 0:
            flo, fhi, _, _ = engine.forest_edges()
            kill = rng.integers(0, len(flo), size=min(8, len(flo)))
            stream.delete(flo[kill], fhi[kill])
        qu = rng.integers(0, n, args.queries_per_batch)
        qv = rng.integers(0, n, args.queries_per_batch)
        t0 = time.perf_counter()
        stream.query(qu, qv)
        q_tp.append(args.queries_per_batch / (time.perf_counter() - t0))
        if k % max(1, n_batches // 10) == 0:
            print(
                f"batch {k:4d}: v{rep.raw.version} weight={rep.weight:.0f} "
                f"ncc={rep.n_components} update={up_lat[-1] * 1e3:.1f}ms "
                f"queries={q_tp[-1] / 1e6:.2f}M/s"
            )
        if args.metrics_every and (k + 1) % args.metrics_every == 0:
            snap = obs.metrics_snapshot()["histograms"]
            qs = snap.get("span.stream.query")
            us = snap.get("span.stream.update")
            parts = [f"# metrics @batch {k}:"]
            for tag, s in (("query", qs), ("update", us)):
                if s:
                    parts.append(
                        f"{tag} p50={s['p50'] * 1e3:.2f}ms "
                        f"p95={s['p95'] * 1e3:.2f}ms "
                        f"p99={s['p99'] * 1e3:.2f}ms n={s['count']}"
                    )
            print(" ".join(parts))

    lat = np.asarray(up_lat[1:] or up_lat)  # drop the compile call
    print(
        f"updates: p50={np.percentile(lat, 50) * 1e3:.1f}ms "
        f"p95={np.percentile(lat, 95) * 1e3:.1f}ms "
        f"({args.batch_size / np.median(lat):.0f} edges/s sustained)"
    )
    print(f"queries: median {np.median(q_tp) / 1e6:.2f}M/s "
          f"(batch={args.queries_per_batch})")
    if args.trace:
        obs.export_trace(args.trace)
        print(f"# trace written to {args.trace} "
              f"({len(obs.trace_events())} spans) — open in ui.perfetto.dev")

    if not args.delete_every:
        full = plan(
            from_edges(lo, hi, w.astype(np.float64), n), SolveSpec()
        ).solve()
        weight = stream.solve().weight
        ok = abs(full.weight - weight) < max(1.0, 1e-6 * weight)
        print(f"verify vs full recompute: weight {weight:.0f} vs "
              f"{full.weight:.0f} -> {'OK' if ok else 'MISMATCH'}")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
