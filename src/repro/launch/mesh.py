"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state. Logical axes:

- ``pod``   — inter-pod data parallelism (DCN-ish links at real scale)
- ``data``  — intra-pod data parallelism / FSDP
- ``model`` — tensor/expert parallelism

Single pod = 16×16 = 256 chips (TPU v5e pod); multi-pod adds a leading pod
axis (2×16×16 = 512). Any (P, D, M) shape works — sharding rules reference
axis *names* — so scaling to 64 pods (16k chips) is a config change.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """1×1 mesh for smoke tests / examples on this CPU container."""
    return make_mesh((1, 1), ("data", "model"))
