"""Open-loop SLO load harness for the streaming serving path (DESIGN.md §11).

Drives :class:`~repro.stream.service.QueryService` /
:class:`~repro.stream.service.MicroBatcher` with **open-loop** Poisson
arrivals — inter-arrival gaps are drawn from a seeded exponential at the
offered QPS and queries are *admitted on schedule regardless of how the
server keeps up* (closed-loop harnesses hide overload by slowing the
client down; an open loop exposes it as queue growth, drops and tail
latency). Meanwhile a concurrent writer thread keeps mutating the graph
through the stream plan's ``update``, so the measured latencies include
snapshot churn, exactly like the serving deployment.

Three actors:

- **producer** (thread): walks the precomputed Poisson arrival schedule
  and pushes ``(deadline, u, v)`` into a *bounded* admission queue;
  ``queue.Full`` is a drop (counted, never blocks — open loop);
- **writer** (thread): mutates the graph every ``--writer-interval-ms``
  — inserts edge batches via ``plan.update`` (wrapping around the edge
  stream) and, on a ``--delete-frac`` fraction of rounds, deletes a
  slice of previously-inserted edges via ``plan.delete`` (exact
  replacement-edge deletions, so snapshots stay true MSFs under churn);
- **consumer** (main thread): pulls admitted queries into the
  MicroBatcher and flushes either at the micro-batch size or when the
  queue momentarily empties; per-query end-to-end latency (scheduled
  arrival → host-resident answer, i.e. including queue wait) goes into a
  ``repro.obs`` histogram.

The run emits an ``slo-report/v1`` JSON document (offered vs achieved
QPS, p50/p95/p99, drop/timeout counters, MicroBatcher admission
metrics) and the process exits nonzero when configured SLO targets are
missed — the CI smoke gate of the serving path::

    PYTHONPATH=src python -m repro.launch.loadgen --qps 200 --duration 5 \
        --out SLO_loadgen_smoke.json

Also reachable as ``python -m repro.launch.serve_graph --loadgen ...``.

``--target tcp://host:port`` switches both load lanes onto the wire
(DESIGN.md §13): point queries are pipelined over a ``serve/v1``
connection to a ``repro.serve`` server (started with
``serve_graph --serve``), which fuses them into micro-batches
server-side; the writer churns inserts/deletes over a second
connection. The report keeps the ``slo-report/v1`` schema and adds a
``server`` block (end-of-run status + ``serve.*`` metrics) in place of
the in-process ``batcher`` block.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import queue
import threading
import time

import numpy as np

SCHEMA = "slo-report/v1"


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="loadgen", description="open-loop SLO load harness"
    )
    ap.add_argument("--target", metavar="tcp://HOST:PORT", default=None,
                    help="drive a repro.serve server over the wire instead "
                         "of an in-process plan (serve/v1 protocol; start "
                         "one with `serve_graph --serve`). scale/edge-factor/"
                         "seed/warm-frac must match the server's so the "
                         "writer continues the same edge stream")
    ap.add_argument("--warm-frac", type=float, default=0.25,
                    help="[--target] fraction of the edge stream the server "
                         "already inserted at warm-up; the remote writer "
                         "starts after it")
    ap.add_argument("--max-inflight", type=int, default=1024,
                    help="[--target] pipelined queries in flight before "
                         "arrivals drop (the open-loop admission bound)")
    ap.add_argument("--qps", type=float, default=200.0,
                    help="offered arrival rate (Poisson)")
    ap.add_argument("--duration", type=float, default=5.0,
                    help="seconds of offered load")
    ap.add_argument("--scale", type=int, default=10,
                    help="n = 2**scale vertices")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--micro-batch", type=int, default=256,
                    help="MicroBatcher window (auto-flush threshold)")
    ap.add_argument("--queue-cap", type=int, default=4096,
                    help="admission queue bound; arrivals past it drop")
    ap.add_argument("--timeout-ms", type=float, default=250.0,
                    help="per-query latency budget; slower answers count "
                         "as timeouts (still answered)")
    ap.add_argument("--writer-batch", type=int, default=512)
    ap.add_argument("--writer-interval-ms", type=float, default=20.0)
    ap.add_argument("--delete-frac", type=float, default=0.2,
                    help="fraction of writer rounds that delete a slice "
                         "of previously-inserted edges (exact "
                         "replacement-edge deletions, DESIGN.md §6.4); "
                         "0 disables the delete mix")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", metavar="PATH", default=None,
                    help="write the slo-report/v1 JSON here")
    ap.add_argument("--slo-p50-ms", type=float, default=250.0)
    ap.add_argument("--slo-p99-ms", type=float, default=2000.0)
    ap.add_argument("--max-drop-frac", type=float, default=0.2)
    ap.add_argument("--min-qps-frac", type=float, default=0.5,
                    help="achieved/offered QPS floor")
    return ap


def _env() -> dict:
    import jax

    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count(),
    }


def _arrival_schedule(rng, qps: float, duration: float) -> np.ndarray:
    """Poisson arrival offsets (seconds from start) within [0, duration)."""
    # E[count] = qps * duration; draw with slack, trim at the horizon.
    draw = max(16, int(qps * duration * 1.5) + 64)
    offs = np.cumsum(rng.exponential(1.0 / qps, size=draw))
    while offs[-1] < duration:  # pathological under-draw; extend
        offs = np.concatenate(
            [offs, offs[-1] + np.cumsum(rng.exponential(1.0 / qps, size=draw))]
        )
    return offs[offs < duration]


def run(args) -> dict:
    from repro import obs
    from repro.launch.serve_graph import edge_stream
    from repro.solve import SolveSpec, plan
    from repro.stream.service import MicroBatcher, QueryService, next_pow2

    obs.enable("metrics")
    obs.metrics_reset()

    n = 1 << args.scale
    lo, hi, w = edge_stream(args.scale, args.edge_factor, args.seed)
    rng = np.random.default_rng(args.seed)

    stream = plan(
        n, SolveSpec(mode="stream", batch_capacity=args.writer_batch)
    )
    # Seed the forest with the first quarter of the stream (chunked —
    # insert_batch rejects batches above capacity), leaving the rest for
    # the concurrent writer to churn through during the run.
    warm = max(args.writer_batch, len(lo) // 4)
    for at in range(0, warm, args.writer_batch):
        end = min(at + args.writer_batch, warm)
        stream.update(lo[at:end], hi[at:end], w[at:end])

    service = QueryService(stream.engine.snapshots)
    batcher = MicroBatcher(service, max_queue=args.micro_batch)
    # Pre-compile every padded query width the run can hit, so arrivals
    # never pay XLA compilation (that's plan-build cost, not serving SLO).
    pad = service.pad_floor
    while True:
        z = np.zeros(pad, np.int32)
        service.connected(z, z)
        if pad >= next_pow2(args.micro_batch, service.pad_floor):
            break
        pad *= 2

    hist = obs.histogram("loadgen.e2e_latency_s")
    dropped = obs.counter("loadgen.dropped")
    timeouts = obs.counter("loadgen.timeout")

    admission: queue.Queue = queue.Queue(maxsize=args.queue_cap)
    producer_done = threading.Event()
    stop_writer = threading.Event()
    writer_stats = {
        "updates": 0,
        "edges": 0,
        "deletes": 0,
        "edges_deleted": 0,
        "replacements": 0,
        "unhealed": 0,
    }

    offs = _arrival_schedule(rng, args.qps, args.duration)
    qu = rng.integers(0, n, size=len(offs))
    qv = rng.integers(0, n, size=len(offs))
    t_start = time.perf_counter()

    def producer() -> None:
        for i, off in enumerate(offs):
            lag = (t_start + off) - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            try:  # never blocks: open loop — overload shows up as drops
                admission.put_nowait((t_start + off, int(qu[i]), int(qv[i])))
            except queue.Full:
                dropped.inc()
        producer_done.set()

    def writer() -> None:
        pos = warm
        interval = args.writer_interval_ms / 1e3
        wrng = np.random.default_rng(args.seed + 1)
        while not stop_writer.is_set():
            if args.delete_frac > 0 and wrng.random() < args.delete_frac:
                # Delete-churn round: tombstone-and-heal a random slice
                # of the edges inserted so far (exact replacement-edge
                # deletions; re-inserting them later is an MSF no-op, so
                # the wrap-around keeps the graph statistically stable).
                at = int(wrng.integers(0, max(1, pos - args.writer_batch)))
                end = min(at + max(1, args.writer_batch // 4), pos)
                rep = stream.delete(lo[at:end], hi[at:end])
                writer_stats["deletes"] += 1
                writer_stats["edges_deleted"] += end - at
                if rep.raw is not None:
                    writer_stats["replacements"] += rep.raw.n_replacements
                writer_stats["unhealed"] = rep.n_unhealed
            else:
                if pos >= len(lo):
                    pos = warm  # wrap; duplicate inserts are MSF no-ops
                end = min(pos + args.writer_batch, len(lo))
                stream.update(lo[pos:end], hi[pos:end], w[pos:end])
                writer_stats["updates"] += 1
                writer_stats["edges"] += end - pos
                pos = end
            stop_writer.wait(interval)

    answered = 0
    pending: list[float] = []  # scheduled arrival times of the open window

    def flush_window() -> None:
        nonlocal answered
        if not pending:
            return
        batcher.flush()  # idempotent after a MicroBatcher auto-flush
        t_now = time.perf_counter()
        for t_arr in pending:
            lat = t_now - t_arr
            hist.observe(lat)
            if lat > args.timeout_ms / 1e3:
                timeouts.inc()
        answered += len(pending)
        pending.clear()

    threads = [threading.Thread(target=producer, daemon=True),
               threading.Thread(target=writer, daemon=True)]
    for t in threads:
        t.start()
    while True:
        try:
            t_arr, u, v = admission.get(timeout=0.02)
        except queue.Empty:
            flush_window()  # partial window: bound tail latency
            if producer_done.is_set() and admission.empty():
                break
            continue
        batcher.ask_connected(u, v)
        pending.append(t_arr)
        if len(pending) >= args.micro_batch:
            flush_window()
    flush_window()
    elapsed = time.perf_counter() - t_start
    stop_writer.set()
    for t in threads:
        t.join(timeout=10.0)

    s = hist.summary() or {}
    snap = obs.metrics_snapshot()
    n_dropped = int(snap["counters"].get("loadgen.dropped", 0))
    n_timeout = int(snap["counters"].get("loadgen.timeout", 0))
    offered = len(offs)
    achieved_qps = answered / elapsed if elapsed > 0 else 0.0
    drop_frac = n_dropped / offered if offered else 0.0

    p50_ms = float(s.get("p50", 0.0)) * 1e3
    p99_ms = float(s.get("p99", 0.0)) * 1e3
    failures: list[str] = []
    if p50_ms > args.slo_p50_ms:
        failures.append(f"p50 {p50_ms:.1f}ms > target {args.slo_p50_ms}ms")
    if p99_ms > args.slo_p99_ms:
        failures.append(f"p99 {p99_ms:.1f}ms > target {args.slo_p99_ms}ms")
    if drop_frac > args.max_drop_frac:
        failures.append(
            f"drop fraction {drop_frac:.3f} > target {args.max_drop_frac}"
        )
    if achieved_qps < args.min_qps_frac * args.qps:
        failures.append(
            f"achieved {achieved_qps:.1f} qps < "
            f"{args.min_qps_frac:.2f} x offered {args.qps}"
        )

    batcher_metrics = {
        k.removeprefix("stream.batcher."): v
        for k, v in snap["counters"].items()
        if k.startswith("stream.batcher.")
    }
    batcher_metrics["queue_depth"] = snap["gauges"].get(
        "stream.batcher.queue_depth", 0
    )
    return {
        "schema": SCHEMA,
        "env": _env(),
        "config": {k: v for k, v in vars(args).items() if k != "out"},
        "offered_qps": args.qps,
        "achieved_qps": achieved_qps,
        "duration_s": elapsed,
        "queries": {
            "offered": offered,
            "answered": answered,
            "dropped": n_dropped,
            "timeouts": n_timeout,
        },
        "latency_ms": {
            "p50": p50_ms,
            "p95": float(s.get("p95", 0.0)) * 1e3,
            "p99": p99_ms,
            "min": float(s.get("min", 0.0)) * 1e3,
            "max": float(s.get("max", 0.0)) * 1e3,
            "mean": (float(s["sum"]) / s["count"] * 1e3) if s.get("count")
            else 0.0,
            "count": int(s.get("count", 0)),
        },
        "writer": {
            "updates": writer_stats["updates"],
            "edges_inserted": writer_stats["edges"],
            "deletes": writer_stats["deletes"],
            "edges_deleted": writer_stats["edges_deleted"],
            "replacements": writer_stats["replacements"],
            "unhealed": writer_stats["unhealed"],
            "snapshot_version": service.snapshot_version(),
        },
        "batcher": batcher_metrics,
        "slo": {
            "targets": {
                "p50_ms": args.slo_p50_ms,
                "p99_ms": args.slo_p99_ms,
                "max_drop_frac": args.max_drop_frac,
                "min_qps_frac": args.min_qps_frac,
            },
            "failures": failures,
            "passed": not failures,
        },
    }


def run_tcp(args) -> dict:
    """Open-loop load over the wire: drive a ``repro.serve`` server with
    pipelined ``serve/v1`` point queries (the server fuses them into
    micro-batches) while a writer connection churns inserts/deletes.

    Same three actors as :func:`run`, network-shaped: the **producer**
    walks the Poisson schedule and pipelines one ``connected`` request
    per arrival — admission is bounded by ``--max-inflight`` outstanding
    futures and arrivals past the bound *drop* (open loop, never
    blocks); a completion callback records end-to-end latency (scheduled
    arrival → response decoded) and in-band rejections (``overloaded`` /
    ``deadline`` from the server's own admission control). The
    **writer** uses a second socket so write frames never head-of-line
    block the pipelined query stream.
    """
    from repro import obs
    from repro.launch.serve_graph import edge_stream
    from repro.serve import ServeClient

    obs.enable("metrics")
    obs.metrics_reset()

    qc = ServeClient(args.target)  # pipelined query connection
    wc = ServeClient(args.target)  # writer connection (own socket)
    try:
        return _run_tcp(args, qc, wc, obs, edge_stream)
    finally:
        qc.close()
        wc.close()


def _run_tcp(args, qc, wc, obs, edge_stream) -> dict:
    status0 = qc.status(check=True)["result"]
    n = int(status0["n"])
    if n != 1 << args.scale:
        raise SystemExit(
            f"server has n={n} but --scale {args.scale} implies "
            f"n={1 << args.scale}; match the server's --scale"
        )
    lo, hi, w = edge_stream(args.scale, args.edge_factor, args.seed)
    warm = int(len(lo) * args.warm_frac)

    hist = obs.histogram("loadgen.e2e_latency_s")
    dropped = obs.counter("loadgen.dropped")
    timeouts = obs.counter("loadgen.timeout")

    rng = np.random.default_rng(args.seed)
    offs = _arrival_schedule(rng, args.qps, args.duration)
    qu = rng.integers(0, n, size=len(offs))
    qv = rng.integers(0, n, size=len(offs))

    inflight = threading.Semaphore(args.max_inflight)
    done = threading.Event()
    lock = threading.Lock()
    stats = {"answered": 0, "rejected": 0, "errors": 0, "max_version": -1}
    outstanding = [0]

    def on_response(fut, t_arr: float) -> None:
        t_now = time.perf_counter()
        inflight.release()
        with lock:
            outstanding[0] -= 1
            if outstanding[0] == 0:
                done.set()
            try:
                resp = fut.result()
            except Exception:
                stats["errors"] += 1
                return
            if resp.get("ok"):
                stats["answered"] += 1
                stats["max_version"] = max(
                    stats["max_version"], resp.get("snapshot_version", -1)
                )
                lat = t_now - t_arr
                hist.observe(lat)
                if lat > args.timeout_ms / 1e3:
                    timeouts.inc()
            else:
                # the server's admission control said no — that's a drop
                # from the SLO's point of view, tracked separately
                stats["rejected"] += 1
                code = (resp.get("error") or {}).get("code", "unknown")
                obs.counter(f"loadgen.rejected.{code}").inc()

    stop_writer = threading.Event()
    writer_stats = {
        "updates": 0, "edges": 0, "deletes": 0, "edges_deleted": 0,
        "replacements": 0, "unhealed": 0, "write_rejected": 0,
    }

    def writer() -> None:
        pos = warm
        interval = args.writer_interval_ms / 1e3
        wrng = np.random.default_rng(args.seed + 1)
        while not stop_writer.is_set():
            try:
                if args.delete_frac > 0 and wrng.random() < args.delete_frac:
                    at = int(wrng.integers(0, max(1, pos - args.writer_batch)))
                    end = min(at + max(1, args.writer_batch // 4), pos)
                    resp = wc.delete(lo[at:end], hi[at:end])
                    if resp.get("ok"):
                        r = resp["result"]
                        writer_stats["deletes"] += 1
                        writer_stats["edges_deleted"] += end - at
                        writer_stats["replacements"] += r["n_replacements"]
                        writer_stats["unhealed"] = r["n_unhealed_new"]
                    else:
                        writer_stats["write_rejected"] += 1
                else:
                    if pos >= len(lo):
                        pos = warm  # wrap; duplicate inserts are MSF no-ops
                    end = min(pos + args.writer_batch, len(lo))
                    resp = wc.insert(lo[pos:end], hi[pos:end], w[pos:end])
                    if resp.get("ok"):
                        writer_stats["updates"] += 1
                        writer_stats["edges"] += end - pos
                        pos = end
                    else:
                        writer_stats["write_rejected"] += 1
            except (ConnectionError, OSError):
                return  # server went away; the SLO gate will say so
            stop_writer.wait(interval)

    t_start = time.perf_counter()
    wt = threading.Thread(target=writer, daemon=True)
    wt.start()
    for i, off in enumerate(offs):
        lag = (t_start + off) - time.perf_counter()
        if lag > 0:
            time.sleep(lag)
        if not inflight.acquire(blocking=False):
            dropped.inc()  # admission bound hit: open-loop drop
            continue
        t_arr = t_start + off
        with lock:
            outstanding[0] += 1
            done.clear()
        try:
            fut = qc.submit("connected", u=[int(qu[i])], v=[int(qv[i])],
                            deadline_ms=args.timeout_ms)
        except (ConnectionError, OSError):
            inflight.release()
            with lock:
                outstanding[0] -= 1
                stats["errors"] += 1
            break
        fut.add_done_callback(lambda f, t=t_arr: on_response(f, t))
    # drain the pipeline: every submitted query gets its callback
    with lock:
        all_done = outstanding[0] == 0
    if not all_done:
        done.wait(timeout=max(10.0, 4 * args.timeout_ms / 1e3))
    elapsed = time.perf_counter() - t_start
    stop_writer.set()
    wt.join(timeout=10.0)

    try:
        server_status = qc.status(check=True)["result"]
        server_metrics = qc.metrics(check=True)["result"]["metrics"]
    except Exception:
        server_status, server_metrics = {}, {}

    s = hist.summary() or {}
    snap = obs.metrics_snapshot()
    n_dropped = int(snap["counters"].get("loadgen.dropped", 0))
    n_timeout = int(snap["counters"].get("loadgen.timeout", 0))
    offered = len(offs)
    answered = stats["answered"]
    achieved_qps = answered / elapsed if elapsed > 0 else 0.0
    # server-side rejections are unanswered offered load, same as drops
    drop_frac = ((n_dropped + stats["rejected"] + stats["errors"]) / offered
                 if offered else 0.0)

    p50_ms = float(s.get("p50", 0.0)) * 1e3
    p99_ms = float(s.get("p99", 0.0)) * 1e3
    failures: list[str] = []
    if answered == 0:
        failures.append("no queries answered")
    if p50_ms > args.slo_p50_ms:
        failures.append(f"p50 {p50_ms:.1f}ms > target {args.slo_p50_ms}ms")
    if p99_ms > args.slo_p99_ms:
        failures.append(f"p99 {p99_ms:.1f}ms > target {args.slo_p99_ms}ms")
    if drop_frac > args.max_drop_frac:
        failures.append(
            f"drop fraction {drop_frac:.3f} > target {args.max_drop_frac}"
        )
    if achieved_qps < args.min_qps_frac * args.qps:
        failures.append(
            f"achieved {achieved_qps:.1f} qps < "
            f"{args.min_qps_frac:.2f} x offered {args.qps}"
        )

    return {
        "schema": SCHEMA,
        "env": _env(),
        "config": {k: v for k, v in vars(args).items() if k != "out"},
        "offered_qps": args.qps,
        "achieved_qps": achieved_qps,
        "duration_s": elapsed,
        "queries": {
            "offered": offered,
            "answered": answered,
            "dropped": n_dropped,
            "rejected": stats["rejected"],
            "errors": stats["errors"],
            "timeouts": n_timeout,
        },
        "latency_ms": {
            "p50": p50_ms,
            "p95": float(s.get("p95", 0.0)) * 1e3,
            "p99": p99_ms,
            "min": float(s.get("min", 0.0)) * 1e3,
            "max": float(s.get("max", 0.0)) * 1e3,
            "mean": (float(s["sum"]) / s["count"] * 1e3) if s.get("count")
            else 0.0,
            "count": int(s.get("count", 0)),
        },
        "writer": {
            "updates": writer_stats["updates"],
            "edges_inserted": writer_stats["edges"],
            "deletes": writer_stats["deletes"],
            "edges_deleted": writer_stats["edges_deleted"],
            "replacements": writer_stats["replacements"],
            "unhealed": writer_stats["unhealed"],
            "write_rejected": writer_stats["write_rejected"],
            "snapshot_version": stats["max_version"],
        },
        "server": {
            "target": args.target,
            "status": server_status,
            "metrics": {
                "counters": {
                    k: v for k, v in server_metrics.get("counters", {}).items()
                    if k.startswith("serve.")
                },
                "histograms": {
                    k: v
                    for k, v in server_metrics.get("histograms", {}).items()
                    if k.startswith("serve.")
                },
            },
        },
        "slo": {
            "targets": {
                "p50_ms": args.slo_p50_ms,
                "p99_ms": args.slo_p99_ms,
                "max_drop_frac": args.max_drop_frac,
                "min_qps_frac": args.min_qps_frac,
            },
            "failures": failures,
            "passed": not failures,
        },
    }


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    report = run_tcp(args) if args.target else run(args)
    lat = report["latency_ms"]
    print(
        f"offered {report['offered_qps']:.0f} qps for "
        f"{report['duration_s']:.1f}s -> achieved "
        f"{report['achieved_qps']:.1f} qps; "
        f"p50={lat['p50']:.1f}ms p95={lat['p95']:.1f}ms "
        f"p99={lat['p99']:.1f}ms "
        f"(answered {report['queries']['answered']}, "
        f"dropped {report['queries']['dropped']}, "
        f"timeouts {report['queries']['timeouts']})"
    )
    print(
        f"writer: {report['writer']['updates']} updates, "
        f"{report['writer']['edges_inserted']} edges, "
        f"{report['writer']['deletes']} delete rounds "
        f"({report['writer']['edges_deleted']} edges, "
        f"{report['writer']['replacements']} replacements, "
        f"{report['writer']['unhealed']} unhealed), snapshot "
        f"v{report['writer']['snapshot_version']}; "
        + (f"batcher: {report['batcher']}" if "batcher" in report
           else f"server: {report['server']['metrics']['counters']}")
    )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"# slo report written to {args.out}")
    slo = report["slo"]
    if slo["passed"]:
        print("SLO: PASS")
        return 0
    print("SLO: FAIL")
    for msg in slo["failures"]:
        print(f"  {msg}")
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
