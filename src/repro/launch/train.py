"""End-to-end training driver with fault tolerance.

Examples (CPU container — smoke-sized configs):

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --steps 60
  PYTHONPATH=src python -m repro.launch.train --arch gat-cora --steps 100
  PYTHONPATH=src python -m repro.launch.train --arch xdeepfm --steps 100 --compress
  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b --steps 40 \
      --fault-at 25 --supervise   # injected crash + automatic restart

Fault tolerance: async checkpoints every ``--ckpt-every`` steps with atomic
DONE markers; ``--supervise`` wraps the run loop in a supervisor that
restarts from the latest complete checkpoint on any exception. The data
pipeline is step-keyed, so the restarted run consumes exactly the batches
the crashed run would have. A step-time watchdog flags straggler steps
(> mean + 4σ) — at real scale this feeds the reshard/elastic path.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


class FaultInjected(RuntimeError):
    pass


def build_training(arch: str, mesh, seed: int = 0, full: bool = False):
    """Returns (params, opt_state, step_fn(params, opt, step_idx) -> (params,
    opt, metrics)) for the smoke config of ``arch``."""
    import jax
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.data.pipeline import (
        LMBatchSource,
        MoleculeBatchSource,
        RecsysBatchSource,
        make_planted_graph_task,
    )
    from repro.models import gnn as G
    from repro.models import recsys as R
    from repro.models import transformer as T
    from repro.optim.adamw import adamw_init
    from repro.train import steps as S

    family = registry.family_of(arch)
    cfg = registry.get_config(arch, smoke=not full)
    key = jax.random.key(seed)

    if family == "lm":
        src = LMBatchSource(cfg.vocab, seq_len=64, batch=8, seed=seed)
        params = T.init_lm(key, cfg)

        def step_fn(params, opt, i):
            toks, labels = src.batch_at(i)
            return jitted(params, opt, jnp.asarray(toks), jnp.asarray(labels))

        jitted = jax.jit(
            lambda p, o, t, l: S.lm_train_step(p, o, t, l, cfg, mesh)
        )
    elif family == "gnn":
        import dataclasses

        if cfg.kind == "nequip":
            src = MoleculeBatchSource(n_atoms=12, n_edges=40, batch=16, seed=seed)
            params = G.init_nequip(key, cfg)
            n_graphs = 16

            def step_fn(params, opt, i):
                b = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
                return jitted(params, opt, b)

            jitted = jax.jit(lambda p, o, b: S.gnn_train_step(p, o, b, cfg, n_graphs))
        else:
            task = make_planted_graph_task(200, 800, cfg.d_in, max(cfg.n_classes, 1), seed)
            e = len(task["src"])
            n = len(task["x"])
            batch = dict(
                src=jnp.asarray(task["src"]), dst=jnp.asarray(task["dst"]),
                edge_valid=jnp.asarray(task["edge_valid"]),
                x=jnp.asarray(task["x"]),
                node_mask=jnp.ones(n, jnp.float32),
            )
            if cfg.kind == "meshgraphnet":
                rngx = np.random.default_rng(seed)
                batch["e_feat"] = jnp.asarray(rngx.standard_normal((e, 4)).astype(np.float32))
                w = rngx.standard_normal((cfg.d_in, cfg.d_out)).astype(np.float32)
                batch["targets"] = jnp.asarray(task["x"] @ w)
                params = G.init_meshgraphnet(key, cfg)
            elif cfg.kind == "gatedgcn":
                batch["e_feat"] = jnp.ones((e, 1), jnp.float32)
                batch["labels"] = jnp.asarray(task["labels"] % cfg.n_classes)
                params = G.init_gatedgcn(key, cfg)
            else:
                batch["labels"] = jnp.asarray(task["labels"] % cfg.n_classes)
                params = G.init_gat(key, cfg)

            def step_fn(params, opt, i):
                return jitted(params, opt, batch)

            jitted = jax.jit(lambda p, o, b: S.gnn_train_step(p, o, b, cfg, 1))
    elif family == "recsys":
        from repro.models.recsys import field_offsets

        offs, sizes = field_offsets(cfg)
        src = RecsysBatchSource(offs, sizes, batch=256, seed=seed)
        params = R.init_xdeepfm(key, cfg)

        def step_fn(params, opt, i):
            ids, labels = src.batch_at(i)
            return jitted(params, opt, jnp.asarray(ids), jnp.asarray(labels))

        jitted = jax.jit(lambda p, o, i_, l: S.recsys_train_step(p, o, i_, l, cfg))
    else:
        raise ValueError(family)

    opt = adamw_init(params)
    return params, opt, step_fn


def run(args) -> dict:
    import jax

    from repro.checkpoint import (
        latest_step, restore_checkpoint, save_checkpoint, wait_for_saves,
    )
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    params, opt, step_fn = build_training(args.arch, mesh, seed=args.seed)

    start = 0
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            state = restore_checkpoint(args.ckpt_dir, last, {"p": params, "o": opt})
            params, opt = state["p"], state["o"]
            start = last
            print(f"[restore] resumed from checkpoint step {last}")

    losses = []
    times = []
    for i in range(start, args.steps):
        t0 = time.time()
        if args.fault_at is not None and i == args.fault_at and not getattr(run, "_faulted", False):
            run._faulted = True
            raise FaultInjected(f"injected node failure at step {i}")
        params, opt, metrics = step_fn(params, opt, i)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        times.append(dt)
        losses.append(loss)
        # straggler watchdog: flag steps > mean + 4*std of the trailing window
        if len(times) > 10:
            w = np.array(times[-50:-1])
            if dt > w.mean() + 4 * w.std() + 1e-3:
                print(f"[watchdog] step {i} took {dt:.3f}s (window mean {w.mean():.3f}s) — straggler flagged")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, i + 1, {"p": params, "o": opt})
        if i % max(1, args.steps // 10) == 0:
            print(f"step {i:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")
    wait_for_saves()
    first = float(np.mean(losses[:5])) if len(losses) >= 5 else losses[0]
    last_l = float(np.mean(losses[-5:]))
    print(f"[done] loss {first:.4f} -> {last_l:.4f} over {len(losses)} executed steps")
    return dict(first_loss=first, last_loss=last_l, steps=len(losses))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fault-at", type=int, default=None)
    ap.add_argument("--supervise", action="store_true")
    args = ap.parse_args()

    if not args.supervise:
        run(args)
        return

    # supervisor: restart from latest checkpoint on failure (max 3 restarts)
    for attempt in range(4):
        try:
            run(args)
            return
        except FaultInjected as e:
            print(f"[supervisor] attempt {attempt}: {e}; restarting from latest checkpoint")
    raise RuntimeError("too many restarts")


if __name__ == "__main__":
    main()
