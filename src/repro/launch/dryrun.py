import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede any jax import (jax locks the device
count at first init); 512 placeholder host devices let ``jax.make_mesh``
build the production meshes. Run:

  PYTHONPATH=src python -m repro.launch.dryrun                    # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --msf
  PYTHONPATH=src python -m repro.launch.dryrun --variant triangle_skip=1

Per cell: ``.lower().compile()`` must succeed; prints
``memory_analysis()`` (fits?) and ``cost_analysis()`` (FLOPs/bytes), plus
the parsed collective bytes; writes a JSON artifact per cell under
``experiments/dryrun/`` for EXPERIMENTS.md §Dry-run and §Roofline.
"""
import argparse
import json
import time
import traceback


def parse_variant(s):
    out = {}
    if not s:
        return out
    for kv in s.split(","):
        k, v = kv.split("=")
        out[k] = int(v) if v.lstrip("-").isdigit() else v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--msf", action="store_true", help="also run MSF engine cells")
    ap.add_argument("--msf-only", action="store_true")
    ap.add_argument("--variant", default="", help="k=v,... perf-variant knobs")
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    import jax
    from repro.analysis.roofline import roofline
    from repro.configs import registry
    from repro.configs.base import MSF_SHAPES
    from repro.launch.cells import build_cell, build_msf_cell, lower_cell
    from repro.launch.mesh import make_production_mesh

    os.makedirs(args.outdir, exist_ok=True)
    meshes = {"single": make_production_mesh(multi_pod=False)}
    if args.mesh in ("multi", "both"):
        meshes["multi"] = make_production_mesh(multi_pod=True)
    if args.mesh == "multi":
        meshes.pop("single")

    cells = []
    if not args.msf_only:
        for arch, shape in registry.all_cells():
            if args.arch and arch != args.arch:
                continue
            if args.shape and shape != args.shape:
                continue
            cells.append(("arch", arch, shape))
    if args.msf or args.msf_only:
        for s in MSF_SHAPES:
            if args.shape and s.name != args.shape:
                continue
            cells.append(("msf", "msf-engine", s.name))

    variant = parse_variant(args.variant)
    n_ok = n_fail = 0
    for mesh_name, mesh in meshes.items():
        n_dev = mesh.size
        for kind, arch, shape in cells:
            cell_id = f"{arch}:{shape}@{mesh_name}" + (f"+{args.tag}" if args.tag else "")
            t0 = time.time()
            try:
                if kind == "msf":
                    scfg = next(s for s in MSF_SHAPES if s.name == shape)
                    cell = build_msf_cell(scfg, mesh, **{
                        k: v for k, v in variant.items() if k in ("shortcut", "capacity", "pack")
                    })
                else:
                    cell = build_cell(arch, shape, mesh, variant)
                lowered = lower_cell(cell)
                compiled = lowered.compile()
                mem = compiled.memory_analysis()
                rf = roofline(
                    compiled, n_devices=n_dev, model_flops=cell.meta.get("model_flops")
                )
                rec = dict(
                    cell=cell_id, arch=arch, shape=shape, mesh=mesh_name,
                    n_devices=n_dev, ok=True,
                    compile_s=round(time.time() - t0, 1),
                    meta={k: v for k, v in cell.meta.items() if k != "family"},
                    family=cell.meta.get("family"),
                    **rf,
                )
                print(
                    f"[OK ] {cell_id:48s} {rec['compile_s']:6.1f}s "
                    f"flops/dev={rf['flops_per_device']:.3e} "
                    f"bytes/dev={rf['bytes_per_device']:.3e} "
                    f"coll/dev={rf['collective_bytes_per_device']:.3e} "
                    f"dom={rf['dominant']} "
                    f"args={mem.argument_size_in_bytes/2**30:.2f}GiB "
                    f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB"
                )
                n_ok += 1
            except Exception as e:  # noqa: BLE001 — record and continue
                rec = dict(
                    cell=cell_id, arch=arch, shape=shape, mesh=mesh_name,
                    n_devices=n_dev, ok=False, error=f"{type(e).__name__}: {e}",
                    compile_s=round(time.time() - t0, 1),
                )
                print(f"[FAIL] {cell_id}: {type(e).__name__}: {str(e)[:300]}")
                traceback.print_exc(limit=4)
                n_fail += 1
            fname = cell_id.replace(":", "_").replace("@", "_").replace("+", "_")
            with open(os.path.join(args.outdir, fname + ".json"), "w") as f:
                json.dump(rec, f, indent=1, default=str)
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
