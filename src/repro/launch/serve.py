"""Batched LM serving demo: prefill a prompt batch, decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --tokens 16
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T
    from repro.train import steps as S

    assert registry.family_of(args.arch) == "lm", "serving demo is for LM archs"
    cfg = registry.get_config(args.arch, smoke=True)
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.key(0), cfg)
    toks = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )

    # serve with headroom for generated tokens
    cache_len = args.prompt_len + args.tokens
    pad = jnp.zeros((args.batch, args.tokens), jnp.int32)
    prefill = jax.jit(lambda p, t: S.lm_prefill_step(p, t, cfg, mesh))
    decode = jax.jit(lambda p, tok, c, pos: S.lm_decode_step(p, tok, c, pos, cfg, mesh))

    t0 = time.time()
    nxt, cache = prefill(params, toks)
    # right-pad the cache to full serving capacity (prefill emitted exactly
    # prompt_len entries; windowed archs already rolled)
    tcap = cache["k"].shape[2]
    want = cache_len if cfg.sliding_window is None else min(cache_len, cfg.sliding_window)
    if tcap < want:
        padw = want - tcap
        cache = {
            k: jnp.pad(v, ((0, 0), (0, 0), (0, padw), (0, 0), (0, 0)))
            for k, v in cache.items()
        }
    print(f"prefill: {args.batch}x{args.prompt_len} in {time.time()-t0:.2f}s")

    out = [nxt]
    t0 = time.time()
    for i in range(args.tokens - 1):
        pos = jnp.int32(args.prompt_len + i)
        nxt, cache = decode(params, out[-1], cache, pos)
        out.append(nxt)
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"decoded {args.tokens - 1} steps x batch {args.batch} in {dt:.2f}s "
          f"({(args.tokens - 1) * args.batch / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
