"""Dry-run cell programs: (arch × shape × mesh) → lowerable jit function.

``build_cell`` returns a ``Cell`` with the step function, abstract inputs
(``ShapeDtypeStruct`` — never allocated), and in/out shardings, following
the shannon/kernels pattern. ``input_specs`` for modality frontends are
stubs per the assignment (precomputed features), and GNN feature tensors
stand in for dataset arrays.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import GNNConfig, LMConfig, RecsysConfig, ShapeCell
from repro.graphs.sampler import max_sample_sizes
from repro.models import transformer as T
from repro.models import gnn as G
from repro.models import recsys as R
from repro.optim.adamw import adamw_init
from repro.train import steps


@dataclasses.dataclass
class Cell:
    name: str
    fn: Callable
    abstract_args: Tuple[Any, ...]
    in_shardings: Any
    out_shardings: Any
    meta: Dict[str, Any]
    mesh: Any = None


def _ns(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _dp(mesh):
    return T.dp_axis_names(mesh)


def _all_axes(mesh):
    return tuple(mesh.axis_names)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _opt_specs(param_specs):
    from repro.optim.adamw import AdamWState

    return AdamWState(mu=param_specs, nu=param_specs, step=P())


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch: str, cfg: LMConfig, shape: ShapeCell, mesh, variant: Dict) -> Cell:
    dp = _dp(mesh)
    if shape.kind in ("prefill", "decode"):
        # serving keeps no f32 master weights: bf16 params halve both the
        # weight-gather wire format and the HBM weight reads
        cfg = dataclasses.replace(
            cfg, param_dtype=variant.get("serve_param_dtype", "bfloat16")
        )
    pspecs = T.lm_param_specs(cfg, mesh)
    params_abs = jax.eval_shape(partial(T.init_lm, cfg=cfg), jax.random.key(0))
    b, s = shape.global_batch, shape.seq_len
    meta: Dict[str, Any] = dict(
        family="lm", params=cfg.param_count(), active_params=cfg.active_param_count(),
    )

    if shape.kind == "train":
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        tokens = _sds((b, s), jnp.int32)
        tskip = bool(variant.get("triangle_skip", cfg.triangle_skip))
        cfg_v = dataclasses.replace(
            cfg,
            vocab_chunk=variant.get("vocab_chunk", cfg.vocab_chunk),
            attn_q_chunk=variant.get("attn_q_chunk", cfg.attn_q_chunk),
            attn_kv_chunk=variant.get("attn_kv_chunk", cfg.attn_kv_chunk),
            remat=bool(variant.get("remat", cfg.remat)),
            fsdp=bool(variant.get("fsdp", cfg.fsdp)),
            grad_accum=int(variant.get("grad_accum", cfg.grad_accum)),
        )
        pspecs = T.lm_param_specs(cfg_v, mesh)

        def fn(params, opt, toks, labels):
            loss, grads = steps.lm_loss_and_grad(
                params, toks, labels, cfg_v, mesh, triangle_skip=tskip
            )
            params, opt, gnorm, _ = steps._apply_opt(params, opt, grads, opt.step)
            return params, opt, {"loss": loss, "gnorm": gnorm}

        meta["model_flops"] = 6 * cfg.active_param_count() * b * s
        return Cell(
            name=f"{arch}:{shape.name}",
            fn=fn,
            abstract_args=(params_abs, opt_abs, tokens, tokens),
            in_shardings=(pspecs, _opt_specs(pspecs), P(dp, None), P(dp, None)),
            out_shardings=(pspecs, _opt_specs(pspecs), P()),
            meta=meta,
        )

    if shape.kind == "prefill":
        tokens = _sds((b, s), jnp.int32)

        def fn(params, toks):
            return steps.lm_prefill_step(params, toks, cfg, mesh)

        meta["model_flops"] = 2 * cfg.active_param_count() * b * s
        return Cell(
            name=f"{arch}:{shape.name}",
            fn=fn,
            abstract_args=(params_abs, tokens),
            in_shardings=(pspecs, P(dp, None)),
            out_shardings=(P(dp), _cache_spec_tree(cfg, mesh, b)),
            meta=meta,
        )

    if shape.kind == "decode":
        cache_abs = T.cache_shape(cfg, b, s)
        cspec = _cache_spec_tree(cfg, mesh, b)
        token = _sds((b,), jnp.int32)

        def fn(params, tok, cache):
            pos = jnp.int32(s - 1)
            return steps.lm_decode_step(params, tok, cache, pos, cfg, mesh)

        meta["model_flops"] = 2 * cfg.active_param_count() * b
        tok_spec = P(dp) if b % max(T.dp_size(mesh), 1) == 0 and T.dp_size(mesh) > 1 else P()
        return Cell(
            name=f"{arch}:{shape.name}",
            fn=fn,
            abstract_args=(params_abs, token, cache_abs),
            in_shardings=(pspecs, tok_spec, cspec),
            out_shardings=(tok_spec, cspec),
            meta=meta,
        )
    raise ValueError(shape.kind)


def _cache_spec_tree(cfg, mesh, batch):
    return T.cache_specs(cfg, mesh, batch)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_batch_abstract(cfg: GNNConfig, shape: ShapeCell, mesh):
    """Abstract batch arrays for a GNN shape cell (directed edge count =
    2× undirected for the dataset-style cells). Node/edge counts are padded
    to a mesh-divisible size — exactly what the real pipeline's padding
    does — so ``jit in_shardings`` divisibility holds."""
    if shape.name == "minibatch_lg":
        n, e = max_sample_sizes(shape.batch_nodes, shape.fanout)
        d_in = shape.d_feat
        n_graphs = 1
    elif shape.name == "molecule":
        n = shape.n_nodes * shape.batch_graphs
        e = shape.n_edges * shape.batch_graphs
        d_in = shape.d_feat
        n_graphs = shape.batch_graphs
    else:
        n, e = shape.n_nodes, 2 * shape.n_edges
        d_in = shape.d_feat
        n_graphs = 1
    p = mesh.size
    n = -(-n // p) * p
    e = -(-e // p) * p

    batch: Dict[str, Any] = dict(
        src=_sds((e,), jnp.int32),
        dst=_sds((e,), jnp.int32),
        edge_valid=_sds((e,), jnp.bool_),
    )
    if cfg.kind == "nequip":
        batch.update(
            species=_sds((n,), jnp.int32),
            pos=_sds((n, 3), jnp.float32),
            graph_ids=_sds((n,), jnp.int32),
            energy=_sds((n_graphs,), jnp.float32),
        )
    else:
        batch["x"] = _sds((n, d_in), jnp.float32)
        if cfg.kind in ("meshgraphnet", "gatedgcn"):
            d_e = 4 if cfg.kind == "meshgraphnet" else 1
            batch["e_feat"] = _sds((e, d_e), jnp.float32)
        if cfg.n_classes:
            batch["labels"] = _sds((n,), jnp.int32)
            batch["node_mask"] = _sds((n,), jnp.float32)
        else:
            batch["targets"] = _sds((n, cfg.d_out), jnp.float32)
            batch["node_mask"] = _sds((n,), jnp.float32)
    return batch, d_in, n_graphs


def _gnn_cell(arch: str, cfg: GNNConfig, shape: ShapeCell, mesh, variant: Dict) -> Cell:
    batch_abs, d_in, n_graphs = _gnn_batch_abstract(cfg, shape, mesh)
    cfg = dataclasses.replace(cfg, d_in=d_in or cfg.d_in)
    flat = _all_axes(mesh)

    if cfg.kind == "nequip":
        params_abs = jax.eval_shape(partial(G.init_nequip, cfg=cfg), jax.random.key(0))
    elif cfg.kind == "gat":
        params_abs = jax.eval_shape(partial(G.init_gat, cfg=cfg), jax.random.key(0))
    elif cfg.kind == "meshgraphnet":
        params_abs = jax.eval_shape(partial(G.init_meshgraphnet, cfg=cfg), jax.random.key(0))
    else:
        params_abs = jax.eval_shape(partial(G.init_gatedgcn, cfg=cfg), jax.random.key(0))
    opt_abs = jax.eval_shape(adamw_init, params_abs)
    pspecs = jax.tree.map(lambda _: P(), params_abs)

    # nodes/edges shard over the flattened mesh (1D edge partition; the 2D
    # multilinear schedule is the §Perf variant for ogb_products).
    def bspec(k, v):
        if v.ndim == 0:
            return P()
        return P(flat, *([None] * (v.ndim - 1)))

    bspecs = {k: bspec(k, v) for k, v in batch_abs.items()}
    if "energy" in bspecs:
        bspecs["energy"] = P()

    def fn(params, opt, batch):
        return steps.gnn_train_step(params, opt, batch, cfg, n_graphs)

    # per-edge analytic flops (fwd+bwd ≈ 3×fwd)
    e = batch_abs["src"].shape[0]
    n = (batch_abs.get("x") or batch_abs["species"]).shape[0]
    h = cfg.d_hidden
    if cfg.kind == "gat":
        mf = 3 * (2 * n * cfg.d_in * h * cfg.n_heads + 6 * e * h * cfg.n_heads)
    elif cfg.kind == "meshgraphnet":
        mf = 3 * cfg.n_layers * (2 * (3 * h) * h * e * 2 + 2 * (2 * h) * h * n * 2)
    elif cfg.kind == "gatedgcn":
        mf = 3 * cfg.n_layers * (2 * 5 * h * h * (2 * e + 3 * n))
    else:
        paths = len(G._nequip_paths(cfg.l_max))
        mf = 3 * cfg.n_layers * e * paths * h * 75  # CG contraction dominated
    return Cell(
        name=f"{arch}:{shape.name}",
        fn=fn,
        abstract_args=(params_abs, opt_abs, batch_abs),
        in_shardings=(pspecs, _opt_specs(pspecs), bspecs),
        out_shardings=(pspecs, _opt_specs(pspecs), P()),
        meta=dict(family="gnn", model_flops=mf, n_nodes=n, n_edges=e),
    )


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------

def _recsys_cell(arch: str, cfg: RecsysConfig, shape: ShapeCell, mesh, variant: Dict) -> Cell:
    dp = _dp(mesh)
    flat = _all_axes(mesh)
    f = cfg.n_sparse
    dsz = max(T.dp_size(mesh), 1)

    if shape.kind == "retrieval":
        # pad the candidate set to a mesh-divisible size (what the real
        # index-build does)
        n_cand = -(-shape.n_candidates // mesh.size) * mesh.size
        params_abs = jax.eval_shape(
            partial(R.init_retrieval, cfg=cfg, n_candidates=n_cand),
            jax.random.key(0),
        )
        pspecs = {"table": P("model", None), "tower_w": P(), "items": P(flat, None)}
        ids = _sds((shape.batch, f), jnp.int32)

        def fn(params, ids):
            return steps.recsys_retrieval_step(params, ids, cfg)

        return Cell(
            name=f"{arch}:{shape.name}",
            fn=fn,
            abstract_args=(params_abs, ids),
            in_shardings=(pspecs, P()),
            out_shardings=P(),
            meta=dict(
                family="recsys",
                model_flops=2 * shape.n_candidates * cfg.retrieval_dim * shape.batch,
            ),
        )

    params_abs = jax.eval_shape(partial(R.init_xdeepfm, cfg=cfg), jax.random.key(0))
    pspecs = jax.tree.map(lambda _: P(), params_abs)
    pspecs["table"] = P("model", None)
    pspecs["lin_table"] = P("model", None)
    b = shape.batch
    ids = _sds((b, f), jnp.int32)
    bspec = P(dp, None) if b % dsz == 0 and dsz > 1 else P()
    d = cfg.embed_dim
    cin_f = 0
    h_prev = f
    for hh in cfg.cin_layers:
        cin_f += 2 * h_prev * f * hh * d
        h_prev = hh
    mlp_f = 0
    dims = [f * d] + list(cfg.mlp_layers) + [1]
    for a_, b_ in zip(dims[:-1], dims[1:]):
        mlp_f += 2 * a_ * b_
    fwd = b * (cin_f + mlp_f)

    if shape.kind == "train":
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        labels = _sds((b,), jnp.float32)
        lspec = P(dp) if b % dsz == 0 and dsz > 1 else P()

        def fn(params, opt, ids, labels):
            return steps.recsys_train_step(params, opt, ids, labels, cfg)

        return Cell(
            name=f"{arch}:{shape.name}",
            fn=fn,
            abstract_args=(params_abs, opt_abs, ids, labels),
            in_shardings=(pspecs, _opt_specs(pspecs), bspec, lspec),
            out_shardings=(pspecs, _opt_specs(pspecs), P()),
            meta=dict(family="recsys", model_flops=3 * fwd),
        )

    def fn(params, ids):
        return steps.recsys_serve_step(params, ids, cfg)

    lspec = P(dp) if b % dsz == 0 and dsz > 1 else P()
    return Cell(
        name=f"{arch}:{shape.name}",
        fn=fn,
        abstract_args=(params_abs, ids),
        in_shardings=(pspecs, bspec),
        out_shardings=lspec,
        meta=dict(family="recsys", model_flops=fwd),
    )


# ---------------------------------------------------------------------------
# MSF engine cells (the paper's own system on the production mesh)
# ---------------------------------------------------------------------------

def build_msf_cell(shape: ShapeCell, mesh, *, shortcut="csp", capacity=1 << 20, pack=0) -> Cell:
    from repro.core.msf_dist import build_dist_driver
    from repro.graphs.partition import Partition2D, pad_n

    axes = mesh.axis_names
    if "pod" in axes:
        row_axis: Any = ("pod", "data")
        rows = mesh.shape["pod"] * mesh.shape["data"]
    else:
        row_axis = "data"
        rows = mesh.shape["data"]
    cols = mesh.shape["model"]
    n = shape.n_nodes
    m_dir = 2 * shape.n_edges
    n_pad, S = pad_n(n, rows, cols)
    e_max = -(-m_dir // (rows * cols))
    part = Partition2D(
        src_row=None, dst_col=None, w=None, eid=None, valid=None,
        rows=rows, cols=cols, shard_size=S, n=n, n_pad=n_pad,
    )
    driver = build_dist_driver(
        part, mesh, row_axis=row_axis, col_axis="model",
        shortcut=shortcut, capacity=capacity, pack=bool(pack),
    )
    shp = (rows, cols, e_max)
    args = (
        _sds(shp, jnp.int32), _sds(shp, jnp.int32), _sds(shp, jnp.float32),
        _sds(shp, jnp.int32), _sds(shp, jnp.bool_),
    )
    espec = P(row_axis, "model", None)
    return Cell(
        name=f"msf-engine:{shape.name}",
        fn=driver,
        abstract_args=args,
        in_shardings=(espec,) * 5,
        out_shardings=None,  # driver is already jitted with internal specs
        mesh=mesh,
        meta=dict(
            family="msf", n=n, m=shape.n_edges,
            # per AS iteration: ~1 flop-ish comparison per directed edge; use
            # 5 ops/edge × log2(n) iterations as the useful-work proxy
            model_flops=5 * m_dir * max(int(np.log2(max(n, 2))), 1),
        ),
    )


# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh, variant: Optional[Dict] = None) -> Cell:
    variant = variant or {}
    family = registry.family_of(arch)
    cfg = registry.get_config(arch)
    shape = registry.get_shape(arch, shape_name)
    if family == "lm":
        cell = _lm_cell(arch, cfg, shape, mesh, variant)
    elif family == "gnn":
        cell = _gnn_cell(arch, cfg, shape, mesh, variant)
    elif family == "recsys":
        cell = _recsys_cell(arch, cfg, shape, mesh, variant)
    else:
        raise ValueError(family)
    cell.mesh = mesh
    return cell


def lower_cell(cell: Cell):
    if cell.out_shardings is None:
        return cell.fn.lower(*cell.abstract_args)  # already jitted (msf driver)
    jitted = jax.jit(
        cell.fn,
        in_shardings=_ns(cell.mesh, cell.in_shardings),
        out_shardings=_ns(cell.mesh, cell.out_shardings),
    )
    return jitted.lower(*cell.abstract_args)
