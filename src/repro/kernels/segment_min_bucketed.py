"""Pallas TPU kernel: bucketed packed-key segment-min (sparse MSF path).

TPU adaptation of the paper's sparse multilinear kernel: TPUs have no
vectorized scatter, so instead of CRCW min-writes we pre-bucket edges by
output row block (host side, part of graph partitioning) and reduce each
bucket with a compare-broadcast-min over an (BI, BE) VMEM tile:

    out[r] = min over bucket edges e { keys[e] : rows[e] == r }

Keys are the pack32 layout (weight << 24 | idx) from ``repro.core.semiring``
— a single uint32 min implements the full MINWEIGHT monoid in the paper's
integer-weight regime. Identity/padding = 0xFFFFFFFF.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

UMAX = np.uint32(0xFFFFFFFF)


def _kernel(keys_ref, rows_ref, out_ref, *, block_rows, block_edges):
    keys = keys_ref[0, :]  # [BE] uint32
    rows = rows_ref[0, :]  # [BE] int32 in [0, block_rows)
    r = jax.lax.broadcasted_iota(jnp.int32, (block_rows, block_edges), 0)
    eq = rows[None, :] == r
    vals = jnp.where(eq, keys[None, :], UMAX)
    out_ref[...] = jnp.min(vals, axis=1)


def segment_min_bucketed_pallas(
    keys: jax.Array,
    rows: jax.Array,
    *,
    block_rows: int = 128,
    interpret: bool = False,
):
    """keys uint32 [NB, BE]; rows int32 [NB, BE] (local row in the bucket's
    block). Returns uint32 [NB * block_rows]."""
    nb, be = keys.shape
    kernel = functools.partial(_kernel, block_rows=block_rows, block_edges=be)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, be), lambda b: (b, 0)),
            pl.BlockSpec((1, be), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows,), jnp.uint32),
        interpret=interpret,
    )(keys, rows)
