"""Pallas TPU kernels: packed-key segment-min (sparse MSF path).

TPU adaptation of the paper's sparse multilinear kernel: TPUs have no
vectorized scatter, so instead of CRCW min-writes we reduce with a
compare-broadcast-min over (BI, BE) VMEM tiles:

    out[r] = min over edges e { keys[e] : seg[e] == r }

Keys are the pack32 layout (weight << 24 | idx) from ``repro.core.semiring``
— a single uint32 min implements the full MINWEIGHT monoid in the paper's
integer-weight regime. Identity/padding = 0xFFFFFFFF.

Two layouts:

- ``segment_min_bucketed_pallas`` — edges pre-bucketed by output row block
  (host side, part of graph partitioning); one grid step per bucket.
- ``segment_min_flat_pallas``     — flat [E] edge arrays with arbitrary
  (possibly unsorted) segment ids, as produced *inside* jit by the MSF
  hook loop and the coarsening dedupe; grid = (row blocks, edge blocks),
  the row block's output tile stays resident in VMEM and accumulates the
  min across the sequential edge-block dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

UMAX = np.uint32(0xFFFFFFFF)


def _kernel(keys_ref, rows_ref, out_ref, *, block_rows, block_edges):
    keys = keys_ref[0, :]  # [BE] uint32
    rows = rows_ref[0, :]  # [BE] int32 in [0, block_rows)
    r = jax.lax.broadcasted_iota(jnp.int32, (block_rows, block_edges), 0)
    eq = rows[None, :] == r
    vals = jnp.where(eq, keys[None, :], UMAX)
    out_ref[...] = jnp.min(vals, axis=1)


def _validate_blocked(keys, rows, block_rows: int) -> None:
    """Shared shape/dtype validation — loud errors instead of silent wrong
    shapes (a mis-sized bucket used to produce garbage rows)."""
    if keys.shape != rows.shape:
        raise ValueError(
            f"keys/rows shape mismatch: {keys.shape} vs {rows.shape}"
        )
    if keys.dtype != jnp.uint32:
        raise ValueError(f"keys must be uint32 (pack32 layout), got {keys.dtype}")
    if rows.dtype != jnp.int32:
        raise ValueError(f"rows must be int32, got {rows.dtype}")
    if block_rows <= 0 or block_rows % 8:
        raise ValueError(
            f"block_rows must be a positive multiple of 8 (TPU sublane), "
            f"got {block_rows}"
        )


def segment_min_bucketed_pallas(
    keys: jax.Array,
    rows: jax.Array,
    *,
    block_rows: int = 128,
    interpret: bool = False,
):
    """keys uint32 [NB, BE]; rows int32 [NB, BE] (local row in the bucket's
    block). Returns uint32 [NB * block_rows]."""
    _validate_blocked(keys, rows, block_rows)
    if keys.ndim != 2:
        raise ValueError(f"expected [NB, BE] bucketed layout, got {keys.shape}")
    nb, be = keys.shape
    if nb == 0 or be == 0:
        raise ValueError(
            f"empty bucket layout {keys.shape}; pad each bucket to >= 128 "
            f"lanes (see kernels.ops.bucket_edges_by_row_block)"
        )
    if be % 128:
        raise ValueError(f"bucket edge dim {be} must be a multiple of 128 lanes")
    kernel = functools.partial(_kernel, block_rows=block_rows, block_edges=be)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, be), lambda b: (b, 0)),
            pl.BlockSpec((1, be), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows,), jnp.uint32),
        interpret=interpret,
    )(keys, rows)


def _flat_kernel(keys_ref, segs_ref, out_ref, *, block_rows, block_edges):
    rb = pl.program_id(0)
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = jnp.full((block_rows,), UMAX, jnp.uint32)

    keys = keys_ref[0, :]  # [BE] uint32
    segs = segs_ref[0, :]  # [BE] int32, *global* segment ids
    local = segs - rb * block_rows
    r = jax.lax.broadcasted_iota(jnp.int32, (block_rows, block_edges), 0)
    eq = local[None, :] == r
    vals = jnp.where(eq, keys[None, :], UMAX)
    out_ref[...] = jnp.minimum(out_ref[...], jnp.min(vals, axis=1))


def segment_min_flat_pallas(
    keys: jax.Array,
    segs: jax.Array,
    *,
    num_segments: int,
    block_rows: int = 128,
    block_edges: int = 512,
    interpret: bool = False,
):
    """Flat-layout packed segment-min: keys uint32 [E], segs int32 [E] with
    values in [0, num_segments). Returns uint32 [num_segments].

    The output row block is revisited across the (sequential) edge-block
    grid dimension and accumulates with ``min`` — the TPU-legal stand-in
    for a CRCW min-write. Cost is O(num_segments / block_rows × E) lane
    compares; callers with a host-side bucketing opportunity should prefer
    ``segment_min_bucketed_pallas``.
    """
    _validate_blocked(keys, segs, block_rows)
    if keys.ndim != 1:
        raise ValueError(f"expected flat [E] layout, got {keys.shape}")
    # Stricter than the %8 of _validate_blocked: both the edge tile's
    # last dim and the 1-D output tile land on TPU lanes — enforce the
    # 128 multiple here rather than deep inside Mosaic compilation.
    if block_edges % 128:
        raise ValueError(f"block_edges={block_edges} must be a multiple of 128 lanes")
    if block_rows % 128:
        raise ValueError(
            f"block_rows={block_rows} must be a multiple of 128 (1-D output tile)"
        )
    e = keys.shape[0]
    if e == 0:
        raise ValueError("empty edge array; pad to >= one block of edges")
    if e % block_edges:
        raise ValueError(
            f"edge count {e} must be a multiple of block_edges={block_edges} "
            f"(pad with identity keys)"
        )
    if num_segments <= 0 or num_segments % block_rows:
        raise ValueError(
            f"num_segments={num_segments} must be a positive multiple of "
            f"block_rows={block_rows} (pad the output)"
        )
    kernel = functools.partial(
        _flat_kernel, block_rows=block_rows, block_edges=block_edges
    )
    ne = e // block_edges
    return pl.pallas_call(
        kernel,
        grid=(num_segments // block_rows, ne),
        in_specs=[
            pl.BlockSpec((1, block_edges), lambda rb, eb: (eb, 0)),
            pl.BlockSpec((1, block_edges), lambda rb, eb: (eb, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda rb, eb: (rb,)),
        out_shape=jax.ShapeDtypeStruct((num_segments,), jnp.uint32),
        interpret=interpret,
    )(keys.reshape(ne, block_edges), segs.reshape(ne, block_edges))
