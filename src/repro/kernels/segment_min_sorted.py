"""Pallas TPU kernel: packed-key segment-min over *sorted* segment ids.

The coarsening dedupe (``repro.coarsen.filter``) produces segment ids by
a boundary-flag prefix-sum over the *sorted* pair keys, so ``segs`` is
non-decreasing and every segment occupies one contiguous edge range. The
flat kernel (``segment_min_flat_pallas``) ignores that structure and
rescans every edge block for every output row block — O(E²/block_rows)
lanes at ``num_segments = E``. This kernel exploits it:

- Each output row block ``rb`` covers segments
  ``[rb·block_rows, (rb+1)·block_rows)``; sortedness means those
  segments live in a contiguous *edge-block* range
  ``[first_eb[rb], last_eb[rb]]``.
- The grid is one step per (row block, edge block) *intersection pair*.
  The staircase structure bounds the pair count by
  ``num_edge_blocks + num_row_blocks`` — linear, not quadratic — and the
  per-row-block edge-block offsets are **scalar-prefetched**
  (``pltpu.PrefetchScalarGridSpec``) so the BlockSpec index maps DMA
  exactly the blocks each step touches and nothing else.
- The output tile stays VMEM-resident across a row block's consecutive
  steps and accumulates with ``min`` (first touch initializes to the
  identity); steps padded beyond the live pair count re-reduce the final
  pair, which is idempotent under min.

Keys are the pack32 layout (``repro.core.semiring``), identity/padding
= 0xFFFFFFFF. Correctness does NOT require masking boundary blocks: an
edge whose segment falls outside the step's row block compares unequal
to every local row and contributes the identity.

Contract: ``segs`` must be non-decreasing. Violations are not detected
(the check would cost the O(E) pass this kernel exists to avoid) — the
result silently loses the out-of-order contributions. Callers with
unsorted ids want ``segment_min_flat_pallas``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.segment_min_bucketed import _validate_blocked

UMAX = np.uint32(0xFFFFFFFF)


def build_step_maps(
    segs: jax.Array,
    *,
    num_segments: int,
    block_rows: int,
    block_edges: int,
):
    """Per-grid-step (row block, edge block) indices for the sorted kernel.

    Pure jnp (runs inside the caller's jit; the results feed the kernel as
    scalar-prefetch operands). ``segs`` is the full padded [E] sorted id
    array. Returns int32 ``(rb_map, eb_map)`` of static length
    ``num_edge_blocks + num_row_blocks``:

    - ``rb_map`` is non-decreasing and visits *every* row block at least
      once (empty row blocks get one step so their output tile is
      initialized to the identity);
    - within a row block, ``eb_map`` walks ``first_eb..last_eb``;
    - steps beyond the live pair count clamp to the last live pair
      (idempotent re-reduction).
    """
    e = segs.shape[0]
    ne = e // block_edges
    r = num_segments // block_rows
    rb = jnp.arange(r, dtype=jnp.int32)
    # Edge index range [p_lo, p_hi) of the segments in row block rb.
    p_lo = jnp.searchsorted(segs, rb * block_rows).astype(jnp.int32)
    p_hi = jnp.searchsorted(segs, (rb + 1) * block_rows).astype(jnp.int32)
    first_eb = jnp.clip(p_lo // block_edges, 0, ne - 1)
    last_eb = jnp.where(
        p_hi > p_lo, jnp.clip((p_hi - 1) // block_edges, 0, ne - 1), first_eb
    )
    last_eb = jnp.maximum(last_eb, first_eb)
    start = jnp.cumsum(last_eb - first_eb + 1) - (last_eb - first_eb + 1)
    steps = jnp.arange(ne + r, dtype=jnp.int32)
    rb_map = jnp.clip(
        jnp.searchsorted(start, steps, side="right").astype(jnp.int32) - 1,
        0,
        r - 1,
    )
    eb_map = jnp.minimum(
        first_eb[rb_map] + (steps - start[rb_map]), last_eb[rb_map]
    )
    return rb_map, eb_map.astype(jnp.int32)


def _sorted_kernel(
    rb_map_ref, eb_map_ref, keys_ref, segs_ref, out_ref, *, block_rows, block_edges
):
    s = pl.program_id(0)
    rb = rb_map_ref[s]

    first = jnp.logical_or(s == 0, rb_map_ref[jnp.maximum(s - 1, 0)] != rb)

    @pl.when(first)
    def _init():
        out_ref[...] = jnp.full((block_rows,), UMAX, jnp.uint32)

    keys = keys_ref[0, :]  # [BE] uint32
    segs = segs_ref[0, :]  # [BE] int32 sorted global segment ids
    local = segs - rb * block_rows
    r = jax.lax.broadcasted_iota(jnp.int32, (block_rows, block_edges), 0)
    eq = local[None, :] == r  # out-of-block segments match no local row
    vals = jnp.where(eq, keys[None, :], UMAX)
    out_ref[...] = jnp.minimum(out_ref[...], jnp.min(vals, axis=1))


def segment_min_sorted_pallas(
    keys: jax.Array,
    segs: jax.Array,
    *,
    num_segments: int,
    block_rows: int = 128,
    block_edges: int = 512,
    interpret: bool = False,
):
    """Sorted-segment packed segment-min: keys uint32 [E], segs int32 [E]
    non-decreasing with values in [0, num_segments). Returns uint32
    [num_segments] (UMAX at empty segments).

    Shape contract mirrors ``segment_min_flat_pallas`` (E a multiple of
    ``block_edges``, ``num_segments`` a multiple of ``block_rows``; callers
    pad via ``kernels.ops.segment_min_sorted``); cost is
    O((E/block_edges + num_segments/block_rows) · block_rows·block_edges)
    lanes instead of the flat kernel's O(num_segments·E/block_rows).
    """
    _validate_blocked(keys, segs, block_rows)
    if keys.ndim != 1:
        raise ValueError(f"expected flat [E] layout, got {keys.shape}")
    if block_edges % 128:
        raise ValueError(f"block_edges={block_edges} must be a multiple of 128 lanes")
    if block_rows % 128:
        raise ValueError(
            f"block_rows={block_rows} must be a multiple of 128 (1-D output tile)"
        )
    e = keys.shape[0]
    if e == 0:
        raise ValueError("empty edge array; pad to >= one block of edges")
    if e % block_edges:
        raise ValueError(
            f"edge count {e} must be a multiple of block_edges={block_edges} "
            f"(pad with identity keys)"
        )
    if num_segments <= 0 or num_segments % block_rows:
        raise ValueError(
            f"num_segments={num_segments} must be a positive multiple of "
            f"block_rows={block_rows} (pad the output)"
        )
    ne = e // block_edges
    rb_map, eb_map = build_step_maps(
        segs,
        num_segments=num_segments,
        block_rows=block_rows,
        block_edges=block_edges,
    )
    kernel = functools.partial(
        _sorted_kernel, block_rows=block_rows, block_edges=block_edges
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(ne + num_segments // block_rows,),
        in_specs=[
            pl.BlockSpec((1, block_edges), lambda s, rbm, ebm: (ebm[s], 0)),
            pl.BlockSpec((1, block_edges), lambda s, rbm, ebm: (ebm[s], 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda s, rbm, ebm: (rbm[s],)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_segments,), jnp.uint32),
        interpret=interpret,
    )(
        rb_map,
        eb_map,
        keys.reshape(ne, block_edges),
        segs.reshape(ne, block_edges),
    )
