"""Pallas TPU kernel: dense-block multilinear MSF kernel (paper §III-A).

Computes, per row i: the MINWEIGHT-monoid reduction
    (minw, mincol, minpay)_i = argmin_j { (a_ij, j) : p_i != p_j }
with payload p_j — i.e. Algorithm 1 line 9 with f(p_i, a_ij, p_j).

TPU mapping (DESIGN.md §2): grid = (rows/BI, cols/BJ) with the column
dimension innermost and *sequential*; the (BI,) running accumulators live in
the output VMEM blocks, which Pallas revisits for every j because their
index_map ignores j. Each grid step loads an (BI, BJ) tile of A and the
(BI,)/(BJ,) slabs of p — a VPU compare/select + min-reduce over lanes, the
all-at-once form of the kernel (no materialized (a_ij, p_j) pairs, which is
exactly the paper's complaint about the pairwise SpMV formulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

INF = np.float32(np.inf)
IMAX = np.int32(np.iinfo(np.int32).max)


def _kernel(x_ref, y_ref, a_ref, minw_ref, mincol_ref, minpay_ref, *, block_j):
    j_blk = pl.program_id(1)

    @pl.when(j_blk == 0)
    def _init():
        minw_ref[...] = jnp.full_like(minw_ref, INF)
        mincol_ref[...] = jnp.full_like(mincol_ref, IMAX)
        minpay_ref[...] = jnp.full_like(minpay_ref, IMAX)

    x = x_ref[...]  # [BI] int32 (p row slab)
    y = y_ref[...]  # [BJ] int32 (p col slab)
    a = a_ref[...]  # [BI, BJ] f32
    col = j_blk * block_j + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)

    valid = (x[:, None] != y[None, :]) & (a < INF)
    w = jnp.where(valid, a, INF)
    bw = jnp.min(w, axis=1)
    on = (w == bw[:, None]) & (bw[:, None] < INF)
    bcol = jnp.min(jnp.where(on, col, IMAX), axis=1)
    winner = on & (col == bcol[:, None])
    bpay = jnp.min(
        jnp.where(winner, jnp.broadcast_to(y[None, :], a.shape).astype(jnp.int32), IMAX),
        axis=1,
    )

    # MINWEIGHT combine with the running accumulator (lexicographic (w, col)).
    cw, ccol, cpay = minw_ref[...], mincol_ref[...], minpay_ref[...]
    nw = jnp.minimum(cw, bw)
    c_on = (cw == nw) & (nw < INF)
    b_on = (bw == nw) & (nw < INF)
    ncol = jnp.minimum(jnp.where(c_on, ccol, IMAX), jnp.where(b_on, bcol, IMAX))
    c_win = c_on & (ccol == ncol)
    b_win = b_on & (bcol == ncol)
    npay = jnp.minimum(jnp.where(c_win, cpay, IMAX), jnp.where(b_win, bpay, IMAX))

    minw_ref[...] = nw
    mincol_ref[...] = ncol
    minpay_ref[...] = npay


def multilinear_dense_pallas(
    p: jax.Array,
    a: jax.Array,
    *,
    block_i: int = 128,
    block_j: int = 128,
    interpret: bool = False,
):
    """p: int32 [n]; a: f32 [n, n] with +inf for absent edges. n must be a
    multiple of the block sizes (``ops.multilinear_dense`` pads)."""
    n = a.shape[0]
    assert n % block_i == 0 and a.shape[1] % block_j == 0
    grid = (n // block_i, a.shape[1] // block_j)
    kernel = functools.partial(_kernel, block_j=block_j)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i,), lambda i, j: (i,)),
            pl.BlockSpec((block_j,), lambda i, j: (j,)),
            pl.BlockSpec((block_i, block_j), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_i,), lambda i, j: (i,)),
            pl.BlockSpec((block_i,), lambda i, j: (i,)),
            pl.BlockSpec((block_i,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(p, p, a)
