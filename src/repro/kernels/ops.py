"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs as traced JAX ops, validating the exact TPU program
logic. On a TPU backend they compile to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.multilinear_dense import multilinear_dense_pallas
from repro.kernels.segment_min_bucketed import segment_min_bucketed_pallas

INF = jnp.float32(jnp.inf)
IMAX = jnp.int32(jnp.iinfo(jnp.int32).max)
UMAX = np.uint32(0xFFFFFFFF)


def _use_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def multilinear_dense(
    p: jax.Array,
    a: jax.Array,
    *,
    block_i: int = 128,
    block_j: int = 128,
    interpret: bool | None = None,
):
    """Min outgoing edge per vertex over a dense adjacency (see ref.py).

    Pads n up to the block size; padded rows/cols carry +inf / sentinel p
    values so they reduce to the monoid identity.
    """
    n = a.shape[0]
    bi = min(block_i, max(8, 1 << (n - 1).bit_length()))
    bj = min(block_j, max(128, 1 << (n - 1).bit_length()))
    n_i = -(-n // bi) * bi
    n_j = -(-n // bj) * bj
    a_p = jnp.full((n_i, n_j), INF, jnp.float32).at[:n, :n].set(a)
    # Padded vertices get unique negative ids so p_i != p_j never matches
    # spuriously... they must *never* be selected: a = inf handles that.
    p_pad_i = jnp.full((n_i,), -1, jnp.int32).at[:n].set(p.astype(jnp.int32))
    minw, mincol, minpay = multilinear_dense_pallas(
        p_pad_i,
        a_p,
        block_i=bi,
        block_j=bj,
        interpret=_use_interpret(interpret),
    )
    return minw[:n], mincol[:n], minpay[:n]


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def segment_min_bucketed(
    keys: jax.Array,
    rows: jax.Array,
    *,
    block_rows: int = 128,
    interpret: bool | None = None,
):
    return segment_min_bucketed_pallas(
        keys, rows, block_rows=block_rows, interpret=_use_interpret(interpret)
    )


def bucket_edges_by_row_block(
    seg: np.ndarray, keys: np.ndarray, n: int, block_rows: int = 128
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side bucketing for the segment-min kernel: group edges by
    ``seg // block_rows`` and pad each bucket to the max size (multiple of
    128 lanes). Returns (keys [NB, BE] uint32, rows [NB, BE] int32)."""
    nb = -(-n // block_rows)
    b = seg // block_rows
    counts = np.bincount(b, minlength=nb)
    be = max(128, int(-(-counts.max() // 128) * 128)) if len(seg) else 128
    keys_out = np.full((nb, be), UMAX, np.uint32)
    rows_out = np.zeros((nb, be), np.int32)
    order = np.argsort(b, kind="stable")
    seg_s, keys_s, b_s = seg[order], keys[order], b[order]
    starts = np.concatenate([[0], np.cumsum(counts)])
    for k in range(nb):
        lo, hi = starts[k], starts[k + 1]
        keys_out[k, : hi - lo] = keys_s[lo:hi]
        rows_out[k, : hi - lo] = seg_s[lo:hi] - k * block_rows
    return keys_out, rows_out
