"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs as traced JAX ops, validating the exact TPU program
logic. On a TPU backend they compile to Mosaic.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.multilinear_dense import multilinear_dense_pallas
from repro.kernels.segment_min_bucketed import (
    segment_min_bucketed_pallas,
    segment_min_flat_pallas,
)
from repro.kernels.segment_min_sorted import segment_min_sorted_pallas

INF = jnp.float32(jnp.inf)
IMAX = jnp.int32(jnp.iinfo(jnp.int32).max)
UMAX = np.uint32(0xFFFFFFFF)


def _use_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def multilinear_dense(
    p: jax.Array,
    a: jax.Array,
    *,
    block_i: int = 128,
    block_j: int = 128,
    interpret: bool | None = None,
):
    """Min outgoing edge per vertex over a dense adjacency (see ref.py).

    Pads n up to the block size; padded rows/cols carry +inf / sentinel p
    values so they reduce to the monoid identity.
    """
    n = a.shape[0]
    bi = min(block_i, max(8, 1 << (n - 1).bit_length()))
    bj = min(block_j, max(128, 1 << (n - 1).bit_length()))
    n_i = -(-n // bi) * bi
    n_j = -(-n // bj) * bj
    a_p = jnp.full((n_i, n_j), INF, jnp.float32).at[:n, :n].set(a)
    # Padded vertices get unique negative ids so p_i != p_j never matches
    # spuriously... they must *never* be selected: a = inf handles that.
    p_pad_i = jnp.full((n_i,), -1, jnp.int32).at[:n].set(p.astype(jnp.int32))
    minw, mincol, minpay = multilinear_dense_pallas(
        p_pad_i,
        a_p,
        block_i=bi,
        block_j=bj,
        interpret=_use_interpret(interpret),
    )
    return minw[:n], mincol[:n], minpay[:n]


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def segment_min_bucketed(
    keys: jax.Array,
    rows: jax.Array,
    *,
    block_rows: int = 128,
    interpret: bool | None = None,
):
    return segment_min_bucketed_pallas(
        keys, rows, block_rows=block_rows, interpret=_use_interpret(interpret)
    )


@partial(
    jax.jit,
    static_argnames=("num_segments", "block_rows", "block_edges", "interpret"),
)
def segment_min_flat(
    keys: jax.Array,
    segs: jax.Array,
    *,
    num_segments: int,
    block_rows: int = 128,
    block_edges: int = 512,
    interpret: bool | None = None,
):
    """Flat packed-key segment-min over arbitrary (unsorted) segment ids.

    Pads the edge dimension to a block_edges multiple (identity keys) and
    the segment dimension to a block_rows multiple, then slices back — the
    caller keeps natural shapes.
    """
    e = keys.shape[0]
    e_pad = max(block_edges, -(-e // block_edges) * block_edges)
    s_pad = max(block_rows, -(-num_segments // block_rows) * block_rows)
    keys_p = jnp.full((e_pad,), UMAX, jnp.uint32).at[:e].set(keys)
    segs_p = jnp.zeros((e_pad,), jnp.int32).at[:e].set(segs)
    out = segment_min_flat_pallas(
        keys_p,
        segs_p,
        num_segments=s_pad,
        block_rows=block_rows,
        block_edges=block_edges,
        interpret=_use_interpret(interpret),
    )
    return out[:num_segments]


@partial(
    jax.jit,
    static_argnames=("num_segments", "block_rows", "block_edges", "interpret"),
)
def segment_min_sorted(
    keys: jax.Array,
    segs: jax.Array,
    *,
    num_segments: int,
    block_rows: int = 128,
    block_edges: int = 512,
    interpret: bool | None = None,
):
    """Contiguous-range packed segment-min over **sorted** segment ids.

    Same pad-and-slice contract as :func:`segment_min_flat`, but the
    kernel scalar-prefetches per-row-block edge-block offsets so each
    grid step reads only the blocks its segments touch — O(E) lanes for
    the coarsening dedupe where the flat kernel is O(E²/block_rows).
    Padding entries get segment id ``num_segments_padded − 1`` (identity
    keys), preserving sortedness and covering the tail row block.
    """
    e = keys.shape[0]
    e_pad = max(block_edges, -(-e // block_edges) * block_edges)
    s_pad = max(block_rows, -(-num_segments // block_rows) * block_rows)
    keys_p = jnp.full((e_pad,), UMAX, jnp.uint32).at[:e].set(keys)
    segs_p = jnp.full((e_pad,), s_pad - 1, jnp.int32).at[:e].set(segs)
    out = segment_min_sorted_pallas(
        keys_p,
        segs_p,
        num_segments=s_pad,
        block_rows=block_rows,
        block_edges=block_edges,
        interpret=_use_interpret(interpret),
    )
    return out[:num_segments]


def dedupe_segmin_backend(backend: str | None):
    """Resolve a segmin request for a *dedupe* site — one whose segment ids
    are sorted (the boundary prefix-sum over sorted pair keys in the
    coarsening filter, single-device and distributed alike).

    Returns the packed-segmin callable to pass to the filter, or ``None``
    for the plain XLA ``segment_min``: a Pallas request ("pallas"/"sorted")
    selects the contiguous-range sorted kernel (the flat kernel's full
    rescan is O(E²/block_rows) at num_segments = E and was never viable
    here); "jnp" pins XLA; None/"auto" picks the sorted kernel on TPU and
    XLA elsewhere (interpreted Pallas loses badly to XLA on CPU). The
    single home of that rule — call sites must not re-implement it.
    """
    if backend in ("pallas", "sorted"):
        return make_packed_segmin("sorted")
    if backend == "jnp":
        return None
    return (
        make_packed_segmin("sorted")
        if jax.default_backend() == "tpu"
        else None
    )


def flat_segmin_backend(backend: str | None) -> str | None:
    """Resolve a segmin backend request for a *flat* reduction site —
    one whose segment ids are unsorted (the MSF hook loops, the residual
    solve). "sorted" is dedupe-only (the contiguous-range kernel silently
    loses out-of-order contributions), so it degrades to "auto" here;
    every other request passes through. The single home of that rule —
    call sites must not re-implement it.
    """
    return "auto" if backend == "sorted" else backend


@lru_cache(maxsize=None)
def make_packed_segmin(backend: str = "auto"):
    """Resolve a packed (uint32 key, int32 seg) → uint32 [n] segment-min.

    ``backend``: "jnp" (pure-JAX ``segment_min``), "pallas" (the flat
    Pallas kernel, ``interpret=True`` selected automatically off
    ``jax.default_backend()``), "sorted" (the contiguous-range Pallas
    kernel — the caller's segment ids MUST be non-decreasing, e.g. the
    coarsening dedupe's boundary prefix-sum ranks), or "auto" (pallas on
    TPU, jnp elsewhere — interpreted Pallas is orders of magnitude slower
    than XLA on CPU, so auto never picks it there).

    Cached so repeat calls return the *same* callable — callers pass the
    result as a jit-static argument and must not miss the jit cache.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        def _jnp(keys, segs, num_segments):
            return jax.ops.segment_min(keys, segs, num_segments=num_segments)

        return _jnp
    if backend == "pallas":
        def _pallas(keys, segs, num_segments):
            return segment_min_flat(keys, segs, num_segments=num_segments)

        return _pallas
    if backend == "sorted":
        def _sorted(keys, segs, num_segments):
            return segment_min_sorted(keys, segs, num_segments=num_segments)

        return _sorted
    raise ValueError(f"unknown segment-min backend {backend!r}")


def bucket_edges_by_row_block(
    seg: np.ndarray, keys: np.ndarray, n: int, block_rows: int = 128
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side bucketing for the segment-min kernel: group edges by
    ``seg // block_rows`` and pad each bucket to the max size (multiple of
    128 lanes). Returns (keys [NB, BE] uint32, rows [NB, BE] int32)."""
    nb = -(-n // block_rows)
    b = seg // block_rows
    counts = np.bincount(b, minlength=nb)
    be = max(128, int(-(-counts.max() // 128) * 128)) if len(seg) else 128
    keys_out = np.full((nb, be), UMAX, np.uint32)
    rows_out = np.zeros((nb, be), np.int32)
    order = np.argsort(b, kind="stable")
    seg_s, keys_s, b_s = seg[order], keys[order], b[order]
    starts = np.concatenate([[0], np.cumsum(counts)])
    for k in range(nb):
        lo, hi = starts[k], starts[k + 1]
        keys_out[k, : hi - lo] = keys_s[lo:hi]
        rows_out[k, : hi - lo] = seg_s[lo:hi] - k * block_rows
    return keys_out, rows_out
