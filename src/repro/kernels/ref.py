"""Pure-jnp oracles for the Pallas kernels (no Pallas imports)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)
IMAX = jnp.int32(jnp.iinfo(jnp.int32).max)
UMAX = jnp.uint32(0xFFFFFFFF)


def multilinear_dense_ref(p: jax.Array, a: jax.Array):
    """Oracle for the dense-block multilinear MSF kernel.

    w_i = min_j { a_ij : p_i != p_j }, with (weight, col) lexicographic
    argmin and payload p_argmin. Returns (minw f32 [n], mincol i32 [n],
    minpay i32 [n]); identity (inf, IMAX, IMAX) for rows with no valid edge.
    """
    n = a.shape[0]
    col = jnp.arange(n, dtype=jnp.int32)
    valid = (p[:, None] != p[None, :]) & (a < INF)
    w = jnp.where(valid, a, INF)
    minw = jnp.min(w, axis=1)
    on = (w == minw[:, None]) & (minw[:, None] < INF)
    mincol = jnp.min(jnp.where(on, col[None, :], IMAX), axis=1)
    winner = on & (col[None, :] == mincol[:, None])
    minpay = jnp.min(
        jnp.where(winner, p[None, :].astype(jnp.int32), IMAX), axis=1
    )
    return minw, mincol, minpay


def segment_min_bucketed_ref(keys: jax.Array, rows: jax.Array, block_rows: int):
    """Oracle for the bucketed packed-key segment-min kernel.

    keys: uint32 [NB, BE] (UMAX = identity/padding); rows: int32 [NB, BE],
    local row index within the bucket's row block. Returns uint32
    [NB * block_rows].
    """
    nb, be = keys.shape
    r = jnp.arange(block_rows, dtype=jnp.int32)
    # [NB, block_rows, BE] compare-broadcast-reduce
    eq = rows[:, None, :] == r[None, :, None]
    vals = jnp.where(eq, keys[:, None, :], UMAX)
    return jnp.min(vals, axis=2).reshape(nb * block_rows)


def segment_min_flat_ref(keys: jax.Array, segs: jax.Array, num_segments: int):
    """Oracle for the flat-layout packed segment-min kernel.

    keys: uint32 [E] (UMAX = identity/padding); segs: int32 [E] global
    segment ids. Returns uint32 [num_segments] (UMAX at empty segments —
    ``segment_min``'s identity for uint32 is the dtype max).
    """
    return jax.ops.segment_min(keys, segs, num_segments=num_segments)


def segment_min_sorted_ref(keys: jax.Array, segs: jax.Array, num_segments: int):
    """Oracle for the sorted-segment packed segment-min kernel.

    Identical reduction to :func:`segment_min_flat_ref`; the sorted kernel
    only restricts *how* segment ids may be laid out (non-decreasing), not
    what the result is, so the oracle is the same segment_min.
    """
    return jax.ops.segment_min(keys, segs, num_segments=num_segments)
