"""jax version compatibility (single import point).

The codebase targets the modern spellings — ``jax.shard_map`` and
``jax.make_mesh(..., axis_types=...)``. Older jax (< 0.5, e.g. the 0.4.x
CPU wheels in CI containers) has ``shard_map`` under ``jax.experimental``
and no ``AxisType``/``axis_types`` (Auto is the implicit behavior there,
so the fallback is semantics-preserving). Import both names from here.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.5: experimental home, and check_vma was named check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        return _shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=check_vma,
            **kw,
        )


@jax.custom_jvp
def optimization_barrier(x):
    """Differentiable ``lax.optimization_barrier``.

    jax < 0.5 has no differentiation rule for the primitive; this wrapper
    keeps the barrier on the primal (the scheduling pin is all we want)
    and passes tangents through untouched, which transposes cleanly for
    reverse mode on every version.
    """
    return jax.lax.optimization_barrier(x)


@optimization_barrier.defjvp
def _optimization_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return optimization_barrier(x), t


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types when supported.

    Floor note: ``jax.make_mesh`` itself exists from 0.4.35 — the
    requirements/CI floor — so only the ``axis_types`` spelling needs a
    fallback here.
    """
    shape, axes = tuple(shape), tuple(axes)
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except (AttributeError, TypeError):  # jax < 0.5: Auto is implicit
        return jax.make_mesh(shape, axes)
