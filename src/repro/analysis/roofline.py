"""Roofline terms from the compiled dry-run artifact (no hardware needed).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s            (seconds)
  memory     = HLO_bytes_per_device / HBM_bw                 (seconds)
  collective = collective_bytes_per_device / link_bw          (seconds)

Two sources are combined:

- ``repro.analysis.hlo_analyzer`` — parses the compiled (post-SPMD,
  per-device) HLO with *while-loop trip-count multipliers*. XLA's built-in
  ``cost_analysis()`` counts loop bodies once, so a 61-layer scanned
  transformer would be 61× under-reported; the analyzer fixes that and is
  the primary source for all three terms (validated against hand counts).
- ``compiled.cost_analysis()`` — kept as the ``xla_*`` cross-check fields
  (no loop multiplicity, but an independent elementwise-FLOP count to
  sanity-check the analyzer's ``ew_flops`` against).

Dynamic-trip-count loops (the MSF engine's convergence loop) are flagged:
their numbers are per loop iteration — the paper's own reporting unit
(time *per iteration*, Fig 3/4).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

from typing import Dict

from repro.analysis.hlo_analyzer import analyze

TPU_V5E = dict(
    peak_flops_bf16=197e12,  # per chip
    hbm_bw=819e9,  # B/s
    ici_bw=50e9,  # B/s per link
)


def roofline(compiled, *, n_devices: int, model_flops: float | None = None,
             hw: Dict = TPU_V5E) -> Dict:
    ca = compiled.cost_analysis() or {}
    res = analyze(compiled.as_text())
    flops = max(float(res["flops"]), float(ca.get("flops", 0.0)))
    bytes_acc = max(float(res["bytes"]), float(ca.get("bytes accessed", 0.0)))
    coll_total = float(res["collective_bytes"])

    t_compute = flops / hw["peak_flops_bf16"]
    t_memory = bytes_acc / hw["hbm_bw"]
    t_collective = coll_total / hw["ici_bw"]
    terms = dict(compute=t_compute, memory=t_memory, collective=t_collective)
    dominant = max(terms, key=terms.get)

    mem = compiled.memory_analysis()
    out = dict(
        flops_per_device=flops,
        bytes_per_device=bytes_acc,
        collective_bytes_per_device=coll_total,
        t_compute_s=t_compute,
        t_memory_s=t_memory,
        t_collective_s=t_collective,
        dominant=dominant,
        bound_time_s=max(terms.values()),
        dynamic_loops=int(res["dynamic_loops"]),
        xla_flops_per_device=float(ca.get("flops", 0.0)),
        xla_bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        arg_bytes_per_device=int(mem.argument_size_in_bytes),
        temp_bytes_per_device=int(mem.temp_size_in_bytes),
        output_bytes_per_device=int(mem.output_size_in_bytes),
    )
    if model_flops:
        out["model_flops"] = float(model_flops)
        hlo_global = flops * n_devices
        out["useful_flops_ratio"] = float(model_flops) / max(hlo_global, 1.0)
        # roofline fraction: useful-work rate vs peak, if the step ran at
        # its binding roofline term
        out["roofline_fraction"] = (
            float(model_flops) / n_devices / hw["peak_flops_bf16"]
        ) / max(out["bound_time_s"], 1e-30)
    return out


# re-exported for tests
from repro.analysis.hlo_analyzer import HloCost  # noqa: E402,F401


def collective_bytes(hlo_text: str) -> float:
    return analyze(hlo_text)["collective_bytes"]
