"""Summarize experiments/dryrun/*.json into EXPERIMENTS.md markdown tables.

  PYTHONPATH=src python -m repro.analysis.summarize [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b):
    if b >= 2**30:
        return f"{b/2**30:.1f}G"
    if b >= 2**20:
        return f"{b/2**20:.1f}M"
    return f"{b/2**10:.0f}K"


def fmt_s(s):
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.2f}ms"
    return f"{s*1e6:.1f}us"


def load(dir_):
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def _tag_of(cell_id: str) -> str:
    return cell_id.split("+", 1)[1] if "+" in cell_id else ""


def dryrun_table(recs, mesh, tag=""):
    rows = [
        "| cell | ok | compile | FLOPs/dev | bytes/dev | coll/dev | args/dev | temp/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or _tag_of(r.get("cell", "")) != tag:
            continue
        if not r.get("ok"):
            rows.append(f"| {r['arch']}:{r['shape']} | FAIL: {r.get('error','')[:60]} | | | | | | |")
            continue
        rows.append(
            f"| {r['arch']}:{r['shape']} | ok | {r['compile_s']}s "
            f"| {r['flops_per_device']:.2e} | {r['bytes_per_device']:.2e} "
            f"| {r['collective_bytes_per_device']:.2e} "
            f"| {fmt_bytes(r['arg_bytes_per_device'])} | {fmt_bytes(r['temp_bytes_per_device'])} |"
        )
    return "\n".join(rows)


def roofline_table(recs, tag=""):
    rows = [
        "| cell | t_compute | t_memory | t_collective | dominant | MODEL_FLOPS | useful/HLO | roofline-frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != "single" or not r.get("ok") or _tag_of(r.get("cell", "")) != tag:
            continue
        mf = r.get("model_flops")
        rows.append(
            f"| {r['arch']}:{r['shape']} | {fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} "
            f"| {fmt_s(r['t_collective_s'])} | **{r['dominant']}** "
            f"| {mf:.2e} | {r.get('useful_flops_ratio', 0):.3f} "
            f"| {r.get('roofline_fraction', 0):.4f} |"
            if mf else
            f"| {r['arch']}:{r['shape']} | {fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} "
            f"| {fmt_s(r['t_collective_s'])} | **{r['dominant']}** | - | - | - |"
        )
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    recs = load(args.dir)
    sel = [r for r in recs if _tag_of(r.get("cell", "")) == args.tag]
    n_ok = sum(1 for r in sel if r.get("ok"))
    print(f"## tag={args.tag or '(baseline)'}: {len(sel)} records, {n_ok} ok\n")
    print("### single-pod (16x16 = 256 chips)\n")
    print(dryrun_table(recs, "single", args.tag))
    print("\n### multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(recs, "multi", args.tag))
    print("\n### roofline (single-pod)\n")
    print(roofline_table(recs, args.tag))


if __name__ == "__main__":
    main()
