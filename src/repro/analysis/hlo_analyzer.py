"""HLO-text cost analyzer with while-loop trip-count awareness.

XLA's built-in ``cost_analysis()`` counts a while-loop *body once* — a
61-layer scanned transformer reports 1/61 of its FLOPs. This analyzer
parses the compiled (post-SPMD, per-device) HLO text, builds a module-wide
symbol table of result shapes, recovers static trip counts from loop
conditions, and accumulates per-computation:

- ``dot_flops``      — 2 · |result| · |contracted dims| per dot
- ``ew_flops``       — elementwise/reduction arithmetic: one op per
                       result element for the arithmetic opcodes, operand
                       elements per reduce, E·log2(E) comparisons per
                       sort — the flop currency of gather/segment-min
                       programs like the MSF kernels, which contain no
                       dots at all
- ``bytes``          — operands + result of top-level ops (fusion bodies
                       don't touch HBM; the fusion op's own operands do)
- ``collective_bytes`` — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute

then multiplies loop bodies by their trip counts. Dynamic loops (the MSF
engine's convergence loop) get multiplier 1 and are flagged — their
metrics are *per iteration* (the paper's own unit, Fig 3/4).
``analyze()`` also reports ``flops`` = dot_flops + ew_flops, the total
the roofline and ``SolveReport.cost`` consume.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
    "all-gather-start", "all-reduce-start", "collective-permute-start",
}

# Elementwise / data-movement ops: charged result bytes only (the write).
# Their operand reads are charged where those operands were *produced* —
# the producer-consumer "each buffer written once, read once" traffic
# model. Charging full operands per op double-counts every fusion-eligible
# chain (XLA:TPU fuses these; XLA:CPU's HLO keeps them separate).
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "and", "or", "xor", "not", "negate", "abs", "sign", "compare",
    "select", "convert", "exponential", "exponential-minus-one", "log",
    "log-plus-one", "rsqrt", "sqrt", "tanh", "logistic", "power",
    "clamp", "floor", "ceil", "round-nearest-afz", "is-finite",
    "copy", "reshape", "broadcast", "iota", "slice", "pad", "reverse",
    "concatenate", "transpose", "rng-bit-generator", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "remainder",
    "cosine", "sine", "expm1", "log1p", "atan2", "real", "imag",
}

# Opcodes charged 1 flop per result element (arithmetic, compares,
# selects, transcendentals). Deliberately a subset of _ELEMENTWISE:
# pure data movement (copy/reshape/broadcast/iota/slice/pad/reverse/
# concatenate/transpose/convert) moves bytes but computes nothing.
_EW_FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "and", "or", "xor", "not", "negate", "abs", "sign", "compare",
    "select", "clamp", "floor", "ceil", "round-nearest-afz", "is-finite",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder", "power", "atan2", "exponential", "exponential-minus-one",
    "log", "log-plus-one", "rsqrt", "sqrt", "tanh", "logistic",
    "cosine", "sine", "expm1", "log1p",
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
# type = lazy-anything (tuple types can contain /*index=N*/ comments);
# opcode = the first lowercase word directly followed by '(' after the '='.
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w\-.]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w\-.]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_info(type_str: str) -> Tuple[int, List[int]]:
    """(total bytes, dims of first array) for a type string (incl tuples)."""
    total = 0
    first_dims: Optional[List[int]] = None
    for dt, dims in _SHAPE_RE.findall(type_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        dl = []
        if dims:
            for d in dims.split(","):
                dl.append(int(d))
                n *= int(d)
        total += n * b
        if first_dims is None:
            first_dims = dl
    return total, first_dims or []


def _elements(type_str: str) -> float:
    """Total array elements across a type string (tuples included)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return float(total)


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attrs


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, _Computation] = {}
        self.shapes: Dict[str, str] = {}  # %name -> type string
        self.const_vals: Dict[str, float] = {}
        self._parse(hlo_text)
        self.dynamic_loops = 0
        self._memo: Dict[str, Dict[str, float]] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str):
        cur: Optional[_Computation] = None
        entry = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if line.endswith("{"):
                m = _COMP_RE.match(line.strip())
                if m:
                    name = m.group(1)
                    if not name.startswith("%"):
                        name = "%" + name
                    cur = _Computation(name)
                    self.comps[name] = cur
                    if raw.strip().startswith("ENTRY"):
                        entry = name
                    continue
            if line.strip() == "}":
                cur = None
                continue
            m = _LINE_RE.match(line)
            if m and cur is not None:
                name, tstr, opcode, rest = m.groups()
                cur.ops.append(_Op(name, tstr, opcode, rest))
                self.shapes[name] = tstr
                if opcode == "constant":
                    cm = re.match(r"([\d.eE+\-]+)\)", rest.strip())
                    if cm:
                        try:
                            self.const_vals[name] = float(cm.group(1))
                        except ValueError:
                            pass
        self.entry = entry

    # ------------------------------------------------------------------
    def _operand_names(self, rest: str) -> List[str]:
        # operands are before the first "), " attr separator
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    rest = rest[:i]
                    break
                depth -= 1
        return re.findall(r"%[\w\-.]+", rest)

    def _attr(self, rest: str, key: str) -> Optional[str]:
        m = re.search(key + r"=(%[\w\-.]+)", rest)
        return m.group(1) if m else None

    def _trip_count(self, while_rest: str, cond_name: Optional[str]) -> Optional[int]:
        # backend_config known_trip_count only — XLA stamps it for every
        # counted loop (scan). Guessing from condition constants misfires
        # badly on data-dependent loops whose conditions mention sentinels
        # like INT32_MAX (the MSF convergence loop).
        m = _TRIP_RE.search(while_rest)
        if m:
            return int(m.group(1))
        return None

    def _dot_flops(self, op: _Op) -> float:
        out_bytes, out_dims = _shape_info(op.type_str)
        n_out = math.prod(out_dims) if out_dims else 0
        operands = self._operand_names(op.rest)
        if not operands:
            return 0.0
        lhs = self.shapes.get(operands[0])
        if lhs is None:
            return 0.0
        _, lhs_dims = _shape_info(lhs)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        k = 1
        if m and m.group(1):
            for d in m.group(1).split(","):
                if int(d) < len(lhs_dims):
                    k *= lhs_dims[int(d)]
        return 2.0 * n_out * k

    # ------------------------------------------------------------------
    def comp_cost(self, comp_name: str) -> Dict[str, float]:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        # g_full / g_traffic: full operand bytes vs realistic traffic of
        # gather-like ops inside this computation — used to discount the
        # operands of enclosing fusions (an input-fused gather reads only
        # the gathered rows, not the whole source array).
        out = {"dot_flops": 0.0, "ew_flops": 0.0, "bytes": 0.0,
               "collective_bytes": 0.0, "g_full": 0.0, "g_traffic": 0.0}
        if comp is None:
            return out
        self._memo[comp_name] = out  # cycle guard
        for op in comp.ops:
            if op.opcode in ("parameter", "constant", "get-tuple-element",
                             "tuple", "bitcast"):
                continue
            res_bytes, _ = _shape_info(op.type_str)
            opnd_bytes = 0.0
            for o in self._operand_names(op.rest):
                b, _ = _shape_info(self.shapes.get(o, ""))
                opnd_bytes += b
            if op.opcode in _EW_FLOP:
                out["ew_flops"] += _elements(op.type_str)
            elif op.opcode == "reduce":
                # one combiner application per input element (up to const
                # factors) — charge operand elements, excluding the inits
                operands = self._operand_names(op.rest)
                n_in = max(1, len(operands) // 2)
                for o in operands[:n_in]:
                    out["ew_flops"] += _elements(self.shapes.get(o, ""))
            elif op.opcode == "sort":
                operands = self._operand_names(op.rest)
                if operands:
                    e = _elements(self.shapes.get(operands[0], ""))
                    if e > 1:
                        out["ew_flops"] += e * math.log2(e)
            if op.opcode == "while":
                body = self._attr(op.rest, "body")
                cond = self._attr(op.rest, "condition")
                trips = self._trip_count(op.rest, cond)
                if trips is None:
                    trips = 1
                    self.dynamic_loops += 1
                sub = self.comp_cost(body) if body else None
                subc = self.comp_cost(cond) if cond else None
                for k in ("dot_flops", "ew_flops", "bytes", "collective_bytes"):
                    out[k] += trips * (
                        (sub[k] if sub else 0.0) + (subc[k] if subc else 0.0)
                    )
                continue
            if op.opcode == "conditional":
                branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|true_computation=(%[\w\-.]+)|false_computation=(%[\w\-.]+))", op.rest)
                names: List[str] = []
                for g in branches:
                    for item in g:
                        if item:
                            names.extend(re.findall(r"%[\w\-.]+", item))
                if names:
                    subs = [self.comp_cost(n) for n in names]
                    for k in ("dot_flops", "ew_flops", "bytes", "collective_bytes"):
                        out[k] += max(s[k] for s in subs)
                continue
            if op.opcode == "call":
                tgt = self._attr(op.rest, "to_apply")
                if tgt:
                    sub = self.comp_cost(tgt)
                    for k in ("dot_flops", "ew_flops", "bytes", "collective_bytes"):
                        out[k] += sub[k]
                continue
            if op.opcode in ("fusion", "custom-call"):
                # fusion bodies don't touch HBM; count dots inside though,
                # and discount operands that are only read through gathers
                tgt = self._attr(op.rest, "calls") or self._attr(op.rest, "to_apply")
                g_full = g_traffic = 0.0
                if tgt:
                    sub = self.comp_cost(tgt)
                    out["dot_flops"] += sub["dot_flops"]
                    out["ew_flops"] += sub["ew_flops"]
                    g_full, g_traffic = sub["g_full"], sub["g_traffic"]
                out["bytes"] += res_bytes + max(0.0, opnd_bytes - g_full) + g_traffic
                continue
            if op.opcode.removesuffix("-start") in _COLLECTIVES:
                if op.opcode.endswith("-done"):
                    continue
                if op.opcode.startswith("all-gather"):
                    moved = res_bytes  # gather output > operand; count output
                else:
                    moved = opnd_bytes
                out["collective_bytes"] += moved
                out["bytes"] += res_bytes + opnd_bytes
                continue
            if op.opcode == "dot":
                out["dot_flops"] += self._dot_flops(op)
            if op.opcode in ("gather", "dynamic-slice"):
                # traffic = rows actually read + indices + result, NOT the
                # whole source array (else a C-row gather from a [T, d]
                # activation is charged T·d bytes)
                operands = self._operand_names(op.rest)
                idx_bytes = 0.0
                src_bytes = 0.0
                if operands:
                    src_bytes, _ = _shape_info(self.shapes.get(operands[0], ""))
                for o in operands[1:]:
                    b, _ = _shape_info(self.shapes.get(o, ""))
                    idx_bytes += b
                traffic = 2 * res_bytes + idx_bytes
                out["bytes"] += traffic
                out["g_full"] += src_bytes
                out["g_traffic"] += traffic
                continue
            if op.opcode in ("scatter", "dynamic-update-slice"):
                # read-modify-write of the touched region: 2× updates +
                # indices (the untouched target region is aliased in place)
                operands = self._operand_names(op.rest)
                tgt_bytes = 0.0
                if operands:
                    tgt_bytes, _ = _shape_info(self.shapes.get(operands[0], ""))
                upd_idx_bytes = 0.0
                for o in operands[1:]:
                    b, _ = _shape_info(self.shapes.get(o, ""))
                    upd_idx_bytes += b
                traffic = 2 * upd_idx_bytes
                out["bytes"] += traffic
                out["g_full"] += tgt_bytes
                out["g_traffic"] += traffic
                continue
            if op.opcode in _ELEMENTWISE:
                out["bytes"] += res_bytes
            else:
                out["bytes"] += res_bytes + opnd_bytes
        return out

    def entry_cost(self) -> Dict[str, float]:
        c = dict(self.comp_cost(self.entry))
        c["flops"] = c["dot_flops"] + c["ew_flops"]
        c["dynamic_loops"] = self.dynamic_loops
        return c


def analyze(hlo_text: str) -> Dict[str, float]:
    return HloCost(hlo_text).entry_cost()
